//! Minimal std-only stand-in for the `anyhow` crate, vendored so the
//! offline build has no registry dependency (the same substitution DESIGN.md
//! documents for `rand`/`proptest`/`criterion`).
//!
//! Covers exactly the surface the minisa crate uses: `Error`, `Result`,
//! the `anyhow!` / `bail!` / `ensure!` macros, and the `Context` extension
//! trait on `Result` and `Option`. Error values keep a human-readable
//! context chain ("outer: inner"), which is what our call sites rely on.

use std::error::Error as StdError;
use std::fmt;

/// A type-erased error with a context chain.
pub struct Error {
    /// Context messages, outermost first, ending with the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self { chain: vec![m.to_string()] }
    }

    /// Push an outer context message.
    fn wrap<C: fmt::Display>(mut self, c: C) -> Self {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Mirror anyhow's Debug: message plus a Caused-by chain.
        match self.chain.split_first() {
            Some((head, rest)) if !rest.is_empty() => {
                writeln!(f, "{head}")?;
                writeln!(f, "\nCaused by:")?;
                for (i, c) in rest.iter().enumerate() {
                    writeln!(f, "    {i}: {c}")?;
                }
                Ok(())
            }
            _ => write!(f, "{}", self.chain.join(": ")),
        }
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

/// `anyhow::Result`, defaulting the error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context extension for `Result` and `Option` (the two impls minisa uses).
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(c))
    }
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::Error::msg(format!($msg)) };
    ($msg:literal, $($arg:tt)*) => { $crate::Error::msg(format!($msg, $($arg)*)) };
    ($err:expr $(,)?) => { $crate::Error::msg(format!("{}", $err)) };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => { return Err($crate::anyhow!($($t)*)) };
}

/// `assert!`-like guard that returns an [`Error`] instead of panicking.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*)
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chains() {
        let r: Result<()> = Err(io_err()).context("opening manifest");
        let e = r.unwrap_err();
        assert_eq!(e.to_string(), "opening manifest: gone");
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3u32).with_context(|| "unused").unwrap(), 3);
    }

    #[test]
    fn macros_work() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
        let e = anyhow!(io_err());
        assert_eq!(e.to_string(), "gone");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "gone");
    }
}
