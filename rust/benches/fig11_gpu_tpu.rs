//! Regenerates **Fig. 11**: iso-power (575 W) latency comparison —
//! FEATHER+ (64 × 16×256 mesh) vs RTX 5090 vs TPU v6e-8 — with the
//! compute-utilization line.
//!
//! Paper reference: geomean 23.7× vs the GPU and 7.8× vs the TPU; >60%
//! FEATHER+ utilization on irregular shapes; ~30% slower than the TPU on
//! perfectly-aligned GEMMs.

use minisa::coordinator::compare_devices;
use minisa::mapper::search::MapperOptions;
use minisa::report::{f2, pct, Table};
use minisa::util::geomean;
use minisa::workloads;

fn main() {
    let small = std::env::var("MINISA_BENCH_SMALL").is_ok();
    let ws = if small { workloads::suite_small() } else { workloads::suite50() };
    let opts = MapperOptions { full_layout_search: false, ..Default::default() };
    let rows = compare_devices(&ws, &opts, 16);
    let mut t = Table::new(
        "Fig. 11: latency (µs) and utilization at iso-575W",
        &["workload", "category", "FEATHER+ µs", "GPU µs", "TPU µs", "util", "vs GPU", "vs TPU"],
    );
    let mut vs_gpu = Vec::new();
    let mut vs_tpu = Vec::new();
    let mut irregular_utils = Vec::new();
    for r in &rows {
        let g = r.gpu_us / r.feather_us.max(1e-9);
        let p = r.tpu_us / r.feather_us.max(1e-9);
        vs_gpu.push(g);
        vs_tpu.push(p);
        if r.workload.is_irregular() {
            irregular_utils.push(r.feather_utilization);
        }
        t.row(vec![
            r.workload.name.clone(),
            r.workload.category.clone(),
            f2(r.feather_us),
            f2(r.gpu_us),
            f2(r.tpu_us),
            pct(r.feather_utilization),
            f2(g),
            f2(p),
        ]);
    }
    println!("{}", t.render());
    println!(
        "geomean: {}x vs RTX5090 (paper 23.7x), {}x vs TPUv6e-8 (paper 7.8x)",
        f2(geomean(&vs_gpu)),
        f2(geomean(&vs_tpu))
    );
    if !irregular_utils.is_empty() {
        println!(
            "mean FEATHER+ utilization on irregular shapes: {} (paper: >60%)",
            pct(minisa::util::mean(&irregular_utils))
        );
    }
    let _ = t.write_csv(std::path::Path::new("results/bench_fig11.csv"));
}
