//! Regenerates **Fig. 10**: end-to-end speedup of MINISA over the
//! micro-instruction baseline and the stall analysis, across the nine
//! (AH, AW) configurations on the workload suite.
//!
//! Paper reference: geomean speedup 1× (≤64 PEs) → 1.9× (16×16) → 7.5×
//! (16×64) → 31.6× (16×256); MINISA stall ≈ 0 everywhere.
//!
//! Full suite by default; set MINISA_BENCH_SMALL=1 for the fast slice.

use minisa::arch::ArchConfig;
use minisa::coordinator::{evaluate_suite, summarize_by_config};
use minisa::mapper::search::MapperOptions;
use minisa::report::{f2, pct, Table};
use minisa::workloads;

fn main() {
    let small = std::env::var("MINISA_BENCH_SMALL").is_ok();
    let ws = if small { workloads::suite_small() } else { workloads::suite50() };
    let opts = MapperOptions { full_layout_search: false, ..Default::default() };
    let t0 = std::time::Instant::now();
    let rows = evaluate_suite(&ArchConfig::paper_sweep(), &ws, &opts, 16);
    eprintln!(
        "fig10: {} points in {:.1}s ({} workloads × 9 configs)",
        rows.len(),
        t0.elapsed().as_secs_f64(),
        ws.len()
    );
    let paper: &[(&str, f64)] =
        &[("16x16", 1.9), ("16x64", 7.5), ("16x256", 31.6), ("4x4", 1.0), ("8x8", 1.0)];
    let mut t = Table::new(
        "Fig. 10: geomean end-to-end speedup + stall analysis",
        &["config", "geo_speedup", "paper", "micro_stall", "minisa_stall"],
    );
    for s in summarize_by_config(&rows) {
        let p = paper.iter().find(|p| p.0 == s.config).map(|p| f2(p.1)).unwrap_or_default();
        t.row(vec![
            s.config,
            f2(s.geo_speedup),
            p,
            pct(s.mean_stall_micro),
            pct(s.mean_stall_minisa),
        ]);
    }
    println!("\n{}", t.render());
    let _ = t.write_csv(std::path::Path::new("results/bench_fig10.csv"));
}
