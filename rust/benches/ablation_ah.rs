//! Regenerates the **§VI-D2 AH-scaling ablation**: with AW=64, scaling AH
//! 4 → 16 yields 2.6–4× speedup from larger dot products and intra-column
//! parallelism, but raises the compute granularity (utilization becomes
//! more sensitive to VN size / small K).

use minisa::arch::ArchConfig;
use minisa::coordinator::evaluate_suite;
use minisa::mapper::search::MapperOptions;
use minisa::report::{f2, pct, Table};
use minisa::util::geomean;
use minisa::workloads::{self, Gemm};

fn main() {
    let mut ws = workloads::suite_small();
    // Add a tiny-K workload to expose the granularity sensitivity.
    ws.push(Gemm::new("tiny_k10", "FHE-BConv", 65536, 10, 21));
    let opts = MapperOptions { full_layout_search: false, ..Default::default() };
    let mut t = Table::new(
        "§VI-D2: scaling AH at AW=64",
        &["AH", "geo cycles", "speedup vs 4", "mean util", "util(tiny K=10)"],
    );
    let mut base = None;
    for ah in [4usize, 8, 16] {
        let cfg = ArchConfig::paper(ah, 64);
        let rows = evaluate_suite(&[cfg], &ws, &opts, 16);
        let cycles: Vec<f64> = rows.iter().map(|r| r.decision.report.total_cycles).collect();
        let utils: Vec<f64> = rows.iter().map(|r| r.decision.report.utilization()).collect();
        let tiny = rows
            .iter()
            .find(|r| r.workload.name == "tiny_k10")
            .map(|r| r.decision.report.utilization())
            .unwrap_or(0.0);
        let g = geomean(&cycles);
        let speedup = base.map(|b: f64| b / g).unwrap_or(1.0);
        if base.is_none() {
            base = Some(g);
        }
        t.row(vec![
            ah.to_string(),
            format!("{g:.0}"),
            f2(speedup),
            pct(minisa::util::mean(&utils)),
            pct(tiny),
        ]);
    }
    println!("{}", t.render());
    println!("Paper: AH 4→16 gives 2.6–4× speedup; small-K utilization drops as AH grows.");
}
