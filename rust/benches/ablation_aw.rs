//! Regenerates the **§VI-D1 AW-scaling ablation**: with AH=16, scaling AW
//! 64 → 256 should deliver near-linear speedup (~4×) at almost unchanged
//! utilization (columns are independent parallelism), with interconnect
//! cost growing subquadratically.

use minisa::arch::ArchConfig;
use minisa::arch::area::area;
use minisa::coordinator::evaluate_suite;
use minisa::mapper::search::MapperOptions;
use minisa::report::{f2, pct, Table};
use minisa::util::geomean;
use minisa::workloads;

fn main() {
    let ws = workloads::suite_small();
    let opts = MapperOptions { full_layout_search: false, ..Default::default() };
    let mut t = Table::new(
        "§VI-D1: scaling AW at AH=16",
        &["AW", "geo cycles", "speedup vs 64", "mean util", "area µm² (F+)", "area ratio"],
    );
    let mut base_cycles = None;
    let mut base_area = None;
    for aw in [64usize, 128, 256] {
        let cfg = ArchConfig::paper(16, aw);
        let rows = evaluate_suite(&[cfg.clone()], &ws, &opts, 16);
        let cycles: Vec<f64> = rows.iter().map(|r| r.decision.report.total_cycles).collect();
        let utils: Vec<f64> = rows.iter().map(|r| r.decision.report.utilization()).collect();
        let g = geomean(&cycles);
        let a = area(&cfg, true).total_um2;
        let speedup = base_cycles.map(|b: f64| b / g).unwrap_or(1.0);
        let aratio = base_area.map(|b: f64| a / b).unwrap_or(1.0);
        if base_cycles.is_none() {
            base_cycles = Some(g);
            base_area = Some(a);
        }
        t.row(vec![
            aw.to_string(),
            format!("{g:.0}"),
            f2(speedup),
            pct(minisa::util::mean(&utils)),
            format!("{a:.0}"),
            f2(aratio),
        ]);
    }
    println!("{}", t.render());
    println!("Paper: AW 64→256 gives ~4× speedup at ~flat utilization; cost O(AW)–O(AW log AW).");
}
