//! Hot-path micro/macro timings for the §Perf optimization pass:
//!
//! * mapper candidate scoring (the evaluate inner loop),
//! * full single-shape mapper search — **before** (seed serial phase-2
//!   layout refinement) and **after** (parallel bounded refinement),
//! * trace lowering,
//! * functional simulation throughput (MACs/s) — **before** (reference
//!   per-wave interpreter) and **after** (compiled `WavePlan` execution),
//! * blocked multi-row execution (`BlockSim` + `Program::execute_rows`)
//!   vs the sequential scalar chunk loop, per element backend — the §Perf
//!   tentpole's headline MACs/s + rows/s numbers,
//! * the `ModP::mac_block` delayed-reduction MAC kernel vs the sequential
//!   Montgomery fold, per field,
//! * 5-engine pipeline simulation,
//! * ISA encode throughput.
//!
//! Methodology (docs/PERF.md): every timing runs `util::bench::time` with
//! explicit warmup iterations before the measured ones and reports the
//! **median** of the sample set (min/mean also recorded); throughput
//! metrics derive from the median. CI diffs the emitted JSON against the
//! committed baseline via `tools/bench_regression.py`.
//!
//! EXPERIMENTS.md §Perf records the deltas; this binary also emits the
//! machine-readable `BENCH_hotpath.json` (run from `rust/`:
//! `cargo bench --bench hotpath`) so the perf trajectory is tracked
//! across PRs.

use minisa::arch::ArchConfig;
use minisa::functional::FunctionalSim;
use minisa::isa::encode::Codec;
use minisa::isa::inst::Inst;
use minisa::mapper::exec::{execute_program, execute_program_on};
use minisa::mapper::lower_gemm;
use minisa::mapper::search::{candidates, estimate, search, MapperOptions};
use minisa::mapping::{Dataflow, MappingCfg, StreamCfg};
use minisa::perf::{simulate, TilePlan};
use minisa::util::bench::{time, BenchLog};
use minisa::util::Lcg;
use minisa::workloads::Gemm;

fn main() {
    let mut log = BenchLog::new();
    let opts = MapperOptions::default();

    // --- Mapper scoring (per-candidate cost) ---
    let cfg = ArchConfig::paper(16, 256);
    let g = Gemm::new("gpt", "GPT-oss", 2048, 2880, 5120);
    let cands = candidates(&cfg, &g, &opts);
    println!("candidates for {g} @ {}: {}", cfg.name(), cands.len());
    log.bench("mapper/score one candidate (16x256)", 10, 2000, || {
        estimate(&cfg, &g, &cands[cands.len() / 2], 4, 0, true)
    });

    // --- Full search: seed-equivalent serial phase-2 vs parallel bounded ---
    // Baseline isolates the phase-2 change: seed phase-1 already ran at the
    // default thread count, so only `refine_serial` differs from `opts`.
    let serial_opts = MapperOptions { refine_serial: true, ..Default::default() };
    let (_, t_before) = log.bench("mapper/full search gpt@16x256 (serial phase2)", 1, 5, || {
        search(&cfg, &g, &serial_opts).unwrap()
    });
    let (_, t_after) = log.bench("mapper/full search gpt@16x256", 1, 5, || {
        search(&cfg, &g, &opts).unwrap()
    });
    let search_speedup = t_before.median_ns / t_after.median_ns;
    println!("  mapper search speedup (serial → parallel phase-2): {search_speedup:.2}x");
    log.metric("mapper_search_gpt_16x256_before_median_ms", t_before.median_ns / 1e6);
    log.metric("mapper_search_gpt_16x256_after_median_ms", t_after.median_ns / 1e6);
    log.metric("mapper_search_gpt_16x256_speedup", search_speedup);

    let small_cfg = ArchConfig::paper(4, 16);
    let small_g = Gemm::new("bconv", "FHE", 65536, 40, 88);
    log.bench("mapper/full search bconv@4x16", 1, 5, || {
        search(&small_cfg, &small_g, &opts).unwrap()
    });

    // --- Lowering ---
    let cfg44 = ArchConfig::paper(4, 4);
    let gl = Gemm::new("low", "t", 256, 40, 88);
    let d = search(&cfg44, &gl, &opts).unwrap();
    let (prog, _) = log.bench("lower/256x40x88@4x4", 2, 50, || {
        lower_gemm(&cfg44, &gl, &d.choice, d.i_order, d.w_order, d.o_order)
    });
    println!("  trace: {} insts, {} invocations", prog.trace.len(), prog.invocations);

    // --- Functional simulation throughput: reference vs compiled plans ---
    let mut rng = Lcg::new(5);
    let iv: Vec<i32> = (0..gl.m * gl.k).map(|_| rng.range(0, 15) as i32 - 7).collect();
    let wv: Vec<i32> = (0..gl.k * gl.n).map(|_| rng.range(0, 15) as i32 - 7).collect();
    let macs = gl.macs() as f64;
    let (ref_out, t_ref) = time(2, 15, || {
        let mut sim = FunctionalSim::new(&cfg44);
        sim.use_plans = false;
        execute_program_on(&mut sim, &gl, &prog, &iv, &wv).unwrap()
    });
    t_ref.report("funcsim/256x40x88@4x4 (reference)");
    log.record("funcsim/256x40x88@4x4 (reference)", t_ref);
    let (out, t_plan) = time(2, 15, || execute_program(&cfg44, &gl, &prog, &iv, &wv).unwrap());
    t_plan.report("funcsim/256x40x88@4x4 (wave plans)");
    log.record("funcsim/256x40x88@4x4 (wave plans)", t_plan);
    assert_eq!(ref_out, out, "plan path must be bit-identical");
    let rate_before = macs / (t_ref.median_ns / 1e9) / 1e6;
    let rate_after = macs / (t_plan.median_ns / 1e9) / 1e6;
    println!(
        "  functional sim rate: {rate_before:.1} → {rate_after:.1} MMAC/s \
         ({:.2}x, {} outputs)",
        rate_after / rate_before,
        out.len()
    );
    log.metric("funcsim_mmacs_per_s_before", rate_before);
    log.metric("funcsim_mmacs_per_s_after", rate_after);
    log.metric("funcsim_rows_per_s_after", gl.m as f64 / (t_plan.median_ns / 1e9));
    log.metric("funcsim_speedup", t_ref.median_ns / t_plan.median_ns);

    // --- Pipeline model ---
    let plans: Vec<TilePlan> = (0..100_000)
        .map(|i| TilePlan {
            instr_bits: 180,
            compute_cycles: 512 + (i % 7) as u64,
            drain_cycles: 20,
            macs_used: 1 << 16,
            ..Default::default()
        })
        .collect();
    log.bench("perf/pipeline sim 100k tiles", 2, 30, || simulate(&cfg, &plans));

    // --- ISA encode throughput ---
    let codec = Codec::new(&cfg);
    let insts: Vec<Inst> = (0..1000)
        .map(|i| {
            if i % 2 == 0 {
                Inst::ExecuteMapping(MappingCfg {
                    r0: i % 64,
                    c0: (i * 7) % 128,
                    g_r: 1 + (i % 16),
                    g_c: 1 + (i % 8),
                    s_r: 1,
                    s_c: 16,
                })
            } else {
                Inst::ExecuteStreaming(StreamCfg {
                    df: Dataflow::WoS,
                    m0: 0,
                    s_m: 1 + (i % 4),
                    t: 64,
                    vn_size: 16,
                })
            }
        })
        .collect();
    let (bytes, t) = log.bench("isa/encode 1000 instructions", 5, 200, || {
        codec.encode_all(&insts).unwrap()
    });
    println!(
        "  encode rate: {:.1} Minst/s ({} bytes)",
        1000.0 / (t.median_ns / 1e9) / 1e6,
        bytes.len()
    );

    // --- Functional-sim raw wave loop ---
    let mut sim = FunctionalSim::new(&cfg44);
    let a = sim.hbm_alloc(1024);
    sim.hbm_write(a, &vec![1i32; 1024]);
    log.bench("funcsim/load 256 rows", 5, 500, || {
        sim.exec(&Inst::Load {
            target: minisa::isa::inst::BufTarget::Streaming,
            hbm_addr: a,
            rows: 256,
        })
        .unwrap()
    });

    bench_blocked(&mut log);

    match log.write_json("BENCH_hotpath.json") {
        Ok(()) => println!("\nwrote BENCH_hotpath.json"),
        Err(e) => eprintln!("\nfailed to write BENCH_hotpath.json: {e}"),
    }

    bench_arith();
    bench_artifact();
    bench_registry();
}

/// §Perf tentpole: the blocked multi-row serving executor
/// ([`execute_program_words_blocked`]: `BlockSim` lanes through
/// `Program::execute_rows` → `WavePlan::execute_rows`) against the
/// sequential scalar chunk loop it replaces, per element backend. The two
/// paths are asserted bit-identical here (the full battery lives in
/// `tests/plan_equivalence.rs`); the acceptance bar is ≥2x MACs/s on the
/// Montgomery fields (`blocked_*_vs_scalar_speedup`).
fn bench_blocked(log: &mut BenchLog) {
    use minisa::arith::{decode_words, ElemType};
    use minisa::coordinator::serve::{execute_program_words_blocked, execute_program_words_on};
    use minisa::functional::{BlockSim, DEFAULT_ROW_BLOCK};
    use minisa::mapper::chain::Chain;
    use minisa::program::Program;

    println!("\n--- blocked multi-row execution vs scalar chunk loop ---");
    let cfg = ArchConfig::paper(4, 4);
    let o = MapperOptions { full_layout_search: false, threads: 1, ..Default::default() };
    let chain = Chain::mlp("blk", 8, &[40, 88, 24]);
    let program = Program::compile(&cfg, &chain, &o).expect("bench chain compiles on 4x4");
    let m = program.rows();
    let kf = program.in_features();
    // Two full blocks of row chunks so the gather loop and the block-refill
    // boundary are both exercised.
    let rows = 2 * DEFAULT_ROW_BLOCK * m;

    for elem in [ElemType::Goldilocks, ElemType::BabyBear, ElemType::I32] {
        minisa::with_element!(elem, E => {
            let mut rng = Lcg::new(0xB10C);
            let input = elem.sample_words(&mut rng, rows * kf);
            let w: Vec<Vec<E>> = chain
                .layers
                .iter()
                .map(|g| decode_words::<E>(&elem.sample_words(&mut rng, g.k * g.n)))
                .collect();
            // MAC count of the whole batched request, from a fresh sim (the
            // blocked path is stats-identical — the battery asserts it).
            let mut count_sim: FunctionalSim<E> = FunctionalSim::new(&cfg);
            execute_program_words_on(&mut count_sim, &program, rows, &input, &w).unwrap();
            let macs = count_sim.stats.macs_used as f64;

            let (scalar_out, t_scalar) = time(2, 15, || {
                let mut sim: FunctionalSim<E> = FunctionalSim::new(&cfg);
                execute_program_words_on(&mut sim, &program, rows, &input, &w).unwrap()
            });
            t_scalar.report(&format!("funcsim/blocked-{elem} {rows} rows (scalar loop)"));
            log.record(&format!("funcsim/blocked-{elem} {rows} rows (scalar loop)"), t_scalar);
            let (blocked_out, t_blocked) = time(2, 15, || {
                let mut block: BlockSim<E> = BlockSim::new(&cfg);
                execute_program_words_blocked(&mut block, &program, rows, &input, &w).unwrap()
            });
            t_blocked.report(&format!("funcsim/blocked-{elem} {rows} rows (blocked)"));
            log.record(&format!("funcsim/blocked-{elem} {rows} rows (blocked)"), t_blocked);
            assert_eq!(scalar_out, blocked_out, "{elem}: blocked path must be bit-identical");

            let rate_scalar = macs / (t_scalar.median_ns / 1e9) / 1e6;
            let rate_blocked = macs / (t_blocked.median_ns / 1e9) / 1e6;
            let speedup = t_scalar.median_ns / t_blocked.median_ns;
            println!(
                "  {elem}: {rate_scalar:.1} → {rate_blocked:.1} MMAC/s ({speedup:.2}x, \
                 {rows} rows)"
            );
            log.metric(&format!("blocked_{elem}_scalar_mmacs_per_s"), rate_scalar);
            log.metric(&format!("blocked_{elem}_mmacs_per_s"), rate_blocked);
            log.metric(
                &format!("blocked_{elem}_rows_per_s"),
                rows as f64 / (t_blocked.median_ns / 1e9),
            );
            log.metric(&format!("blocked_{elem}_vs_scalar_speedup"), speedup);
        });
    }
}

/// `arith` hot path: the Montgomery mul-accumulate inner loop (what
/// `ModP`'s `Element::mac` runs per MAC slot) against the naive
/// `(a·b) % p` u128 reduction it replaces — per field, plus the end-to-end
/// functional-sim MAC rate over a field. Emits `BENCH_arith.json`.
fn bench_arith() {
    use minisa::arith::{naive_gemm_e, BabyBear, Goldilocks, ModP, PrimeField};

    println!("\n--- arith: Montgomery vs naive % reduction ---");
    let mut alog = BenchLog::new();

    fn field_case<F: PrimeField>(alog: &mut BenchLog) {
        const LEN: usize = 1 << 14;
        let mut rng = Lcg::new(0xA217);
        let xs: Vec<u64> = (0..LEN).map(|_| rng.next_u64() % F::P).collect();
        let ys: Vec<u64> = (0..LEN).map(|_| rng.next_u64() % F::P).collect();
        let xm: Vec<ModP<F>> = xs.iter().map(|&x| ModP::<F>::new(x)).collect();
        let ym: Vec<ModP<F>> = ys.iter().map(|&y| ModP::<F>::new(y)).collect();

        // Naive: widen to u128, `%` per multiply AND per accumulate — the
        // schoolbook inner loop the Montgomery form replaces.
        let (naive_sum, t_naive) =
            alog.bench(&format!("arith/{} naive % mul-acc {}", F::NAME, LEN), 3, 200, || {
                let mut acc: u64 = 0;
                for (&a, &b) in xs.iter().zip(&ys) {
                    let prod = ((a as u128 * b as u128) % F::P as u128) as u64;
                    acc = ((acc as u128 + prod as u128) % F::P as u128) as u64;
                }
                acc
            });
        // Montgomery: one REDC per multiply, add-with-conditional-subtract
        // per accumulate (the `Element::mac` path).
        let (mont_sum, t_mont) =
            alog.bench(&format!("arith/{} montgomery mul-acc {}", F::NAME, LEN), 3, 200, || {
                let mut acc = ModP::<F>::default();
                for (&a, &b) in xm.iter().zip(&ym) {
                    acc = acc + a * b;
                }
                acc
            });
        assert_eq!(mont_sum.to_u64(), naive_sum, "{}: reductions agree", F::NAME);
        let speedup = t_naive.median_ns / t_mont.median_ns;
        println!("  {}: montgomery {speedup:.2}x vs naive %", F::NAME);
        alog.metric(&format!("arith_{}_mont_vs_naive_speedup", F::NAME), speedup);
        alog.metric(
            &format!("arith_{}_mont_mmacs_per_s", F::NAME),
            LEN as f64 / (t_mont.median_ns / 1e9) / 1e6,
        );
        // Blocked delayed-REDC kernel (`ModP::mac_block`, the backend of
        // `Element::dot` in the wave hot loop): one REDC per
        // `DELAYED_MACS`-sized group instead of one per multiply.
        let (blk_sum, t_blk) =
            alog.bench(&format!("arith/{} mac_block dot {}", F::NAME, LEN), 3, 200, || {
                ModP::<F>::mac_block(ModP::<F>::default(), &xm, &ym)
            });
        assert_eq!(blk_sum.to_u64(), naive_sum, "{}: mac_block agrees", F::NAME);
        let blk_speedup = t_mont.median_ns / t_blk.median_ns;
        println!(
            "  {}: mac_block {blk_speedup:.2}x vs sequential montgomery (delay group {})",
            F::NAME,
            ModP::<F>::DELAYED_MACS
        );
        alog.metric(
            &format!("arith_{}_mac_block_mmacs_per_s", F::NAME),
            LEN as f64 / (t_blk.median_ns / 1e9) / 1e6,
        );
        alog.metric(&format!("arith_{}_mac_block_vs_mont_speedup", F::NAME), blk_speedup);
    }

    field_case::<BabyBear>(&mut alog);
    field_case::<Goldilocks>(&mut alog);
    field_case::<minisa::arith::PallasStyle>(&mut alog);

    // End-to-end: a field GEMM through the naive generic reference (upper
    // bound on the functional-sim arithmetic throughput over ModP).
    {
        type Gl = ModP<Goldilocks>;
        let (m, k, n) = (32usize, 64usize, 32usize);
        let mut rng = Lcg::new(0xF00D);
        let iv: Vec<Gl> = (0..m * k).map(|_| Gl::new(rng.next_u64())).collect();
        let wv: Vec<Gl> = (0..k * n).map(|_| Gl::new(rng.next_u64())).collect();
        let (_, t) = alog.bench("arith/goldilocks naive_gemm_e 32x64x32", 2, 50, || {
            naive_gemm_e::<Gl>(&iv, &wv, m, k, n)
        });
        alog.metric(
            "arith_goldilocks_gemm_mmacs_per_s",
            (m * k * n) as f64 / (t.median_ns / 1e9) / 1e6,
        );
    }

    match alog.write_json("BENCH_arith.json") {
        Ok(()) => println!("wrote BENCH_arith.json"),
        Err(e) => eprintln!("failed to write BENCH_arith.json: {e}"),
    }
}

/// Artifact axis (EXPERIMENTS.md §Artifacts): the paper's
/// instruction-traffic reduction, measured on the *deployable* form —
/// `.minisa` container bytes (checksummed, with decisions) vs the
/// micro-instruction baseline bytes across one suite row per category —
/// plus the compile-once/load-everywhere timing split
/// (`Program::compile` vs `Artifact::load + Program::from_artifact`).
/// Emits `BENCH_artifact.json`.
fn bench_artifact() {
    use minisa::arith::ElemType;
    use minisa::artifact::{Artifact, Compiler};
    use minisa::mapper::chain::Chain;
    use minisa::program::Program;
    use minisa::workloads::{self, ntt};

    println!("\n--- artifact: container bytes vs micro-instruction baseline ---");
    let mut alog = BenchLog::new();
    let cfg = ArchConfig::paper(16, 64);
    let o = MapperOptions { full_layout_search: false, threads: 1, ..Default::default() };

    // One representative row per Table IV category (NTTs at suite scale for
    // the byte axis; functional execution is not involved).
    let suite = workloads::suite50();
    let pick = |name: &str| suite.iter().find(|g| g.name == name).unwrap().clone();
    for g in [pick("bconv_00"), pick("fhe_ntt_1024"), pick("zkp_ntt_8192"), pick("gpt_oss_64x2048")]
    {
        let d = search(&cfg, &g, &o).unwrap_or_else(|| panic!("{} maps on 16x64", g.name));
        let lowered = lower_gemm(&cfg, &g, &d.choice, d.i_order, d.w_order, d.o_order);
        let chain = Chain { layers: vec![g.clone()] };
        let art = Compiler::new(&cfg).options(o.clone()).compile(&chain).expect("compiles");
        let container = art.to_bytes().len() as f64;
        let micro = lowered.micro_bytes() as f64;
        let reduction = micro / container;
        println!(
            "  {}: container {} B (trace {} B) vs micro {} B → {reduction:.1}x as-deployed",
            g.name,
            container,
            art.trace_bytes.len(),
            micro
        );
        alog.metric(&format!("artifact_container_bytes_{}", g.name), container);
        alog.metric(&format!("artifact_trace_bytes_{}", g.name), art.trace_bytes.len() as f64);
        alog.metric(&format!("micro_bytes_{}", g.name), micro);
        alog.metric(&format!("artifact_vs_micro_reduction_{}", g.name), reduction);
    }

    // Compile-once/load-everywhere: mapper-run compile vs artifact load
    // (decode + deterministic re-lowering + plan recompilation) on a
    // 3-layer chain with an attached weights payload.
    {
        let ccfg = ArchConfig::paper(4, 4);
        let chain = Chain::mlp("bench_load", 32, &[40, 88, 24]);
        let mut rng = Lcg::new(0xA57);
        let weights: Vec<Vec<u64>> = chain
            .layers
            .iter()
            .map(|g| ElemType::I32.sample_words(&mut rng, g.k * g.n))
            .collect();
        let (_, t_compile) = alog.bench("artifact/compile 3-layer chain @4x4", 1, 10, || {
            Program::compile(&ccfg, &chain, &o).unwrap()
        });
        let art =
            Compiler::new(&ccfg).options(o.clone()).weights(weights).compile(&chain).unwrap();
        let path = std::env::temp_dir()
            .join(format!("minisa_bench_{}.minisa", std::process::id()));
        art.save(&path).unwrap();
        let (loaded, t_load) = alog.bench("artifact/load 3-layer chain @4x4", 1, 10, || {
            let a = Artifact::load(&path).unwrap();
            Program::from_artifact(&a).unwrap()
        });
        std::fs::remove_file(&path).ok();
        assert_eq!(loaded.fused.len(), art.inst_count, "loaded stream intact");
        let speedup = t_compile.median_ns / t_load.median_ns;
        println!("  load vs compile: {speedup:.1}x faster (zero mapper runs on load)");
        alog.metric("artifact_compile_median_ms", t_compile.median_ns / 1e6);
        alog.metric("artifact_load_median_ms", t_load.median_ns / 1e6);
        alog.metric("artifact_load_vs_compile_speedup", speedup);
        // NTT scaling entry: sanity that the scaled suite path also ships.
        let zkp = ntt::scaled(&pick("zkp_ntt_8192"), 64);
        let zart = Compiler::new(&ccfg)
            .options(o.clone())
            .compile(&Chain { layers: vec![zkp] })
            .unwrap();
        alog.metric("artifact_container_bytes_zkp_ntt_64_scaled", zart.to_bytes().len() as f64);
    }

    match alog.write_json("BENCH_artifact.json") {
        Ok(()) => println!("wrote BENCH_artifact.json"),
        Err(e) => eprintln!("failed to write BENCH_artifact.json: {e}"),
    }
}

/// Registry axis (EXPERIMENTS.md §Registry): what the content-addressed
/// store buys on the session-bringup path —
///
/// * **load vs compile**: `Registry::load` (hash-verified get + zero-copy
///   decode + `Program::from_artifact`) against a mapper-run
///   `Program::compile` of the same chain;
/// * **cold vs warm**: a program-cache miss (full fetch/verify/decode)
///   against a hit (one `Arc` clone);
/// * the gated serving-throughput metric: rows/s through the
///   *cache-loaded* program + shared weights, so a regression anywhere in
///   the zero-copy pipeline (decode, `WordMatrix` views, `WordWeights`
///   bridging) trips the §Perf bench gate.
///
/// Emits `BENCH_registry.json`.
fn bench_registry() {
    use minisa::arith::ElemType;
    use minisa::artifact::Compiler;
    use minisa::coordinator::serve::{execute_program_words, WordWeights};
    use minisa::mapper::chain::Chain;
    use minisa::program::Program;
    use minisa::registry::{LoadedWeights, MemBackend, Registry};

    println!("\n--- registry: load vs compile, cold vs warm ---");
    let mut rlog = BenchLog::new();
    let cfg = ArchConfig::paper(4, 4);
    let o = MapperOptions { full_layout_search: false, threads: 1, ..Default::default() };
    let elem = ElemType::Goldilocks;
    let chain = Chain::mlp("bench_reg", 32, &[40, 88, 24]);
    let mut rng = Lcg::new(0x2E6);
    let weights: Vec<Vec<u64>> = chain
        .layers
        .iter()
        .map(|g| elem.sample_words(&mut rng, g.k * g.n))
        .collect();
    let art = Compiler::new(&cfg)
        .options(o.clone())
        .elem(elem)
        .weights(weights)
        .compile(&chain)
        .unwrap();

    let (_, t_compile) = rlog.bench("registry/compile 3-layer chain @4x4", 1, 10, || {
        Program::compile(&cfg, &chain, &o).unwrap()
    });

    // Cold: every iteration pays the full miss — fetch, hash-verify,
    // zero-copy decode, deterministic re-lowering (capacity 0 disables the
    // cache, so no iteration ever hits).
    let cold = Registry::new(Box::new(MemBackend::new()), 0);
    let key = cold.put(&art).unwrap();
    let (_, t_cold) = rlog.bench("registry/load cold (cache disabled)", 1, 10, || {
        cold.load(key).unwrap()
    });

    // Warm: the steady state of a fleet bringing up its Nth session of one
    // content hash — a cache hit is one Arc clone.
    let warm = Registry::new(Box::new(MemBackend::new()), 4);
    let wkey = warm.put(&art).unwrap();
    let (loaded, t_warm) = rlog.bench("registry/load warm (cache hit)", 5, 2000, || {
        warm.load(wkey).unwrap().0
    });
    let cs = warm.cache_stats();
    assert_eq!(cs.misses, 1, "exactly the arming load misses");

    let load_vs_compile = t_compile.median_ns / t_cold.median_ns;
    let warm_vs_cold = t_cold.median_ns / t_warm.median_ns;
    println!(
        "  load vs compile: {load_vs_compile:.1}x; warm hit vs cold miss: {warm_vs_cold:.1}x"
    );
    rlog.metric("registry_compile_median_ms", t_compile.median_ns / 1e6);
    rlog.metric("registry_cold_load_median_ms", t_cold.median_ns / 1e6);
    rlog.metric("registry_warm_load_median_us", t_warm.median_ns / 1e3);
    rlog.metric("registry_load_vs_compile_speedup", load_vs_compile);
    rlog.metric("registry_warm_vs_cold_speedup", warm_vs_cold);

    // Serving throughput through the cache-loaded session — the gated
    // metric (rows/s marker): executes the loaded program against the
    // shared weight allocation exactly as a fleet device would.
    let rows = 2 * loaded.program.rows();
    let input = elem.sample_words(&mut rng, rows * loaded.program.in_features());
    let ww: &WordWeights = match &loaded.weights {
        LoadedWeights::Words(w) => w,
        LoadedWeights::F32(_) => unreachable!("bench artifact is word-typed"),
    };
    let (out, t_exec) = rlog.bench("registry/exec loaded program", 2, 15, || {
        execute_program_words(&loaded.program, rows, &input, ww).unwrap()
    });
    assert!(!out.is_empty());
    rlog.metric(
        "registry_loaded_exec_rows_per_s",
        rows as f64 / (t_exec.median_ns / 1e9),
    );

    match rlog.write_json("BENCH_registry.json") {
        Ok(()) => println!("wrote BENCH_registry.json"),
        Err(e) => eprintln!("failed to write BENCH_registry.json: {e}"),
    }
}
