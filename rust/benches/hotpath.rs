//! Hot-path micro/macro timings for the §Perf optimization pass:
//!
//! * mapper candidate scoring (the evaluate inner loop),
//! * full single-shape mapper search,
//! * trace lowering,
//! * functional simulation throughput (MACs/s),
//! * 5-engine pipeline simulation,
//! * ISA encode throughput.
//!
//! Run before/after optimization; EXPERIMENTS.md §Perf records the deltas.

use minisa::arch::ArchConfig;
use minisa::functional::FunctionalSim;
use minisa::isa::encode::Codec;
use minisa::isa::inst::Inst;
use minisa::mapper::exec::execute_program;
use minisa::mapper::lower_gemm;
use minisa::mapper::search::{candidates, estimate, search, MapperOptions};
use minisa::mapping::{Dataflow, MappingCfg, StreamCfg};
use minisa::perf::{simulate, TilePlan};
use minisa::util::bench::bench;
use minisa::util::Lcg;
use minisa::workloads::Gemm;

fn main() {
    let opts = MapperOptions::default();

    // --- Mapper scoring (per-candidate cost) ---
    let cfg = ArchConfig::paper(16, 256);
    let g = Gemm::new("gpt", "GPT-oss", 2048, 2880, 5120);
    let cands = candidates(&cfg, &g, &opts);
    println!("candidates for {g} @ {}: {}", cfg.name(), cands.len());
    bench("mapper/score one candidate (16x256)", 10, 2000, || {
        estimate(&cfg, &g, &cands[cands.len() / 2], 4, 0, true)
    });

    // --- Full search ---
    bench("mapper/full search gpt@16x256", 1, 5, || search(&cfg, &g, &opts).unwrap());
    let small_cfg = ArchConfig::paper(4, 16);
    let small_g = Gemm::new("bconv", "FHE", 65536, 40, 88);
    bench("mapper/full search bconv@4x16", 1, 5, || {
        search(&small_cfg, &small_g, &opts).unwrap()
    });

    // --- Lowering ---
    let cfg44 = ArchConfig::paper(4, 4);
    let gl = Gemm::new("low", "t", 256, 40, 88);
    let d = search(&cfg44, &gl, &opts).unwrap();
    let prog = bench("lower/256x40x88@4x4", 2, 50, || {
        lower_gemm(&cfg44, &gl, &d.choice, d.i_order, d.w_order, d.o_order)
    });
    println!("  trace: {} insts, {} invocations", prog.trace.len(), prog.invocations);

    // --- Functional simulation throughput ---
    let mut rng = Lcg::new(5);
    let iv: Vec<i32> = (0..gl.m * gl.k).map(|_| rng.range(0, 15) as i32 - 7).collect();
    let wv: Vec<i32> = (0..gl.k * gl.n).map(|_| rng.range(0, 15) as i32 - 7).collect();
    let (out, t) = minisa::util::bench::time(1, 10, || {
        execute_program(&cfg44, &gl, &prog, &iv, &wv).unwrap()
    });
    t.report("funcsim/256x40x88@4x4");
    let macs = gl.macs() as f64;
    println!(
        "  functional sim rate: {:.1} MMAC/s ({} outputs)",
        macs / (t.median_ns / 1e9) / 1e6,
        out.len()
    );

    // --- Pipeline model ---
    let plans: Vec<TilePlan> = (0..100_000)
        .map(|i| TilePlan {
            instr_bits: 180,
            compute_cycles: 512 + (i % 7) as u64,
            drain_cycles: 20,
            macs_used: 1 << 16,
            ..Default::default()
        })
        .collect();
    bench("perf/pipeline sim 100k tiles", 2, 30, || simulate(&cfg, &plans));

    // --- ISA encode throughput ---
    let codec = Codec::new(&cfg);
    let insts: Vec<Inst> = (0..1000)
        .map(|i| {
            if i % 2 == 0 {
                Inst::ExecuteMapping(MappingCfg {
                    r0: i % 64,
                    c0: (i * 7) % 128,
                    g_r: 1 + (i % 16),
                    g_c: 1 + (i % 8),
                    s_r: 1,
                    s_c: 16,
                })
            } else {
                Inst::ExecuteStreaming(StreamCfg {
                    df: Dataflow::WoS,
                    m0: 0,
                    s_m: 1 + (i % 4),
                    t: 64,
                    vn_size: 16,
                })
            }
        })
        .collect();
    let (bytes, t) = minisa::util::bench::time(5, 200, || codec.encode_all(&insts).unwrap());
    t.report("isa/encode 1000 instructions");
    println!(
        "  encode rate: {:.1} Minst/s ({} bytes)",
        1000.0 / (t.median_ns / 1e9) / 1e6,
        bytes.len()
    );

    // --- Functional-sim raw wave loop ---
    let mut sim = FunctionalSim::new(&cfg44);
    let a = sim.hbm_alloc(1024);
    sim.hbm_write(a, &vec![1i32; 1024]);
    bench("funcsim/load 256 rows", 5, 500, || {
        sim.exec(&Inst::Load {
            target: minisa::isa::inst::BufTarget::Streaming,
            hbm_addr: a,
            rows: 256,
        })
        .unwrap()
    });
}
