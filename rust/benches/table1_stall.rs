//! Regenerates **Table I**: explicit instruction-fetch stall of the
//! micro-instruction baseline on `I[65536×40] · W[40×88]` across six
//! FEATHER+ sizes, plus the MINISA column (always ~0%).
//!
//! Paper reference row: 4×4→0, 8×8→0, 4×64→75.3%, 16×16→65.2%,
//! 8×128→90.4%, 16×256→96.9%.

use minisa::arch::ArchConfig;
use minisa::coordinator::evaluate_one;
use minisa::mapper::search::MapperOptions;
use minisa::report::{pct, Table};
use minisa::util::bench::bench;
use minisa::workloads::table1_workload;

fn main() {
    let g = table1_workload();
    let opts = MapperOptions { full_layout_search: false, ..Default::default() };
    let paper = [0.0, 0.0, 0.753, 0.652, 0.904, 0.969];
    let mut t = Table::new(
        "Table I: fetch stall for I[65536x40]·W[40x88] (micro-instruction baseline)",
        &["FEATHER+", "stall(model)", "stall(paper)", "stall(MINISA)", "speedup"],
    );
    for (cfg, p) in ArchConfig::table1_sweep().into_iter().zip(paper) {
        let row = bench(&format!("table1/{}", cfg.name()), 0, 3, || {
            evaluate_one(&cfg, &g, &opts).expect("feasible")
        });
        t.row(vec![
            cfg.name(),
            pct(row.micro.instr_stall_fraction()),
            pct(p),
            pct(row.decision.report.instr_stall_fraction()),
            format!("{:.2}", row.speedup()),
        ]);
    }
    println!("\n{}", t.render());
    let _ = t.write_csv(std::path::Path::new("results/bench_table1.csv"));
}
