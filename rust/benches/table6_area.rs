//! Regenerates **Table VI**: post-PnR area/power of FEATHER vs FEATHER+
//! from the component model, side-by-side with the published TSMC-28nm
//! numbers (buffers at depth 64 as registers, like the paper's PnR).

use minisa::arch::area::{table_vi, PAPER_TABLE_VI};
use minisa::report::Table;

fn main() {
    let mut t = Table::new(
        "Table VI: area (µm²) and power (mW), FEATHER → FEATHER+",
        &[
            "setup", "F model", "F paper", "F+ model", "F+ paper",
            "Δarea model", "Δarea paper", "Δpower model", "Δpower paper",
        ],
    );
    for row in table_vi() {
        let p = PAPER_TABLE_VI.iter().find(|p| p.0 == row.config).unwrap();
        t.row(vec![
            row.config.clone(),
            format!("{:.0}", row.feather_um2),
            format!("{:.0}", p.1),
            format!("{:.0}", row.featherplus_um2),
            format!("{:.0}", p.2),
            format!("{:.2}%", row.area_increase_pct),
            format!("{:.2}%", (p.2 / p.1 - 1.0) * 100.0),
            format!("{:.2}%", row.power_increase_pct),
            format!("{:.2}%", (p.4 / p.3 - 1.0) * 100.0),
        ]);
    }
    println!("{}", t.render());
    println!("Takeaway (§VI-E): all-to-all distribution costs ≤ ~8%, amortized at scale.");
    let _ = t.write_csv(std::path::Path::new("results/bench_table6.csv"));
}
