//! Regenerates **Fig. 13**: cycle-level latency breakdown (Compute,
//! Load In/W, Out→Stream, Store Out) and compute utilization for
//! representative workloads on FEATHER+ 4×64, 16×64 and 16×256.
//!
//! Paper takeaway: utilization stays high across irregular shapes
//! (K=10-class and K=2ⁿ alike); regular shapes approach peak.

use minisa::arch::ArchConfig;
use minisa::mapper::search::{search, MapperOptions};
use minisa::report::{pct, Table};
use minisa::workloads::{self, Gemm};

fn main() {
    let reps: Vec<Gemm> = vec![
        workloads::table1_workload(),
        Gemm::new("bconv_k28", "FHE-BConv", 65536, 28, 72),
        workloads::fhe_ntt().swap_remove(0),
        workloads::zkp_ntt().swap_remove(0),
        workloads::gpt_oss().swap_remove(0),
        Gemm::new("aligned_2k", "regular", 2048, 2048, 2048),
    ];
    let opts = MapperOptions { full_layout_search: false, ..Default::default() };
    for (ah, aw) in [(4usize, 64usize), (16, 64), (16, 256)] {
        let cfg = ArchConfig::paper(ah, aw);
        let mut t = Table::new(
            &format!("Fig. 13 breakdown on FEATHER+ {} (cycles, overlapping engines)", cfg.name()),
            &["workload", "compute", "load_in", "load_w", "out→stream", "store_out", "total", "util"],
        );
        for g in &reps {
            let Some(d) = search(&cfg, g, &opts) else { continue };
            let r = &d.report;
            t.row(vec![
                g.name.clone(),
                format!("{:.0}", r.compute_cycles),
                format!("{:.0}", r.load_in_cycles),
                format!("{:.0}", r.load_w_cycles),
                format!("{:.0}", r.out_stream_cycles),
                format!("{:.0}", r.store_out_cycles),
                format!("{:.0}", r.total_cycles),
                pct(r.utilization()),
            ]);
        }
        println!("{}", t.render());
    }
    println!("Takeaway: FEATHER+ keeps PEs busy on irregular shapes; rigid padding losses don't apply.");
}
