//! Regenerates **Fig. 12**: off-chip instruction bytes, MINISA vs
//! micro-instructions, and the instruction-to-data byte ratios (black/red
//! lines), per workload at 16×256 and as geomeans per config.
//!
//! Paper reference: micro-instructions reach ~100× the data bytes; MINISA
//! reduces instruction bytes by geomean ~2·10⁴–2·10⁵× at 16×256 (max
//! 4.4·10⁵×), making the ratio negligible (<0.1%).

use minisa::arch::ArchConfig;
use minisa::coordinator::{evaluate_suite, summarize_by_config};
use minisa::mapper::search::MapperOptions;
use minisa::report::{eng, Table};
use minisa::util::geomean;
use minisa::workloads;

fn main() {
    let small = std::env::var("MINISA_BENCH_SMALL").is_ok();
    let ws = if small { workloads::suite_small() } else { workloads::suite50() };
    let opts = MapperOptions { full_layout_search: false, ..Default::default() };

    // Per-workload detail at the largest scale.
    let big = ArchConfig::paper(16, 256);
    let rows = evaluate_suite(&[big.clone()], &ws, &opts, 16);
    let mut t = Table::new(
        "Fig. 12 @16x256: instruction bytes and instruction:data ratios",
        &["workload", "micro_B", "minisa_B", "reduction", "i:d micro", "i:d MINISA"],
    );
    let mut reductions = Vec::new();
    let mut max_red = 0f64;
    for r in &rows {
        reductions.push(r.instr_reduction());
        max_red = max_red.max(r.instr_reduction());
        t.row(vec![
            r.workload.name.clone(),
            r.micro_bytes.to_string(),
            r.minisa_bytes.to_string(),
            eng(r.instr_reduction()),
            format!("{:.1}", r.micro_instr_to_data()),
            format!("{:.2e}", r.minisa_instr_to_data()),
        ]);
    }
    println!("{}", t.render());
    println!(
        "geomean reduction @16x256: {} (paper ~2e4–2e5), max {} (paper 4.4e5)",
        eng(geomean(&reductions)),
        eng(max_red)
    );
    let micro_ratio_max = rows.iter().map(|r| r.micro_instr_to_data()).fold(0.0, f64::max);
    println!("max micro instruction:data ratio: {micro_ratio_max:.1}× (paper: up to ~100×)");

    // Geomeans per config.
    let all = evaluate_suite(&ArchConfig::paper_sweep(), &ws, &opts, 16);
    let mut s = Table::new(
        "Fig. 12: geomean instruction-byte reduction per config",
        &["config", "geo_reduction"],
    );
    for c in summarize_by_config(&all) {
        s.row(vec![c.config, eng(c.geo_instr_reduction)]);
    }
    println!("{}", s.render());
    let _ = t.write_csv(std::path::Path::new("results/bench_fig12_detail.csv"));
    let _ = s.write_csv(std::path::Path::new("results/bench_fig12_summary.csv"));
}
