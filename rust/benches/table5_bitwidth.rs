//! Regenerates **Table V**: MINISA instruction bitwidths per architecture
//! configuration, next to the published values, and times the bit-level
//! codec (encode+decode roundtrip) since it sits on the trace-generation
//! path.

use minisa::arch::ArchConfig;
use minisa::isa::bitwidth::table_v;
use minisa::isa::encode::Codec;
use minisa::isa::inst::Inst;
use minisa::mapping::{Dataflow, MappingCfg, StreamCfg};
use minisa::report::Table;
use minisa::util::bench::bench;

fn main() {
    // Published Table V (Set*VNLayout, E.Mapping, E.Streaming) per config.
    let paper: &[(&str, u32, u32, u32)] = &[
        ("4x4", 42, 81, 57),
        ("4x16", 40, 83, 51),
        ("4x64", 38, 85, 45),
        ("8x8", 43, 86, 58),
        ("8x32", 41, 88, 52),
        ("8x128", 39, 90, 46),
        ("16x16", 44, 91, 59),
        ("16x64", 42, 93, 53),
        ("16x256", 40, 95, 47),
    ];
    let mut t = Table::new(
        "Table V: ISA bitwidths (model | paper)",
        &["config", "Set*VNLayout", "E.Mapping", "E.Streaming"],
    );
    for row in table_v() {
        let p = paper.iter().find(|p| p.0 == row.config);
        let fmt = |m: u32, pv: Option<u32>| match pv {
            Some(v) => format!("{m} | {v}"),
            None => m.to_string(),
        };
        t.row(vec![
            row.config.clone(),
            fmt(row.set_layout_bits, p.map(|p| p.1)),
            fmt(row.execute_mapping_bits, p.map(|p| p.2)),
            fmt(row.execute_streaming_bits, p.map(|p| p.3)),
        ]);
    }
    println!("{}", t.render());

    // Codec hot path: encode + decode a compute-trigger pair.
    let cfg = ArchConfig::paper(16, 256);
    let codec = Codec::new(&cfg);
    let prog = [
        Inst::ExecuteMapping(MappingCfg { r0: 3, c0: 128, g_r: 8, g_c: 4, s_r: 1, s_c: 16 }),
        Inst::ExecuteStreaming(StreamCfg {
            df: Dataflow::WoS,
            m0: 0,
            s_m: 2,
            t: 512,
            vn_size: 16,
        }),
    ];
    bench("codec/encode+decode EM+ES pair", 100, 10_000, || {
        let bytes = codec.encode_all(&prog).unwrap();
        codec.decode_n(&bytes, 2).unwrap()
    });
    let _ = t.write_csv(std::path::Path::new("results/bench_table5.csv"));
}
