//! Ablation for the §III-B refinement claims: how much on-chip buffer
//! capacity FEATHER's point-to-point distribution wastes on duplicated
//! data, which FEATHER+'s all-to-all crossbars eliminate — for the actual
//! mapper decisions of the evaluation workloads.

use minisa::arch::dedup::analyze_decision;
use minisa::arch::ArchConfig;
use minisa::mapper::search::{search, MapperOptions};
use minisa::report::{f2, Table};
use minisa::workloads;

fn main() {
    let opts = MapperOptions { full_layout_search: false, ..Default::default() };
    for (ah, aw) in [(4usize, 16usize), (16, 64)] {
        let cfg = ArchConfig::paper(ah, aw);
        let mut t = Table::new(
            &format!("FEATHER duplication requirement on {} (per interior invocation)", cfg.name()),
            &["workload", "distinct VNs", "FEATHER VN slots", "dup words", "inflation"],
        );
        for g in workloads::suite_small() {
            let Some(d) = search(&cfg, &g, &opts) else { continue };
            let r = analyze_decision(&cfg, &d, g.m);
            t.row(vec![
                g.name.clone(),
                (r.distinct_stationary_vns + r.distinct_streamed_vns).to_string(),
                (r.feather_stationary_vns + r.feather_streamed_vns).to_string(),
                r.duplicated_words().to_string(),
                f2(r.inflation()),
            ]);
        }
        println!("{}", t.render());
    }
    println!(
        "Takeaway (§III-B): whenever the mapper replicates VN groups across columns\n\
         (duplication knob > 1) or shares a stream, FEATHER must materialize physical\n\
         copies; FEATHER+ multicasts one resident copy — zero duplicated words."
    );
}
