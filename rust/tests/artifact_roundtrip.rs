//! Artifact round-trip battery — the acceptance criteria of the
//! compile/serve redesign, as tests:
//!
//! * `compile → save → load → re-encode` is **byte-stable** across
//!   randomized suite-shaped chains and both dataflows (the container
//!   serialization is a fixed point, and a loaded program re-emits the
//!   exact stream it was loaded from);
//! * a `Program` loaded via `Program::from_artifact` serves
//!   **bit-identically** to the freshly compiled one — for every `Element`
//!   backend — with **zero mapper runs** at load (`searches_run()` frozen,
//!   `program_compiles == 0`, `artifact_loads == 1`) and zero runtime plan
//!   compiles.

use std::sync::Arc;

use minisa::arch::ArchConfig;
use minisa::arith::{decode_words, encode_words, ElemType};
use minisa::artifact::{Artifact, Compiler};
use minisa::coordinator::serve::{spawn, ArtifactSource, NaiveExecutor, Request};
use minisa::functional::FunctionalSim;
use minisa::mapper::chain::Chain;
use minisa::mapper::search::searches_run;
use minisa::mapping::Dataflow;
use minisa::program::Program;
use minisa::util::prop::forall;
use minisa::util::Lcg;
use minisa::with_element;
use minisa::workloads::Gemm;

fn temp_path(tag: &str, case: usize) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("minisa_{tag}_{}_{case}.minisa", std::process::id()))
}

/// compile → save → load → re-encode is byte-stable, across randomized
/// chains (suite-shaped feature ladders, 1–3 layers, every element type).
#[test]
fn compile_save_load_reencode_byte_stable() {
    forall("artifact-byte-stability", 20, |g| {
        // Case id drawn from the generator (forall takes `Fn`, so no
        // mutable capture): seeds the weights and names the temp file.
        let case = g.usize(1, 1_000_000);
        let (ah, aw) = *g.pick(&[(4usize, 4usize), (4, 8), (8, 8)]);
        let cfg = ArchConfig::paper(ah, aw);
        // Suite-shaped small ladders (BConv-like narrow K, NTT-like square,
        // GPT-like widen/narrow), 1–3 layers.
        let n_layers = g.usize(1, 3);
        let widths = [8usize, 12, 16, 20, 24];
        let mut dims = vec![*g.pick(&widths)];
        for _ in 0..n_layers {
            dims.push(*g.pick(&widths));
        }
        let m = *g.pick(&[4usize, 8, 10]);
        let chain = Chain::mlp("prop", m, &dims);
        let elem = *g.pick(&ElemType::ALL);
        let mut rng = Lcg::new(case as u64 * 7919 + 5);
        let weights: Vec<Vec<u64>> =
            chain.layers.iter().map(|gm| elem.sample_words(&mut rng, gm.k * gm.n)).collect();
        let art = Compiler::new(&cfg)
            .elem(elem)
            .weights(weights)
            .compile(&chain)
            .expect("chain compiles");
        let bytes = art.to_bytes();
        // Parse → serialize is a fixed point.
        let back = Artifact::from_bytes(&bytes).expect("parses");
        assert_eq!(back.to_bytes(), bytes, "container serialization fixed point");
        // Through the filesystem.
        let path = temp_path("prop", case);
        art.save(&path).unwrap();
        let loaded = Artifact::load(&path).expect("loads");
        std::fs::remove_file(&path).ok();
        assert_eq!(loaded.to_bytes(), bytes, "file round-trip fixed point");
        // Through a Program and back: the loaded executable form re-encodes
        // to the exact stream it was loaded from.
        let program = Program::from_artifact(&loaded).expect("loads into a Program");
        let re = program.to_artifact(loaded.payload.clone()).expect("re-packages");
        assert_eq!(re.to_bytes(), bytes, "load → re-encode byte-stable");
    });
}

/// Byte stability specifically across **both dataflows**: the alternating
/// 3-boundary MLP compiles layers under WO-S *and* IO-S (asserted), and the
/// artifact still round-trips exactly.
#[test]
fn both_dataflows_roundtrip_byte_stable() {
    let cfg = ArchConfig::paper(4, 4);
    let chain = Chain::mlp("alt", 32, &[32, 32, 32, 32]);
    let art = Compiler::new(&cfg).compile(&chain).unwrap();
    let program = Program::from_artifact(&art).unwrap();
    let dfs: Vec<Dataflow> = program.layers.iter().map(|l| l.decision.choice.df).collect();
    assert!(
        dfs.contains(&Dataflow::WoS) && dfs.contains(&Dataflow::IoS),
        "both dataflows present: {dfs:?}"
    );
    assert!(program.elided >= 1, "elision survives the trip");
    let bytes = art.to_bytes();
    assert_eq!(Artifact::from_bytes(&bytes).unwrap().to_bytes(), bytes);
    assert_eq!(program.to_artifact(None).unwrap().to_bytes(), bytes);
}

/// In-process acceptance: for i32 / f32 / Goldilocks, the loaded program
/// executes bit-identically to the freshly compiled one, with zero mapper
/// runs at load and zero runtime plan compiles.
#[test]
fn loaded_program_executes_bit_identically_in_process() {
    let cfg = ArchConfig::paper(4, 4);
    let chain = Chain::mlp("acc", 8, &[12, 16, 8]);
    // Same deterministic profile as Compiler's default, so `fresh` and the
    // artifact's program come from identical searches.
    let opts = minisa::mapper::search::MapperOptions {
        full_layout_search: false,
        threads: 1,
        ..Default::default()
    };
    let fresh = Program::compile(&cfg, &chain, &opts).unwrap();
    for elem in [ElemType::I32, ElemType::F32, ElemType::Goldilocks] {
        let mut rng = Lcg::new(101);
        let weight_words: Vec<Vec<u64>> =
            chain.layers.iter().map(|g| elem.sample_words(&mut rng, g.k * g.n)).collect();
        let art = Compiler::new(&cfg)
            .elem(elem)
            .weights(weight_words.clone())
            .compile(&chain)
            .unwrap();
        let searches_before = searches_run();
        let loaded = Program::from_artifact(&art).unwrap();
        assert_eq!(searches_run(), searches_before, "{elem}: load must not run the mapper");
        assert_eq!(loaded.fused.insts, fresh.fused.insts, "{elem}: same canonical stream");
        assert_eq!(loaded.plan_count(), fresh.plan_count());
        let input_words = elem.sample_words(&mut rng, fresh.rows() * fresh.in_features());
        let identical = with_element!(elem, E => {
            let w: Vec<Vec<E>> = weight_words.iter().map(|m| decode_words::<E>(m)).collect();
            let input: Vec<E> = decode_words::<E>(&input_words);
            let mut sim_fresh: FunctionalSim<E> = FunctionalSim::new(&cfg);
            let mut sim_loaded: FunctionalSim<E> = FunctionalSim::new(&cfg);
            let a = fresh.execute(&mut sim_fresh, &input, &w).unwrap();
            let b = loaded.execute(&mut sim_loaded, &input, &w).unwrap();
            assert_eq!(sim_loaded.plan_compiles, 0, "{elem}: loaded plans came recompiled-at-load");
            a == b && b == loaded.reference(&input, &w)
        });
        assert!(identical, "{elem}: loaded execution bit-identical to compiled + reference");
    }
}

/// Serving acceptance: a session registered from an artifact answers every
/// request with exactly the bytes the compiled session answers, for every
/// element backend — and its server never compiles (`program_compiles == 0`,
/// `artifact_loads == 1`).
#[test]
fn artifact_session_matches_compiled_session_every_backend() {
    for elem in ElemType::ALL {
        let cfg = ArchConfig::paper(4, 4);
        let chain = Chain::mlp("serve", 4, &[8, 12, 8]);
        let mut rng = Lcg::new(7 + elem as u64);
        let weight_words: Vec<Vec<u64>> =
            chain.layers.iter().map(|g| elem.sample_words(&mut rng, g.k * g.n)).collect();
        let art = Compiler::new(&cfg)
            .elem(elem)
            .weights(weight_words.clone())
            .compile(&chain)
            .unwrap();

        let (tx_c, rx_c, h_c, server_c) = spawn(&cfg, Arc::new(NaiveExecutor));
        let (tx_a, rx_a, h_a, server_a) = spawn(&cfg, Arc::new(NaiveExecutor));
        let pid_c = if elem == ElemType::F32 {
            let wf: Vec<Vec<f32>> =
                weight_words.iter().map(|m| decode_words::<f32>(m)).collect();
            server_c.register_chain(&chain, wf).unwrap()
        } else {
            server_c.register_chain_elem(&chain, weight_words.clone(), elem).unwrap()
        };
        let searches_before = searches_run();
        let pid_a = server_a.register(ArtifactSource::Artifact(Box::new(art))).unwrap();
        assert_eq!(searches_run(), searches_before, "{elem}: registration ran the mapper");

        for id in 0..4u64 {
            let words = elem.sample_words(&mut rng, 4 * 8);
            if elem == ElemType::F32 {
                let input: Vec<f32> = decode_words::<f32>(&words);
                tx_c.send(Request::for_program(id, pid_c, 4, input.clone())).unwrap();
                tx_a.send(Request::for_program(id, pid_a, 4, input)).unwrap();
            } else {
                tx_c.send(Request::for_program_words(id, pid_c, 4, words.clone())).unwrap();
                tx_a.send(Request::for_program_words(id, pid_a, 4, words)).unwrap();
            }
        }
        let mut got_c = std::collections::HashMap::new();
        let mut got_a = std::collections::HashMap::new();
        for _ in 0..4 {
            let rc = rx_c.recv().unwrap();
            assert!(rc.error.is_none(), "{elem}: {:?}", rc.error);
            got_c.insert(rc.id, (rc.output, rc.output_words));
            let ra = rx_a.recv().unwrap();
            assert!(ra.error.is_none(), "{elem}: {:?}", ra.error);
            got_a.insert(ra.id, (ra.output, ra.output_words));
        }
        // f32 outputs compare as bits so the check is truly bit-level.
        for (id, (out_c, words_c)) in &got_c {
            let (out_a, words_a) = &got_a[id];
            let bits = |v: &[f32]| -> Vec<u64> { encode_words::<f32>(v) };
            assert_eq!(bits(out_a), bits(out_c), "{elem}: request {id} f32 output bits");
            assert_eq!(words_a, words_c, "{elem}: request {id} word output");
        }
        drop(tx_c);
        drop(tx_a);
        let stats_c = h_c.join().unwrap();
        let stats_a = h_a.join().unwrap();
        assert_eq!(stats_c.program_compiles, 1, "{elem}: compiled session compiles once");
        assert_eq!(stats_c.artifact_loads, 0);
        assert_eq!(stats_a.program_compiles, 0, "{elem}: artifact session never compiles");
        assert_eq!(stats_a.artifact_loads, 1);
        assert_eq!(stats_a.program_served, 4);
        assert_eq!(server_a.fleet().plan_compiles(), 0, "{elem}: no runtime plan compiles");
    }
}

/// A corrupted container never loads into a Program (checksum layer), and a
/// container whose *accounting* drifted from its stream is rejected by the
/// loader's fidelity proof (semantic layer).
#[test]
fn corruption_is_rejected_at_both_layers() {
    let cfg = ArchConfig::paper(4, 4);
    let chain = Chain::mlp("tamper", 4, &[8, 8]);
    let art = Compiler::new(&cfg).compile(&chain).unwrap();
    let bytes = art.to_bytes();
    // Checksum layer: any flipped body byte fails from_bytes.
    for idx in [12usize, bytes.len() / 2, bytes.len() - 9] {
        let mut bad = bytes.clone();
        bad[idx] ^= 0x10;
        assert!(Artifact::from_bytes(&bad).is_err(), "flip at {idx} must be caught");
    }
    // Semantic layer: valid checksum, lying accounting.
    let mut lying = art.clone();
    lying.decision.elided += 1;
    let relaundered = Artifact::from_bytes(&lying.to_bytes()).unwrap();
    assert!(Program::from_artifact(&relaundered).is_err(), "accounting drift must be caught");
    // Semantic layer: stream swapped for a different chain's stream.
    let other = Compiler::new(&cfg)
        .compile(&Chain { layers: vec![Gemm::new("o", "t", 4, 8, 8), Gemm::new("p", "t", 4, 8, 8)] })
        .unwrap();
    let mut franken = art.clone();
    franken.trace_bytes = other.trace_bytes.clone();
    franken.inst_count = other.inst_count;
    franken.layer_starts = vec![0];
    let franken = Artifact::from_bytes(&franken.to_bytes()).unwrap();
    assert!(Program::from_artifact(&franken).is_err(), "foreign stream must be caught");
}
