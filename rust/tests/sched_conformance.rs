//! Cost-aware scheduler conformance battery (§ROADMAP item 3 tentpole).
//!
//! Locks down the heterogeneous-fleet scheduling invariants end to end:
//!
//! * **Bit-identity** — executing a compiled [`Program`] over a mixed-arch
//!   fleet (cost-aware placement + weighted row sharding) equals
//!   single-device execution bit-for-bit, for SatI32 / f32 / Goldilocks
//!   backends, adversarial row counts and shard minima, with **zero**
//!   runtime wave-plan compiles.
//! * **Placement eligibility** — session work never lands on a device whose
//!   arch fingerprint differs from the program's; dropping every eligible
//!   device yields the typed `no eligible device` error instead of a hang
//!   or a wrong-arch execution.
//! * **Weighted sharding** — `sched::weighted_shards` conserves rows and
//!   pins the stitch order (ranges ascend with device order).
//! * **Predicted vs simulated** — `sched::predict_cycles` tracks the
//!   functional simulator's `SimStats`-derived streaming cycles within a
//!   stated tolerance for the suite GEMM shapes, on paper(4,4) and a larger
//!   arch, and [`FleetReport`] surfaces the per-device error.
//! * **Shared fetch channel** — at a fetch-bound arch the micro twin
//!   contends for the group's shared instruction channel while MINISA does
//!   not, so MINISA's modeled fleet-wide speedup exceeds 1 (the paper's
//!   per-device stall headline re-emerging at fleet scale).
//!
//! Property cases come from `util::prop` (`forall`), so failures print a
//! reproducible seed + draw log.

use std::collections::HashMap;
use std::sync::Arc;

use minisa::arch::ArchConfig;
use minisa::arith::{decode_words, naive_gemm_e, ElemType, Element};
use minisa::artifact::{arch_fingerprint, Compiler};
use minisa::coordinator::fleet::{Fleet, FleetOptions};
use minisa::coordinator::sched::{cycles_per_row, predict_cycles, weighted_shards, DevicePrediction};
use minisa::coordinator::serve::{
    execute_program_words, execute_program_words_on, spawn_with_options, ArtifactSource,
    NaiveExecutor, Request, ServerOptions, WordWeights,
};
use minisa::functional::FunctionalSim;
use minisa::mapper::chain::Chain;
use minisa::mapper::search::MapperOptions;
use minisa::program::Program;
use minisa::util::prop::forall;
use minisa::util::Lcg;
use minisa::with_element;
use minisa::workloads;

/// The backends the scheduler battery must prove conformant (the fourth
/// backend, BabyBear, is covered by `tests/fleet_conformance.rs`).
const BACKENDS: [ElemType; 3] = [ElemType::I32, ElemType::F32, ElemType::Goldilocks];

fn fast() -> MapperOptions {
    MapperOptions { full_layout_search: false, threads: 1, ..Default::default() }
}

/// The arch pool mixed fleets draw from. All pow2-AW (ArchConfig::validate)
/// and all small enough that functional execution stays cheap.
fn arch_pool() -> Vec<ArchConfig> {
    vec![
        ArchConfig::paper(4, 4),
        ArchConfig::paper(4, 8),
        ArchConfig::paper(8, 8),
        ArchConfig::paper(4, 16),
    ]
}

/// One shared chain (M = 5, deliberately odd so batched rows never align
/// with the compiled height), compiled once per pool arch — plans are
/// element-independent, so a single compile per arch serves every backend.
fn compile_pool() -> (Chain, Vec<(ArchConfig, Program)>) {
    let chain = Chain::mlp("sched", 5, &[8, 12, 8]);
    let pool = arch_pool()
        .into_iter()
        .map(|cfg| {
            let p = Program::compile(&cfg, &chain, &fast())
                .unwrap_or_else(|| panic!("chain compiles on {}", cfg.name()));
            (cfg, p)
        })
        .collect();
    (chain, pool)
}

/// Chained naive reference in `elem`'s number system, over an arbitrary row
/// count (unlike `Program::reference`, which is fixed at the compiled M).
fn reference_words(
    chain: &Chain,
    weights: &[Vec<u64>],
    elem: ElemType,
    rows: usize,
    input: &[u64],
) -> Vec<u64> {
    with_element!(elem, E => {
        let w: Vec<Vec<E>> = weights.iter().map(|m| decode_words::<E>(m)).collect();
        let mut act: Vec<E> = decode_words::<E>(input);
        let mut out: Vec<<E as Element>::Acc> = Vec::new();
        for (li, (g, wm)) in chain.layers.iter().zip(&w).enumerate() {
            out = naive_gemm_e::<E>(&act, wm, rows, g.k, g.n);
            if li + 1 < chain.layers.len() {
                act = out.iter().map(|&v| E::reduce(v)).collect();
            }
        }
        out.iter().map(|&v| E::reduce(v).encode()).collect()
    })
}

/// Property: for every backend, mixed-arch fleet composition, row count and
/// (adversarial) `shard_min_rows`, cost-aware fleet execution equals the
/// single-device path bit-for-bit, compiles nothing at runtime, conserves
/// rows, and never places a shard on a fingerprint-ineligible device.
#[test]
fn hetero_fleet_bit_identical_for_all_backends() {
    let (chain, pool) = compile_pool();
    for elem in BACKENDS {
        let mut wrng = Lcg::new(0x5C4ED ^ elem as u64);
        let weights: Vec<Vec<u64>> =
            chain.layers.iter().map(|g| elem.sample_words(&mut wrng, g.k * g.n)).collect();
        forall(&format!("sched-conformance-{elem}"), 18, |g| {
            // Fleet composition: 1–4 devices drawn from the pool, with the
            // target arch guaranteed present somewhere.
            let devices = g.usize(1, 4);
            let target = g.usize(0, pool.len() - 1);
            let mut archs: Vec<ArchConfig> = (0..devices)
                .map(|_| pool[g.usize(0, pool.len() - 1)].0.clone())
                .collect();
            let slot = g.usize(0, devices - 1);
            archs[slot] = pool[target].0.clone();
            let (tcfg, program) = &pool[target];
            let tfp = arch_fingerprint(tcfg);

            let rows = g.usize(1, 23);
            let shard_min_rows = g.usize(1, 40);
            let fleet = Fleet::with_archs(
                &archs,
                Arc::new(NaiveExecutor),
                FleetOptions { shard_min_rows, ..Default::default() },
            );
            let ww = WordWeights::new(weights.clone(), elem);
            let input = elem.sample_words(g.rng(), rows * program.in_features());
            let sharded = fleet
                .run_program_words(None, program, rows, &input, &ww)
                .expect("hetero fleet execution succeeds");
            let single =
                execute_program_words(program, rows, &input, &ww).expect("single-device");
            assert_eq!(
                sharded, single,
                "archs={archs:?} target={} rows={rows} min={shard_min_rows}",
                tcfg.name()
            );
            assert_eq!(fleet.plan_compiles(), 0, "zero runtime plan compiles");
            // Eligibility + conservation: every executed row is accounted
            // to a fingerprint-matching device, nothing else was touched.
            let mut total_rows = 0u64;
            for d in fleet.devices() {
                let st = d.stats();
                if d.fingerprint() != tfp {
                    assert_eq!(
                        (st.shards, st.rows),
                        (0, 0),
                        "device {} ({}) is ineligible for {} work",
                        d.id,
                        d.arch().name(),
                        tcfg.name()
                    );
                }
                total_rows += st.rows;
            }
            assert_eq!(total_rows, rows as u64, "weighted shards conserve rows");
        });
    }
}

/// Deterministic eligibility pins: a mixed fleet keeps session work off the
/// mismatched device even when that device is device 0 (the default-home
/// slot), and dropping every eligible device yields the typed error.
#[test]
fn ineligible_devices_never_touch_session_work() {
    let chain = Chain::mlp("elig", 5, &[8, 12, 8]);
    let small = ArchConfig::paper(4, 4);
    let wide = ArchConfig::paper(4, 8);
    let program = Program::compile(&small, &chain, &fast()).expect("compiles on 4x4");
    let elem = ElemType::Goldilocks;
    let mut rng = Lcg::new(77);
    let weights: Vec<Vec<u64>> =
        chain.layers.iter().map(|g| elem.sample_words(&mut rng, g.k * g.n)).collect();
    let ww = WordWeights::new(weights, elem);
    // Device 0 is the wrong arch: the home/leader fallback must skip it.
    let fleet = Fleet::with_archs(
        &[wide.clone(), small.clone(), small.clone()],
        Arc::new(NaiveExecutor),
        FleetOptions { shard_min_rows: 1, ..Default::default() },
    );
    let input = elem.sample_words(&mut rng, 11 * program.in_features());
    let out = fleet.run_program_words(None, &program, 11, &input, &ww).unwrap();
    assert_eq!(out, execute_program_words(&program, 11, &input, &ww).unwrap());
    let d0 = fleet.devices()[0].stats();
    assert_eq!((d0.shards, d0.rows), (0, 0), "4x8 device never runs 4x4 work");
    // Drop both eligible devices: typed error, not a hang, and still no
    // wrong-arch execution.
    assert!(fleet.fail_device(1));
    assert!(fleet.fail_device(2));
    let err = fleet.run_program_words(None, &program, 11, &input, &ww).unwrap_err().to_string();
    assert!(err.starts_with("no eligible device"), "typed scheduler error, got: {err}");
    let d0 = fleet.devices()[0].stats();
    assert_eq!((d0.shards, d0.rows), (0, 0), "failure path still respects eligibility");
}

/// Regression pins on the public weighted-sharding API: shard ranges always
/// concatenate to `0..rows` in ascending device order (the stitch-order
/// invariant the fleet's output assembly relies on), each shard meets the
/// minimum, and load skews shift rows toward less-loaded / faster devices.
#[test]
fn weighted_shards_conserve_rows_and_pin_stitch_order() {
    let check = |rows: usize, min_rows: usize, preds: &[DevicePrediction]| {
        let shards = weighted_shards(rows, min_rows, preds);
        assert!(!shards.is_empty(), "rows={rows} min={min_rows}");
        let mut next = 0usize;
        let mut last_dev = None;
        for (dev, r) in &shards {
            assert!(*dev < preds.len());
            if let Some(prev) = last_dev {
                assert!(*dev > prev, "stitch order pinned to ascending device order");
            }
            last_dev = Some(*dev);
            assert_eq!(r.start, next, "shards are contiguous in row order");
            assert!(r.len() >= min_rows.min(rows), "shard meets the minimum");
            next = r.end;
        }
        assert_eq!(next, rows, "shards conserve rows");
        shards
    };
    let even = |n: usize| vec![DevicePrediction { pending_cycles: 0.0, cycles_per_row: 4.0 }; n];
    check(24, 1, &even(3));
    check(7, 3, &even(4));
    check(1, 1, &even(7));
    check(100, 100, &even(3)); // min > rows/2 → one shard
    // A heavily loaded device receives fewer rows than its idle peers.
    let mut skew = even(3);
    skew[1].pending_cycles = 1.0e6;
    let shards = check(60, 1, &skew);
    let loaded: usize =
        shards.iter().filter(|(d, _)| *d == 1).map(|(_, r)| r.len()).sum();
    let idle: usize = shards.iter().filter(|(d, _)| *d == 0).map(|(_, r)| r.len()).sum();
    assert!(loaded < idle, "loaded device sheds rows: loaded={loaded} idle={idle}");
    // A faster arch (lower cycles/row) receives more rows.
    let mut rates = even(2);
    rates[1].cycles_per_row = 1.0;
    let shards = check(50, 1, &rates);
    let fast_rows: usize =
        shards.iter().filter(|(d, _)| *d == 1).map(|(_, r)| r.len()).sum();
    assert!(fast_rows > 25, "faster arch pulls the majority: {fast_rows}");
}

/// Step-function pins on the cost model itself: a program charges whole
/// chain passes (`ceil(rows / m)`), never fractions, and `cycles_per_row`
/// is the per-row amortization of one pass.
#[test]
fn predict_cycles_charges_whole_chain_passes() {
    let chain = Chain::mlp("pc", 5, &[8, 12, 8]);
    let cfg = ArchConfig::paper(4, 4);
    let p = Program::compile(&cfg, &chain, &fast()).expect("compiles");
    let m = p.rows();
    assert_eq!(m, 5);
    assert_eq!(predict_cycles(&p, 0), 0.0);
    let one = predict_cycles(&p, 1);
    assert!(one > 0.0);
    assert_eq!(one, p.total_cycles, "any partial chunk costs a whole pass");
    assert_eq!(predict_cycles(&p, m), p.total_cycles);
    assert_eq!(predict_cycles(&p, m + 1), 2.0 * p.total_cycles);
    assert_eq!(predict_cycles(&p, 4 * m), 4.0 * p.total_cycles);
    assert!((cycles_per_row(&p) * m as f64 - p.total_cycles).abs() < 1e-9);
}

/// Served conformance over a mixed-arch fleet: an artifact compiled for the
/// *larger* arch registers against a 4x4-home server (zero mapper runs,
/// zero program compiles), serves bit-exactly, runs only on the matching
/// device, and the fleet report + metrics snapshot surface the per-device
/// predicted-vs-modeled error and the shared fetch-channel contention.
#[test]
fn mixed_arch_server_serves_bit_exact_with_zero_runtime_compiles() {
    let home = ArchConfig::paper(4, 4);
    let big = ArchConfig::paper(4, 16);
    let chain = Chain::mlp("mix", 4, &[8, 12, 8]);
    let elem = ElemType::I32;
    let mut rng = Lcg::new(4242);
    let weights: Vec<Vec<u64>> =
        chain.layers.iter().map(|g| elem.sample_words(&mut rng, g.k * g.n)).collect();
    let art = Compiler::new(&big).weights(weights.clone()).compile(&chain).expect("artifact");
    let opts = ServerOptions {
        device_archs: vec![home.clone(), big.clone()],
        shard_min_rows: 4,
        max_batch: 8,
        ..Default::default()
    };
    let (tx, rx, h, server) = spawn_with_options(&home, Arc::new(NaiveExecutor), opts);
    let pid = server.register(ArtifactSource::Artifact(Box::new(art))).expect("registers");
    let n_req = 6u64;
    let mut expects = HashMap::new();
    for id in 0..n_req {
        // Rows stay multiples of the compiled height, so every dispatched
        // chunk is whole and the prediction must match the modeled cycles
        // exactly (DeviceLoad::predict_err == 0).
        let rows = if id % 2 == 0 { 4 } else { 8 };
        let input = elem.sample_words(&mut rng, rows * 8);
        expects.insert(id, reference_words(&chain, &weights, elem, rows, &input));
        tx.send(Request::for_program_words(id, pid, rows, input)).unwrap();
    }
    for _ in 0..n_req {
        let r = rx.recv().unwrap();
        assert!(r.error.is_none(), "id={}: {:?}", r.id, r.error);
        assert_eq!(&r.output_words, &expects[&r.id], "id={}", r.id);
    }
    drop(tx);
    let stats = h.join().unwrap();
    assert_eq!(stats.program_compiles, 0, "artifact load performs no compile");
    assert_eq!(stats.artifact_loads, 1);
    assert_eq!(stats.program_served, n_req);
    assert_eq!(stats.errors, 0);
    assert_eq!(server.fleet().plan_compiles(), 0, "zero runtime plan compiles");

    let rep = server.fleet_report(1.0);
    let d0 = &rep.devices[0];
    assert_eq!((d0.shards, d0.rows), (0, 0), "4x4 device never runs 4x16 work");
    let d1 = &rep.devices[1];
    assert!(d1.rows > 0 && d1.predicted_cycles > 0.0, "cost-aware dispatch engaged: {d1:?}");
    assert!(
        d1.predict_err() < 1e-9,
        "whole-chunk dispatches predict exactly: err={} {d1:?}",
        d1.predict_err()
    );
    let sf = rep.shared_fetch();
    assert!(sf.is_populated());
    assert!(sf.control_speedup() >= 1.0 - 1e-9, "MINISA never loses to micro: {sf:?}");
    assert!(sf.micro_contention >= sf.minisa_contention, "{sf:?}");
    // The snapshot exports the new gauges.
    let snap = server.metrics_snapshot(1.0).to_json();
    assert!(snap.contains("fleet_dev1_predict_err"), "snapshot exports predict_err");
    assert!(snap.contains("fleet_fetch_contention"), "snapshot exports contention");
}

/// Shared fetch channel at a fetch-bound arch (8×32: the micro twin needs
/// ~2 kbit of control per wave against a 72 bit/cycle channel): three
/// same-group devices each execute one chain pass, so the group's summed
/// micro fetch demand exceeds any single device's standalone makespan —
/// micro contends, MINISA's tiny traces do not, and the modeled fleet-wide
/// MINISA speedup clears the per-device one. This is the paper's per-device
/// fetch-stall headline reproduced at fleet scale.
#[test]
fn shared_fetch_channel_micro_contends_and_minisa_wins_fleet_wide() {
    let cfg = ArchConfig::paper(8, 32);
    let chain = Chain::mlp("sfetch", 8, &[8, 12, 8]);
    let program = Program::compile(&cfg, &chain, &fast()).expect("compiles on 8x32");
    let m = program.rows();
    let elem = ElemType::I32;
    let mut rng = Lcg::new(9);
    let weights: Vec<Vec<u64>> =
        chain.layers.iter().map(|g| elem.sample_words(&mut rng, g.k * g.n)).collect();
    let ww = WordWeights::new(weights, elem);
    let archs = vec![cfg.clone(); 3];
    let fleet = Fleet::with_archs(
        &archs,
        Arc::new(NaiveExecutor),
        FleetOptions { shard_min_rows: 1, ..Default::default() },
    );
    // 3·m rows over 3 equal idle devices: the waterfill splits evenly, so
    // every device executes exactly one whole chain pass.
    let rows = 3 * m;
    let input = elem.sample_words(&mut rng, rows * program.in_features());
    let out = fleet.run_program_words(None, &program, rows, &input, &ww).unwrap();
    assert_eq!(out, execute_program_words(&program, rows, &input, &ww).unwrap());
    let rep = fleet.report(1.0);
    for d in &rep.devices {
        assert_eq!(d.rows, m as u64, "even split, one pass per device: {d:?}");
        assert!(
            d.predict_err() < 1e-9,
            "whole-pass shards predict exactly: err={} {d:?}",
            d.predict_err()
        );
    }
    let sf = rep.shared_fetch();
    assert!(
        sf.micro_contention > 1.5,
        "micro saturates the shared channel at a fetch-bound arch: {sf:?}"
    );
    assert!(sf.minisa_contention < sf.micro_contention, "{sf:?}");
    assert!(
        sf.control_speedup() > 1.5,
        "MINISA beats micro fleet-wide under shared fetch: {sf:?}"
    );
    assert!(
        sf.control_speedup() >= rep.modeled().control_speedup() * 0.999,
        "fleet-wide speedup is at least the per-device one: {sf:?} vs {:?}",
        rep.modeled()
    );
}

/// Per-layer model-vs-simulation breakdown, printed when an accuracy
/// assertion fails.
fn breakdown(program: &Program, sim_waves: u64, stream_cycles: u64) -> String {
    let mut s = format!(
        "arch={} predicted={:.1} sim_waves={} stream_cycles={}\n",
        program.cfg.name(),
        program.total_cycles,
        sim_waves,
        stream_cycles
    );
    for (i, l) in program.layers.iter().enumerate() {
        let r = &l.decision.report;
        s.push_str(&format!(
            "  layer {i} {}: waves={} invocations={} vn={} | model total={:.1} \
             compute={:.1} load={:.1} fetch={:.1} store={:.1} stall_instr={:.1}\n",
            l.gemm,
            l.lowered.waves,
            l.lowered.invocations,
            l.decision.choice.vn,
            r.total_cycles,
            r.compute_cycles,
            r.load_in_cycles + r.load_w_cycles,
            r.fetch_cycles,
            r.store_out_cycles,
            r.stall_instr_cycles,
        ));
    }
    s
}

/// Predicted-vs-simulated accuracy over the suite GEMM shapes, on paper(4,4)
/// and a larger arch. Suite M values (65536-class) are far beyond what the
/// functional simulator can execute, so each shape runs as a single-layer
/// chain at serving height M = 8 — the (K, N) structure is what drives the
/// mapping and therefore the prediction.
///
/// Two levels of teeth:
///
/// * **Exact wave identity** — the streaming waves the functional simulator
///   actually issues equal the lowering's modeled wave count (`SimStats`
///   agrees with the schedule the prediction was derived from).
/// * **Stated tolerance on cycles** — `predict_cycles` is an end-to-end
///   engine-pipeline bound (instruction fetch, off-chip loads/stores,
///   stationary fill, drain), while `SimStats` counts pure streaming
///   compute; the prediction must therefore never undershoot the
///   SimStats-derived cycles (`macs_possible / (AH·AW)`), and may exceed
///   them only up to 24× (generous headroom for load-bound skinny-M shapes
///   and the closed form's uniform-tile wave overestimate).
///
/// Release-profile work (the NTT shapes stream millions of MACs through the
/// interpreter): debug runs skip it; the dedicated CI step runs it with
/// `--include-ignored`.
#[test]
#[ignore = "release-profile work: run with --include-ignored (CI does)"]
fn predicted_cycles_track_simstats_within_tolerance() {
    const TOL: f64 = 24.0;
    // Functional cross-check budget: shapes whose weight matrix exceeds
    // this word count assert the model-side identity only (zkp_ntt_8192's
    // 67M-word weight would dominate the whole CI step).
    const SIM_BUDGET_WORDS: usize = 2_000_000;
    let m = 8usize;
    for cfg in [ArchConfig::paper(4, 4), ArchConfig::paper(8, 16)] {
        for g in workloads::suite_small() {
            let chain = Chain::mlp(&g.name, m, &[g.k, g.n]);
            let program = Program::compile(&cfg, &chain, &fast())
                .unwrap_or_else(|| panic!("{g} compiles on {}", cfg.name()));
            let modeled_waves: u64 = program.layers.iter().map(|l| l.lowered.waves).sum();
            let stream_cycles: u64 = program
                .layers
                .iter()
                .map(|l| l.lowered.waves * l.decision.choice.vn as u64)
                .sum();
            let predicted = predict_cycles(&program, m);
            assert!(
                predicted >= stream_cycles as f64 * (1.0 - 1e-9),
                "prediction undershoots streaming compute for {g}:\n{}",
                breakdown(&program, 0, stream_cycles)
            );
            assert!(
                predicted <= TOL * stream_cycles as f64,
                "prediction exceeds {TOL}x the streaming cycles for {g}:\n{}",
                breakdown(&program, 0, stream_cycles)
            );
            if g.k * g.n > SIM_BUDGET_WORDS {
                continue;
            }
            let elem = ElemType::I32;
            let mut rng = Lcg::new(0xACC ^ g.k as u64);
            let words: Vec<Vec<u64>> =
                chain.layers.iter().map(|l| elem.sample_words(&mut rng, l.k * l.n)).collect();
            let w: Vec<Vec<i32>> = words.iter().map(|m| decode_words::<i32>(m)).collect();
            let input = elem.sample_words(&mut rng, m * g.k);
            let mut sim: FunctionalSim<i32> = FunctionalSim::new(&cfg);
            execute_program_words_on(&mut sim, &program, m, &input, &w)
                .unwrap_or_else(|e| panic!("{g} executes on {}: {e}", cfg.name()));
            let sim_waves = sim.stats.waves;
            let sim_stream = sim.stats.macs_possible / (cfg.ah * cfg.aw) as u64;
            assert_eq!(
                sim_waves,
                modeled_waves,
                "simulated waves equal the modeled schedule for {g}:\n{}",
                breakdown(&program, sim_waves, sim_stream)
            );
            assert_eq!(
                sim_stream,
                stream_cycles,
                "SimStats-derived streaming cycles match the lowering for {g}:\n{}",
                breakdown(&program, sim_waves, sim_stream)
            );
        }
    }
    // FleetReport surfaces the error: a whole-pass dispatch predicts
    // exactly; a partial chunk honestly shows the step-function gap.
    let cfg = ArchConfig::paper(4, 4);
    let g = workloads::table1_workload();
    let chain = Chain::mlp("t1", m, &[g.k, g.n]);
    let program = Program::compile(&cfg, &chain, &fast()).expect("table1 shape compiles");
    let elem = ElemType::I32;
    let mut rng = Lcg::new(1);
    let words: Vec<Vec<u64>> =
        chain.layers.iter().map(|l| elem.sample_words(&mut rng, l.k * l.n)).collect();
    let ww = WordWeights::new(words, elem);
    let fleet =
        Fleet::with_archs(&[cfg.clone()], Arc::new(NaiveExecutor), FleetOptions::default());
    let input = elem.sample_words(&mut rng, m * g.k);
    fleet.run_program_words(None, &program, m, &input, &ww).unwrap();
    let rep = fleet.report(1.0);
    let d = &rep.devices[0];
    assert!(d.predicted_cycles > 0.0, "prediction surfaced: {d:?}");
    assert!(d.predict_err() < 1e-9, "whole-pass error is zero: {d:?}");
    let input = elem.sample_words(&mut rng, (m + 1) * g.k);
    fleet.run_program_words(None, &program, m + 1, &input, &ww).unwrap();
    let rep = fleet.report(1.0);
    let d = &rep.devices[0];
    let err = d.predict_err();
    assert!(
        err > 0.0 && err < 1.0,
        "partial chunk shows the honest ceil-vs-fraction gap: err={err} {d:?}"
    );
}
