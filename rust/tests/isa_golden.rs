//! Golden ISA snapshot tests.
//!
//! Locks the encoded MINISA instruction stream for five suite GEMMs: any
//! change to instruction encoding, lowering, or trace elision that silently
//! alters the Fig. 12-style instruction-traffic numbers fails a diff here
//! instead of passing review. The golden file stores, per workload, the
//! instruction count, per-class counts, encoded byte length and an FNV-1a
//! hash of the exact byte stream.
//!
//! Blessing protocol: if `tests/golden/isa_golden.txt` is missing, this test
//! writes it (fresh checkouts and the toolchain-less authoring environment
//! stay green) and the file should then be committed; once present, any
//! mismatch is a hard failure. Regenerate intentionally with
//! `UPDATE_GOLDEN=1 cargo test --test isa_golden`.

use std::path::Path;

use minisa::arch::ArchConfig;
use minisa::isa::encode::Codec;
use minisa::mapper::lower_gemm;
use minisa::mapper::search::{search, MapperOptions};
use minisa::workloads::{self, ntt, Gemm};

const GOLDEN_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden/isa_golden.txt");

fn opts() -> MapperOptions {
    // threads = 1 and the constrained layout search: fully deterministic
    // decisions, so the lowered trace (and its bytes) is a pure function of
    // (workload, config).
    MapperOptions { full_layout_search: false, threads: 1, ..Default::default() }
}

/// Five suite GEMMs spanning every workload category. NTT entries are
/// scaled to CI-sized transforms with the suite's own scaling rule (the
/// name records the lineage), keeping the full M of the BConv/LLM rows.
fn golden_workloads() -> Vec<Gemm> {
    let suite = workloads::suite50();
    let pick = |name: &str| -> Gemm {
        suite.iter().find(|g| g.name == name).unwrap_or_else(|| panic!("suite entry {name}")).clone()
    };
    vec![
        pick("bconv_00"),
        pick("bconv_40"),
        ntt::scaled(&pick("fhe_ntt_1024"), 128),
        ntt::scaled(&pick("zkp_ntt_8192"), 128),
        pick("gpt_oss_64x2048"),
    ]
}

fn fnv64(bytes: &[u8]) -> u64 {
    bytes
        .iter()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, &b| (h ^ b as u64).wrapping_mul(0x100_0000_01b3))
}

/// Lower + encode every golden workload and render the snapshot lines.
fn snapshot() -> String {
    let cfg = ArchConfig::paper(4, 4);
    let codec = Codec::new(&cfg);
    let o = opts();
    let mut lines = vec![
        "# Golden MINISA traces: cfg paper(4,4), constrained layout search, 1 thread."
            .to_string(),
        "# Regenerate intentionally: UPDATE_GOLDEN=1 cargo test --test isa_golden".to_string(),
    ];
    for g in golden_workloads() {
        let d = search(&cfg, &g, &o)
            .unwrap_or_else(|| panic!("{} must map feasibly on paper(4,4)", g.name));
        let prog = lower_gemm(&cfg, &g, &d.choice, d.i_order, d.w_order, d.o_order);
        let bytes = codec.encode_all(&prog.trace.insts).expect("golden trace encodes");
        // Encoding must be deterministic before it can be golden.
        assert_eq!(bytes, codec.encode_all(&prog.trace.insts).unwrap(), "{}", g.name);
        let (layout, exec, mem, act) = prog.trace.class_counts();
        assert_eq!(layout + exec + mem + act, prog.trace.len(), "{}: class counts", g.name);
        lines.push(format!(
            "name={} m={} k={} n={} insts={} layout={} exec={} mem={} act={} bytes={} fnv={:016x}",
            g.name,
            g.m,
            g.k,
            g.n,
            prog.trace.len(),
            layout,
            exec,
            mem,
            act,
            bytes.len(),
            fnv64(&bytes),
        ));
    }
    lines.join("\n") + "\n"
}

#[test]
// These lower full-size suite rows (M=65536 BConv, 2048×64×2048 GPT-oss),
// which is release-profile work; the dedicated CI step runs this test
// binary with `--release`, so skip it in the debug `cargo test -q` pass
// rather than paying the unoptimized lowering twice.
#[cfg_attr(debug_assertions, ignore = "full-size lowering: run via `cargo test --release --test isa_golden`")]
fn golden_isa_snapshot_matches() {
    let current = snapshot();
    let path = Path::new(GOLDEN_PATH);
    let bless = std::env::var_os("UPDATE_GOLDEN").is_some();
    match std::fs::read_to_string(path) {
        Ok(prev) if !bless => {
            assert_eq!(
                prev, current,
                "\nencoded MINISA traces changed — instruction-traffic numbers (Fig. 12) \
                 shifted.\nIf intentional, regenerate with: UPDATE_GOLDEN=1 cargo test \
                 --test isa_golden\nand commit rust/tests/golden/isa_golden.txt"
            );
        }
        _ => {
            std::fs::create_dir_all(path.parent().unwrap()).expect("create tests/golden");
            std::fs::write(path, &current).expect("write golden snapshot");
            eprintln!(
                "isa_golden: wrote fresh snapshot to {} — commit it to lock encoded traces",
                path.display()
            );
        }
    }
}

/// The encoded golden streams decode back to the exact instruction
/// sequences (byte-level lock above, structural lock here).
#[test]
#[cfg_attr(debug_assertions, ignore = "full-size lowering: run via `cargo test --release --test isa_golden`")]
fn golden_traces_roundtrip_through_codec() {
    let cfg = ArchConfig::paper(4, 4);
    let codec = Codec::new(&cfg);
    let o = opts();
    for g in golden_workloads() {
        let d = search(&cfg, &g, &o).unwrap();
        let prog = lower_gemm(&cfg, &g, &d.choice, d.i_order, d.w_order, d.o_order);
        let bytes = codec.encode_all(&prog.trace.insts).unwrap();
        let decoded = codec.decode_n(&bytes, prog.trace.insts.len()).expect("decodes");
        assert_eq!(decoded, prog.trace.insts, "{}: decode(encode(t)) == t", g.name);
        // Byte count agrees with the bit-exact width model.
        let bits: u64 = prog.trace.insts.iter().map(|i| codec.width_bits(i) as u64).sum();
        assert_eq!(bytes.len() as u64, bits.div_ceil(8), "{}: width model", g.name);
    }
}
