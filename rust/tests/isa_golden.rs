//! Golden ISA snapshot tests.
//!
//! Locks the encoded MINISA instruction stream for five suite GEMMs: any
//! change to instruction encoding, lowering, or trace elision that silently
//! alters the Fig. 12-style instruction-traffic numbers fails a diff here
//! instead of passing review. The golden file stores, per workload, the
//! instruction count, per-class counts, encoded byte length and an FNV-1a
//! hash of the exact byte stream.
//!
//! Blessing protocol: if `tests/golden/isa_golden.txt` is missing, this test
//! writes it (fresh checkouts and the toolchain-less authoring environment
//! stay green) and the file should then be committed; once present, any
//! mismatch is a hard failure. Regenerate intentionally with
//! `UPDATE_GOLDEN=1 cargo test --test isa_golden`.

use std::path::Path;

use minisa::arch::ArchConfig;
use minisa::arith::ElemType;
use minisa::artifact::{fnv64, Artifact, Compiler};
use minisa::isa::encode::Codec;
use minisa::mapper::chain::Chain;
use minisa::mapper::lower_gemm;
use minisa::mapper::search::{search, MapperOptions};
use minisa::program::Program;
use minisa::util::Lcg;
use minisa::workloads::{self, ntt, Gemm};

const GOLDEN_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden/isa_golden.txt");

fn opts() -> MapperOptions {
    // threads = 1 and the constrained layout search: fully deterministic
    // decisions, so the lowered trace (and its bytes) is a pure function of
    // (workload, config).
    MapperOptions { full_layout_search: false, threads: 1, ..Default::default() }
}

/// Five suite GEMMs spanning every workload category. NTT entries are
/// scaled to CI-sized transforms with the suite's own scaling rule (the
/// name records the lineage), keeping the full M of the BConv/LLM rows.
fn golden_workloads() -> Vec<Gemm> {
    let suite = workloads::suite50();
    let pick = |name: &str| -> Gemm {
        suite.iter().find(|g| g.name == name).unwrap_or_else(|| panic!("suite entry {name}")).clone()
    };
    vec![
        pick("bconv_00"),
        pick("bconv_40"),
        ntt::scaled(&pick("fhe_ntt_1024"), 128),
        ntt::scaled(&pick("zkp_ntt_8192"), 128),
        pick("gpt_oss_64x2048"),
    ]
}

/// The two artifacts whose **container bytes** the snapshot locks: a 3-layer
/// alternating MLP with an i32 weights payload, and a bare (weightless)
/// single-layer scaled ZKP NTT. Deterministic: `opts()` search profile,
/// fixed weight seed.
fn golden_artifacts() -> Vec<(&'static str, Artifact)> {
    let cfg = ArchConfig::paper(4, 4);
    let mlp = Chain::mlp("golden_mlp", 16, &[16, 24, 16, 8]);
    let mut rng = Lcg::new(0x9A17);
    let weights: Vec<Vec<u64>> =
        mlp.layers.iter().map(|g| ElemType::I32.sample_words(&mut rng, g.k * g.n)).collect();
    let with_weights = Compiler::new(&cfg)
        .options(opts())
        .elem(ElemType::I32)
        .weights(weights)
        .compile(&mlp)
        .expect("golden MLP compiles");
    let suite = workloads::suite50();
    let zkp = suite.iter().find(|g| g.name == "zkp_ntt_8192").unwrap();
    let ntt_chain = Chain { layers: vec![ntt::scaled(zkp, 64)] };
    let bare = Compiler::new(&cfg)
        .options(opts())
        .compile(&ntt_chain)
        .expect("golden NTT compiles");
    vec![("golden_mlp_i32", with_weights), ("golden_zkp_ntt_64", bare)]
}

/// Lower + encode every golden workload and render the snapshot lines.
fn snapshot() -> String {
    let cfg = ArchConfig::paper(4, 4);
    let codec = Codec::new(&cfg);
    let o = opts();
    let mut lines = vec![
        "# Golden MINISA traces: cfg paper(4,4), constrained layout search, 1 thread."
            .to_string(),
        "# Regenerate intentionally: UPDATE_GOLDEN=1 cargo test --test isa_golden".to_string(),
    ];
    for g in golden_workloads() {
        let d = search(&cfg, &g, &o)
            .unwrap_or_else(|| panic!("{} must map feasibly on paper(4,4)", g.name));
        let prog = lower_gemm(&cfg, &g, &d.choice, d.i_order, d.w_order, d.o_order);
        let bytes = codec.encode_all(&prog.trace.insts).expect("golden trace encodes");
        // Encoding must be deterministic before it can be golden.
        assert_eq!(bytes, codec.encode_all(&prog.trace.insts).unwrap(), "{}", g.name);
        let (layout, exec, mem, act) = prog.trace.class_counts();
        assert_eq!(layout + exec + mem + act, prog.trace.len(), "{}: class counts", g.name);
        lines.push(format!(
            "name={} m={} k={} n={} insts={} layout={} exec={} mem={} act={} bytes={} fnv={:016x}",
            g.name,
            g.m,
            g.k,
            g.n,
            prog.trace.len(),
            layout,
            exec,
            mem,
            act,
            bytes.len(),
            fnv64(&bytes),
        ));
    }
    // Artifact container bytes: any drift in the wire format, the encoded
    // stream, or the serialized decisions fails the diff here.
    for (name, art) in golden_artifacts() {
        let container = art.to_bytes();
        assert_eq!(container, art.to_bytes(), "{name}: container deterministic");
        lines.push(format!(
            "artifact={} layers={} insts={} trace_bytes={} container_bytes={} fnv={:016x}",
            name,
            art.chain.layers.len(),
            art.inst_count,
            art.trace_bytes.len(),
            container.len(),
            fnv64(&container),
        ));
    }
    lines.join("\n") + "\n"
}

#[test]
// These lower full-size suite rows (M=65536 BConv, 2048×64×2048 GPT-oss),
// which is release-profile work; the dedicated CI step runs this test
// binary with `--release`, so skip it in the debug `cargo test -q` pass
// rather than paying the unoptimized lowering twice.
#[cfg_attr(debug_assertions, ignore = "full-size lowering: run via `cargo test --release --test isa_golden`")]
fn golden_isa_snapshot_matches() {
    let current = snapshot();
    let path = Path::new(GOLDEN_PATH);
    let bless = std::env::var_os("UPDATE_GOLDEN").is_some();
    match std::fs::read_to_string(path) {
        Ok(prev) if !bless => {
            assert_eq!(
                prev, current,
                "\nencoded MINISA traces changed — instruction-traffic numbers (Fig. 12) \
                 shifted.\nIf intentional, regenerate with: UPDATE_GOLDEN=1 cargo test \
                 --test isa_golden\nand commit rust/tests/golden/isa_golden.txt"
            );
        }
        _ => {
            std::fs::create_dir_all(path.parent().unwrap()).expect("create tests/golden");
            std::fs::write(path, &current).expect("write golden snapshot");
            eprintln!(
                "isa_golden: wrote fresh snapshot to {} — commit it to lock encoded traces",
                path.display()
            );
        }
    }
}

/// The encoded golden streams decode back to the exact instruction
/// sequences (byte-level lock above, structural lock here).
#[test]
#[cfg_attr(debug_assertions, ignore = "full-size lowering: run via `cargo test --release --test isa_golden`")]
fn golden_traces_roundtrip_through_codec() {
    let cfg = ArchConfig::paper(4, 4);
    let codec = Codec::new(&cfg);
    let o = opts();
    for g in golden_workloads() {
        let d = search(&cfg, &g, &o).unwrap();
        let prog = lower_gemm(&cfg, &g, &d.choice, d.i_order, d.w_order, d.o_order);
        let bytes = codec.encode_all(&prog.trace.insts).unwrap();
        let decoded = codec.decode_n(&bytes, prog.trace.insts.len()).expect("decodes");
        assert_eq!(decoded, prog.trace.insts, "{}: decode(encode(t)) == t", g.name);
        // Byte count agrees with the bit-exact width model.
        let bits: u64 = prog.trace.insts.iter().map(|i| codec.width_bits(i) as u64).sum();
        assert_eq!(bytes.len() as u64, bits.div_ceil(8), "{}: width model", g.name);
    }
}

/// The golden artifact containers parse back to equal values, pass their
/// stream round-trip verification, and load into Programs without a mapper
/// run — structural lock next to the snapshot's byte lock. (Small chains:
/// safe for the debug pass.)
#[test]
fn golden_artifact_containers_roundtrip() {
    for (name, art) in golden_artifacts() {
        let bytes = art.to_bytes();
        let back = Artifact::from_bytes(&bytes).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(back, art, "{name}: parse(serialize(a)) == a");
        let check = back.verify().unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(check.insts, art.inst_count, "{name}");
        let searches_before = minisa::mapper::search::searches_run();
        let program = Program::from_artifact(&back).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(
            minisa::mapper::search::searches_run(),
            searches_before,
            "{name}: loading must not run the mapper"
        );
        assert_eq!(program.fused.len(), art.inst_count, "{name}");
        assert!(program.plan_count() > 0, "{name}: wave plans recompiled at load");
    }
}
