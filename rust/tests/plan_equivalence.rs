//! Compiled-plan equivalence suite: `FunctionalSim` with `use_plans = true`
//! (the default, interpreting compiled `WavePlan`s) must be **bit-identical**
//! to the reference per-wave interpreter (`use_plans = false`, the seed
//! semantics) — same outputs, same `SimStats` (macs_used, birrd_adds,
//! ob_conflicts, ...), and same `SimError` on illegal programs.
//!
//! The §Perf blocked battery extends the chain three ways deep: blocked
//! multi-row execution (`BlockSim` → `WavePlan::execute_rows`) ≡ the
//! sequential scalar chunk loop ≡ the pre-plan reference interpreter,
//! across every element backend × row counts straddling the block
//! boundary × fleet shard boundaries — words, `SimStats`, and the zero
//! runtime-plan-compile invariant all equal.

use std::sync::Arc;

use minisa::arch::vn::VnGrid;
use minisa::arch::ArchConfig;
use minisa::arith::{decode_words, ElemType};
use minisa::coordinator::fleet::{Fleet, FleetOptions};
use minisa::coordinator::serve::{
    execute_program_words, execute_program_words_blocked, execute_program_words_on, NaiveExecutor,
    WordWeights,
};
use minisa::functional::{pack_image, BlockSim, FunctionalSim, SimError, SimStats, DEFAULT_ROW_BLOCK};
use minisa::isa::inst::{BufTarget, Inst, LayoutInst};
use minisa::layout::VnLayout;
use minisa::mapper::chain::Chain;
use minisa::mapper::exec::execute_program_on;
use minisa::mapper::lower_gemm;
use minisa::mapper::search::MapperOptions;
use minisa::mapper::MappingChoice;
use minisa::mapping::{Dataflow, MappingCfg, StreamCfg};
use minisa::program::Program;
use minisa::util::prop::forall;
use minisa::util::Lcg;
use minisa::with_element;
use minisa::workloads::Gemm;

/// Run one lowered program through both interpreters; returns
/// (plan result, reference result, plan stats, reference stats).
#[allow(clippy::type_complexity)]
fn run_both(
    cfg: &ArchConfig,
    g: &Gemm,
    ch: &MappingChoice,
    orders: (u8, u8, u8),
    seed: u64,
) -> (Result<Vec<i64>, SimError>, Result<Vec<i64>, SimError>, SimStats, SimStats) {
    let prog = lower_gemm(cfg, g, ch, orders.0, orders.1, orders.2);
    let mut rng = Lcg::new(seed);
    let iv: Vec<i32> = (0..g.m * g.k).map(|_| rng.range(0, 15) as i32 - 7).collect();
    let wv: Vec<i32> = (0..g.k * g.n).map(|_| rng.range(0, 15) as i32 - 7).collect();
    let mut fast = FunctionalSim::new(cfg);
    assert!(fast.use_plans, "plans are the default execution mode");
    let mut slow = FunctionalSim::new(cfg);
    slow.use_plans = false;
    let a = execute_program_on(&mut fast, g, &prog, &iv, &wv);
    let b = execute_program_on(&mut slow, g, &prog, &iv, &wv);
    (a, b, fast.stats.clone(), slow.stats.clone())
}

/// Randomized equivalence over mapper-generated programs: both dataflows,
/// non-power-of-two M/K/N, random layout orders and mapping knobs.
#[test]
fn randomized_plan_equivalence() {
    forall("plan-equivalence", 60, |gen| {
        let (ah, aw) = *gen.pick(&[(4usize, 4usize), (4, 8), (8, 8)]);
        let cfg = ArchConfig::paper(ah, aw);
        // usize(1, 24) covers plenty of non-powers-of-two; both dataflows.
        let m = gen.usize(1, 24);
        let k = gen.usize(1, 24);
        let n = gen.usize(1, 24);
        let g = Gemm::new("p", "prop", m, k, n);
        let vn = ah.min(k).max(1);
        let df = if gen.bool() { Dataflow::WoS } else { Dataflow::IoS };
        let (ms, ks, ns) = minisa::mapper::lower::search_dims(&g, df);
        let m_t = gen.pick(&[ah, 2 * ah, 4 * ah]).min(&ms.max(1)).to_owned().max(1);
        let k_t = (*gen.pick(&[vn, 2 * vn, 4 * vn])).min(ks.max(1)).max(1);
        let n_t = (*gen.pick(&[1usize, 2, ah, 2 * ah])).min(ns.max(1)).max(1);
        let nbc = gen.pow2(0, 2).min(aw);
        let dup = gen.pow2(0, 2).min(aw / nbc).max(1);
        let ch = MappingChoice { df, vn, m_t, k_t, n_t, nbc, dup };
        let io = gen.usize(0, 5) as u8;
        let oo = gen.usize(0, 5) as u8;
        let seed = gen.usize(0, 1 << 20) as u64;
        let (a, b, sa, sb) = run_both(&cfg, &g, &ch, (io, 0, oo), seed);
        assert_eq!(a, b, "{g} {ch:?} orders ({io},0,{oo})");
        assert_eq!(sa, sb, "stats diverged: {g} {ch:?} orders ({io},0,{oo})");
    });
}

/// Fixed awkward shapes (prime-ish dims, every layout order pair) — the
/// cases most likely to hit padding and remainder paths.
#[test]
fn fixed_odd_shapes_all_orders() {
    let cfg = ArchConfig::paper(4, 4);
    for (m, k, n) in [(7usize, 13usize, 11usize), (5, 9, 3), (12, 20, 10), (1, 1, 1)] {
        let g = Gemm::new("t", "test", m, k, n);
        for df in [Dataflow::WoS, Dataflow::IoS] {
            let ch = MappingChoice { df, vn: 4, m_t: 8, k_t: 8, n_t: 8, nbc: 2, dup: 1 };
            for io in 0..6u8 {
                for oo in 0..6u8 {
                    let (a, b, sa, sb) = run_both(&cfg, &g, &ch, (io, 0, oo), 17);
                    assert_eq!(a, b, "({m},{k},{n}) {df:?} orders ({io},{oo})");
                    assert_eq!(sa, sb, "stats: ({m},{k},{n}) {df:?} orders ({io},{oo})");
                }
            }
        }
    }
}

/// Hand-built single-invocation trace against a config with a tiny output
/// buffer; `mapping` chooses the Eq.-(1) placement, `o_lay` the OVN layout.
fn single_tile_trace(
    sim: &mut FunctionalSim,
    cfg: &ArchConfig,
    n: usize,
    em: MappingCfg,
    o_lay: VnLayout,
) -> Vec<Inst> {
    let (m, k, vn) = (4usize, 4usize, 4usize);
    let gi = VnGrid::new(k, m, vn);
    let gw = VnGrid::new(k, n, vn);
    let i_lay = VnLayout::row_major(gi.rows(), m, vn);
    let w_lay = VnLayout::row_major(gw.rows(), n, vn);
    let iv: Vec<i32> = vec![1; m * k];
    let wv: Vec<i32> = vec![1; k * n];
    let i_img = pack_image(&i_lay, cfg.aw, |r, c| gi.gather_input(&iv, r, c));
    let w_img = pack_image(&w_lay, cfg.aw, |r, c| gw.gather_weight(&wv, r, c));
    let ia = sim.hbm_alloc(i_img.len());
    sim.hbm_write(ia, &i_img);
    let wa = sim.hbm_alloc(w_img.len());
    sim.hbm_write(wa, &w_img);
    vec![
        Inst::Load {
            target: BufTarget::Streaming,
            hbm_addr: ia,
            rows: i_lay.rows_needed(cfg.aw) as u32,
        },
        Inst::Load {
            target: BufTarget::Stationary,
            hbm_addr: wa,
            rows: w_lay.rows_needed(cfg.aw) as u32,
        },
        Inst::SetIVNLayout(LayoutInst { layout: i_lay }),
        Inst::SetWVNLayout(LayoutInst { layout: w_lay }),
        Inst::SetOVNLayout(LayoutInst { layout: o_lay }),
        Inst::ExecuteMapping(em),
        Inst::ExecuteStreaming(StreamCfg {
            df: Dataflow::WoS,
            m0: 0,
            s_m: 4,
            t: 1,
            vn_size: vn,
        }),
    ]
}

fn run_error_case(
    cfg: &ArchConfig,
    n: usize,
    em: MappingCfg,
    o_lay: VnLayout,
) -> (Result<(), SimError>, Result<(), SimError>, SimStats, SimStats) {
    let mut fast = FunctionalSim::new(cfg);
    let trace = single_tile_trace(&mut fast, cfg, n, em, o_lay);
    let a = fast.exec_trace(&trace);
    let mut slow = FunctionalSim::new(cfg);
    slow.use_plans = false;
    let trace = single_tile_trace(&mut slow, cfg, n, em, o_lay);
    let b = slow.exec_trace(&trace);
    (a, b, fast.stats.clone(), slow.stats.clone())
}

/// OB overflow raises the identical `SimError` (same row, same depth) at
/// the identical point, with identical partial `SimStats`, in both modes.
#[test]
fn ob_overflow_identical_in_both_modes() {
    let mut cfg = ArchConfig::paper(4, 4);
    cfg.ob_bytes = 4 * 4 * 8; // d_ob = 8 rows
    assert_eq!(cfg.d_ob(), 8);
    // Distinct stationary columns per PE column (Fig. 4 case 3): q reaches
    // 15, so OVN rows reach 12..16 ≥ depth 8 → overflow mid-wave.
    let em = MappingCfg { r0: 0, c0: 0, g_r: 4, g_c: 4, s_r: 1, s_c: 4 };
    let o_lay = VnLayout::row_major(4, 4, 4);
    let (a, b, sa, sb) = run_error_case(&cfg, 16, em, o_lay);
    assert!(matches!(a, Err(SimError::ObOverflow { .. })), "got {a:?}");
    assert_eq!(a, b);
    assert_eq!(sa, sb, "stats at error point must match");
}

/// Orphan-psum shapes (outputs falling outside the OVN layout with nonzero
/// partial sums) raise the identical error with identical stats.
#[test]
fn orphan_psum_identical_in_both_modes() {
    let cfg = ArchConfig::paper(4, 4);
    // Replicated stationary VNs; OVN layout only covers p < 2 of 4.
    let em = MappingCfg { r0: 0, c0: 0, g_r: 4, g_c: 1, s_r: 1, s_c: 0 };
    let o_lay = VnLayout::row_major(1, 2, 4);
    let (a, b, sa, sb) = run_error_case(&cfg, 4, em, o_lay);
    assert!(matches!(a, Err(SimError::OrphanPsum { .. })), "got {a:?}");
    assert_eq!(a, b);
    assert_eq!(sa, sb, "stats at error point must match");
}

/// A healthy single-tile trace on the same harness stays error-free and
/// identical in both modes (guards the harness itself).
#[test]
fn healthy_trace_identical_in_both_modes() {
    let cfg = ArchConfig::paper(4, 4);
    let em = MappingCfg { r0: 0, c0: 0, g_r: 4, g_c: 1, s_r: 1, s_c: 0 };
    let o_lay = VnLayout::row_major(1, 4, 4);
    let (a, b, sa, sb) = run_error_case(&cfg, 4, em, o_lay);
    assert_eq!(a, Ok(()));
    assert_eq!(a, b);
    assert_eq!(sa, sb);
}

// ---------------------------------------------------------------------------
// §Perf blocked multi-row battery
// ---------------------------------------------------------------------------

/// The battery's shared 3-layer chain, compiled once (plans are
/// element-independent). M = 2 keeps each chunk small so row counts around
/// the block boundary stay cheap to sweep.
fn battery_program() -> (ArchConfig, Chain, Program) {
    let cfg = ArchConfig::paper(4, 4);
    let o = MapperOptions { full_layout_search: false, threads: 1, ..Default::default() };
    let chain = Chain::mlp("battery", 2, &[5, 7, 4]);
    let p = Program::compile(&cfg, &chain, &o).expect("battery chain compiles on 4x4");
    (cfg, chain, p)
}

/// Tentpole equivalence: for every element backend × row counts straddling
/// the block boundary (1, block−1, block, block+1, 4·block+3 — in rows,
/// where one "block" is `DEFAULT_ROW_BLOCK` compiled-height chunks), the
/// blocked executor ≡ the sequential scalar chunk loop ≡ the pre-plan
/// reference interpreter: identical words, identical `SimStats` (including
/// MAC counts), and zero runtime plan compiles on the seeded paths.
#[test]
fn blocked_rows_equivalence_battery() {
    let (cfg, chain, program) = battery_program();
    let kf = program.in_features();
    let block_rows = DEFAULT_ROW_BLOCK * program.rows();
    for (ei, elem) in ElemType::ALL.into_iter().enumerate() {
        for rows in [1, block_rows - 1, block_rows, block_rows + 1, 4 * block_rows + 3] {
            with_element!(elem, E => {
                let mut rng = Lcg::new(0xBA77E5 ^ ((ei as u64) << 32) ^ rows as u64);
                let input = elem.sample_words(&mut rng, rows * kf);
                let w: Vec<Vec<E>> = chain
                    .layers
                    .iter()
                    .map(|g| decode_words::<E>(&elem.sample_words(&mut rng, g.k * g.n)))
                    .collect();

                let mut block: BlockSim<E> = BlockSim::new(&cfg);
                let blocked =
                    execute_program_words_blocked(&mut block, &program, rows, &input, &w)
                        .unwrap();

                let mut scalar: FunctionalSim<E> = FunctionalSim::new(&cfg);
                let seq =
                    execute_program_words_on(&mut scalar, &program, rows, &input, &w).unwrap();

                let mut reference: FunctionalSim<E> = FunctionalSim::new(&cfg);
                reference.use_plans = false;
                let refr =
                    execute_program_words_on(&mut reference, &program, rows, &input, &w)
                        .unwrap();

                assert_eq!(blocked, seq, "{elem} rows={rows}: blocked vs scalar words");
                assert_eq!(seq, refr, "{elem} rows={rows}: scalar vs reference words");
                assert_eq!(
                    block.stats(),
                    scalar.stats,
                    "{elem} rows={rows}: blocked stats must equal the sequential loop's"
                );
                assert_eq!(
                    scalar.stats, reference.stats,
                    "{elem} rows={rows}: plan stats must equal the reference interpreter's"
                );
                assert_eq!(block.plan_compiles(), 0, "{elem} rows={rows}: blocked is seeded");
                assert_eq!(scalar.plan_compiles, 0, "{elem} rows={rows}: scalar is seeded");
            });
        }
    }
}

/// Fleet shard boundaries through the blocked device path: a 3-device fleet
/// at `shard_min_rows = 1` splits a 4-blocks-plus-3 batch at rows that
/// align with neither the compiled height nor the block boundary — results
/// stay bit-identical to single-device execution for every backend, with
/// zero runtime plan compiles.
#[test]
fn blocked_fleet_shard_boundaries() {
    let (cfg, chain, program) = battery_program();
    let rows = 4 * DEFAULT_ROW_BLOCK * program.rows() + 3;
    for elem in ElemType::ALL {
        let mut rng = Lcg::new(0xF7EE7 ^ elem as u64);
        let weights: Vec<Vec<u64>> =
            chain.layers.iter().map(|g| elem.sample_words(&mut rng, g.k * g.n)).collect();
        let ww = WordWeights::new(weights, elem);
        let input = elem.sample_words(&mut rng, rows * program.in_features());
        let fleet = Fleet::new(
            &cfg,
            Arc::new(NaiveExecutor),
            FleetOptions { devices: 3, shard_min_rows: 1, ..Default::default() },
        );
        let sharded = fleet.run_program_words(None, &program, rows, &input, &ww).unwrap();
        let single = execute_program_words(&program, rows, &input, &ww).unwrap();
        assert_eq!(sharded, single, "{elem}: fleet shards through the blocked path");
        assert_eq!(fleet.plan_compiles(), 0, "{elem}: zero runtime plan compiles");
    }
}
