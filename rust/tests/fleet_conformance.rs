//! Cross-backend fleet conformance battery.
//!
//! Locks down the tentpole invariant of the fleet executor: executing a
//! compiled [`Program`] sharded across N simulated FEATHER+ devices is
//! **bit-identical** to single-device execution, for every element backend
//! (SatI32, f32, Goldilocks, BabyBear), for adversarial shard boundaries
//! (1-row shards, `shard_min_rows > M`, shard counts that don't divide the
//! row count), and for every fleet size in {1, 2, 3, 7} — with **zero**
//! runtime wave-plan compiles across the fleet and exactly **one** program
//! compile per registered session.
//!
//! Property cases come from `util::prop` (`forall`), so failures print a
//! reproducible seed + draw log.

use std::collections::HashMap;
use std::sync::Arc;

use minisa::arch::ArchConfig;
use minisa::arith::{decode_words, naive_gemm_e, ElemType, Element};
use minisa::coordinator::fleet::{plan_shards, Fleet, FleetOptions};
use minisa::coordinator::serve::{
    execute_program_words, spawn_with_options, NaiveExecutor, Request, ServerOptions, WordWeights,
};
use minisa::mapper::chain::Chain;
use minisa::mapper::search::MapperOptions;
use minisa::program::Program;
use minisa::util::prop::forall;
use minisa::util::Lcg;
use minisa::with_element;

/// The four element backends the battery must prove conformant.
const BACKENDS: [ElemType; 4] =
    [ElemType::I32, ElemType::F32, ElemType::Goldilocks, ElemType::BabyBear];

const FLEET_SIZES: [usize; 4] = [1, 2, 3, 7];

fn fast() -> MapperOptions {
    MapperOptions { full_layout_search: false, threads: 1, ..Default::default() }
}

/// One shared compiled program: plans are element-independent, so a single
/// compile serves every backend in the battery (itself part of the
/// compile-once story under test). M = 5 is deliberately odd so batched
/// rows never align with the compiled height.
fn compile_program() -> (ArchConfig, Chain, Program) {
    let cfg = ArchConfig::paper(4, 4);
    let chain = Chain::mlp("conf", 5, &[8, 12, 8]);
    let p = Program::compile(&cfg, &chain, &fast()).expect("chain compiles");
    (cfg, chain, p)
}

/// Chained naive reference in `elem`'s number system, over an arbitrary row
/// count (unlike `Program::reference`, which is fixed at the compiled M).
fn reference_words(
    chain: &Chain,
    weights: &[Vec<u64>],
    elem: ElemType,
    rows: usize,
    input: &[u64],
) -> Vec<u64> {
    with_element!(elem, E => {
        let w: Vec<Vec<E>> = weights.iter().map(|m| decode_words::<E>(m)).collect();
        let mut act: Vec<E> = decode_words::<E>(input);
        let mut out: Vec<<E as Element>::Acc> = Vec::new();
        for (li, (g, wm)) in chain.layers.iter().zip(&w).enumerate() {
            out = naive_gemm_e::<E>(&act, wm, rows, g.k, g.n);
            if li + 1 < chain.layers.len() {
                act = out.iter().map(|&v| E::reduce(v)).collect();
            }
        }
        out.iter().map(|&v| E::reduce(v).encode()).collect()
    })
}

/// Property: for every backend, fleet size, row count and (adversarial)
/// `shard_min_rows`, fleet execution equals the single-device path
/// bit-for-bit and compiles nothing at runtime.
#[test]
fn sharded_execution_bit_identical_for_all_backends() {
    let (cfg, chain, program) = compile_program();
    for elem in BACKENDS {
        let mut wrng = Lcg::new(0xF1EE7 ^ elem as u64);
        let weights: Vec<Vec<u64>> =
            chain.layers.iter().map(|g| elem.sample_words(&mut wrng, g.k * g.n)).collect();
        forall(&format!("fleet-conformance-{elem}"), 24, |g| {
            let devices = *g.pick(&FLEET_SIZES);
            let rows = g.usize(1, 23);
            // Includes 1 (single-row shards) and values far above any row
            // count in play (shard_min_rows > M → one shard).
            let shard_min_rows = g.usize(1, 40);
            let fleet = Fleet::new(
                &cfg,
                Arc::new(NaiveExecutor),
                FleetOptions { devices, shard_min_rows, ..Default::default() },
            );
            let ww = WordWeights::new(weights.clone(), elem);
            let input = elem.sample_words(g.rng(), rows * program.in_features());
            let sharded = fleet
                .run_program_words(None, &program, rows, &input, &ww)
                .expect("fleet execution succeeds");
            let single =
                execute_program_words(&program, rows, &input, &ww).expect("single-device");
            assert_eq!(sharded, single, "devices={devices} rows={rows} min={shard_min_rows}");
            assert_eq!(fleet.plan_compiles(), 0, "zero runtime plan compiles");
        });
    }
}

/// Deterministic adversarial boundaries: 1-row shards on a 7-device fleet,
/// `shard_min_rows` far above the batch height, and a single-row batch.
#[test]
fn adversarial_shard_boundaries_stay_exact() {
    let (cfg, chain, program) = compile_program();
    let elem = ElemType::Goldilocks;
    let mut rng = Lcg::new(42);
    let weights: Vec<Vec<u64>> =
        chain.layers.iter().map(|g| elem.sample_words(&mut rng, g.k * g.n)).collect();
    for (devices, rows, min_rows) in
        [(7usize, 9usize, 1usize), (7, 9, 1000), (3, 1, 1), (2, 23, 5), (7, 7, 1)]
    {
        let fleet = Fleet::new(
            &cfg,
            Arc::new(NaiveExecutor),
            FleetOptions { devices, shard_min_rows: min_rows, ..Default::default() },
        );
        let ww = WordWeights::new(weights.clone(), elem);
        let input = elem.sample_words(&mut rng, rows * program.in_features());
        let sharded = fleet.run_program_words(None, &program, rows, &input, &ww).unwrap();
        let single = execute_program_words(&program, rows, &input, &ww).unwrap();
        assert_eq!(sharded, single, "devices={devices} rows={rows} min={min_rows}");
        assert_eq!(fleet.plan_compiles(), 0);
        // Sanity on the shard plan itself for the extremes.
        let shards = plan_shards(rows, devices, min_rows);
        if min_rows > rows {
            assert_eq!(shards.len(), 1, "oversized min collapses to one shard");
        }
        if min_rows == 1 && devices >= rows {
            assert!(shards.iter().all(|s| s.len() == 1), "1-row shards");
        }
    }
}

/// Served conformance, per fleet size and backend: a fleet server answers
/// the same words as the chained naive reference, with `program_compiles ==
/// 1` and zero fleet plan compiles — the compile-once/serve-many invariant
/// survives multi-device dispatch.
#[test]
fn fleet_server_serves_bit_exact_with_one_compile() {
    for devices in [1usize, 2, 3] {
        for elem in BACKENDS {
            let cfg = ArchConfig::paper(4, 4);
            let chain = Chain::mlp("conf", 4, &[8, 12, 8]);
            let opts =
                ServerOptions { devices, shard_min_rows: 1, max_batch: 8, ..Default::default() };
            let (tx, rx, h, server) =
                spawn_with_options(&cfg, Arc::new(NaiveExecutor), opts);
            let mut rng = Lcg::new(1000 + devices as u64 + elem as u64 * 31);
            let weights: Vec<Vec<u64>> =
                chain.layers.iter().map(|g| elem.sample_words(&mut rng, g.k * g.n)).collect();
            let pid = server.register_chain_elem(&chain, weights.clone(), elem).unwrap();
            let n_req = 6u64;
            let mut expects = HashMap::new();
            for id in 0..n_req {
                // Rows ≠ compiled height on odd ids: exercises chunking
                // inside shards.
                let rows = if id % 2 == 0 { 4 } else { 7 };
                let input = elem.sample_words(&mut rng, rows * 8);
                expects.insert(id, reference_words(&chain, &weights, elem, rows, &input));
                tx.send(Request::for_program_words(id, pid, rows, input)).unwrap();
            }
            for _ in 0..n_req {
                let r = rx.recv().unwrap();
                assert!(r.error.is_none(), "devices={devices} {elem}: {:?}", r.error);
                assert_eq!(
                    &r.output_words, &expects[&r.id],
                    "devices={devices} {elem} id={}",
                    r.id
                );
            }
            drop(tx);
            let stats = h.join().unwrap();
            assert_eq!(stats.program_compiles, 1, "one compile per fleet ({devices} devices)");
            assert_eq!(stats.program_served, n_req);
            assert_eq!(stats.errors, 0);
            assert_eq!(
                server.fleet().plan_compiles(),
                0,
                "devices={devices} {elem}: zero runtime plan compiles"
            );
        }
    }
}

/// Repeated fleet execution stays compile-free: the per-device simulators
/// persist across dispatches, so round 2+ hits warm plan caches (still 0
/// compiles, same bytes).
#[test]
fn repeated_execution_reuses_device_plan_caches() {
    let (cfg, chain, program) = compile_program();
    let elem = ElemType::BabyBear;
    let mut rng = Lcg::new(7);
    let weights: Vec<Vec<u64>> =
        chain.layers.iter().map(|g| elem.sample_words(&mut rng, g.k * g.n)).collect();
    let fleet = Fleet::new(
        &cfg,
        Arc::new(NaiveExecutor),
        FleetOptions { devices: 3, shard_min_rows: 1, ..Default::default() },
    );
    let ww = WordWeights::new(weights, elem);
    let input = elem.sample_words(&mut rng, 12 * program.in_features());
    let first = fleet.run_program_words(None, &program, 12, &input, &ww).unwrap();
    for round in 0..3 {
        let again = fleet.run_program_words(None, &program, 12, &input, &ww).unwrap();
        assert_eq!(again, first, "round {round} deterministic");
    }
    assert_eq!(fleet.plan_compiles(), 0);
    let rep = fleet.report(1.0);
    let shards: u64 = rep.devices.iter().map(|d| d.shards).sum();
    assert!(shards >= 4, "multiple dispatches recorded shards: {rep:?}");
}
