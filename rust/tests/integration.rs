//! Cross-module integration tests: mapper → ISA → functional simulator →
//! performance model, over real workload shapes and both dataflows, plus
//! the program-serving path (compile-once/serve-many).

use std::sync::Arc;

use minisa::arch::ArchConfig;
use minisa::coordinator::evaluate_one;
use minisa::coordinator::serve::{spawn, NaiveExecutor, Request};
use minisa::isa::encode::Codec;
use minisa::mapper::chain::Chain;
use minisa::mapper::exec::validate_decision;
use minisa::mapper::search::{instr_traffic, search, MapperOptions};
use minisa::mapper::lower_gemm;
use minisa::util::prop::forall;
use minisa::util::Lcg;
use minisa::workloads::{self, Gemm};

fn fast_opts() -> MapperOptions {
    MapperOptions { full_layout_search: false, threads: 2, ..Default::default() }
}

/// The full pipeline is exact on downscaled versions of every suite
/// workload family (full shapes are too large to simulate functionally;
/// shrinking M/K/N preserves every code path incl. padding).
#[test]
fn suite_shapes_downscaled_end_to_end_exact() {
    let cfg = ArchConfig::paper(4, 8);
    let shrink = |x: usize, cap: usize| x.min(cap).max(1);
    for g in workloads::suite50() {
        let small = Gemm::new(&g.name, &g.category, shrink(g.m, 24), shrink(g.k, 40), shrink(g.n, 24));
        let d = search(&cfg, &small, &fast_opts())
            .unwrap_or_else(|| panic!("no mapping for {small}"));
        let prog = lower_gemm(&cfg, &small, &d.choice, d.i_order, d.w_order, d.o_order);
        let (got, expect) = validate_decision(&cfg, &small, &prog, 9)
            .unwrap_or_else(|e| panic!("{small}: {e}"));
        assert_eq!(got, expect, "{small}");
    }
}

/// Lowered traces always encode and decode losslessly at the bit level.
#[test]
fn lowered_traces_roundtrip_through_codec() {
    let cfg = ArchConfig::paper(4, 4);
    let g = Gemm::new("rt", "t", 20, 24, 12);
    let d = search(&cfg, &g, &fast_opts()).unwrap();
    let prog = lower_gemm(&cfg, &g, &d.choice, d.i_order, d.w_order, d.o_order);
    let codec = Codec::new(&cfg);
    let bytes = codec.encode_all(&prog.trace.insts).expect("encodable");
    assert_eq!(bytes.len() as u64, prog.trace.size_bytes(&codec));
    let decoded = codec.decode_n(&bytes, prog.trace.insts.len()).expect("decodable");
    // Execute/memory instructions must decode identically (layout VN size
    // is architectural, checked separately).
    for (a, b) in prog.trace.insts.iter().zip(&decoded) {
        match a {
            minisa::isa::inst::Inst::ExecuteMapping(_)
            | minisa::isa::inst::Inst::ExecuteStreaming(_)
            | minisa::isa::inst::Inst::Load { .. }
            | minisa::isa::inst::Inst::Store { .. } => assert_eq!(a, b),
            _ => {}
        }
    }
}

/// Property: for random shapes, the searched decision's analytical traffic
/// numbers agree with the exact lowering's trace accounting.
#[test]
fn traffic_estimate_matches_lowering() {
    forall("traffic-vs-lowering", 25, |gen| {
        let cfg = ArchConfig::paper(4, 4);
        let g = Gemm::new(
            "p",
            "prop",
            gen.usize(1, 40),
            gen.usize(1, 32),
            gen.usize(1, 40),
        );
        let Some(d) = search(&cfg, &g, &fast_opts()) else { return };
        let prog = lower_gemm(&cfg, &g, &d.choice, d.i_order, d.w_order, d.o_order);
        let (minisa_bits, micro_bits) = instr_traffic(&cfg, &g, &d.choice).unwrap();
        assert_eq!(prog.minisa_bits, minisa_bits, "{g} {:?}", d.choice);
        assert_eq!(prog.micro_bits, micro_bits, "{g} {:?}", d.choice);
    });
}

/// Fig. 10 / Table I shape holds through the full evaluate path.
#[test]
fn speedup_and_reduction_scale_with_array() {
    let g = workloads::table1_workload();
    let small = evaluate_one(&ArchConfig::paper(4, 4), &g, &fast_opts()).unwrap();
    let large = evaluate_one(&ArchConfig::paper(16, 256), &g, &fast_opts()).unwrap();
    // Small array: compute-bound either way.
    assert!(small.speedup() < 1.5);
    assert!(small.micro.instr_stall_fraction() < 0.05);
    // Large array: micro-instructions are fetch-bound, MINISA is not.
    assert!(large.speedup() > 10.0, "speedup {}", large.speedup());
    assert!(large.micro.instr_stall_fraction() > 0.9);
    assert!(large.decision.report.instr_stall_fraction() < 0.01);
    assert!(large.instr_reduction() > small.instr_reduction());
    // Paper's instruction-to-data claim: micro instruction bytes are of
    // the order of the data (→ up to ~100× on reduction-deep shapes, see
    // below); MINISA's are negligible (<0.1%).
    assert!(large.micro_instr_to_data() > 0.5, "{}", large.micro_instr_to_data());
    assert!(large.minisa_instr_to_data() < 1e-3);
    // Deep-K ZKP NTT is the ~100× regime.
    let deep = workloads::zkp_ntt().swap_remove(2);
    let zk = evaluate_one(&ArchConfig::paper(16, 256), &deep, &fast_opts()).unwrap();
    assert!(zk.micro_instr_to_data() > 30.0, "{}", zk.micro_instr_to_data());
}

/// Utilization sanity across the paper sweep for an aligned workload.
#[test]
fn aligned_workload_utilization_high_everywhere() {
    let g = Gemm::new("aligned", "t", 4096, 1024, 1024);
    for cfg in ArchConfig::paper_sweep() {
        let d = search(&cfg, &g, &fast_opts()).unwrap();
        assert!(
            d.report.utilization() > 0.35,
            "{}: util {}",
            cfg.name(),
            d.report.utilization()
        );
    }
}

/// Program-vs-single-layer serving equivalence: a registered 3-layer chain
/// served through program requests is bit-identical to three sequential
/// single-GEMM requests through the ad-hoc path — while the chain's mapper
/// search and plan compilation run exactly once for N requests.
#[test]
fn program_serving_matches_sequential_single_gemms() {
    let cfg = ArchConfig::paper(4, 4);
    let chain = Chain::mlp("mlp3", 4, &[8, 12, 8, 6]);
    assert_eq!(chain.layers.len(), 3);
    let mut rng = Lcg::new(41);
    let weights: Vec<Vec<f32>> = chain.layers.iter().map(|g| rng.f32_matrix(g.k, g.n)).collect();
    let inputs: Vec<Vec<f32>> = (0..4).map(|_| rng.f32_matrix(4, 8)).collect();

    // Old path: each layer as its own single-GEMM request, sequenced on the
    // data dependence (layer i's response feeds layer i+1's request).
    let (tx_a, rx_a, h_a, _srv_a) = spawn(&cfg, Arc::new(NaiveExecutor));
    let weight_arcs: Vec<Arc<Vec<f32>>> = weights.iter().cloned().map(Arc::new).collect();
    let mut old_path: Vec<Vec<f32>> = Vec::new();
    for input in &inputs {
        let mut act = input.clone();
        for (g, w) in chain.layers.iter().zip(&weight_arcs) {
            tx_a.send(Request::gemm(0, g.m, g.k, g.n, act, Arc::clone(w))).unwrap();
            let resp = rx_a.recv().unwrap();
            assert!(resp.error.is_none());
            act = resp.output;
        }
        old_path.push(act);
    }
    drop(tx_a);
    h_a.join().unwrap();

    // New path: register the chain once, serve every activation against the
    // compiled program.
    let (tx_b, rx_b, h_b, srv_b) = spawn(&cfg, Arc::new(NaiveExecutor));
    let pid = srv_b.register_chain(&chain, weights).unwrap();
    for (id, input) in inputs.iter().enumerate() {
        tx_b.send(Request::for_program(id as u64, pid, 4, input.clone())).unwrap();
    }
    let mut new_path: Vec<Vec<f32>> = vec![Vec::new(); inputs.len()];
    for _ in 0..inputs.len() {
        let resp = rx_b.recv().unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        new_path[resp.id as usize] = resp.output;
    }
    drop(tx_b);
    let stats = h_b.join().unwrap();

    assert_eq!(old_path, new_path, "program path must be bit-identical to the ad-hoc path");
    // Compile-once/serve-many: one chain-aware mapper run for N requests,
    // and the program path never touches the per-shape mapper cache.
    assert_eq!(stats.program_compiles, 1);
    assert_eq!(stats.program_served, inputs.len() as u64);
    assert_eq!(stats.mapper_cache_misses, 0);
    // The compiled program reports the §IV-G2 boundary elision it found.
    let program = srv_b.program(pid).unwrap();
    assert!(program.plan_count() > 0);
}

/// Both dataflows stay exact under layer chaining shapes (tall and wide).
#[test]
fn dataflow_choice_respects_shape_heuristic() {
    let cfg = ArchConfig::paper(8, 8);
    // Wide N: WO-S preferred; tall M: IO-S competitive (§III-C1b).
    let wide = Gemm::new("wide", "t", 16, 64, 4096);
    let tall = Gemm::new("tall", "t", 4096, 64, 16);
    let dw = search(&cfg, &wide, &fast_opts()).unwrap();
    let dt = search(&cfg, &tall, &fast_opts()).unwrap();
    // The two shapes are transposes; their best latencies should match
    // closely because IO-S == transposed WO-S (§V-B).
    let ratio = dw.report.total_cycles / dt.report.total_cycles;
    assert!((0.5..2.0).contains(&ratio), "ratio {ratio}");
}
