//! Cross-module integration tests: mapper → ISA → functional simulator →
//! performance model, over real workload shapes and both dataflows.

use minisa::arch::ArchConfig;
use minisa::coordinator::evaluate_one;
use minisa::isa::encode::Codec;
use minisa::mapper::exec::validate_decision;
use minisa::mapper::search::{instr_traffic, search, MapperOptions};
use minisa::mapper::lower_gemm;
use minisa::util::prop::forall;
use minisa::workloads::{self, Gemm};

fn fast_opts() -> MapperOptions {
    MapperOptions { full_layout_search: false, threads: 2, ..Default::default() }
}

/// The full pipeline is exact on downscaled versions of every suite
/// workload family (full shapes are too large to simulate functionally;
/// shrinking M/K/N preserves every code path incl. padding).
#[test]
fn suite_shapes_downscaled_end_to_end_exact() {
    let cfg = ArchConfig::paper(4, 8);
    let shrink = |x: usize, cap: usize| x.min(cap).max(1);
    for g in workloads::suite50() {
        let small = Gemm::new(&g.name, &g.category, shrink(g.m, 24), shrink(g.k, 40), shrink(g.n, 24));
        let d = search(&cfg, &small, &fast_opts())
            .unwrap_or_else(|| panic!("no mapping for {small}"));
        let prog = lower_gemm(&cfg, &small, &d.choice, d.i_order, d.w_order, d.o_order);
        let (got, expect) = validate_decision(&cfg, &small, &prog, 9)
            .unwrap_or_else(|e| panic!("{small}: {e}"));
        assert_eq!(got, expect, "{small}");
    }
}

/// Lowered traces always encode and decode losslessly at the bit level.
#[test]
fn lowered_traces_roundtrip_through_codec() {
    let cfg = ArchConfig::paper(4, 4);
    let g = Gemm::new("rt", "t", 20, 24, 12);
    let d = search(&cfg, &g, &fast_opts()).unwrap();
    let prog = lower_gemm(&cfg, &g, &d.choice, d.i_order, d.w_order, d.o_order);
    let codec = Codec::new(&cfg);
    let bytes = codec.encode_all(&prog.trace.insts).expect("encodable");
    assert_eq!(bytes.len() as u64, prog.trace.size_bytes(&cfg));
    let decoded = codec.decode_n(&bytes, prog.trace.insts.len()).expect("decodable");
    // Execute/memory instructions must decode identically (layout VN size
    // is architectural, checked separately).
    for (a, b) in prog.trace.insts.iter().zip(&decoded) {
        match a {
            minisa::isa::inst::Inst::ExecuteMapping(_)
            | minisa::isa::inst::Inst::ExecuteStreaming(_)
            | minisa::isa::inst::Inst::Load { .. }
            | minisa::isa::inst::Inst::Store { .. } => assert_eq!(a, b),
            _ => {}
        }
    }
}

/// Property: for random shapes, the searched decision's analytical traffic
/// numbers agree with the exact lowering's trace accounting.
#[test]
fn traffic_estimate_matches_lowering() {
    forall("traffic-vs-lowering", 25, |gen| {
        let cfg = ArchConfig::paper(4, 4);
        let g = Gemm::new(
            "p",
            "prop",
            gen.usize(1, 40),
            gen.usize(1, 32),
            gen.usize(1, 40),
        );
        let Some(d) = search(&cfg, &g, &fast_opts()) else { return };
        let prog = lower_gemm(&cfg, &g, &d.choice, d.i_order, d.w_order, d.o_order);
        let (minisa_bits, micro_bits) = instr_traffic(&cfg, &g, &d.choice).unwrap();
        assert_eq!(prog.minisa_bits, minisa_bits, "{g} {:?}", d.choice);
        assert_eq!(prog.micro_bits, micro_bits, "{g} {:?}", d.choice);
    });
}

/// Fig. 10 / Table I shape holds through the full evaluate path.
#[test]
fn speedup_and_reduction_scale_with_array() {
    let g = workloads::table1_workload();
    let small = evaluate_one(&ArchConfig::paper(4, 4), &g, &fast_opts()).unwrap();
    let large = evaluate_one(&ArchConfig::paper(16, 256), &g, &fast_opts()).unwrap();
    // Small array: compute-bound either way.
    assert!(small.speedup() < 1.5);
    assert!(small.micro.instr_stall_fraction() < 0.05);
    // Large array: micro-instructions are fetch-bound, MINISA is not.
    assert!(large.speedup() > 10.0, "speedup {}", large.speedup());
    assert!(large.micro.instr_stall_fraction() > 0.9);
    assert!(large.decision.report.instr_stall_fraction() < 0.01);
    assert!(large.instr_reduction() > small.instr_reduction());
    // Paper's instruction-to-data claim: micro instruction bytes are of
    // the order of the data (→ up to ~100× on reduction-deep shapes, see
    // below); MINISA's are negligible (<0.1%).
    assert!(large.micro_instr_to_data() > 0.5, "{}", large.micro_instr_to_data());
    assert!(large.minisa_instr_to_data() < 1e-3);
    // Deep-K ZKP NTT is the ~100× regime.
    let deep = workloads::zkp_ntt().swap_remove(2);
    let zk = evaluate_one(&ArchConfig::paper(16, 256), &deep, &fast_opts()).unwrap();
    assert!(zk.micro_instr_to_data() > 30.0, "{}", zk.micro_instr_to_data());
}

/// Utilization sanity across the paper sweep for an aligned workload.
#[test]
fn aligned_workload_utilization_high_everywhere() {
    let g = Gemm::new("aligned", "t", 4096, 1024, 1024);
    for cfg in ArchConfig::paper_sweep() {
        let d = search(&cfg, &g, &fast_opts()).unwrap();
        assert!(
            d.report.utilization() > 0.35,
            "{}: util {}",
            cfg.name(),
            d.report.utilization()
        );
    }
}

/// Both dataflows stay exact under layer chaining shapes (tall and wide).
#[test]
fn dataflow_choice_respects_shape_heuristic() {
    let cfg = ArchConfig::paper(8, 8);
    // Wide N: WO-S preferred; tall M: IO-S competitive (§III-C1b).
    let wide = Gemm::new("wide", "t", 16, 64, 4096);
    let tall = Gemm::new("tall", "t", 4096, 64, 16);
    let dw = search(&cfg, &wide, &fast_opts()).unwrap();
    let dt = search(&cfg, &tall, &fast_opts()).unwrap();
    // The two shapes are transposes; their best latencies should match
    // closely because IO-S == transposed WO-S (§V-B).
    let ratio = dw.report.total_cycles / dt.report.total_cycles;
    assert!((0.5..2.0).contains(&ratio), "ratio {ratio}");
}
