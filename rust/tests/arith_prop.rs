//! Property tests for the `arith` element backends: for every backend,
//! random mapper-lowered GEMMs executed through the functional simulator
//! must match the naive reference *in that backend's number system* —
//! `ModP` against a schoolbook big-integer mod-p oracle, `SatI32`
//! bit-identical to the pre-refactor i32 path on overflow-heavy inputs
//! (products beyond i32, saturating inter-layer commits), `f32` on exactly
//! representable operands (so accumulation order cannot perturb bits).

use minisa::arch::ArchConfig;
use minisa::arith::{naive_gemm_e, BabyBear, Element, Goldilocks, ModP, PallasStyle, PrimeField};
use minisa::functional::{naive_gemm, FunctionalSim};
use minisa::mapper::exec::execute_program_on;
use minisa::mapper::lower_gemm;
use minisa::mapper::MappingChoice;
use minisa::mapping::Dataflow;
use minisa::program::Program;
use minisa::util::prop::{forall, Gen};
use minisa::workloads::Gemm;

/// Draw a random-but-legal mapping choice for `g` (mirrors the constraints
/// of the i32 `mapper-lowering-exact` property).
fn random_choice(gen: &mut Gen, cfg: &ArchConfig, g: &Gemm) -> (MappingChoice, u8, u8) {
    let (ah, aw) = (cfg.ah, cfg.aw);
    let vn = ah.min(g.k).max(1);
    let df = if gen.bool() { Dataflow::WoS } else { Dataflow::IoS };
    let (ms, ks, ns) = minisa::mapper::lower::search_dims(g, df);
    let m_t = gen.pick(&[ah, 2 * ah, 4 * ah]).min(&ms.max(1)).to_owned().max(1);
    let k_t = (*gen.pick(&[vn, 2 * vn, 4 * vn])).min(ks.max(1)).max(1);
    let n_t = (*gen.pick(&[1usize, 2, ah, 2 * ah])).min(ns.max(1)).max(1);
    let nbc = gen.pow2(0, 2).min(aw);
    let dup = gen.pow2(0, 2).min(aw / nbc).max(1);
    let io = gen.usize(0, 5) as u8;
    let oo = gen.usize(0, 5) as u8;
    (MappingChoice { df, vn, m_t, k_t, n_t, nbc, dup }, io, oo)
}

/// Lower + execute under backend `E`, asserting equality with the generic
/// naive reference.
fn check_exact<E: Element>(
    cfg: &ArchConfig,
    g: &Gemm,
    ch: &MappingChoice,
    orders: (u8, u8, u8),
    iv: &[E],
    wv: &[E],
) {
    let prog = lower_gemm(cfg, g, ch, orders.0, orders.1, orders.2);
    let mut sim: FunctionalSim<E> = FunctionalSim::new(cfg);
    let got = execute_program_on(&mut sim, g, &prog, iv, wv)
        .unwrap_or_else(|e| panic!("{} {g} {ch:?}: {e}", E::NAME));
    let expect = naive_gemm_e::<E>(iv, wv, g.m, g.k, g.n);
    assert_eq!(got, expect, "{} {g} {ch:?} orders {orders:?}", E::NAME);
}

/// Schoolbook mod-p oracle through u128 big-integer arithmetic — written
/// against canonical residues, independently of the Montgomery
/// representation under test.
fn schoolbook_modp(iv: &[u64], wv: &[u64], m: usize, k: usize, n: usize, p: u64) -> Vec<u64> {
    let mut o = vec![0u64; m * n];
    for mi in 0..m {
        for ki in 0..k {
            let a = iv[mi * k + ki] as u128;
            for ni in 0..n {
                let prod = (a * wv[ki * n + ni] as u128) % p as u128;
                let cell = &mut o[mi * n + ni];
                *cell = ((*cell as u128 + prod) % p as u128) as u64;
            }
        }
    }
    o
}

fn modp_property<F: PrimeField>() {
    forall(&format!("modp-gemm-exact-{}", F::NAME), 30, |gen| {
        let (ah, aw) = *gen.pick(&[(4usize, 4usize), (4, 8)]);
        let cfg = ArchConfig::paper(ah, aw);
        let m = gen.usize(1, 12);
        let k = gen.usize(1, 16);
        let n = gen.usize(1, 12);
        let g = Gemm::new("p", "prop", m, k, n);
        let (ch, io, oo) = random_choice(gen, &cfg, &g);
        // Uniform canonical residues — the full field, not small values.
        let ivc: Vec<u64> = (0..m * k).map(|_| gen.rng().next_u64() % F::P).collect();
        let wvc: Vec<u64> = (0..k * n).map(|_| gen.rng().next_u64() % F::P).collect();
        let iv: Vec<ModP<F>> = ivc.iter().map(|&x| ModP::<F>::new(x)).collect();
        let wv: Vec<ModP<F>> = wvc.iter().map(|&x| ModP::<F>::new(x)).collect();
        // Simulator vs generic naive reference…
        check_exact::<ModP<F>>(&cfg, &g, &ch, (io, 0, oo), &iv, &wv);
        // …and the generic reference itself vs the schoolbook mod-p oracle.
        let via_e: Vec<u64> =
            naive_gemm_e::<ModP<F>>(&iv, &wv, m, k, n).into_iter().map(|x| x.to_u64()).collect();
        assert_eq!(via_e, schoolbook_modp(&ivc, &wvc, m, k, n, F::P), "{} oracle", F::NAME);
    });
}

#[test]
fn modp_gemms_match_schoolbook_babybear() {
    modp_property::<BabyBear>();
}

#[test]
fn modp_gemms_match_schoolbook_goldilocks() {
    modp_property::<Goldilocks>();
}

#[test]
fn modp_gemms_match_schoolbook_pallas() {
    modp_property::<PallasStyle>();
}

/// `SatI32` on overflow-heavy operands (|v| up to 60000: products overflow
/// i32, sums stay safely inside the i64 accumulator): the generic path is
/// bit-identical to the pre-refactor `naive_gemm` i32 reference.
#[test]
fn sat_i32_overflow_heavy_bit_identical() {
    forall("sat-i32-overflow-heavy", 30, |gen| {
        let cfg = ArchConfig::paper(4, 4);
        let m = gen.usize(1, 10);
        let k = gen.usize(1, 16);
        let n = gen.usize(1, 10);
        let g = Gemm::new("p", "prop", m, k, n);
        let (ch, io, oo) = random_choice(gen, &cfg, &g);
        let big = |gen: &mut Gen| gen.usize(0, 120_000) as i32 - 60_000;
        let iv: Vec<i32> = (0..m * k).map(|_| big(gen)).collect();
        let wv: Vec<i32> = (0..k * n).map(|_| big(gen)).collect();
        check_exact::<i32>(&cfg, &g, &ch, (io, 0, oo), &iv, &wv);
        // The pre-refactor entry point and the generic one are the same
        // function on i32.
        assert_eq!(naive_gemm(&iv, &wv, m, k, n), naive_gemm_e::<i32>(&iv, &wv, m, k, n));
    });
}

/// A 2-layer chain whose first layer saturates (outputs beyond ±2^31): the
/// inter-layer `Element::reduce` commit clamps exactly like the
/// pre-refactor `clamp_acc` path, end to end through a compiled Program.
/// Second-layer weights stay small so the i64 accumulator cannot overflow
/// even on saturated ±2^31 activations.
#[test]
fn saturating_chain_matches_reference() {
    let cfg = ArchConfig::paper(4, 4);
    let opts = minisa::mapper::search::MapperOptions {
        full_layout_search: false,
        threads: 1,
        ..Default::default()
    };
    forall("sat-i32-chain", 10, |gen| {
        let chain = minisa::mapper::chain::Chain::mlp("sat", 4, &[8, 8, 8]);
        let p = Program::compile(&cfg, &chain, &opts).expect("feasible");
        let big = |gen: &mut Gen| gen.usize(0, 120_000) as i32 - 60_000;
        let small = |gen: &mut Gen| gen.usize(0, 6) as i32 - 3;
        let input: Vec<i32> = (0..p.rows() * p.in_features()).map(|_| big(gen)).collect();
        let weights: Vec<Vec<i32>> = chain
            .layers
            .iter()
            .enumerate()
            .map(|(li, g)| {
                (0..g.k * g.n).map(|_| if li == 0 { big(gen) } else { small(gen) }).collect()
            })
            .collect();
        let reference = p.reference_i32(&input, &weights);
        // The first layer must actually saturate for this case to bite.
        let l1 = naive_gemm(&input, &weights[0], 4, 8, 8);
        if l1.iter().all(|&v| v <= i32::MAX as i64 && v >= i32::MIN as i64) {
            return; // draw didn't overflow; property vacuous for this case
        }
        let mut sim = FunctionalSim::new(&cfg);
        let got = p.execute_i32(&mut sim, &input, &weights).unwrap();
        assert_eq!(got, reference, "saturating chain bit-identical");
    });
}

/// f32 on exactly representable integer operands: bit-identical to the
/// generic naive reference (all intermediate sums are exact integers well
/// below 2^24, so accumulation order is irrelevant).
#[test]
fn f32_exact_on_representable_operands() {
    forall("f32-gemm-exact", 30, |gen| {
        let cfg = ArchConfig::paper(4, 4);
        let m = gen.usize(1, 10);
        let k = gen.usize(1, 12);
        let n = gen.usize(1, 10);
        let g = Gemm::new("p", "prop", m, k, n);
        let (ch, io, oo) = random_choice(gen, &cfg, &g);
        let iv: Vec<f32> = (0..m * k).map(|_| gen.usize(0, 16) as f32 - 8.0).collect();
        let wv: Vec<f32> = (0..k * n).map(|_| gen.usize(0, 16) as f32 - 8.0).collect();
        check_exact::<f32>(&cfg, &g, &ch, (io, 0, oo), &iv, &wv);
    });
}

/// Encode/decode round-trips over the serving word format for every
/// backend, on full-range draws (the `Gen::u64_below` / `Gen::i32_any`
/// generators added for this suite).
#[test]
fn word_encoding_roundtrips() {
    forall("word-encoding-roundtrip", 200, |gen| {
        let v = gen.i32_any();
        assert_eq!(i32::decode(v.encode()), v);
        assert_eq!(<i32 as Element>::reduce(v as i64), v, "reduce is identity inside i32");
        let b = gen.u64_below(BabyBear::P);
        assert_eq!(ModP::<BabyBear>::decode(b).encode(), b);
        let gl = gen.u64_below(Goldilocks::P);
        assert_eq!(ModP::<Goldilocks>::decode(gl).encode(), gl);
        let pa = gen.u64_below(PallasStyle::P);
        assert_eq!(ModP::<PallasStyle>::decode(pa).encode(), pa);
    });
}
