//! Telemetry integration suite (§Observability tentpole,
//! docs/OBSERVABILITY.md) — the three guarantees the unified telemetry
//! layer makes, proven against the real serving stack:
//!
//! 1. **Exact under concurrency**: counters and histograms incremented
//!    from N threads sum exactly — no lost updates, bucket counts always
//!    sum to the event count.
//! 2. **Complete, ordered timelines**: a traced request's span covers
//!    every pipeline stage (arrival → admission → batch → dispatch →
//!    execute → stitch → respond) with monotonically non-decreasing
//!    timestamps, and each stage's delta lands in its
//!    `serve_stage_<name>_us` histogram.
//! 3. **Tracing is invisible**: serving results are bit-identical with
//!    tracing on and off, and a tracing-disabled server registers zero
//!    span histograms.

use std::sync::Arc;

use minisa::arch::ArchConfig;
use minisa::coordinator::serve::{
    spawn_with_options, NaiveExecutor, Request, Response, ServerOptions,
};
use minisa::obs::{MetricsRegistry, Snapshot, Stage, TraceOptions};
use minisa::util::Lcg;

/// Serve `n` deterministic ad-hoc GEMM requests (seeded inputs, shared
/// weight) under the given tracing options; responses sorted by id plus
/// the server's final telemetry snapshot.
fn gemm_burst(tracing: TraceOptions, n: usize) -> (Vec<Response>, Snapshot) {
    let cfg = ArchConfig::paper(4, 4);
    let opts = ServerOptions { tracing, ..Default::default() };
    let (tx, rx, h, server) = spawn_with_options(&cfg, Arc::new(NaiveExecutor), opts);
    let mut rng = Lcg::new(5);
    let w = Arc::new(rng.f32_matrix(8, 4));
    for id in 0..n as u64 {
        tx.send(Request::gemm(id, 4, 8, 4, rng.f32_matrix(4, 8), Arc::clone(&w))).unwrap();
    }
    let mut got: Vec<Response> = (0..n).map(|_| rx.recv().unwrap()).collect();
    drop(tx);
    h.join().unwrap();
    got.sort_by_key(|r| r.id);
    let snap = server.metrics_snapshot(1_000.0);
    (got, snap)
}

#[test]
fn concurrent_counter_and_histogram_updates_sum_exactly() {
    const THREADS: usize = 8;
    const PER_THREAD: usize = 10_000;
    let reg = Arc::new(MetricsRegistry::new());
    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let reg = Arc::clone(&reg);
            std::thread::spawn(move || {
                // Fetch handles once, like the serving hot path does.
                let c = reg.counter("telemetry_events_total");
                let h = reg.histogram("telemetry_latency_us");
                for i in 0..PER_THREAD {
                    c.inc();
                    h.record(((t * PER_THREAD + i) % 1000) as f64);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let total = (THREADS * PER_THREAD) as u64;
    let snap = reg.snapshot();
    assert_eq!(snap.counter("telemetry_events_total"), Some(total));
    let hist = snap.histogram("telemetry_latency_us").expect("histogram registered");
    assert_eq!(hist.count, total, "histogram lost events under concurrency");
    let bucket_sum: u64 = hist.buckets.iter().map(|&(_, n)| n).sum();
    assert_eq!(bucket_sum, total, "bucket counts must sum to the event count");
    assert_eq!(hist.min, 0.0);
    assert_eq!(hist.max, 999.0);
}

#[test]
fn traced_requests_carry_complete_ordered_timelines() {
    let n = 6;
    let (got, snap) = gemm_burst(TraceOptions::all(), n);
    for r in &got {
        assert!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
        let t = r.trace.as_ref().unwrap_or_else(|| panic!("request {} untraced", r.id));
        assert!(t.is_complete(), "request {} timeline incomplete: {:?}", r.id, t.stages());
        assert!(t.is_monotonic(), "request {} timestamps regressed", r.id);
        assert_eq!(t.stages(), Stage::ALL.to_vec());
        // Every delta is a non-negative duration and they sum to the
        // end-to-end latency.
        let deltas = t.deltas_us();
        assert_eq!(deltas.len(), Stage::ALL.len() - 1);
        let sum: f64 = deltas.iter().map(|&(_, us)| us).sum();
        assert!((sum - t.total_us()).abs() < 1.0, "deltas {sum} vs total {}", t.total_us());
    }
    // Each stage's histogram saw every request (arrival opens the timeline
    // and has no duration, hence no histogram).
    for stage in &Stage::ALL[1..] {
        let name = format!("serve_stage_{}_us", stage.name());
        let h = snap.histogram(&name).unwrap_or_else(|| panic!("{name} missing"));
        assert_eq!(h.count, n as u64, "{name}");
    }
    assert_eq!(snap.histogram("serve_request_us").map(|h| h.count), Some(n as u64));
}

#[test]
fn tracing_is_bitwise_invisible_and_off_means_zero_span_entries() {
    let n = 5;
    let (traced, _) = gemm_burst(TraceOptions::all(), n);
    let (plain, snap_off) = gemm_burst(TraceOptions::default(), n);
    assert_eq!(traced.len(), plain.len());
    for (a, b) in traced.iter().zip(&plain) {
        assert_eq!(a.id, b.id);
        // Bit-level equality, not float comparison: tracing must not
        // perturb the computation at all.
        let abits: Vec<u32> = a.output.iter().map(|v| v.to_bits()).collect();
        let bbits: Vec<u32> = b.output.iter().map(|v| v.to_bits()).collect();
        assert_eq!(abits, bbits, "request {} output diverged under tracing", a.id);
        assert_eq!(a.output_words, b.output_words);
        assert!(a.trace.is_some(), "traced run lost request {}'s trace", a.id);
        assert!(b.trace.is_none(), "untraced run grew a trace on request {}", b.id);
    }
    // Span histograms are created only by trace recording, so the
    // tracing-disabled server's registry has none.
    assert!(
        snap_off.histograms.is_empty(),
        "tracing disabled but histograms registered: {:?}",
        snap_off.histograms.iter().map(|(k, _)| k.clone()).collect::<Vec<_>>()
    );
    assert_eq!(snap_off.counter("serve_served_total"), Some(n as u64));
}

#[test]
fn sampling_traces_exactly_one_in_n() {
    let cfg = ArchConfig::paper(4, 4);
    let opts = ServerOptions {
        tracing: TraceOptions { enabled: true, sample_every: 3 },
        ..Default::default()
    };
    let (tx, rx, h, _server) = spawn_with_options(&cfg, Arc::new(NaiveExecutor), opts);
    let mut rng = Lcg::new(11);
    let w = Arc::new(rng.f32_matrix(8, 4));
    // Serialized send/recv so arrival order (and thus the sample sequence)
    // is deterministic.
    for id in 0..9u64 {
        tx.send(Request::gemm(id, 4, 8, 4, rng.f32_matrix(4, 8), Arc::clone(&w))).unwrap();
        let r = rx.recv().unwrap();
        assert_eq!(r.id, id);
        assert_eq!(r.trace.is_some(), id % 3 == 0, "request {id}");
    }
    drop(tx);
    let stats = h.join().unwrap();
    assert_eq!(stats.served, 9);
}

#[test]
fn exporters_render_the_live_snapshot() {
    let (_, snap) = gemm_burst(TraceOptions::all(), 3);
    let prom = snap.to_prometheus();
    assert!(prom.contains("# TYPE serve_served_total counter"), "{prom}");
    assert!(prom.contains("# TYPE serve_request_us histogram"), "{prom}");
    assert!(prom.contains("serve_request_us_bucket{le=\"+Inf\"} 3"), "{prom}");
    assert!(prom.contains("# TYPE fleet_dev0_busy_us gauge"), "{prom}");
    let json = snap.to_json();
    assert!(json.contains("\"schema\": 1"), "{json}");
    assert!(json.contains("\"serve_served_total\": 3"), "{json}");
    assert!(json.contains("\"serve_stage_execute_us\""), "{json}");
    assert_eq!(json.matches('{').count(), json.matches('}').count(), "{json}");
    assert_eq!(json.matches('[').count(), json.matches(']').count(), "{json}");
}
