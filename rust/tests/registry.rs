//! Registry battery — the acceptance criteria of the content-addressed
//! artifact registry, as tests:
//!
//! * **Zero-downtime hot swap**: a loadgen-style stream is served across a
//!   [`Server::swap`] to a stored *delta* — every request is answered
//!   exactly once, bit-exact against whichever artifact version admitted
//!   it, with zero errors and zero program compiles on the serving path;
//! * **Zero-copy load**: three sessions registered under one content hash
//!   share a single decoded weight allocation (pointer identity), with the
//!   shared program cache reporting exactly one miss;
//! * **Delta round-trip**: a weights-only delta resolves to bytes
//!   *identical* to a full recompile of the same chain + weights;
//! * **Concurrency**: N threads put/get/gc one on-disk store without torn
//!   blobs; a get of a gc'd key is the typed miss, never a panic or a
//!   corruption report; the program cache stays within capacity under
//!   racing loads.

use std::sync::Arc;

use minisa::arch::ArchConfig;
use minisa::arith::ElemType;
use minisa::artifact::{Artifact, Compiler};
use minisa::coordinator::serve::{
    execute_program_words, spawn_with_options, ArtifactSource, NaiveExecutor, Request,
    ServerOptions, WordWeights,
};
use minisa::mapper::chain::Chain;
use minisa::program::Program;
use minisa::registry::{DirBackend, MemBackend, Registry, RegistryError, RegistryKey};
use minisa::util::Lcg;

fn sample_weights(chain: &Chain, elem: ElemType, seed: u64) -> Vec<Vec<u64>> {
    let mut rng = Lcg::new(seed);
    chain.layers.iter().map(|g| elem.sample_words(&mut rng, g.k * g.n)).collect()
}

fn compile(cfg: &ArchConfig, chain: &Chain, elem: ElemType, seed: u64) -> Artifact {
    Compiler::new(cfg)
        .elem(elem)
        .weights(sample_weights(chain, elem, seed))
        .compile(chain)
        .expect("compile")
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("minisa_regtest_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Every request answered exactly once and bit-exact against whichever
/// artifact version admitted it, across a hot swap from a registry base to
/// a stored delta — zero errors, zero compiles on the serving path.
#[test]
fn hot_swap_is_zero_downtime_and_bit_exact() {
    let cfg = ArchConfig::paper(4, 4);
    let elem = ElemType::BabyBear;
    let chain = Chain::mlp("swapmlp", 4, &[8, 12, 8]);
    let reg = Arc::new(Registry::new(Box::new(MemBackend::new()), 4));

    let v1 = compile(&cfg, &chain, elem, 11);
    let base_key = reg.put(&v1).unwrap();
    let w2 = sample_weights(&chain, elem, 22);
    let delta_key = reg.put_delta(base_key, elem, w2.clone()).unwrap();
    assert_ne!(base_key, delta_key);

    // Reference oracle: the exact output stream each version must produce.
    let prog = Program::from_artifact(&v1).unwrap();
    let rows = 4usize;
    let mut rng = Lcg::new(7);
    let input = elem.sample_words(&mut rng, rows * prog.in_features());
    let expected1 =
        execute_program_words(&prog, rows, &input, &WordWeights::new(sample_weights(&chain, elem, 11), elem))
            .unwrap();
    let expected2 =
        execute_program_words(&prog, rows, &input, &WordWeights::new(w2, elem)).unwrap();
    assert_ne!(expected1, expected2, "versions must be distinguishable");

    let opts = ServerOptions { registry: Some(Arc::clone(&reg)), ..Default::default() };
    let (tx, rx, handle, server) =
        spawn_with_options(&cfg, Arc::new(NaiveExecutor), opts);
    let pid = server
        .register(ArtifactSource::Registry { key: base_key.to_string() })
        .unwrap();

    const PRE: u64 = 24;
    const TOTAL: u64 = 48;
    for id in 0..PRE {
        tx.send(Request::for_program_words(id, pid, rows, input.clone())).unwrap();
    }
    // Atomic switch: once swap() returns, every later admission is v2.
    server
        .swap(pid, ArtifactSource::Registry { key: delta_key.to_string() })
        .unwrap();
    for id in PRE..TOTAL {
        tx.send(Request::for_program_words(id, pid, rows, input.clone())).unwrap();
    }
    drop(tx);

    let mut seen = vec![0u32; TOTAL as usize];
    let (mut n_v1, mut n_v2) = (0u64, 0u64);
    for resp in rx.iter() {
        assert!(resp.error.is_none(), "request {} errored: {:?}", resp.id, resp.error);
        seen[resp.id as usize] += 1;
        if resp.output_words == expected1 {
            n_v1 += 1;
            assert!(resp.id < PRE, "v1 output after the swap returned (id {})", resp.id);
        } else if resp.output_words == expected2 {
            n_v2 += 1;
        } else {
            panic!("request {} matches neither artifact version", resp.id);
        }
    }
    assert!(seen.iter().all(|&c| c == 1), "every request answered exactly once: {seen:?}");
    // Both versions actually served (the stream straddled the swap), and
    // everything sent after the swap admitted against v2.
    assert!(n_v2 >= TOTAL - PRE, "post-swap requests are all v2 ({n_v1} v1 / {n_v2} v2)");

    let stats = handle.join().unwrap();
    assert_eq!(stats.served, TOTAL);
    assert_eq!(stats.errors, 0);
    assert_eq!(stats.shed, 0);
    assert_eq!(stats.swaps, 1);
    assert_eq!(stats.swap_failed, 0);
    assert_eq!(stats.program_compiles, 0, "no compile ever touches the serving path");
    assert_eq!(stats.artifact_loads, 2, "initial session + swap replacement");
    assert_eq!(stats.registry_misses, 2, "base load and delta load each miss once");
}

/// Three sessions registered under one content hash share a single decoded
/// weight allocation — pointer identity, not just value equality — and the
/// shared program cache reports exactly one miss.
#[test]
fn three_sessions_one_weight_allocation() {
    let cfg = ArchConfig::paper(4, 4);
    let elem = ElemType::Goldilocks;
    let chain = Chain::mlp("shared", 4, &[8, 12, 8]);
    let reg = Arc::new(Registry::new(Box::new(MemBackend::new()), 4));
    let key = reg.put(&compile(&cfg, &chain, elem, 33)).unwrap();

    let opts = ServerOptions { registry: Some(Arc::clone(&reg)), ..Default::default() };
    let (tx, rx, handle, server) = spawn_with_options(&cfg, Arc::new(NaiveExecutor), opts);
    let pids: Vec<_> = (0..3)
        .map(|_| server.register(ArtifactSource::Registry { key: key.to_string() }).unwrap())
        .collect();
    let ptrs: Vec<_> = pids.iter().map(|&p| server.weights_ptr(p).unwrap()).collect();
    assert_eq!(ptrs[0], ptrs[1]);
    assert_eq!(ptrs[1], ptrs[2], "one allocation across all sessions: {ptrs:?}");
    let cs = reg.cache_stats();
    assert_eq!((cs.misses, cs.hits), (1, 2));

    // All three sessions serve, bit-identically (same content hash).
    let prog = server.program(pids[0]).unwrap();
    let mut rng = Lcg::new(9);
    let input = elem.sample_words(&mut rng, 4 * prog.in_features());
    for (i, &p) in pids.iter().enumerate() {
        tx.send(Request::for_program_words(i as u64, p, 4, input.clone())).unwrap();
    }
    drop(tx);
    let outs: Vec<_> = rx.iter().map(|r| {
        assert!(r.error.is_none());
        r.output_words
    }).collect();
    assert_eq!(outs.len(), 3);
    assert_eq!(outs[0], outs[1]);
    assert_eq!(outs[1], outs[2]);
    let stats = handle.join().unwrap();
    assert_eq!(stats.program_compiles, 0);
    assert_eq!(stats.artifact_loads, 3);
    assert_eq!((stats.registry_misses, stats.registry_hits), (1, 2));
}

/// A weights-only delta round-trips to bytes identical to a full recompile
/// of the same chain + weights — content addressing is a fixed point of
/// composition.
#[test]
fn delta_round_trips_to_full_recompile_bytes() {
    let cfg = ArchConfig::paper(4, 8);
    let elem = ElemType::I32;
    let chain = Chain::mlp("deltamlp", 8, &[8, 16, 8]);
    let reg = Registry::new(Box::new(MemBackend::new()), 4);

    let base_key = reg.put(&compile(&cfg, &chain, elem, 5)).unwrap();
    let w2 = sample_weights(&chain, elem, 6);
    let delta_key = reg.put_delta(base_key, elem, w2.clone()).unwrap();

    let resolved = reg.get(delta_key).unwrap();
    let full = Compiler::new(&cfg).elem(elem).weights(w2).compile(&chain).unwrap();
    assert_eq!(resolved.to_bytes(), full.to_bytes(), "delta ≡ full recompile, byte for byte");
    // And the content address *is* the full recompile's address.
    let (full_key, _) = RegistryKey::of(&full);
    assert_eq!(delta_key, full_key);
}

/// N threads hammer one on-disk store with put/get/gc. No torn blobs: every
/// get either verifies fully or is the typed miss — never a corruption
/// report, never a panic.
#[test]
fn concurrent_put_get_gc_without_torn_blobs() {
    let dir = temp_dir("conc");
    let cfg = ArchConfig::paper(4, 4);
    let elem = ElemType::BabyBear;
    // A pool of distinct artifacts (distinct weight seeds → distinct keys).
    let chain = Chain::mlp("conc", 4, &[8, 12, 8]);
    let pool: Vec<Artifact> = (0..4).map(|s| compile(&cfg, &chain, elem, 100 + s)).collect();
    let keys: Vec<RegistryKey> = pool.iter().map(|a| RegistryKey::of(a).0).collect();

    let reg = Arc::new(Registry::new(Box::new(DirBackend::open(&dir).unwrap()), 2));
    let handles: Vec<_> = (0..8)
        .map(|t| {
            let reg = Arc::clone(&reg);
            let pool = pool.clone();
            let keys = keys.clone();
            std::thread::spawn(move || {
                for i in 0..20 {
                    let j = (t + i) % pool.len();
                    match (t + i) % 3 {
                        0 => {
                            reg.put(&pool[j]).unwrap();
                        }
                        1 => match reg.get(keys[j]) {
                            // get() verified the content hash internally.
                            Ok(art) => assert_eq!(RegistryKey::of(&art).0, keys[j]),
                            Err(RegistryError::Miss(_)) => {}
                            Err(e) => panic!("torn or corrupt read: {e}"),
                        },
                        _ => {
                            // Unpinned gc mid-race: deletes nothing that is
                            // resolvable, must never error.
                            reg.gc(&[]).unwrap();
                            // Racing loads keep the LRU within capacity.
                            match reg.load(keys[j]) {
                                Ok(_) => {
                                    let cs = reg.cache_stats();
                                    assert!(cs.len <= cs.capacity, "LRU overflow: {cs:?}");
                                }
                                Err(RegistryError::Miss(_)) => {}
                                Err(e) => panic!("load hit torn state: {e}"),
                            }
                        }
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    // Quiescent store verifies end to end.
    for (key, check) in reg.verify_all().unwrap() {
        check.unwrap_or_else(|e| panic!("{key} failed post-race verify: {e}"));
    }
    let cs = reg.cache_stats();
    assert!(cs.len <= cs.capacity);
    std::fs::remove_dir_all(&dir).ok();
}

/// gc honors the pinned closure (a pinned delta keeps its base alive), a
/// get of a gc'd key is the typed miss, and a dangling delta is the typed
/// error — then gc removes it.
#[test]
fn gc_pins_bases_and_misses_are_typed() {
    let dir = temp_dir("gc");
    let cfg = ArchConfig::paper(4, 4);
    let elem = ElemType::Pallas;
    let chain = Chain::mlp("gcmlp", 4, &[8, 8]);
    let reg = Registry::new(Box::new(DirBackend::open(&dir).unwrap()), 2);

    let base = reg.put(&compile(&cfg, &chain, elem, 1)).unwrap();
    let delta = reg.put_delta(base, elem, sample_weights(&chain, elem, 2)).unwrap();
    let loner = reg.put(&compile(&cfg, &chain, elem, 3)).unwrap();

    // Pinning the delta keeps its transitive base; the loner goes.
    let report = reg.gc(&[delta]).unwrap();
    assert_eq!(report.deleted, vec![loner]);
    assert_eq!(report.kept.len(), 2);
    assert!(reg.get(base).is_ok());
    assert!(reg.get(delta).is_ok());
    match reg.get(loner) {
        Err(RegistryError::Miss(_)) => {}
        other => panic!("gc'd key must be the typed miss, got {other:?}"),
    }

    // Deleting the base under the delta makes the delta dangling — typed,
    // and the next gc sweeps it.
    assert!(reg.delete(base).unwrap());
    match reg.get(delta) {
        Err(RegistryError::Dangling { .. }) => {}
        other => panic!("expected Dangling, got {other:?}"),
    }
    reg.gc(&[]).unwrap();
    match reg.get(delta) {
        Err(RegistryError::Miss(_)) => {}
        other => panic!("dangling delta must be swept to a miss, got {other:?}"),
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Cross-process durability: what one Registry handle puts, a second handle
/// over the same directory resolves and fully verifies (the CI smoke runs
/// the same flow across real processes via the CLI).
#[test]
fn second_handle_over_same_dir_resolves_deltas() {
    let dir = temp_dir("dur");
    let cfg = ArchConfig::paper(4, 4);
    let elem = ElemType::Goldilocks;
    let chain = Chain::mlp("dur", 4, &[8, 12, 8]);
    let (base, delta) = {
        let reg = Registry::new(Box::new(DirBackend::open(&dir).unwrap()), 2);
        let base = reg.put(&compile(&cfg, &chain, elem, 41)).unwrap();
        let delta = reg.put_delta(base, elem, sample_weights(&chain, elem, 42)).unwrap();
        (base, delta)
    };
    let reg2 = Registry::new(Box::new(DirBackend::open(&dir).unwrap()), 2);
    let art = reg2.get(delta).unwrap();
    assert_eq!(RegistryKey::of(&art).0, delta);
    assert!(reg2.get(base).is_ok());
    let entries = reg2.list().unwrap();
    assert_eq!(entries.len(), 2);
    assert!(entries.iter().any(|e| e.kind == "delta" && e.base == Some(base.content)));
    std::fs::remove_dir_all(&dir).ok();
}
