//! Failure-injection tests: corrupt lowered programs in targeted ways and
//! verify the functional simulator rejects (or provably tolerates) each
//! fault instead of silently producing wrong numbers.

use minisa::arch::ArchConfig;
use minisa::functional::SimError;
use minisa::isa::inst::{BufTarget, Inst};
use minisa::mapper::exec::{execute_program, validate_decision};
use minisa::mapper::search::{search, MapperOptions};
use minisa::mapper::lower_gemm;
use minisa::util::Lcg;
use minisa::workloads::Gemm;

fn setup() -> (ArchConfig, Gemm, minisa::mapper::lower::LoweredProgram) {
    let cfg = ArchConfig::paper(4, 4);
    let g = Gemm::new("fi", "t", 12, 12, 12);
    let opts = MapperOptions { full_layout_search: false, ..Default::default() };
    let d = search(&cfg, &g, &opts).unwrap();
    let prog = lower_gemm(&cfg, &g, &d.choice, d.i_order, d.w_order, d.o_order);
    (cfg, g, prog)
}

fn operands(g: &Gemm, seed: u64) -> (Vec<i32>, Vec<i32>) {
    let mut rng = Lcg::new(seed);
    (
        (0..g.m * g.k).map(|_| rng.range(0, 9) as i32 - 4).collect(),
        (0..g.k * g.n).map(|_| rng.range(0, 9) as i32 - 4).collect(),
    )
}

#[test]
fn baseline_program_is_valid() {
    let (cfg, g, prog) = setup();
    let (got, expect) = validate_decision(&cfg, &g, &prog, 5).unwrap();
    assert_eq!(got, expect);
}

#[test]
fn dropping_execute_mapping_is_detected() {
    let (cfg, g, mut prog) = setup();
    let idx = prog
        .trace
        .insts
        .iter()
        .position(|i| matches!(i, Inst::ExecuteMapping(_)))
        .unwrap();
    prog.trace.insts.remove(idx);
    let (iv, wv) = operands(&g, 1);
    let r = execute_program(&cfg, &g, &prog, &iv, &wv);
    assert_eq!(r.unwrap_err(), SimError::NoMapping);
}

#[test]
fn dropping_layout_setter_is_detected() {
    let (cfg, g, mut prog) = setup();
    // Remove every SetIVNLayout and SetWVNLayout — executes must then fail.
    prog.trace
        .insts
        .retain(|i| !matches!(i, Inst::SetIVNLayout(_) | Inst::SetWVNLayout(_)));
    let (iv, wv) = operands(&g, 2);
    let r = execute_program(&cfg, &g, &prog, &iv, &wv);
    assert!(matches!(r, Err(SimError::NoLayout(_))), "{r:?}");
}

#[test]
fn corrupted_load_address_is_detected_or_changes_output() {
    let (cfg, g, mut prog) = setup();
    // Point the first Load at a wild HBM address.
    for inst in prog.trace.insts.iter_mut() {
        if let Inst::Load { hbm_addr, .. } = inst {
            *hbm_addr = 0xFFF_FFFF;
            break;
        }
    }
    let (iv, wv) = operands(&g, 3);
    match execute_program(&cfg, &g, &prog, &iv, &wv) {
        Err(SimError::HbmOutOfRange { .. }) => {}
        Err(e) => panic!("unexpected error class: {e}"),
        Ok(out) => {
            // If the address happened to land in mapped HBM the result must
            // differ from the reference (no silent luck).
            let expect = minisa::functional::naive_gemm(&iv, &wv, g.m, g.k, g.n);
            assert_ne!(out, expect, "corrupted load produced correct output");
        }
    }
}

#[test]
fn oversized_load_is_rejected() {
    let (cfg, g, mut prog) = setup();
    for inst in prog.trace.insts.iter_mut() {
        if let Inst::Load { rows, .. } = inst {
            *rows = (cfg.d_str() + 1) as u32;
            break;
        }
    }
    let (iv, wv) = operands(&g, 4);
    let r = execute_program(&cfg, &g, &prog, &iv, &wv);
    assert!(matches!(r, Err(SimError::BufferOverflow { .. })), "{r:?}");
}

#[test]
fn illegal_mapping_params_rejected_by_validation() {
    let (cfg, g, mut prog) = setup();
    for inst in prog.trace.insts.iter_mut() {
        if let Inst::ExecuteMapping(em) = inst {
            em.g_r = cfg.aw + 1; // out of [1, AW]
            break;
        }
    }
    let (iv, wv) = operands(&g, 5);
    let r = execute_program(&cfg, &g, &prog, &iv, &wv);
    assert!(matches!(r, Err(SimError::Invalid(_))), "{r:?}");
}

#[test]
fn swapped_buffer_targets_corrupt_results_detectably() {
    let (cfg, g, mut prog) = setup();
    // Swap the streaming/stationary targets of the two loads: data lands in
    // the wrong buffers.
    for inst in prog.trace.insts.iter_mut() {
        if let Inst::Load { target, .. } = inst {
            *target = match target {
                BufTarget::Streaming => BufTarget::Stationary,
                BufTarget::Stationary => BufTarget::Streaming,
            };
        }
    }
    let (iv, wv) = operands(&g, 6);
    match execute_program(&cfg, &g, &prog, &iv, &wv) {
        Err(_) => {} // rejected is fine
        Ok(out) => {
            let expect = minisa::functional::naive_gemm(&iv, &wv, g.m, g.k, g.n);
            assert_ne!(out, expect, "swapped buffers silently correct");
        }
    }
}

#[test]
fn truncated_trace_yields_incomplete_output() {
    let (cfg, g, mut prog) = setup();
    // Drop the last ExecuteStreaming: some outputs must be missing/wrong.
    let idx = prog
        .trace
        .insts
        .iter()
        .rposition(|i| matches!(i, Inst::ExecuteStreaming(_)))
        .unwrap();
    prog.trace.insts.remove(idx);
    let (iv, wv) = operands(&g, 7);
    let out = execute_program(&cfg, &g, &prog, &iv, &wv).expect("still executes");
    let expect = minisa::functional::naive_gemm(&iv, &wv, g.m, g.k, g.n);
    assert_ne!(out, expect, "dropping compute left output intact");
}

#[test]
fn bitflip_in_encoded_stream_never_panics() {
    // Decode robustness: flip each byte of the encoded trace and decode —
    // must return Ok(different program) or a clean error, never panic.
    let (cfg, _g, prog) = setup();
    let codec = minisa::isa::encode::Codec::new(&cfg);
    let bytes = codec.encode_all(&prog.trace.insts).unwrap();
    let n = prog.trace.insts.len();
    for i in 0..bytes.len().min(64) {
        let mut corrupt = bytes.clone();
        corrupt[i] ^= 0xA5;
        let _ = codec.decode_n(&corrupt, n); // Ok or Err, both acceptable
    }
}
