//! Failure-injection tests: corrupt lowered programs in targeted ways and
//! verify the functional simulator rejects (or provably tolerates) each
//! fault instead of silently producing wrong numbers — plus fleet-level
//! injection: device dropout under concurrent load and executor panics
//! inside tile-parallel shards.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use minisa::arch::ArchConfig;
use minisa::arith::{decode_words, naive_gemm_e, ElemType, Goldilocks, ModP};
use minisa::coordinator::fleet::{Fleet, FleetOptions};
use minisa::coordinator::serve::{
    spawn_with_options, NaiveExecutor, Request, ServerOptions, TileExecutor,
};
use minisa::functional::SimError;
use minisa::isa::inst::{BufTarget, Inst};
use minisa::mapper::chain::Chain;
use minisa::mapper::exec::{execute_program, validate_decision};
use minisa::mapper::search::{search, MapperOptions};
use minisa::mapper::lower_gemm;
use minisa::program::Program;
use minisa::util::Lcg;
use minisa::workloads::Gemm;

fn setup() -> (ArchConfig, Gemm, minisa::mapper::lower::LoweredProgram) {
    let cfg = ArchConfig::paper(4, 4);
    let g = Gemm::new("fi", "t", 12, 12, 12);
    let opts = MapperOptions { full_layout_search: false, ..Default::default() };
    let d = search(&cfg, &g, &opts).unwrap();
    let prog = lower_gemm(&cfg, &g, &d.choice, d.i_order, d.w_order, d.o_order);
    (cfg, g, prog)
}

fn operands(g: &Gemm, seed: u64) -> (Vec<i32>, Vec<i32>) {
    let mut rng = Lcg::new(seed);
    (
        (0..g.m * g.k).map(|_| rng.range(0, 9) as i32 - 4).collect(),
        (0..g.k * g.n).map(|_| rng.range(0, 9) as i32 - 4).collect(),
    )
}

#[test]
fn baseline_program_is_valid() {
    let (cfg, g, prog) = setup();
    let (got, expect) = validate_decision(&cfg, &g, &prog, 5).unwrap();
    assert_eq!(got, expect);
}

#[test]
fn dropping_execute_mapping_is_detected() {
    let (cfg, g, mut prog) = setup();
    let idx = prog
        .trace
        .insts
        .iter()
        .position(|i| matches!(i, Inst::ExecuteMapping(_)))
        .unwrap();
    prog.trace.insts.remove(idx);
    let (iv, wv) = operands(&g, 1);
    let r = execute_program(&cfg, &g, &prog, &iv, &wv);
    assert_eq!(r.unwrap_err(), SimError::NoMapping);
}

#[test]
fn dropping_layout_setter_is_detected() {
    let (cfg, g, mut prog) = setup();
    // Remove every SetIVNLayout and SetWVNLayout — executes must then fail.
    prog.trace
        .insts
        .retain(|i| !matches!(i, Inst::SetIVNLayout(_) | Inst::SetWVNLayout(_)));
    let (iv, wv) = operands(&g, 2);
    let r = execute_program(&cfg, &g, &prog, &iv, &wv);
    assert!(matches!(r, Err(SimError::NoLayout(_))), "{r:?}");
}

#[test]
fn corrupted_load_address_is_detected_or_changes_output() {
    let (cfg, g, mut prog) = setup();
    // Point the first Load at a wild HBM address.
    for inst in prog.trace.insts.iter_mut() {
        if let Inst::Load { hbm_addr, .. } = inst {
            *hbm_addr = 0xFFF_FFFF;
            break;
        }
    }
    let (iv, wv) = operands(&g, 3);
    match execute_program(&cfg, &g, &prog, &iv, &wv) {
        Err(SimError::HbmOutOfRange { .. }) => {}
        Err(e) => panic!("unexpected error class: {e}"),
        Ok(out) => {
            // If the address happened to land in mapped HBM the result must
            // differ from the reference (no silent luck).
            let expect = minisa::functional::naive_gemm(&iv, &wv, g.m, g.k, g.n);
            assert_ne!(out, expect, "corrupted load produced correct output");
        }
    }
}

#[test]
fn oversized_load_is_rejected() {
    let (cfg, g, mut prog) = setup();
    for inst in prog.trace.insts.iter_mut() {
        if let Inst::Load { rows, .. } = inst {
            *rows = (cfg.d_str() + 1) as u32;
            break;
        }
    }
    let (iv, wv) = operands(&g, 4);
    let r = execute_program(&cfg, &g, &prog, &iv, &wv);
    assert!(matches!(r, Err(SimError::BufferOverflow { .. })), "{r:?}");
}

#[test]
fn illegal_mapping_params_rejected_by_validation() {
    let (cfg, g, mut prog) = setup();
    for inst in prog.trace.insts.iter_mut() {
        if let Inst::ExecuteMapping(em) = inst {
            em.g_r = cfg.aw + 1; // out of [1, AW]
            break;
        }
    }
    let (iv, wv) = operands(&g, 5);
    let r = execute_program(&cfg, &g, &prog, &iv, &wv);
    assert!(matches!(r, Err(SimError::Invalid(_))), "{r:?}");
}

#[test]
fn swapped_buffer_targets_corrupt_results_detectably() {
    let (cfg, g, mut prog) = setup();
    // Swap the streaming/stationary targets of the two loads: data lands in
    // the wrong buffers.
    for inst in prog.trace.insts.iter_mut() {
        if let Inst::Load { target, .. } = inst {
            *target = match target {
                BufTarget::Streaming => BufTarget::Stationary,
                BufTarget::Stationary => BufTarget::Streaming,
            };
        }
    }
    let (iv, wv) = operands(&g, 6);
    match execute_program(&cfg, &g, &prog, &iv, &wv) {
        Err(_) => {} // rejected is fine
        Ok(out) => {
            let expect = minisa::functional::naive_gemm(&iv, &wv, g.m, g.k, g.n);
            assert_ne!(out, expect, "swapped buffers silently correct");
        }
    }
}

#[test]
fn truncated_trace_yields_incomplete_output() {
    let (cfg, g, mut prog) = setup();
    // Drop the last ExecuteStreaming: some outputs must be missing/wrong.
    let idx = prog
        .trace
        .insts
        .iter()
        .rposition(|i| matches!(i, Inst::ExecuteStreaming(_)))
        .unwrap();
    prog.trace.insts.remove(idx);
    let (iv, wv) = operands(&g, 7);
    let out = execute_program(&cfg, &g, &prog, &iv, &wv).expect("still executes");
    let expect = minisa::functional::naive_gemm(&iv, &wv, g.m, g.k, g.n);
    assert_ne!(out, expect, "dropping compute left output intact");
}

/// Concurrency stress: 32 concurrent clients against a 3-device fleet with
/// one device dropping mid-stream. Every request must get a response
/// (result or error) with no hangs, and all work — including anything
/// requeued off the dropped device — must land bit-exact against the
/// chained naive mod-p reference.
#[test]
fn fleet_dropout_under_concurrent_load_answers_everything_exactly() {
    type G = ModP<Goldilocks>;
    let cfg = ArchConfig::paper(4, 4);
    let opts = ServerOptions { devices: 3, shard_min_rows: 2, max_batch: 8, ..Default::default() };
    let (tx, rx, h, server) = spawn_with_options(&cfg, Arc::new(NaiveExecutor), opts);
    let chain = Chain::mlp("stress", 4, &[8, 12, 8]);
    let mut rng = Lcg::new(0xD20);
    let weights: Vec<Vec<u64>> = chain
        .layers
        .iter()
        .map(|g| ElemType::Goldilocks.sample_words(&mut rng, g.k * g.n))
        .collect();
    let pid = server.register_chain_elem(&chain, weights.clone(), ElemType::Goldilocks).unwrap();
    let wg: Vec<Vec<G>> = weights.iter().map(|w| decode_words::<G>(w)).collect();

    let n_clients = 32u64;
    // Precompute inputs and expected outputs (chained naive mod-p).
    let cases: Vec<(u64, Vec<u64>, Vec<u64>)> = (0..n_clients)
        .map(|id| {
            let rows = 4usize;
            let input = ElemType::Goldilocks.sample_words(&mut rng, rows * 8);
            use minisa::arith::Element;
            let mut act: Vec<G> = decode_words::<G>(&input);
            let mut out = Vec::new();
            for (g, w) in chain.layers.iter().zip(&wg) {
                out = naive_gemm_e::<G>(&act, w, rows, g.k, g.n);
                act = out.iter().map(|&v| <G as Element>::reduce(v)).collect();
            }
            let expect: Vec<u64> = out.into_iter().map(|v| v.to_u64()).collect();
            (id, input, expect)
        })
        .collect();

    std::thread::scope(|s| {
        // 32 concurrent clients.
        for (id, input, _) in &cases {
            let txc = tx.clone();
            let (id, input) = (*id, input.clone());
            s.spawn(move || {
                txc.send(Request::for_program_words(id, pid, 4, input)).unwrap();
            });
        }
        // One device drops mid-stream.
        let fleet = Arc::clone(server.fleet());
        s.spawn(move || {
            std::thread::sleep(Duration::from_millis(3));
            assert!(fleet.fail_device(1));
        });
    });

    // No hangs: every request is answered within the timeout.
    let mut got: HashMap<u64, _> = HashMap::new();
    for _ in 0..n_clients {
        let r = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("every request answered, no hang");
        got.insert(r.id, r);
    }
    assert_eq!(got.len() as u64, n_clients);
    for (id, _, expect) in &cases {
        let r = &got[id];
        assert!(r.error.is_none(), "request {id}: {:?}", r.error);
        assert_eq!(&r.output_words, expect, "request {id} bit-exact (incl. requeued work)");
    }
    drop(tx);
    let stats = h.join().unwrap();
    assert_eq!(stats.program_served, n_clients);
    assert_eq!(stats.errors, 0, "dropout requeues, it does not error");
    assert!(server.fleet().devices()[1].is_failed());
    assert_eq!(server.fleet().plan_compiles(), 0);
    // The survivors carried the whole load: total executed rows equals the
    // stream (requests may be co-batched, so count rows, not dispatches).
    let rows_total: u64 =
        server.fleet().devices().iter().map(|d| d.stats().rows).sum();
    assert_eq!(rows_total, n_clients * 4);
}

/// An executor that panics when the leading activation element carries a
/// poison marker — used to panic exactly one tile-parallel shard.
struct PanicOnMarker;

impl TileExecutor for PanicOnMarker {
    fn gemm(&self, m: usize, k: usize, n: usize, iv: &[f32], wv: &[f32]) -> anyhow::Result<Vec<f32>> {
        NaiveExecutor.gemm(m, k, n, iv, wv)
    }
    fn run_program(
        &self,
        program: &Program,
        rows: usize,
        input: &[f32],
        weights: &Arc<Vec<Vec<f32>>>,
    ) -> anyhow::Result<Vec<f32>> {
        assert!(input.first() != Some(&666.0), "injected shard panic");
        let mut act = input.to_vec();
        for (layer, w) in program.layers.iter().zip(weights.iter()) {
            act = self.gemm(rows, layer.gemm.k, layer.gemm.n, &act, w)?;
        }
        Ok(act)
    }
    fn name(&self) -> &str {
        "panic-on-marker"
    }
}

/// Regression: a panic inside one shard must not leak a "busy" device slot.
/// After the contained panic, every device reads idle and a subsequent
/// multi-shard batch uses all of them again.
#[test]
fn shard_panic_restores_device_availability() {
    let cfg = ArchConfig::paper(4, 4);
    let fleet = Fleet::new(
        &cfg,
        Arc::new(PanicOnMarker),
        FleetOptions { devices: 2, shard_min_rows: 1, ..Default::default() },
    );
    let chain = Chain::mlp("panic", 4, &[8, 8]);
    let o = MapperOptions { full_layout_search: false, threads: 1, ..Default::default() };
    let program = Program::compile(&cfg, &chain, &o).unwrap();
    let mut rng = Lcg::new(3);
    let weights: Arc<Vec<Vec<f32>>> =
        Arc::new(chain.layers.iter().map(|g| rng.f32_matrix(g.k, g.n)).collect());

    // Poison the first row: the first shard panics, the batch errors.
    let mut poisoned = rng.f32_matrix(4, 8);
    poisoned[0] = 666.0;
    let e = fleet.run_program(None, &program, 4, &poisoned, &weights).unwrap_err();
    assert!(e.to_string().contains("panicked"), "{e}");
    assert!(
        fleet.devices().iter().all(|d| !d.is_busy()),
        "no leaked busy slots after a shard panic"
    );

    // The fleet still shards across *both* devices afterwards, bit-exact.
    let input = rng.f32_matrix(4, 8);
    let got = fleet.run_program(None, &program, 4, &input, &weights).unwrap();
    let mut act = input.clone();
    for (g, w) in chain.layers.iter().zip(weights.iter()) {
        act = NaiveExecutor.gemm(4, g.k, g.n, &act, w).unwrap();
    }
    assert_eq!(got, act);
    for d in fleet.devices() {
        assert!(d.stats().shards >= 1, "device {} reused after the panic", d.id);
        assert!(!d.is_busy());
    }
}

// ----------------------------------------------------------------------
// FaultPlan battery: scripted dropout + slow-shard + panic schedules
// across {SatI32, f32, Goldilocks} × devices ∈ {1, 3, 7}. The `faults`
// feature is forced on for test builds by the self-dev-dependency in
// Cargo.toml, so `FaultPlan` is available here.
// ----------------------------------------------------------------------

use minisa::coordinator::fleet::{FaultDropout, FaultPlan};
use minisa::coordinator::serve::{Response, ServeStats};

const BATTERY_REQUESTS: u64 = 24;
const BATTERY_ROWS: usize = 4;

struct StreamResult {
    got: HashMap<u64, Response>,
    stats: ServeStats,
    /// Total rows executed across fleet devices (0 on the inline
    /// single-device leader, which does not route through the fleet).
    rows_executed: u64,
    busy_leak: bool,
}

/// Serve `BATTERY_REQUESTS` requests of `BATTERY_ROWS` rows each through a
/// fresh server, optionally under a fault plan. The request payloads are
/// derived from a fixed seed, so two calls with the same `elem` see an
/// identical stream — the fault-free single-device call is the bit-exact
/// reference for every faulted configuration.
fn run_stream(elem: ElemType, devices: usize, plan: Option<FaultPlan>) -> StreamResult {
    let cfg = ArchConfig::paper(4, 4);
    let opts =
        ServerOptions { devices, shard_min_rows: 1, max_batch: 4, ..Default::default() };
    let (tx, rx, h, server) = spawn_with_options(&cfg, Arc::new(NaiveExecutor), opts);
    let chain = Chain::mlp("battery", BATTERY_ROWS, &[8, 12, 8]);
    let mut rng = Lcg::new(0xBA77E57);
    let pid = if elem == ElemType::F32 {
        let ws: Vec<Vec<f32>> =
            chain.layers.iter().map(|g| rng.f32_matrix(g.k, g.n)).collect();
        server.register_chain(&chain, ws).unwrap()
    } else {
        let ws: Vec<Vec<u64>> =
            chain.layers.iter().map(|g| elem.sample_words(&mut rng, g.k * g.n)).collect();
        server.register_chain_elem(&chain, ws, elem).unwrap()
    };
    if let Some(p) = plan {
        server.fleet().set_fault_plan(p);
    }
    for id in 0..BATTERY_REQUESTS {
        let r = if elem == ElemType::F32 {
            Request::for_program(id, pid, BATTERY_ROWS, rng.f32_matrix(BATTERY_ROWS, 8))
        } else {
            let words = elem.sample_words(&mut rng, BATTERY_ROWS * 8);
            Request::for_program_words(id, pid, BATTERY_ROWS, words)
        };
        tx.send(r).unwrap();
    }
    let mut got = HashMap::new();
    for _ in 0..BATTERY_REQUESTS {
        let r = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("every request answered, no hang");
        assert!(got.insert(r.id, r).is_none(), "duplicate response");
    }
    drop(tx);
    let stats = h.join().unwrap();
    let busy_leak = server.fleet().devices().iter().any(|d| d.is_busy());
    let rows_executed = server.fleet().devices().iter().map(|d| d.stats().rows).sum();
    StreamResult { got, stats, rows_executed, busy_leak }
}

/// The scripted schedule: a transient dropout early, a permanent dropout
/// later (when the fleet is big enough to survive it), slow shards
/// throughout, and optionally seeded executor panics.
fn scripted_plan(devices: usize, panics: bool) -> FaultPlan {
    let mut dropouts = Vec::new();
    if devices > 1 {
        dropouts.push(FaultDropout { device: 1, after_shards: 2, transient: true });
    }
    if devices > 2 {
        dropouts.push(FaultDropout { device: 2, after_shards: 6, transient: false });
    }
    FaultPlan {
        seed: 0xFA11,
        dropouts,
        slow_prob: 0.2,
        slow_ms: 1,
        panic_prob: if panics { 0.15 } else { 0.0 },
    }
}

/// The battery proper: for one element type, every fleet size × schedule
/// combination must answer every request exactly once (success or typed
/// error), leak no busy slots, conserve rows, and answer all successful
/// work bit-identical to the fault-free single-device reference.
fn fault_battery(elem: ElemType) {
    let reference = run_stream(elem, 1, None);
    assert_eq!(reference.stats.errors, 0, "fault-free reference must not error");
    for r in reference.got.values() {
        assert!(r.error.is_none(), "reference request {}: {:?}", r.id, r.error);
    }

    for devices in [1usize, 3, 7] {
        for panics in [false, true] {
            let r = run_stream(elem, devices, Some(scripted_plan(devices, panics)));
            let label = format!("{elem} × {devices} devices, panics={panics}");
            assert_eq!(r.got.len() as u64, BATTERY_REQUESTS, "{label}");
            assert!(!r.busy_leak, "{label}: leaked busy slot");
            let mut succeeded = 0u64;
            for (id, resp) in &r.got {
                match &resp.error {
                    None => {
                        succeeded += 1;
                        let refr = &reference.got[id];
                        assert_eq!(resp.output, refr.output, "{label}: request {id}");
                        assert_eq!(
                            resp.output_words, refr.output_words,
                            "{label}: request {id}"
                        );
                    }
                    Some(msg) => {
                        // Injected panics are the only permitted failure —
                        // dropouts requeue and slow shards just wait.
                        assert!(panics, "{label}: unexpected error: {msg}");
                        assert!(
                            resp.code.is_some(),
                            "{label}: untyped error for request {id}: {msg}"
                        );
                    }
                }
            }
            if !panics {
                assert_eq!(
                    succeeded, BATTERY_REQUESTS,
                    "{label}: dropout/slow schedules must not fail requests"
                );
                assert_eq!(r.stats.errors, 0, "{label}");
            }
            if devices > 1 {
                // Rows conserved: every successful request's rows executed
                // at least once (panicked attempts may add more).
                assert!(
                    r.rows_executed >= succeeded * BATTERY_ROWS as u64,
                    "{label}: executed {} rows for {} successes",
                    r.rows_executed,
                    succeeded
                );
                if !panics {
                    assert_eq!(
                        r.rows_executed,
                        BATTERY_REQUESTS * BATTERY_ROWS as u64,
                        "{label}: dropouts must requeue, not re-execute, work"
                    );
                }
            }
        }
    }
}

#[test]
fn fault_battery_sat_i32() {
    fault_battery(ElemType::I32);
}

#[test]
fn fault_battery_f32() {
    fault_battery(ElemType::F32);
}

#[test]
fn fault_battery_goldilocks() {
    fault_battery(ElemType::Goldilocks);
}

/// Heterogeneous-fleet fault injection (§Sched satellite): in a mixed-arch
/// fleet exactly one device matches the session's arch fingerprint, and the
/// scripted schedule drops that device permanently mid-stream. Requests
/// served before the dropout stay bit-exact; every request after it is
/// *answered* with the typed `no eligible device` error — no hang — and at
/// no point does a wrong-arch device execute a row.
#[test]
fn hetero_fleet_dropping_only_eligible_device_errors_cleanly() {
    use minisa::coordinator::admission::ErrorCode;
    type G = ModP<Goldilocks>;
    let home = ArchConfig::paper(4, 4);
    let opts = ServerOptions {
        device_archs: vec![
            ArchConfig::paper(4, 4),
            ArchConfig::paper(4, 8),
            ArchConfig::paper(4, 8),
        ],
        shard_min_rows: 1,
        max_batch: 4,
        ..Default::default()
    };
    let (tx, rx, h, server) = spawn_with_options(&home, Arc::new(NaiveExecutor), opts);
    let chain = Chain::mlp("hetero-fault", 4, &[8, 12, 8]);
    let elem = ElemType::Goldilocks;
    let mut rng = Lcg::new(0x4E7E);
    let weights: Vec<Vec<u64>> =
        chain.layers.iter().map(|g| elem.sample_words(&mut rng, g.k * g.n)).collect();
    let pid = server.register_chain_elem(&chain, weights.clone(), elem).unwrap();
    let wg: Vec<Vec<G>> = weights.iter().map(|w| decode_words::<G>(w)).collect();
    // The session's only eligible device drops permanently after two shards.
    server.fleet().set_fault_plan(FaultPlan {
        dropouts: vec![FaultDropout { device: 0, after_shards: 2, transient: false }],
        ..Default::default()
    });
    let n_req = 8u64;
    let mut successes = 0u64;
    let mut errors = 0u64;
    for id in 0..n_req {
        let input = elem.sample_words(&mut rng, 4 * 8);
        // Lock-step send/recv: each request is its own batch, so the
        // dropout lands at a deterministic request boundary.
        tx.send(Request::for_program_words(id, pid, 4, input.clone())).unwrap();
        let r = rx.recv_timeout(Duration::from_secs(30)).expect("answered, no hang");
        assert_eq!(r.id, id);
        match &r.error {
            None => {
                assert_eq!(errors, 0, "no success after the eligible device dropped");
                successes += 1;
                use minisa::arith::Element;
                let mut act: Vec<G> = decode_words::<G>(&input);
                let mut out = Vec::new();
                for (g, w) in chain.layers.iter().zip(&wg) {
                    out = naive_gemm_e::<G>(&act, w, 4, g.k, g.n);
                    act = out.iter().map(|&v| <G as Element>::reduce(v)).collect();
                }
                let expect: Vec<u64> = out.into_iter().map(|v| v.to_u64()).collect();
                assert_eq!(r.output_words, expect, "request {id} bit-exact before dropout");
            }
            Some(msg) => {
                errors += 1;
                assert_eq!(
                    r.code,
                    Some(ErrorCode::NoEligibleDevice),
                    "request {id}: typed no_eligible_device, got {:?}: {msg}",
                    r.code
                );
                assert!(
                    msg.contains("no eligible device"),
                    "request {id}: scheduler names the cause: {msg}"
                );
            }
        }
    }
    drop(tx);
    let stats = h.join().unwrap();
    assert!(successes >= 1, "the eligible device served work before dropping");
    assert!(errors >= 1, "the permanent dropout surfaced as typed errors");
    assert_eq!(stats.errors, errors);
    assert!(server.fleet().devices()[0].is_failed());
    // No misplacement at any point: the arch-incompatible devices never
    // executed a shard, before or after the dropout.
    for d in &server.fleet().devices()[1..] {
        let st = d.stats();
        assert_eq!((st.shards, st.rows), (0, 0), "device {} is 4x8, session is 4x4", d.id);
    }
}

#[test]
fn bitflip_in_encoded_stream_never_panics() {
    // Decode robustness: flip each byte of the encoded trace and decode —
    // must return Ok(different program) or a clean error, never panic.
    let (cfg, _g, prog) = setup();
    let codec = minisa::isa::encode::Codec::new(&cfg);
    let bytes = codec.encode_all(&prog.trace.insts).unwrap();
    let n = prog.trace.insts.len();
    for i in 0..bytes.len().min(64) {
        let mut corrupt = bytes.clone();
        corrupt[i] ^= 0xA5;
        let _ = codec.decode_n(&corrupt, n); // Ok or Err, both acceptable
    }
}
