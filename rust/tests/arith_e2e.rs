//! End-to-end acceptance for the `arith` subsystem (ISSUE 3): the suite's
//! ZKP-NTT entry executes *for real* over a Montgomery prime field through
//! the compile-once Program path, and a served program-session response is
//! bit-exact against the naive mod-p reference.
//!
//! The full-size entry (K=N=8192) would need a 512 MB twiddle matrix, so
//! the tests run the entry scaled to a CI-sized transform via
//! `workloads::ntt::scaled` — same category, same K=N/M=K÷16 structure,
//! same field, same lowering path.

use std::sync::Arc;

use minisa::arch::ArchConfig;
use minisa::arith::{decode_words, ElemType, Goldilocks, ModP};
use minisa::coordinator::serve::{spawn, NaiveExecutor, Request};
use minisa::functional::FunctionalSim;
use minisa::mapper::chain::Chain;
use minisa::mapper::search::MapperOptions;
use minisa::program::Program;
use minisa::util::Lcg;
use minisa::workloads::{self, ntt};

type G = ModP<Goldilocks>;

fn fast() -> MapperOptions {
    MapperOptions { full_layout_search: false, threads: 1, ..Default::default() }
}

/// The scaled ZKP-NTT suite entry as a 1-layer chain plus its twiddle
/// weights over the entry's default field (Goldilocks for ZKP).
fn zkp_ntt_chain(max_n: usize) -> (Chain, usize, Vec<G>) {
    let entry = &workloads::zkp_ntt()[0];
    assert_eq!(ntt::default_elem(&entry.category), ElemType::Goldilocks);
    let g = ntt::scaled(entry, max_n);
    let n = ntt::ntt_size(&g).expect("scaled entry is still an NTT kernel");
    assert_eq!(g.m, n / 16, "ZKP M = K/16 rule survives scaling");
    let tw = ntt::twiddle_matrix::<Goldilocks>(n).unwrap();
    (Chain { layers: vec![g] }, n, tw)
}

/// ZKP-NTT executes end-to-end over ModP via the Program path: compiled
/// once, zero runtime plan compiles, output equal to the schoolbook NTT.
#[test]
fn zkp_ntt_entry_executes_field_exact_via_program_path() {
    let cfg = ArchConfig::paper(4, 4);
    let (chain, n, tw) = zkp_ntt_chain(64);
    let m = chain.layers[0].m;
    let program = Program::compile(&cfg, &chain, &fast()).expect("ZKP-NTT maps");
    assert!(program.plan_count() > 0, "wave plans precompiled");

    let mut rng = Lcg::new(0x5EED);
    let input: Vec<G> = (0..m * n).map(|_| G::new(rng.next_u64())).collect();
    let mut sim: FunctionalSim<G> = FunctionalSim::new(&cfg);
    let got = program.execute(&mut sim, &input, &[tw.clone()]).unwrap();
    assert_eq!(sim.plan_compiles, 0, "compile-once: zero runtime plan compiles");

    let expect = ntt::ntt_reference::<Goldilocks>(&input, m, n).unwrap();
    assert_eq!(got, expect, "NTT-as-GEMM over the Program path is field-exact");

    // Repeat executions stay compile-free on the same simulator.
    let input2: Vec<G> = (0..m * n).map(|_| G::new(rng.next_u64())).collect();
    let _ = program.execute(&mut sim, &input2, &[tw]).unwrap();
    assert_eq!(sim.plan_compiles, 0);
}

/// The 2-layer NTT → INTT chain is the identity over the field — the
/// strongest cheap witness that *chained* field execution (including the
/// inter-layer OB commit, which must be a field no-op) is exact.
#[test]
fn ntt_intt_chain_is_identity() {
    let cfg = ArchConfig::paper(4, 4);
    let n = 16usize;
    let m = 4usize;
    let g1 = minisa::workloads::Gemm::new("ntt", "ZKP-NTT", m, n, n);
    let g2 = minisa::workloads::Gemm::new("intt", "ZKP-NTT", m, n, n);
    let chain = Chain { layers: vec![g1, g2] };
    let program = Program::compile(&cfg, &chain, &fast()).expect("chain maps");
    let weights =
        vec![ntt::twiddle_matrix::<Goldilocks>(n).unwrap(), ntt::intt_matrix::<Goldilocks>(n).unwrap()];
    let mut rng = Lcg::new(77);
    let input: Vec<G> = (0..m * n).map(|_| G::new(rng.next_u64())).collect();
    let mut sim: FunctionalSim<G> = FunctionalSim::new(&cfg);
    let got = program.execute(&mut sim, &input, &weights).unwrap();
    assert_eq!(got, input, "INTT(NTT(x)) == x through the compiled chain");
    assert_eq!(sim.plan_compiles, 0);
}

/// Serving acceptance: the scaled ZKP-NTT registered as an element-typed
/// session — compiled exactly once (`program_compiles == 1`), served
/// responses bit-exact against the schoolbook mod-p reference.
#[test]
fn served_zkp_ntt_session_is_bit_exact_against_naive_modp() {
    let cfg = ArchConfig::paper(4, 4);
    let (chain, n, _) = zkp_ntt_chain(32);
    let m = chain.layers[0].m;
    let (tx, rx, h, server) = spawn(&cfg, Arc::new(NaiveExecutor));
    let tw_words = ntt::twiddle_words(ElemType::Goldilocks, n).unwrap();
    let pid = server.register_chain_elem(&chain, vec![tw_words], ElemType::Goldilocks).unwrap();
    assert_eq!(server.session_elem(pid), Some(ElemType::Goldilocks));

    let mut rng = Lcg::new(0xE2E);
    let n_req = 5u64;
    let mut expects = std::collections::HashMap::new();
    for id in 0..n_req {
        let input_words = ElemType::Goldilocks.sample_words(&mut rng, m * n);
        let input: Vec<G> = decode_words::<G>(&input_words);
        let expect: Vec<u64> = ntt::ntt_reference::<Goldilocks>(&input, m, n)
            .unwrap()
            .into_iter()
            .map(|x| x.to_u64())
            .collect();
        expects.insert(id, expect);
        tx.send(Request::for_program_words(id, pid, m, input_words)).unwrap();
    }
    for _ in 0..n_req {
        let resp = rx.recv().unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(
            &resp.output_words, &expects[&resp.id],
            "served NTT bit-exact vs naive mod-p reference"
        );
    }
    drop(tx);
    let stats = h.join().unwrap();
    assert_eq!(stats.program_compiles, 1, "NTT chain compiled exactly once");
    assert_eq!(stats.program_served, n_req);
    assert_eq!(stats.errors, 0);
}

/// Field sessions of different primes coexist on one server and answer in
/// their own number systems (batch keys differ by program id; payload kind
/// separation is covered in the serve unit tests).
#[test]
fn mixed_field_sessions_coexist() {
    use minisa::arith::BabyBear;
    type B = ModP<BabyBear>;
    let cfg = ArchConfig::paper(4, 4);
    let n = 16usize;
    let m = 2usize;
    let (tx, rx, h, server) = spawn(&cfg, Arc::new(NaiveExecutor));
    let mk_chain = |name: &str, cat: &str| Chain {
        layers: vec![minisa::workloads::Gemm::new(name, cat, m, n, n)],
    };
    let pid_g = server
        .register_chain_elem(
            &mk_chain("zkp", "ZKP-NTT"),
            vec![ntt::twiddle_words(ElemType::Goldilocks, n).unwrap()],
            ElemType::Goldilocks,
        )
        .unwrap();
    let pid_b = server
        .register_chain_elem(
            &mk_chain("fhe", "FHE-NTT"),
            vec![ntt::twiddle_words(ElemType::BabyBear, n).unwrap()],
            ElemType::BabyBear,
        )
        .unwrap();
    let mut rng = Lcg::new(9);
    let in_g = ElemType::Goldilocks.sample_words(&mut rng, m * n);
    let in_b = ElemType::BabyBear.sample_words(&mut rng, m * n);
    let expect_g: Vec<u64> = ntt::ntt_reference::<Goldilocks>(&decode_words::<G>(&in_g), m, n)
        .unwrap()
        .into_iter()
        .map(|x| x.to_u64())
        .collect();
    let expect_b: Vec<u64> = ntt::ntt_reference::<BabyBear>(&decode_words::<B>(&in_b), m, n)
        .unwrap()
        .into_iter()
        .map(|x| x.to_u64())
        .collect();
    tx.send(Request::for_program_words(0, pid_g, m, in_g)).unwrap();
    tx.send(Request::for_program_words(1, pid_b, m, in_b)).unwrap();
    for _ in 0..2 {
        let r = rx.recv().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        let expect = if r.id == 0 { &expect_g } else { &expect_b };
        assert_eq!(&r.output_words, expect, "request {} exact in its own field", r.id);
        assert_eq!(r.batch_size, 1, "different sessions never co-batch");
    }
    drop(tx);
    let stats = h.join().unwrap();
    assert_eq!(stats.program_compiles, 2);
    assert_eq!(stats.program_served, 2);
}
