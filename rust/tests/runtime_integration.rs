//! PJRT runtime integration: loads the AOT artifacts produced by
//! `make artifacts` and cross-checks them against the functional simulator
//! and naive references. Skips (with a loud message) when artifacts are
//! missing, so `cargo test` works pre-`make artifacts`.

use std::path::Path;

use minisa::arch::ArchConfig;
use minisa::coordinator::serve::TileExecutor;
use minisa::runtime::{gemm_via_tiles, PjrtExecutor, Runtime};
use minisa::util::Lcg;

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts` for runtime tests");
        None
    }
}

fn naive(m: usize, k: usize, n: usize, iv: &[f32], wv: &[f32]) -> Vec<f32> {
    let mut o = vec![0f32; m * n];
    for mi in 0..m {
        for ki in 0..k {
            for ni in 0..n {
                o[mi * n + ni] += iv[mi * k + ki] * wv[ki * n + ni];
            }
        }
    }
    o
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() <= tol * (1.0 + y.abs()), "{what}[{i}]: {x} vs {y}");
    }
}

#[test]
fn artifacts_all_load_and_execute() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::open(dir).expect("runtime");
    assert!(rt.artifacts().len() >= 6, "expected all aot.py artifacts");
    let mut rng = Lcg::new(3);
    for meta in rt.artifacts().to_vec() {
        let args: Vec<Vec<f32>> = meta
            .args
            .iter()
            .map(|s| rng.f32_matrix(s[0], s[1]))
            .collect();
        let refs: Vec<&[f32]> = args.iter().map(|a| a.as_slice()).collect();
        let out = rt.execute_f32(&meta.name, &refs).unwrap_or_else(|e| {
            panic!("{}: {e:#}", meta.name);
        });
        assert!(!out.is_empty(), "{}", meta.name);
        assert!(out.iter().all(|v| v.is_finite()), "{}: non-finite", meta.name);
    }
}

#[test]
fn gemm_artifact_matches_naive_exactly_for_ints() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::open(dir).expect("runtime");
    let mut rng = Lcg::new(7);
    // Integer-valued f32 operands → Pallas/XLA result must be bit-exact.
    let iv: Vec<f32> = (0..64 * 64).map(|_| (rng.range(0, 15) as i32 - 7) as f32).collect();
    let wv: Vec<f32> = (0..64 * 64).map(|_| (rng.range(0, 15) as i32 - 7) as f32).collect();
    let out = rt.execute_f32("gemm_64x64x64", &[&iv, &wv]).unwrap();
    let expect = naive(64, 64, 64, &iv, &wv);
    assert_eq!(out, expect);
}

#[test]
fn irregular_tile_artifact_matches() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::open(dir).expect("runtime");
    let mut rng = Lcg::new(8);
    let iv = rng.f32_matrix(64, 40);
    let wv = rng.f32_matrix(40, 88);
    let out = rt.execute_f32("gemm_64x40x88", &[&iv, &wv]).unwrap();
    assert_close(&out, &naive(64, 40, 88, &iv, &wv), 1e-4, "gemm_64x40x88");
}

#[test]
fn tiled_execution_covers_mismatched_shapes() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::open(dir).expect("runtime");
    let mut rng = Lcg::new(9);
    // 100×50×70 has no exact artifact → the tiler must pad and slice.
    let (m, k, n) = (100usize, 50usize, 70usize);
    let iv = rng.f32_matrix(m, k);
    let wv = rng.f32_matrix(k, n);
    let out = gemm_via_tiles(&rt, m, k, n, &iv, &wv).unwrap();
    assert_close(&out, &naive(m, k, n, &iv, &wv), 1e-4, "tiled 100x50x70");
}

#[test]
fn chain_artifact_matches_two_layer_reference() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::open(dir).expect("runtime");
    let mut rng = Lcg::new(10);
    let x = rng.f32_matrix(32, 64);
    let w1 = rng.f32_matrix(64, 48);
    let w2 = rng.f32_matrix(48, 32);
    let out = rt.execute_f32("chain_32x64x48x32", &[&x, &w1, &w2]).unwrap();
    // Reference: layer2(relu(layer1(x))).
    let h: Vec<f32> = naive(32, 64, 48, &x, &w1).iter().map(|v| v.max(0.0)).collect();
    let expect = naive(32, 48, 32, &h, &w2);
    assert_close(&out, &expect, 1e-4, "chain");
}

#[test]
fn pjrt_executor_thread_isolated() {
    let Some(dir) = artifacts() else { return };
    let exe = PjrtExecutor::start(dir).expect("executor");
    assert_eq!(exe.platform(), "cpu");
    let mut rng = Lcg::new(11);
    let iv = rng.f32_matrix(64, 64);
    let wv = rng.f32_matrix(64, 64);
    let out = exe.gemm(64, 64, 64, &iv, &wv).unwrap();
    assert_close(&out, &naive(64, 64, 64, &iv, &wv), 1e-4, "executor");
    // Callable from several threads concurrently.
    std::thread::scope(|s| {
        for t in 0..4 {
            let exe = &exe;
            s.spawn(move || {
                let mut rng = Lcg::new(100 + t);
                let iv = rng.f32_matrix(64, 64);
                let wv = rng.f32_matrix(64, 64);
                let out = exe.gemm(64, 64, 64, &iv, &wv).unwrap();
                assert_close(&out, &naive(64, 64, 64, &iv, &wv), 1e-4, "mt");
            });
        }
    });
}

#[test]
fn functional_sim_matches_pjrt_oracle() {
    // The headline cross-layer check: mapper-lowered MINISA trace executed
    // in the functional simulator == the JAX/Pallas HLO oracle on PJRT.
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::open(dir).expect("runtime");
    let cfg = ArchConfig::paper(4, 4);
    let g = minisa::workloads::Gemm::new("xcheck", "t", 64, 40, 88);
    let opts = minisa::mapper::search::MapperOptions {
        full_layout_search: false,
        ..Default::default()
    };
    let d = minisa::mapper::search::search(&cfg, &g, &opts).unwrap();
    let prog = minisa::mapper::lower_gemm(&cfg, &g, &d.choice, d.i_order, d.w_order, d.o_order);
    let mut rng = Lcg::new(12);
    let iv: Vec<i32> = (0..g.m * g.k).map(|_| rng.range(0, 9) as i32 - 4).collect();
    let wv: Vec<i32> = (0..g.k * g.n).map(|_| rng.range(0, 9) as i32 - 4).collect();
    let sim = minisa::mapper::exec::execute_program(&cfg, &g, &prog, &iv, &wv).unwrap();
    let xf: Vec<f32> = iv.iter().map(|&v| v as f32).collect();
    let wf: Vec<f32> = wv.iter().map(|&v| v as f32).collect();
    let oracle = gemm_via_tiles(&rt, g.m, g.k, g.n, &xf, &wf).unwrap();
    for (i, (s, o)) in sim.iter().zip(&oracle).enumerate() {
        assert_eq!(*s as f32, *o, "element {i}");
    }
}
