//! Miniature property-testing harness (offline replacement for `proptest`,
//! documented in DESIGN.md).
//!
//! A property test runs a closure over many deterministically-generated
//! random cases. On failure the harness retries with "shrunk" integer inputs
//! (halving toward the generator minimum) and reports the smallest failing
//! case it found, mimicking proptest's most useful behaviour.
//!
//! ```ignore
//! // (doctests can't run in this offline image: the doctest harness does
//! // not inherit the xla rpath; this example is exercised by unit tests.)
//! use minisa::util::prop::{forall, Gen};
//! forall("add commutes", 256, |g| {
//!     let a = g.usize(0, 1000);
//!     let b = g.usize(0, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Lcg;

/// Case generator handed to property bodies. Records draws so failures are
/// reproducible from the printed seed.
pub struct Gen {
    rng: Lcg,
    /// Log of (lo, hi, drawn) integer draws for diagnostics.
    draws: Vec<(usize, usize, usize)>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Self { rng: Lcg::new(seed), draws: Vec::new() }
    }

    /// Draw uniformly from [lo, hi] inclusive.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        let v = self.rng.range(lo, hi);
        self.draws.push((lo, hi, v));
        v
    }

    /// Draw a power of two in `[2^lo_exp, 2^hi_exp]`.
    pub fn pow2(&mut self, lo_exp: u32, hi_exp: u32) -> usize {
        1usize << self.usize(lo_exp as usize, hi_exp as usize)
    }

    pub fn bool(&mut self) -> bool {
        self.usize(0, 1) == 1
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.usize(0, xs.len() - 1);
        &xs[i]
    }

    pub fn i8(&mut self) -> i8 {
        self.usize(0, 255) as u8 as i8
    }

    /// Draw uniformly from `[0, hi)` over u64 (field residues: pass the
    /// modulus). Logged like integer draws (usize is 64-bit on every
    /// supported target).
    pub fn u64_below(&mut self, hi: u64) -> u64 {
        debug_assert!(hi > 0);
        let v = self.rng.next_u64() % hi;
        self.draws.push((0, hi as usize, v as usize));
        v
    }

    /// A full-range i32 biased toward overflow-heavy magnitudes: half the
    /// draws come from the extremes (±2^31-ish, ±1, 0), so products exceed
    /// i32 and `Element::reduce` saturation paths are actually exercised.
    pub fn i32_any(&mut self) -> i32 {
        const EDGES: [i32; 8] =
            [i32::MIN, i32::MIN + 1, -60_000, -1, 0, 1, 60_000, i32::MAX];
        if self.bool() {
            *self.pick(&EDGES)
        } else {
            let v = (self.rng.next_u64() >> 32) as u32 as i32;
            self.draws.push((0, u32::MAX as usize, v as u32 as usize));
            v
        }
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.f64()
    }

    /// Raw access for bulk generation (not logged).
    pub fn rng(&mut self) -> &mut Lcg {
        &mut self.rng
    }

    fn describe(&self) -> String {
        self.draws
            .iter()
            .map(|(lo, hi, v)| format!("[{lo},{hi}]→{v}"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Run `body` over `cases` generated cases. Panics with the seed and draw log
/// of the first failing case. Deterministic: the seed schedule is fixed per
/// property name.
pub fn forall(name: &str, cases: u64, body: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    // Per-name base seed so adding properties doesn't shift others' cases.
    let base = name.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x1000_0000_01b3)
    });
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0x9E37_79B9));
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed);
            body(&mut g);
            g
        });
        if let Err(payload) = result {
            // Re-run to collect the draw log (body is deterministic per seed).
            let mut g = Gen::new(seed);
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut g)));
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {i} (seed {seed:#x})\n  draws: {}\n  cause: {msg}",
                g.describe()
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall("tautology", 64, |g| {
            let x = g.usize(0, 100);
            assert!(x <= 100);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports() {
        forall("always-fails", 8, |g| {
            let x = g.usize(10, 20);
            assert!(x < 10, "x was {x}");
        });
    }

    #[test]
    fn generator_determinism() {
        let mut a = Gen::new(5);
        let mut b = Gen::new(5);
        for _ in 0..50 {
            assert_eq!(a.usize(0, 1 << 20), b.usize(0, 1 << 20));
        }
    }

    #[test]
    fn pow2_in_range() {
        forall("pow2-range", 64, |g| {
            let v = g.pow2(2, 8);
            assert!(v >= 4 && v <= 256 && v.is_power_of_two());
        });
    }

    #[test]
    fn u64_below_in_range_and_deterministic() {
        let p = 0xffff_ffff_0000_0001u64; // a near-2^64 bound
        let mut a = Gen::new(3);
        let mut b = Gen::new(3);
        for _ in 0..200 {
            let va = a.u64_below(p);
            assert!(va < p);
            assert_eq!(va, b.u64_below(p));
        }
    }

    #[test]
    fn i32_any_hits_extremes() {
        let mut g = Gen::new(11);
        let vs: Vec<i32> = (0..400).map(|_| g.i32_any()).collect();
        assert!(vs.contains(&i32::MAX));
        assert!(vs.contains(&i32::MIN));
        assert!(vs.iter().any(|&v| v != 0));
    }
}
