//! Tiny benchmarking harness for the `harness = false` bench targets
//! (offline substitute for criterion, DESIGN.md): warmup + N timed
//! iterations, reporting min/median/mean.

use std::time::Instant;

/// Timing summary in nanoseconds.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub iters: usize,
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
}

impl Timing {
    pub fn report(&self, name: &str) {
        println!(
            "{name:<44} {:>12} min {:>12} median {:>12} mean  ({} iters)",
            fmt_ns(self.min_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            self.iters
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Time `f`, returning (result of last call, timing). `iters >= 1`.
pub fn time<R>(warmup: usize, iters: usize, mut f: impl FnMut() -> R) -> (R, Timing) {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    let mut last = None;
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        last = Some(std::hint::black_box(f()));
        samples.push(t0.elapsed().as_secs_f64() * 1e9);
    }
    samples.sort_by(f64::total_cmp);
    let t = Timing {
        iters: samples.len(),
        min_ns: samples[0],
        median_ns: samples[samples.len() / 2],
        mean_ns: samples.iter().sum::<f64>() / samples.len() as f64,
    };
    (last.unwrap(), t)
}

/// Run-and-report convenience.
pub fn bench<R>(name: &str, warmup: usize, iters: usize, f: impl FnMut() -> R) -> R {
    let (r, t) = time(warmup, iters, f);
    t.report(name);
    r
}

/// Machine-readable benchmark log: collects named [`Timing`]s plus derived
/// scalar metrics and serializes them as JSON (hand-rolled — the offline
/// build has no serde). `benches/hotpath.rs` writes `BENCH_hotpath.json`
/// with it so the perf trajectory is tracked across PRs (EXPERIMENTS.md
/// §Perf).
#[derive(Debug, Default)]
pub struct BenchLog {
    timings: Vec<(String, Timing)>,
    metrics: Vec<(String, f64)>,
}

/// JSON-safe f64 formatting (NaN/inf are not valid JSON numbers). Shared
/// with the metrics snapshot exporter (`crate::obs::export`).
pub(crate) fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

pub(crate) fn json_escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            '\n' => "\\n".chars().collect(),
            c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
            c => vec![c],
        })
        .collect()
}

impl BenchLog {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a timing under `name` (later records with the same name are
    /// kept as separate entries; names are expected unique per run).
    pub fn record(&mut self, name: &str, t: Timing) {
        self.timings.push((name.to_string(), t));
    }

    /// Record a derived scalar metric (throughput, speedup, ...).
    pub fn metric(&mut self, name: &str, value: f64) {
        self.metrics.push((name.to_string(), value));
    }

    /// Time `f` like [`bench`] and record the result under `name`.
    pub fn bench<R>(
        &mut self,
        name: &str,
        warmup: usize,
        iters: usize,
        f: impl FnMut() -> R,
    ) -> (R, Timing) {
        let (r, t) = time(warmup, iters, f);
        t.report(name);
        self.record(name, t);
        (r, t)
    }

    /// Serialize as a JSON object string.
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n  \"benches\": {");
        for (i, (name, t)) in self.timings.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "\n    \"{}\": {{\"min_ns\": {}, \"median_ns\": {}, \"mean_ns\": {}, \"iters\": {}}}",
                json_escape(name),
                json_num(t.min_ns),
                json_num(t.median_ns),
                json_num(t.mean_ns),
                t.iters
            ));
        }
        s.push_str("\n  },\n  \"metrics\": {");
        for (i, (name, v)) in self.metrics.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("\n    \"{}\": {}", json_escape(name), json_num(*v)));
        }
        s.push_str("\n  }\n}\n");
        s
    }

    /// Write the JSON log to `path`.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_sane() {
        let (v, t) = time(1, 5, || {
            let mut s = 0u64;
            for i in 0..1000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert_eq!(v, (0..1000u64).map(|i| i * i).fold(0u64, u64::wrapping_add));
        assert!(t.min_ns > 0.0);
        assert!(t.min_ns <= t.median_ns);
        assert_eq!(t.iters, 5);
    }

    #[test]
    fn bench_log_emits_valid_shape() {
        let mut log = BenchLog::new();
        let (_, t) = log.bench("unit/smoke \"quoted\"", 0, 3, || 1 + 1);
        log.record("second", t);
        log.metric("speedup", 2.5);
        log.metric("bad", f64::INFINITY);
        let j = log.to_json();
        assert!(j.contains("\"benches\""));
        assert!(j.contains("\"metrics\""));
        assert!(j.contains("\\\"quoted\\\""));
        assert!(j.contains("\"speedup\": 2.5"));
        assert!(j.contains("\"bad\": null"));
        // Balanced braces — cheap structural sanity without a JSON parser.
        assert_eq!(j.matches('{').count(), j.matches('}').count());
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.0e9), "3.00 s");
    }
}
