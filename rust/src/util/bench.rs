//! Tiny benchmarking harness for the `harness = false` bench targets
//! (offline substitute for criterion, DESIGN.md): warmup + N timed
//! iterations, reporting min/median/mean.

use std::time::Instant;

/// Timing summary in nanoseconds.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub iters: usize,
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
}

impl Timing {
    pub fn report(&self, name: &str) {
        println!(
            "{name:<44} {:>12} min {:>12} median {:>12} mean  ({} iters)",
            fmt_ns(self.min_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            self.iters
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Time `f`, returning (result of last call, timing). `iters >= 1`.
pub fn time<R>(warmup: usize, iters: usize, mut f: impl FnMut() -> R) -> (R, Timing) {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    let mut last = None;
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        last = Some(std::hint::black_box(f()));
        samples.push(t0.elapsed().as_secs_f64() * 1e9);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let t = Timing {
        iters: samples.len(),
        min_ns: samples[0],
        median_ns: samples[samples.len() / 2],
        mean_ns: samples.iter().sum::<f64>() / samples.len() as f64,
    };
    (last.unwrap(), t)
}

/// Run-and-report convenience.
pub fn bench<R>(name: &str, warmup: usize, iters: usize, f: impl FnMut() -> R) -> R {
    let (r, t) = time(warmup, iters, f);
    t.report(name);
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_sane() {
        let (v, t) = time(1, 5, || {
            let mut s = 0u64;
            for i in 0..1000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert_eq!(v, (0..1000u64).map(|i| i * i).fold(0u64, u64::wrapping_add));
        assert!(t.min_ns > 0.0);
        assert!(t.min_ns <= t.median_ns);
        assert_eq!(t.iters, 5);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.0e9), "3.00 s");
    }
}
