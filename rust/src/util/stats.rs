//! Statistics helpers for the benchmark harness and report generation.

/// Geometric mean of positive values; returns `f64::NAN` on empty input.
/// Values `<= 0` are clamped to a tiny epsilon (instruction-ratio series can
/// contain zeros when MINISA traffic rounds to zero bytes).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let s: f64 = xs.iter().map(|&x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Median (average of middle two for even lengths).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Linear-interpolated percentile, `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    // total_cmp, not partial_cmp().unwrap(): a stray NaN in a latency
    // series must not panic the reporting path (NaNs sort last).
    v.sort_by(f64::total_cmp);
    if v.len() == 1 {
        return v[0];
    }
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    v[lo] * (1.0 - frac) + v[hi] * frac
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-9);
        assert!(geomean(&[]).is_nan());
    }

    #[test]
    fn geomean_single() {
        assert!((geomean(&[7.5]) - 7.5).abs() < 1e-9);
    }

    #[test]
    fn mean_median() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn percentile_interp() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
        assert_eq!(percentile(&xs, 50.0), 5.0);
    }

    #[test]
    fn percentile_tolerates_nan_input() {
        // Regression: the old partial_cmp(..).unwrap() sort panicked on any
        // NaN sample. NaNs now sort last; finite quantiles stay usable.
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(median(&xs), 2.5);
        assert!(percentile(&xs, 100.0).is_nan());
        assert!(median(&[f64::NAN]).is_nan());
    }

    #[test]
    fn stddev_basic() {
        assert_eq!(stddev(&[5.0]), 0.0);
        let s = stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s - 2.138_089_935).abs() < 1e-6);
    }
}
