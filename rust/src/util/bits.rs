//! MSB-first bit packing used by the MINISA instruction encoder.
//!
//! Instructions are variable-width bit records (Table V widths range from
//! ~38 to ~95 bits); `BitWriter`/`BitReader` pack them into byte streams the
//! way the accelerator's instruction fetch unit would see them.

/// Append-only MSB-first bit buffer.
#[derive(Debug, Default, Clone)]
pub struct BitWriter {
    bytes: Vec<u8>,
    /// Number of valid bits in the buffer.
    len_bits: usize,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Write the low `width` bits of `value`, MSB first. `width <= 64`.
    /// Panics (debug) if `value` does not fit in `width` bits.
    pub fn put(&mut self, value: u64, width: u32) {
        debug_assert!(width <= 64);
        debug_assert!(
            width == 64 || value < (1u64 << width),
            "value {value} does not fit in {width} bits"
        );
        for i in (0..width).rev() {
            let bit = (value >> i) & 1;
            let byte_idx = self.len_bits / 8;
            let bit_idx = 7 - (self.len_bits % 8);
            if byte_idx == self.bytes.len() {
                self.bytes.push(0);
            }
            if bit == 1 {
                self.bytes[byte_idx] |= 1 << bit_idx;
            }
            self.len_bits += 1;
        }
    }

    pub fn len_bits(&self) -> usize {
        self.len_bits
    }

    /// Length in whole bytes (final partial byte zero-padded).
    pub fn len_bytes(&self) -> usize {
        self.bytes.len()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.bytes
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.bytes
    }
}

/// MSB-first bit cursor over a byte slice.
#[derive(Debug, Clone)]
pub struct BitReader<'a> {
    bytes: &'a [u8],
    pos_bits: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        Self { bytes, pos_bits: 0 }
    }

    /// Read `width` bits MSB-first. Returns `None` past end of buffer.
    pub fn get(&mut self, width: u32) -> Option<u64> {
        debug_assert!(width <= 64);
        if self.pos_bits + width as usize > self.bytes.len() * 8 {
            return None;
        }
        let mut v = 0u64;
        for _ in 0..width {
            let byte_idx = self.pos_bits / 8;
            let bit_idx = 7 - (self.pos_bits % 8);
            let bit = (self.bytes[byte_idx] >> bit_idx) & 1;
            v = (v << 1) | bit as u64;
            self.pos_bits += 1;
        }
        Some(v)
    }

    pub fn pos_bits(&self) -> usize {
        self.pos_bits
    }

    pub fn remaining_bits(&self) -> usize {
        self.bytes.len() * 8 - self.pos_bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Lcg;

    #[test]
    fn roundtrip_simple() {
        let mut w = BitWriter::new();
        w.put(0b101, 3);
        w.put(0xAB, 8);
        w.put(1, 1);
        assert_eq!(w.len_bits(), 12);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get(3), Some(0b101));
        assert_eq!(r.get(8), Some(0xAB));
        assert_eq!(r.get(1), Some(1));
    }

    #[test]
    fn zero_width_is_noop() {
        let mut w = BitWriter::new();
        w.put(0, 0);
        assert_eq!(w.len_bits(), 0);
        w.put(3, 2);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get(0), Some(0));
        assert_eq!(r.get(2), Some(3));
    }

    #[test]
    fn read_past_end_returns_none() {
        let mut w = BitWriter::new();
        w.put(0xF, 4);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get(4), Some(0xF));
        // Remaining 4 zero-pad bits of the byte are readable...
        assert_eq!(r.get(4), Some(0));
        // ...but beyond the buffer is not.
        assert_eq!(r.get(1), None);
    }

    #[test]
    fn roundtrip_randomized_records() {
        // Property: any sequence of (value, width) fields round-trips.
        let mut rng = Lcg::new(0xBEEF);
        for _ in 0..200 {
            let n = rng.range(1, 24);
            let fields: Vec<(u64, u32)> = (0..n)
                .map(|_| {
                    let width = rng.range(1, 48) as u32;
                    let value = rng.next_u64() & ((1u64 << width) - 1);
                    (value, width)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(v, wd) in &fields {
                w.put(v, wd);
            }
            let total: usize = fields.iter().map(|&(_, wd)| wd as usize).sum();
            assert_eq!(w.len_bits(), total);
            let bytes = w.into_bytes();
            assert_eq!(bytes.len(), total.div_ceil(8));
            let mut r = BitReader::new(&bytes);
            for &(v, wd) in &fields {
                assert_eq!(r.get(wd), Some(v));
            }
        }
    }

    #[test]
    fn full_64bit_values() {
        let mut w = BitWriter::new();
        w.put(u64::MAX, 64);
        w.put(u64::MAX >> 1, 63);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get(64), Some(u64::MAX));
        assert_eq!(r.get(63), Some(u64::MAX >> 1));
    }
}
