//! Small self-contained utilities: deterministic RNG, statistics helpers,
//! bit-packing, and a miniature property-testing harness.
//!
//! The build environment is fully offline (only the `xla` crate's vendored
//! dependency set is available), so `rand`, `proptest` and `criterion` are
//! replaced by the deterministic equivalents in this module. DESIGN.md
//! documents the substitution.

pub mod bench;
pub mod bits;
pub mod prop;
pub mod rng;
pub mod stats;

pub use bits::{BitReader, BitWriter};
pub use prop::Gen;
pub use rng::Lcg;
pub use stats::{geomean, mean, median, percentile};

/// FNV-1a 64-bit hash — the one hashing implementation shared by the
/// `.minisa` container checksum, the arch fingerprint, and the registry's
/// content addresses (`registry::RegistryKey`). Offset basis and prime per
/// the FNV reference parameters.
pub fn fnv64(bytes: &[u8]) -> u64 {
    bytes
        .iter()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, &b| (h ^ b as u64).wrapping_mul(0x100_0000_01b3))
}

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0, "ceil_div by zero");
    a.div_ceil(b)
}

/// `ceil(log2(x))` for x >= 1; number of bits needed to represent values in
/// `[0, x)`. `clog2(1) == 0`.
#[inline]
pub fn clog2(x: usize) -> u32 {
    debug_assert!(x >= 1, "clog2 of zero");
    usize::BITS - (x - 1).leading_zeros()
}

/// Round `x` up to the next multiple of `m`.
#[inline]
pub fn round_up(x: usize, m: usize) -> usize {
    ceil_div(x, m) * m
}

/// `true` iff `x` is a power of two (and nonzero).
#[inline]
pub fn is_pow2(x: usize) -> bool {
    x != 0 && x & (x - 1) == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
        assert_eq!(ceil_div(65536, 16), 4096);
    }

    #[test]
    fn clog2_matches_definition() {
        assert_eq!(clog2(1), 0);
        assert_eq!(clog2(2), 1);
        assert_eq!(clog2(3), 2);
        assert_eq!(clog2(4), 2);
        assert_eq!(clog2(5), 3);
        assert_eq!(clog2(256), 8);
        assert_eq!(clog2(257), 9);
    }

    #[test]
    fn round_up_basic() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    /// Known FNV-1a 64-bit vectors (from the FNV reference test suite) —
    /// the checksum, fingerprint, and registry content hash all depend on
    /// these exact parameters never drifting.
    #[test]
    fn fnv64_known_vectors() {
        assert_eq!(fnv64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv64(b"foobar"), 0x8594_4171_f739_67e8);
        // Order sensitivity (not a pure XOR of bytes).
        assert_ne!(fnv64(b"ab"), fnv64(b"ba"));
    }

    #[test]
    fn is_pow2_basic() {
        assert!(is_pow2(1));
        assert!(is_pow2(2));
        assert!(is_pow2(1024));
        assert!(!is_pow2(0));
        assert!(!is_pow2(3));
        assert!(!is_pow2(255));
    }
}
