//! Deterministic 64-bit LCG + xorshift mix. Replaces `rand` in this offline
//! build; quality is more than sufficient for synthetic-operand generation
//! and property-test case generation.

/// Deterministic pseudo-random generator (splitmix64-style).
#[derive(Debug, Clone)]
pub struct Lcg {
    state: u64,
}

impl Lcg {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point.
        Self { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Next raw 64-bit value (splitmix64 output function).
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`; `n` must be nonzero.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Signed int8-ranged value, the element type used by FEATHER+ operands.
    pub fn i8val(&mut self) -> i8 {
        (self.next_u64() & 0xFF) as u8 as i8
    }

    /// Fill a matrix (row-major `rows * cols`) with small signed values.
    pub fn i8_matrix(&mut self, rows: usize, cols: usize) -> Vec<i8> {
        (0..rows * cols).map(|_| self.i8val()).collect()
    }

    /// f32 in [-1, 1).
    pub fn f32val(&mut self) -> f32 {
        (self.f64() * 2.0 - 1.0) as f32
    }

    pub fn f32_matrix(&mut self, rows: usize, cols: usize) -> Vec<f32> {
        (0..rows * cols).map(|_| self.f32val()).collect()
    }

    /// Random boolean.
    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Pick a random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Lcg::new(7);
        let mut b = Lcg::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Lcg::new(1);
        let mut b = Lcg::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_in_range() {
        let mut r = Lcg::new(42);
        for _ in 0..1000 {
            let v = r.below(17);
            assert!(v < 17);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Lcg::new(42);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let v = r.range(3, 6);
            assert!((3..=6).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 6;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Lcg::new(9);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn matrix_shapes() {
        let mut r = Lcg::new(3);
        assert_eq!(r.i8_matrix(4, 5).len(), 20);
        assert_eq!(r.f32_matrix(2, 3).len(), 6);
    }
}
