//! Command-line interface mirroring the paper artifact's entry points
//! (Appendix D): `evaluate`, `compare`, `analyze`, `search`, `trace`,
//! `bitwidth`, `area`, `workloads`, `serve`.
//!
//! Hand-rolled argument parsing (offline substitute for clap, DESIGN.md).

pub mod animate;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::arch::config::ArchConfig;
use crate::arith::{decode_words, ElemType, Element};
use crate::artifact::{Artifact, Compiler, WordMatrix};
use crate::coordinator::{compare_devices, evaluate_suite, summarize_by_config};
use crate::functional::FunctionalSim;
use crate::isa::encode::Codec;
use crate::mapper::chain::Chain;
use crate::mapper::search::{search as mapper_search, searches_run, MapperOptions};
use crate::program::Program;
use crate::report::{eng, f1, f2, pct, Table};
use crate::with_element;
use crate::workloads::{self, ntt, Gemm};

/// Parsed command line: subcommand + flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub cmd: String,
    pub flags: HashMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Self {
        let mut a = Args::default();
        let mut it = argv.iter();
        if let Some(c) = it.next() {
            a.cmd = c.clone();
        }
        // Name of the most recent bare `--flag` awaiting a value.
        let mut pending: Option<String> = None;
        for tok in it {
            if let Some(name) = tok.strip_prefix("--") {
                pending = None;
                // --flag value | --flag=value | bare --flag (boolean)
                if let Some((k, v)) = name.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else {
                    a.flags.insert(name.to_string(), "true".to_string());
                    pending = Some(name.to_string());
                }
            } else if let Some(key) = pending.take() {
                a.flags.insert(key, tok.clone());
            } else {
                a.positional.push(tok.clone());
            }
        }
        a
    }

    pub fn usize_flag(&self, k: &str, default: usize) -> usize {
        self.flags.get(k).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn str_flag(&self, k: &str, default: &str) -> String {
        self.flags.get(k).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn bool_flag(&self, k: &str) -> bool {
        self.flags.get(k).map(|v| v == "true" || v == "1").unwrap_or(false)
    }

    pub fn f64_flag(&self, k: &str, default: f64) -> f64 {
        self.flags.get(k).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

fn load_workloads(args: &Args) -> Vec<Gemm> {
    if let Some(csv) = args.flags.get("csv") {
        match workloads::from_csv(&PathBuf::from(csv)) {
            Ok(w) => return w,
            Err(e) => {
                eprintln!("warning: {e}; falling back to built-in suite");
            }
        }
    }
    if args.bool_flag("small") {
        workloads::suite_small()
    } else {
        workloads::suite50()
    }
}

fn configs(args: &Args) -> Vec<ArchConfig> {
    if let (Some(ah), Some(aw)) = (args.flags.get("ah"), args.flags.get("aw")) {
        let ah: usize = ah.parse().unwrap_or(16);
        let aw: usize = if aw == "same" { ah } else { aw.parse().unwrap_or(256) };
        vec![ArchConfig::paper(ah, aw)]
    } else if args.bool_flag("small") {
        vec![ArchConfig::paper(4, 4), ArchConfig::paper(4, 16), ArchConfig::paper(8, 8)]
    } else {
        ArchConfig::paper_sweep()
    }
}

fn opts(args: &Args) -> MapperOptions {
    MapperOptions {
        full_layout_search: !args.bool_flag("fast"),
        threads: args.usize_flag("jobs", 4),
        ..Default::default()
    }
}

fn out_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str_flag("out", "results"))
}

/// Parse `--elem {i32,f32,babybear,goldilocks,pallas}` (element backend for
/// functional execution and element-typed serving sessions).
fn elem_flag(args: &Args, default: ElemType) -> anyhow::Result<ElemType> {
    match args.flags.get("elem") {
        None => Ok(default),
        Some(s) => ElemType::parse(s).map_err(anyhow::Error::msg),
    }
}

/// `minisa evaluate` — Fig. 10/12 data: full (mapping, layout) co-search for
/// every workload × config, MINISA vs micro-instructions.
pub fn cmd_evaluate(args: &Args) -> anyhow::Result<()> {
    let ws = load_workloads(args);
    let cfgs = configs(args);
    let o = opts(args);
    let jobs = args.usize_flag("jobs", 8);
    eprintln!("evaluating {} workloads × {} configs on {jobs} jobs...", ws.len(), cfgs.len());
    let t0 = std::time::Instant::now();
    let rows = evaluate_suite(&cfgs, &ws, &o, jobs);
    eprintln!("done in {:.1}s ({} points)", t0.elapsed().as_secs_f64(), rows.len());

    let mut t = Table::new(
        "Per-workload evaluation (Fig. 10 / Fig. 12 data)",
        &[
            "config", "workload", "speedup", "instr_reduction", "micro_stall",
            "minisa_stall", "utilization", "minisa_B", "micro_B", "instr:data(micro)",
            "instr:data(minisa)",
        ],
    );
    for r in &rows {
        t.row(vec![
            r.config.clone(),
            r.workload.name.clone(),
            f2(r.speedup()),
            eng(r.instr_reduction()),
            pct(r.micro.instr_stall_fraction()),
            pct(r.decision.report.instr_stall_fraction()),
            pct(r.decision.report.utilization()),
            r.minisa_bytes.to_string(),
            r.micro_bytes.to_string(),
            f2(r.micro_instr_to_data()),
            format!("{:.2e}", r.minisa_instr_to_data()),
        ]);
    }
    let dir = out_dir(args);
    t.write_csv(&dir.join("evaluate.csv"))?;

    let mut s = Table::new(
        "Geomean by config (Fig. 10 headline)",
        &["config", "geo_speedup", "geo_instr_reduction", "micro_stall", "minisa_stall", "utilization"],
    );
    for c in summarize_by_config(&rows) {
        s.row(vec![
            c.config,
            f2(c.geo_speedup),
            eng(c.geo_instr_reduction),
            pct(c.mean_stall_micro),
            pct(c.mean_stall_minisa),
            pct(c.mean_utilization),
        ]);
    }
    s.write_csv(&dir.join("evaluate_summary.csv"))?;
    println!("{}", s.render());
    println!("wrote {}/evaluate.csv and evaluate_summary.csv", dir.display());
    Ok(())
}

/// `minisa compare` — Table I + instruction-byte comparison.
pub fn cmd_compare(args: &Args) -> anyhow::Result<()> {
    let o = opts(args);
    let g = workloads::table1_workload();
    let mut t = Table::new(
        "Table I: instruction-fetch stall, micro-instruction baseline",
        &["FEATHER+", "stall(micro)", "stall(MINISA)", "speedup", "minisa_B", "micro_B"],
    );
    for cfg in ArchConfig::table1_sweep() {
        if let Some(row) = crate::coordinator::evaluate_one(&cfg, &g, &o) {
            t.row(vec![
                cfg.name(),
                pct(row.micro.instr_stall_fraction()),
                pct(row.decision.report.instr_stall_fraction()),
                f2(row.speedup()),
                row.minisa_bytes.to_string(),
                row.micro_bytes.to_string(),
            ]);
        }
    }
    println!("{}", t.render());
    t.write_csv(&out_dir(args).join("table1.csv"))?;
    Ok(())
}

/// `minisa analyze` — Fig. 11 GPU/TPU comparison (+ Fig. 13 breakdown with
/// `--breakdown`).
pub fn cmd_analyze(args: &Args) -> anyhow::Result<()> {
    let ws = load_workloads(args);
    let o = opts(args);
    let jobs = args.usize_flag("jobs", 8);
    let rows = compare_devices(&ws, &o, jobs);
    let mut t = Table::new(
        "Fig. 11: latency (µs) — FEATHER+ 64×(16×256) mesh vs RTX5090 vs TPUv6e-8",
        &["workload", "feather_us", "gpu_us", "tpu_us", "feather_util", "vs_gpu", "vs_tpu"],
    );
    let mut vs_gpu = Vec::new();
    let mut vs_tpu = Vec::new();
    for r in &rows {
        vs_gpu.push(r.gpu_us / r.feather_us.max(1e-9));
        vs_tpu.push(r.tpu_us / r.feather_us.max(1e-9));
        t.row(vec![
            r.workload.name.clone(),
            f1(r.feather_us),
            f1(r.gpu_us),
            f1(r.tpu_us),
            pct(r.feather_utilization),
            f2(*vs_gpu.last().unwrap()),
            f2(*vs_tpu.last().unwrap()),
        ]);
    }
    println!("{}", t.render());
    println!(
        "geomean speedup: vs GPU {}x, vs TPU {}x",
        f2(crate::util::geomean(&vs_gpu)),
        f2(crate::util::geomean(&vs_tpu))
    );
    t.write_csv(&out_dir(args).join("gpu_tpu_compare.csv"))?;

    if args.bool_flag("breakdown") {
        cmd_breakdown(args)?;
    }
    Ok(())
}

/// Fig. 13: latency breakdown for representative workloads.
pub fn cmd_breakdown(args: &Args) -> anyhow::Result<()> {
    let o = opts(args);
    let reps: Vec<Gemm> = {
        let mut v = vec![workloads::table1_workload()];
        v.push(workloads::fhe_ntt().swap_remove(0));
        v.push(workloads::gpt_oss().swap_remove(0));
        v.push(workloads::zkp_ntt().swap_remove(0));
        v
    };
    let mut t = Table::new(
        "Fig. 13: cycle breakdown + utilization",
        &["config", "workload", "compute", "load_in", "load_w", "out_stream", "store",
          "fetch", "total", "utilization"],
    );
    for (ah, aw) in [(4usize, 64usize), (16, 64), (16, 256)] {
        let cfg = ArchConfig::paper(ah, aw);
        for g in &reps {
            if let Some(d) = mapper_search(&cfg, g, &o) {
                let r = &d.report;
                t.row(vec![
                    cfg.name(),
                    g.name.clone(),
                    f1(r.compute_cycles),
                    f1(r.load_in_cycles),
                    f1(r.load_w_cycles),
                    f1(r.out_stream_cycles),
                    f1(r.store_out_cycles),
                    f1(r.fetch_cycles),
                    f1(r.total_cycles),
                    pct(r.utilization()),
                ]);
            }
        }
    }
    println!("{}", t.render());
    t.write_csv(&out_dir(args).join("breakdown.csv"))?;
    Ok(())
}

/// `minisa search` — single-shape (mapping, layout) co-search.
pub fn cmd_search(args: &Args) -> anyhow::Result<()> {
    let m = args.usize_flag("m", 1024);
    let k = args.usize_flag("k", 40);
    let n = args.usize_flag("n", 88);
    let cfg = configs(args).into_iter().next().unwrap_or_else(|| ArchConfig::paper(16, 64));
    let g = Gemm::new("custom", "cli", m, k, n);
    let mut o = opts(args);
    if args.bool_flag("layout-constrained") {
        o.full_layout_search = false;
    }
    let d = mapper_search(&cfg, &g, &o)
        .ok_or_else(|| anyhow::anyhow!("no feasible mapping for {g} on {}", cfg.name()))?;
    println!("workload: {g}");
    println!("config:   {} (D={}, VN≤{})", cfg.name(), cfg.d(), cfg.ah);
    println!(
        "decision: df={:?} vn={} tile=({},{},{}) nbc={} dup={} orders(i,w,o)=({},{},{})",
        d.choice.df, d.choice.vn, d.choice.m_t, d.choice.k_t, d.choice.n_t,
        d.choice.nbc, d.choice.dup, d.i_order, d.w_order, d.o_order
    );
    println!(
        "estimate: {} cycles ({} µs @1GHz), utilization {}, instr stall {}",
        f1(d.report.total_cycles),
        f2(d.report.latency_us(&cfg)),
        pct(d.report.utilization()),
        pct(d.report.instr_stall_fraction())
    );
    Ok(())
}

/// `minisa trace` — lower a shape and dump the MINISA program.
pub fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    let m = args.usize_flag("m", 16);
    let k = args.usize_flag("k", 16);
    let n = args.usize_flag("n", 16);
    let cfg = configs(args).into_iter().next().unwrap_or_else(|| ArchConfig::paper(4, 4));
    let g = Gemm::new("custom", "cli", m, k, n);
    let o = opts(args);
    let d = mapper_search(&cfg, &g, &o)
        .ok_or_else(|| anyhow::anyhow!("no feasible mapping"))?;
    let prog = crate::mapper::lower_gemm(&cfg, &g, &d.choice, d.i_order, d.w_order, d.o_order);
    println!("{}", prog.trace.disassemble());
    println!(
        "{} instructions, {} bytes MINISA vs {} bytes micro ({}× reduction), {} invocations, {} waves",
        prog.trace.len(),
        prog.minisa_bytes(),
        prog.micro_bytes(),
        eng(prog.instr_reduction()),
        prog.invocations,
        prog.waves
    );
    if args.bool_flag("validate") {
        let elem = elem_flag(args, ElemType::I32)?;
        let mut rng = crate::util::Lcg::new(42);
        let iw = elem.sample_words(&mut rng, g.m * g.k);
        let ww = elem.sample_words(&mut rng, g.k * g.n);
        let exact = with_element!(elem, E => {
            let iv: Vec<E> = decode_words::<E>(&iw);
            let wv: Vec<E> = decode_words::<E>(&ww);
            let got = crate::mapper::exec::execute_program(&cfg, &g, &prog, &iv, &wv)
                .map_err(|e| anyhow::anyhow!("functional sim: {e}"))?;
            got == crate::arith::naive_gemm_e::<E>(&iv, &wv, g.m, g.k, g.n)
        });
        anyhow::ensure!(exact, "functional mismatch under {elem}!");
        println!("functional simulation matches naive GEMM over {elem} ✓");
    }
    Ok(())
}

/// `minisa bitwidth` — Table V.
pub fn cmd_bitwidth(_args: &Args) -> anyhow::Result<()> {
    let mut t = Table::new(
        "Table V: MINISA ISA bitwidths",
        &["config", "Set*VNLayout", "E.Mapping", "E.Streaming"],
    );
    for row in crate::isa::bitwidth::table_v() {
        t.row(vec![
            row.config,
            format!("{} bits", row.set_layout_bits),
            format!("{} bits", row.execute_mapping_bits),
            format!("{} bits", row.execute_streaming_bits),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

/// `minisa area` — Table VI.
pub fn cmd_area(_args: &Args) -> anyhow::Result<()> {
    let mut t = Table::new(
        "Table VI: area/power, FEATHER vs FEATHER+ (model vs published)",
        &["setup", "F µm²", "F+ µm²", "Δarea", "F mW", "F+ mW", "Δpower", "paper F µm²", "paper Δ"],
    );
    for row in crate::arch::area::table_vi() {
        let paper = crate::arch::area::PAPER_TABLE_VI
            .iter()
            .find(|p| p.0 == row.config);
        t.row(vec![
            row.config.clone(),
            format!("{:.0}", row.feather_um2),
            format!("{:.0}", row.featherplus_um2),
            format!("{:.2}%", row.area_increase_pct),
            f2(row.feather_mw),
            f2(row.featherplus_mw),
            format!("{:.2}%", row.power_increase_pct),
            paper.map(|p| format!("{:.0}", p.1)).unwrap_or_default(),
            paper
                .map(|p| format!("{:.2}%", (p.2 / p.1 - 1.0) * 100.0))
                .unwrap_or_default(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

/// `minisa workloads` — dump the suite as CSV.
pub fn cmd_workloads(args: &Args) -> anyhow::Result<()> {
    let ws = load_workloads(args);
    print!("{}", workloads::to_csv(&ws));
    Ok(())
}

/// Parse `--device-archs 4x4,8x16,...` — one FEATHER+ `ArchConfig` per
/// fleet device (heterogeneous fleet; docs/SERVING.md §Heterogeneous
/// fleets). Each entry is `AHxAW` over the paper's buffer geometry.
fn parse_device_archs(spec: &str) -> anyhow::Result<Vec<ArchConfig>> {
    let mut out = Vec::new();
    for tok in spec.split(',') {
        let t = tok.trim();
        let (ah, aw) = t
            .split_once('x')
            .ok_or_else(|| anyhow::anyhow!("--device-archs '{t}': expected AHxAW (e.g. 4x4)"))?;
        let ah: usize =
            ah.trim().parse().map_err(|e| anyhow::anyhow!("--device-archs '{t}': AH: {e}"))?;
        let aw: usize =
            aw.trim().parse().map_err(|e| anyhow::anyhow!("--device-archs '{t}': AW: {e}"))?;
        let cfg = ArchConfig::paper(ah, aw);
        cfg.validate().map_err(|e| anyhow::anyhow!("--device-archs '{t}': {e}"))?;
        out.push(cfg);
    }
    anyhow::ensure!(!out.is_empty(), "--device-archs: expected at least one AHxAW entry");
    Ok(out)
}

/// Parse the fleet sizing + admission flags shared by the serving commands.
/// `--trace` turns on request tracing (per-stage span histograms,
/// docs/OBSERVABILITY.md); `--trace-sample N` traces every Nth arrival.
/// `--device-archs` builds a heterogeneous fleet (overrides `--devices`).
fn server_options(args: &Args) -> anyhow::Result<crate::coordinator::serve::ServerOptions> {
    use crate::coordinator::admission::AdmissionOptions;
    let d = crate::coordinator::serve::ServerOptions::default();
    let da = AdmissionOptions::default();
    let device_archs = match args.flags.get("device-archs") {
        Some(spec) => parse_device_archs(spec)?,
        None => Vec::new(),
    };
    Ok(crate::coordinator::serve::ServerOptions {
        devices: args.usize_flag("devices", d.devices).max(1),
        device_archs,
        shard_min_rows: args.usize_flag("shard-min-rows", d.shard_min_rows).max(1),
        max_batch: args.usize_flag("max-batch", d.max_batch).max(1),
        shard_timeout_ms: args.usize_flag("shard-timeout-ms", d.shard_timeout_ms as usize) as u64,
        admission: AdmissionOptions {
            rate_per_s: args.f64_flag("rate-limit", da.rate_per_s),
            burst: args.f64_flag("burst", da.burst),
            max_in_flight: args.usize_flag("in-flight", da.max_in_flight),
        },
        tracing: crate::obs::TraceOptions {
            enabled: args.bool_flag("trace"),
            sample_every: args.usize_flag("trace-sample", 1).max(1) as u64,
        },
        // Attached by the command itself when `--registry` is given (the
        // command may also need the handle for key resolution up front).
        registry: None,
    })
}

/// `--registry <dir>` — open (creating if needed) the on-disk artifact
/// registry, with `--registry-cache N` bounding the shared program cache.
fn registry_from_args(
    args: &Args,
) -> anyhow::Result<Option<std::sync::Arc<crate::registry::Registry>>> {
    match args.flags.get("registry") {
        None => Ok(None),
        Some(dir) => {
            let backend = crate::registry::DirBackend::open(Path::new(dir))
                .map_err(|e| anyhow::anyhow!("--registry {dir}: {e}"))?;
            let cap = args
                .usize_flag("registry-cache", crate::registry::DEFAULT_CACHE_CAPACITY);
            Ok(Some(std::sync::Arc::new(crate::registry::Registry::new(
                Box::new(backend),
                cap,
            ))))
        }
    }
}

/// `--metrics-out <path>`: dump the server's full telemetry snapshot
/// (counters, span histograms, fleet stall gauges) as JSON. Shared by
/// `serve`, `serve-model` and `loadgen`; validated in CI by
/// `tools/check_metrics.py` against `tools/metrics_schema.json`.
fn write_metrics_snapshot(
    args: &Args,
    server: &crate::coordinator::serve::Server,
    wall_us: f64,
) -> anyhow::Result<()> {
    if let Some(path) = args.flags.get("metrics-out") {
        let snap = server.metrics_snapshot(wall_us);
        std::fs::write(path, snap.to_json()).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        println!(
            "metrics snapshot → {path} ({} counters, {} gauges, {} histograms)",
            snap.counters.len(),
            snap.gauges.len(),
            snap.histograms.len(),
        );
    }
    Ok(())
}

/// Parse `--qos` / `--deadline-ms` on the serving commands. The deadline is
/// relative: it is applied per request at send time, not resolved to one
/// absolute instant shared by the whole run.
fn qos_flags(args: &Args) -> anyhow::Result<(crate::coordinator::admission::QosClass, Option<u64>)> {
    use crate::coordinator::admission::QosClass;
    let qos = match args.flags.get("qos") {
        None => QosClass::Interactive,
        Some(s) => QosClass::parse(s).map_err(anyhow::Error::msg)?,
    };
    let deadline_ms = match args.flags.get("deadline-ms") {
        None => None,
        Some(v) => {
            Some(v.parse::<u64>().map_err(|e| anyhow::anyhow!("--deadline-ms '{v}': {e}"))?)
        }
    };
    Ok((qos, deadline_ms))
}

/// Tag a request with the parsed `--qos`/`--deadline-ms` pair.
fn tag_request(
    r: crate::coordinator::serve::Request,
    qos: crate::coordinator::admission::QosClass,
    deadline_ms: Option<u64>,
) -> crate::coordinator::serve::Request {
    let r = r.with_qos(qos);
    match deadline_ms {
        Some(ms) => r.with_deadline_ms(ms),
        None => r,
    }
}

/// Pick the PJRT executor when artifacts are available, else the naive one.
fn serving_executor(args: &Args) -> std::sync::Arc<dyn crate::coordinator::serve::TileExecutor> {
    use crate::coordinator::serve::NaiveExecutor;
    use std::sync::Arc;
    let dir = PathBuf::from(args.str_flag("artifacts", "artifacts"));
    match crate::runtime::PjrtExecutor::start(&dir) {
        Ok(exe) => {
            eprintln!("PJRT runtime on {}", exe.platform());
            Arc::new(exe)
        }
        Err(e) => {
            eprintln!("PJRT unavailable ({e:#}); using naive executor");
            Arc::new(NaiveExecutor)
        }
    }
}

/// `minisa run` — compile a model Program and execute it functionally,
/// end-to-end, under a chosen element backend (`--elem`), verifying the
/// result against the naive reference in the same number system.
///
/// Three ways to pick the workload (`--suite`, `--ntt`, `--dims` — see
/// [`resolve_chain`]), plus `--artifact <path>`: skip compilation entirely
/// and execute a deployable `.minisa` artifact (architecture, weights and
/// element type all come from the container; zero mapper runs, enforced).
pub fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let mut rng = crate::util::Lcg::new(args.usize_flag("seed", 42) as u64);

    // Either load a deployable artifact (zero mapper runs) or resolve a
    // chain and compile it here.
    let (program, weight_words, elem) = if let Some(path) = args.flags.get("artifact") {
        // One read, one buffer: the payload matrices borrow the container
        // bytes (`Artifact::from_shared`) instead of copying them.
        let art =
            Artifact::load_shared(Path::new(path)).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        let payload = art.payload.clone().ok_or_else(|| {
            anyhow::anyhow!("{path} carries no weights payload; recompile with weights to run it")
        })?;
        let searches_before = searches_run();
        let t0 = std::time::Instant::now();
        let program =
            Program::from_artifact(&art).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        let load_ms = t0.elapsed().as_secs_f64() * 1e3;
        anyhow::ensure!(searches_run() == searches_before, "artifact load ran the mapper");
        println!(
            "loaded {} layer(s) for {} from {path} in {load_ms:.1} ms: {} B encoded trace / \
             {} insts decoded, byte fidelity verified, zero mapper runs ✓",
            program.layer_count(),
            program.cfg.name(),
            art.trace_bytes.len(),
            art.inst_count,
        );
        (program, payload.weights, payload.elem)
    } else {
        let cfg = configs(args).into_iter().next().unwrap_or_else(|| ArchConfig::paper(4, 4));
        let o = opts(args);
        let (chain, weight_words, elem) = resolve_chain(args, &mut rng)?;
        let t0 = std::time::Instant::now();
        let program = Program::compile(&cfg, &chain, &o)
            .ok_or_else(|| anyhow::anyhow!("no feasible mapping for chain on {}", cfg.name()))?;
        let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
        println!(
            "compiled {} layer(s) on {} in {:.1} ms: {} wave plans, fused trace {} B \
             ({} SetIVNLayout elided)",
            program.layer_count(),
            cfg.name(),
            compile_ms,
            program.plan_count(),
            program.fused_bytes,
            program.elided,
        );
        let weights: Vec<WordMatrix> = weight_words.into_iter().map(WordMatrix::from).collect();
        (program, weights, elem)
    };
    let cfg = program.cfg.clone();

    let input_words = elem.sample_words(&mut rng, program.rows() * program.in_features());
    let t1 = std::time::Instant::now();
    let (exact, plan_compiles, checksum) = with_element!(elem, E => {
        let w: Vec<Vec<E>> = weight_words.iter().map(|m| m.decode::<E>()).collect();
        let input: Vec<E> = decode_words::<E>(&input_words);
        let mut sim: FunctionalSim<E> = FunctionalSim::new(&cfg);
        let got = program
            .execute(&mut sim, &input, &w)
            .map_err(|e| anyhow::anyhow!("functional execution: {e}"))?;
        let expect = program.reference(&input, &w);
        let checksum = got
            .iter()
            .map(|&v| E::reduce(v).encode())
            .fold(0u64, |h, x| h.rotate_left(7) ^ x);
        (got == expect, sim.plan_compiles, checksum)
    });
    let exec_ms = t1.elapsed().as_secs_f64() * 1e3;
    println!(
        "executed {}×{}→{} over {} in {:.1} ms ({} runtime plan compiles), checksum {:016x}",
        program.rows(),
        program.in_features(),
        program.out_features(),
        elem,
        exec_ms,
        plan_compiles,
        checksum,
    );
    anyhow::ensure!(exact, "functional output does NOT match the naive {elem} reference");
    anyhow::ensure!(plan_compiles == 0, "expected zero runtime plan compiles (compile-once)");
    println!("functional execution matches the naive {elem} reference exactly ✓");

    // `--devices N`: re-execute the same activation tile-parallel across a
    // simulated fleet and verify the stitched output is bit-identical to
    // the single-device run (the conformance invariant, live on the CLI).
    let devices = args.usize_flag("devices", 1);
    if devices > 1 {
        use crate::coordinator::fleet::{Fleet, FleetOptions};
        use crate::coordinator::serve::{execute_program_words, NaiveExecutor, WordWeights};
        let shard_min_rows = args.usize_flag("shard-min-rows", 1).max(1);
        let fleet = Fleet::new(
            &cfg,
            std::sync::Arc::new(NaiveExecutor),
            FleetOptions { devices, shard_min_rows, ..Default::default() },
        );
        let ww = WordWeights::from_matrices(&weight_words, elem);
        let rows = program.rows();
        let t2 = std::time::Instant::now();
        let sharded = fleet
            .run_program_words(None, &program, rows, &input_words, &ww)
            .map_err(|e| anyhow::anyhow!("fleet execution: {e}"))?;
        let wall_us = t2.elapsed().as_secs_f64() * 1e6;
        let single = execute_program_words(&program, rows, &input_words, &ww)
            .map_err(|e| anyhow::anyhow!("single-device reference: {e}"))?;
        anyhow::ensure!(
            sharded == single,
            "fleet-sharded output diverges from single-device execution"
        );
        let report = fleet.report(wall_us);
        anyhow::ensure!(
            report.plan_compiles() == 0,
            "fleet execution compiled plans at runtime (expected zero)"
        );
        println!("{}", report.render());
        println!(
            "fleet of {devices} devices matches single-device execution bit-exactly ✓"
        );
    }
    Ok(())
}

/// Resolve the (chain, canonical-word weights, element type) triple the
/// `run` and `compile` commands share:
///
/// * `--suite <name> [--scale N]` — an NTT entry of the 50-workload suite,
///   scaled to a CI-sized transform; weights are the real twiddle matrix.
/// * `--ntt N` — a bare size-N NTT over the chosen (or default ZKP) field.
/// * `--dims k0,k1,... --m M` — an MLP chain with random operands.
fn resolve_chain(
    args: &Args,
    rng: &mut crate::util::Lcg,
) -> anyhow::Result<(Chain, Vec<Vec<u64>>, ElemType)> {
    if let Some(name) = args.flags.get("suite") {
        let g = workloads::suite50()
            .into_iter()
            .find(|g| &g.name == name)
            .ok_or_else(|| anyhow::anyhow!("no suite entry named '{name}' (see `workloads`)"))?;
        let scale = args.usize_flag("scale", 64);
        let g = if ntt::ntt_size(&g).is_some() { ntt::scaled(&g, scale) } else { g };
        let n = ntt::ntt_size(&g).ok_or_else(|| {
            anyhow::anyhow!(
                "suite entry '{name}' is not an NTT kernel; use `--dims`/`--m` to execute \
                 arbitrary chains"
            )
        })?;
        let elem = elem_flag(args, ntt::default_elem(&g.category))?;
        let tw = ntt::twiddle_words(elem, n).map_err(anyhow::Error::msg)?;
        println!(
            "suite entry {} scaled to M={} K=N={} over {} (p = {})",
            g.name,
            g.m,
            n,
            elem,
            elem.modulus().unwrap_or(0)
        );
        Ok((Chain { layers: vec![g] }, vec![tw], elem))
    } else if let Some(nspec) = args.flags.get("ntt") {
        let n: usize = nspec.parse().map_err(|e| anyhow::anyhow!("--ntt '{nspec}': {e}"))?;
        let m = args.usize_flag("m", (n / 16).max(1));
        let elem = elem_flag(args, ElemType::Goldilocks)?;
        let tw = ntt::twiddle_words(elem, n).map_err(anyhow::Error::msg)?;
        let g = Gemm::new(&format!("ntt_{n}"), "ZKP-NTT", m, n, n);
        Ok((Chain { layers: vec![g] }, vec![tw], elem))
    } else {
        let spec = args.str_flag("dims", "16,24,16");
        let parsed: Result<Vec<usize>, _> = spec.split(',').map(|t| t.trim().parse()).collect();
        let dims = parsed.map_err(|e| anyhow::anyhow!("--dims '{spec}': {e}"))?;
        anyhow::ensure!(dims.len() >= 2, "--dims needs at least two widths");
        let m = args.usize_flag("m", 8);
        let chain = Chain::mlp("run", m, &dims);
        let elem = elem_flag(args, ElemType::I32)?;
        let ws: Vec<Vec<u64>> =
            chain.layers.iter().map(|g| elem.sample_words(&mut rng, g.k * g.n)).collect();
        Ok((chain, ws, elem))
    }
}

/// `minisa compile` — the [`Compiler`] front-end on the command line:
/// resolve a chain (same `--suite`/`--ntt`/`--dims` surface as `run`),
/// run the chain-aware mapper exactly once, and write the deployable
/// `.minisa` artifact whose payload is the encoded instruction stream.
pub fn cmd_compile(args: &Args) -> anyhow::Result<()> {
    let cfg = configs(args).into_iter().next().unwrap_or_else(|| ArchConfig::paper(4, 4));
    let mut rng = crate::util::Lcg::new(args.usize_flag("seed", 42) as u64);
    let (chain, weight_words, elem) = resolve_chain(args, &mut rng)?;
    let out = PathBuf::from(args.str_flag("out", "model.minisa"));
    let t0 = std::time::Instant::now();
    let artifact = Compiler::new(&cfg)
        .options(opts(args))
        .elem(elem)
        .weights(weight_words)
        .compile(&chain)
        .map_err(|e| anyhow::anyhow!("compile: {e}"))?;
    let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
    let check = artifact.verify().map_err(|e| anyhow::anyhow!("verify: {e}"))?;
    // Serialize once: the same buffer is written and measured.
    let bytes = artifact.to_bytes();
    std::fs::write(&out, &bytes).map_err(|e| anyhow::anyhow!("{}: {e}", out.display()))?;
    let container = bytes.len();
    let (cfg_only, compute, memory, act) = check.classes;
    println!(
        "compiled {} layer(s) on {} in {compile_ms:.1} ms → {}",
        chain.layers.len(),
        cfg.name(),
        out.display()
    );
    println!(
        "  container {container} B (v{}, checksummed, fingerprint {:016x}): encoded trace \
         {} B / {} insts (cfg {cfg_only} / exec {compute} / mem {memory} / act {act}), \
         {} SetIVNLayout elided; weights {} matrices over {elem}",
        crate::artifact::VERSION,
        artifact.fingerprint(),
        check.trace_bytes,
        check.insts,
        artifact.decision.elided,
        chain.layers.len(),
    );
    println!(
        "  stream decodes and re-encodes byte-identically ✓ (trace fnv {:016x})",
        check.trace_fnv
    );
    Ok(())
}

/// `minisa inspect <artifact>` — header metadata, per-class instruction
/// counts and encoded bytes, `--disasm` for the full disassembly.
pub fn cmd_inspect(args: &Args) -> anyhow::Result<()> {
    let (art, path) = if let Some(spec) = args.flags.get("from-registry") {
        // `--from-registry <key>` — fetch (and fully re-verify: content
        // hash against key, delta resolution, composed checksum) straight
        // from the registry instead of a file.
        let reg = registry_from_args(args)?.ok_or_else(|| {
            anyhow::anyhow!("--from-registry requires --registry <dir>")
        })?;
        let key = reg.find(spec, None).map_err(|e| anyhow::anyhow!("{spec}: {e}"))?;
        let art = reg.get(key).map_err(|e| anyhow::anyhow!("{key}: {e}"))?;
        (art, format!("registry:{key}"))
    } else {
        let path = args
            .positional
            .first()
            .cloned()
            .or_else(|| args.flags.get("artifact").cloned())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "usage: minisa inspect <file.minisa> [--disasm] | \
                     --from-registry <key> --registry <dir>"
                )
            })?;
        let art =
            Artifact::load(Path::new(&path)).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        (art, path)
    };
    let check = art.verify().map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    println!(
        "{path}: MINISA artifact v{} for {} (fingerprint {:016x}), {} B container",
        crate::artifact::VERSION,
        art.cfg.name(),
        art.fingerprint(),
        art.to_bytes().len(),
    );
    println!("  checksum ok; stream decodes and re-encodes byte-identically ✓");
    for (g, d) in art.chain.layers.iter().zip(&art.decision.per_layer) {
        println!(
            "  layer {:<16} M={:<6} K={:<6} N={:<6} df={:?} vn={} tile=({},{},{}) nbc={} dup={} \
             orders=({},{},{}) {:.0} cycles",
            g.name,
            g.m,
            g.k,
            g.n,
            d.choice.df,
            d.choice.vn,
            d.choice.m_t,
            d.choice.k_t,
            d.choice.n_t,
            d.choice.nbc,
            d.choice.dup,
            d.i_order,
            d.w_order,
            d.o_order,
            d.report.total_cycles,
        );
    }
    println!(
        "  fused trace: {} insts, {} B encoded ({} B standalone, {} SetIVNLayout elided \
         §IV-G2), modeled {:.0} cycles",
        check.insts,
        check.trace_bytes,
        art.decision.standalone_bytes,
        art.decision.elided,
        art.decision.total_cycles,
    );
    // Per-class accounting: counts and bits share one classification
    // (`Trace::class_counts` / `Trace::class_bits`).
    let trace = art.decode_trace().map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    let (b0, b1, b2, b3) = trace.class_bits(&Codec::new(&art.cfg));
    let (c0, c1, c2, c3) = check.classes;
    println!("  class           insts       bits      bytes");
    for (label, count, b) in [
        ("config-only", c0, b0),
        ("compute", c1, b1),
        ("memory", c2, b2),
        ("activation", c3, b3),
    ] {
        println!("  {label:<14} {count:>6} {b:>10} {:>10.1}", b as f64 / 8.0);
    }
    match &art.payload {
        Some(p) => {
            let words: usize = p.weights.iter().map(WordMatrix::len).sum();
            println!("  weights: {} matrices over {} ({words} words)", p.weights.len(), p.elem);
        }
        None => println!("  weights: none (serving this artifact requires a payload)"),
    }
    if args.bool_flag("disasm") {
        println!("\n{}", trace.disassemble());
    }
    Ok(())
}

/// `minisa serve` — run the serving loop on ad-hoc single-GEMM requests.
/// With `--elem` other than f32, the GEMM is registered as a single-layer
/// element-typed program session and served as word requests (ad-hoc f32
/// payloads cannot carry field residues).
pub fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    use crate::coordinator::serve::{spawn_with_options, Request};
    use std::sync::Arc;

    let cfg = configs(args).into_iter().next().unwrap_or_else(|| ArchConfig::paper(16, 64));
    let requests = args.usize_flag("requests", 64);
    let elem = elem_flag(args, ElemType::F32)?;
    let (qos, deadline_ms) = qos_flags(args)?;
    let sopts = server_options(args)?;
    let executor = serving_executor(args);
    let backend = executor.name().to_string();
    let (tx, rx, h, server) = spawn_with_options(&cfg, executor, sopts);
    let mut rng = crate::util::Lcg::new(7);
    let wall = std::time::Instant::now();
    if elem == ElemType::F32 {
        let weight = Arc::new(rng.f32_matrix(64, 64)); // shared → batches by identity
        for id in 0..requests as u64 {
            let r = Request::gemm(id, 64, 64, 64, rng.f32_matrix(64, 64), Arc::clone(&weight));
            tx.send(tag_request(r, qos, deadline_ms))?;
        }
    } else {
        let g = Gemm::new("serve_gemm", "cli", 64, 64, 64);
        let chain = Chain { layers: vec![g] };
        let w = elem.sample_words(&mut rng, 64 * 64);
        let pid = server.register_chain_elem(&chain, vec![w], elem)?;
        eprintln!("single-GEMM session {pid:?} over {elem}");
        for id in 0..requests as u64 {
            let r = Request::for_program_words(id, pid, 64, elem.sample_words(&mut rng, 64 * 64));
            tx.send(tag_request(r, qos, deadline_ms))?;
        }
    }
    let mut served = 0;
    let mut dropped = 0; // shed / deadline_exceeded: policy, not failure
    let mut failed = 0;
    let mut lat = Vec::new();
    while served + dropped + failed < requests {
        use crate::coordinator::admission::ErrorCode;
        let r = rx.recv()?;
        match (r.code, r.error) {
            (Some(ErrorCode::Shed | ErrorCode::DeadlineExceeded), Some(e)) => {
                eprintln!("request {} dropped: {e}", r.id);
                dropped += 1;
            }
            (_, Some(e)) => {
                eprintln!("request {} failed: {e}", r.id);
                failed += 1;
            }
            _ => {
                lat.push(r.service_us);
                served += 1;
            }
        }
    }
    drop(tx);
    let stats = h.join().map_err(|_| anyhow::anyhow!("server panicked"))?;
    anyhow::ensure!(failed == 0, "{failed}/{requests} requests failed");
    if dropped > 0 {
        println!("{dropped}/{requests} requests shed or expired (typed, by policy)");
    }
    let wall_us = wall.elapsed().as_secs_f64() * 1e6;
    println!(
        "served {} requests on '{}' in {:.1} ms: p50 {:.1} µs, p99 {:.1} µs, {:.0} req/s, {} batches (max {})",
        stats.served,
        backend,
        wall_us / 1e3,
        crate::util::percentile(&lat, 50.0),
        crate::util::percentile(&lat, 99.0),
        stats.throughput_per_s(wall_us),
        stats.batches,
        stats.max_batch,
    );
    if server.fleet().device_count() > 1 {
        println!("{}", server.fleet_report(wall_us).render());
    }
    write_metrics_snapshot(args, &server, wall_us)?;
    Ok(())
}

/// `minisa serve-model` — the compile-once/serve-many path: register a
/// model session, then stream activation-only requests at it.
///
/// Two session sources:
/// * `--artifact <path>` — **load** a deployable `.minisa` artifact (the
///   server adopts the artifact's architecture; element type and weights
///   come from its payload). Hard-fails if registration compiles anything
///   or runs the mapper: this is the production load path.
/// * `--dims k0,k1,...` / `--gpt` + `--m` + `--elem` — compile-on-register
///   as before.
pub fn cmd_serve_model(args: &Args) -> anyhow::Result<()> {
    use crate::coordinator::serve::{spawn_with_options, ArtifactSource, Request};

    let requests = args.usize_flag("requests", 32);
    let registry = registry_from_args(args)?;
    let artifact = match args.flags.get("artifact") {
        Some(p) => Some(Artifact::load(Path::new(p)).map_err(|e| anyhow::anyhow!("{p}: {e}"))?),
        None => None,
    };
    let model_key = args.flags.get("model-key").cloned();
    anyhow::ensure!(
        artifact.is_none() || model_key.is_none(),
        "--artifact and --model-key are mutually exclusive"
    );
    let cfg = match (&artifact, &model_key) {
        // The container pins the architecture; --ah/--aw are ignored.
        (Some(a), _) => a.cfg.clone(),
        (None, Some(spec)) => {
            // Resolve the key up front to adopt the stored artifact's
            // architecture (the session itself registers through the
            // server's shared program cache below).
            let reg = registry.as_ref().ok_or_else(|| {
                anyhow::anyhow!("--model-key requires --registry <dir>")
            })?;
            let key = reg.find(spec, None).map_err(|e| anyhow::anyhow!("{spec}: {e}"))?;
            reg.get(key).map_err(|e| anyhow::anyhow!("{key}: {e}"))?.cfg
        }
        _ => configs(args).into_iter().next().unwrap_or_else(|| ArchConfig::paper(16, 64)),
    };
    let from_artifact = artifact.is_some() || model_key.is_some();

    let mut sopts = server_options(args)?;
    sopts.registry = registry.clone();
    let executor = serving_executor(args);
    let backend = executor.name().to_string();
    let (tx, rx, h, server) = spawn_with_options(&cfg, executor, sopts);
    let mut rng = crate::util::Lcg::new(23);
    let (pid, elem) = if let Some(spec) = model_key {
        let searches_before = searches_run();
        let pid = server.register(ArtifactSource::Registry { key: spec.clone() })?;
        anyhow::ensure!(
            searches_run() == searches_before,
            "registry registration ran the mapper (expected zero mapper runs)"
        );
        let elem = server.session_elem(pid).expect("just registered");
        println!("session {pid:?} loaded from registry key '{spec}'");
        (pid, elem)
    } else if let Some(art) = artifact {
        let elem = art.payload.as_ref().map(|p| p.elem).unwrap_or(ElemType::F32);
        let searches_before = searches_run();
        let pid = server.register(ArtifactSource::Artifact(Box::new(art)))?;
        anyhow::ensure!(
            searches_run() == searches_before,
            "artifact registration ran the mapper (expected zero mapper runs)"
        );
        (pid, elem)
    } else {
        let m = args.usize_flag("m", 16);
        let dims: Vec<usize> = if args.bool_flag("gpt") {
            workloads::gpt_oss_mlp_dims()
        } else {
            let spec = args.str_flag("dims", "256,512,256");
            let parsed: Result<Vec<usize>, _> =
                spec.split(',').map(|t| t.trim().parse()).collect();
            parsed.map_err(|e| anyhow::anyhow!("--dims '{spec}': {e}"))?
        };
        anyhow::ensure!(dims.len() >= 2, "--dims needs at least two widths");
        let chain = Chain::mlp("serve_model", m, &dims);
        let elem = elem_flag(args, ElemType::F32)?;
        let pid = if elem == ElemType::F32 {
            let weights: Vec<Vec<f32>> =
                chain.layers.iter().map(|g| rng.f32_matrix(g.k, g.n)).collect();
            server.register_chain(&chain, weights)?
        } else {
            let weights: Vec<Vec<u64>> =
                chain.layers.iter().map(|g| elem.sample_words(&mut rng, g.k * g.n)).collect();
            server.register_chain_elem(&chain, weights, elem)?
        };
        (pid, elem)
    };
    let prog = server.program(pid).expect("just registered");
    let m = args.usize_flag("m", if from_artifact { prog.rows() } else { 16 });
    let kf = prog.in_features();
    println!(
        "program {:?} over {} on {}: {} layers, modeled {:.0} cycles/pass, fused trace {} B vs \
         {} B standalone ({} SetIVNLayout elided, §IV-G2), {} wave plans {}",
        pid,
        elem,
        cfg.name(),
        prog.layer_count(),
        prog.total_cycles,
        prog.fused_bytes,
        prog.standalone_bytes,
        prog.elided,
        prog.plan_count(),
        if from_artifact { "recompiled from the loaded stream" } else { "precompiled" },
    );

    // `--swap-to <path|key> [--swap-after N]` — hot-swap the session to a
    // new artifact version after N requests have been admitted, while the
    // earlier ones are still queued or in flight (the std-only stand-in for
    // a SIGHUP-style reload trigger). Zero downtime: the server drains
    // in-flight work against the old version and atomically switches.
    let swap_to = args.flags.get("swap-to").cloned();
    let swap_after = args.usize_flag("swap-after", requests / 2);
    let (qos, deadline_ms) = qos_flags(args)?;
    let wall = std::time::Instant::now();
    for id in 0..requests as u64 {
        if let Some(spec) = &swap_to {
            if id as usize == swap_after {
                // A spec that names a file swaps from disk; anything else
                // resolves through the attached registry (deltas included).
                let src = if Path::new(spec).is_file() {
                    ArtifactSource::Path(PathBuf::from(spec))
                } else {
                    ArtifactSource::Registry { key: spec.clone() }
                };
                server
                    .swap(pid, src)
                    .map_err(|e| anyhow::anyhow!("--swap-to {spec}: {e}"))?;
                println!(
                    "hot-swapped {pid:?} → '{spec}' after {swap_after} requests (old version \
                     drains in flight; zero downtime)"
                );
            }
        }
        let r = if elem == ElemType::F32 {
            Request::for_program(id, pid, m, rng.f32_matrix(m, kf))
        } else {
            Request::for_program_words(id, pid, m, elem.sample_words(&mut rng, m * kf))
        };
        tx.send(tag_request(r, qos, deadline_ms))?;
    }
    let mut lat = Vec::new();
    let mut dropped = 0; // shed / deadline_exceeded: policy, not failure
    for _ in 0..requests {
        use crate::coordinator::admission::ErrorCode;
        let r = rx.recv()?;
        match (r.code, r.error) {
            (Some(ErrorCode::Shed | ErrorCode::DeadlineExceeded), Some(e)) => {
                eprintln!("request {} dropped: {e}", r.id);
                dropped += 1;
            }
            (_, Some(e)) => anyhow::bail!("request {}: {e}", r.id),
            _ => lat.push(r.service_us),
        }
    }
    if dropped > 0 {
        println!("{dropped}/{requests} requests shed or expired (typed, by policy)");
    }
    drop(tx);
    let stats = h.join().map_err(|_| anyhow::anyhow!("server panicked"))?;
    let wall_us = wall.elapsed().as_secs_f64() * 1e6;
    println!(
        "served {} program requests on '{}' in {:.1} ms: p50 {:.1} µs, p99 {:.1} µs, \
         {:.0} req/s, {} batches (max {}), {} chain compile(s), {} artifact load(s)",
        stats.program_served,
        backend,
        wall_us / 1e3,
        crate::util::percentile(&lat, 50.0),
        crate::util::percentile(&lat, 99.0),
        stats.throughput_per_s(wall_us),
        stats.batches,
        stats.max_batch,
        stats.program_compiles,
        stats.artifact_loads,
    );
    if from_artifact {
        // The production invariant, enforced (the CI cross-process smoke
        // step serves a file compiled by another process through here).
        // Swap replacements load too — from a file or the registry, never
        // the mapper — so expected loads are 1 + completed swaps.
        anyhow::ensure!(
            stats.program_compiles == 0,
            "artifact serving compiled a program (expected zero)"
        );
        let expect_loads = 1 + stats.swaps;
        anyhow::ensure!(
            stats.artifact_loads == expect_loads,
            "expected exactly {expect_loads} artifact load(s), saw {}",
            stats.artifact_loads
        );
        println!(
            "artifact session: {expect_loads} load(s), 0 program compiles, 0 mapper runs ✓"
        );
    }
    if stats.swaps + stats.swap_failed > 0 {
        println!(
            "hot swap: {} completed, {} failed; registry cache: {} hit(s) / {} miss(es), {} \
             eviction(s)",
            stats.swaps,
            stats.swap_failed,
            stats.registry_hits,
            stats.registry_misses,
            stats.registry_evictions,
        );
    }
    if server.fleet().device_count() > 1 {
        let report = server.fleet_report(wall_us);
        anyhow::ensure!(
            report.plan_compiles() == 0,
            "fleet serving compiled plans at runtime (expected zero)"
        );
        println!("{}", report.render());
    }
    write_metrics_snapshot(args, &server, wall_us)?;
    Ok(())
}

/// `minisa registry <list|put|gc|verify|diff>` — operate on a
/// content-addressed artifact registry (docs/REGISTRY.md). Every verb takes
/// `--registry <dir>`; keys anywhere a `<spec>` is accepted may be the
/// exact `<content>-<arch>` key, a content-hash prefix (≥ 4 hex digits), or
/// a model name.
pub fn cmd_registry(args: &Args) -> anyhow::Result<()> {
    use crate::registry::RegistryKey;
    let usage = "usage: minisa registry <list|put|gc|verify|diff> --registry <dir> [flags]";
    let reg = registry_from_args(args)?.ok_or_else(|| anyhow::anyhow!("{usage}"))?;
    let verb = args.positional.first().map(String::as_str).unwrap_or("list");
    let find = |spec: &str| -> anyhow::Result<RegistryKey> {
        reg.find(spec, None).map_err(|e| anyhow::anyhow!("{spec}: {e}"))
    };
    match verb {
        "list" => {
            let entries = reg.list().map_err(|e| anyhow::anyhow!("{e}"))?;
            let mut t = Table::new(
                &format!("registry {}", args.str_flag("registry", "?")),
                &["key", "kind", "model", "bytes", "base"],
            );
            let n = entries.len();
            for e in entries {
                t.row(vec![
                    e.key.to_string(),
                    e.kind.to_string(),
                    e.model,
                    e.blob_bytes.to_string(),
                    e.base.map(|b| format!("{b:016x}")).unwrap_or_else(|| "-".to_string()),
                ]);
            }
            println!("{}", t.render());
            println!("{n} entr{} ({:?})", if n == 1 { "y" } else { "ies" }, reg.cache_stats());
        }
        "put" => {
            if let Some(p) = args.flags.get("artifact") {
                // Full artifact from disk: content-addressed, idempotent.
                let art =
                    Artifact::load(Path::new(p)).map_err(|e| anyhow::anyhow!("{p}: {e}"))?;
                let key = reg.put(&art).map_err(|e| anyhow::anyhow!("{p}: {e}"))?;
                println!("{p} → {key} (full, {} B container)", art.to_bytes().len());
            } else if let Some(spec) = args.flags.get("delta-of") {
                // Weights-only delta against a stored base: new weights are
                // synthesized from `--seed` (the repo's synthetic-weights
                // discipline — a fine-tune stand-in), stored as the small
                // delta container, keyed by the *composed* content hash.
                let base = find(spec)?;
                let base_art = reg.get(base).map_err(|e| anyhow::anyhow!("{base}: {e}"))?;
                let elem = base_art
                    .payload
                    .as_ref()
                    .map(|p| p.elem)
                    .ok_or_else(|| anyhow::anyhow!("{base}: base has no weights payload"))?;
                let mut rng = crate::util::Lcg::new(args.usize_flag("seed", 424242) as u64);
                let weights: Vec<Vec<u64>> = base_art
                    .chain
                    .layers
                    .iter()
                    .map(|g| elem.sample_words(&mut rng, g.k * g.n))
                    .collect();
                let key = reg
                    .put_delta(base, elem, weights)
                    .map_err(|e| anyhow::anyhow!("{base}: {e}"))?;
                println!("delta of {base} → {key} (weights-only, base trace reused)");
            } else {
                anyhow::bail!(
                    "registry put: need --artifact <file.minisa> or --delta-of <spec> [--seed N]"
                );
            }
        }
        "gc" => {
            let mut pins = Vec::new();
            if let Some(spec) = args.flags.get("pin") {
                for s in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
                    pins.push(find(s)?);
                }
            }
            let report = reg.gc(&pins).map_err(|e| anyhow::anyhow!("gc: {e}"))?;
            for k in &report.deleted {
                println!("deleted {k}");
            }
            println!(
                "gc: kept {} (pinned closure{}), deleted {}",
                report.kept.len(),
                if pins.is_empty() { " = everything resolvable" } else { "" },
                report.deleted.len(),
            );
        }
        "verify" => {
            let results = reg.verify_all().map_err(|e| anyhow::anyhow!("verify: {e}"))?;
            let mut bad = 0;
            for (key, r) in &results {
                match r {
                    Ok(check) => println!(
                        "{key} ok: {} insts, {} B trace, round-trips byte-identically",
                        check.insts, check.trace_bytes
                    ),
                    Err(e) => {
                        bad += 1;
                        println!("{key} FAILED: {e}");
                    }
                }
            }
            println!("verified {} entr{}, {bad} failed", results.len(),
                if results.len() == 1 { "y" } else { "ies" });
            anyhow::ensure!(bad == 0, "{bad} registry entr{} failed verification",
                if bad == 1 { "y" } else { "ies" });
        }
        "diff" => {
            let (a, b) = match &args.positional[1..] {
                [a, b] => (find(a)?, find(b)?),
                _ => anyhow::bail!("usage: minisa registry diff <specA> <specB> --registry <dir>"),
            };
            let (aa, ab) = (
                reg.get(a).map_err(|e| anyhow::anyhow!("{a}: {e}"))?,
                reg.get(b).map_err(|e| anyhow::anyhow!("{b}: {e}"))?,
            );
            let lines = crate::registry::diff(&aa, &ab);
            if lines.is_empty() {
                println!("{a} and {b}: identical structure (weights not value-compared)");
            } else {
                println!("{a} vs {b}:");
                for l in &lines {
                    println!("  {l}");
                }
            }
        }
        other => anyhow::bail!("unknown registry verb '{other}'\n{usage}"),
    }
    Ok(())
}

/// `minisa loadgen` — open-loop Poisson load generator for the serving
/// front door (EXPERIMENTS.md §Serving robustness).
///
/// Drives a mixed-QoS, mixed-element workload at an offered rate that is
/// independent of service latency (open loop: a slow server does not slow
/// the arrival process), across three model sessions (f32, saturating i32,
/// Goldilocks) on a simulated device fleet. Emits `BENCH_serving.json`
/// (throughput, per-class p50/p99/p999 latency, shed/expired/retried
/// counts) and enforces the robustness invariants: every request answered
/// exactly once, and — unless `--overload` — zero Interactive sheds and
/// zero execution errors.
///
/// `--faults scripted` arms a deterministic [`FaultPlan`] (transient
/// dropout of device 1 plus slow shards); requires the `faults` feature
/// outside of test builds.
pub fn cmd_loadgen(args: &Args) -> anyhow::Result<()> {
    use crate::coordinator::admission::{ErrorCode, QosClass};
    use crate::coordinator::serve::{spawn_with_options, ArtifactSource, NaiveExecutor, Request};
    use std::collections::{HashMap as Map, HashSet};
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    let cfg = match (args.flags.get("ah"), args.flags.get("aw")) {
        (Some(_), Some(_)) => configs(args).into_iter().next().unwrap(),
        _ => ArchConfig::paper(4, 4),
    };
    let duration = Duration::from_millis(args.usize_flag("duration-ms", 1000) as u64);
    let rate = args.f64_flag("rate", 200.0).max(1.0); // offered load, req/s
    let overload = args.bool_flag("overload");
    let interactive_deadline_ms = args.usize_flag("deadline-ms", 200) as u64;
    let mut sopts = server_options(args)?;
    // Loadgen always traces (at the `--trace-sample` rate, default every
    // request) so its metrics snapshot carries the per-stage latency
    // histograms. Traced serving is bit-identical to untraced serving
    // (tests/telemetry.rs), so this does not perturb the measurement.
    sopts.tracing.enabled = true;
    let device_archs = sopts.device_archs.clone();
    let seed = args.usize_flag("seed", 42) as u64;
    let mut rng = crate::util::Lcg::new(seed);

    // Loadgen measures the front door, not the backend: always the naive
    // executor, so runs are deterministic and PJRT noise stays out.
    let (tx, rx, h, server) = spawn_with_options(&cfg, Arc::new(NaiveExecutor), sopts);

    // Three sessions across distinct element backends; the affinity hash
    // of each session is a distinct rate-limiter key.
    let m = 4usize;
    let dims = [8usize, 12, 8];
    let chain = Chain::mlp("loadgen", m, &dims);
    let kf = dims[0];
    let w_f32: Vec<Vec<f32>> = chain.layers.iter().map(|g| rng.f32_matrix(g.k, g.n)).collect();
    let pid_f32 = server.register_chain(&chain, w_f32)?;
    let mut word_session = |elem: ElemType, rng: &mut crate::util::Lcg| {
        let ws: Vec<Vec<u64>> =
            chain.layers.iter().map(|g| elem.sample_words(rng, g.k * g.n)).collect();
        server.register_chain_elem(&chain, ws, elem)
    };
    let pid_i32 = word_session(ElemType::I32, &mut rng)?;
    let pid_gl = word_session(ElemType::Goldilocks, &mut rng)?;

    // Heterogeneous fleets get one extra artifact-backed session per
    // distinct non-home arch, so predicted-completion-time placement has
    // real cross-arch work to schedule (each session is only eligible on
    // its own arch's devices; docs/SERVING.md §Heterogeneous fleets).
    let mut extra: Vec<crate::coordinator::serve::ProgramId> = Vec::new();
    {
        let mut seen: Vec<String> = vec![cfg.name()];
        for a in &device_archs {
            if seen.contains(&a.name()) {
                continue;
            }
            seen.push(a.name());
            let ws: Vec<Vec<u64>> = chain
                .layers
                .iter()
                .map(|g| ElemType::I32.sample_words(&mut rng, g.k * g.n))
                .collect();
            let art = crate::artifact::Compiler::new(a)
                .weights(ws)
                .compile(&chain)
                .map_err(|e| anyhow::anyhow!("compile loadgen chain for {}: {e}", a.name()))?;
            extra.push(server.register(ArtifactSource::Artifact(Box::new(art)))?);
        }
        if !extra.is_empty() {
            eprintln!("heterogeneous fleet: {} extra cross-arch session(s)", extra.len());
        }
    }

    match args.str_flag("faults", "none").as_str() {
        "none" => {}
        "scripted" => {
            #[cfg(any(test, feature = "faults"))]
            {
                use crate::coordinator::fleet::{FaultDropout, FaultPlan};
                let mut dropouts = Vec::new();
                if server.fleet().device_count() > 1 {
                    dropouts.push(FaultDropout { device: 1, after_shards: 3, transient: true });
                }
                server.fleet().set_fault_plan(FaultPlan {
                    seed,
                    dropouts,
                    slow_prob: 0.05,
                    slow_ms: 2,
                    panic_prob: 0.0,
                });
                eprintln!("fault plan armed: transient device-1 dropout + 5% slow shards");
            }
            #[cfg(not(any(test, feature = "faults")))]
            anyhow::bail!("--faults scripted requires building with `--features faults`");
        }
        other => anyhow::bail!("--faults '{other}' (expected none | scripted)"),
    }

    // Collector: timestamps every response as it arrives; ends when the
    // server thread drops its response sender.
    let collector = std::thread::spawn(move || {
        let mut got: Vec<(u64, Option<ErrorCode>, Instant)> = Vec::new();
        while let Ok(r) = rx.recv() {
            got.push((r.id, r.code, Instant::now()));
        }
        got
    });

    // Open-loop Poisson sender: exponential inter-arrivals at `rate`.
    let mut sent: Map<u64, (Instant, QosClass)> = Map::new();
    let start = Instant::now();
    let mut next_s = 0.0f64;
    let mut id = 0u64;
    while start.elapsed() < duration {
        next_s += -(1.0 - rng.f64()).ln() / rate;
        let target = start + Duration::from_secs_f64(next_s);
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        if start.elapsed() >= duration {
            break;
        }
        // QoS mix: 50% Interactive (tight deadline), 30% Batch (loose
        // deadline), 20% BestEffort (no deadline).
        let r = match id % 10 {
            0..=4 => Request::for_program(id, pid_f32, m, rng.f32_matrix(m, kf))
                .with_qos(QosClass::Interactive)
                .with_deadline_ms(interactive_deadline_ms),
            5..=7 => {
                let words = ElemType::I32.sample_words(&mut rng, m * kf);
                Request::for_program_words(id, pid_i32, m, words)
                    .with_qos(QosClass::Batch)
                    .with_deadline_ms(interactive_deadline_ms * 4)
            }
            8 if !extra.is_empty() => {
                // Cross-arch traffic: round-robin over the non-home-arch
                // sessions so every device group stays populated.
                let pid = extra[(id as usize / 10) % extra.len()];
                let words = ElemType::I32.sample_words(&mut rng, m * kf);
                Request::for_program_words(id, pid, m, words).with_qos(QosClass::BestEffort)
            }
            _ => {
                let words = ElemType::Goldilocks.sample_words(&mut rng, m * kf);
                Request::for_program_words(id, pid_gl, m, words)
                    .with_qos(QosClass::BestEffort)
            }
        };
        sent.insert(id, (Instant::now(), r.admission.qos));
        tx.send(r)?;
        id += 1;
    }
    let offered_wall_us = start.elapsed().as_secs_f64() * 1e6;
    drop(tx); // close the front door; the server drains and exits
    let stats = h.join().map_err(|_| anyhow::anyhow!("server panicked"))?;
    let got = collector.join().map_err(|_| anyhow::anyhow!("collector panicked"))?;
    let wall_us = start.elapsed().as_secs_f64() * 1e6;

    // Exactly-once: every sent id answered once, no strays, no duplicates.
    // Per-class latency goes straight into the shared log-scale histogram
    // (`crate::obs::Histogram`) — the same quantile implementation the
    // span histograms use, replacing the old sort-a-Vec percentile path.
    let mut seen = HashSet::new();
    let mut lat: Map<QosClass, crate::obs::Histogram> = Map::new();
    let (mut ok, mut shed, mut expired, mut errors) = (0u64, 0u64, 0u64, 0u64);
    let mut interactive_shed = 0u64;
    for (rid, code, at) in &got {
        anyhow::ensure!(seen.insert(*rid), "duplicate response for request {rid}");
        let (sent_at, qos) =
            *sent.get(rid).ok_or_else(|| anyhow::anyhow!("response for unknown id {rid}"))?;
        match code {
            None => {
                ok += 1;
                lat.entry(qos)
                    .or_default()
                    .record(at.saturating_duration_since(sent_at).as_secs_f64() * 1e6);
            }
            Some(ErrorCode::Shed) => {
                shed += 1;
                if qos == QosClass::Interactive {
                    interactive_shed += 1;
                }
            }
            Some(ErrorCode::DeadlineExceeded) => expired += 1,
            Some(
                ErrorCode::SessionGone
                | ErrorCode::Watchdog
                | ErrorCode::NoEligibleDevice
                | ErrorCode::Exec,
            ) => errors += 1,
        }
    }
    anyhow::ensure!(
        got.len() == sent.len(),
        "{} of {} requests went unanswered",
        sent.len() - got.len().min(sent.len()),
        sent.len()
    );
    anyhow::ensure!(
        server.admission().in_flight() == 0,
        "admission in-flight count leaked: {}",
        server.admission().in_flight()
    );

    let mut log = crate::util::bench::BenchLog::new();
    log.metric("offered_rate_per_s", rate);
    log.metric("duration_ms", duration.as_millis() as f64);
    log.metric("devices", server.fleet().device_count() as f64);
    log.metric("sent", sent.len() as f64);
    log.metric("answered", got.len() as f64);
    log.metric("succeeded", ok as f64);
    log.metric("shed", shed as f64);
    log.metric("expired", expired as f64);
    log.metric("errors", errors as f64);
    log.metric("interactive_shed", interactive_shed as f64);
    log.metric("injected", stats.injected as f64);
    log.metric("batches", stats.batches as f64);
    log.metric("throughput_per_s", stats.throughput_per_s(wall_us));
    for qos in QosClass::ALL {
        let h = lat.get(&qos);
        let n = h.map(|h| h.count()).unwrap_or(0);
        let key = qos.name().replace('-', "_");
        log.metric(&format!("{key}_succeeded"), n as f64);
        for (tag, p) in [("p50", 50.0), ("p99", 99.0), ("p999", 99.9)] {
            let v = if n == 0 { 0.0 } else { h.unwrap().percentile(p) };
            log.metric(&format!("{key}_{tag}_us"), v);
        }
    }
    if server.fleet().device_count() > 1 {
        let rep = server.fleet_report(wall_us);
        log.metric("retries", rep.retries() as f64);
        log.metric("watchdog_trips", rep.watchdog_trips() as f64);
        log.metric("recoveries", rep.recoveries() as f64);
        log.metric("steal_wait_mean_us", rep.steal_wait_mean_us());
        // Cost-aware scheduling accuracy + shared fetch-channel contention
        // (docs/OBSERVABILITY.md): predicted-vs-modeled cycle error over
        // the devices that did work, and the fleet-wide control speedup
        // under the shared instruction-fetch channel.
        let errs: Vec<f64> = rep
            .devices
            .iter()
            .filter(|d| d.predicted_cycles > 0.0)
            .map(|d| d.predict_err())
            .collect();
        log.metric(
            "predict_err_mean",
            if errs.is_empty() { 0.0 } else { crate::util::mean(&errs) },
        );
        let sf = rep.shared_fetch();
        log.metric("fetch_contention_micro", sf.micro_contention);
        log.metric("fetch_contention_minisa", sf.minisa_contention);
        log.metric("fetch_control_speedup", sf.control_speedup());
        let rows: u64 = rep.devices.iter().map(|d| d.rows).sum();
        log.metric("rows_per_s", rows as f64 / (wall_us / 1e6).max(1e-9));
        println!("{}", rep.render());
    }
    let out = args.str_flag("out", "BENCH_serving.json");
    log.write_json(&out).map_err(|e| anyhow::anyhow!("{out}: {e}"))?;
    write_metrics_snapshot(args, &server, wall_us)?;

    println!(
        "loadgen: offered {:.0} req/s for {} ms over {} device(s): {} sent, {} ok, \
         {} shed, {} expired, {} errors, {} injected → {out}",
        rate,
        duration.as_millis(),
        server.fleet().device_count(),
        sent.len(),
        ok,
        shed,
        expired,
        errors,
        stats.injected,
    );
    if let Some(ih) = lat.get(&QosClass::Interactive).filter(|h| h.count() > 0) {
        println!(
            "interactive: p50 {:.0} µs, p99 {:.0} µs, p999 {:.0} µs (deadline {} ms)",
            ih.percentile(50.0),
            ih.percentile(99.0),
            ih.percentile(99.9),
            interactive_deadline_ms,
        );
        // Acceptance: Interactive p99 stays bounded by its deadline — a
        // success answered after the deadline would have been converted to
        // `deadline_exceeded` at the stitch, so any success latency above
        // the deadline means a hand-off point failed to drop it. 10 ms of
        // slack covers collector-thread scheduling between the stitch-time
        // expiry check and the receive timestamp. (Histogram quantiles
        // clamp to the observed max, so the bound cannot loosen.)
        anyhow::ensure!(
            ih.percentile(99.0) <= (interactive_deadline_ms as f64 + 10.0) * 1e3,
            "interactive p99 exceeds the {interactive_deadline_ms} ms deadline"
        );
    }
    if !overload {
        anyhow::ensure!(
            interactive_shed == 0,
            "{interactive_shed} Interactive requests shed at low offered load"
        );
        anyhow::ensure!(errors == 0, "{errors} requests failed (exec/watchdog/session_gone)");
    }
    println!(
        "every request answered exactly once ✓ ({} offered-load window µs {:.0})",
        if overload { "overload run" } else { "low-load invariants hold" },
        offered_wall_us,
    );
    Ok(())
}

/// `minisa metrics` — run a short fully-traced serving burst and export
/// the resulting telemetry snapshot (docs/OBSERVABILITY.md): Prometheus
/// text exposition by default, `--json` for the JSON snapshot document,
/// `--out <file>` to write to a file instead of stdout. A quick way to see
/// the whole metric catalog — serving counters, per-stage span histograms
/// and the fleet stall-accounting gauges — with live values.
pub fn cmd_metrics(args: &Args) -> anyhow::Result<()> {
    use crate::coordinator::serve::{spawn_with_options, NaiveExecutor, Request};
    use std::sync::Arc;

    let cfg = configs(args).into_iter().next().unwrap_or_else(|| ArchConfig::paper(4, 4));
    let requests = args.usize_flag("requests", 16);
    let mut sopts = server_options(args)?;
    sopts.tracing = crate::obs::TraceOptions::all();
    let (tx, rx, h, server) = spawn_with_options(&cfg, Arc::new(NaiveExecutor), sopts);
    let mut rng = crate::util::Lcg::new(args.usize_flag("seed", 42) as u64);
    let m = 4usize;
    let dims = [8usize, 12, 8];
    let chain = Chain::mlp("metrics", m, &dims);
    let ws: Vec<Vec<f32>> = chain.layers.iter().map(|g| rng.f32_matrix(g.k, g.n)).collect();
    let pid = server.register_chain(&chain, ws)?;
    let wall = std::time::Instant::now();
    for id in 0..requests as u64 {
        tx.send(Request::for_program(id, pid, m, rng.f32_matrix(m, dims[0])))?;
    }
    for _ in 0..requests {
        let r = rx.recv()?;
        anyhow::ensure!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
    }
    drop(tx);
    h.join().map_err(|_| anyhow::anyhow!("server panicked"))?;
    let wall_us = wall.elapsed().as_secs_f64() * 1e6;
    let snap = server.metrics_snapshot(wall_us);
    let text = if args.bool_flag("json") { snap.to_json() } else { snap.to_prometheus() };
    match args.flags.get("out") {
        Some(path) => {
            std::fs::write(path, &text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
            println!(
                "{} metric series → {path} ({requests} traced requests served on {})",
                snap.len(),
                cfg.name(),
            );
        }
        None => print!("{text}"),
    }
    Ok(())
}

pub fn usage() -> &'static str {
    "MINISA / FEATHER+ toolchain (paper reproduction)\n\
     \n\
     USAGE: minisa <command> [flags]\n\
     \n\
     COMMANDS\n\
       evaluate   (mapping, layout) co-search, MINISA vs micro — Fig. 10/12\n\
                  [--small] [--jobs N] [--csv file] [--ah N --aw N|same] [--out dir]\n\
       compare    instruction overhead + stalls on the Table I workload\n\
       analyze    FEATHER+ vs RTX5090 vs TPUv6e-8 — Fig. 11 [--breakdown]\n\
       search     single-shape mapper search [--m --k --n --ah --aw]\n\
                  [--layout-constrained]\n\
       trace      dump the lowered MINISA program [--m --k --n --validate]\n\
                  [--elem E] (validate under that element backend)\n\
       run        compile + execute a Program end-to-end, verified against\n\
                  the naive reference [--elem E] [--devices N]\n\
                  [--suite <name> [--scale N] | --ntt N | --dims k0,k1,... --m N]\n\
                  [--artifact f.minisa] (load instead of compiling: zero\n\
                  mapper runs, weights/elem/config come from the container)\n\
       compile    compile a chain into a deployable .minisa artifact\n\
                  (encoded instruction stream + decisions + weights)\n\
                  [--suite|--ntt|--dims as for run] [--elem E] [--out file]\n\
       inspect    inspect a .minisa artifact: header, per-class instruction\n\
                  counts/bytes, round-trip check  <file> [--disasm]\n\
                  [--from-registry <key> --registry <dir>] (fetch + fully\n\
                  re-verify from the registry instead of a file)\n\
       registry   content-addressed artifact registry (docs/REGISTRY.md)\n\
                  list|put|gc|verify|diff  --registry <dir>\n\
                  put --artifact f.minisa | put --delta-of <spec> [--seed N]\n\
                  gc [--pin spec,spec,...]  diff <specA> <specB>\n\
                  (<spec> = exact key | content-hash prefix | model name)\n\
       bitwidth   Table V ISA bitwidths\n\
       area       Table VI area/power model\n\
       workloads  dump the 50-workload suite CSV [--small]\n\
       serve      serving loop, ad-hoc single-GEMM requests [--requests N]\n\
                  [--elem E] (non-f32: a single-GEMM element session)\n\
                  [--devices N --shard-min-rows R --max-batch B]\n\
       serve-model  compile-once/serve-many model sessions (§IV-G programs)\n\
                  [--dims k0,k1,... | --gpt] [--m N] [--requests N] [--elem E]\n\
                  [--artifact f.minisa] (serve a compiled artifact: hard-\n\
                  fails on any mapper run or program compile)\n\
                  [--registry <dir> --model-key <spec>] (serve straight from\n\
                  the registry through the shared program cache)\n\
                  [--swap-to <path|spec> [--swap-after N]] (zero-downtime\n\
                  hot swap mid-traffic; deltas resolve against their base)\n\
                  [--devices N --shard-min-rows R --max-batch B]\n\
       loadgen    open-loop Poisson load generator for the serving front\n\
                  door; emits BENCH_serving.json and enforces the\n\
                  robustness invariants (docs/SERVING.md)\n\
                  [--duration-ms N] [--rate R] [--devices N] [--overload]\n\
                  [--faults none|scripted] [--deadline-ms N] [--out file]\n\
       metrics    run a short traced serving burst and export the metric\n\
                  catalog with live values (docs/OBSERVABILITY.md)\n\
                  [--requests N] [--json] [--out file] [--devices N]\n\
       animate    cycle-by-cycle NEST/BIRRD/OB animation [--m --k --n --waves]\n\
     \n\
     --elem E selects the element arithmetic backend:\n\
       i32 (saturating, default for run), f32 (default for serving),\n\
       babybear / goldilocks / pallas (Montgomery prime fields — the FHE/ZKP\n\
       NTT number systems; see EXPERIMENTS.md §Field arithmetic)\n\
     --devices N shards work across a simulated N-device fleet (request-\n\
       parallel work stealing + tile-parallel M-row sharding, bit-identical\n\
       to one device; see EXPERIMENTS.md §Fleet serving)\n\
     serving admission flags (serve, serve-model, loadgen):\n\
       --qos interactive|batch|best-effort  --deadline-ms N (per request)\n\
       --in-flight N --rate-limit R --burst B (shed policy, docs/SERVING.md)\n\
       --shard-timeout-ms N (per-shard watchdog; 0 = off)\n\
     serving telemetry flags (serve, serve-model, loadgen, metrics):\n\
       --trace (per-request span timelines → serve_stage_* histograms)\n\
       --trace-sample N (trace every Nth arrival; default 1)\n\
       --metrics-out f.json (write the full telemetry snapshot as JSON;\n\
         docs/OBSERVABILITY.md — loadgen always traces)\n"
}

/// Dispatch. Returns process exit code.
pub fn run(argv: &[String]) -> i32 {
    let args = Args::parse(argv);
    let r = match args.cmd.as_str() {
        "evaluate" => cmd_evaluate(&args),
        "compare" => cmd_compare(&args),
        "analyze" => cmd_analyze(&args),
        "breakdown" => cmd_breakdown(&args),
        "search" => cmd_search(&args),
        "trace" => cmd_trace(&args),
        "run" => cmd_run(&args),
        "compile" => cmd_compile(&args),
        "inspect" => cmd_inspect(&args),
        "bitwidth" => cmd_bitwidth(&args),
        "area" => cmd_area(&args),
        "workloads" => cmd_workloads(&args),
        "animate" => {
            let m = args.usize_flag("m", 8);
            let k = args.usize_flag("k", 8);
            let n = args.usize_flag("n", 8);
            let cfg = configs(&args).into_iter().next().unwrap_or_else(|| ArchConfig::paper(4, 4));
            let g = Gemm::new("animate", "cli", m, k, n);
            match animate::animate(&cfg, &g, args.usize_flag("waves", 4)) {
                Ok(s) => {
                    println!("{s}");
                    Ok(())
                }
                Err(e) => Err(anyhow::anyhow!(e)),
            }
        }
        "serve" => cmd_serve(&args),
        "serve-model" => cmd_serve_model(&args),
        "registry" => cmd_registry(&args),
        "loadgen" => cmd_loadgen(&args),
        "metrics" => cmd_metrics(&args),
        "help" | "" => {
            println!("{}", usage());
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n{}", usage());
            return 2;
        }
    };
    match r {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_flags() {
        let argv: Vec<String> =
            ["search", "--m", "128", "--k=40", "--fast", "--ah", "4", "--aw", "16"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let a = Args::parse(&argv);
        assert_eq!(a.cmd, "search");
        assert_eq!(a.usize_flag("m", 0), 128);
        assert_eq!(a.usize_flag("k", 0), 40);
        assert_eq!(a.usize_flag("ah", 0), 4);
    }

    #[test]
    fn bitwidth_and_area_commands_run() {
        assert!(cmd_bitwidth(&Args::default()).is_ok());
        assert!(cmd_area(&Args::default()).is_ok());
    }

    #[test]
    fn search_command_runs() {
        let argv: Vec<String> = ["search", "--m", "64", "--k", "40", "--n", "24", "--ah", "4", "--aw", "4", "--fast"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert_eq!(run(&argv), 0);
    }

    #[test]
    fn unknown_command_fails() {
        let argv = vec!["frobnicate".to_string()];
        assert_eq!(run(&argv), 2);
    }

    #[test]
    fn serve_model_command_runs() {
        let argv: Vec<String> = [
            "serve-model", "--dims", "16,24,16", "--m", "4", "--requests", "6", "--ah", "4",
            "--aw", "4",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(run(&argv), 0);
    }

    #[test]
    fn run_command_executes_field_ntt() {
        let argv: Vec<String> = [
            "run", "--ntt", "16", "--m", "2", "--elem", "babybear", "--ah", "4", "--aw", "4",
            "--fast",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(run(&argv), 0);
    }

    #[test]
    fn run_command_executes_scaled_suite_entry() {
        let argv: Vec<String> = [
            "run", "--suite", "zkp_ntt_8192", "--scale", "32", "--ah", "4", "--aw", "4", "--fast",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(run(&argv), 0);
    }

    #[test]
    fn run_command_executes_i32_chain() {
        let argv: Vec<String> =
            ["run", "--dims", "8,12,8", "--m", "4", "--ah", "4", "--aw", "4", "--fast"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        assert_eq!(run(&argv), 0);
    }

    #[test]
    fn run_rejects_unknown_elem() {
        let argv: Vec<String> =
            ["run", "--ntt", "16", "--elem", "i64", "--ah", "4", "--aw", "4", "--fast"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        assert_eq!(run(&argv), 1);
    }

    #[test]
    fn serve_model_command_runs_over_field_elem() {
        let argv: Vec<String> = [
            "serve-model", "--dims", "8,12,8", "--m", "2", "--requests", "4", "--elem",
            "goldilocks", "--ah", "4", "--aw", "4",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(run(&argv), 0);
    }

    #[test]
    fn serve_model_command_runs_on_a_fleet() {
        let argv: Vec<String> = [
            "serve-model", "--dims", "16,24,16", "--m", "4", "--requests", "8", "--ah", "4",
            "--aw", "4", "--devices", "3", "--shard-min-rows", "1",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(run(&argv), 0);
    }

    #[test]
    fn run_command_verifies_fleet_against_single_device() {
        let argv: Vec<String> = [
            "run", "--ntt", "16", "--m", "4", "--elem", "goldilocks", "--ah", "4", "--aw", "4",
            "--fast", "--devices", "3", "--shard-min-rows", "1",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(run(&argv), 0);
    }

    fn argv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    /// The full artifact pipeline on the CLI: `compile` writes a `.minisa`
    /// file, `inspect` reads it back (with disassembly), and both `run
    /// --artifact` and `serve-model --artifact` execute it — the latter two
    /// hard-fail internally on any mapper run, so exit code 0 *is* the
    /// zero-mapper-runs assertion.
    #[test]
    fn compile_inspect_run_serve_artifact_pipeline() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("minisa_cli_{}.minisa", std::process::id()));
        let p = path.to_str().unwrap();
        assert_eq!(
            run(&argv(&[
                "compile", "--dims", "8,12,8", "--m", "4", "--elem", "goldilocks", "--ah", "4",
                "--aw", "4", "--fast", "--out", p,
            ])),
            0
        );
        assert_eq!(run(&argv(&["inspect", p, "--disasm"])), 0);
        assert_eq!(run(&argv(&["run", "--artifact", p])), 0);
        assert_eq!(run(&argv(&["serve-model", "--artifact", p, "--requests", "4"])), 0);
        // Fleet serving from the artifact keeps the same guarantees.
        assert_eq!(
            run(&argv(&[
                "serve-model", "--artifact", p, "--requests", "6", "--devices", "2",
                "--shard-min-rows", "1",
            ])),
            0
        );
        std::fs::remove_file(&path).ok();
    }

    /// The registry pipeline on the CLI: `compile` → `registry put` →
    /// `serve-model --model-key` (served straight from the store, zero
    /// compiles on the serving path) → `--swap-to` a stored delta
    /// mid-traffic → `registry gc --pin` keeps the delta's live base →
    /// `inspect --from-registry` re-verifies the entry in place.
    #[test]
    fn registry_cli_round_trip() {
        let dir = std::env::temp_dir().join(format!("minisa_reg_cli_{}", std::process::id()));
        let reg_dir = dir.join("store");
        std::fs::create_dir_all(&reg_dir).unwrap();
        let d = reg_dir.to_str().unwrap().to_string();
        let base_path = dir.join("base.minisa");
        let bp = base_path.to_str().unwrap().to_string();
        assert_eq!(
            run(&argv(&[
                "compile", "--dims", "8,12,8", "--m", "4", "--elem", "babybear", "--ah", "4",
                "--aw", "4", "--fast", "--out", &bp,
            ])),
            0
        );
        assert_eq!(run(&argv(&["registry", "put", "--registry", &d, "--artifact", &bp])), 0);
        // Recompute the content address the same way `put` did, so the rest
        // of the test can target entries by exact key.
        let art = Artifact::load(Path::new(&bp)).unwrap();
        let (key, _) = crate::registry::RegistryKey::of(&art);
        let key_s = key.to_string();
        assert_eq!(
            run(&argv(&["registry", "put", "--registry", &d, "--delta-of", &key_s, "--seed", "7"])),
            0
        );
        assert_eq!(run(&argv(&["registry", "list", "--registry", &d])), 0);
        assert_eq!(run(&argv(&["registry", "verify", "--registry", &d])), 0);
        // Find the delta's key through the library (kind is "delta").
        let reg = crate::registry::Registry::open_dir(&reg_dir).unwrap();
        let delta_key = reg
            .list()
            .unwrap()
            .into_iter()
            .find(|e| e.kind == "delta")
            .expect("delta entry present")
            .key
            .to_string();
        assert_ne!(delta_key, key_s, "delta must live at a distinct content address");
        assert_eq!(run(&argv(&["registry", "diff", &key_s, &delta_key, "--registry", &d])), 0);
        // Serve from the registry and hot-swap to the delta mid-traffic.
        assert_eq!(
            run(&argv(&[
                "serve-model", "--registry", &d, "--model-key", &key_s, "--requests", "8",
                "--swap-to", &delta_key, "--swap-after", "4",
            ])),
            0
        );
        // gc pinned to the delta keeps its base alive; both inspect cleanly.
        assert_eq!(
            run(&argv(&["registry", "gc", "--registry", &d, "--pin", &delta_key])),
            0
        );
        assert_eq!(
            run(&argv(&["inspect", "--from-registry", &key_s, "--registry", &d])),
            0
        );
        assert_eq!(
            run(&argv(&["inspect", "--from-registry", &delta_key, "--registry", &d, "--disasm"])),
            0
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn inspect_rejects_missing_and_garbage_files() {
        assert_eq!(run(&argv(&["inspect"])), 1, "no path");
        assert_eq!(run(&argv(&["inspect", "/nonexistent/x.minisa"])), 1);
        let dir = std::env::temp_dir();
        let path = dir.join(format!("minisa_garbage_{}.minisa", std::process::id()));
        std::fs::write(&path, b"not an artifact at all").unwrap();
        assert_eq!(run(&argv(&["inspect", path.to_str().unwrap()])), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn run_artifact_requires_weights_payload() {
        // `compile` always attaches weights, so build a bare artifact
        // directly and confirm `run --artifact` refuses it.
        let cfg = ArchConfig::paper(4, 4);
        let chain = Chain::mlp("bare", 4, &[8, 8]);
        let art = Compiler::new(&cfg).compile(&chain).unwrap();
        let path =
            std::env::temp_dir().join(format!("minisa_bare_{}.minisa", std::process::id()));
        art.save(&path).unwrap();
        assert_eq!(run(&argv(&["run", "--artifact", path.to_str().unwrap()])), 1);
        std::fs::remove_file(&path).ok();
    }

    /// The CI smoke step in miniature: loadgen with scripted faults on a
    /// fleet must answer every request exactly once, shed no Interactive
    /// traffic at low offered load, and write the bench JSON.
    #[test]
    fn loadgen_scripted_faults_smoke() {
        let out = std::env::temp_dir()
            .join(format!("minisa_loadgen_{}.json", std::process::id()));
        let p = out.to_str().unwrap();
        assert_eq!(
            run(&argv(&[
                "loadgen", "--duration-ms", "200", "--rate", "300", "--devices", "3",
                "--shard-min-rows", "1", "--faults", "scripted", "--out", p,
            ])),
            0
        );
        let json = std::fs::read_to_string(&out).unwrap();
        assert!(json.contains("throughput_per_s"), "{json}");
        assert!(json.contains("interactive_p99_us"), "{json}");
        std::fs::remove_file(&out).ok();
    }

    /// Overload run: a tiny in-flight budget on one device sheds traffic,
    /// but with `--overload` the command still exits 0 (typed sheds are
    /// policy, not failure) and every request is answered exactly once.
    #[test]
    fn loadgen_overload_sheds_but_answers_everything() {
        let out = std::env::temp_dir()
            .join(format!("minisa_loadgen_over_{}.json", std::process::id()));
        let p = out.to_str().unwrap();
        assert_eq!(
            run(&argv(&[
                "loadgen", "--duration-ms", "150", "--rate", "500", "--in-flight", "2",
                "--rate-limit", "50", "--burst", "2", "--overload", "--out", p,
            ])),
            0
        );
        std::fs::remove_file(&out).ok();
    }

    /// The CI metrics-gate step in miniature: loadgen with `--metrics-out`
    /// writes a JSON telemetry snapshot carrying the serving counters, the
    /// per-stage span histograms (loadgen always traces) and the per-device
    /// modeled stall gauges.
    #[test]
    fn loadgen_metrics_out_writes_snapshot() {
        let dir = std::env::temp_dir();
        let bench = dir.join(format!("minisa_lg_bench_{}.json", std::process::id()));
        let snap = dir.join(format!("minisa_lg_snap_{}.json", std::process::id()));
        assert_eq!(
            run(&argv(&[
                "loadgen", "--duration-ms", "200", "--rate", "300", "--devices", "2",
                "--shard-min-rows", "1", "--out", bench.to_str().unwrap(), "--metrics-out",
                snap.to_str().unwrap(),
            ])),
            0
        );
        let json = std::fs::read_to_string(&snap).unwrap();
        for key in [
            "serve_served_total",
            "serve_batches_total",
            "serve_stage_execute_us",
            "serve_request_us",
            "fleet_dev0_micro_fetch_stall_cycles",
            "fleet_micro_stall_fraction",
        ] {
            assert!(json.contains(key), "snapshot missing {key}: {json}");
        }
        std::fs::remove_file(&bench).ok();
        std::fs::remove_file(&snap).ok();
    }

    #[test]
    fn metrics_command_exports_both_formats() {
        let out = std::env::temp_dir()
            .join(format!("minisa_metrics_{}.prom", std::process::id()));
        let p = out.to_str().unwrap();
        assert_eq!(
            run(&argv(&["metrics", "--requests", "4", "--ah", "4", "--aw", "4", "--out", p])),
            0
        );
        let prom = std::fs::read_to_string(&out).unwrap();
        assert!(prom.contains("# TYPE serve_served_total counter"), "{prom}");
        assert!(prom.contains("serve_request_us"), "{prom}");
        assert_eq!(
            run(&argv(&[
                "metrics", "--requests", "4", "--ah", "4", "--aw", "4", "--json", "--out", p,
            ])),
            0
        );
        let json = std::fs::read_to_string(&out).unwrap();
        assert!(json.contains("\"counters\""), "{json}");
        std::fs::remove_file(&out).ok();
    }

    #[test]
    fn loadgen_rejects_unknown_fault_schedule() {
        assert_eq!(run(&argv(&["loadgen", "--duration-ms", "50", "--faults", "chaos"])), 1);
    }

    #[test]
    fn serve_rejects_unknown_qos_class() {
        assert_eq!(
            run(&argv(&["serve", "--requests", "2", "--qos", "gold", "--ah", "4", "--aw", "4"])),
            1
        );
    }

    #[test]
    fn serve_model_rejects_bad_dims() {
        let argv: Vec<String> = ["serve-model", "--dims", "16", "--ah", "4", "--aw", "4"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert_eq!(run(&argv), 1);
    }
}
