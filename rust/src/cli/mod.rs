//! Command-line interface mirroring the paper artifact's entry points
//! (Appendix D): `evaluate`, `compare`, `analyze`, `search`, `trace`,
//! `bitwidth`, `area`, `workloads`, `serve`.
//!
//! Hand-rolled argument parsing (offline substitute for clap, DESIGN.md).

pub mod animate;

use std::collections::HashMap;
use std::path::PathBuf;

use crate::arch::config::ArchConfig;
use crate::arith::{decode_words, ElemType, Element};
use crate::coordinator::{compare_devices, evaluate_suite, summarize_by_config};
use crate::functional::FunctionalSim;
use crate::mapper::search::{search as mapper_search, MapperOptions};
use crate::report::{eng, f1, f2, pct, Table};
use crate::with_element;
use crate::workloads::{self, ntt, Gemm};

/// Parsed command line: subcommand + flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub cmd: String,
    pub flags: HashMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Self {
        let mut a = Args::default();
        let mut it = argv.iter();
        if let Some(c) = it.next() {
            a.cmd = c.clone();
        }
        // Name of the most recent bare `--flag` awaiting a value.
        let mut pending: Option<String> = None;
        for tok in it {
            if let Some(name) = tok.strip_prefix("--") {
                pending = None;
                // --flag value | --flag=value | bare --flag (boolean)
                if let Some((k, v)) = name.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else {
                    a.flags.insert(name.to_string(), "true".to_string());
                    pending = Some(name.to_string());
                }
            } else if let Some(key) = pending.take() {
                a.flags.insert(key, tok.clone());
            } else {
                a.positional.push(tok.clone());
            }
        }
        a
    }

    pub fn usize_flag(&self, k: &str, default: usize) -> usize {
        self.flags.get(k).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn str_flag(&self, k: &str, default: &str) -> String {
        self.flags.get(k).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn bool_flag(&self, k: &str) -> bool {
        self.flags.get(k).map(|v| v == "true" || v == "1").unwrap_or(false)
    }
}

fn load_workloads(args: &Args) -> Vec<Gemm> {
    if let Some(csv) = args.flags.get("csv") {
        match workloads::from_csv(&PathBuf::from(csv)) {
            Ok(w) => return w,
            Err(e) => {
                eprintln!("warning: {e}; falling back to built-in suite");
            }
        }
    }
    if args.bool_flag("small") {
        workloads::suite_small()
    } else {
        workloads::suite50()
    }
}

fn configs(args: &Args) -> Vec<ArchConfig> {
    if let (Some(ah), Some(aw)) = (args.flags.get("ah"), args.flags.get("aw")) {
        let ah: usize = ah.parse().unwrap_or(16);
        let aw: usize = if aw == "same" { ah } else { aw.parse().unwrap_or(256) };
        vec![ArchConfig::paper(ah, aw)]
    } else if args.bool_flag("small") {
        vec![ArchConfig::paper(4, 4), ArchConfig::paper(4, 16), ArchConfig::paper(8, 8)]
    } else {
        ArchConfig::paper_sweep()
    }
}

fn opts(args: &Args) -> MapperOptions {
    MapperOptions {
        full_layout_search: !args.bool_flag("fast"),
        threads: args.usize_flag("jobs", 4),
        ..Default::default()
    }
}

fn out_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str_flag("out", "results"))
}

/// Parse `--elem {i32,f32,babybear,goldilocks,pallas}` (element backend for
/// functional execution and element-typed serving sessions).
fn elem_flag(args: &Args, default: ElemType) -> anyhow::Result<ElemType> {
    match args.flags.get("elem") {
        None => Ok(default),
        Some(s) => ElemType::parse(s).map_err(anyhow::Error::msg),
    }
}

/// `minisa evaluate` — Fig. 10/12 data: full (mapping, layout) co-search for
/// every workload × config, MINISA vs micro-instructions.
pub fn cmd_evaluate(args: &Args) -> anyhow::Result<()> {
    let ws = load_workloads(args);
    let cfgs = configs(args);
    let o = opts(args);
    let jobs = args.usize_flag("jobs", 8);
    eprintln!("evaluating {} workloads × {} configs on {jobs} jobs...", ws.len(), cfgs.len());
    let t0 = std::time::Instant::now();
    let rows = evaluate_suite(&cfgs, &ws, &o, jobs);
    eprintln!("done in {:.1}s ({} points)", t0.elapsed().as_secs_f64(), rows.len());

    let mut t = Table::new(
        "Per-workload evaluation (Fig. 10 / Fig. 12 data)",
        &[
            "config", "workload", "speedup", "instr_reduction", "micro_stall",
            "minisa_stall", "utilization", "minisa_B", "micro_B", "instr:data(micro)",
            "instr:data(minisa)",
        ],
    );
    for r in &rows {
        t.row(vec![
            r.config.clone(),
            r.workload.name.clone(),
            f2(r.speedup()),
            eng(r.instr_reduction()),
            pct(r.micro.instr_stall_fraction()),
            pct(r.decision.report.instr_stall_fraction()),
            pct(r.decision.report.utilization()),
            r.minisa_bytes.to_string(),
            r.micro_bytes.to_string(),
            f2(r.micro_instr_to_data()),
            format!("{:.2e}", r.minisa_instr_to_data()),
        ]);
    }
    let dir = out_dir(args);
    t.write_csv(&dir.join("evaluate.csv"))?;

    let mut s = Table::new(
        "Geomean by config (Fig. 10 headline)",
        &["config", "geo_speedup", "geo_instr_reduction", "micro_stall", "minisa_stall", "utilization"],
    );
    for c in summarize_by_config(&rows) {
        s.row(vec![
            c.config,
            f2(c.geo_speedup),
            eng(c.geo_instr_reduction),
            pct(c.mean_stall_micro),
            pct(c.mean_stall_minisa),
            pct(c.mean_utilization),
        ]);
    }
    s.write_csv(&dir.join("evaluate_summary.csv"))?;
    println!("{}", s.render());
    println!("wrote {}/evaluate.csv and evaluate_summary.csv", dir.display());
    Ok(())
}

/// `minisa compare` — Table I + instruction-byte comparison.
pub fn cmd_compare(args: &Args) -> anyhow::Result<()> {
    let o = opts(args);
    let g = workloads::table1_workload();
    let mut t = Table::new(
        "Table I: instruction-fetch stall, micro-instruction baseline",
        &["FEATHER+", "stall(micro)", "stall(MINISA)", "speedup", "minisa_B", "micro_B"],
    );
    for cfg in ArchConfig::table1_sweep() {
        if let Some(row) = crate::coordinator::evaluate_one(&cfg, &g, &o) {
            t.row(vec![
                cfg.name(),
                pct(row.micro.instr_stall_fraction()),
                pct(row.decision.report.instr_stall_fraction()),
                f2(row.speedup()),
                row.minisa_bytes.to_string(),
                row.micro_bytes.to_string(),
            ]);
        }
    }
    println!("{}", t.render());
    t.write_csv(&out_dir(args).join("table1.csv"))?;
    Ok(())
}

/// `minisa analyze` — Fig. 11 GPU/TPU comparison (+ Fig. 13 breakdown with
/// `--breakdown`).
pub fn cmd_analyze(args: &Args) -> anyhow::Result<()> {
    let ws = load_workloads(args);
    let o = opts(args);
    let jobs = args.usize_flag("jobs", 8);
    let rows = compare_devices(&ws, &o, jobs);
    let mut t = Table::new(
        "Fig. 11: latency (µs) — FEATHER+ 64×(16×256) mesh vs RTX5090 vs TPUv6e-8",
        &["workload", "feather_us", "gpu_us", "tpu_us", "feather_util", "vs_gpu", "vs_tpu"],
    );
    let mut vs_gpu = Vec::new();
    let mut vs_tpu = Vec::new();
    for r in &rows {
        vs_gpu.push(r.gpu_us / r.feather_us.max(1e-9));
        vs_tpu.push(r.tpu_us / r.feather_us.max(1e-9));
        t.row(vec![
            r.workload.name.clone(),
            f1(r.feather_us),
            f1(r.gpu_us),
            f1(r.tpu_us),
            pct(r.feather_utilization),
            f2(*vs_gpu.last().unwrap()),
            f2(*vs_tpu.last().unwrap()),
        ]);
    }
    println!("{}", t.render());
    println!(
        "geomean speedup: vs GPU {}x, vs TPU {}x",
        f2(crate::util::geomean(&vs_gpu)),
        f2(crate::util::geomean(&vs_tpu))
    );
    t.write_csv(&out_dir(args).join("gpu_tpu_compare.csv"))?;

    if args.bool_flag("breakdown") {
        cmd_breakdown(args)?;
    }
    Ok(())
}

/// Fig. 13: latency breakdown for representative workloads.
pub fn cmd_breakdown(args: &Args) -> anyhow::Result<()> {
    let o = opts(args);
    let reps: Vec<Gemm> = {
        let mut v = vec![workloads::table1_workload()];
        v.push(workloads::fhe_ntt().swap_remove(0));
        v.push(workloads::gpt_oss().swap_remove(0));
        v.push(workloads::zkp_ntt().swap_remove(0));
        v
    };
    let mut t = Table::new(
        "Fig. 13: cycle breakdown + utilization",
        &["config", "workload", "compute", "load_in", "load_w", "out_stream", "store",
          "fetch", "total", "utilization"],
    );
    for (ah, aw) in [(4usize, 64usize), (16, 64), (16, 256)] {
        let cfg = ArchConfig::paper(ah, aw);
        for g in &reps {
            if let Some(d) = mapper_search(&cfg, g, &o) {
                let r = &d.report;
                t.row(vec![
                    cfg.name(),
                    g.name.clone(),
                    f1(r.compute_cycles),
                    f1(r.load_in_cycles),
                    f1(r.load_w_cycles),
                    f1(r.out_stream_cycles),
                    f1(r.store_out_cycles),
                    f1(r.fetch_cycles),
                    f1(r.total_cycles),
                    pct(r.utilization()),
                ]);
            }
        }
    }
    println!("{}", t.render());
    t.write_csv(&out_dir(args).join("breakdown.csv"))?;
    Ok(())
}

/// `minisa search` — single-shape (mapping, layout) co-search.
pub fn cmd_search(args: &Args) -> anyhow::Result<()> {
    let m = args.usize_flag("m", 1024);
    let k = args.usize_flag("k", 40);
    let n = args.usize_flag("n", 88);
    let cfg = configs(args).into_iter().next().unwrap_or_else(|| ArchConfig::paper(16, 64));
    let g = Gemm::new("custom", "cli", m, k, n);
    let mut o = opts(args);
    if args.bool_flag("layout-constrained") {
        o.full_layout_search = false;
    }
    let d = mapper_search(&cfg, &g, &o)
        .ok_or_else(|| anyhow::anyhow!("no feasible mapping for {g} on {}", cfg.name()))?;
    println!("workload: {g}");
    println!("config:   {} (D={}, VN≤{})", cfg.name(), cfg.d(), cfg.ah);
    println!(
        "decision: df={:?} vn={} tile=({},{},{}) nbc={} dup={} orders(i,w,o)=({},{},{})",
        d.choice.df, d.choice.vn, d.choice.m_t, d.choice.k_t, d.choice.n_t,
        d.choice.nbc, d.choice.dup, d.i_order, d.w_order, d.o_order
    );
    println!(
        "estimate: {} cycles ({} µs @1GHz), utilization {}, instr stall {}",
        f1(d.report.total_cycles),
        f2(d.report.latency_us(&cfg)),
        pct(d.report.utilization()),
        pct(d.report.instr_stall_fraction())
    );
    Ok(())
}

/// `minisa trace` — lower a shape and dump the MINISA program.
pub fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    let m = args.usize_flag("m", 16);
    let k = args.usize_flag("k", 16);
    let n = args.usize_flag("n", 16);
    let cfg = configs(args).into_iter().next().unwrap_or_else(|| ArchConfig::paper(4, 4));
    let g = Gemm::new("custom", "cli", m, k, n);
    let o = opts(args);
    let d = mapper_search(&cfg, &g, &o)
        .ok_or_else(|| anyhow::anyhow!("no feasible mapping"))?;
    let prog = crate::mapper::lower_gemm(&cfg, &g, &d.choice, d.i_order, d.w_order, d.o_order);
    println!("{}", prog.trace.disassemble());
    println!(
        "{} instructions, {} bytes MINISA vs {} bytes micro ({}× reduction), {} invocations, {} waves",
        prog.trace.len(),
        prog.minisa_bytes(),
        prog.micro_bytes(),
        eng(prog.instr_reduction()),
        prog.invocations,
        prog.waves
    );
    if args.bool_flag("validate") {
        let elem = elem_flag(args, ElemType::I32)?;
        let mut rng = crate::util::Lcg::new(42);
        let iw = elem.sample_words(&mut rng, g.m * g.k);
        let ww = elem.sample_words(&mut rng, g.k * g.n);
        let exact = with_element!(elem, E => {
            let iv: Vec<E> = decode_words::<E>(&iw);
            let wv: Vec<E> = decode_words::<E>(&ww);
            let got = crate::mapper::exec::execute_program(&cfg, &g, &prog, &iv, &wv)
                .map_err(|e| anyhow::anyhow!("functional sim: {e}"))?;
            got == crate::arith::naive_gemm_e::<E>(&iv, &wv, g.m, g.k, g.n)
        });
        anyhow::ensure!(exact, "functional mismatch under {elem}!");
        println!("functional simulation matches naive GEMM over {elem} ✓");
    }
    Ok(())
}

/// `minisa bitwidth` — Table V.
pub fn cmd_bitwidth(_args: &Args) -> anyhow::Result<()> {
    let mut t = Table::new(
        "Table V: MINISA ISA bitwidths",
        &["config", "Set*VNLayout", "E.Mapping", "E.Streaming"],
    );
    for row in crate::isa::bitwidth::table_v() {
        t.row(vec![
            row.config,
            format!("{} bits", row.set_layout_bits),
            format!("{} bits", row.execute_mapping_bits),
            format!("{} bits", row.execute_streaming_bits),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

/// `minisa area` — Table VI.
pub fn cmd_area(_args: &Args) -> anyhow::Result<()> {
    let mut t = Table::new(
        "Table VI: area/power, FEATHER vs FEATHER+ (model vs published)",
        &["setup", "F µm²", "F+ µm²", "Δarea", "F mW", "F+ mW", "Δpower", "paper F µm²", "paper Δ"],
    );
    for row in crate::arch::area::table_vi() {
        let paper = crate::arch::area::PAPER_TABLE_VI
            .iter()
            .find(|p| p.0 == row.config);
        t.row(vec![
            row.config.clone(),
            format!("{:.0}", row.feather_um2),
            format!("{:.0}", row.featherplus_um2),
            format!("{:.2}%", row.area_increase_pct),
            f2(row.feather_mw),
            f2(row.featherplus_mw),
            format!("{:.2}%", row.power_increase_pct),
            paper.map(|p| format!("{:.0}", p.1)).unwrap_or_default(),
            paper
                .map(|p| format!("{:.2}%", (p.2 / p.1 - 1.0) * 100.0))
                .unwrap_or_default(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

/// `minisa workloads` — dump the suite as CSV.
pub fn cmd_workloads(args: &Args) -> anyhow::Result<()> {
    let ws = load_workloads(args);
    print!("{}", workloads::to_csv(&ws));
    Ok(())
}

/// Parse the fleet sizing flags shared by the serving commands.
fn server_options(args: &Args) -> crate::coordinator::serve::ServerOptions {
    let d = crate::coordinator::serve::ServerOptions::default();
    crate::coordinator::serve::ServerOptions {
        devices: args.usize_flag("devices", d.devices).max(1),
        shard_min_rows: args.usize_flag("shard-min-rows", d.shard_min_rows).max(1),
        max_batch: args.usize_flag("max-batch", d.max_batch).max(1),
    }
}

/// Pick the PJRT executor when artifacts are available, else the naive one.
fn serving_executor(args: &Args) -> std::sync::Arc<dyn crate::coordinator::serve::TileExecutor> {
    use crate::coordinator::serve::NaiveExecutor;
    use std::sync::Arc;
    let dir = PathBuf::from(args.str_flag("artifacts", "artifacts"));
    match crate::runtime::PjrtExecutor::start(&dir) {
        Ok(exe) => {
            eprintln!("PJRT runtime on {}", exe.platform());
            Arc::new(exe)
        }
        Err(e) => {
            eprintln!("PJRT unavailable ({e:#}); using naive executor");
            Arc::new(NaiveExecutor)
        }
    }
}

/// `minisa run` — compile a model Program and execute it functionally,
/// end-to-end, under a chosen element backend (`--elem`), verifying the
/// result against the naive reference in the same number system.
///
/// Three ways to pick the workload:
/// * `--suite <name> [--scale N]` — an NTT entry of the 50-workload suite
///   (FHE-NTT/ZKP-NTT), scaled to a CI-sized transform (default cap 64);
///   weights are the *real* twiddle matrix of the entry's field, so this
///   is the paper's FHE/ZKP rows executing for real, not as shape models.
/// * `--ntt N` — a bare size-N NTT over the chosen (or default ZKP) field.
/// * `--dims k0,k1,... --m M` — an MLP chain with random operands.
pub fn cmd_run(args: &Args) -> anyhow::Result<()> {
    use crate::mapper::chain::Chain;
    use crate::program::Program;

    let cfg = configs(args).into_iter().next().unwrap_or_else(|| ArchConfig::paper(4, 4));
    let o = opts(args);
    let mut rng = crate::util::Lcg::new(args.usize_flag("seed", 42) as u64);

    // Resolve the chain and its weights (as canonical words) + element type.
    let (chain, weight_words, elem) = if let Some(name) = args.flags.get("suite") {
        let g = workloads::suite50()
            .into_iter()
            .find(|g| &g.name == name)
            .ok_or_else(|| anyhow::anyhow!("no suite entry named '{name}' (see `workloads`)"))?;
        let scale = args.usize_flag("scale", 64);
        let g = if ntt::ntt_size(&g).is_some() { ntt::scaled(&g, scale) } else { g };
        let n = ntt::ntt_size(&g).ok_or_else(|| {
            anyhow::anyhow!(
                "suite entry '{name}' is not an NTT kernel; use `--dims`/`--m` to execute \
                 arbitrary chains"
            )
        })?;
        let elem = elem_flag(args, ntt::default_elem(&g.category))?;
        let tw = ntt::twiddle_words(elem, n).map_err(anyhow::Error::msg)?;
        println!(
            "suite entry {} scaled to M={} K=N={} over {} (p = {})",
            g.name,
            g.m,
            n,
            elem,
            elem.modulus().unwrap_or(0)
        );
        (Chain { layers: vec![g] }, vec![tw], elem)
    } else if let Some(nspec) = args.flags.get("ntt") {
        let n: usize = nspec.parse().map_err(|e| anyhow::anyhow!("--ntt '{nspec}': {e}"))?;
        let m = args.usize_flag("m", (n / 16).max(1));
        let elem = elem_flag(args, ElemType::Goldilocks)?;
        let tw = ntt::twiddle_words(elem, n).map_err(anyhow::Error::msg)?;
        let g = Gemm::new(&format!("ntt_{n}"), "ZKP-NTT", m, n, n);
        (Chain { layers: vec![g] }, vec![tw], elem)
    } else {
        let spec = args.str_flag("dims", "16,24,16");
        let parsed: Result<Vec<usize>, _> = spec.split(',').map(|t| t.trim().parse()).collect();
        let dims = parsed.map_err(|e| anyhow::anyhow!("--dims '{spec}': {e}"))?;
        anyhow::ensure!(dims.len() >= 2, "--dims needs at least two widths");
        let m = args.usize_flag("m", 8);
        let chain = Chain::mlp("run", m, &dims);
        let elem = elem_flag(args, ElemType::I32)?;
        let ws: Vec<Vec<u64>> =
            chain.layers.iter().map(|g| elem.sample_words(&mut rng, g.k * g.n)).collect();
        (chain, ws, elem)
    };

    let t0 = std::time::Instant::now();
    let program = Program::compile(&cfg, &chain, &o)
        .ok_or_else(|| anyhow::anyhow!("no feasible mapping for chain on {}", cfg.name()))?;
    let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "compiled {} layer(s) on {} in {:.1} ms: {} wave plans, fused trace {} B \
         ({} SetIVNLayout elided)",
        program.layer_count(),
        cfg.name(),
        compile_ms,
        program.plan_count(),
        program.fused_bytes,
        program.elided,
    );

    let input_words = elem.sample_words(&mut rng, program.rows() * program.in_features());
    let t1 = std::time::Instant::now();
    let (exact, plan_compiles, checksum) = with_element!(elem, E => {
        let w: Vec<Vec<E>> = weight_words.iter().map(|m| decode_words::<E>(m)).collect();
        let input: Vec<E> = decode_words::<E>(&input_words);
        let mut sim: FunctionalSim<E> = FunctionalSim::new(&cfg);
        let got = program
            .execute(&mut sim, &input, &w)
            .map_err(|e| anyhow::anyhow!("functional execution: {e}"))?;
        let expect = program.reference(&input, &w);
        let checksum = got
            .iter()
            .map(|&v| E::reduce(v).encode())
            .fold(0u64, |h, x| h.rotate_left(7) ^ x);
        (got == expect, sim.plan_compiles, checksum)
    });
    let exec_ms = t1.elapsed().as_secs_f64() * 1e3;
    println!(
        "executed {}×{}→{} over {} in {:.1} ms ({} runtime plan compiles), checksum {:016x}",
        program.rows(),
        program.in_features(),
        program.out_features(),
        elem,
        exec_ms,
        plan_compiles,
        checksum,
    );
    anyhow::ensure!(exact, "functional output does NOT match the naive {elem} reference");
    anyhow::ensure!(plan_compiles == 0, "expected zero runtime plan compiles (compile-once)");
    println!("functional execution matches the naive {elem} reference exactly ✓");

    // `--devices N`: re-execute the same activation tile-parallel across a
    // simulated fleet and verify the stitched output is bit-identical to
    // the single-device run (the conformance invariant, live on the CLI).
    let devices = args.usize_flag("devices", 1);
    if devices > 1 {
        use crate::coordinator::fleet::{Fleet, FleetOptions};
        use crate::coordinator::serve::{execute_program_words, NaiveExecutor, WordWeights};
        let shard_min_rows = args.usize_flag("shard-min-rows", 1).max(1);
        let fleet = Fleet::new(
            &cfg,
            std::sync::Arc::new(NaiveExecutor),
            FleetOptions { devices, shard_min_rows },
        );
        let ww = WordWeights::new(weight_words, elem);
        let rows = program.rows();
        let t2 = std::time::Instant::now();
        let sharded = fleet
            .run_program_words(None, &program, rows, &input_words, &ww)
            .map_err(|e| anyhow::anyhow!("fleet execution: {e}"))?;
        let wall_us = t2.elapsed().as_secs_f64() * 1e6;
        let single = execute_program_words(&program, rows, &input_words, &ww)
            .map_err(|e| anyhow::anyhow!("single-device reference: {e}"))?;
        anyhow::ensure!(
            sharded == single,
            "fleet-sharded output diverges from single-device execution"
        );
        let report = fleet.report(wall_us);
        anyhow::ensure!(
            report.plan_compiles() == 0,
            "fleet execution compiled plans at runtime (expected zero)"
        );
        println!("{}", report.render());
        println!(
            "fleet of {devices} devices matches single-device execution bit-exactly ✓"
        );
    }
    Ok(())
}

/// `minisa serve` — run the serving loop on ad-hoc single-GEMM requests.
/// With `--elem` other than f32, the GEMM is registered as a single-layer
/// element-typed program session and served as word requests (ad-hoc f32
/// payloads cannot carry field residues).
pub fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    use crate::coordinator::serve::{spawn_with_options, Request};
    use std::sync::Arc;

    let cfg = configs(args).into_iter().next().unwrap_or_else(|| ArchConfig::paper(16, 64));
    let requests = args.usize_flag("requests", 64);
    let elem = elem_flag(args, ElemType::F32)?;
    let sopts = server_options(args);
    let executor = serving_executor(args);
    let backend = executor.name().to_string();
    let (tx, rx, h, server) = spawn_with_options(&cfg, executor, sopts);
    let mut rng = crate::util::Lcg::new(7);
    let wall = std::time::Instant::now();
    if elem == ElemType::F32 {
        let weight = Arc::new(rng.f32_matrix(64, 64)); // shared → batches by identity
        for id in 0..requests as u64 {
            tx.send(Request::gemm(id, 64, 64, 64, rng.f32_matrix(64, 64), Arc::clone(&weight)))?;
        }
    } else {
        use crate::mapper::chain::Chain;
        let g = Gemm::new("serve_gemm", "cli", 64, 64, 64);
        let chain = Chain { layers: vec![g] };
        let w = elem.sample_words(&mut rng, 64 * 64);
        let pid = server.register_chain_elem(&chain, vec![w], elem)?;
        eprintln!("single-GEMM session {pid:?} over {elem}");
        for id in 0..requests as u64 {
            tx.send(Request::for_program_words(id, pid, 64, elem.sample_words(&mut rng, 64 * 64)))?;
        }
    }
    let mut served = 0;
    let mut failed = 0;
    let mut lat = Vec::new();
    while served + failed < requests {
        let r = rx.recv()?;
        if let Some(e) = r.error {
            eprintln!("request {} failed: {e}", r.id);
            failed += 1;
        } else {
            lat.push(r.service_us);
            served += 1;
        }
    }
    drop(tx);
    let stats = h.join().map_err(|_| anyhow::anyhow!("server panicked"))?;
    anyhow::ensure!(failed == 0, "{failed}/{requests} requests failed");
    let wall_us = wall.elapsed().as_secs_f64() * 1e6;
    println!(
        "served {} requests on '{}' in {:.1} ms: p50 {:.1} µs, p99 {:.1} µs, {:.0} req/s, {} batches (max {})",
        stats.served,
        backend,
        wall_us / 1e3,
        crate::util::percentile(&lat, 50.0),
        crate::util::percentile(&lat, 99.0),
        stats.throughput_per_s(wall_us),
        stats.batches,
        stats.max_batch,
    );
    if sopts.devices > 1 {
        println!("{}", server.fleet().report(wall_us).render());
    }
    Ok(())
}

/// `minisa serve-model` — the compile-once/serve-many path: register a
/// model chain as a program session, then stream activation-only requests
/// at it. `--dims k0,k1,...` sets the feature ladder (default: a small MLP;
/// `--gpt` uses the Tab. IV GPT-oss MLP slice), `--m` the rows per request.
pub fn cmd_serve_model(args: &Args) -> anyhow::Result<()> {
    use crate::coordinator::serve::{spawn_with_options, Request};
    use crate::mapper::chain::Chain;

    let cfg = configs(args).into_iter().next().unwrap_or_else(|| ArchConfig::paper(16, 64));
    let m = args.usize_flag("m", 16);
    let requests = args.usize_flag("requests", 32);
    let dims: Vec<usize> = if args.bool_flag("gpt") {
        workloads::gpt_oss_mlp_dims()
    } else {
        let spec = args.str_flag("dims", "256,512,256");
        let parsed: Result<Vec<usize>, _> = spec.split(',').map(|t| t.trim().parse()).collect();
        parsed.map_err(|e| anyhow::anyhow!("--dims '{spec}': {e}"))?
    };
    anyhow::ensure!(dims.len() >= 2, "--dims needs at least two widths");
    let chain = Chain::mlp("serve_model", m, &dims);
    let elem = elem_flag(args, ElemType::F32)?;

    let sopts = server_options(args);
    let executor = serving_executor(args);
    let backend = executor.name().to_string();
    let (tx, rx, h, server) = spawn_with_options(&cfg, executor, sopts);
    let mut rng = crate::util::Lcg::new(23);
    let pid = if elem == ElemType::F32 {
        let weights: Vec<Vec<f32>> =
            chain.layers.iter().map(|g| rng.f32_matrix(g.k, g.n)).collect();
        server.register_chain(&chain, weights)?
    } else {
        let weights: Vec<Vec<u64>> =
            chain.layers.iter().map(|g| elem.sample_words(&mut rng, g.k * g.n)).collect();
        server.register_chain_elem(&chain, weights, elem)?
    };
    let prog = server.program(pid).expect("just registered");
    println!(
        "program {:?} over {}: {} layers, modeled {:.0} cycles/pass, fused trace {} B vs {} B \
         standalone ({} SetIVNLayout elided, §IV-G2), {} wave plans precompiled",
        pid,
        elem,
        prog.layer_count(),
        prog.total_cycles,
        prog.fused_bytes,
        prog.standalone_bytes,
        prog.elided,
        prog.plan_count(),
    );

    let wall = std::time::Instant::now();
    for id in 0..requests as u64 {
        if elem == ElemType::F32 {
            tx.send(Request::for_program(id, pid, m, rng.f32_matrix(m, dims[0])))?;
        } else {
            tx.send(Request::for_program_words(
                id,
                pid,
                m,
                elem.sample_words(&mut rng, m * dims[0]),
            ))?;
        }
    }
    let mut lat = Vec::new();
    for _ in 0..requests {
        let r = rx.recv()?;
        anyhow::ensure!(r.error.is_none(), "request {}: {}", r.id, r.error.unwrap_or_default());
        lat.push(r.service_us);
    }
    drop(tx);
    let stats = h.join().map_err(|_| anyhow::anyhow!("server panicked"))?;
    let wall_us = wall.elapsed().as_secs_f64() * 1e6;
    println!(
        "served {} program requests on '{}' in {:.1} ms: p50 {:.1} µs, p99 {:.1} µs, \
         {:.0} req/s, {} batches (max {}), {} chain compile(s)",
        stats.program_served,
        backend,
        wall_us / 1e3,
        crate::util::percentile(&lat, 50.0),
        crate::util::percentile(&lat, 99.0),
        stats.throughput_per_s(wall_us),
        stats.batches,
        stats.max_batch,
        stats.program_compiles,
    );
    if sopts.devices > 1 {
        let report = server.fleet().report(wall_us);
        anyhow::ensure!(
            report.plan_compiles() == 0,
            "fleet serving compiled plans at runtime (expected zero)"
        );
        println!("{}", report.render());
    }
    Ok(())
}

pub fn usage() -> &'static str {
    "MINISA / FEATHER+ toolchain (paper reproduction)\n\
     \n\
     USAGE: minisa <command> [flags]\n\
     \n\
     COMMANDS\n\
       evaluate   (mapping, layout) co-search, MINISA vs micro — Fig. 10/12\n\
                  [--small] [--jobs N] [--csv file] [--ah N --aw N|same] [--out dir]\n\
       compare    instruction overhead + stalls on the Table I workload\n\
       analyze    FEATHER+ vs RTX5090 vs TPUv6e-8 — Fig. 11 [--breakdown]\n\
       search     single-shape mapper search [--m --k --n --ah --aw]\n\
                  [--layout-constrained]\n\
       trace      dump the lowered MINISA program [--m --k --n --validate]\n\
                  [--elem E] (validate under that element backend)\n\
       run        compile + execute a Program end-to-end, verified against\n\
                  the naive reference [--elem E] [--devices N]\n\
                  [--suite <name> [--scale N] | --ntt N | --dims k0,k1,... --m N]\n\
       bitwidth   Table V ISA bitwidths\n\
       area       Table VI area/power model\n\
       workloads  dump the 50-workload suite CSV [--small]\n\
       serve      serving loop, ad-hoc single-GEMM requests [--requests N]\n\
                  [--elem E] (non-f32: a single-GEMM element session)\n\
                  [--devices N --shard-min-rows R --max-batch B]\n\
       serve-model  compile-once/serve-many model sessions (§IV-G programs)\n\
                  [--dims k0,k1,... | --gpt] [--m N] [--requests N] [--elem E]\n\
                  [--devices N --shard-min-rows R --max-batch B]\n\
       animate    cycle-by-cycle NEST/BIRRD/OB animation [--m --k --n --waves]\n\
     \n\
     --elem E selects the element arithmetic backend:\n\
       i32 (saturating, default for run), f32 (default for serving),\n\
       babybear / goldilocks / pallas (Montgomery prime fields — the FHE/ZKP\n\
       NTT number systems; see EXPERIMENTS.md §Field arithmetic)\n\
     --devices N shards work across a simulated N-device fleet (request-\n\
       parallel work stealing + tile-parallel M-row sharding, bit-identical\n\
       to one device; see EXPERIMENTS.md §Fleet serving)\n"
}

/// Dispatch. Returns process exit code.
pub fn run(argv: &[String]) -> i32 {
    let args = Args::parse(argv);
    let r = match args.cmd.as_str() {
        "evaluate" => cmd_evaluate(&args),
        "compare" => cmd_compare(&args),
        "analyze" => cmd_analyze(&args),
        "breakdown" => cmd_breakdown(&args),
        "search" => cmd_search(&args),
        "trace" => cmd_trace(&args),
        "run" => cmd_run(&args),
        "bitwidth" => cmd_bitwidth(&args),
        "area" => cmd_area(&args),
        "workloads" => cmd_workloads(&args),
        "animate" => {
            let m = args.usize_flag("m", 8);
            let k = args.usize_flag("k", 8);
            let n = args.usize_flag("n", 8);
            let cfg = configs(&args).into_iter().next().unwrap_or_else(|| ArchConfig::paper(4, 4));
            let g = Gemm::new("animate", "cli", m, k, n);
            match animate::animate(&cfg, &g, args.usize_flag("waves", 4)) {
                Ok(s) => {
                    println!("{s}");
                    Ok(())
                }
                Err(e) => Err(anyhow::anyhow!(e)),
            }
        }
        "serve" => cmd_serve(&args),
        "serve-model" => cmd_serve_model(&args),
        "help" | "" => {
            println!("{}", usage());
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n{}", usage());
            return 2;
        }
    };
    match r {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_flags() {
        let argv: Vec<String> =
            ["search", "--m", "128", "--k=40", "--fast", "--ah", "4", "--aw", "16"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let a = Args::parse(&argv);
        assert_eq!(a.cmd, "search");
        assert_eq!(a.usize_flag("m", 0), 128);
        assert_eq!(a.usize_flag("k", 0), 40);
        assert_eq!(a.usize_flag("ah", 0), 4);
    }

    #[test]
    fn bitwidth_and_area_commands_run() {
        assert!(cmd_bitwidth(&Args::default()).is_ok());
        assert!(cmd_area(&Args::default()).is_ok());
    }

    #[test]
    fn search_command_runs() {
        let argv: Vec<String> = ["search", "--m", "64", "--k", "40", "--n", "24", "--ah", "4", "--aw", "4", "--fast"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert_eq!(run(&argv), 0);
    }

    #[test]
    fn unknown_command_fails() {
        let argv = vec!["frobnicate".to_string()];
        assert_eq!(run(&argv), 2);
    }

    #[test]
    fn serve_model_command_runs() {
        let argv: Vec<String> = [
            "serve-model", "--dims", "16,24,16", "--m", "4", "--requests", "6", "--ah", "4",
            "--aw", "4",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(run(&argv), 0);
    }

    #[test]
    fn run_command_executes_field_ntt() {
        let argv: Vec<String> = [
            "run", "--ntt", "16", "--m", "2", "--elem", "babybear", "--ah", "4", "--aw", "4",
            "--fast",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(run(&argv), 0);
    }

    #[test]
    fn run_command_executes_scaled_suite_entry() {
        let argv: Vec<String> = [
            "run", "--suite", "zkp_ntt_8192", "--scale", "32", "--ah", "4", "--aw", "4", "--fast",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(run(&argv), 0);
    }

    #[test]
    fn run_command_executes_i32_chain() {
        let argv: Vec<String> =
            ["run", "--dims", "8,12,8", "--m", "4", "--ah", "4", "--aw", "4", "--fast"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        assert_eq!(run(&argv), 0);
    }

    #[test]
    fn run_rejects_unknown_elem() {
        let argv: Vec<String> =
            ["run", "--ntt", "16", "--elem", "i64", "--ah", "4", "--aw", "4", "--fast"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        assert_eq!(run(&argv), 1);
    }

    #[test]
    fn serve_model_command_runs_over_field_elem() {
        let argv: Vec<String> = [
            "serve-model", "--dims", "8,12,8", "--m", "2", "--requests", "4", "--elem",
            "goldilocks", "--ah", "4", "--aw", "4",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(run(&argv), 0);
    }

    #[test]
    fn serve_model_command_runs_on_a_fleet() {
        let argv: Vec<String> = [
            "serve-model", "--dims", "16,24,16", "--m", "4", "--requests", "8", "--ah", "4",
            "--aw", "4", "--devices", "3", "--shard-min-rows", "1",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(run(&argv), 0);
    }

    #[test]
    fn run_command_verifies_fleet_against_single_device() {
        let argv: Vec<String> = [
            "run", "--ntt", "16", "--m", "4", "--elem", "goldilocks", "--ah", "4", "--aw", "4",
            "--fast", "--devices", "3", "--shard-min-rows", "1",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(run(&argv), 0);
    }

    #[test]
    fn serve_model_rejects_bad_dims() {
        let argv: Vec<String> = ["serve-model", "--dims", "16", "--ah", "4", "--aw", "4"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert_eq!(run(&argv), 1);
    }
}
