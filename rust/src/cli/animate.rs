//! `minisa animate` — the artifact's "GUI with cycle-by-cycle animation"
//! (Appendix A item 3), rendered as terminal frames: for a small tile it
//! shows, wave by wave, which streamed VN enters each NEST column, the
//! stationary VN held by every PE, the psums leaving through BIRRD and the
//! output-buffer accumulation state.

use crate::arch::config::ArchConfig;
use crate::mapper::lower::{lower_gemm, StagedOperand};
use crate::mapper::search::{search, MapperOptions};
use crate::mapping::{Dataflow, MappingCfg, StreamCfg};
use crate::workloads::Gemm;

/// Render the animation; returns the frames as one string (printed by the
/// CLI; kept pure for tests).
pub fn animate(cfg: &ArchConfig, g: &Gemm, max_waves: usize) -> Result<String, String> {
    let opts = MapperOptions { full_layout_search: false, ..Default::default() };
    let d = search(cfg, g, &opts).ok_or("no feasible mapping")?;
    let prog = lower_gemm(cfg, g, &d.choice, d.i_order, d.w_order, d.o_order);
    let mut out = String::new();
    out.push_str(&format!(
        "animating {g} on FEATHER+ {} — dataflow {:?}, VN={}\n\n",
        cfg.name(),
        d.choice.df,
        d.choice.vn
    ));
    // Find the first ExecuteMapping/ExecuteStreaming pair.
    let mut em: Option<MappingCfg> = None;
    let mut es: Option<StreamCfg> = None;
    for inst in &prog.trace.insts {
        match inst {
            crate::isa::inst::Inst::ExecuteMapping(m) => em = Some(*m),
            crate::isa::inst::Inst::ExecuteStreaming(s) => {
                es = Some(*s);
                break;
            }
            _ => {}
        }
    }
    let (em, es) = (em.ok_or("no ExecuteMapping in trace")?, es.ok_or("no ExecuteStreaming")?);
    let sta_label = match es.df {
        Dataflow::WoS => "W",
        Dataflow::IoS => "I",
    };
    let str_label = match es.df {
        Dataflow::WoS => "I",
        Dataflow::IoS => "W",
    };
    out.push_str("PE array (stationary VN per PE, rows = a_h):\n");
    let active = es.vn_size.min(cfg.ah);
    for a_h in 0..cfg.ah {
        out.push_str(&format!("  a_h={a_h}: "));
        for a_w in 0..cfg.aw.min(8) {
            if a_h < active {
                let (r, c) = em.stationary_vn(a_h, a_w);
                out.push_str(&format!("{sta_label}({r},{c:>2}) "));
            } else {
                out.push_str(" (idle)  ");
            }
        }
        if cfg.aw > 8 {
            out.push_str("…");
        }
        out.push('\n');
    }
    out.push_str(&format!(
        "\nBIRRD: {} stages × {} switches; OB: {} banks\n",
        cfg.birrd_stages(),
        cfg.aw / 2,
        cfg.aw
    ));
    out.push_str(&format!(
        "staging: {} regions ({} streamed / {} stationary)\n\n",
        prog.staging.len(),
        prog.staging.iter().filter(|s| s.operand == StagedOperand::Streamed).count(),
        prog.staging.iter().filter(|s| s.operand == StagedOperand::Stationary).count(),
    ));
    for t in 0..es.t.min(max_waves) {
        out.push_str(&format!("— wave {t} (cycles {}..{}) —\n", t * es.vn_size, (t + 1) * es.vn_size));
        out.push_str("  streamed into column tops: ");
        for a_w in 0..cfg.aw.min(8) {
            let (m, j) = es.streamed_vn(&em, a_w, t);
            out.push_str(&format!("{str_label}({m:>2},{j}) "));
        }
        if cfg.aw > 8 {
            out.push_str("…");
        }
        out.push('\n');
        // Show where column 0's psums land.
        let mut dests = Vec::new();
        for a_h in 0..active.min(4) {
            let (m, _j) = es.streamed_vn(&em, 0, t);
            let (_r, c) = em.stationary_vn(a_h, 0);
            let (p, q) = match es.df {
                Dataflow::WoS => (m, c),
                Dataflow::IoS => (c, m),
            };
            dests.push(format!("O({p},{q})"));
        }
        out.push_str(&format!("  column-0 psums → BIRRD → OB slots: {}\n", dests.join(" ")));
    }
    if es.t > max_waves {
        out.push_str(&format!("… {} more waves (T = {})\n", es.t - max_waves, es.t));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn animation_renders_for_small_tile() {
        let cfg = ArchConfig::paper(4, 4);
        let g = Gemm::new("anim", "demo", 8, 8, 8);
        let s = animate(&cfg, &g, 3).unwrap();
        assert!(s.contains("PE array"));
        assert!(s.contains("wave 0"));
        assert!(s.contains("BIRRD: 3 stages"));
        assert!(s.contains("OB slots"));
    }

    #[test]
    fn animation_caps_waves() {
        let cfg = ArchConfig::paper(4, 4);
        let g = Gemm::new("anim", "demo", 64, 8, 8);
        let s = animate(&cfg, &g, 2).unwrap();
        assert!(s.contains("more waves"));
        assert!(!s.contains("wave 2 "));
    }
}
