//! # MINISA — Minimal ISA for the FEATHER+ reconfigurable inference accelerator
//!
//! Full-system reproduction of *MINISA: Minimal Instruction Set Architecture
//! for Next-gen Reconfigurable Inference Accelerator* (CS.AR 2026): the
//! eight-instruction VN-granularity ISA, the FEATHER+ architectural model,
//! a functional trace simulator, a cycle-level 5-engine performance model,
//! the micro-instruction baseline, the FEATHER+ mapper (mapping-first /
//! layout-second co-search), the 50-workload evaluation suite, GPU/TPU
//! baseline models and a PJRT runtime that executes AOT-compiled JAX/Pallas
//! GEMM oracles for numerical cross-validation.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured results.

pub mod arch;
pub mod arith;
pub mod artifact;
pub mod functional;
pub mod isa;
pub mod layout;
pub mod mapping;
pub mod util;
pub mod workloads;
pub mod mapper;
pub mod microinst;
pub mod obs;
pub mod program;
pub mod registry;
pub mod perf;
pub mod baselines;
pub mod coordinator;
pub mod report;
pub mod cli;
pub mod runtime;
