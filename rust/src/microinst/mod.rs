//! Micro-instruction baseline cost model (§III-D, Table I).
//!
//! The baseline programming model configures FEATHER+ with explicit,
//! fine-grained control: every BIRRD switch, every buffer-bank address
//! generator and every PE's local control word is delivered from off-chip
//! through the 9 B/cycle instruction interface. Per *wave* (one streamed VN
//! traversing a column, `vn` cycles), the fetch unit must supply:
//!
//! * per-PE control words (register select, accumulate/forward, VN bounds),
//! * 2 bits per BIRRD 2×2 switch (pass/swap/add-left/add-right),
//! * one write address per OB bank,
//! * one read address per streaming-buffer bank (multi-bank in the
//!   baseline; FEATHER+'s single-bank simplification is a MINISA-side win),
//!
//! plus a per-invocation stationary staging descriptor (per-PE source
//! addresses). These component counts grow with AH·AW and AW·log AW, which
//! is why fetch stalls explode at scale (0% below 8×8 → ~97% at 16×256).

use crate::arch::config::ArchConfig;
use crate::util::clog2;

/// Per-PE micro-control word width in bits (MAERI/FEATHER-class designs:
/// register-bank select, accumulate vs forward, VN-boundary flags).
pub const PE_CTRL_BITS: u64 = 6;
/// Bits per BIRRD 2×2 switch state.
pub const BIRRD_SW_BITS: u64 = 2;

/// Byte/bit accounting for the micro-instruction baseline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MicroCost {
    /// Control bits fetched per wave (vn-cycle streaming step).
    pub bits_per_wave: u64,
    /// Control bits fetched once per NEST invocation (stationary staging).
    pub bits_per_invocation: u64,
    /// Derived: average control bits per compute cycle at full streaming.
    pub bits_per_cycle: f64,
}

/// Component breakdown of the per-wave control stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MicroBreakdown {
    pub pe_ctrl_bits: u64,
    pub birrd_bits: u64,
    pub ob_addr_bits: u64,
    pub str_addr_bits: u64,
}

impl MicroBreakdown {
    pub fn total(&self) -> u64 {
        self.pe_ctrl_bits + self.birrd_bits + self.ob_addr_bits + self.str_addr_bits
    }
}

/// Per-wave control-bit breakdown for a configuration.
pub fn breakdown(cfg: &ArchConfig) -> MicroBreakdown {
    MicroBreakdown {
        pe_ctrl_bits: (cfg.ah * cfg.aw) as u64 * PE_CTRL_BITS,
        birrd_bits: cfg.birrd_switches() as u64 * BIRRD_SW_BITS,
        ob_addr_bits: cfg.aw as u64 * clog2(cfg.d_ob()) as u64,
        str_addr_bits: cfg.aw as u64 * clog2(cfg.d_str()) as u64,
    }
}

/// Full micro-instruction cost for a configuration with a given VN size.
pub fn cost(cfg: &ArchConfig, vn_size: usize) -> MicroCost {
    let per_wave = breakdown(cfg).total();
    // Stationary staging: a source address per PE register bank.
    let per_invocation = (cfg.ah * cfg.aw) as u64 * clog2(cfg.d_sta()) as u64;
    MicroCost {
        bits_per_wave: per_wave,
        bits_per_invocation: per_invocation,
        bits_per_cycle: per_wave as f64 / vn_size.max(1) as f64,
    }
}

/// Total baseline instruction bits for a schedule of `waves` streaming waves
/// and `invocations` NEST invocations.
pub fn total_bits(cfg: &ArchConfig, waves: u64, invocations: u64, vn_size: usize) -> u64 {
    let c = cost(cfg, vn_size);
    waves * c.bits_per_wave + invocations * c.bits_per_invocation
}

/// Quick analytic stall estimate (used by tests; the full pipeline model in
/// `perf` produces the reported numbers): the fetch engine sustains
/// `instr_bw` bytes/cycle while compute consumes one wave per `vn` cycles.
pub fn stall_fraction_estimate(cfg: &ArchConfig, vn_size: usize) -> f64 {
    let bits_per_cycle = cost(cfg, vn_size).bits_per_cycle;
    let sustain = cfg.instr_bw * 8.0; // bits per cycle the interface delivers
    if bits_per_cycle <= sustain {
        0.0
    } else {
        1.0 - sustain / bits_per_cycle
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_arrays_do_not_stall() {
        // Table I: 4×4 and 8×8 show 0% fetch stall.
        assert_eq!(stall_fraction_estimate(&ArchConfig::paper(4, 4), 4), 0.0);
        let s88 = stall_fraction_estimate(&ArchConfig::paper(8, 8), 8);
        assert!(s88 < 0.25, "8x8 stall {s88}");
    }

    #[test]
    fn large_arrays_stall_like_table_i() {
        // Table I: 16×256 → 96.9%. Model must land within a few points.
        let s = stall_fraction_estimate(&ArchConfig::paper(16, 256), 16);
        assert!((0.93..=0.99).contains(&s), "16x256 stall {s}");
        // 8×128 → 90.4%.
        let s = stall_fraction_estimate(&ArchConfig::paper(8, 128), 8);
        assert!((0.85..=0.97).contains(&s), "8x128 stall {s}");
        // 4×64 → 75.3% (model overshoots somewhat; same regime).
        let s = stall_fraction_estimate(&ArchConfig::paper(4, 64), 4);
        assert!((0.6..=0.97).contains(&s), "4x64 stall {s}");
    }

    #[test]
    fn stall_monotone_within_row_height() {
        // Wider arrays at fixed AH stall more.
        for ah in [4usize, 8, 16] {
            let mut prev = -1.0f64;
            for aw in [ah, 4 * ah, 16 * ah] {
                let s = stall_fraction_estimate(&ArchConfig::paper(ah, aw), ah);
                assert!(s >= prev, "AH={ah} AW={aw}: {s} < {prev}");
                prev = s;
            }
        }
    }

    #[test]
    fn breakdown_components_positive_and_sum() {
        let cfg = ArchConfig::paper(16, 64);
        let b = breakdown(&cfg);
        assert!(b.pe_ctrl_bits > 0 && b.birrd_bits > 0);
        assert!(b.ob_addr_bits > 0 && b.str_addr_bits > 0);
        assert_eq!(b.total(), cost(&cfg, 16).bits_per_wave);
    }

    #[test]
    fn pe_control_dominates_at_scale() {
        // §III-D: control state scales with the array; at 16×256 the per-PE
        // term is the largest component.
        let b = breakdown(&ArchConfig::paper(16, 256));
        assert!(b.pe_ctrl_bits > b.birrd_bits);
        assert!(b.pe_ctrl_bits > b.ob_addr_bits + b.str_addr_bits);
    }

    #[test]
    fn total_bits_linear_in_waves() {
        let cfg = ArchConfig::paper(8, 32);
        let a = total_bits(&cfg, 100, 1, 8);
        let b = total_bits(&cfg, 200, 1, 8);
        let per_wave = cost(&cfg, 8).bits_per_wave;
        assert_eq!(b - a, 100 * per_wave);
    }
}
