//! BIRRD — the multistage reordering-in-reduction network (§III-A).
//!
//! BIRRD sits between the NEST column outputs and the output buffer. In one
//! traversal it (a) spatially reduces psums from PE columns that target the
//! same output element and (b) reorders surviving values to arbitrary
//! output-buffer banks. Topologically it is a Benes-class network:
//! `2·log2(AW) − 1` stages of `AW/2` 2×2 switches, which is rearrangeably
//! non-blocking — any output permutation is routable.
//!
//! The functional simulator uses `reduce_and_route` (semantic model);
//! `Benes::route_permutation` implements the classic looping algorithm so
//! tests can verify the rearrangeability claim the micro-instruction cost
//! model depends on (every switch = 2 control bits per cycle).

use crate::util::is_pow2;

/// Semantic result of one BIRRD traversal.
#[derive(Debug, Clone, PartialEq)]
pub struct ReduceResult {
    /// (bank, value) pairs written to the output buffer this cycle.
    pub writes: Vec<(usize, i64)>,
    /// Number of pairwise additions performed in-network.
    pub adds: usize,
}

/// Benes network over `n = 2^k` ports (the BIRRD topology skeleton).
#[derive(Debug, Clone)]
pub struct Benes {
    pub n: usize,
}

impl Benes {
    pub fn new(n: usize) -> Self {
        assert!(is_pow2(n) && n >= 2, "Benes needs power-of-two ports");
        Self { n }
    }

    pub fn stages(&self) -> usize {
        2 * (self.n.trailing_zeros() as usize) - 1
    }

    pub fn switches(&self) -> usize {
        self.stages() * self.n / 2
    }

    /// Route a permutation with the recursive looping algorithm.
    /// `perm[i] = o` sends input `i` to output `o`. Returns per-stage swap
    /// bits (stage-major; within a stage, blocks upper-first).
    /// Panics if `perm` is not a permutation.
    pub fn route_permutation(&self, perm: &[usize]) -> Vec<Vec<bool>> {
        assert_eq!(perm.len(), self.n);
        let mut seen = vec![false; self.n];
        for &p in perm {
            assert!(p < self.n && !seen[p], "not a permutation");
            seen[p] = true;
        }
        let mut plan: Vec<Vec<bool>> = vec![Vec::new(); self.stages()];
        route_rec(perm, 0, self.stages(), &mut plan);
        plan
    }

    /// Apply a routing plan to values; `out[perm[i]] == values[i]`.
    pub fn apply(&self, plan: &[Vec<bool>], values: &[i64]) -> Vec<i64> {
        assert_eq!(values.len(), self.n);
        let total = self.stages();
        let mut v = values.to_vec();
        for (s, swaps) in plan.iter().enumerate() {
            // Benes stage "level": 0,1,…,k-1,…,1,0 — block size n>>level.
            let level = s.min(total - 1 - s);
            let half = self.n >> (level + 1);
            let blocks = 1usize << level;
            let mut idx = 0;
            for b in 0..blocks {
                let base = b * (half * 2);
                for i in 0..half {
                    if swaps[idx] {
                        v.swap(base + i, base + i + half);
                    }
                    idx += 1;
                }
            }
            debug_assert_eq!(idx, swaps.len(), "stage {s} switch count");
        }
        v
    }
}

#[inline]
fn pair(i: usize, half: usize) -> usize {
    if i < half { i + half } else { i - half }
}

/// Recursive looping algorithm. Emits this sub-network's first stage at
/// `plan[depth]`, its last at `plan[total-1-depth]`, and recurses (upper
/// sub-network before lower, so blocks order left-to-right per stage).
fn route_rec(perm: &[usize], depth: usize, total: usize, plan: &mut [Vec<bool>]) {
    let n = perm.len();
    if n == 2 {
        plan[depth].push(perm[0] == 1);
        return;
    }
    let half = n / 2;
    let mut inv = vec![0usize; n];
    for (i, &p) in perm.iter().enumerate() {
        inv[p] = i;
    }
    // 2-color inputs: paired inputs differ; inputs feeding paired outputs
    // differ. The constraint graph is a union of even cycles, so the greedy
    // cycle walk below always succeeds.
    const UNSET: usize = usize::MAX;
    let mut color = vec![UNSET; n];
    for start in 0..n {
        if color[start] != UNSET {
            continue;
        }
        let mut i = start;
        let c = 0usize;
        loop {
            color[i] = c;
            color[pair(i, half)] = 1 - c;
            // pair(i) (color 1−c) produces output perm[pair(i)] in subnet
            // 1−c; its partner output must come from subnet c, i.e. the
            // input feeding it takes color c.
            let j = inv[pair(perm[pair(i, half)], half)];
            if color[j] != UNSET {
                break;
            }
            i = j; // c unchanged
        }
    }
    // First stage: switch i crosses iff input i goes to the lower subnet.
    for i in 0..half {
        plan[depth].push(color[i] == 1);
    }
    // Last stage: switch o crosses iff output o is produced by the lower
    // subnet.
    let mut last = Vec::with_capacity(half);
    for o in 0..half {
        last.push(color[inv[o]] == 1);
    }
    // Sub-permutations.
    let mut upper = vec![0usize; half];
    let mut lower = vec![0usize; half];
    for i in 0..n {
        let sub_in = i % half;
        let sub_out = perm[i] % half;
        if color[i] == 0 {
            upper[sub_in] = sub_out;
        } else {
            lower[sub_in] = sub_out;
        }
    }
    route_rec(&upper, depth + 1, total, plan);
    route_rec(&lower, depth + 1, total, plan);
    plan[total - 1 - depth].extend(last);
}

/// Semantic BIRRD traversal used by the functional simulator: psums from the
/// AW column outputs carry their destination OB bank; values sharing a bank
/// reduce in-network (spatial reduction), then one write per bank issues.
/// Returns `None` only for out-of-range banks — BIRRD is rearrangeable, so
/// any ≤AW-bank pattern routes.
pub fn reduce_and_route(dests: &[(usize, i64)], aw: usize) -> Option<ReduceResult> {
    let mut by_bank: std::collections::BTreeMap<usize, i64> = std::collections::BTreeMap::new();
    for &(bank, v) in dests {
        if bank >= aw {
            return None;
        }
        *by_bank.entry(bank).or_insert(0) += v;
    }
    let adds = dests.len() - by_bank.len();
    Some(ReduceResult { writes: by_bank.into_iter().collect(), adds })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::Lcg;

    #[test]
    fn benes_counts() {
        assert_eq!(Benes::new(2).stages(), 1);
        assert_eq!(Benes::new(4).stages(), 3);
        assert_eq!(Benes::new(8).stages(), 5);
        assert_eq!(Benes::new(256).stages(), 15);
        assert_eq!(Benes::new(4).switches(), 6);
        assert_eq!(Benes::new(256).switches(), 15 * 128);
    }

    fn check_perm(b: &Benes, perm: &[usize]) {
        let plan = b.route_permutation(perm);
        assert_eq!(plan.len(), b.stages());
        let vals: Vec<i64> = (0..b.n as i64).map(|x| x * 10 + 1).collect();
        let out = b.apply(&plan, &vals);
        for (i, &p) in perm.iter().enumerate() {
            assert_eq!(out[p], vals[i], "input {i} → output {p} (perm {perm:?})");
        }
    }

    #[test]
    fn identity_and_reverse_route() {
        for n in [2usize, 4, 8, 16, 32] {
            let b = Benes::new(n);
            check_perm(&b, &(0..n).collect::<Vec<_>>());
            check_perm(&b, &(0..n).rev().collect::<Vec<_>>());
        }
    }

    #[test]
    fn all_permutations_of_4_route() {
        // Exhaustive rearrangeability check at n=4 (24 perms).
        let b = Benes::new(4);
        let mut perm = [0usize, 1, 2, 3];
        // Heap's algorithm, iterative.
        let mut c = [0usize; 4];
        check_perm(&b, &perm);
        let mut i = 0;
        while i < 4 {
            if c[i] < i {
                if i % 2 == 0 {
                    perm.swap(0, i);
                } else {
                    perm.swap(c[i], i);
                }
                check_perm(&b, &perm);
                c[i] += 1;
                i = 0;
            } else {
                c[i] = 0;
                i += 1;
            }
        }
    }

    #[test]
    fn random_permutations_route() {
        // Rearrangeability property: every permutation is realizable.
        forall("benes-rearrangeable", 150, |g| {
            let n = g.pow2(1, 6); // 2..64 ports
            let b = Benes::new(n);
            let mut perm: Vec<usize> = (0..n).collect();
            for i in (1..n).rev() {
                let j = g.usize(0, i);
                perm.swap(i, j);
            }
            check_perm(&b, &perm);
        });
    }

    #[test]
    fn switch_count_matches_plan() {
        let mut rng = Lcg::new(3);
        for n in [4usize, 8, 16, 32, 256] {
            let b = Benes::new(n);
            let mut perm: Vec<usize> = (0..n).collect();
            for i in (1..n).rev() {
                let j = rng.below(i + 1);
                perm.swap(i, j);
            }
            let plan = b.route_permutation(&perm);
            let total: usize = plan.iter().map(|s| s.len()).sum();
            assert_eq!(total, b.switches());
        }
    }

    #[test]
    fn reduce_and_route_sums_shared_banks() {
        let r = reduce_and_route(&[(0, 5), (0, 7), (2, 1)], 4).unwrap();
        assert_eq!(r.writes, vec![(0, 12), (2, 1)]);
        assert_eq!(r.adds, 1);
    }

    #[test]
    fn reduce_and_route_rejects_oob_bank() {
        assert!(reduce_and_route(&[(4, 1)], 4).is_none());
    }

    #[test]
    fn reduce_preserves_total() {
        forall("birrd-reduce-conserves-sum", 100, |g| {
            let aw = g.pow2(1, 4);
            let n = g.usize(1, 2 * aw);
            let dests: Vec<(usize, i64)> =
                (0..n).map(|_| (g.usize(0, aw - 1), g.usize(0, 100) as i64 - 50)).collect();
            let total: i64 = dests.iter().map(|d| d.1).sum();
            let r = reduce_and_route(&dests, aw).unwrap();
            assert_eq!(r.writes.iter().map(|w| w.1).sum::<i64>(), total);
        });
    }
}
