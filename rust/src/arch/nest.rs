//! NEST — the column-wise independent AH × AW PE array (§III-A).
//!
//! Each PE holds `2 × AH` local registers (double-buffered so the next
//! tile's stationary VN loads while the current one computes) and performs
//! an AH-element dot product between its stationary registers and the
//! streaming operand pipelining top→bottom through its column.
//!
//! This module is the *functional* PE model used by the trace simulator;
//! timing lives in `perf`.

/// One processing element: double-buffered stationary registers + MAC.
#[derive(Debug, Clone)]
pub struct Pe {
    /// Two register banks of AH elements each.
    regs: [Vec<i32>; 2],
    /// Bank used by compute; `1 - active` is the load target.
    active: usize,
}

impl Pe {
    pub fn new(ah: usize) -> Self {
        Self { regs: [vec![0; ah], vec![0; ah]], active: 0 }
    }

    /// Load a stationary VN into the shadow bank.
    pub fn load_shadow(&mut self, vn: &[i32]) {
        let shadow = 1 - self.active;
        self.regs[shadow][..vn.len()].copy_from_slice(vn);
        for v in self.regs[shadow][vn.len()..].iter_mut() {
            *v = 0;
        }
    }

    /// Swap shadow → active (tile boundary; hides load latency, §III-A).
    pub fn swap(&mut self) {
        self.active = 1 - self.active;
    }

    /// AH-element dot product with the streamed VN (Constraint 1: all AH
    /// registers participate in one dot product).
    pub fn dot(&self, streamed: &[i32]) -> i64 {
        self.regs[self.active]
            .iter()
            .zip(streamed)
            .map(|(&a, &b)| a as i64 * b as i64)
            .sum()
    }

    pub fn active_regs(&self) -> &[i32] {
        &self.regs[self.active]
    }
}

/// The PE array. Columns are fully independent (Constraint 2: the streaming
/// operand is reused by every PE of a column; columns never interact except
/// through BIRRD reduction).
#[derive(Debug, Clone)]
pub struct Nest {
    pub ah: usize,
    pub aw: usize,
    pes: Vec<Pe>,
}

impl Nest {
    pub fn new(ah: usize, aw: usize) -> Self {
        Self { ah, aw, pes: (0..ah * aw).map(|_| Pe::new(ah)).collect() }
    }

    pub fn pe(&self, a_h: usize, a_w: usize) -> &Pe {
        &self.pes[a_h * self.aw + a_w]
    }

    pub fn pe_mut(&mut self, a_h: usize, a_w: usize) -> &mut Pe {
        &mut self.pes[a_h * self.aw + a_w]
    }

    /// Swap all PEs' register banks (start of a new compute tile).
    pub fn swap_all(&mut self) {
        self.pes.iter_mut().for_each(Pe::swap);
    }

    /// One streaming step for a column: every PE row computes its dot
    /// product against the shared streamed VN, yielding AH psums
    /// (one per PE), bottom-of-column order.
    pub fn column_step(&self, a_w: usize, streamed: &[i32]) -> Vec<i64> {
        (0..self.ah).map(|a_h| self.pe(a_h, a_w).dot(streamed)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pe_dot_product() {
        let mut pe = Pe::new(4);
        pe.load_shadow(&[1, 2, 3, 4]);
        pe.swap();
        assert_eq!(pe.dot(&[1, 1, 1, 1]), 10);
        assert_eq!(pe.dot(&[0, 0, 0, 2]), 8);
    }

    #[test]
    fn double_buffering_isolation() {
        let mut pe = Pe::new(2);
        pe.load_shadow(&[5, 5]);
        pe.swap(); // active = [5,5]
        pe.load_shadow(&[9, 9]); // shadow load must not affect compute
        assert_eq!(pe.dot(&[1, 1]), 10);
        pe.swap();
        assert_eq!(pe.dot(&[1, 1]), 18);
    }

    #[test]
    fn shadow_load_zero_pads() {
        let mut pe = Pe::new(4);
        pe.load_shadow(&[7, 7, 7, 7]);
        pe.swap();
        pe.load_shadow(&[1]); // short VN → rest zeroed
        pe.swap();
        assert_eq!(pe.dot(&[1, 1, 1, 1]), 1);
    }

    #[test]
    fn column_step_independent_rows() {
        let mut nest = Nest::new(2, 2);
        nest.pe_mut(0, 0).load_shadow(&[1, 0]);
        nest.pe_mut(1, 0).load_shadow(&[0, 1]);
        nest.pe_mut(0, 1).load_shadow(&[2, 2]);
        nest.pe_mut(1, 1).load_shadow(&[3, 3]);
        nest.swap_all();
        assert_eq!(nest.column_step(0, &[10, 20]), vec![10, 20]);
        assert_eq!(nest.column_step(1, &[1, 1]), vec![4, 6]);
    }
}
