//! The Virtual Neuron (VN) abstraction (§IV-B).
//!
//! A VN is the minimal hardware dot-product atom: `vn_size ≤ AH` consecutive
//! elements of an operand along its reduction rank. Operand-specific VNs:
//!
//! * `I_VN(m, j)` — input row `m`, reduction tile `j` (rank J, size K)
//! * `W_VN(r, c)` — reduction tile `r` (rank K), output column `c` (rank N)
//! * `O_VN(r, c)` — next-layer reduction tile `r` over rank Q(=N), output
//!   row `c` (rank P = M)
//!
//! VNs falling (partially) outside tensor bounds are implicitly zero-padded
//! (§IV-C2), which the accessors here implement.

use crate::workloads::Gemm;
use crate::util::ceil_div;

/// Operand kinds an on-chip buffer can hold.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Operand {
    Input,
    Weight,
    Output,
}

/// A logical 2-D VN array view over a row-major matrix.
///
/// For weights (K×N): rows index the reduction tile `r = k/vn`, columns
/// index `n`. For inputs (M×K): the VN grid is transposed relative to the
/// matrix — rows index `m`, columns index `j = k/vn`; we normalize both to
/// the `(r, c)` convention used by the ISA: `r` = reduction tile, `c` =
/// non-reduction index.
#[derive(Debug, Clone)]
pub struct VnGrid {
    /// Reduction-rank length (K for weights, K for inputs, N for outputs).
    pub red_len: usize,
    /// Non-reduction rank length (N for weights, M for inputs/outputs).
    pub non_red_len: usize,
    /// VN length (≤ AH).
    pub vn_size: usize,
}

impl VnGrid {
    pub fn new(red_len: usize, non_red_len: usize, vn_size: usize) -> Self {
        assert!(vn_size > 0);
        Self { red_len, non_red_len, vn_size }
    }

    /// Weight-operand VN grid of a GEMM.
    pub fn weights(g: &Gemm, vn: usize) -> Self {
        Self::new(g.k, g.n, vn)
    }

    /// Input-operand VN grid of a GEMM.
    pub fn inputs(g: &Gemm, vn: usize) -> Self {
        Self::new(g.k, g.m, vn)
    }

    /// Output-operand VN grid of a GEMM (reduction rank = N = next layer J).
    pub fn outputs(g: &Gemm, vn: usize) -> Self {
        Self::new(g.n, g.m, vn)
    }

    /// Number of reduction tiles (`r` range).
    pub fn rows(&self) -> usize {
        ceil_div(self.red_len, self.vn_size)
    }

    /// `c` range.
    pub fn cols(&self) -> usize {
        self.non_red_len
    }

    /// Total VN count.
    pub fn count(&self) -> usize {
        self.rows() * self.cols()
    }

    /// Whether VN (r, c) overlaps the tensor at all.
    pub fn in_bounds(&self, r: usize, c: usize) -> bool {
        r < self.rows() && c < self.cols()
    }

    /// Elements of W_VN(r, c) from a row-major K×N matrix, zero-padded to
    /// `vn_size`. Element i is `W[r·vn + i, c]`.
    pub fn gather_weight<T: Copy + Default>(&self, w: &[T], r: usize, c: usize) -> Vec<T> {
        debug_assert_eq!(w.len(), self.red_len * self.non_red_len);
        let mut out = vec![T::default(); self.vn_size];
        if c >= self.non_red_len {
            return out;
        }
        for (i, o) in out.iter_mut().enumerate() {
            let k = r * self.vn_size + i;
            if k < self.red_len {
                *o = w[k * self.non_red_len + c];
            }
        }
        out
    }

    /// Elements of I_VN(m=c, j=r) from a row-major M×K matrix, zero-padded.
    /// Element i is `I[c, r·vn + i]`.
    pub fn gather_input<T: Copy + Default>(&self, inp: &[T], r: usize, c: usize) -> Vec<T> {
        debug_assert_eq!(inp.len(), self.non_red_len * self.red_len);
        let mut out = vec![T::default(); self.vn_size];
        if c >= self.non_red_len {
            return out;
        }
        for (i, o) in out.iter_mut().enumerate() {
            let k = r * self.vn_size + i;
            if k < self.red_len {
                *o = inp[c * self.red_len + k];
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::Gemm;

    fn gemm(m: usize, k: usize, n: usize) -> Gemm {
        Gemm::new("t", "test", m, k, n)
    }

    #[test]
    fn grid_counts() {
        let g = gemm(6, 10, 8);
        let w = VnGrid::weights(&g, 4);
        assert_eq!(w.rows(), 3); // ceil(10/4)
        assert_eq!(w.cols(), 8);
        assert_eq!(w.count(), 24);
        let i = VnGrid::inputs(&g, 4);
        assert_eq!(i.rows(), 3);
        assert_eq!(i.cols(), 6);
        let o = VnGrid::outputs(&g, 4);
        assert_eq!(o.rows(), 2); // ceil(8/4)
        assert_eq!(o.cols(), 6);
    }

    #[test]
    fn gather_weight_values_and_padding() {
        // W is 3x2: [[1,2],[3,4],[5,6]] with K=3, N=2, vn=2.
        let g = gemm(1, 3, 2);
        let grid = VnGrid::weights(&g, 2);
        let w: Vec<i32> = vec![1, 2, 3, 4, 5, 6];
        assert_eq!(grid.gather_weight(&w, 0, 0), vec![1, 3]);
        assert_eq!(grid.gather_weight(&w, 0, 1), vec![2, 4]);
        // r=1 covers k=2..4 → k=3 padded.
        assert_eq!(grid.gather_weight(&w, 1, 0), vec![5, 0]);
        // fully out-of-bounds column → zeros.
        assert_eq!(grid.gather_weight(&w, 0, 7), vec![0, 0]);
        assert_eq!(grid.gather_weight(&w, 9, 0), vec![0, 0]);
    }

    #[test]
    fn gather_input_values_and_padding() {
        // I is 2x3: [[1,2,3],[4,5,6]], M=2, K=3, vn=2.
        let g = gemm(2, 3, 1);
        let grid = VnGrid::inputs(&g, 2);
        let i: Vec<i32> = vec![1, 2, 3, 4, 5, 6];
        assert_eq!(grid.gather_input(&i, 0, 0), vec![1, 2]);
        assert_eq!(grid.gather_input(&i, 1, 0), vec![3, 0]);
        assert_eq!(grid.gather_input(&i, 0, 1), vec![4, 5]);
        assert_eq!(grid.gather_input(&i, 1, 1), vec![6, 0]);
        assert_eq!(grid.gather_input(&i, 0, 5), vec![0, 0]);
    }

    #[test]
    fn dot_of_gathers_matches_matmul_entry() {
        // Property-style check on a fixed case: sum over r of
        // dot(I_VN(m=c_i, r), W_VN(r, c_w)) == (I·W)[c_i, c_w].
        let g = gemm(3, 5, 4);
        let mut rng = crate::util::Lcg::new(11);
        let iv: Vec<i32> = (0..g.m * g.k).map(|_| rng.range(0, 9) as i32).collect();
        let wv: Vec<i32> = (0..g.k * g.n).map(|_| rng.range(0, 9) as i32).collect();
        let gi = VnGrid::inputs(&g, 2);
        let gw = VnGrid::weights(&g, 2);
        for m in 0..g.m {
            for n in 0..g.n {
                let mut acc = 0i64;
                for r in 0..gw.rows() {
                    let a = gi.gather_input(&iv, r, m);
                    let b = gw.gather_weight(&wv, r, n);
                    acc += a.iter().zip(&b).map(|(&x, &y)| x as i64 * y as i64).sum::<i64>();
                }
                let expect: i64 = (0..g.k)
                    .map(|k| iv[m * g.k + k] as i64 * wv[k * g.n + n] as i64)
                    .sum();
                assert_eq!(acc, expect, "mismatch at ({m},{n})");
            }
        }
    }
}
