//! Distribution networks from buffers to NEST (§III-B).
//!
//! FEATHER uses rigid per-column point-to-point links: buffer column `c`
//! feeds PE column `c` only, so any value needed by several columns must be
//! *duplicated* in the buffer, and the stationary tensor must be pre-known
//! and offline-reordered into its preferred layout.
//!
//! FEATHER+ replaces these with two all-to-all crossbars (streaming- and
//! stationary-side), letting one resident copy be multicast to arbitrary PE
//! columns — eliminating on-chip duplication and the pre-known-weights
//! assumption.

use super::config::HwGen;

/// A distribution request for one cycle: for each PE column, which buffer
/// column it wants to read (or `None` for idle).
pub type DistRequest = Vec<Option<usize>>;

/// Outcome of distributing one cycle's requests.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DistOutcome {
    /// Requests that can be served this cycle.
    pub served: usize,
    /// Requests that would require an on-chip duplicate under this
    /// generation's network (FEATHER point-to-point only).
    pub needs_duplication: usize,
}

/// Check a distribution pattern against a hardware generation.
///
/// * `FeatherPlus`: all-to-all crossbar — every pattern is served in one
///   cycle (a single buffer column may fan out to any set of PE columns).
/// * `Feather`: point-to-point — PE column `i` can only read buffer column
///   `i`; any other source requires the value to have been duplicated into
///   buffer column `i` ahead of time.
pub fn distribute(gen: HwGen, req: &DistRequest) -> DistOutcome {
    match gen {
        HwGen::FeatherPlus => DistOutcome {
            served: req.iter().filter(|r| r.is_some()).count(),
            needs_duplication: 0,
        },
        HwGen::Feather => {
            let mut served = 0;
            let mut dup = 0;
            for (pe_col, r) in req.iter().enumerate() {
                match r {
                    Some(src) if *src == pe_col => served += 1,
                    Some(_) => dup += 1,
                    None => {}
                }
            }
            DistOutcome { served, needs_duplication: dup }
        }
    }
}

/// Count distinct buffer columns multicast to >1 PE column — the data that
/// FEATHER would have to physically replicate in its buffers (the on-chip
/// duplication FEATHER+ removes, §III-B).
pub fn duplication_factor(req: &DistRequest) -> usize {
    use std::collections::HashMap;
    let mut fanout: HashMap<usize, usize> = HashMap::new();
    for r in req.iter().flatten() {
        *fanout.entry(*r).or_insert(0) += 1;
    }
    fanout.values().filter(|&&f| f > 1).map(|&f| f - 1).sum()
}

/// Crossbar hardware cost in 2:1 mux-equivalents: an AW×AW crossbar of
/// `width`-bit ports costs ~AW·AW·width muxes (the O(AW²) term of §VI-D1).
pub fn crossbar_mux_cost(aw: usize, width_bits: usize) -> u64 {
    (aw * aw * width_bits) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn featherplus_serves_everything() {
        let req: DistRequest = vec![Some(0), Some(0), Some(0), None];
        let out = distribute(HwGen::FeatherPlus, &req);
        assert_eq!(out.served, 3);
        assert_eq!(out.needs_duplication, 0);
    }

    #[test]
    fn feather_needs_duplicates_for_multicast() {
        // All four PE columns want buffer column 0: FEATHER must duplicate
        // it into columns 1..3.
        let req: DistRequest = vec![Some(0), Some(0), Some(0), Some(0)];
        let out = distribute(HwGen::Feather, &req);
        assert_eq!(out.served, 1);
        assert_eq!(out.needs_duplication, 3);
        assert_eq!(duplication_factor(&req), 3);
    }

    #[test]
    fn feather_identity_pattern_is_free() {
        let req: DistRequest = (0..8).map(Some).collect();
        let out = distribute(HwGen::Feather, &req);
        assert_eq!(out.served, 8);
        assert_eq!(out.needs_duplication, 0);
        assert_eq!(duplication_factor(&req), 0);
    }

    #[test]
    fn duplication_counts_per_source() {
        // col0 fanout 2 (+1 dup), col3 fanout 3 (+2 dups).
        let req: DistRequest = vec![Some(0), Some(0), Some(3), Some(3), Some(3), None];
        assert_eq!(duplication_factor(&req), 3);
    }

    #[test]
    fn crossbar_cost_quadratic() {
        assert_eq!(crossbar_mux_cost(4, 8), 128);
        assert_eq!(crossbar_mux_cost(8, 8), 512); // 4× for 2× ports
    }
}
