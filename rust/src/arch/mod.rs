//! FEATHER+ architectural model (§III): configuration, buffers, the NEST PE
//! array, the BIRRD reduce-and-reorder network, the all-to-all distribution
//! crossbars and the post-PnR area/power model.

pub mod area;
pub mod birrd;
pub mod buffer;
pub mod config;
pub mod crossbar;
pub mod dedup;
pub mod nest;
pub mod vn;

pub use config::{ArchConfig, HwGen};
pub use vn::{Operand, VnGrid};
