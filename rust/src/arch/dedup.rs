//! On-chip data-duplication analysis: FEATHER vs FEATHER+ (§III-B claim 2).
//!
//! FEATHER's point-to-point buffer→NEST links force any value consumed by
//! several PE columns to be *physically replicated* in the buffer (one copy
//! per consuming column). FEATHER+'s all-to-all crossbars multicast a
//! single resident copy. This module quantifies, for an actual mapper
//! decision, how many duplicate words FEATHER would have to materialize —
//! the buffer capacity MINISA+FEATHER+ win back for activations/weights.

use crate::arch::config::{ArchConfig, HwGen};
use crate::mapper::MappingChoice;
use crate::mapping::{MappingCfg, StreamCfg};
#[cfg(test)]
use crate::mapping::Dataflow;
use crate::util::ceil_div;

/// Duplication report for one compute tile under a mapping.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DedupReport {
    /// Distinct stationary VNs the tile keeps resident.
    pub distinct_stationary_vns: usize,
    /// Stationary VN *slots* FEATHER must materialize (with duplicates).
    pub feather_stationary_vns: usize,
    /// Distinct streamed VNs per invocation wave-set.
    pub distinct_streamed_vns: usize,
    /// Streamed VN slots FEATHER must materialize.
    pub feather_streamed_vns: usize,
    /// VN size (words per VN).
    pub vn_size: usize,
}

impl DedupReport {
    /// Duplicated words FEATHER stores that FEATHER+ does not.
    pub fn duplicated_words(&self) -> usize {
        ((self.feather_stationary_vns - self.distinct_stationary_vns)
            + (self.feather_streamed_vns - self.distinct_streamed_vns))
            * self.vn_size
    }

    /// Buffer-capacity inflation factor under FEATHER (≥ 1.0).
    pub fn inflation(&self) -> f64 {
        let distinct = self.distinct_stationary_vns + self.distinct_streamed_vns;
        let feather = self.feather_stationary_vns + self.feather_streamed_vns;
        if distinct == 0 {
            1.0
        } else {
            feather as f64 / distinct as f64
        }
    }
}

/// Analyze one invocation's duplication under a mapping choice.
///
/// FEATHER requirement: PE column `a_w` reads only buffer column `a_w`, so
/// every (VN, consuming-column) pair needs a resident copy in that column.
/// FEATHER+ requirement: one copy per distinct VN.
pub fn analyze_invocation(
    cfg: &ArchConfig,
    choice: &MappingChoice,
    em: &MappingCfg,
    es: &StreamCfg,
) -> DedupReport {
    let active_rows = choice.vn.min(cfg.ah);
    // Stationary: each PE holds one VN; count distinct (r, c).
    let mut sta: Vec<(usize, usize)> = Vec::with_capacity(active_rows * cfg.aw);
    for a_w in 0..cfg.aw {
        for a_h in 0..active_rows {
            sta.push(em.stationary_vn(a_h, a_w));
        }
    }
    let feather_sta = sta.len();
    sta.sort_unstable();
    sta.dedup();
    // Streamed: per wave, each column consumes one VN; over the invocation,
    // column a_w consumes T distinct VNs — FEATHER must hold column a_w's
    // whole stream in buffer column a_w.
    let mut str_vns: Vec<(usize, usize)> = Vec::with_capacity(cfg.aw * es.t.min(64));
    let probe_t = es.t.min(64); // streams are periodic in our lowering
    for a_w in 0..cfg.aw {
        for t in 0..probe_t {
            str_vns.push(es.streamed_vn(em, a_w, t));
        }
    }
    let feather_str = str_vns.len();
    str_vns.sort_unstable();
    str_vns.dedup();
    DedupReport {
        distinct_stationary_vns: sta.len(),
        feather_stationary_vns: feather_sta,
        distinct_streamed_vns: str_vns.len(),
        feather_streamed_vns: feather_str,
        vn_size: choice.vn,
    }
}

/// Analyze the interior tile of a mapper decision (the representative
/// invocation the lowering emits).
pub fn analyze_decision(cfg: &ArchConfig, d: &crate::mapper::Decision, m_extent: usize) -> DedupReport {
    let ch = d.choice;
    let rows_active = ch.vn.min(cfg.ah);
    let period = (ch.nbc * ch.dup).min(cfg.aw).max(1);
    let em = MappingCfg { r0: 0, c0: 0, g_r: period, g_c: ch.nbc, s_r: 1, s_c: rows_active };
    let es = StreamCfg {
        df: ch.df,
        m0: 0,
        s_m: ch.dup,
        t: ceil_div(m_extent.min(ch.m_t), ch.dup).max(1),
        vn_size: ch.vn,
    };
    analyze_invocation(cfg, &ch, &em, &es)
}

/// Hardware-generation check used by tests: FEATHER+ never needs
/// duplication by construction (crossbar multicast).
pub fn required_copies(gen: HwGen, fanout: usize) -> usize {
    match gen {
        HwGen::Feather => fanout.max(1),
        HwGen::FeatherPlus => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapper::search::{search, MapperOptions};
    use crate::workloads::Gemm;

    #[test]
    fn replicated_mapping_duplicates_on_feather() {
        // Fig. 4 case 1: same W_VNs replicated across all columns → FEATHER
        // stores AW copies, FEATHER+ one.
        let cfg = ArchConfig::paper(4, 4);
        let ch = MappingChoice {
            df: Dataflow::WoS,
            vn: 4,
            m_t: 16,
            k_t: 4,
            n_t: 4,
            nbc: 1,
            dup: 4,
        };
        let em = MappingCfg { r0: 0, c0: 0, g_r: 4, g_c: 1, s_r: 1, s_c: 4 };
        let es = StreamCfg { df: Dataflow::WoS, m0: 0, s_m: 4, t: 4, vn_size: 4 };
        let r = analyze_invocation(&cfg, &ch, &em, &es);
        assert_eq!(r.distinct_stationary_vns, 4); // 4 distinct VNs (a_h)
        assert_eq!(r.feather_stationary_vns, 16); // ×4 columns
        assert!(r.duplicated_words() > 0);
        assert!(r.inflation() > 1.5, "{}", r.inflation());
    }

    #[test]
    fn distinct_mapping_needs_no_duplicates() {
        // Fig. 4 case 3: every column holds different VNs and consumes a
        // disjoint stream → FEATHER ≈ FEATHER+.
        let cfg = ArchConfig::paper(4, 4);
        let ch = MappingChoice {
            df: Dataflow::WoS,
            vn: 4,
            m_t: 4,
            k_t: 4,
            n_t: 16,
            nbc: 4,
            dup: 1,
        };
        let em = MappingCfg { r0: 0, c0: 0, g_r: 4, g_c: 4, s_r: 1, s_c: 4 };
        let es = StreamCfg { df: Dataflow::WoS, m0: 0, s_m: 1, t: 4, vn_size: 4 };
        let r = analyze_invocation(&cfg, &ch, &em, &es);
        assert_eq!(r.distinct_stationary_vns, r.feather_stationary_vns);
        // All columns share the same stream (G_c = G_r) → streamed dup.
        assert!(r.distinct_streamed_vns <= r.feather_streamed_vns);
    }

    #[test]
    fn decisions_report_inflation() {
        let cfg = ArchConfig::paper(4, 16);
        let g = Gemm::new("d", "t", 1024, 40, 24);
        let opts = MapperOptions { full_layout_search: false, ..Default::default() };
        let d = search(&cfg, &g, &opts).unwrap();
        let r = analyze_decision(&cfg, &d, g.m);
        assert!(r.inflation() >= 1.0);
        assert!(r.distinct_stationary_vns > 0);
    }

    #[test]
    fn copies_by_generation() {
        assert_eq!(required_copies(HwGen::Feather, 7), 7);
        assert_eq!(required_copies(HwGen::FeatherPlus, 7), 1);
        assert_eq!(required_copies(HwGen::Feather, 0), 1);
    }
}
