//! On-chip buffer models (§III-A).
//!
//! * Streaming buffer — single bank in FEATHER+ (simplified banking,
//!   §III-B), holds the streamed tensor.
//! * Stationary buffer — holds the tensor pinned in PE local registers.
//! * Output buffer (OB) — the only multi-bank buffer, with per-bank address
//!   generation, accumulating partial sums (temporal reduction level 3).
//!
//! Buffers are `D × AW` element grids; VN layouts place `vn_size`-element
//! VNs in contiguous rows of one column (see `layout`).

use crate::arith::Element;
use crate::layout::VnLayout;

/// A `depth × width` scratchpad of elements `T`.
#[derive(Debug, Clone)]
pub struct DataBuffer<T> {
    pub depth: usize,
    pub width: usize,
    data: Vec<T>,
}

impl<T: Copy + Default> DataBuffer<T> {
    pub fn new(depth: usize, width: usize) -> Self {
        Self { depth, width, data: vec![T::default(); depth * width] }
    }

    #[inline]
    pub fn get(&self, row: usize, col: usize) -> T {
        debug_assert!(row < self.depth && col < self.width);
        self.data[row * self.width + col]
    }

    #[inline]
    pub fn set(&mut self, row: usize, col: usize, v: T) {
        debug_assert!(row < self.depth && col < self.width);
        self.data[row * self.width + col] = v;
    }

    pub fn clear(&mut self) {
        self.data.iter_mut().for_each(|v| *v = T::default());
    }

    /// Write VN (r, c) of a layout. Returns false (no-op) if the VN does not
    /// fit the buffer.
    pub fn write_vn(&mut self, layout: &VnLayout, r: usize, c: usize, elems: &[T]) -> bool {
        debug_assert_eq!(elems.len(), layout.vn_size);
        match layout.addr(r, c, self.width) {
            Some((row0, col)) if row0 + layout.vn_size <= self.depth => {
                for (i, &e) in elems.iter().enumerate() {
                    self.set(row0 + i, col, e);
                }
                true
            }
            _ => false,
        }
    }

    /// Read VN (r, c); `None` when unmapped or out of capacity.
    pub fn read_vn(&self, layout: &VnLayout, r: usize, c: usize) -> Option<Vec<T>> {
        let (row0, col) = layout.addr(r, c, self.width)?;
        if row0 + layout.vn_size > self.depth {
            return None;
        }
        Some((0..layout.vn_size).map(|i| self.get(row0 + i, col)).collect())
    }

    /// Allocation-free variant of `read_vn`: fills `out` (resized to
    /// `vn_size`) and returns `true`, or returns `false` when unmapped.
    /// Used on the functional simulator's wave loop (§Perf).
    pub fn read_vn_into(&self, layout: &VnLayout, r: usize, c: usize, out: &mut Vec<T>) -> bool {
        match layout.addr(r, c, self.width) {
            Some((row0, col)) if row0 + layout.vn_size <= self.depth => {
                out.clear();
                out.extend((0..layout.vn_size).map(|i| self.get(row0 + i, col)));
                true
            }
            _ => false,
        }
    }

    pub fn rows(&self) -> impl Iterator<Item = &[T]> {
        self.data.chunks(self.width)
    }

    /// Raw row-major backing words — the compiled-plan interpreter indexes
    /// precomputed word offsets directly instead of going through
    /// `addr()`-based VN reads (§Perf).
    #[inline]
    pub fn data(&self) -> &[T] {
        &self.data
    }
}

/// Multi-bank accumulator output buffer. Banks correspond to columns; each
/// bank has its own address generator (the architectural feature that makes
/// flexible output layouts possible, §III-A). Generic over the element
/// backend: cells hold `E::Acc` psums and accumulate with `E::acc_add`
/// (i64 saturating-int accumulators by default — the pre-`arith` semantics).
#[derive(Debug, Clone)]
pub struct OutputBuffer<E: Element = i32> {
    pub depth: usize,
    pub banks: usize,
    data: Vec<E::Acc>,
    /// Per-cycle bank-conflict counter (two different addresses to one bank
    /// in one accumulation group).
    pub conflicts: u64,
}

impl<E: Element> OutputBuffer<E> {
    pub fn new(depth: usize, banks: usize) -> Self {
        Self { depth, banks, data: vec![E::acc_zero(); depth * banks], conflicts: 0 }
    }

    #[inline]
    pub fn get(&self, row: usize, bank: usize) -> E::Acc {
        self.data[row * self.banks + bank]
    }

    /// Accumulate into (row, bank).
    #[inline]
    pub fn accumulate(&mut self, row: usize, bank: usize, v: E::Acc) {
        debug_assert!(row < self.depth && bank < self.banks);
        let cell = &mut self.data[row * self.banks + bank];
        *cell = E::acc_add(*cell, v);
    }

    /// Accumulate a group of same-cycle writes, counting bank conflicts
    /// (more than one distinct row per bank in the group).
    pub fn accumulate_group(&mut self, writes: &[(usize, usize, E::Acc)]) {
        let mut seen: Vec<Option<usize>> = vec![None; self.banks];
        for &(row, bank, v) in writes {
            match seen[bank] {
                None => seen[bank] = Some(row),
                Some(prev) if prev != row => self.conflicts += 1,
                _ => {}
            }
            self.accumulate(row, bank, v);
        }
    }

    /// Clear for a new output tile (SetOVNLayout lifecycle, §IV-G1).
    pub fn clear(&mut self) {
        self.data.iter_mut().for_each(|v| *v = E::acc_zero());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layout::VnLayout;

    #[test]
    fn databuffer_rw() {
        let mut b: DataBuffer<i8> = DataBuffer::new(8, 4);
        b.set(3, 2, 42);
        assert_eq!(b.get(3, 2), 42);
        assert_eq!(b.get(0, 0), 0);
        b.clear();
        assert_eq!(b.get(3, 2), 0);
    }

    #[test]
    fn vn_rw_roundtrip() {
        let mut b: DataBuffer<i8> = DataBuffer::new(16, 4);
        let l = VnLayout::row_major(2, 4, 4);
        assert!(b.write_vn(&l, 1, 2, &[1, 2, 3, 4]));
        assert_eq!(b.read_vn(&l, 1, 2), Some(vec![1, 2, 3, 4]));
        // Unwritten VN reads zeros (not None) when mapped.
        assert_eq!(b.read_vn(&l, 0, 0), Some(vec![0, 0, 0, 0]));
        // Outside layout extents → None.
        assert!(b.read_vn(&l, 5, 0).is_none());
    }

    #[test]
    fn vn_write_checks_capacity() {
        let mut b: DataBuffer<i8> = DataBuffer::new(4, 2); // 2 VNs of 4 fit
        let l = VnLayout::row_major(2, 2, 4); // needs 8 rows
        assert!(b.write_vn(&l, 0, 0, &[1, 1, 1, 1]));
        assert!(b.write_vn(&l, 0, 1, &[2, 2, 2, 2]));
        // VN slot L=2 → row 4: out of capacity.
        assert!(!b.write_vn(&l, 1, 0, &[3, 3, 3, 3]));
        assert!(b.read_vn(&l, 1, 0).is_none());
    }

    #[test]
    fn output_buffer_accumulates() {
        let mut ob: OutputBuffer = OutputBuffer::new(8, 4);
        ob.accumulate(2, 1, 10);
        ob.accumulate(2, 1, -3);
        assert_eq!(ob.get(2, 1), 7);
        ob.clear();
        assert_eq!(ob.get(2, 1), 0);
    }

    #[test]
    fn output_buffer_conflict_counting() {
        let mut ob: OutputBuffer = OutputBuffer::new(8, 2);
        // Same bank, two rows in one group → conflict.
        ob.accumulate_group(&[(0, 0, 1), (1, 0, 1)]);
        assert_eq!(ob.conflicts, 1);
        // Same bank same row → fine.
        ob.accumulate_group(&[(0, 1, 1), (0, 1, 2)]);
        assert_eq!(ob.conflicts, 1);
        assert_eq!(ob.get(0, 1), 3);
    }
}
