//! FEATHER+ architectural configuration (§VI-A, Table V).
//!
//! An `ArchConfig` fixes the NEST dimensions (AH × AW), on-chip buffer
//! capacities, off-chip bandwidths and the instruction-fetch interface.
//! All ISA bitwidths, cost models and the mapper derive from this struct.

use crate::util::{ceil_div, clog2};

/// Which hardware generation a config models. FEATHER (baseline, ISCA'24)
/// uses point-to-point buffer→NEST links and multi-bank streaming buffers;
/// FEATHER+ adds the all-to-all distribution crossbars, single-bank
/// streaming buffer and OB→stationary links (§III-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum HwGen {
    Feather,
    FeatherPlus,
}

/// Full architecture configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ArchConfig {
    /// PE rows per column == local dot-product length (VN size upper bound).
    pub ah: usize,
    /// Number of independent PE columns.
    pub aw: usize,
    /// Hardware generation (affects distribution network + duplication).
    pub gen: HwGen,
    /// Element width of input/weight operands in bytes (paper: INT8 → 1).
    pub elem_bytes: usize,
    /// Partial-sum / output element width in bytes (32-bit accumulators).
    pub acc_bytes: usize,
    /// Streaming-buffer capacity in bytes.
    pub str_bytes: usize,
    /// Stationary-buffer capacity in bytes.
    pub sta_bytes: usize,
    /// Output-buffer capacity in bytes.
    pub ob_bytes: usize,
    /// Dedicated instruction-buffer capacity in bytes.
    pub instr_bytes: usize,
    /// Off-chip instruction interface, bytes per cycle (paper: 9 B/cyc).
    pub instr_bw: f64,
    /// Off-chip input/weight bandwidth, bytes per cycle (paper: AW B/cyc).
    pub data_bw_in: f64,
    /// Off-chip output bandwidth, bytes per cycle (paper: 4·AW B/cyc).
    pub data_bw_out: f64,
    /// HBM address-space size in bytes (sets Load/Store address width).
    pub hbm_bytes: u64,
    /// Clock in GHz, used only to convert cycles → µs in reports.
    pub clock_ghz: f64,
}

impl ArchConfig {
    /// The paper's experimental setup for a given (AH, AW) — Table V.
    ///
    /// On-chip SRAM scales with AH and is split streaming 40% / stationary
    /// 40% / output 20%; Table V lists (StrB/StaB, OB, Instr) in MB as
    /// (1.6, 0.8, 0.5) for AH=4, (6.4, 3.2, 1.0) for AH=8 and
    /// (25.6, 12.8, 2.0) for AH=16, where the first entry is the *combined*
    /// streaming+stationary capacity (40% + 40% of the data SRAM).
    pub fn paper(ah: usize, aw: usize) -> Self {
        let (data_mb, instr_mb) = match ah {
            4 => (1.6 + 0.8, 0.5),
            8 => (6.4 + 3.2, 1.0),
            16 => (25.6 + 12.8, 2.0),
            // Geometric interpolation for non-paper heights.
            _ => ((ah * ah) as f64 * 0.15, 0.5 * (ah as f64 / 4.0)),
        };
        let mb = 1_000_000.0;
        let data = data_mb * mb;
        Self {
            ah,
            aw,
            gen: HwGen::FeatherPlus,
            elem_bytes: 1,
            acc_bytes: 4,
            str_bytes: (data * 0.4) as usize,
            sta_bytes: (data * 0.4) as usize,
            ob_bytes: (data * 0.2) as usize,
            instr_bytes: (instr_mb * mb) as usize,
            instr_bw: 9.0,
            data_bw_in: aw as f64,
            data_bw_out: 4.0 * aw as f64,
            hbm_bytes: 32 << 30, // 32 GiB
            clock_ghz: 1.0,
        }
    }

    /// All nine (AH, AW) configurations swept by the paper's evaluation.
    pub fn paper_sweep() -> Vec<Self> {
        let mut v = Vec::new();
        for &(ah, aws) in &[(4usize, [4usize, 16, 64]), (8, [8, 32, 128]), (16, [16, 64, 256])] {
            for &aw in &aws {
                v.push(Self::paper(ah, aw));
            }
        }
        v
    }

    /// The six configurations of Table I.
    pub fn table1_sweep() -> Vec<Self> {
        [(4, 4), (8, 8), (4, 64), (16, 16), (8, 128), (16, 256)]
            .iter()
            .map(|&(ah, aw)| Self::paper(ah, aw))
            .collect()
    }

    /// FEATHER (baseline generation) twin of this config.
    pub fn as_feather(mut self) -> Self {
        self.gen = HwGen::Feather;
        self
    }

    /// Streaming-buffer depth D_str in rows of AW elements.
    pub fn d_str(&self) -> usize {
        self.str_bytes / (self.aw * self.elem_bytes)
    }

    /// Stationary-buffer depth D_sta in rows of AW elements.
    pub fn d_sta(&self) -> usize {
        self.sta_bytes / (self.aw * self.elem_bytes)
    }

    /// The ISA's D parameter: the paper sets D = D_sta = D_str (Fig. 5);
    /// we take the min so encodings are always in range for both buffers.
    pub fn d(&self) -> usize {
        self.d_str().min(self.d_sta())
    }

    /// Output-buffer depth in rows of AW accumulators.
    pub fn d_ob(&self) -> usize {
        self.ob_bytes / (self.aw * self.acc_bytes)
    }

    /// Max number of VNs (of size AH) resident per data buffer: ⌊D/AH⌋·AW.
    pub fn max_vns(&self) -> usize {
        (self.d() / self.ah) * self.aw
    }

    /// Number of PEs.
    pub fn pes(&self) -> usize {
        self.ah * self.aw
    }

    /// Peak MACs per cycle.
    pub fn peak_macs_per_cycle(&self) -> usize {
        self.pes()
    }

    /// BIRRD stage count. BIRRD is a butterfly-like reduce-and-reorder
    /// network over AW ports: `2·log2(AW) − 1` stages of AW/2 two-input
    /// switches (Benes-equivalent rearrangeability, §III-A / FEATHER §IV).
    pub fn birrd_stages(&self) -> usize {
        if self.aw <= 1 {
            return 0;
        }
        2 * clog2(self.aw) as usize - 1
    }

    /// Total BIRRD 2×2 switches: stages × AW/2.
    pub fn birrd_switches(&self) -> usize {
        self.birrd_stages() * (self.aw / 2)
    }

    /// Pipeline fill/drain latency of one NEST invocation: array depth +
    /// BIRRD stages + OB write.
    pub fn drain_cycles(&self) -> usize {
        self.ah + self.birrd_stages() + 1
    }

    /// Cycles to load one full stationary tile (AH regs × AW cols) from the
    /// stationary buffer. One buffer row (AW elements) per cycle through the
    /// distribution network; double-buffered local registers hide this for
    /// all but the first tile (§III-A).
    pub fn stationary_fill_cycles(&self, vn_size: usize) -> usize {
        // AH·AW elements arrive AW per cycle → AH cycles (vn_size rows when
        // VN is shorter than AH).
        vn_size.min(self.ah)
    }

    /// Convert cycles to microseconds at the configured clock.
    pub fn cycles_to_us(&self, cycles: f64) -> f64 {
        cycles / (self.clock_ghz * 1e3)
    }

    /// Sanity-check invariants; used by tests and the CLI.
    pub fn validate(&self) -> Result<(), String> {
        if !crate::util::is_pow2(self.aw) {
            return Err(format!("AW={} must be a power of two (BIRRD)", self.aw));
        }
        if self.ah == 0 || self.aw == 0 {
            return Err("AH/AW must be nonzero".into());
        }
        if self.d() < self.ah {
            return Err(format!("buffer depth D={} < AH={}", self.d(), self.ah));
        }
        if self.d_ob() == 0 {
            return Err("output buffer too small".into());
        }
        Ok(())
    }

    /// Short display name, e.g. "16x256".
    pub fn name(&self) -> String {
        format!("{}x{}", self.ah, self.aw)
    }

    /// Number of VN rows (r-index range) a K-length reduction needs.
    pub fn k_tiles(&self, k: usize) -> usize {
        ceil_div(k, self.ah)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_configs_validate() {
        for c in ArchConfig::paper_sweep() {
            c.validate().unwrap_or_else(|e| panic!("{}: {e}", c.name()));
        }
        for c in ArchConfig::table1_sweep() {
            c.validate().unwrap();
        }
    }

    #[test]
    fn paper_sweep_has_nine() {
        assert_eq!(ArchConfig::paper_sweep().len(), 9);
        assert_eq!(ArchConfig::table1_sweep().len(), 6);
    }

    #[test]
    fn capacities_match_table_v() {
        let c = ArchConfig::paper(16, 256);
        // 25.6 + 12.8 MB data: 40/40/20 split.
        assert_eq!(c.str_bytes, 15_360_000);
        assert_eq!(c.sta_bytes, 15_360_000);
        assert_eq!(c.ob_bytes, 7_680_000);
        assert_eq!(c.instr_bytes, 2_000_000);
        assert_eq!(c.instr_bw, 9.0);
        assert_eq!(c.data_bw_in, 256.0);
        assert_eq!(c.data_bw_out, 1024.0);
    }

    #[test]
    fn depths_consistent() {
        let c = ArchConfig::paper(4, 4);
        assert_eq!(c.d_str(), c.str_bytes / 4);
        assert!(c.d() >= c.ah);
        assert_eq!(c.max_vns(), (c.d() / 4) * 4);
    }

    #[test]
    fn birrd_counts() {
        let c = ArchConfig::paper(4, 4);
        assert_eq!(c.birrd_stages(), 3); // 2*2-1
        assert_eq!(c.birrd_switches(), 6);
        let c = ArchConfig::paper(16, 256);
        assert_eq!(c.birrd_stages(), 15); // 2*8-1
        assert_eq!(c.birrd_switches(), 15 * 128);
    }

    #[test]
    fn feather_twin_keeps_dims() {
        let c = ArchConfig::paper(8, 32).as_feather();
        assert_eq!(c.gen, HwGen::Feather);
        assert_eq!((c.ah, c.aw), (8, 32));
    }

    #[test]
    fn rejects_non_pow2_aw() {
        let mut c = ArchConfig::paper(4, 4);
        c.aw = 6;
        assert!(c.validate().is_err());
    }

    #[test]
    fn k_tiles_rounding() {
        let c = ArchConfig::paper(16, 16);
        assert_eq!(c.k_tiles(40), 3);
        assert_eq!(c.k_tiles(16), 1);
        assert_eq!(c.k_tiles(17), 2);
    }
}
