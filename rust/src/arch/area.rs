//! Post-PnR area/power model, FEATHER vs FEATHER+ (Table VI, §VI-E).
//!
//! We cannot run TSMC-28nm PnR, so this is a component-level model with
//! interpretable unit costs (28nm-class flop/MAC/switch areas) calibrated so
//! the five published Table VI points land within band, and the paper's
//! qualitative claims hold: FEATHER→FEATHER+ costs ≤ ~8% area/power, small
//! for square arrays and larger for wide ones, because the all-to-all
//! distribution network amortizes over distributed register and compute
//! resources. DESIGN.md §Hardware-Adaptation records the substitution.
//!
//! Like the paper's PnR experiment, buffers are modeled at depth 64,
//! implemented as registers (a real deployment would use SRAM macros).

use super::config::ArchConfig;

/// Unit costs in µm² (TSMC 28nm class).
mod unit {
    /// One register bit (flop + local clocking).
    pub const REG_BIT: f64 = 4.0;
    /// One 8-bit MAC (multiplier + accumulator slice).
    pub const MAC8: f64 = 250.0;
    /// One BIRRD 2×2 switch incl. reduction adder (24-bit psum datapath).
    pub const BIRRD_SW: f64 = 150.0;
    /// One crossbar crosspoint bit (mux + wire load).
    pub const XBAR_BIT: f64 = 1.15;
    /// Fixed control overhead of the FEATHER+ distribution network.
    pub const XBAR_CTRL: f64 = 600.0;
    /// Global wiring/control factor applied to the total.
    pub const WIRE_FACTOR: f64 = 1.2;
    /// Power density: mW per µm² (fit to Table VI's 0.63–0.70 range).
    pub const MW_PER_UM2: f64 = 0.000_65;
    /// PnR buffer depth used by the paper for Table VI.
    pub const PNR_DEPTH: usize = 64;
    /// Mux-tree source cap (long-wire sharing in the physical design).
    pub const XBAR_FANIN_CAP: usize = 63;
}

/// Area/power breakdown for one configuration and generation.
#[derive(Debug, Clone, PartialEq)]
pub struct AreaReport {
    pub config: String,
    pub pe_um2: f64,
    pub buffer_um2: f64,
    pub birrd_um2: f64,
    pub dist_um2: f64,
    pub total_um2: f64,
    pub power_mw: f64,
}

/// Model one generation's area at the paper's PnR buffer depth (64).
pub fn area(cfg: &ArchConfig, plus: bool) -> AreaReport {
    let (ah, aw) = (cfg.ah as f64, cfg.aw as f64);
    let ebits = (cfg.elem_bytes * 8) as f64;
    let abits = (cfg.acc_bytes * 8) as f64;
    let depth = unit::PNR_DEPTH as f64;

    // PEs: 1 MAC + 2·AH local register file per PE.
    let pe = ah * aw * (unit::MAC8 + 2.0 * ah * ebits * unit::REG_BIT);
    // Buffers as registers: streaming + stationary (elem width) + OB (acc).
    let buffer =
        depth * aw * (2.0 * ebits + abits) * unit::REG_BIT;
    // BIRRD switches.
    let birrd = cfg.birrd_switches() as f64 * unit::BIRRD_SW;
    // Distribution network: FEATHER point-to-point is wiring only (in the
    // wire factor); FEATHER+ adds two all-to-all crossbars with a fan-in
    // capped mux tree per output.
    let dist = if plus {
        let fanin = (cfg.aw - 1).min(unit::XBAR_FANIN_CAP) as f64;
        unit::XBAR_CTRL + 2.0 * aw * fanin * ebits * unit::XBAR_BIT
    } else {
        0.0
    };
    let total = (pe + buffer + birrd + dist) * unit::WIRE_FACTOR;
    AreaReport {
        config: cfg.name(),
        pe_um2: pe,
        buffer_um2: buffer,
        birrd_um2: birrd,
        dist_um2: dist,
        total_um2: total,
        power_mw: total * unit::MW_PER_UM2,
    }
}

/// One Table VI comparison row.
#[derive(Debug, Clone)]
pub struct TableVIRow {
    pub config: String,
    pub feather_um2: f64,
    pub featherplus_um2: f64,
    pub area_increase_pct: f64,
    pub feather_mw: f64,
    pub featherplus_mw: f64,
    pub power_increase_pct: f64,
}

/// The published Table VI reference values (setup, F µm², F+ µm², F mW,
/// F+ mW) for side-by-side reporting.
pub const PAPER_TABLE_VI: [(&str, f64, f64, f64, f64); 5] = [
    ("4x4", 70_598.0, 71_573.0, 44.59, 45.34),
    ("8x8", 174_370.0, 176_573.0, 108.97, 110.49),
    ("16x16", 476_174.0, 482_044.0, 293.47, 297.72),
    ("4x64", 1_259_903.0, 1_352_697.0, 854.77, 915.14),
    ("8x128", 3_198_595.0, 3_441_146.0, 2240.27, 2350.88),
];

/// Regenerate Table VI rows from the model.
pub fn table_vi() -> Vec<TableVIRow> {
    [(4usize, 4usize), (8, 8), (16, 16), (4, 64), (8, 128)]
        .iter()
        .map(|&(ah, aw)| {
            let cfg = ArchConfig::paper(ah, aw);
            let f = area(&cfg, false);
            let fp = area(&cfg, true);
            TableVIRow {
                config: cfg.name(),
                feather_um2: f.total_um2,
                featherplus_um2: fp.total_um2,
                area_increase_pct: (fp.total_um2 / f.total_um2 - 1.0) * 100.0,
                feather_mw: f.power_mw,
                featherplus_mw: fp.power_mw,
                power_increase_pct: (fp.power_mw / f.power_mw - 1.0) * 100.0,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overhead_bounded_like_paper() {
        // Paper: FEATHER+ adds at most ~7.6% area.
        for row in table_vi() {
            assert!(
                row.area_increase_pct > 0.0 && row.area_increase_pct <= 8.5,
                "{}: {:.2}%",
                row.config,
                row.area_increase_pct
            );
        }
    }

    #[test]
    fn wide_arrays_pay_more_than_square() {
        let rows = table_vi();
        let pct = |name: &str| {
            rows.iter().find(|r| r.config == name).unwrap().area_increase_pct
        };
        assert!(pct("4x64") > pct("4x4"));
        assert!(pct("4x64") > pct("16x16"));
        assert!(pct("8x128") > pct("8x8"));
    }

    #[test]
    fn absolute_areas_within_band_of_paper() {
        // Component model should land within 2× of every published point.
        for (name, f_paper, fp_paper, _, _) in PAPER_TABLE_VI {
            let row = table_vi().into_iter().find(|r| r.config == name).unwrap();
            let ratio_f = row.feather_um2 / f_paper;
            let ratio_fp = row.featherplus_um2 / fp_paper;
            assert!(
                (0.5..2.0).contains(&ratio_f),
                "{name}: model {:.0} vs paper {f_paper:.0}",
                row.feather_um2
            );
            assert!((0.5..2.0).contains(&ratio_fp), "{name} F+");
        }
    }

    #[test]
    fn area_scales_sublinearly_in_components() {
        // Doubling AW should roughly double area (O(AW) NEST+buffers with
        // subquadratic interconnect, §VI-D1).
        let a1 = area(&ArchConfig::paper(16, 64), true).total_um2;
        let a2 = area(&ArchConfig::paper(16, 128), true).total_um2;
        let ratio = a2 / a1;
        assert!((1.8..2.4).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn power_tracks_area() {
        let r = area(&ArchConfig::paper(8, 8), true);
        assert!((r.power_mw / r.total_um2 - 0.00065).abs() < 1e-9);
    }

    #[test]
    fn breakdown_sums_to_total() {
        let r = area(&ArchConfig::paper(8, 32), true);
        let sum = (r.pe_um2 + r.buffer_um2 + r.birrd_um2 + r.dist_um2) * 1.2;
        assert!((sum - r.total_um2).abs() < 1e-6);
    }
}
