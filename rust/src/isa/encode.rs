//! Bit-level MINISA encoding/decoding.
//!
//! Instructions pack MSB-first into the byte stream the fetch unit reads at
//! 9 B/cycle. Count fields (G_r, G_c, s_m, T, VN_SIZE, layout factors) use
//! the "value − 1" encoding of Fig. 3 ("all fields encode value-1 omitting
//! zero"); index and stride fields (r0, c0, s_r, s_c, m0) encode directly.

use super::bitwidth::{IsaBitwidths, DF_BITS, OPCODE_BITS, ORDER_BITS};
use super::inst::{ActFn, BufTarget, Inst, LayoutInst, Opcode};
use crate::arch::config::ArchConfig;
use crate::layout::VnLayout;
use crate::mapping::{Dataflow, MappingCfg, StreamCfg};
use crate::util::{BitReader, BitWriter};

/// Encoding error.
#[derive(Debug, Clone, PartialEq)]
pub enum EncodeError {
    FieldOverflow { field: &'static str, value: u64, bits: u32 },
    Truncated,
    BadOpcode,
}

impl std::fmt::Display for EncodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EncodeError::FieldOverflow { field, value, bits } => {
                write!(f, "field {field} value {value} exceeds {bits}-bit range")
            }
            EncodeError::Truncated => write!(f, "truncated instruction stream"),
            EncodeError::BadOpcode => write!(f, "invalid opcode bits"),
        }
    }
}

impl std::error::Error for EncodeError {}

/// Stateless encoder/decoder bound to one architecture's field widths.
#[derive(Debug, Clone, Copy)]
pub struct Codec {
    pub bw: IsaBitwidths,
}

impl Codec {
    pub fn new(cfg: &ArchConfig) -> Self {
        Self { bw: IsaBitwidths::for_config(cfg) }
    }

    fn put(
        w: &mut BitWriter,
        field: &'static str,
        value: u64,
        bits: u32,
    ) -> Result<(), EncodeError> {
        if bits < 64 && value >= (1u64 << bits) {
            return Err(EncodeError::FieldOverflow { field, value, bits });
        }
        w.put(value, bits);
        Ok(())
    }

    /// Encode one instruction, appending to `w`. Returns the bit width.
    pub fn encode_into(&self, inst: &Inst, w: &mut BitWriter) -> Result<u32, EncodeError> {
        let start = w.len_bits();
        let bw = &self.bw;
        Self::put(w, "opcode", inst.opcode() as u64, OPCODE_BITS)?;
        match inst {
            Inst::SetIVNLayout(l) | Inst::SetWVNLayout(l) | Inst::SetOVNLayout(l) => {
                let v = &l.layout;
                Self::put(w, "order", v.order as u64, ORDER_BITS)?;
                Self::put(w, "n_l0", v.n_l0 as u64 - 1, bw.aw_bits)?;
                Self::put(w, "n_l1", v.n_l1 as u64 - 1, bw.stride_bits)?;
                Self::put(w, "r_l1", v.r_l1 as u64 - 1, bw.stride_bits)?;
            }
            Inst::ExecuteMapping(m) => {
                Self::put(w, "g_r", m.g_r as u64 - 1, bw.aw_bits)?;
                Self::put(w, "g_c", m.g_c as u64 - 1, bw.aw_bits)?;
                Self::put(w, "r0", m.r0 as u64, bw.slot_bits)?;
                Self::put(w, "c0", m.c0 as u64, bw.slot_bits)?;
                Self::put(w, "s_r", m.s_r as u64, bw.stride_bits)?;
                Self::put(w, "s_c", m.s_c as u64, bw.stride_bits)?;
            }
            Inst::ExecuteStreaming(s) => {
                Self::put(w, "df", s.df.bit(), DF_BITS)?;
                Self::put(w, "m0", s.m0 as u64, bw.stride_bits.saturating_sub(1).max(1))?;
                Self::put(w, "s_m", s.s_m as u64 - 1, bw.stride_bits.saturating_sub(1).max(1))?;
                Self::put(w, "vn_size", s.vn_size as u64 - 1, bw.vn_bits)?;
                Self::put(w, "t", s.t as u64 - 1, bw.stride_bits)?;
            }
            Inst::Load { target, hbm_addr, rows } | Inst::Store { target, hbm_addr, rows } => {
                Self::put(w, "hbm_addr", *hbm_addr, bw.hbm_bits)?;
                Self::put(w, "target", target.bit(), 1)?;
                Self::put(w, "rows", *rows as u64 - 1, bw.rows_bits)?;
            }
            Inst::Activation { func, target, rows } => {
                Self::put(w, "func", *func as u64, 2)?;
                Self::put(w, "target", target.bit(), 1)?;
                Self::put(w, "rows", *rows as u64 - 1, bw.rows_bits)?;
            }
        }
        Ok((w.len_bits() - start) as u32)
    }

    /// Encode a full instruction sequence into a byte stream.
    pub fn encode_all(&self, insts: &[Inst]) -> Result<Vec<u8>, EncodeError> {
        let mut w = BitWriter::new();
        for i in insts {
            self.encode_into(i, &mut w)?;
        }
        Ok(w.into_bytes())
    }

    /// Exact bit length of one instruction under this codec.
    pub fn width_bits(&self, inst: &Inst) -> u32 {
        let bw = &self.bw;
        match inst {
            Inst::SetIVNLayout(_) | Inst::SetWVNLayout(_) | Inst::SetOVNLayout(_) => {
                bw.set_layout()
            }
            Inst::ExecuteMapping(_) => bw.execute_mapping(),
            Inst::ExecuteStreaming(_) => bw.execute_streaming(),
            Inst::Load { .. } | Inst::Store { .. } => bw.load_store(),
            Inst::Activation { .. } => bw.activation(),
        }
    }

    fn get(r: &mut BitReader, bits: u32) -> Result<u64, EncodeError> {
        r.get(bits).ok_or(EncodeError::Truncated)
    }

    /// Decode one instruction from the cursor.
    pub fn decode_one(&self, r: &mut BitReader) -> Result<Inst, EncodeError> {
        let bw = &self.bw;
        let op = Opcode::from_bits(Self::get(r, OPCODE_BITS)?).ok_or(EncodeError::BadOpcode)?;
        let inst = match op {
            Opcode::SetIVNLayout | Opcode::SetWVNLayout | Opcode::SetOVNLayout => {
                let order = Self::get(r, ORDER_BITS)? as u8;
                let n_l0 = Self::get(r, bw.aw_bits)? as usize + 1;
                let n_l1 = Self::get(r, bw.stride_bits)? as usize + 1;
                let r_l1 = Self::get(r, bw.stride_bits)? as usize + 1;
                // Decoded VN size is the architectural AH implied by vn_bits.
                let vn = 1usize << bw.vn_bits;
                let li = LayoutInst { layout: VnLayout::new(order.min(5), n_l0, n_l1, r_l1, vn) };
                match op {
                    Opcode::SetIVNLayout => Inst::SetIVNLayout(li),
                    Opcode::SetWVNLayout => Inst::SetWVNLayout(li),
                    _ => Inst::SetOVNLayout(li),
                }
            }
            Opcode::ExecuteMapping => Inst::ExecuteMapping(MappingCfg {
                g_r: Self::get(r, bw.aw_bits)? as usize + 1,
                g_c: Self::get(r, bw.aw_bits)? as usize + 1,
                r0: Self::get(r, bw.slot_bits)? as usize,
                c0: Self::get(r, bw.slot_bits)? as usize,
                s_r: Self::get(r, bw.stride_bits)? as usize,
                s_c: Self::get(r, bw.stride_bits)? as usize,
            }),
            Opcode::ExecuteStreaming => Inst::ExecuteStreaming(StreamCfg {
                df: Dataflow::from_bit(Self::get(r, DF_BITS)?),
                m0: Self::get(r, bw.stride_bits.saturating_sub(1).max(1))? as usize,
                s_m: Self::get(r, bw.stride_bits.saturating_sub(1).max(1))? as usize + 1,
                vn_size: Self::get(r, bw.vn_bits)? as usize + 1,
                t: Self::get(r, bw.stride_bits)? as usize + 1,
            }),
            Opcode::Load | Opcode::Store => {
                let hbm_addr = Self::get(r, bw.hbm_bits)?;
                let target = BufTarget::from_bit(Self::get(r, 1)?);
                let rows = Self::get(r, bw.rows_bits)? as u32 + 1;
                if op == Opcode::Load {
                    Inst::Load { target, hbm_addr, rows }
                } else {
                    Inst::Store { target, hbm_addr, rows }
                }
            }
            Opcode::Activation => Inst::Activation {
                func: ActFn::from_bits(Self::get(r, 2)?),
                target: BufTarget::from_bit(Self::get(r, 1)?),
                rows: Self::get(r, bw.rows_bits)? as u32 + 1,
            },
        };
        Ok(inst)
    }

    /// Decode exactly `n` instructions from a byte stream.
    pub fn decode_n(&self, bytes: &[u8], n: usize) -> Result<Vec<Inst>, EncodeError> {
        let mut r = BitReader::new(bytes);
        (0..n).map(|_| self.decode_one(&mut r)).collect()
    }

    /// Decode `n` instructions into an **executable** stream: like
    /// [`Self::decode_n`], then rehydrate the implicit layout VN-size
    /// field. Fig. 5 encodes layouts without their reduction-L0 factor —
    /// "the VN size" — which the hardware binds only when the following
    /// `ExecuteStreaming` programs `VN_SIZE`. [`Self::decode_one`] can
    /// therefore only guess the architectural AH; this mirrors the
    /// hardware's binding instead, giving each layout the VN size of the
    /// next `ExecuteStreaming` in stream order (architectural AH when none
    /// follows), so decoded traces address buffers exactly like the traces
    /// that produced the bytes. This is the artifact loader's path back
    /// from the canonical encoded stream (`crate::artifact`).
    pub fn decode_stream(&self, bytes: &[u8], n: usize) -> Result<Vec<Inst>, EncodeError> {
        let mut insts = self.decode_n(bytes, n)?;
        let mut vn = 1usize << self.bw.vn_bits;
        for inst in insts.iter_mut().rev() {
            match inst {
                Inst::ExecuteStreaming(es) => vn = es.vn_size,
                Inst::SetIVNLayout(l) | Inst::SetWVNLayout(l) | Inst::SetOVNLayout(l) => {
                    l.layout.vn_size = vn;
                }
                _ => {}
            }
        }
        Ok(insts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    fn codec(ah: usize, aw: usize) -> (ArchConfig, Codec) {
        let cfg = ArchConfig::paper(ah, aw);
        let c = Codec::new(&cfg);
        (cfg, c)
    }

    fn sample_insts(cfg: &ArchConfig) -> Vec<Inst> {
        let vn = cfg.ah;
        vec![
            Inst::Load { target: BufTarget::Streaming, hbm_addr: 0x1234, rows: 64 },
            Inst::Load { target: BufTarget::Stationary, hbm_addr: 0xBEEF00, rows: 32 },
            Inst::SetIVNLayout(LayoutInst { layout: VnLayout::new(1, 2, 3, 4, vn) }),
            Inst::SetWVNLayout(LayoutInst { layout: VnLayout::new(2, 4, 1, 2, vn) }),
            Inst::SetOVNLayout(LayoutInst { layout: VnLayout::new(0, 1, 8, 1, vn) }),
            Inst::ExecuteMapping(MappingCfg { r0: 0, c0: 8, g_r: 2, g_c: 1, s_r: 1, s_c: 0 }),
            Inst::ExecuteStreaming(StreamCfg {
                df: Dataflow::WoS,
                m0: 0,
                s_m: 2,
                t: 16,
                vn_size: vn,
            }),
            Inst::Activation { func: ActFn::Relu, target: BufTarget::Streaming, rows: 16 },
            Inst::Store { target: BufTarget::Streaming, hbm_addr: 0xAB00, rows: 8 },
        ]
    }

    #[test]
    fn roundtrip_sample_program() {
        for (ah, aw) in [(4, 4), (8, 32), (16, 256)] {
            let (cfg, c) = codec(ah, aw);
            let prog = sample_insts(&cfg);
            let bytes = c.encode_all(&prog).unwrap();
            let decoded = c.decode_n(&bytes, prog.len()).unwrap();
            for (a, b) in prog.iter().zip(&decoded) {
                match (a, b) {
                    // Layout VN size is implicit in the encoding; compare
                    // the explicit fields only.
                    (Inst::SetIVNLayout(x), Inst::SetIVNLayout(y))
                    | (Inst::SetWVNLayout(x), Inst::SetWVNLayout(y))
                    | (Inst::SetOVNLayout(x), Inst::SetOVNLayout(y)) => {
                        assert_eq!(x.layout.order, y.layout.order);
                        assert_eq!(x.layout.n_l0, y.layout.n_l0);
                        assert_eq!(x.layout.n_l1, y.layout.n_l1);
                        assert_eq!(x.layout.r_l1, y.layout.r_l1);
                    }
                    _ => assert_eq!(a, b),
                }
            }
        }
    }

    #[test]
    fn encoded_width_matches_analysis() {
        let (cfg, c) = codec(16, 64);
        for inst in sample_insts(&cfg) {
            let mut w = BitWriter::new();
            let bits = c.encode_into(&inst, &mut w).unwrap();
            assert_eq!(bits, c.width_bits(&inst), "{inst:?}");
        }
    }

    #[test]
    fn field_overflow_rejected() {
        let (_, c) = codec(4, 4);
        // G_r beyond AW must fail to encode.
        let bad = Inst::ExecuteMapping(MappingCfg {
            r0: 0,
            c0: 0,
            g_r: 4096,
            g_c: 1,
            s_r: 0,
            s_c: 0,
        });
        assert!(matches!(
            c.encode_all(&[bad]),
            Err(EncodeError::FieldOverflow { field: "g_r", .. })
        ));
    }

    #[test]
    fn truncated_stream_detected() {
        let (cfg, c) = codec(4, 16);
        let bytes = c.encode_all(&sample_insts(&cfg)[..1]).unwrap();
        assert!(c.decode_n(&bytes, 2).is_err());
    }

    #[test]
    fn roundtrip_randomized() {
        forall("isa-roundtrip", 150, |g| {
            let configs = [(4usize, 4usize), (8, 32), (16, 64)];
            let &(ah, aw) = g.pick(&configs);
            let cfg = ArchConfig::paper(ah, aw);
            let c = Codec::new(&cfg);
            let d_ah = (cfg.d() / cfg.ah).max(2);
            let em = MappingCfg {
                r0: g.usize(0, 63),
                c0: g.usize(0, 63),
                g_r: g.usize(1, aw),
                g_c: g.usize(1, aw),
                s_r: g.usize(0, (d_ah - 1).min(1 << 10)),
                s_c: g.usize(0, (d_ah - 1).min(1 << 10)),
            };
            let es = StreamCfg {
                df: if g.bool() { Dataflow::WoS } else { Dataflow::IoS },
                m0: g.usize(0, 100),
                s_m: g.usize(1, 64),
                t: g.usize(1, 512),
                vn_size: g.usize(1, ah),
            };
            let prog = [Inst::ExecuteMapping(em), Inst::ExecuteStreaming(es)];
            let bytes = c.encode_all(&prog).unwrap();
            let dec = c.decode_n(&bytes, 2).unwrap();
            assert_eq!(dec[0], prog[0]);
            assert_eq!(dec[1], prog[1]);
        });
    }

    /// `decode_stream` recovers the implicit layout VN size from the
    /// following `ExecuteStreaming` — full structural equality even when
    /// the programmed VN is smaller than the architectural AH (where the
    /// raw `decode_n` can only guess AH).
    #[test]
    fn decode_stream_rehydrates_layout_vn() {
        let (cfg, c) = codec(4, 16);
        let lay = |vn: usize| LayoutInst { layout: VnLayout::new(1, 2, 3, 2, vn) };
        let es = |vn: usize| {
            Inst::ExecuteStreaming(StreamCfg { df: Dataflow::WoS, m0: 0, s_m: 1, t: 4, vn_size: vn })
        };
        let em = Inst::ExecuteMapping(MappingCfg { r0: 0, c0: 0, g_r: 1, g_c: 1, s_r: 1, s_c: 0 });
        // Two "layers" with different VN sizes (2, then 4), plus a trailing
        // layout with no following E.Streaming (falls back to AH).
        let prog = vec![
            Inst::SetIVNLayout(lay(2)),
            Inst::SetWVNLayout(lay(2)),
            Inst::SetOVNLayout(lay(2)),
            em,
            es(2),
            Inst::SetIVNLayout(lay(4)),
            em,
            es(4),
            Inst::SetOVNLayout(lay(cfg.ah)),
        ];
        let bytes = c.encode_all(&prog).unwrap();
        let decoded = c.decode_stream(&bytes, prog.len()).unwrap();
        assert_eq!(decoded, prog, "rehydrated stream is structurally identical");
        // The raw decode loses the vn=2 layouts (guesses AH = 4).
        let raw = c.decode_n(&bytes, prog.len()).unwrap();
        let Inst::SetIVNLayout(l) = &raw[0] else { panic!() };
        assert_eq!(l.layout.vn_size, cfg.ah);
        // Re-encoding either form reproduces the bytes (vn is not encoded).
        assert_eq!(c.encode_all(&decoded).unwrap(), bytes);
        assert_eq!(c.encode_all(&raw).unwrap(), bytes);
    }

    #[test]
    fn trace_byte_budget_is_tight() {
        // Stream length in bytes == ceil(sum of widths / 8).
        let (cfg, c) = codec(8, 8);
        let prog = sample_insts(&cfg);
        let total_bits: u32 = prog.iter().map(|i| c.width_bits(i)).sum();
        let bytes = c.encode_all(&prog).unwrap();
        assert_eq!(bytes.len(), (total_bits as usize).div_ceil(8));
    }
}
