//! MINISA program traces (§IV-G).
//!
//! The canonical trace for one layer is
//! `Set*VNLayout → {E.Mapping / E.Streaming}^T` plus Load/Store around it.
//! For consecutive layers, layer i's `SetOVNLayout` doubles as layer i+1's
//! `SetIVNLayout`, which is therefore skipped (§IV-G2).

use super::encode::Codec;
use super::inst::Inst;

/// A MINISA instruction trace with byte accounting.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub insts: Vec<Inst>,
    /// Layer boundaries (index of first instruction of each layer).
    pub layer_starts: Vec<usize>,
}

impl Trace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Rebuild a trace from decoded instructions plus layer boundaries —
    /// the artifact loader's way back from the canonical encoded stream
    /// (`crate::artifact`; layer starts travel in the container, not the
    /// byte stream).
    pub fn from_insts(insts: Vec<Inst>, layer_starts: Vec<usize>) -> Self {
        Self { insts, layer_starts }
    }

    pub fn push(&mut self, inst: Inst) {
        self.insts.push(inst);
    }

    pub fn extend(&mut self, insts: impl IntoIterator<Item = Inst>) {
        self.insts.extend(insts);
    }

    /// Mark the start of a new layer at the current position.
    pub fn begin_layer(&mut self) {
        self.layer_starts.push(self.insts.len());
    }

    /// Append another trace's instructions as one new layer (the §IV-G
    /// multi-layer fusion primitive). The other trace's own layer marks are
    /// ignored: per-layer lowered programs are single-layer traces.
    pub fn splice_layer(&mut self, other: &Trace) {
        self.begin_layer();
        self.insts.extend(other.insts.iter().copied());
    }

    pub fn len(&self) -> usize {
        self.insts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.insts.is_empty()
    }

    /// Number of layers marked in this trace.
    pub fn layer_count(&self) -> usize {
        self.layer_starts.len()
    }

    /// Instruction index range of layer `li`, if marked.
    pub fn layer_range(&self, li: usize) -> Option<std::ops::Range<usize>> {
        let start = *self.layer_starts.get(li)?;
        let end = self.layer_starts.get(li + 1).copied().unwrap_or(self.insts.len());
        Some(start..end)
    }

    /// Total encoded size in bits under a codec. Takes the caller's
    /// [`Codec`] instead of rebuilding one per call — the mapper scores
    /// thousands of candidate traces per search, and `Codec::new` re-derives
    /// every field width each time ([`Codec`] is `Copy`; build it once).
    pub fn size_bits(&self, codec: &Codec) -> u64 {
        self.insts.iter().map(|i| codec.width_bits(i) as u64).sum()
    }

    /// Total encoded size in bytes (the off-chip instruction traffic).
    pub fn size_bytes(&self, codec: &Codec) -> u64 {
        self.size_bits(codec).div_ceil(8)
    }

    /// Count instructions of each class: (config, compute-trigger, memory,
    /// activation).
    pub fn class_counts(&self) -> (usize, usize, usize, usize) {
        let mut cfg_only = 0;
        let mut compute = 0;
        let mut memory = 0;
        let mut act = 0;
        for i in &self.insts {
            if i.is_config_only() {
                cfg_only += 1;
            } else if i.is_compute_trigger() {
                compute += 1;
            } else if matches!(i, Inst::Activation { .. }) {
                act += 1;
            } else {
                memory += 1;
            }
        }
        (cfg_only, compute, memory, act)
    }

    /// Encoded bits per class under a codec: (config-only, compute-trigger,
    /// memory, activation) — the byte-accounting twin of
    /// [`Self::class_counts`], sharing its classification.
    pub fn class_bits(&self, codec: &Codec) -> (u64, u64, u64, u64) {
        let mut cfg_only = 0;
        let mut compute = 0;
        let mut memory = 0;
        let mut act = 0;
        for i in &self.insts {
            let w = codec.width_bits(i) as u64;
            if i.is_config_only() {
                cfg_only += w;
            } else if i.is_compute_trigger() {
                compute += w;
            } else if matches!(i, Inst::Activation { .. }) {
                act += w;
            } else {
                memory += w;
            }
        }
        (cfg_only, compute, memory, act)
    }

    /// Number of compute tiles = number of E.Mapping/E.Streaming pairs.
    pub fn tile_count(&self) -> usize {
        self.insts.iter().filter(|i| matches!(i, Inst::ExecuteMapping(_))).count()
    }

    /// Inter-layer elision (§IV-G2): remove each layer's `SetIVNLayout` when
    /// the previous layer ended with a `SetOVNLayout` describing the same
    /// layout (output of layer i *is* the input of layer i+1). Returns the
    /// number of instructions elided.
    pub fn elide_interlayer_layouts(&mut self) -> usize {
        let mut drop = vec![false; self.insts.len()];
        let mut elided = 0;
        for (li, &start) in self.layer_starts.iter().enumerate().skip(1) {
            let prev_range = self.layer_starts[li - 1]..start;
            let prev_ovn = self.insts[prev_range]
                .iter()
                .rev()
                .find_map(|i| match i {
                    Inst::SetOVNLayout(l) => Some(l.layout),
                    _ => None,
                });
            let end = self.layer_starts.get(li + 1).copied().unwrap_or(self.insts.len());
            if let Some(prev) = prev_ovn {
                for idx in start..end {
                    if let Inst::SetIVNLayout(l) = &self.insts[idx] {
                        if l.layout == prev {
                            drop[idx] = true;
                            elided += 1;
                        }
                        break; // only the leading SetIVNLayout is elidable
                    }
                }
            }
        }
        if elided > 0 {
            let mut kept = Vec::with_capacity(self.insts.len() - elided);
            let mut new_starts = Vec::with_capacity(self.layer_starts.len());
            let mut removed_before = 0usize;
            let mut next_layer = 0usize;
            for (idx, inst) in self.insts.iter().enumerate() {
                while next_layer < self.layer_starts.len()
                    && self.layer_starts[next_layer] == idx
                {
                    new_starts.push(idx - removed_before);
                    next_layer += 1;
                }
                if drop[idx] {
                    removed_before += 1;
                } else {
                    kept.push(*inst);
                }
            }
            self.insts = kept;
            self.layer_starts = new_starts;
        }
        elided
    }

    /// Human-readable disassembly.
    pub fn disassemble(&self) -> String {
        let mut s = String::new();
        let mut layer = 0usize;
        for (idx, inst) in self.insts.iter().enumerate() {
            if self.layer_starts.get(layer) == Some(&idx) {
                s.push_str(&format!("; ---- layer {layer} ----\n"));
                layer += 1;
            }
            s.push_str(&format!("{idx:6}: {}\n", disasm_one(inst)));
        }
        s
    }
}

fn disasm_one(inst: &Inst) -> String {
    match inst {
        Inst::SetIVNLayout(l) => format!(
            "SetIVNLayout  order={} M_L0={} M_L1={} J_L1={}",
            l.layout.order, l.layout.n_l0, l.layout.n_l1, l.layout.r_l1
        ),
        Inst::SetWVNLayout(l) => format!(
            "SetWVNLayout  order={} N_L0={} N_L1={} K_L1={}",
            l.layout.order, l.layout.n_l0, l.layout.n_l1, l.layout.r_l1
        ),
        Inst::SetOVNLayout(l) => format!(
            "SetOVNLayout  order={} P_L0={} P_L1={} Q_L1={}",
            l.layout.order, l.layout.n_l0, l.layout.n_l1, l.layout.r_l1
        ),
        Inst::ExecuteMapping(m) => format!(
            "E.Mapping     r0={} c0={} G_r={} G_c={} s_r={} s_c={}",
            m.r0, m.c0, m.g_r, m.g_c, m.s_r, m.s_c
        ),
        Inst::ExecuteStreaming(s) => format!(
            "E.Streaming   df={:?} m0={} s_m={} T={} VN={}",
            s.df, s.m0, s.s_m, s.t, s.vn_size
        ),
        Inst::Load { target, hbm_addr, rows } => {
            format!("Load          {target:?} hbm={hbm_addr:#x} rows={rows}")
        }
        Inst::Store { target, hbm_addr, rows } => {
            format!("Write         {target:?} hbm={hbm_addr:#x} rows={rows}")
        }
        Inst::Activation { func, target, rows } => {
            format!("Activation    {func:?} {target:?} rows={rows}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::config::ArchConfig;
    use crate::isa::inst::{BufTarget, LayoutInst};
    use crate::layout::VnLayout;
    use crate::mapping::{Dataflow, MappingCfg, StreamCfg};

    fn layer(t: &mut Trace, ivn: VnLayout, ovn: VnLayout, tiles: usize) {
        t.begin_layer();
        t.push(Inst::SetIVNLayout(LayoutInst { layout: ivn }));
        t.push(Inst::SetWVNLayout(LayoutInst { layout: VnLayout::row_major(2, 8, 4) }));
        t.push(Inst::SetOVNLayout(LayoutInst { layout: ovn }));
        for i in 0..tiles {
            t.push(Inst::ExecuteMapping(MappingCfg {
                r0: i,
                c0: 0,
                g_r: 1,
                g_c: 1,
                s_r: 1,
                s_c: 0,
            }));
            t.push(Inst::ExecuteStreaming(StreamCfg {
                df: Dataflow::WoS,
                m0: 0,
                s_m: 1,
                t: 4,
                vn_size: 4,
            }));
        }
    }

    #[test]
    fn canonical_layer_structure() {
        let mut t = Trace::new();
        layer(&mut t, VnLayout::row_major(1, 4, 4), VnLayout::row_major(1, 4, 4), 3);
        let (cfg_only, compute, memory, act) = t.class_counts();
        assert_eq!(cfg_only, 2); // IVN + WVN layouts
        assert_eq!(compute, 6); // 3 pairs
        assert_eq!(memory, 1); // OVN layout
        assert_eq!(act, 0);
        assert_eq!(t.tile_count(), 3);
    }

    #[test]
    fn interlayer_elision_drops_matching_ivn() {
        let shared = VnLayout::new(1, 4, 2, 2, 4);
        let mut t = Trace::new();
        layer(&mut t, VnLayout::row_major(1, 4, 4), shared, 2);
        layer(&mut t, shared, VnLayout::row_major(2, 2, 4), 2);
        let before = t.len();
        let elided = t.elide_interlayer_layouts();
        assert_eq!(elided, 1);
        assert_eq!(t.len(), before - 1);
        // Layer 1 must no longer start with a SetIVNLayout.
        let l1 = t.layer_starts[1];
        assert!(!matches!(t.insts[l1], Inst::SetIVNLayout(_)));
    }

    #[test]
    fn elision_keeps_mismatched_layouts() {
        let mut t = Trace::new();
        layer(&mut t, VnLayout::row_major(1, 4, 4), VnLayout::new(1, 4, 2, 2, 4), 1);
        layer(&mut t, VnLayout::new(3, 2, 2, 2, 4), VnLayout::row_major(2, 2, 4), 1);
        assert_eq!(t.elide_interlayer_layouts(), 0);
    }

    #[test]
    fn splice_layer_marks_boundaries() {
        let mut a = Trace::new();
        layer(&mut a, VnLayout::row_major(1, 4, 4), VnLayout::row_major(1, 4, 4), 2);
        let mut b = Trace::new();
        layer(&mut b, VnLayout::row_major(1, 4, 4), VnLayout::row_major(2, 2, 4), 1);
        let mut fused = Trace::new();
        fused.splice_layer(&a);
        fused.splice_layer(&b);
        assert_eq!(fused.layer_count(), 2);
        assert_eq!(fused.len(), a.len() + b.len());
        assert_eq!(fused.layer_range(0), Some(0..a.len()));
        assert_eq!(fused.layer_range(1), Some(a.len()..a.len() + b.len()));
        assert_eq!(fused.layer_range(2), None);
    }

    #[test]
    fn size_accounting() {
        let codec = Codec::new(&ArchConfig::paper(4, 4));
        let mut t = Trace::new();
        layer(&mut t, VnLayout::row_major(1, 4, 4), VnLayout::row_major(1, 4, 4), 2);
        t.push(Inst::Load { target: BufTarget::Streaming, hbm_addr: 0, rows: 4 });
        let bits = t.size_bits(&codec);
        assert!(bits > 0);
        assert_eq!(t.size_bytes(&codec), bits.div_ceil(8));
    }

    #[test]
    fn class_bits_partition_total_size() {
        let codec = Codec::new(&ArchConfig::paper(4, 4));
        let mut t = Trace::new();
        layer(&mut t, VnLayout::row_major(1, 4, 4), VnLayout::row_major(2, 2, 4), 3);
        t.push(Inst::Store { target: BufTarget::Streaming, hbm_addr: 0, rows: 2 });
        let (b0, b1, b2, b3) = t.class_bits(&codec);
        assert_eq!(b0 + b1 + b2 + b3, t.size_bits(&codec), "classes partition the stream");
        let (c0, c1, c2, c3) = t.class_counts();
        // Non-empty classes carry bits and vice versa.
        for (c, b) in [(c0, b0), (c1, b1), (c2, b2), (c3, b3)] {
            assert_eq!(c == 0, b == 0);
        }
    }

    #[test]
    fn from_insts_preserves_structure() {
        let mut t = Trace::new();
        layer(&mut t, VnLayout::row_major(1, 4, 4), VnLayout::row_major(1, 4, 4), 2);
        layer(&mut t, VnLayout::row_major(1, 4, 4), VnLayout::row_major(2, 2, 4), 1);
        let rebuilt = Trace::from_insts(t.insts.clone(), t.layer_starts.clone());
        assert_eq!(rebuilt.len(), t.len());
        assert_eq!(rebuilt.layer_count(), 2);
        assert_eq!(rebuilt.layer_range(1), t.layer_range(1));
    }

    #[test]
    fn disassembly_mentions_layers() {
        let mut t = Trace::new();
        layer(&mut t, VnLayout::row_major(1, 4, 4), VnLayout::row_major(1, 4, 4), 1);
        layer(&mut t, VnLayout::row_major(1, 4, 4), VnLayout::row_major(1, 4, 4), 1);
        let d = t.disassemble();
        assert!(d.contains("layer 0"));
        assert!(d.contains("layer 1"));
        assert!(d.contains("E.Mapping"));
        assert!(d.contains("SetWVNLayout"));
    }
}
