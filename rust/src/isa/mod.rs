//! MINISA — the minimal VN-level instruction set (§IV, Tab. II).
//!
//! Eight instructions:
//! three layout setters (`SetIVNLayout`, `SetWVNLayout`, `SetOVNLayout`),
//! two compute triggers (`ExecuteMapping`, `ExecuteStreaming`),
//! two memory movers (`Load`, `Write`/Store) and `Activation`.

pub mod bitwidth;
pub mod encode;
pub mod inst;
pub mod opt;
pub mod trace;

pub use bitwidth::IsaBitwidths;
pub use inst::{ActFn, BufTarget, Inst, LayoutInst, Opcode};
pub use trace::Trace;
