//! MINISA instruction definitions (Tab. II, Figs. 3 & 5).

use crate::layout::VnLayout;
use crate::mapping::{MappingCfg, StreamCfg};

/// 3-bit opcodes. Values follow Fig. 3/5 where given (`ExecuteStreaming` =
/// 011, `ExecuteMapping` = 111, SetWVN = 000, SetIVN = 001, SetOVN = 010,
/// Load = 101, Store = 100); `Activation` takes the remaining code.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Opcode {
    SetWVNLayout = 0b000,
    SetIVNLayout = 0b001,
    SetOVNLayout = 0b010,
    ExecuteStreaming = 0b011,
    Store = 0b100,
    Load = 0b101,
    Activation = 0b110,
    ExecuteMapping = 0b111,
}

impl Opcode {
    pub fn from_bits(b: u64) -> Option<Self> {
        Some(match b {
            0b000 => Opcode::SetWVNLayout,
            0b001 => Opcode::SetIVNLayout,
            0b010 => Opcode::SetOVNLayout,
            0b011 => Opcode::ExecuteStreaming,
            0b100 => Opcode::Store,
            0b101 => Opcode::Load,
            0b110 => Opcode::Activation,
            0b111 => Opcode::ExecuteMapping,
            _ => return None,
        })
    }
}

/// Which on-chip buffer a Load/Store/Activation targets (1-bit field:
/// 0 = stationary, 1 = streaming — Fig. 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BufTarget {
    Stationary,
    Streaming,
}

impl BufTarget {
    pub fn bit(self) -> u64 {
        match self {
            BufTarget::Stationary => 0,
            BufTarget::Streaming => 1,
        }
    }
    pub fn from_bit(b: u64) -> Self {
        if b == 0 { BufTarget::Stationary } else { BufTarget::Streaming }
    }
}

/// Activation functions applied in-buffer (supporting ISA, Tab. II).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum ActFn {
    None = 0,
    Relu = 1,
    Gelu = 2,
    Softmax = 3,
}

impl ActFn {
    pub fn from_bits(b: u64) -> Self {
        match b {
            1 => ActFn::Relu,
            2 => ActFn::Gelu,
            3 => ActFn::Softmax,
            _ => ActFn::None,
        }
    }
}

/// A layout-setting instruction body: the Tab. III order id plus the three
/// partition factors (Fig. 5 fields). The reduction-L0 factor is implicit
/// (= VN size).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LayoutInst {
    pub layout: VnLayout,
}

/// One MINISA instruction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Inst {
    SetIVNLayout(LayoutInst),
    SetWVNLayout(LayoutInst),
    /// Also initializes the output tile for accumulation and commits the
    /// finished tile at tile boundaries (§IV-G1).
    SetOVNLayout(LayoutInst),
    ExecuteMapping(MappingCfg),
    ExecuteStreaming(StreamCfg),
    Load {
        target: BufTarget,
        hbm_addr: u64,
        /// Buffer rows transferred (AW elements each).
        rows: u32,
    },
    Store {
        target: BufTarget,
        hbm_addr: u64,
        rows: u32,
    },
    Activation {
        func: ActFn,
        target: BufTarget,
        rows: u32,
    },
}

impl Inst {
    pub fn opcode(&self) -> Opcode {
        match self {
            Inst::SetIVNLayout(_) => Opcode::SetIVNLayout,
            Inst::SetWVNLayout(_) => Opcode::SetWVNLayout,
            Inst::SetOVNLayout(_) => Opcode::SetOVNLayout,
            Inst::ExecuteMapping(_) => Opcode::ExecuteMapping,
            Inst::ExecuteStreaming(_) => Opcode::ExecuteStreaming,
            Inst::Load { .. } => Opcode::Load,
            Inst::Store { .. } => Opcode::Store,
            Inst::Activation { .. } => Opcode::Activation,
        }
    }

    /// Configuration-only instructions program state registers without
    /// moving data or triggering compute (§IV-G1).
    pub fn is_config_only(&self) -> bool {
        matches!(
            self,
            Inst::SetIVNLayout(_) | Inst::SetWVNLayout(_)
        )
    }

    /// Compute-trigger instructions (§IV-G1): FEATHER+ only starts on-chip
    /// activity when it receives the E.Mapping/E.Streaming pair.
    pub fn is_compute_trigger(&self) -> bool {
        matches!(self, Inst::ExecuteMapping(_) | Inst::ExecuteStreaming(_))
    }

    /// Memory-movement instructions (§IV-G1). SetOVNLayout manages the
    /// output-buffer lifecycle, so it belongs to this class.
    pub fn is_memory(&self) -> bool {
        matches!(
            self,
            Inst::Load { .. } | Inst::Store { .. } | Inst::SetOVNLayout(_)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layout::VnLayout;
    use crate::mapping::Dataflow;

    #[test]
    fn opcode_roundtrip() {
        for b in 0..8u64 {
            let op = Opcode::from_bits(b).unwrap();
            assert_eq!(op as u64, b);
        }
        assert!(Opcode::from_bits(8).is_none());
    }

    #[test]
    fn opcode_values_match_figures() {
        // Fig. 3: ExecuteMapping = 111, ExecuteStreaming = 011.
        assert_eq!(Opcode::ExecuteMapping as u8, 0b111);
        assert_eq!(Opcode::ExecuteStreaming as u8, 0b011);
        // Fig. 5: SetWVN 000, SetIVN 001, SetOVN 010, Load 101 / Store 100.
        assert_eq!(Opcode::SetWVNLayout as u8, 0b000);
        assert_eq!(Opcode::SetIVNLayout as u8, 0b001);
        assert_eq!(Opcode::SetOVNLayout as u8, 0b010);
        assert_eq!(Opcode::Load as u8, 0b101);
        assert_eq!(Opcode::Store as u8, 0b100);
    }

    #[test]
    fn instruction_classes() {
        let lay = LayoutInst { layout: VnLayout::row_major(1, 1, 4) };
        assert!(Inst::SetIVNLayout(lay).is_config_only());
        assert!(Inst::SetWVNLayout(lay).is_config_only());
        assert!(Inst::SetOVNLayout(lay).is_memory());
        assert!(Inst::Load { target: BufTarget::Streaming, hbm_addr: 0, rows: 1 }.is_memory());
        let em = Inst::ExecuteMapping(crate::mapping::MappingCfg {
            r0: 0,
            c0: 0,
            g_r: 1,
            g_c: 1,
            s_r: 0,
            s_c: 0,
        });
        assert!(em.is_compute_trigger());
        let es = Inst::ExecuteStreaming(crate::mapping::StreamCfg {
            df: Dataflow::WoS,
            m0: 0,
            s_m: 1,
            t: 1,
            vn_size: 4,
        });
        assert!(es.is_compute_trigger());
        assert!(!es.is_memory());
    }

    #[test]
    fn target_and_act_bits() {
        assert_eq!(BufTarget::from_bit(0), BufTarget::Stationary);
        assert_eq!(BufTarget::from_bit(1), BufTarget::Streaming);
        assert_eq!(ActFn::from_bits(ActFn::Softmax as u64), ActFn::Softmax);
        assert_eq!(ActFn::from_bits(0), ActFn::None);
    }
}
