//! ISA bitwidth analysis (§IV-C2, Figs. 3 & 5 → Table V).
//!
//! Field widths are sized for the *maximum ratio* between on-chip buffer
//! capacity and architectural dimensions, so any workload that fits on chip
//! is encodable. `D` is the stationary/streaming buffer depth.

use crate::arch::config::ArchConfig;
use crate::util::clog2;

pub const OPCODE_BITS: u32 = 3;
pub const ORDER_BITS: u32 = 3; // ⌈log2 3!⌉
pub const DF_BITS: u32 = 1;

/// Per-instruction field widths for one architecture configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IsaBitwidths {
    /// ⌈log2 AW⌉ — G_r / G_c and level-0 non-reduction factors.
    pub aw_bits: u32,
    /// ⌈log2(D/AH·AW)⌉ — r0/c0 (VN slot indices).
    pub slot_bits: u32,
    /// ⌈log2(D/AH)⌉ — strides s_r/s_c, level-1 factors, T, m0, s_m.
    pub stride_bits: u32,
    /// log2(AH) — VN_SIZE field.
    pub vn_bits: u32,
    /// ⌈log2 HBM bytes⌉ — Load/Store address.
    pub hbm_bits: u32,
    /// ⌈log2 D⌉ — row counts for Load/Store/Activation.
    pub rows_bits: u32,
}

impl IsaBitwidths {
    pub fn for_config(cfg: &ArchConfig) -> Self {
        let d = cfg.d();
        let vn_rows = (d / cfg.ah).max(1); // D/AH
        Self {
            aw_bits: clog2(cfg.aw).max(1),
            slot_bits: clog2(vn_rows * cfg.aw).max(1),
            stride_bits: clog2(vn_rows).max(1),
            vn_bits: clog2(cfg.ah).max(1),
            hbm_bits: clog2(cfg.hbm_bytes as usize).max(1),
            rows_bits: clog2(d).max(1),
        }
    }

    /// ExecuteMapping width (Fig. 3):
    /// opcode + G_r + G_c + r0 + c0 + s_r + s_c.
    pub fn execute_mapping(&self) -> u32 {
        OPCODE_BITS + 2 * self.aw_bits + 2 * self.slot_bits + 2 * self.stride_bits
    }

    /// ExecuteStreaming width (Fig. 3):
    /// opcode + df + m0 + s_m + VN_SIZE + T ("value−1" encoding keeps the
    /// m0/s_m fields one bit narrower — Fig. 3 shows ⌈log2(D/AH)⌉−1).
    pub fn execute_streaming(&self) -> u32 {
        OPCODE_BITS
            + DF_BITS
            + (self.stride_bits.saturating_sub(1))
            + (self.stride_bits.saturating_sub(1))
            + self.vn_bits
            + self.stride_bits
    }

    /// Set*VNLayout width (Fig. 5): opcode + order + L0 + L1 + R_L1.
    pub fn set_layout(&self) -> u32 {
        OPCODE_BITS + ORDER_BITS + self.aw_bits + 2 * self.stride_bits
    }

    /// Load/Store width (Fig. 5): opcode + HBM address + target + rows.
    pub fn load_store(&self) -> u32 {
        OPCODE_BITS + self.hbm_bits + 1 + self.rows_bits
    }

    /// Activation width: opcode + func(2) + target + rows.
    pub fn activation(&self) -> u32 {
        OPCODE_BITS + 2 + 1 + self.rows_bits
    }

    /// Width of the widest instruction — the fetch unit's record size.
    pub fn max_width(&self) -> u32 {
        self.execute_mapping()
            .max(self.execute_streaming())
            .max(self.set_layout())
            .max(self.load_store())
            .max(self.activation())
    }
}

/// One row of Table V for reporting.
#[derive(Debug, Clone)]
pub struct TableVRow {
    pub config: String,
    pub set_layout_bits: u32,
    pub execute_mapping_bits: u32,
    pub execute_streaming_bits: u32,
}

/// Regenerate Table V for the paper's nine configurations.
pub fn table_v() -> Vec<TableVRow> {
    ArchConfig::paper_sweep()
        .iter()
        .map(|cfg| {
            let bw = IsaBitwidths::for_config(cfg);
            TableVRow {
                config: cfg.name(),
                set_layout_bits: bw.set_layout(),
                execute_mapping_bits: bw.execute_mapping(),
                execute_streaming_bits: bw.execute_streaming(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widths_monotone_in_depth() {
        // Wider arrays at fixed capacity → shallower buffers → narrower
        // stride/slot fields; Table V shows Set*VNLayout shrinking with AW.
        let a = IsaBitwidths::for_config(&ArchConfig::paper(16, 16));
        let b = IsaBitwidths::for_config(&ArchConfig::paper(16, 64));
        let c = IsaBitwidths::for_config(&ArchConfig::paper(16, 256));
        assert!(a.set_layout() > b.set_layout());
        assert!(b.set_layout() > c.set_layout());
        // E.Mapping never shrinks with AW (slot indices span D/AH·AW, which
        // is capacity-invariant, while G_r/G_c widen).
        assert!(a.execute_mapping() <= c.execute_mapping());
    }

    #[test]
    fn table_v_shape_matches_paper() {
        // Paper Table V: Set*VNLayout 38–44 bits, E.Mapping 81–95 bits,
        // E.Streaming 45–59 bits across the nine setups. Our derivation
        // from first principles should land in the same bands (±4 bits —
        // the paper's exact buffer-depth rounding isn't published).
        for row in table_v() {
            assert!(
                (34..=48).contains(&row.set_layout_bits),
                "{}: set_layout {}",
                row.config,
                row.set_layout_bits
            );
            assert!(
                (75..=99).contains(&row.execute_mapping_bits),
                "{}: e.mapping {}",
                row.config,
                row.execute_mapping_bits
            );
            assert!(
                (38..=63).contains(&row.execute_streaming_bits),
                "{}: e.streaming {}",
                row.config,
                row.execute_streaming_bits
            );
        }
    }

    #[test]
    fn mapping_is_widest_compute_inst() {
        for cfg in ArchConfig::paper_sweep() {
            let bw = IsaBitwidths::for_config(&cfg);
            assert!(bw.execute_mapping() > bw.execute_streaming());
            assert!(bw.execute_mapping() > bw.set_layout());
        }
    }

    #[test]
    fn load_store_has_hbm_width() {
        let cfg = ArchConfig::paper(4, 4);
        let bw = IsaBitwidths::for_config(&cfg);
        assert_eq!(bw.hbm_bits, 35); // 32 GiB
        assert!(bw.load_store() > bw.hbm_bits);
    }

    #[test]
    fn nine_rows() {
        assert_eq!(table_v().len(), 9);
    }
}
