//! Peephole trace optimizer.
//!
//! The mapper's lowering is deliberately canonical (one Set*VNLayout per
//! k-tile, loads per operand); across tiles and layers many of those
//! instructions are redundant. These passes shrink traces further without
//! changing semantics — the functional simulator is the equivalence oracle
//! (see `optimizer_preserves_semantics` below and the integration tests):
//!
//! 1. **Redundant layout elimination** — a Set{I,W}VNLayout whose layout
//!    equals the one already in effect is dropped (configuration-only
//!    instructions are idempotent, §IV-G1). SetOVNLayout is *not* elidable:
//!    it clears/commits the output tile (lifecycle side effects).
//! 2. **Dead load elimination** — a Load into a buffer that is overwritten
//!    by another Load into the same target before any compute trigger or
//!    Store consumes it.
//! 3. **Inter-layer elision** — re-export of `Trace::elide_interlayer_layouts`
//!    (§IV-G2) for fused multi-layer traces.

use super::inst::Inst;
use super::trace::Trace;
use crate::layout::VnLayout;

/// Statistics from one optimization run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OptStats {
    pub redundant_layouts: usize,
    pub dead_loads: usize,
    pub interlayer_elided: usize,
}

impl OptStats {
    pub fn total(&self) -> usize {
        self.redundant_layouts + self.dead_loads + self.interlayer_elided
    }
}

/// Pass 1: drop Set{I,W}VNLayout instructions that re-program the current
/// layout.
pub fn eliminate_redundant_layouts(trace: &mut Trace) -> usize {
    let mut cur_i: Option<VnLayout> = None;
    let mut cur_w: Option<VnLayout> = None;
    let mut drop = vec![false; trace.insts.len()];
    for (idx, inst) in trace.insts.iter().enumerate() {
        match inst {
            Inst::SetIVNLayout(l) => {
                if cur_i == Some(l.layout) {
                    drop[idx] = true;
                } else {
                    cur_i = Some(l.layout);
                }
            }
            Inst::SetWVNLayout(l) => {
                if cur_w == Some(l.layout) {
                    drop[idx] = true;
                } else {
                    cur_w = Some(l.layout);
                }
            }
            _ => {}
        }
    }
    apply_drops(trace, &drop)
}

/// Pass 2: drop Loads whose data is overwritten before any use. A "use" is
/// any compute trigger (ExecuteStreaming reads both buffers), Store or
/// Activation on the same target.
pub fn eliminate_dead_loads(trace: &mut Trace) -> usize {
    let mut drop = vec![false; trace.insts.len()];
    let mut pending: [Option<usize>; 2] = [None, None]; // per BufTarget
    let idx_of = |t: crate::isa::inst::BufTarget| match t {
        crate::isa::inst::BufTarget::Stationary => 0usize,
        crate::isa::inst::BufTarget::Streaming => 1usize,
    };
    for (idx, inst) in trace.insts.iter().enumerate() {
        match inst {
            Inst::Load { target, .. } => {
                if let Some(prev) = pending[idx_of(*target)] {
                    drop[prev] = true; // overwritten before use
                }
                pending[idx_of(*target)] = Some(idx);
            }
            Inst::ExecuteStreaming(_) => {
                // Consumes both buffers.
                pending = [None, None];
            }
            Inst::Store { target, .. } | Inst::Activation { target, .. } => {
                pending[idx_of(*target)] = None;
            }
            // SetOVNLayout commits OB into an operand buffer → treats both
            // as potentially read by the commit's write-back pattern.
            Inst::SetOVNLayout(_) => {
                pending = [None, None];
            }
            _ => {}
        }
    }
    apply_drops(trace, &drop)
}

fn apply_drops(trace: &mut Trace, drop: &[bool]) -> usize {
    let n = drop.iter().filter(|&&d| d).count();
    if n == 0 {
        return 0;
    }
    let mut kept = Vec::with_capacity(trace.insts.len() - n);
    let mut new_starts = Vec::with_capacity(trace.layer_starts.len());
    let mut removed = 0usize;
    let mut next_layer = 0usize;
    for (idx, inst) in trace.insts.iter().enumerate() {
        while next_layer < trace.layer_starts.len() && trace.layer_starts[next_layer] == idx {
            new_starts.push(idx - removed);
            next_layer += 1;
        }
        if drop[idx] {
            removed += 1;
        } else {
            kept.push(*inst);
        }
    }
    trace.insts = kept;
    trace.layer_starts = new_starts;
    n
}

/// Run all passes to a fixed point.
pub fn optimize(trace: &mut Trace) -> OptStats {
    let mut stats = OptStats::default();
    loop {
        let a = eliminate_redundant_layouts(trace);
        let b = eliminate_dead_loads(trace);
        let c = trace.elide_interlayer_layouts();
        stats.redundant_layouts += a;
        stats.dead_loads += b;
        stats.interlayer_elided += c;
        if a + b + c == 0 {
            return stats;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArchConfig;
    use crate::mapper::exec::execute_program;
    use crate::mapper::search::{search, MapperOptions};
    use crate::util::Lcg;
    use crate::workloads::Gemm;

    #[test]
    fn redundant_layouts_removed() {
        let cfg = ArchConfig::paper(4, 4);
        let g = Gemm::new("o", "t", 16, 24, 16); // multiple k-tiles →
                                                  // repeated identical layouts
        let opts = MapperOptions { full_layout_search: false, ..Default::default() };
        let d = search(&cfg, &g, &opts).unwrap();
        let mut prog =
            crate::mapper::lower_gemm(&cfg, &g, &d.choice, d.i_order, d.w_order, d.o_order);
        let before = prog.trace.len();
        let stats = optimize(&mut prog.trace);
        assert!(prog.trace.len() <= before);
        // Whatever was removed is reflected in the stats.
        assert_eq!(before - prog.trace.len(), stats.total());
    }

    #[test]
    fn optimizer_preserves_semantics() {
        // The defining property: optimized traces compute identical outputs.
        let cfg = ArchConfig::paper(4, 4);
        let opts = MapperOptions { full_layout_search: false, ..Default::default() };
        for (m, k, n) in [(16usize, 24usize, 16usize), (10, 20, 14), (32, 8, 32)] {
            let g = Gemm::new("o", "t", m, k, n);
            let d = search(&cfg, &g, &opts).unwrap();
            let mut prog =
                crate::mapper::lower_gemm(&cfg, &g, &d.choice, d.i_order, d.w_order, d.o_order);
            let mut rng = Lcg::new(1);
            let iv: Vec<i32> = (0..m * k).map(|_| rng.range(0, 9) as i32 - 4).collect();
            let wv: Vec<i32> = (0..k * n).map(|_| rng.range(0, 9) as i32 - 4).collect();
            let base = execute_program(&cfg, &g, &prog, &iv, &wv).unwrap();
            let stats = optimize(&mut prog.trace);
            let opt = execute_program(&cfg, &g, &prog, &iv, &wv).unwrap();
            assert_eq!(base, opt, "({m},{k},{n}) after removing {}", stats.total());
        }
    }

    #[test]
    fn dead_load_detected() {
        use crate::isa::inst::{BufTarget, Inst};
        let mut t = Trace::new();
        t.push(Inst::Load { target: BufTarget::Streaming, hbm_addr: 0, rows: 1 });
        t.push(Inst::Load { target: BufTarget::Streaming, hbm_addr: 64, rows: 1 });
        assert_eq!(eliminate_dead_loads(&mut t), 1);
        // The surviving load is the second one.
        assert!(matches!(t.insts[0], Inst::Load { hbm_addr: 64, .. }));
    }

    #[test]
    fn load_used_by_store_is_live() {
        use crate::isa::inst::{BufTarget, Inst};
        let mut t = Trace::new();
        t.push(Inst::Load { target: BufTarget::Streaming, hbm_addr: 0, rows: 1 });
        t.push(Inst::Store { target: BufTarget::Streaming, hbm_addr: 128, rows: 1 });
        t.push(Inst::Load { target: BufTarget::Streaming, hbm_addr: 64, rows: 1 });
        assert_eq!(eliminate_dead_loads(&mut t), 0);
    }

    #[test]
    fn layer_starts_remap_after_drops() {
        use crate::isa::inst::{BufTarget, Inst};
        let mut t = Trace::new();
        t.begin_layer();
        t.push(Inst::Load { target: BufTarget::Streaming, hbm_addr: 0, rows: 1 });
        t.push(Inst::Load { target: BufTarget::Streaming, hbm_addr: 64, rows: 1 });
        t.begin_layer();
        t.push(Inst::Load { target: BufTarget::Stationary, hbm_addr: 0, rows: 1 });
        eliminate_dead_loads(&mut t);
        assert_eq!(t.layer_starts, vec![0, 1]);
    }
}
