//! PJRT runtime — loads AOT-compiled HLO-text artifacts (produced once by
//! `python/compile/aot.py`) and executes them on the request path. Python
//! never runs here; the binary is self-contained once `artifacts/` exists.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`, with tuple-return unwrapping.
//!
//! The `xla` bindings only exist in images that ship the vendored crate,
//! so everything touching PJRT is gated behind the off-by-default `pjrt`
//! cargo feature. Without it, the same public API compiles to stubs that
//! return a descriptive error — callers (CLI `serve --executor pjrt`, the
//! examples, the artifact-gated integration tests) degrade gracefully.

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::path::PathBuf;
#[cfg(feature = "pjrt")]
use std::sync::Mutex;

#[cfg(feature = "pjrt")]
use anyhow::Context;
use anyhow::{bail, Result};

/// Artifact manifest entry (mirrors `aot.py`'s JSON).
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    /// Argument shapes, row-major.
    pub args: Vec<Vec<usize>>,
}

/// Parse `manifest.json` (minimal JSON parsing — offline build has no serde
/// feature-complete stack; the format is fixed and machine-generated).
pub fn parse_manifest(text: &str) -> Result<Vec<ArtifactMeta>> {
    let mut out = Vec::new();
    // Entries look like: "name": { "args": [[64, 64], [64, 64]], "file": "name.hlo.txt", ... }
    let mut rest = text;
    while let Some(start) = rest.find('"') {
        let after = &rest[start + 1..];
        let Some(endq) = after.find('"') else { break };
        let key = &after[..endq];
        let after_key = &after[endq + 1..];
        let Some(colon) = after_key.find(':') else { break };
        let body = after_key[colon + 1..].trim_start();
        if !body.starts_with('{') {
            rest = &after_key[colon + 1..];
            continue;
        }
        let Some(close) = body.find('}') else { break };
        let obj = &body[..close + 1];
        let file = extract_string(obj, "file").unwrap_or_else(|| format!("{key}.hlo.txt"));
        let args = extract_args(obj).unwrap_or_default();
        out.push(ArtifactMeta { name: key.to_string(), file, args });
        rest = &body[close + 1..];
    }
    if out.is_empty() {
        bail!("no artifacts parsed from manifest");
    }
    Ok(out)
}

fn extract_string(obj: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\"");
    let i = obj.find(&pat)?;
    let after = &obj[i + pat.len()..];
    let q1 = after.find('"')? + 1;
    let q2 = after[q1..].find('"')? + q1;
    Some(after[q1..q2].to_string())
}

fn extract_args(obj: &str) -> Option<Vec<Vec<usize>>> {
    let i = obj.find("\"args\"")?;
    let after = &obj[i..];
    let open = after.find('[')?;
    // Find the matching close bracket of the outer array.
    let mut depth = 0usize;
    let mut end = open;
    for (j, ch) in after[open..].char_indices() {
        match ch {
            '[' => depth += 1,
            ']' => {
                depth -= 1;
                if depth == 0 {
                    end = open + j;
                    break;
                }
            }
            _ => {}
        }
    }
    let body = &after[open + 1..end];
    let mut args = Vec::new();
    let mut rest = body;
    while let Some(s) = rest.find('[') {
        let e = rest[s..].find(']')? + s;
        let dims: Vec<usize> = rest[s + 1..e]
            .split(',')
            .filter_map(|t| t.trim().parse().ok())
            .collect();
        args.push(dims);
        rest = &rest[e + 1..];
    }
    Some(args)
}

/// A compiled executable plus its metadata.
#[cfg(feature = "pjrt")]
struct LoadedExe {
    exe: xla::PjRtLoadedExecutable,
    meta: ArtifactMeta,
}

/// The runtime: a PJRT CPU client with a compiled-executable cache.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Vec<ArtifactMeta>,
    cache: Mutex<HashMap<String, std::sync::Arc<LoadedExe>>>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Open the artifact directory (default `artifacts/`).
    pub fn open(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let mtext = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("{}/manifest.json (run `make artifacts`)", dir.display()))?;
        let manifest = parse_manifest(&mtext)?;
        Ok(Self { client, dir: dir.to_path_buf(), manifest, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts(&self) -> &[ArtifactMeta] {
        &self.manifest
    }

    fn load(&self, name: &str) -> Result<std::sync::Arc<LoadedExe>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let meta = self
            .manifest
            .iter()
            .find(|m| m.name == name)
            .with_context(|| format!("unknown artifact '{name}'"))?
            .clone();
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parse {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compile {name}"))?;
        let loaded = std::sync::Arc::new(LoadedExe { exe, meta });
        self.cache.lock().unwrap().insert(name.to_string(), loaded.clone());
        Ok(loaded)
    }

    /// Execute an artifact on f32 row-major inputs. Returns the first tuple
    /// element flattened row-major (all our artifacts return 1-tuples).
    pub fn execute_f32(&self, name: &str, args: &[&[f32]]) -> Result<Vec<f32>> {
        let loaded = self.load(name)?;
        if args.len() != loaded.meta.args.len() {
            bail!("{name}: expected {} args, got {}", loaded.meta.args.len(), args.len());
        }
        let mut literals = Vec::with_capacity(args.len());
        for (a, shape) in args.iter().zip(&loaded.meta.args) {
            let expect: usize = shape.iter().product();
            if a.len() != expect {
                bail!("{name}: arg size {} != shape {:?}", a.len(), shape);
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(a).reshape(&dims)?);
        }
        let result = loaded.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Number of compiled executables resident.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Pick a *pure GEMM* artifact matching (m, k, n) exactly, if any.
    /// Filters by the `gemm_` naming convention: other artifacts (attention,
    /// relu layers) can share the two-matrix signature but compute different
    /// functions.
    pub fn find_gemm(&self, m: usize, k: usize, n: usize) -> Option<String> {
        self.manifest
            .iter()
            .find(|a| {
                a.name.starts_with("gemm_")
                    && a.args.len() == 2
                    && a.args[0] == vec![m, k]
                    && a.args[1] == vec![k, n]
            })
            .map(|a| a.name.clone())
    }

}

/// Tile a (possibly mismatched) GEMM onto fixed-shape artifact executions:
/// pad blocks up to the tile shape, run, slice back. Shared by the worker
/// thread below and single-threaded users.
#[cfg(feature = "pjrt")]
pub fn gemm_via_tiles(
    rt: &Runtime,
    m: usize,
    k: usize,
    n: usize,
    iv: &[f32],
    wv: &[f32],
) -> Result<Vec<f32>> {
    // Exact match first.
    if let Some(name) = rt.find_gemm(m, k, n) {
        return rt.execute_f32(&name, &[iv, wv]);
    }
    let tiles: Vec<(String, usize, usize, usize)> = rt
        .artifacts()
        .iter()
        .filter(|a| a.name.starts_with("gemm_"))
        .filter(|a| a.args.len() == 2 && a.args[0].len() == 2 && a.args[1].len() == 2)
        .filter(|a| a.args[0][1] == a.args[1][0])
        .map(|a| (a.name.clone(), a.args[0][0], a.args[0][1], a.args[1][1]))
        .collect();
    let tile = tiles
        .iter()
        .filter(|t| t.2 >= k)
        .min_by_key(|t| (t.2, t.1, t.3))
        .or_else(|| tiles.iter().max_by_key(|t| t.2))
        .context("no GEMM artifacts available")?;
    let (name, tm, tk, tn) = (tile.0.clone(), tile.1, tile.2, tile.3);
    if tk < k {
        bail!("no artifact covers K={k} (max {tk}); add a variant to aot.py");
    }
    let mut out = vec![0f32; m * n];
    let mut xpad = vec![0f32; tm * tk];
    let mut wpad = vec![0f32; tk * tn];
    for m0 in (0..m).step_by(tm) {
        let mh = tm.min(m - m0);
        xpad.iter_mut().for_each(|v| *v = 0.0);
        for r in 0..mh {
            xpad[r * tk..r * tk + k].copy_from_slice(&iv[(m0 + r) * k..(m0 + r) * k + k]);
        }
        for n0 in (0..n).step_by(tn) {
            let nh = tn.min(n - n0);
            wpad.iter_mut().for_each(|v| *v = 0.0);
            for r in 0..k {
                wpad[r * tn..r * tn + nh].copy_from_slice(&wv[r * n + n0..r * n + n0 + nh]);
            }
            let o = rt.execute_f32(&name, &[&xpad, &wpad])?;
            for r in 0..mh {
                out[(m0 + r) * n + n0..(m0 + r) * n + n0 + nh]
                    .copy_from_slice(&o[r * tn..r * tn + nh]);
            }
        }
    }
    Ok(out)
}

/// Run an activation through a feature ladder (`dims[0] → dims[1] → …`)
/// layer by layer on the tiler — the worker-side body of
/// `PjrtExecutor::run_program`. (The fused `chain_` artifacts are *not*
/// used here: they bake in an inter-layer nonlinearity that plain GEMM
/// chains don't have; see `tests/runtime_integration.rs`.)
#[cfg(feature = "pjrt")]
pub fn chain_via_tiles(
    rt: &Runtime,
    rows: usize,
    dims: &[usize],
    input: &[f32],
    weights: &[Vec<f32>],
) -> Result<Vec<f32>> {
    anyhow::ensure!(dims.len() >= 2, "chain needs at least one layer");
    anyhow::ensure!(weights.len() == dims.len() - 1, "one weight per chain boundary");
    let mut act = input.to_vec();
    for (w, d) in weights.iter().zip(dims.windows(2)) {
        act = gemm_via_tiles(rt, rows, d[0], d[1], &act, w)?;
    }
    Ok(act)
}

#[cfg(feature = "pjrt")]
type Reply = std::sync::mpsc::Sender<Result<Vec<f32>>>;
#[cfg(feature = "pjrt")]
enum JobKind {
    Gemm { m: usize, k: usize, n: usize, iv: Vec<f32>, wv: Vec<f32> },
    /// Whole-chain pass; the weights stay behind the session's `Arc` — no
    /// per-dispatch copy of the matrices.
    Chain { rows: usize, dims: Vec<usize>, iv: Vec<f32>, ws: std::sync::Arc<Vec<Vec<f32>>> },
}
#[cfg(feature = "pjrt")]
struct Job {
    kind: JobKind,
    reply: Reply,
}

/// A `coordinator::serve::TileExecutor` backed by the PJRT runtime.
///
/// PJRT client handles are `!Send` (Rc + raw pointers inside the xla
/// crate), so the runtime lives on a dedicated worker thread; `gemm` calls
/// marshal over a channel. This also serializes device access, which the
/// single CPU PJRT device requires anyway.
#[cfg(feature = "pjrt")]
pub struct PjrtExecutor {
    tx: Mutex<std::sync::mpsc::Sender<Job>>,
    platform: String,
}

#[cfg(feature = "pjrt")]
impl PjrtExecutor {
    /// Start the worker; fails fast if the artifact dir or PJRT is broken.
    pub fn start(dir: &Path) -> Result<Self> {
        let (tx, rx) = std::sync::mpsc::channel::<Job>();
        let (boot_tx, boot_rx) = std::sync::mpsc::channel::<Result<String>>();
        let dir = dir.to_path_buf();
        std::thread::Builder::new()
            .name("pjrt-worker".into())
            .spawn(move || {
                let rt = match Runtime::open(&dir) {
                    Ok(rt) => {
                        let _ = boot_tx.send(Ok(rt.platform()));
                        rt
                    }
                    Err(e) => {
                        let _ = boot_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(job) = rx.recv() {
                    let r = match job.kind {
                        JobKind::Gemm { m, k, n, iv, wv } => {
                            gemm_via_tiles(&rt, m, k, n, &iv, &wv)
                        }
                        JobKind::Chain { rows, dims, iv, ws } => {
                            chain_via_tiles(&rt, rows, &dims, &iv, &ws)
                        }
                    };
                    let _ = job.reply.send(r);
                }
            })
            .context("spawn pjrt worker")?;
        let platform = boot_rx.recv().context("pjrt worker died")??;
        Ok(Self { tx: Mutex::new(tx), platform })
    }

    pub fn platform(&self) -> &str {
        &self.platform
    }
}

#[cfg(feature = "pjrt")]
impl PjrtExecutor {
    fn submit(&self, kind: JobKind) -> Result<Vec<f32>> {
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Job { kind, reply: reply_tx })
            .map_err(|_| anyhow::anyhow!("pjrt worker gone"))?;
        reply_rx.recv().context("pjrt worker dropped reply")?
    }
}

#[cfg(feature = "pjrt")]
impl crate::coordinator::serve::TileExecutor for PjrtExecutor {
    fn gemm(&self, m: usize, k: usize, n: usize, iv: &[f32], wv: &[f32]) -> Result<Vec<f32>> {
        self.submit(JobKind::Gemm { m, k, n, iv: iv.to_vec(), wv: wv.to_vec() })
    }

    fn name(&self) -> &str {
        "pjrt"
    }

    /// Program-aware entry point: marshal the whole chain to the worker as
    /// one job (one channel round-trip per request batch instead of one per
    /// layer).
    fn run_program(
        &self,
        program: &crate::program::Program,
        rows: usize,
        input: &[f32],
        weights: &std::sync::Arc<Vec<Vec<f32>>>,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(
            weights.len() == program.layer_count(),
            "program expects {} weight matrices, got {}",
            program.layer_count(),
            weights.len()
        );
        let mut dims = vec![program.in_features()];
        dims.extend(program.chain.layers.iter().map(|g| g.n));
        self.submit(JobKind::Chain {
            rows,
            dims,
            iv: input.to_vec(),
            ws: std::sync::Arc::clone(weights),
        })
    }
}

// ---------------------------------------------------------------------------
// Stubs: same public surface without the `pjrt` feature. Every entry point
// fails fast with a descriptive error; artifact-gated tests skip before
// reaching them.
// ---------------------------------------------------------------------------

#[cfg(not(feature = "pjrt"))]
const NO_PJRT: &str =
    "built without the `pjrt` feature: enable it (with the vendored `xla` crate) for PJRT execution";

/// Stub runtime (crate built without the `pjrt` feature).
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    manifest: Vec<ArtifactMeta>,
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    pub fn open(_dir: &Path) -> Result<Self> {
        bail!(NO_PJRT)
    }
    pub fn platform(&self) -> String {
        "unavailable".into()
    }
    pub fn artifacts(&self) -> &[ArtifactMeta] {
        &self.manifest
    }
    pub fn execute_f32(&self, _name: &str, _args: &[&[f32]]) -> Result<Vec<f32>> {
        bail!(NO_PJRT)
    }
    pub fn cached(&self) -> usize {
        0
    }
    pub fn find_gemm(&self, _m: usize, _k: usize, _n: usize) -> Option<String> {
        None
    }
}

/// Stub tiler (crate built without the `pjrt` feature).
#[cfg(not(feature = "pjrt"))]
pub fn gemm_via_tiles(
    _rt: &Runtime,
    _m: usize,
    _k: usize,
    _n: usize,
    _iv: &[f32],
    _wv: &[f32],
) -> Result<Vec<f32>> {
    bail!(NO_PJRT)
}

/// Stub chain runner (crate built without the `pjrt` feature).
#[cfg(not(feature = "pjrt"))]
pub fn chain_via_tiles(
    _rt: &Runtime,
    _rows: usize,
    _dims: &[usize],
    _input: &[f32],
    _weights: &[Vec<f32>],
) -> Result<Vec<f32>> {
    bail!(NO_PJRT)
}

/// Stub executor (crate built without the `pjrt` feature).
#[cfg(not(feature = "pjrt"))]
pub struct PjrtExecutor {
    _private: (),
}

#[cfg(not(feature = "pjrt"))]
impl PjrtExecutor {
    pub fn start(_dir: &Path) -> Result<Self> {
        bail!(NO_PJRT)
    }
    pub fn platform(&self) -> &str {
        "unavailable"
    }
}

#[cfg(not(feature = "pjrt"))]
impl crate::coordinator::serve::TileExecutor for PjrtExecutor {
    fn gemm(&self, _m: usize, _k: usize, _n: usize, _iv: &[f32], _wv: &[f32]) -> Result<Vec<f32>> {
        bail!(NO_PJRT)
    }
    fn name(&self) -> &str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
  "chain_32x64x48x32": { "args": [[32, 64], [64, 48], [48, 32]], "dtype": "f32", "file": "chain_32x64x48x32.hlo.txt", "hlo_chars": 123 },
  "gemm_64x64x64": { "args": [[64, 64], [64, 64]], "dtype": "f32", "file": "gemm_64x64x64.hlo.txt", "hlo_chars": 456 }
}"#;

    #[test]
    fn manifest_parses() {
        let m = parse_manifest(SAMPLE).unwrap();
        assert_eq!(m.len(), 2);
        let chain = &m[0];
        assert_eq!(chain.name, "chain_32x64x48x32");
        assert_eq!(chain.args, vec![vec![32, 64], vec![64, 48], vec![48, 32]]);
        assert_eq!(m[1].file, "gemm_64x64x64.hlo.txt");
    }

    #[test]
    fn manifest_rejects_garbage() {
        assert!(parse_manifest("{}").is_err());
        assert!(parse_manifest("not json").is_err());
    }

    // PJRT-dependent tests live in rust/tests/runtime_integration.rs and
    // are gated on artifacts/ existing.
}
