//! ExecuteMapping / ExecuteStreaming semantics (§IV-D, §IV-E).
//!
//! `ExecuteMapping` places stationary VNs onto the NEST PE array with six
//! parameters θ_EM = (r0, c0, G_r, G_c, s_r, s_c) — Eq. (1):
//!
//! ```text
//! r = r0 + ⌊a_w / G_r⌋
//! c = c0 + s_r·a_h + s_c·(a_w mod G_c)
//! ```
//!
//! `ExecuteStreaming` reuses θ_EM and adds θ_ES = (m0, s_m, T, VN_size, df);
//! the streamed VN entering column `a_w` at step `t` is:
//!
//! ```text
//! j = r0 + ⌊a_w / G_r⌋
//! m = m0 + s_m·t + ⌊(a_w mod G_r) / G_c⌋
//! ```
//!
//! Under WO-S the stationary operand is W and the streamed operand is I;
//! under IO-S the roles swap (the math is identical on the transposed GEMM).

use crate::arch::config::ArchConfig;

/// Dataflow selector (1-bit `df` field of ExecuteStreaming).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Dataflow {
    /// Input-Output stationary: inputs reside in PEs, weights stream.
    IoS,
    /// Weight-Output stationary: weights reside in PEs, inputs stream.
    #[default]
    WoS,
}

impl Dataflow {
    pub fn bit(self) -> u64 {
        match self {
            Dataflow::IoS => 0,
            Dataflow::WoS => 1,
        }
    }

    pub fn from_bit(b: u64) -> Self {
        if b == 0 { Dataflow::IoS } else { Dataflow::WoS }
    }
}

/// θ_EM — ExecuteMapping parameters (Eq. 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MappingCfg {
    /// Starting stationary-VN row index.
    pub r0: usize,
    /// Starting stationary-VN column index.
    pub c0: usize,
    /// Consecutive PE columns sharing one VN row index; 1 ≤ G_r ≤ AW.
    pub g_r: usize,
    /// Replication period of the VN column pattern across PE columns.
    pub g_c: usize,
    /// Stride of VN column index across PE rows (temporal stride).
    pub s_r: usize,
    /// Spacing of VN column index between distinct PE-column patterns.
    pub s_c: usize,
}

impl MappingCfg {
    /// Stationary VN (r, c) held by PE (a_h, a_w) — Eq. (1).
    #[inline]
    pub fn stationary_vn(&self, a_h: usize, a_w: usize) -> (usize, usize) {
        let r = self.r0 + a_w / self.g_r;
        let c = self.c0 + self.s_r * a_h + self.s_c * (a_w % self.g_c);
        (r, c)
    }

    /// ISA legality under a config (Fig. 3 value ranges).
    pub fn validate(&self, cfg: &ArchConfig) -> Result<(), String> {
        let max_vn_slots = cfg.max_vns();
        if self.g_r < 1 || self.g_r > cfg.aw {
            return Err(format!("G_r={} out of [1, {}]", self.g_r, cfg.aw));
        }
        if self.g_c < 1 || self.g_c > cfg.aw {
            return Err(format!("G_c={} out of [1, {}]", self.g_c, cfg.aw));
        }
        if self.r0 >= max_vn_slots || self.c0 >= max_vn_slots {
            return Err(format!("r0/c0 {}/{} exceed {}", self.r0, self.c0, max_vn_slots));
        }
        let s_max = cfg.d() / cfg.ah;
        if self.s_r > s_max || self.s_c > s_max {
            return Err(format!("strides {}/{} exceed D/AH={}", self.s_r, self.s_c, s_max));
        }
        Ok(())
    }
}

/// θ_ES — ExecuteStreaming parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct StreamCfg {
    pub df: Dataflow,
    /// Starting streamed-row index.
    pub m0: usize,
    /// Temporal stride of streamed VN row index.
    pub s_m: usize,
    /// Number of streamed VNs injected per PE column.
    pub t: usize,
    /// VN size for this invocation (≤ AH).
    pub vn_size: usize,
}

impl StreamCfg {
    /// Streamed VN (m, j) entering column `a_w` at step `t` (§IV-E1).
    #[inline]
    pub fn streamed_vn(&self, em: &MappingCfg, a_w: usize, t: usize) -> (usize, usize) {
        let j = em.r0 + a_w / em.g_r;
        let m = self.m0 + self.s_m * t + (a_w % em.g_r) / em.g_c;
        (m, j)
    }

    pub fn validate(&self, cfg: &ArchConfig) -> Result<(), String> {
        if self.vn_size < 1 || self.vn_size > cfg.ah {
            return Err(format!("VN_size={} out of [1, {}]", self.vn_size, cfg.ah));
        }
        let t_max = crate::util::ceil_div(cfg.d(), cfg.ah).max(1);
        if self.t < 1 {
            return Err("T must be ≥ 1".into());
        }
        if self.t > t_max * cfg.aw {
            // Generous cap: T is bounded by resident streamed VNs; with
            // column-parallel splitting one column sees at most all of them.
            return Err(format!("T={} exceeds resident VN bound {}", self.t, t_max * cfg.aw));
        }
        Ok(())
    }
}

/// One compute-tile invocation: the (ExecuteMapping, ExecuteStreaming) pair
/// that triggers NEST (§IV-G1 "compute-trigger").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Invocation {
    pub em: MappingCfg,
    pub es: StreamCfg,
}

impl Invocation {
    /// Enumerate all (PE, step) work items: `(a_h, a_w, t, stationary (r,c),
    /// streamed (m, j))`. The caller filters out-of-bounds VNs (zero-pad).
    pub fn work_items(&self, cfg: &ArchConfig) -> impl Iterator<Item = WorkItem> + '_ {
        let ah = cfg.ah.min(self.es.vn_size.max(1));
        let aw = cfg.aw;
        let t_total = self.es.t;
        let em = self.em;
        let es = self.es;
        // When VN_size < AH only VN_size PE rows are active (§VI-D2).
        let active_rows = if es.vn_size < cfg.ah { es.vn_size } else { cfg.ah };
        let _ = ah;
        (0..aw).flat_map(move |a_w| {
            (0..t_total).flat_map(move |t| {
                let (m, j) = es.streamed_vn(&em, a_w, t);
                (0..active_rows).map(move |a_h| {
                    let (r, c) = em.stationary_vn(a_h, a_w);
                    WorkItem { a_h, a_w, t, sta_r: r, sta_c: c, str_m: m, str_j: j }
                })
            })
        })
    }

    /// Reduction-consistency invariant: the streamed VN's reduction tile
    /// always equals the stationary VN's row index (they meet in a dot
    /// product over the same K-chunk). Holds by construction of the two
    /// equations; checked in tests and by the functional simulator.
    pub fn reduction_consistent(&self, cfg: &ArchConfig) -> bool {
        for a_w in 0..cfg.aw {
            let (_, j) = self.es.streamed_vn(&self.em, a_w, 0);
            let (r, _) = self.em.stationary_vn(0, a_w);
            if j != r {
                return false;
            }
        }
        true
    }
}

/// One PE-step of work within an invocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkItem {
    pub a_h: usize,
    pub a_w: usize,
    pub t: usize,
    /// Stationary VN coordinates (r, c).
    pub sta_r: usize,
    pub sta_c: usize,
    /// Streamed VN coordinates (m, j).
    pub str_m: usize,
    pub str_j: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    fn cfg44() -> ArchConfig {
        ArchConfig::paper(4, 4)
    }

    /// §IV-E2 case study: AH×4 array, (r0, G_r, G_c) = (0, 2, 1),
    /// (m0, s_m, T) = (0, 3, 3). Columns 0-1 are reduction group j=0,
    /// columns 2-3 are j=1; within a group the two columns split the stream.
    #[test]
    fn streaming_case_study() {
        let em = MappingCfg { r0: 0, c0: 0, g_r: 2, g_c: 1, s_r: 1, s_c: 0 };
        let es =
            StreamCfg { df: Dataflow::WoS, m0: 0, s_m: 3, t: 3, vn_size: 4 };
        // j per column: 0,0,1,1.
        for (a_w, expect_j) in [(0, 0), (1, 0), (2, 1), (3, 1)] {
            let (_, j) = es.streamed_vn(&em, a_w, 0);
            assert_eq!(j, expect_j, "col {a_w}");
        }
        // m over three steps: col0: 0,3,6 ; col1: 1,4,7 ; col2: 0,3,6 ; col3: 1,4,7.
        let expect_m = [[0, 3, 6], [1, 4, 7], [0, 3, 6], [1, 4, 7]];
        for a_w in 0..4 {
            for t in 0..3 {
                let (m, _) = es.streamed_vn(&em, a_w, t);
                assert_eq!(m, expect_m[a_w][t], "col {a_w} step {t}");
            }
        }
    }

    /// Fig. 4 mapping case (1): replicate the same W_VNs across all columns.
    #[test]
    fn mapping_replicate_all_columns() {
        let em = MappingCfg { r0: 0, c0: 0, g_r: 4, g_c: 1, s_r: 1, s_c: 0 };
        for a_w in 0..4 {
            for a_h in 0..4 {
                assert_eq!(em.stationary_vn(a_h, a_w), (0, a_h));
            }
        }
    }

    /// Fig. 4 case (2): two replicated groups of two columns.
    #[test]
    fn mapping_two_groups() {
        let em = MappingCfg { r0: 0, c0: 0, g_r: 2, g_c: 1, s_r: 1, s_c: 0 };
        assert_eq!(em.stationary_vn(0, 0), (0, 0));
        assert_eq!(em.stationary_vn(0, 1), (0, 0));
        assert_eq!(em.stationary_vn(0, 2), (1, 0));
        assert_eq!(em.stationary_vn(0, 3), (1, 0));
    }

    /// Fig. 4 case (3): each column a different W_VN column set.
    #[test]
    fn mapping_distinct_columns() {
        let em = MappingCfg { r0: 0, c0: 0, g_r: 4, g_c: 4, s_r: 1, s_c: 4 };
        for a_w in 0..4 {
            for a_h in 0..4 {
                assert_eq!(em.stationary_vn(a_h, a_w), (0, a_h + 4 * a_w));
            }
        }
    }

    #[test]
    fn reduction_consistency_property() {
        // j == r for row 0 of every column, for any legal θ.
        forall("reduction-consistent", 300, |g| {
            let cfg = cfg44();
            let em = MappingCfg {
                r0: g.usize(0, 10),
                c0: g.usize(0, 10),
                g_r: g.usize(1, 4),
                g_c: g.usize(1, 4),
                s_r: g.usize(0, 3),
                s_c: g.usize(0, 3),
            };
            let es = StreamCfg {
                df: Dataflow::WoS,
                m0: g.usize(0, 5),
                s_m: g.usize(1, 4),
                t: g.usize(1, 6),
                vn_size: g.usize(1, 4),
            };
            let inv = Invocation { em, es };
            assert!(inv.reduction_consistent(&cfg));
        });
    }

    #[test]
    fn intra_column_reuse_constraint() {
        // Constraint 2 (§III-C2 / §IV-B3): within a column, every PE row
        // sees the same streamed VN — work items in one (a_w, t) share
        // (str_m, str_j).
        forall("intra-column-reuse", 100, |g| {
            let cfg = cfg44();
            let em = MappingCfg {
                r0: g.usize(0, 4),
                c0: g.usize(0, 4),
                g_r: g.usize(1, 4),
                g_c: g.usize(1, 4),
                s_r: g.usize(0, 2),
                s_c: g.usize(0, 2),
            };
            let es = StreamCfg {
                df: Dataflow::WoS,
                m0: 0,
                s_m: g.usize(1, 3),
                t: g.usize(1, 4),
                vn_size: 4,
            };
            let inv = Invocation { em, es };
            let items: Vec<_> = inv.work_items(&cfg).collect();
            for w in &items {
                let (m, j) = es.streamed_vn(&em, w.a_w, w.t);
                assert_eq!((w.str_m, w.str_j), (m, j));
            }
            // 4 rows × 4 cols × T items.
            assert_eq!(items.len(), 4 * 4 * es.t);
        });
    }

    #[test]
    fn small_vn_disables_rows() {
        let cfg = cfg44();
        let em = MappingCfg { r0: 0, c0: 0, g_r: 4, g_c: 1, s_r: 1, s_c: 0 };
        let es = StreamCfg { df: Dataflow::WoS, m0: 0, s_m: 1, t: 2, vn_size: 2 };
        let inv = Invocation { em, es };
        let items: Vec<_> = inv.work_items(&cfg).collect();
        // Only vn_size=2 rows active.
        assert_eq!(items.len(), 2 * 4 * 2);
        assert!(items.iter().all(|w| w.a_h < 2));
    }

    #[test]
    fn validation_bounds() {
        let cfg = cfg44();
        let mut em = MappingCfg { r0: 0, c0: 0, g_r: 1, g_c: 1, s_r: 1, s_c: 1 };
        assert!(em.validate(&cfg).is_ok());
        em.g_r = 5;
        assert!(em.validate(&cfg).is_err());
        em.g_r = 1;
        em.s_r = cfg.d(); // way over D/AH
        assert!(em.validate(&cfg).is_err());

        let mut es = StreamCfg { df: Dataflow::WoS, m0: 0, s_m: 1, t: 1, vn_size: 4 };
        assert!(es.validate(&cfg).is_ok());
        es.vn_size = 5;
        assert!(es.validate(&cfg).is_err());
        es.vn_size = 4;
        es.t = 0;
        assert!(es.validate(&cfg).is_err());
    }

    #[test]
    fn dataflow_bits_roundtrip() {
        assert_eq!(Dataflow::from_bit(Dataflow::WoS.bit()), Dataflow::WoS);
        assert_eq!(Dataflow::from_bit(Dataflow::IoS.bit()), Dataflow::IoS);
    }
}
