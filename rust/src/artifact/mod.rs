//! Deployable **`.minisa` artifacts** — the encoded instruction stream as
//! the canonical program.
//!
//! The paper's headline result is that MINISA's encoded trace *is* the
//! minimal off-chip artifact (35×–4·10⁵× less instruction traffic than
//! micro-control, Fig. 12), so the compiled form a serving fleet ships
//! around should be exactly that byte stream — not an in-memory struct that
//! every process re-derives with its own mapper run. An [`Artifact`] is a
//! versioned binary container whose payload is the **encoded** fused MINISA
//! trace (via [`Codec`]), together with everything a loader needs to turn
//! those bytes back into an executable [`Program`](crate::program::Program)
//! without ever running the mapper:
//!
//! * the full [`ArchConfig`] it was compiled for (plus a fingerprint for
//!   cheap compatibility checks),
//! * the chain spec and the per-layer [`ChainDecision`] (mapping choices +
//!   layout orders + performance reports — the mapper's *output*, so the
//!   loader replays deterministic lowering, never the search),
//! * the §IV-G2 elision accounting,
//! * an optional resident-weights payload (canonical datapath words +
//!   [`ElemType`] — one format covers i32/f32 and the prime fields),
//! * an FNV-1a checksum over the whole container.
//!
//! The split mirrors VTA's stack (compile a deployable module once, JIT-load
//! it everywhere): [`Compiler`] is the front-end
//! (`Compiler::new(cfg).options(..).elem(..).compile(chain) → Artifact`),
//! `Program::from_artifact` is the back-end — it **decodes the instruction
//! stream back** into the executable trace ([`Codec::decode_stream`]),
//! recompiles the wave plans locally, and proves byte-level round-trip
//! fidelity on every load (decoded stream ≡ deterministic re-lowering ≡
//! stored bytes). See `docs/ARTIFACT.md` for the wire format.

use std::fmt;
use std::path::Path;
use std::sync::Arc;

use crate::arch::config::{ArchConfig, HwGen};
use crate::arith::ElemType;
use crate::isa::encode::{Codec, EncodeError};
use crate::isa::Trace;
use crate::mapper::chain::{Chain, ChainDecision};
use crate::mapper::search::MapperOptions;
use crate::mapper::{Decision, MappingChoice};
use crate::mapping::Dataflow;
use crate::perf::PerfReport;
use crate::program::Program;
use crate::workloads::Gemm;

/// Container magic ("MINISA artifact").
pub const MAGIC: [u8; 8] = *b"MINISArt";
/// Wire-format version this build writes and the only one it reads.
/// Compatibility rule (docs/ARTIFACT.md): readers reject other versions —
/// recompile rather than guess at a foreign layout.
pub const VERSION: u16 = 1;

/// FNV-1a 64-bit hash — the container checksum, the arch fingerprint, and
/// (via [`crate::registry`]) the content address. One implementation for
/// all three, so a registry key can be recomputed from container bytes with
/// no second hasher to drift; lives in [`crate::util`], re-exported here
/// for the historical import path.
pub use crate::util::fnv64;

/// Everything that can go wrong building, parsing or loading an artifact.
#[derive(Debug, Clone, PartialEq)]
pub enum ArtifactError {
    /// The file does not start with [`MAGIC`].
    BadMagic,
    /// Unsupported wire-format version.
    BadVersion { found: u16, supported: u16 },
    /// The container ends before a declared field.
    Truncated,
    /// Structurally invalid contents (checksum mismatch, bad tags, shape
    /// violations) — the container cannot be trusted.
    Corrupt(String),
    /// A well-formed container that contradicts itself or the loader's
    /// environment (decoded stream vs re-lowering, config mismatch).
    Mismatch(String),
    /// The instruction stream failed to encode/decode.
    Encode(EncodeError),
    /// `Compiler::compile` found no feasible mapping for the chain.
    Infeasible,
    /// Filesystem failure on save/load.
    Io(String),
}

impl fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ArtifactError::BadMagic => write!(f, "not a .minisa artifact (bad magic)"),
            ArtifactError::BadVersion { found, supported } => {
                write!(f, "artifact version {found} unsupported (this build reads {supported})")
            }
            ArtifactError::Truncated => write!(f, "truncated artifact container"),
            ArtifactError::Corrupt(m) => write!(f, "corrupt artifact: {m}"),
            ArtifactError::Mismatch(m) => write!(f, "artifact mismatch: {m}"),
            ArtifactError::Encode(e) => write!(f, "instruction stream: {e}"),
            ArtifactError::Infeasible => write!(f, "no feasible mapping for the chain"),
            ArtifactError::Io(m) => write!(f, "artifact io: {m}"),
        }
    }
}

impl std::error::Error for ArtifactError {}

impl From<EncodeError> for ArtifactError {
    fn from(e: EncodeError) -> Self {
        ArtifactError::Encode(e)
    }
}

/// One layer's canonical weight words, either owned or borrowed from a
/// shared container buffer. The borrowed form is what makes
/// [`Artifact::from_shared`] zero-copy: the matrix is an `(offset, len)`
/// window into the *container's own bytes* (one `Arc<[u8]>` for the whole
/// file), so parsing an artifact with an N-million-word payload allocates
/// nothing for the weights and N loaders of the same blob share one
/// buffer. Words are read with `u64::from_le_bytes` per access — no
/// alignment assumption on the backing buffer.
#[derive(Debug, Clone)]
pub enum WordMatrix {
    /// Materialized words (the compile path, and `from_bytes`).
    Owned(Vec<u64>),
    /// A window of `len` little-endian u64 words starting at byte `offset`
    /// of `buf` (the shared container bytes).
    Shared { buf: Arc<[u8]>, offset: usize, len: usize },
}

impl WordMatrix {
    /// Number of weight words.
    pub fn len(&self) -> usize {
        match self {
            WordMatrix::Owned(v) => v.len(),
            WordMatrix::Shared { len, .. } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The `i`-th canonical word. Panics out of range, like slice indexing.
    pub fn word(&self, i: usize) -> u64 {
        match self {
            WordMatrix::Owned(v) => v[i],
            WordMatrix::Shared { buf, offset, len } => {
                assert!(i < *len, "word {i} out of {len}");
                let at = offset + i * 8;
                u64::from_le_bytes(buf[at..at + 8].try_into().unwrap())
            }
        }
    }

    /// Iterate the words in order (by value — the shared form has no
    /// aligned `&[u64]` to lend).
    pub fn iter(&self) -> impl Iterator<Item = u64> + '_ {
        (0..self.len()).map(move |i| self.word(i))
    }

    /// Materialize into an owned word vector (the one deliberate copy).
    pub fn to_words(&self) -> Vec<u64> {
        match self {
            WordMatrix::Owned(v) => v.clone(),
            WordMatrix::Shared { .. } => self.iter().collect(),
        }
    }

    /// Decode into `elem`'s native form straight from the backing buffer —
    /// no intermediate word vector.
    pub fn decode<E: crate::arith::Element>(&self) -> Vec<E> {
        self.iter().map(E::decode).collect()
    }
}

/// Content equality — an `Owned` and a `Shared` matrix with the same words
/// are the same payload (so `from_bytes` ≡ `from_shared` under `==`).
impl PartialEq for WordMatrix {
    fn eq(&self, other: &Self) -> bool {
        self.len() == other.len() && self.iter().eq(other.iter())
    }
}

impl From<Vec<u64>> for WordMatrix {
    fn from(v: Vec<u64>) -> Self {
        WordMatrix::Owned(v)
    }
}

/// Resident weights shipped inside an artifact: one canonical-word matrix
/// per chain layer, in `elem`'s [`crate::arith::Element::encode`] format.
/// One representation covers every backend (f32 stores IEEE bits, fields
/// store canonical residues), so a serving host can register the session
/// without knowing the number system in advance.
#[derive(Debug, Clone, PartialEq)]
pub struct WeightsPayload {
    pub elem: ElemType,
    pub weights: Vec<WordMatrix>,
}

impl WeightsPayload {
    /// Payload over owned word vectors (the compile-side constructor).
    pub fn owned(elem: ElemType, weights: Vec<Vec<u64>>) -> Self {
        Self { elem, weights: weights.into_iter().map(WordMatrix::Owned).collect() }
    }
}

/// A parsed `.minisa` container. The **encoded trace bytes are the canonical
/// program**; everything else exists so a loader can rebuild the executable
/// form (and verify the bytes) without a mapper run.
#[derive(Debug, Clone, PartialEq)]
pub struct Artifact {
    /// Architecture the stream was encoded for (field widths derive from it).
    pub cfg: ArchConfig,
    /// The model chain the program computes.
    pub chain: Chain,
    /// The chain-aware mapper's output: per-layer decisions + elision
    /// accounting (`elided`, `fused_bytes`, `standalone_bytes`,
    /// `total_cycles`).
    pub decision: ChainDecision,
    /// Layer boundaries of the fused trace (instruction indices).
    pub layer_starts: Vec<usize>,
    /// Number of instructions in the encoded stream.
    pub inst_count: usize,
    /// The program itself: the fused MINISA trace, bit-packed by [`Codec`].
    pub trace_bytes: Vec<u8>,
    /// Optional resident weights (+ element type) for serving.
    pub payload: Option<WeightsPayload>,
}

/// What [`Artifact::verify`] proved about the stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactCheck {
    /// Instructions decoded from the stream.
    pub insts: usize,
    /// (config-only, compute-trigger, memory, activation) counts.
    pub classes: (usize, usize, usize, usize),
    /// Encoded stream length in bytes.
    pub trace_bytes: usize,
    /// FNV-1a of the encoded stream.
    pub trace_fnv: u64,
}

impl Artifact {
    /// Fingerprint of the architecture section — two artifacts (or an
    /// artifact and a server) are stream-compatible iff these agree, since
    /// every ISA field width derives from the config.
    pub fn fingerprint(&self) -> u64 {
        arch_fingerprint(&self.cfg)
    }

    /// Decode the canonical stream back into an executable [`Trace`]
    /// (instructions + layer boundaries), including the implicit layout
    /// VN-size rehydration ([`Codec::decode_stream`]).
    pub fn decode_trace(&self) -> Result<Trace, ArtifactError> {
        let codec = Codec::new(&self.cfg);
        let insts = codec.decode_stream(&self.trace_bytes, self.inst_count)?;
        Ok(Trace::from_insts(insts, self.layer_starts.clone()))
    }

    /// Prove the stream round-trips at the byte level: decode every
    /// instruction and re-encode; the bytes must be identical. Returns the
    /// per-class accounting for reporting (`minisa inspect`).
    pub fn verify(&self) -> Result<ArtifactCheck, ArtifactError> {
        let trace = self.decode_trace()?;
        let codec = Codec::new(&self.cfg);
        let reencoded = codec.encode_all(&trace.insts)?;
        if reencoded != self.trace_bytes {
            return Err(ArtifactError::Mismatch(
                "decoded stream does not re-encode to the stored bytes".into(),
            ));
        }
        Ok(ArtifactCheck {
            insts: trace.len(),
            classes: trace.class_counts(),
            trace_bytes: self.trace_bytes.len(),
            trace_fnv: fnv64(&self.trace_bytes),
        })
    }

    /// Serialize to the container wire format (docs/ARTIFACT.md).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = ByteWriter::default();
        w.raw(&MAGIC);
        w.u16(VERSION);
        let mut arch = ByteWriter::default();
        write_arch(&mut arch, &self.cfg);
        w.u64(fnv64(&arch.bytes));
        w.u32(arch.bytes.len() as u32);
        w.raw(&arch.bytes);
        // Chain spec.
        w.u32(self.chain.layers.len() as u32);
        for g in &self.chain.layers {
            w.str(&g.name);
            w.str(&g.category);
            w.u64(g.m as u64);
            w.u64(g.k as u64);
            w.u64(g.n as u64);
        }
        // Per-layer decisions.
        for d in &self.decision.per_layer {
            write_decision(&mut w, d);
        }
        // Elision accounting.
        w.u64(self.decision.elided as u64);
        w.u64(self.decision.fused_bytes);
        w.u64(self.decision.standalone_bytes);
        w.f64(self.decision.total_cycles);
        // The canonical program: the encoded stream.
        w.u32(self.inst_count as u32);
        w.u32(self.layer_starts.len() as u32);
        for &s in &self.layer_starts {
            w.u32(s as u32);
        }
        w.u32(self.trace_bytes.len() as u32);
        w.raw(&self.trace_bytes);
        // Optional weights payload.
        match &self.payload {
            None => w.u8(0),
            Some(p) => {
                w.u8(1);
                w.u8(elem_tag(p.elem));
                for m in &p.weights {
                    w.u32(m.len() as u32);
                    match m {
                        // The shared window is already the wire encoding —
                        // copy it through wholesale.
                        WordMatrix::Shared { buf, offset, len } => {
                            w.raw(&buf[*offset..offset + len * 8]);
                        }
                        WordMatrix::Owned(v) => {
                            for &word in v {
                                w.u64(word);
                            }
                        }
                    }
                }
            }
        }
        let checksum = fnv64(&w.bytes);
        w.u64(checksum);
        w.bytes
    }

    /// Parse and validate a container: magic, version, arch fingerprint,
    /// checksum, and every structural invariant (chain validity, decision
    /// count, layer-start monotonicity, payload shapes). Weight payloads
    /// come back as [`WordMatrix::Owned`]; use [`Artifact::from_shared`]
    /// for the zero-copy borrowed form.
    pub fn from_bytes(bytes: &[u8]) -> Result<Artifact, ArtifactError> {
        Self::parse(bytes, None)
    }

    /// Zero-copy parse: identical validation to [`Artifact::from_bytes`],
    /// but the weight payload borrows windows of `bytes` itself
    /// ([`WordMatrix::Shared`]) instead of materializing word vectors — the
    /// dominant container section is never copied, and every session loaded
    /// from the same buffer shares it. This is the decode path behind
    /// `ArtifactSource::Path` and the registry.
    pub fn from_shared(bytes: Arc<[u8]>) -> Result<Artifact, ArtifactError> {
        let view: Arc<[u8]> = Arc::clone(&bytes);
        Self::parse(&view, Some(bytes))
    }

    /// Read a container through one shared buffer: a single `fs::read`,
    /// then [`Artifact::from_shared`] over it (no second copy of the
    /// payload section).
    pub fn load_shared(path: &Path) -> Result<Artifact, ArtifactError> {
        let bytes: Arc<[u8]> = std::fs::read(path)
            .map_err(|e| ArtifactError::Io(format!("{}: {e}", path.display())))?
            .into();
        Self::from_shared(bytes)
    }

    fn parse(bytes: &[u8], shared: Option<Arc<[u8]>>) -> Result<Artifact, ArtifactError> {
        if bytes.len() < MAGIC.len() + 2 + 8 || bytes[..MAGIC.len()] != MAGIC {
            if bytes.len() >= MAGIC.len() && bytes[..MAGIC.len()] != MAGIC {
                return Err(ArtifactError::BadMagic);
            }
            return Err(ArtifactError::Truncated);
        }
        // Checksum covers everything before the final 8 bytes.
        let body_len = bytes.len() - 8;
        let stored = u64::from_le_bytes(bytes[body_len..].try_into().unwrap());
        if fnv64(&bytes[..body_len]) != stored {
            return Err(ArtifactError::Corrupt("checksum mismatch".into()));
        }
        let mut r = ByteReader { bytes: &bytes[..body_len], pos: MAGIC.len() };
        let version = r.u16()?;
        if version != VERSION {
            return Err(ArtifactError::BadVersion { found: version, supported: VERSION });
        }
        let fingerprint = r.u64()?;
        let arch_len = r.u32()? as usize;
        let arch_bytes = r.raw(arch_len)?;
        if fnv64(arch_bytes) != fingerprint {
            return Err(ArtifactError::Corrupt("arch fingerprint mismatch".into()));
        }
        let cfg = read_arch(&mut ByteReader { bytes: arch_bytes, pos: 0 })?;
        let n_layers = r.u32()? as usize;
        if n_layers == 0 {
            return Err(ArtifactError::Corrupt("zero-layer chain".into()));
        }
        // Capacity hints are capped: a lying length field must fail on
        // truncated reads, not on an absurd up-front allocation.
        let mut layers = Vec::with_capacity(n_layers.min(1024));
        for _ in 0..n_layers {
            let name = r.str()?;
            let category = r.str()?;
            let m = r.u64()? as usize;
            let k = r.u64()? as usize;
            let n = r.u64()? as usize;
            layers.push(Gemm::new(&name, &category, m, k, n));
        }
        let chain = Chain { layers };
        chain.validate().map_err(ArtifactError::Corrupt)?;
        let per_layer: Vec<Decision> =
            (0..n_layers).map(|_| read_decision(&mut r)).collect::<Result<_, _>>()?;
        bound_lowering_work(&cfg, &chain, &per_layer)?;
        let elided = r.u64()? as usize;
        let fused_bytes = r.u64()?;
        let standalone_bytes = r.u64()?;
        let total_cycles = r.f64()?;
        let inst_count = r.u32()? as usize;
        let n_starts = r.u32()? as usize;
        if n_starts != n_layers {
            return Err(ArtifactError::Corrupt(format!(
                "{n_starts} layer starts for {n_layers} layers"
            )));
        }
        let mut layer_starts = Vec::with_capacity(n_starts.min(1024));
        for _ in 0..n_starts {
            layer_starts.push(r.u32()? as usize);
        }
        if layer_starts.windows(2).any(|w| w[0] > w[1])
            || layer_starts.last().is_some_and(|&s| s > inst_count)
            || layer_starts.first().is_some_and(|&s| s != 0)
        {
            return Err(ArtifactError::Corrupt("layer starts out of order".into()));
        }
        let trace_len = r.u32()? as usize;
        let trace_bytes = r.raw(trace_len)?.to_vec();
        let payload = match r.u8()? {
            0 => None,
            1 => {
                let elem = elem_from_tag(r.u8()?)?;
                let mut weights = Vec::with_capacity(n_layers);
                for g in &chain.layers {
                    let len = r.u32()? as usize;
                    if len != g.k * g.n {
                        return Err(ArtifactError::Corrupt(format!(
                            "layer {} weight payload is {len} words, expected {}×{}",
                            g.name, g.k, g.n
                        )));
                    }
                    // Bounds-check and advance past the words either way;
                    // the shared path then keeps only the window, never the
                    // materialized vector. Offsets into `r.bytes` (the body
                    // prefix) are valid into the full shared buffer too.
                    let offset = r.pos;
                    let words = r.raw(len.checked_mul(8).ok_or(ArtifactError::Truncated)?)?;
                    weights.push(match &shared {
                        Some(buf) => {
                            WordMatrix::Shared { buf: Arc::clone(buf), offset, len }
                        }
                        None => WordMatrix::Owned(
                            words
                                .chunks_exact(8)
                                .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                                .collect(),
                        ),
                    });
                }
                Some(WeightsPayload { elem, weights })
            }
            t => return Err(ArtifactError::Corrupt(format!("bad payload flag {t}"))),
        };
        if r.pos != r.bytes.len() {
            return Err(ArtifactError::Corrupt(format!(
                "{} trailing bytes after payload",
                r.bytes.len() - r.pos
            )));
        }
        let decision = ChainDecision {
            per_layer,
            total_cycles,
            elided,
            fused_bytes,
            standalone_bytes,
        };
        Ok(Artifact { cfg, chain, decision, layer_starts, inst_count, trace_bytes, payload })
    }

    /// Write the container to a file. Validates the payload shape first so
    /// a hand-assembled `Artifact` (every field is public) fails *here*
    /// with a descriptive error instead of producing a file whose payload
    /// section can never parse (`from_bytes` reads exactly one `k·n`
    /// matrix per chain layer).
    pub fn save(&self, path: &Path) -> Result<(), ArtifactError> {
        if let Some(p) = &self.payload {
            validate_payload_dims(&self.chain, &p.weights)?;
        }
        std::fs::write(path, self.to_bytes())
            .map_err(|e| ArtifactError::Io(format!("{}: {e}", path.display())))
    }

    /// Read and validate a container from a file.
    pub fn load(path: &Path) -> Result<Artifact, ArtifactError> {
        let bytes = std::fs::read(path)
            .map_err(|e| ArtifactError::Io(format!("{}: {e}", path.display())))?;
        Self::from_bytes(&bytes)
    }
}

/// Fingerprint of an [`ArchConfig`]: FNV over its serialized arch section.
pub fn arch_fingerprint(cfg: &ArchConfig) -> u64 {
    let mut w = ByteWriter::default();
    write_arch(&mut w, cfg);
    fnv64(&w.bytes)
}

/// Largest tensor extent a container may declare (16.7M — generous beyond
/// every Table IV shape, small enough that crafted dims can't turn the
/// loader's deterministic re-lowering into an unbounded loop).
const MAX_DIM: usize = 1 << 24;
/// Cap on the estimated lowering work (output-tiles × invocations) per
/// layer. Real suite traces stay orders of magnitude below this.
const MAX_LOWERING_UNITS: u64 = 1 << 24;

/// Reject containers whose chain/decisions would make the loader's
/// deterministic re-lowering (`Program::from_artifact` → `lower_gemm`)
/// loop or allocate without bound. The checksum only proves integrity, not
/// honesty — FNV is trivially recomputable — so a crafted file with
/// `m = 2^48, m_t = 1` must fail *here*, before any lowering runs.
pub(crate) fn bound_lowering_work(
    cfg: &ArchConfig,
    chain: &Chain,
    decisions: &[Decision],
) -> Result<(), ArtifactError> {
    for (g, d) in chain.layers.iter().zip(decisions) {
        if g.m > MAX_DIM || g.k > MAX_DIM || g.n > MAX_DIM {
            return Err(ArtifactError::Corrupt(format!(
                "layer {} extents {}×{}×{} exceed the {MAX_DIM} cap",
                g.name, g.m, g.k, g.n
            )));
        }
        let c = &d.choice;
        // Zero knobs would divide-by-zero below (and panic lowering later);
        // `read_decision` rejects them at parse, but hand-assembled
        // in-memory artifacts reach here without passing through it.
        if c.vn == 0 || c.m_t == 0 || c.k_t == 0 || c.n_t == 0 || c.nbc == 0 || c.dup == 0 {
            return Err(ArtifactError::Corrupt(format!(
                "layer {} has a zero-sized mapping choice",
                g.name
            )));
        }
        if c.vn > cfg.ah || c.nbc > cfg.aw || c.dup > cfg.aw {
            return Err(ArtifactError::Corrupt(format!(
                "layer {} mapping knobs (vn {}, nbc {}, dup {}) exceed the {} array",
                g.name,
                c.vn,
                c.nbc,
                c.dup,
                cfg.name()
            )));
        }
        // Upper bound on lower_gemm's loop structure: output tiles ×
        // k-tiles × invocations per k-tile (max tile extents, so edge
        // tiles are over- not under-counted).
        let (ms, ks, ns) = crate::mapper::lower::search_dims(g, c.df);
        let tiles = (ms.div_ceil(c.m_t) as u64)
            .saturating_mul(ns.div_ceil(c.n_t) as u64)
            .saturating_mul(ks.div_ceil(c.k_t) as u64);
        let rows_active = c.vn.min(cfg.ah).max(1);
        let period = (c.nbc * c.dup).min(cfg.aw).max(1);
        let kgc = (cfg.aw / period).max(1);
        let nbt = c.n_t.min(ns.max(1)).div_ceil(rows_active);
        let kgt = c.k_t.min(ks.max(1)).div_ceil(c.vn.max(1));
        let inv_per_ktile =
            (nbt.div_ceil(c.nbc.max(1)) as u64).saturating_mul(kgt.div_ceil(kgc) as u64);
        if tiles.saturating_mul(inv_per_ktile.max(1)) > MAX_LOWERING_UNITS {
            return Err(ArtifactError::Corrupt(format!(
                "layer {} demands more than {MAX_LOWERING_UNITS} lowering units",
                g.name
            )));
        }
    }
    Ok(())
}

/// One weight-word matrix per layer, each `k·n` words — the payload-shape
/// rule, shared by [`Compiler::compile`] (fail fast, before the mapper run)
/// and `Program::to_artifact` (the payload actually packaged).
pub(crate) fn validate_payload_dims(
    chain: &Chain,
    weights: &[WordMatrix],
) -> Result<(), ArtifactError> {
    if weights.len() != chain.layers.len() {
        return Err(ArtifactError::Mismatch(format!(
            "chain has {} layers, got {} weight matrices",
            chain.layers.len(),
            weights.len()
        )));
    }
    for (g, w) in chain.layers.iter().zip(weights) {
        if w.len() != g.k * g.n {
            return Err(ArtifactError::Mismatch(format!(
                "layer {} weight is {} words, expected {}×{}",
                g.name,
                w.len(),
                g.k,
                g.n
            )));
        }
    }
    Ok(())
}

/// Builder front-end of the compile/serve split:
/// `Compiler::new(cfg).options(..).elem(..).weights(..).compile(chain)`
/// runs the chain-aware mapper exactly once and emits the deployable
/// [`Artifact`]. Defaults to the serving stack's deterministic profile
/// (constrained layout search, one thread) so identical inputs produce
/// byte-identical artifacts; override with [`Compiler::options`].
#[derive(Debug, Clone)]
pub struct Compiler {
    cfg: ArchConfig,
    opts: MapperOptions,
    elem: ElemType,
    weights: Option<Vec<Vec<u64>>>,
}

impl Compiler {
    pub fn new(cfg: &ArchConfig) -> Self {
        Self {
            cfg: cfg.clone(),
            opts: MapperOptions { full_layout_search: false, threads: 1, ..Default::default() },
            elem: ElemType::I32,
            weights: None,
        }
    }

    /// Override the mapper options (e.g. the full layout search).
    pub fn options(mut self, opts: MapperOptions) -> Self {
        self.opts = opts;
        self
    }

    /// Element type the attached weights (and the serving session) use.
    pub fn elem(mut self, elem: ElemType) -> Self {
        self.elem = elem;
        self
    }

    /// Attach resident weights: one canonical-word matrix per chain layer,
    /// encoded for the backend set via [`Compiler::elem`].
    pub fn weights(mut self, weights: Vec<Vec<u64>>) -> Self {
        self.weights = Some(weights);
        self
    }

    /// Compile a chain into an artifact (the only mapper run in the
    /// artifact's life).
    pub fn compile(&self, chain: &Chain) -> Result<Artifact, ArtifactError> {
        chain.validate().map_err(ArtifactError::Mismatch)?;
        let payload = self
            .weights
            .clone()
            .map(|weights| WeightsPayload::owned(self.elem, weights));
        if let Some(p) = &payload {
            validate_payload_dims(chain, &p.weights)?;
        }
        let program =
            Program::compile(&self.cfg, chain, &self.opts).ok_or(ArtifactError::Infeasible)?;
        program.to_artifact(payload)
    }
}

// ---------------------------------------------------------------------------
// Wire primitives (little-endian, length-prefixed strings).

#[derive(Default)]
struct ByteWriter {
    bytes: Vec<u8>,
}

impl ByteWriter {
    fn raw(&mut self, b: &[u8]) {
        self.bytes.extend_from_slice(b);
    }
    fn u8(&mut self, v: u8) {
        self.bytes.push(v);
    }
    fn u16(&mut self, v: u16) {
        self.raw(&v.to_le_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.raw(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.raw(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.raw(s.as_bytes());
    }
}

struct ByteReader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    fn raw(&mut self, n: usize) -> Result<&'a [u8], ArtifactError> {
        let end = self.pos.checked_add(n).ok_or(ArtifactError::Truncated)?;
        if end > self.bytes.len() {
            return Err(ArtifactError::Truncated);
        }
        let s = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, ArtifactError> {
        Ok(self.raw(1)?[0])
    }
    fn u16(&mut self) -> Result<u16, ArtifactError> {
        Ok(u16::from_le_bytes(self.raw(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32, ArtifactError> {
        Ok(u32::from_le_bytes(self.raw(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, ArtifactError> {
        Ok(u64::from_le_bytes(self.raw(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64, ArtifactError> {
        Ok(f64::from_bits(self.u64()?))
    }
    fn str(&mut self) -> Result<String, ArtifactError> {
        let len = self.u32()? as usize;
        String::from_utf8(self.raw(len)?.to_vec())
            .map_err(|_| ArtifactError::Corrupt("non-UTF8 string".into()))
    }
}

fn write_arch(w: &mut ByteWriter, cfg: &ArchConfig) {
    w.u32(cfg.ah as u32);
    w.u32(cfg.aw as u32);
    w.u8(match cfg.gen {
        HwGen::Feather => 0,
        HwGen::FeatherPlus => 1,
    });
    w.u32(cfg.elem_bytes as u32);
    w.u32(cfg.acc_bytes as u32);
    w.u64(cfg.str_bytes as u64);
    w.u64(cfg.sta_bytes as u64);
    w.u64(cfg.ob_bytes as u64);
    w.u64(cfg.instr_bytes as u64);
    w.f64(cfg.instr_bw);
    w.f64(cfg.data_bw_in);
    w.f64(cfg.data_bw_out);
    w.u64(cfg.hbm_bytes);
    w.f64(cfg.clock_ghz);
}

fn read_arch(r: &mut ByteReader) -> Result<ArchConfig, ArtifactError> {
    let ah = r.u32()? as usize;
    let aw = r.u32()? as usize;
    let gen = match r.u8()? {
        0 => HwGen::Feather,
        1 => HwGen::FeatherPlus,
        t => return Err(ArtifactError::Corrupt(format!("bad hw generation tag {t}"))),
    };
    let cfg = ArchConfig {
        ah,
        aw,
        gen,
        elem_bytes: r.u32()? as usize,
        acc_bytes: r.u32()? as usize,
        str_bytes: r.u64()? as usize,
        sta_bytes: r.u64()? as usize,
        ob_bytes: r.u64()? as usize,
        instr_bytes: r.u64()? as usize,
        instr_bw: r.f64()?,
        data_bw_in: r.f64()?,
        data_bw_out: r.f64()?,
        hbm_bytes: r.u64()?,
        clock_ghz: r.f64()?,
    };
    cfg.validate().map_err(ArtifactError::Corrupt)?;
    Ok(cfg)
}

fn write_decision(w: &mut ByteWriter, d: &Decision) {
    w.u8(d.choice.df.bit() as u8);
    w.u64(d.choice.vn as u64);
    w.u64(d.choice.m_t as u64);
    w.u64(d.choice.k_t as u64);
    w.u64(d.choice.n_t as u64);
    w.u64(d.choice.nbc as u64);
    w.u64(d.choice.dup as u64);
    w.u8(d.i_order);
    w.u8(d.w_order);
    w.u8(d.o_order);
    let rep = &d.report;
    w.f64(rep.total_cycles);
    w.f64(rep.fetch_cycles);
    w.f64(rep.load_in_cycles);
    w.f64(rep.load_w_cycles);
    w.f64(rep.compute_cycles);
    w.f64(rep.out_stream_cycles);
    w.f64(rep.store_out_cycles);
    w.f64(rep.stall_instr_cycles);
    w.f64(rep.stall_data_cycles);
    w.u64(rep.macs_used);
    w.u64(rep.tiles as u64);
    w.u64(rep.peak_macs_per_cycle);
}

fn read_decision(r: &mut ByteReader) -> Result<Decision, ArtifactError> {
    let df = Dataflow::from_bit(r.u8()? as u64);
    let choice = MappingChoice {
        df,
        vn: r.u64()? as usize,
        m_t: r.u64()? as usize,
        k_t: r.u64()? as usize,
        n_t: r.u64()? as usize,
        nbc: r.u64()? as usize,
        dup: r.u64()? as usize,
    };
    // Zero in any knob would panic deterministic lowering at load
    // (`step_by(0)` / divide-by-zero) — reject as corrupt instead.
    if choice.vn == 0
        || choice.m_t == 0
        || choice.k_t == 0
        || choice.n_t == 0
        || choice.nbc == 0
        || choice.dup == 0
    {
        return Err(ArtifactError::Corrupt("zero-sized mapping choice".into()));
    }
    let i_order = r.u8()?;
    let w_order = r.u8()?;
    let o_order = r.u8()?;
    if i_order > 5 || w_order > 5 || o_order > 5 {
        return Err(ArtifactError::Corrupt("layout order id out of range".into()));
    }
    let report = PerfReport {
        total_cycles: r.f64()?,
        fetch_cycles: r.f64()?,
        load_in_cycles: r.f64()?,
        load_w_cycles: r.f64()?,
        compute_cycles: r.f64()?,
        out_stream_cycles: r.f64()?,
        store_out_cycles: r.f64()?,
        stall_instr_cycles: r.f64()?,
        stall_data_cycles: r.f64()?,
        macs_used: r.u64()?,
        tiles: r.u64()? as usize,
        peak_macs_per_cycle: r.u64()?,
    };
    Ok(Decision { choice, i_order, w_order, o_order, report })
}

/// Stable on-wire tag for an [`ElemType`] (wire compatibility demands these
/// never change meaning; append only).
pub(crate) fn elem_tag(e: ElemType) -> u8 {
    match e {
        ElemType::I32 => 0,
        ElemType::F32 => 1,
        ElemType::BabyBear => 2,
        ElemType::Goldilocks => 3,
        ElemType::Pallas => 4,
    }
}

pub(crate) fn elem_from_tag(t: u8) -> Result<ElemType, ArtifactError> {
    ElemType::ALL
        .iter()
        .copied()
        .find(|&e| elem_tag(e) == t)
        .ok_or_else(|| ArtifactError::Corrupt(format!("bad element-type tag {t}")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Lcg;

    fn small_artifact(weights: bool) -> Artifact {
        let cfg = ArchConfig::paper(4, 4);
        let chain = Chain::mlp("art", 8, &[12, 16, 8]);
        let mut c = Compiler::new(&cfg);
        if weights {
            let mut rng = Lcg::new(5);
            let ws: Vec<Vec<u64>> = chain
                .layers
                .iter()
                .map(|g| ElemType::I32.sample_words(&mut rng, g.k * g.n))
                .collect();
            c = c.weights(ws);
        }
        c.compile(&chain).unwrap()
    }

    #[test]
    fn container_roundtrips_bytes_exactly() {
        for weights in [false, true] {
            let art = small_artifact(weights);
            let bytes = art.to_bytes();
            let back = Artifact::from_bytes(&bytes).unwrap();
            assert_eq!(back, art);
            assert_eq!(back.to_bytes(), bytes, "serialization is a fixed point");
            assert_eq!(back.fingerprint(), art.fingerprint());
        }
    }

    /// `from_shared` is the same parse under `==` (WordMatrix equality is
    /// by content), but its payload borrows the container buffer instead of
    /// copying it — and re-serializes to the identical bytes.
    #[test]
    fn shared_parse_is_zero_copy_and_equal() {
        let art = small_artifact(true);
        let bytes: Arc<[u8]> = art.to_bytes().into();
        let shared = Artifact::from_shared(Arc::clone(&bytes)).unwrap();
        assert_eq!(shared, art);
        assert_eq!(shared.to_bytes().as_slice(), &*bytes, "fixed point through the shared path");
        let payload = shared.payload.as_ref().unwrap();
        for m in &payload.weights {
            match m {
                WordMatrix::Shared { buf, .. } => {
                    assert!(Arc::ptr_eq(buf, &bytes), "window borrows the one container buffer");
                }
                WordMatrix::Owned(_) => panic!("shared parse materialized a weight copy"),
            }
        }
        // Tampered shared buffers fail exactly like owned ones.
        let mut bad = bytes.to_vec();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x10;
        assert!(matches!(
            Artifact::from_shared(bad.into()),
            Err(ArtifactError::Corrupt(_))
        ));
    }

    #[test]
    fn verify_proves_stream_roundtrip() {
        let art = small_artifact(false);
        let check = art.verify().unwrap();
        assert_eq!(check.insts, art.inst_count);
        assert_eq!(check.trace_bytes, art.trace_bytes.len());
        let (cfg_only, compute, memory, act) = check.classes;
        assert_eq!(cfg_only + compute + memory + act, check.insts);
        assert!(compute > 0);
    }

    #[test]
    fn save_load_file_roundtrip() {
        let art = small_artifact(true);
        let path = std::env::temp_dir().join(format!("minisa_art_{}.minisa", std::process::id()));
        art.save(&path).unwrap();
        let loaded = Artifact::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(loaded, art);
    }

    #[test]
    fn tampering_is_detected() {
        let art = small_artifact(true);
        let bytes = art.to_bytes();
        // Flip one bit anywhere in the body: checksum mismatch.
        let mut bad = bytes.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x40;
        assert!(matches!(Artifact::from_bytes(&bad), Err(ArtifactError::Corrupt(_))));
        // Truncation.
        assert!(Artifact::from_bytes(&bytes[..bytes.len() - 3]).is_err());
        // Wrong magic.
        let mut nom = bytes.clone();
        nom[0] = b'X';
        assert!(matches!(Artifact::from_bytes(&nom), Err(ArtifactError::BadMagic)));
        // Foreign version (patch both the field and the checksum).
        let mut v2 = bytes.clone();
        v2[8] = 0xFF;
        let body = v2.len() - 8;
        let ck = fnv64(&v2[..body]).to_le_bytes();
        v2[body..].copy_from_slice(&ck);
        assert!(matches!(
            Artifact::from_bytes(&v2),
            Err(ArtifactError::BadVersion { supported: VERSION, .. })
        ));
    }

    /// Containers that declare absurd extents or mapping knobs are
    /// rejected at parse, before any re-lowering could loop on them — the
    /// checksum proves integrity, not honesty.
    #[test]
    fn unbounded_lowering_demands_rejected() {
        let base = small_artifact(false);
        // Huge tensor extents (kept chain-consistent so only the bound
        // trips, not Chain::validate).
        let mut huge = base.clone();
        for g in &mut huge.chain.layers {
            g.m = 1 << 30;
        }
        assert!(matches!(
            Artifact::from_bytes(&huge.to_bytes()),
            Err(ArtifactError::Corrupt(_))
        ));
        // Knobs beyond the array.
        let mut knobs = base.clone();
        knobs.decision.per_layer[0].choice.vn = knobs.cfg.ah + 1;
        assert!(matches!(
            Artifact::from_bytes(&knobs.to_bytes()),
            Err(ArtifactError::Corrupt(_))
        ));
        // Unit tiles against a large-but-capped extent: lowering units blow
        // the budget even though every dim is under MAX_DIM.
        let mut units = base.clone();
        for g in &mut units.chain.layers {
            g.m = (1 << 24) - 1;
        }
        for d in &mut units.decision.per_layer {
            d.choice.m_t = 1;
            d.choice.n_t = 1;
        }
        assert!(matches!(
            Artifact::from_bytes(&units.to_bytes()),
            Err(ArtifactError::Corrupt(_))
        ));
        // The in-memory loader applies the same bound (public fields).
        assert!(crate::program::Program::from_artifact(&huge).is_err());
    }

    /// `save` refuses a hand-assembled artifact whose payload shape could
    /// never parse back, instead of writing a poisoned file.
    #[test]
    fn save_rejects_malformed_payload() {
        let mut art = small_artifact(false);
        art.payload = Some(WeightsPayload::owned(ElemType::I32, vec![vec![1, 2, 3]]));
        let path =
            std::env::temp_dir().join(format!("minisa_badpay_{}.minisa", std::process::id()));
        let err = art.save(&path).unwrap_err();
        assert!(matches!(err, ArtifactError::Mismatch(_)), "{err}");
        assert!(!path.exists(), "no file written");
    }

    #[test]
    fn compiler_validates_inputs() {
        let cfg = ArchConfig::paper(4, 4);
        // Invalid chain.
        let bad = Chain {
            layers: vec![Gemm::new("a", "t", 8, 8, 8), Gemm::new("b", "t", 8, 16, 8)],
        };
        assert!(matches!(
            Compiler::new(&cfg).compile(&bad),
            Err(ArtifactError::Mismatch(_))
        ));
        // Wrong weight count / shape.
        let chain = Chain::mlp("c", 8, &[8, 8]);
        assert!(Compiler::new(&cfg).weights(vec![]).compile(&chain).is_err());
        assert!(Compiler::new(&cfg).weights(vec![vec![0; 7]]).compile(&chain).is_err());
    }

    #[test]
    fn fingerprint_separates_configs() {
        assert_ne!(
            arch_fingerprint(&ArchConfig::paper(4, 4)),
            arch_fingerprint(&ArchConfig::paper(4, 8))
        );
        assert_ne!(
            arch_fingerprint(&ArchConfig::paper(4, 4)),
            arch_fingerprint(&ArchConfig::paper(4, 4).as_feather())
        );
        assert_eq!(
            arch_fingerprint(&ArchConfig::paper(8, 32)),
            arch_fingerprint(&ArchConfig::paper(8, 32))
        );
    }

    #[test]
    fn elem_tags_are_stable_and_total() {
        // Wire stability: these exact values are in shipped containers.
        assert_eq!(elem_tag(ElemType::I32), 0);
        assert_eq!(elem_tag(ElemType::F32), 1);
        assert_eq!(elem_tag(ElemType::BabyBear), 2);
        assert_eq!(elem_tag(ElemType::Goldilocks), 3);
        assert_eq!(elem_tag(ElemType::Pallas), 4);
        for e in ElemType::ALL {
            assert_eq!(elem_from_tag(elem_tag(e)).unwrap(), e);
        }
        assert!(elem_from_tag(9).is_err());
    }
}
