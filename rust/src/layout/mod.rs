//! VN-granularity buffer layouts (§IV-F, Tab. III).
//!
//! A layout places a logical 2-rank tensor into a physical `D × AW` on-chip
//! buffer. Each rank is split into two levels (`K = K_L1·K_L0` etc.); the
//! innermost reduction-level factor is pinned to the VN size, leaving three
//! free ranks `{R_L1, N_L0, N_L1}` whose ordering (3! = 6 permutations,
//! 3-bit `order_id`) plus the level-0/level-1 partition factors fully
//! describe the layout.
//!
//! Address generation: VNs are flattened by the chosen loop order into a 1-D
//! index `L`, then folded row-major over the buffer:
//! `col = L mod AW`, starting row `= (L / AW) · vn_size` — every VN occupies
//! `vn_size` contiguous rows at one column (elements of a VN are read
//! serially over cycles, §IV-F2).

use crate::util::ceil_div;

/// The three free ranks after pinning the reduction L0 factor.
/// `R1` is the level-1 reduction rank (k_L1 / j_L1 / q_L1); `N0`/`N1` are
/// the level-0/level-1 non-reduction ranks (n / m / p).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Rank {
    R1,
    N0,
    N1,
}

/// Loop order, outermost → innermost (Tab. III encoding).
pub const ORDERS: [[Rank; 3]; 6] = [
    [Rank::R1, Rank::N0, Rank::N1], // 000: r1 → n0 → n1
    [Rank::R1, Rank::N1, Rank::N0], // 001
    [Rank::N0, Rank::R1, Rank::N1], // 010
    [Rank::N0, Rank::N1, Rank::R1], // 011
    [Rank::N1, Rank::R1, Rank::N0], // 100
    [Rank::N1, Rank::N0, Rank::R1], // 101
];

/// A concrete VN-granularity layout for one operand buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct VnLayout {
    /// Tab. III order id in [0, 5].
    pub order: u8,
    /// Level-0 non-reduction partition factor (≤ AW by the ISA cap).
    pub n_l0: usize,
    /// Level-1 non-reduction partition factor.
    pub n_l1: usize,
    /// Level-1 reduction partition factor (number of VN rows resident).
    pub r_l1: usize,
    /// VN size (elements per VN, ≤ AH). Reduction L0 factor.
    pub vn_size: usize,
}

impl VnLayout {
    pub fn new(order: u8, n_l0: usize, n_l1: usize, r_l1: usize, vn_size: usize) -> Self {
        assert!(order < 6, "order id {order} out of range");
        assert!(n_l0 >= 1 && n_l1 >= 1 && r_l1 >= 1 && vn_size >= 1);
        Self { order, n_l0, n_l1, r_l1, vn_size }
    }

    /// Canonical row-major layout for a VN grid of `rows × cols`
    /// (order 000 with no level-1/level-0 split of the non-reduction rank):
    /// VNs laid out r-major then c.
    pub fn row_major(rows: usize, cols: usize, vn_size: usize) -> Self {
        Self::new(0, cols.max(1), 1, rows.max(1), vn_size)
    }

    /// Total non-reduction extent covered.
    pub fn non_red(&self) -> usize {
        self.n_l0 * self.n_l1
    }

    /// Total VN slots described.
    pub fn vn_slots(&self) -> usize {
        self.non_red() * self.r_l1
    }

    /// Ordered rank extents, outermost → innermost.
    fn extents(&self) -> [usize; 3] {
        let e = |r: Rank| match r {
            Rank::R1 => self.r_l1,
            Rank::N0 => self.n_l0,
            Rank::N1 => self.n_l1,
        };
        let o = ORDERS[self.order as usize];
        [e(o[0]), e(o[1]), e(o[2])]
    }

    /// Flattened VN index `L` of VN (r, c); `None` if outside this layout's
    /// extents (caller treats as not-resident).
    pub fn flatten(&self, r: usize, c: usize) -> Option<usize> {
        if r >= self.r_l1 || c >= self.non_red() {
            return None;
        }
        let n_l1 = c / self.n_l0;
        let n_l0 = c % self.n_l0;
        let v = |rank: Rank| match rank {
            Rank::R1 => r,
            Rank::N0 => n_l0,
            Rank::N1 => n_l1,
        };
        let o = ORDERS[self.order as usize];
        let e = self.extents();
        Some(v(o[0]) * e[1] * e[2] + v(o[1]) * e[2] + v(o[2]))
    }

    /// Inverse of `flatten`.
    pub fn unflatten(&self, l: usize) -> Option<(usize, usize)> {
        if l >= self.vn_slots() {
            return None;
        }
        let e = self.extents();
        let o = ORDERS[self.order as usize];
        let vals = [l / (e[1] * e[2]), (l / e[2]) % e[1], l % e[2]];
        let mut r = 0;
        let mut n0 = 0;
        let mut n1 = 0;
        for (rank, v) in o.iter().zip(vals) {
            match rank {
                Rank::R1 => r = v,
                Rank::N0 => n0 = v,
                Rank::N1 => n1 = v,
            }
        }
        Some((r, n1 * self.n_l0 + n0))
    }

    /// Physical placement of VN (r, c) in a width-`aw` buffer:
    /// `(first_row, col)`; the VN occupies rows
    /// `first_row .. first_row + vn_size` at `col`.
    pub fn addr(&self, r: usize, c: usize, aw: usize) -> Option<(usize, usize)> {
        let l = self.flatten(r, c)?;
        Some(((l / aw) * self.vn_size, l % aw))
    }

    /// Buffer rows needed to hold all VNs of this layout.
    pub fn rows_needed(&self, aw: usize) -> usize {
        ceil_div(self.vn_slots(), aw) * self.vn_size
    }

    /// Capacity legality (Fig. 5 value-range row): all VNs must fit in a
    /// `d × aw` buffer.
    pub fn fits(&self, d: usize, aw: usize) -> bool {
        self.rows_needed(aw) <= d
    }

    /// ISA-level legality (Fig. 5): `N_L0 ≤ AW` (larger values are
    /// performance-equivalent, §IV-F4b) and capacity.
    pub fn is_legal(&self, d: usize, aw: usize) -> bool {
        self.n_l0 <= aw && self.fits(d, aw)
    }
}

/// Bank-conflict analysis: would reading the VN set `vns` (as one parallel
/// access group, e.g. the AW stationary VNs loaded in one cycle-row) hit the
/// same buffer column twice? FEATHER+'s all-to-all crossbar can *multicast*
/// one resident copy to many PE columns, so duplicate requests to the same
/// VN are free; distinct VNs mapping to the same column conflict.
pub fn conflicting_columns(
    layout: &VnLayout,
    aw: usize,
    vns: &[(usize, usize)],
) -> usize {
    let mut cols: Vec<Option<(usize, usize)>> = vec![None; aw];
    let mut conflicts = 0;
    for &(r, c) in vns {
        if let Some((_, col)) = layout.addr(r, c, aw) {
            match cols[col] {
                None => cols[col] = Some((r, c)),
                Some(prev) if prev == (r, c) => {} // multicast, free
                Some(_) => conflicts += 1,
            }
        }
    }
    conflicts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    /// Fig. 6 case study: K=8, N=8, AH=AW=4 ⇒ vn=4, K_L1=2, N_L0=4, N_L1=2,
    /// order n_L0 → k_L1 → n_L1 (id 010 = index 2). First buffer row must be
    /// W_VN(0,0), W_VN(0,4), W_VN(1,0), W_VN(1,4).
    #[test]
    fn fig6_case_study() {
        let l = VnLayout::new(2, 4, 2, 2, 4);
        let aw = 4;
        assert_eq!(l.addr(0, 0, aw), Some((0, 0)));
        assert_eq!(l.addr(0, 4, aw), Some((0, 1)));
        assert_eq!(l.addr(1, 0, aw), Some((0, 2)));
        assert_eq!(l.addr(1, 4, aw), Some((0, 3)));
        // Second VN row (L = 4..8) starts at buffer row vn_size = 4 and is
        // the n_L0 = 1 pattern: W_VN(0,1), W_VN(0,5), W_VN(1,1), W_VN(1,5).
        assert_eq!(l.addr(0, 1, aw), Some((4, 0)));
        assert_eq!(l.addr(0, 5, aw), Some((4, 1)));
        assert_eq!(l.addr(1, 1, aw), Some((4, 2)));
        assert_eq!(l.addr(1, 5, aw), Some((4, 3)));
    }

    #[test]
    fn row_major_is_sequential() {
        let l = VnLayout::row_major(3, 5, 4);
        let mut expect = 0;
        for r in 0..3 {
            for c in 0..5 {
                assert_eq!(l.flatten(r, c), Some(expect));
                expect += 1;
            }
        }
    }

    #[test]
    fn out_of_extent_is_none() {
        let l = VnLayout::new(0, 4, 2, 3, 4);
        assert_eq!(l.flatten(3, 0), None);
        assert_eq!(l.flatten(0, 8), None);
        assert!(l.flatten(2, 7).is_some());
    }

    #[test]
    fn flatten_bijective_all_orders() {
        // Property: flatten is a bijection [0, r_l1) × [0, n) → [0, slots).
        forall("layout-bijection", 200, |g| {
            let order = g.usize(0, 5) as u8;
            let n_l0 = g.usize(1, 8);
            let n_l1 = g.usize(1, 8);
            let r_l1 = g.usize(1, 8);
            let vn = g.pow2(0, 4);
            let l = VnLayout::new(order, n_l0, n_l1, r_l1, vn);
            let mut seen = vec![false; l.vn_slots()];
            for r in 0..r_l1 {
                for c in 0..l.non_red() {
                    let idx = l.flatten(r, c).unwrap();
                    assert!(idx < l.vn_slots());
                    assert!(!seen[idx], "duplicate L={idx}");
                    seen[idx] = true;
                    assert_eq!(l.unflatten(idx), Some((r, c)));
                }
            }
            assert!(seen.iter().all(|&b| b));
        });
    }

    #[test]
    fn addr_no_two_vns_share_slot() {
        // Property: distinct VNs never collide on (row, col).
        forall("layout-addr-disjoint", 120, |g| {
            let order = g.usize(0, 5) as u8;
            let l = VnLayout::new(order, g.usize(1, 6), g.usize(1, 6), g.usize(1, 6), g.pow2(1, 3));
            let aw = g.pow2(1, 4);
            let mut slots = std::collections::HashSet::new();
            for r in 0..l.r_l1 {
                for c in 0..l.non_red() {
                    let a = l.addr(r, c, aw).unwrap();
                    assert!(slots.insert(a), "VN ({r},{c}) collided at {a:?}");
                    assert_eq!(a.0 % l.vn_size, 0, "rows are vn-aligned");
                    assert!(a.1 < aw);
                }
            }
        });
    }

    #[test]
    fn rows_needed_and_fits() {
        let l = VnLayout::new(0, 4, 1, 2, 4); // 8 VNs
        assert_eq!(l.rows_needed(4), 8); // 2 VN-rows of 4 cols × vn 4
        assert!(l.fits(8, 4));
        assert!(!l.fits(7, 4));
        assert!(l.is_legal(8, 4));
        // N_L0 > AW is ISA-illegal even if capacity is fine.
        let l2 = VnLayout::new(0, 8, 1, 1, 4);
        assert!(!l2.is_legal(100, 4));
    }

    #[test]
    fn conflict_detection() {
        let l = VnLayout::row_major(2, 4, 4);
        let aw = 4;
        // VNs (0,0) and (0,1) land in cols 0 and 1 → no conflict.
        assert_eq!(conflicting_columns(&l, aw, &[(0, 0), (0, 1)]), 0);
        // (0,0) and (1,0): L = 0 and 4 → both col 0 → conflict.
        assert_eq!(conflicting_columns(&l, aw, &[(0, 0), (1, 0)]), 1);
        // Same VN twice = multicast, free.
        assert_eq!(conflicting_columns(&l, aw, &[(0, 2), (0, 2)]), 0);
    }

    #[test]
    fn orders_are_all_permutations() {
        use std::collections::HashSet;
        let set: HashSet<_> = ORDERS.iter().map(|o| format!("{o:?}")).collect();
        assert_eq!(set.len(), 6);
        for o in ORDERS {
            let mut ranks = o.to_vec();
            ranks.sort_by_key(|r| format!("{r:?}"));
            assert_eq!(ranks, vec![Rank::N0, Rank::N1, Rank::R1]);
        }
    }
}
