//! Unified telemetry layer (§Observability tentpole, `docs/OBSERVABILITY.md`).
//!
//! One instrumentation surface across the serving stack:
//!
//! - [`registry`] — lock-light [`MetricsRegistry`] of named atomic
//!   [`Counter`]s, [`Gauge`]s and log-scale [`Histogram`]s. Handles are
//!   `Arc`s around plain atomics: fetch once, cache, one relaxed RMW per
//!   event on the hot path.
//! - [`span`] — per-request [`RequestTrace`] pipeline timelines (arrival →
//!   admission → batch → dispatch → execute → stitch → respond), switched
//!   and sampled by [`TraceOptions`] on `ServerOptions`.
//! - [`export`] — Prometheus text and JSON snapshot renderers over a
//!   point-in-time [`Snapshot`].
//!
//! Servers own their own `Arc<MetricsRegistry>` (so concurrent tests and
//! fleets never share counters); [`global`] exists for process-wide
//! consumers like the `metrics` CLI subcommand.

pub mod export;
pub mod registry;
pub mod span;

pub use export::SNAPSHOT_VERSION;
pub use registry::{
    Counter, Gauge, Histogram, HistogramSnapshot, MetricsRegistry, Snapshot, BUCKETS, OCTAVES,
    SUB_BUCKETS,
};
pub use span::{RequestTrace, Stage, TraceOptions};

use std::sync::OnceLock;

/// Process-global registry for contexts without a natural owner (CLI
/// one-shots). The serving stack deliberately does **not** use this — each
/// `Server` carries its own registry.
pub fn global() -> &'static MetricsRegistry {
    static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();
    GLOBAL.get_or_init(MetricsRegistry::new)
}

/// Point-in-time snapshot of the [`global`] registry.
pub fn snapshot() -> Snapshot {
    global().snapshot()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_registry_is_shared() {
        let c = global().counter("obs_mod_test_total");
        c.add(2);
        global().counter("obs_mod_test_total").inc();
        assert_eq!(snapshot().counter("obs_mod_test_total"), Some(3));
    }
}
