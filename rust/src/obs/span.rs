//! Per-request tracing — the serving pipeline timeline (§Observability
//! tentpole).
//!
//! A sampled request carries a [`RequestTrace`]: an arrival origin plus a
//! monotone list of [`Stage`] marks recorded at every pipeline hand-off
//! (arrival → admission verdict → batch formation → fleet dispatch →
//! execute → stitch → respond). Marks are `Instant`s only — tracing never
//! touches the computation, so traced and untraced serving results are
//! bit-identical by construction (`tests/telemetry.rs` proves it).
//!
//! At respond time the per-stage deltas are folded into the registry's
//! `serve_stage_*_us` histograms ([`RequestTrace::record_into`]); untraced
//! requests skip all of this and pay only the counter adds.

use std::time::Instant;

use super::registry::MetricsRegistry;

/// Serving pipeline stages, in hand-off order. Each mark names the stage
/// that **just completed**: `Admission` is stamped when the admission
/// verdict lands, `Execute` when the executor returns, and so on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Stage {
    /// Request received by the serving leader.
    Arrival,
    /// Admission verdict (admit / shed / expired) decided.
    Admission,
    /// Batch membership decided (batch formed or injected into an open
    /// batch).
    Batch,
    /// A fleet device claimed the batch (single-device mode: dispatch
    /// entry).
    Dispatch,
    /// Executor finished (all shards stitched at the fleet layer).
    Execute,
    /// Outputs sliced per request and the stitch-time deadline re-check
    /// passed.
    Stitch,
    /// Response handed to the transport.
    Respond,
}

impl Stage {
    pub const ALL: [Stage; 7] = [
        Stage::Arrival,
        Stage::Admission,
        Stage::Batch,
        Stage::Dispatch,
        Stage::Execute,
        Stage::Stitch,
        Stage::Respond,
    ];

    /// Stable lowercase name (metric name component).
    pub fn name(self) -> &'static str {
        match self {
            Stage::Arrival => "arrival",
            Stage::Admission => "admission",
            Stage::Batch => "batch",
            Stage::Dispatch => "dispatch",
            Stage::Execute => "execute",
            Stage::Stitch => "stitch",
            Stage::Respond => "respond",
        }
    }
}

/// Tracing switch carried on `ServerOptions` (must stay `Copy`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceOptions {
    /// Master switch; off means zero tracing work and zero span registry
    /// entries.
    pub enabled: bool,
    /// Sample 1-in-N arrivals (1 = every request). 0 is treated as 1.
    pub sample_every: u64,
}

impl Default for TraceOptions {
    fn default() -> Self {
        TraceOptions { enabled: false, sample_every: 1 }
    }
}

impl TraceOptions {
    /// All requests traced — what `loadgen` runs with.
    pub fn all() -> Self {
        TraceOptions { enabled: true, sample_every: 1 }
    }

    /// Should the `seq`-th arrival be traced?
    pub fn sample(&self, seq: u64) -> bool {
        self.enabled && seq % self.sample_every.max(1) == 0
    }
}

/// One request's pipeline timeline: `(stage, mark)` pairs in the order
/// the stages completed. Timestamps are monotone by construction
/// (`Instant::now` is monotonic and marks are appended sequentially along
/// the request's single ownership path).
#[derive(Debug, Clone)]
pub struct RequestTrace {
    events: Vec<(Stage, Instant)>,
}

impl RequestTrace {
    /// Start a trace, stamping [`Stage::Arrival`] now.
    pub fn start() -> Self {
        RequestTrace { events: vec![(Stage::Arrival, Instant::now())] }
    }

    /// Stamp `stage` as completed now. Idempotent per stage: a re-mark of
    /// an already-stamped stage is ignored, so batch-level marks applied
    /// to every member cannot double-count a request that was re-routed.
    pub fn mark(&mut self, stage: Stage) {
        if self.events.iter().any(|&(s, _)| s == stage) {
            return;
        }
        self.events.push((stage, Instant::now()));
    }

    pub fn events(&self) -> &[(Stage, Instant)] {
        &self.events
    }

    /// Stages stamped so far, in completion order.
    pub fn stages(&self) -> Vec<Stage> {
        self.events.iter().map(|&(s, _)| s).collect()
    }

    /// True when every stage of [`Stage::ALL`] is present, in pipeline
    /// order, with non-decreasing timestamps.
    pub fn is_complete(&self) -> bool {
        self.events.len() == Stage::ALL.len()
            && self.events.iter().map(|&(s, _)| s).eq(Stage::ALL)
            && self.is_monotonic()
    }

    /// Timestamps never go backwards.
    pub fn is_monotonic(&self) -> bool {
        self.events.windows(2).all(|w| w[0].1 <= w[1].1)
    }

    /// Per-stage durations in µs: each stamped stage paired with the time
    /// since the previous mark (the arrival mark opens the timeline and
    /// carries no duration).
    pub fn deltas_us(&self) -> Vec<(Stage, f64)> {
        self.events
            .windows(2)
            .map(|w| (w[1].0, w[1].1.duration_since(w[0].1).as_secs_f64() * 1e6))
            .collect()
    }

    /// End-to-end latency (arrival → last mark) in µs.
    pub fn total_us(&self) -> f64 {
        match (self.events.first(), self.events.last()) {
            (Some(&(_, a)), Some(&(_, b))) => b.duration_since(a).as_secs_f64() * 1e6,
            _ => 0.0,
        }
    }

    /// Fold this timeline into the registry: one `serve_stage_<name>_us`
    /// histogram sample per stamped stage plus the end-to-end
    /// `serve_request_us`. Called once at respond time for traced
    /// requests; these histograms are the only place span entries appear,
    /// so a tracing-disabled server registers none of them.
    pub fn record_into(&self, reg: &MetricsRegistry) {
        for (stage, us) in self.deltas_us() {
            reg.histogram(&format!("serve_stage_{}_us", stage.name())).record(us);
        }
        if self.events.len() > 1 {
            reg.histogram("serve_request_us").record(self.total_us());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_respects_switch_and_rate() {
        let off = TraceOptions::default();
        assert!(!off.sample(0));
        let all = TraceOptions::all();
        assert!(all.sample(0) && all.sample(1) && all.sample(17));
        let tenth = TraceOptions { enabled: true, sample_every: 10 };
        let hits = (0..100u64).filter(|&i| tenth.sample(i)).count();
        assert_eq!(hits, 10);
        // 0 clamps to 1 rather than dividing by zero.
        let zero = TraceOptions { enabled: true, sample_every: 0 };
        assert!(zero.sample(5));
    }

    #[test]
    fn full_timeline_is_complete_and_monotonic() {
        let mut t = RequestTrace::start();
        for s in &Stage::ALL[1..] {
            t.mark(*s);
        }
        assert!(t.is_complete());
        assert!(t.is_monotonic());
        assert_eq!(t.stages(), Stage::ALL.to_vec());
        assert_eq!(t.deltas_us().len(), Stage::ALL.len() - 1);
        assert!(t.total_us() >= 0.0);
    }

    #[test]
    fn re_marking_a_stage_is_idempotent() {
        let mut t = RequestTrace::start();
        t.mark(Stage::Admission);
        t.mark(Stage::Admission);
        t.mark(Stage::Batch);
        t.mark(Stage::Batch);
        assert_eq!(t.stages(), vec![Stage::Arrival, Stage::Admission, Stage::Batch]);
        assert!(!t.is_complete());
    }

    #[test]
    fn record_into_registers_one_histogram_per_stage() {
        let reg = MetricsRegistry::new();
        let mut t = RequestTrace::start();
        for s in &Stage::ALL[1..] {
            t.mark(*s);
        }
        t.record_into(&reg);
        let s = reg.snapshot();
        // Six stage deltas + the end-to-end histogram.
        assert_eq!(s.histograms.len(), Stage::ALL.len());
        for stage in &Stage::ALL[1..] {
            let name = format!("serve_stage_{}_us", stage.name());
            assert_eq!(s.histogram(&name).map(|h| h.count), Some(1), "{name}");
        }
        assert_eq!(s.histogram("serve_request_us").map(|h| h.count), Some(1));
    }

    #[test]
    fn partial_timeline_records_partially() {
        // A shed request never reaches Batch: only the stages it stamped
        // land in the registry.
        let reg = MetricsRegistry::new();
        let mut t = RequestTrace::start();
        t.mark(Stage::Admission);
        t.mark(Stage::Respond);
        t.record_into(&reg);
        let s = reg.snapshot();
        assert!(s.histogram("serve_stage_admission_us").is_some());
        assert!(s.histogram("serve_stage_batch_us").is_none());
        assert!(s.histogram("serve_stage_respond_us").is_some());
    }
}
