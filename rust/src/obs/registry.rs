//! Lock-light metrics registry — named atomic counters, gauges and
//! fixed-bucket log-scale histograms (§Observability tentpole).
//!
//! Design constraints, in order:
//!
//! 1. **Hot-path cost**: one relaxed atomic RMW per event. Handles
//!    ([`Counter`], [`Gauge`], [`Histogram`]) are `Arc`s around plain
//!    atomics; holders fetch them **once** at registration time and cache
//!    them, so the registry's name maps are never touched on the serving
//!    hot path.
//! 2. **Std-only**: no external metric crates; histograms are fixed-size
//!    atomic bucket arrays, no allocation after creation.
//! 3. **Exact under concurrency**: every field is a monotone counter or a
//!    commutative min/max, so N threads incrementing concurrently sum
//!    exactly (proven by `tests/telemetry.rs`).
//!
//! Entries are created lazily on first request — a registry nobody
//! recorded into snapshots empty, which is what the tracing-disabled
//! serving test asserts.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex};

/// Sub-buckets per power of two. 8 keeps the worst-case quantile
/// quantization under `2^(1/8) − 1 ≈ 9.1%` of the value, tight enough that
/// histogram-derived p99s stay faithful for the serving latency gates.
pub const SUB_BUCKETS: usize = 8;
/// Octaves covered above 1.0: values up to `2^40` (≈ 1.1e12 — 12 days in
/// microseconds) land in a finite bucket.
pub const OCTAVES: usize = 40;
/// Total bucket count: one underflow bucket `[0, 1)`, `SUB_BUCKETS` per
/// octave, one overflow bucket `[2^OCTAVES, ∞)`.
pub const BUCKETS: usize = SUB_BUCKETS * OCTAVES + 2;

/// Monotone event counter. Cloning shares the underlying atomic.
#[derive(Debug, Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn new() -> Self {
        Self::default()
    }

    /// One event: a single relaxed atomic add.
    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }
}

/// Last-write or running-max gauge over **non-negative** f64 values,
/// stored as IEEE-754 bits (the bit pattern of non-negative floats is
/// order-isomorphic to `u64`, so `fetch_max` on the bits is a float max).
#[derive(Debug, Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Default for Gauge {
    fn default() -> Self {
        Gauge(Arc::new(AtomicU64::new(0f64.to_bits())))
    }
}

impl Gauge {
    pub fn new() -> Self {
        Self::default()
    }

    /// Overwrite the gauge. Negative or NaN values clamp to 0 (the bit
    /// trick requires non-negative payloads).
    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(sanitize(v).to_bits(), Relaxed);
    }

    /// Running maximum: one relaxed `fetch_max`.
    #[inline]
    pub fn set_max(&self, v: f64) {
        self.0.fetch_max(sanitize(v).to_bits(), Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Relaxed))
    }
}

/// Clamp histogram/gauge inputs into the representable domain.
#[inline]
fn sanitize(v: f64) -> f64 {
    if v.is_finite() && v >= 0.0 {
        v
    } else if v == f64::INFINITY {
        f64::MAX
    } else {
        0.0
    }
}

/// Bucket index for a value: `[0,1)` → 0, then `SUB_BUCKETS` buckets per
/// octave, everything at or above `2^OCTAVES` in the final bucket.
#[inline]
pub fn bucket_index(v: f64) -> usize {
    let v = sanitize(v);
    if v < 1.0 {
        return 0;
    }
    let i = (v.log2() * SUB_BUCKETS as f64).floor() as usize + 1;
    i.min(BUCKETS - 1)
}

/// Inclusive lower bound of bucket `i`.
pub fn bucket_lo(i: usize) -> f64 {
    if i == 0 {
        0.0
    } else {
        2f64.powf((i - 1) as f64 / SUB_BUCKETS as f64)
    }
}

/// Exclusive upper bound of bucket `i` (`∞` for the overflow bucket).
pub fn bucket_hi(i: usize) -> f64 {
    if i + 1 >= BUCKETS {
        f64::INFINITY
    } else {
        2f64.powf(i as f64 / SUB_BUCKETS as f64)
    }
}

struct HistogramInner {
    count: AtomicU64,
    /// Sum in thousandths of a unit (integer so a relaxed add suffices;
    /// nanosecond resolution when the unit is microseconds).
    sum_milli: AtomicU64,
    /// Min/max as f64 bits (same trick as [`Gauge`]).
    min_bits: AtomicU64,
    max_bits: AtomicU64,
    buckets: Vec<AtomicU64>,
}

/// Fixed-bucket log-scale histogram. Unit-agnostic; the serving layer
/// records microseconds. Quantiles interpolate within the containing
/// bucket and clamp to the observed `[min, max]`, so a reported p99 never
/// exceeds the largest value actually recorded.
#[derive(Clone)]
pub struct Histogram(Arc<HistogramInner>);

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Histogram")
            .field("count", &self.count())
            .field("sum", &self.sum())
            .finish()
    }
}

impl Histogram {
    pub fn new() -> Self {
        let mut buckets = Vec::with_capacity(BUCKETS);
        buckets.resize_with(BUCKETS, AtomicU64::default);
        Histogram(Arc::new(HistogramInner {
            count: AtomicU64::new(0),
            sum_milli: AtomicU64::new(0),
            min_bits: AtomicU64::new(f64::INFINITY.to_bits()),
            max_bits: AtomicU64::new(0f64.to_bits()),
            buckets,
        }))
    }

    /// Record one observation: a handful of relaxed atomic RMWs, no locks,
    /// no allocation. NaN/negative values clamp to 0.
    pub fn record(&self, v: f64) {
        let v = sanitize(v);
        let h = &*self.0;
        h.count.fetch_add(1, Relaxed);
        h.sum_milli.fetch_add((v * 1e3) as u64, Relaxed);
        h.min_bits.fetch_min(v.to_bits(), Relaxed);
        h.max_bits.fetch_max(v.to_bits(), Relaxed);
        h.buckets[bucket_index(v)].fetch_add(1, Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Relaxed)
    }

    pub fn sum(&self) -> f64 {
        self.0.sum_milli.load(Relaxed) as f64 / 1e3
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return f64::NAN;
        }
        self.sum() / n as f64
    }

    pub fn min(&self) -> f64 {
        if self.count() == 0 {
            return f64::NAN;
        }
        f64::from_bits(self.0.min_bits.load(Relaxed))
    }

    pub fn max(&self) -> f64 {
        if self.count() == 0 {
            return f64::NAN;
        }
        f64::from_bits(self.0.max_bits.load(Relaxed))
    }

    /// Estimated percentile, `p` in `[0, 100]` (the shared implementation
    /// behind every serving percentile — loadgen's per-class p50/p99/p999
    /// included). `NaN` on an empty histogram.
    pub fn percentile(&self, p: f64) -> f64 {
        self.snapshot().percentile(p)
    }

    /// Consistent-enough point-in-time copy (relaxed loads; concurrent
    /// writers may land between field reads, which is fine for reporting).
    pub fn snapshot(&self) -> HistogramSnapshot {
        let h = &*self.0;
        let count = h.count.load(Relaxed);
        let buckets: Vec<(usize, u64)> = h
            .buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| {
                let n = b.load(Relaxed);
                (n > 0).then_some((i, n))
            })
            .collect();
        HistogramSnapshot {
            count,
            sum: h.sum_milli.load(Relaxed) as f64 / 1e3,
            min: if count == 0 { f64::NAN } else { f64::from_bits(h.min_bits.load(Relaxed)) },
            max: if count == 0 { f64::NAN } else { f64::from_bits(h.max_bits.load(Relaxed)) },
            buckets,
        }
    }
}

/// Point-in-time histogram state: non-empty `(bucket index, count)` pairs
/// plus the scalar aggregates.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
    pub buckets: Vec<(usize, u64)>,
}

impl HistogramSnapshot {
    /// Percentile estimate: walk the cumulative bucket counts to the rank,
    /// interpolate linearly within the containing bucket, clamp to the
    /// observed `[min, max]`.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let target = (p.clamp(0.0, 100.0) / 100.0) * self.count as f64;
        let mut cum = 0u64;
        for &(i, n) in &self.buckets {
            let next = cum + n;
            if (next as f64) >= target {
                let lo = bucket_lo(i);
                let hi = bucket_hi(i);
                let frac = if n == 0 { 0.0 } else { (target - cum as f64) / n as f64 };
                let v = if hi.is_finite() { lo + (hi - lo) * frac } else { lo };
                return v.clamp(self.min, self.max);
            }
            cum = next;
        }
        self.max
    }
}

/// A named collection of metrics. Name → handle resolution takes a short
/// mutex (registration is cold); the returned handles are lock-free.
#[derive(Default)]
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<String, Counter>>,
    gauges: Mutex<BTreeMap<String, Gauge>>,
    histograms: Mutex<BTreeMap<String, Histogram>>,
}

impl std::fmt::Debug for MetricsRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.snapshot();
        f.debug_struct("MetricsRegistry")
            .field("counters", &s.counters.len())
            .field("gauges", &s.gauges.len())
            .field("histograms", &s.histograms.len())
            .finish()
    }
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get-or-create the named counter. Fetch once and cache the handle.
    pub fn counter(&self, name: &str) -> Counter {
        self.counters.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    pub fn gauge(&self, name: &str) -> Gauge {
        self.gauges.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    pub fn histogram(&self, name: &str) -> Histogram {
        self.histograms.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    /// Names currently registered in each family (sorted).
    pub fn histogram_names(&self) -> Vec<String> {
        self.histograms.lock().unwrap().keys().cloned().collect()
    }

    /// Point-in-time copy of every entry, sorted by name per family.
    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            counters: self
                .counters
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.get()))
                .collect(),
            gauges: self
                .gauges
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.get()))
                .collect(),
            histograms: self
                .histograms
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.snapshot()))
                .collect(),
        }
    }
}

/// Point-in-time copy of a whole registry — the unit both exporters
/// ([`crate::obs::export`]) consume.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Snapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, f64)>,
    pub histograms: Vec<(String, HistogramSnapshot)>,
}

impl Snapshot {
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.histograms.is_empty()
    }

    pub fn len(&self) -> usize {
        self.counters.len() + self.gauges.len() + self.histograms.len()
    }

    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(k, _)| k == name).map(|(_, v)| *v)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.iter().find(|(k, _)| k == name).map(|(_, v)| *v)
    }

    pub fn histogram(&self, name: &str) -> Option<&HistogramSnapshot> {
        self.histograms.iter().find(|(k, _)| k == name).map(|(_, v)| v)
    }

    /// JSON snapshot document (`docs/OBSERVABILITY.md` §Export formats).
    pub fn to_json(&self) -> String {
        super::export::json(self)
    }

    /// Prometheus text exposition format.
    pub fn to_prometheus(&self) -> String {
        super::export::prometheus(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let r = MetricsRegistry::new();
        assert!(r.snapshot().is_empty(), "fresh registry must be empty");
        let c = r.counter("requests_total");
        c.inc();
        c.add(4);
        // Same name → same underlying atomic.
        assert_eq!(r.counter("requests_total").get(), 5);
        let g = r.gauge("batch_max");
        g.set_max(3.0);
        g.set_max(1.0);
        assert_eq!(g.get(), 3.0);
        g.set(2.5);
        assert_eq!(r.gauge("batch_max").get(), 2.5);
        let s = r.snapshot();
        assert_eq!(s.counter("requests_total"), Some(5));
        assert_eq!(s.gauge("batch_max"), Some(2.5));
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn gauge_clamps_negative_and_nan() {
        let g = Gauge::new();
        g.set(-3.0);
        assert_eq!(g.get(), 0.0);
        g.set(f64::NAN);
        assert_eq!(g.get(), 0.0);
        g.set_max(f64::INFINITY);
        assert_eq!(g.get(), f64::MAX);
    }

    #[test]
    fn bucket_bounds_partition_the_axis() {
        // Every bucket's hi is the next bucket's lo, lo is monotone, and
        // bucket_index lands each bound in its own bucket.
        for i in 0..BUCKETS - 1 {
            assert!(bucket_lo(i) < bucket_hi(i), "bucket {i}");
            assert!((bucket_hi(i) - bucket_lo(i + 1)).abs() < 1e-9 * bucket_hi(i).max(1.0));
        }
        assert_eq!(bucket_index(0.0), 0);
        assert_eq!(bucket_index(0.5), 0);
        assert_eq!(bucket_index(1.0), 1);
        assert_eq!(bucket_index(f64::NAN), 0);
        assert_eq!(bucket_index(f64::MAX), BUCKETS - 1);
        for v in [1.0, 2.0, 3.7, 100.0, 1e6, 3.3e9] {
            let i = bucket_index(v);
            assert!(bucket_lo(i) <= v && v < bucket_hi(i), "{v} in bucket {i}");
        }
    }

    #[test]
    fn histogram_aggregates_and_quantiles() {
        let h = Histogram::new();
        assert!(h.percentile(50.0).is_nan());
        assert!(h.min().is_nan() && h.max().is_nan());
        for v in [10.0, 20.0, 30.0, 40.0, 1000.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert!((h.sum() - 1100.0).abs() < 0.01, "{}", h.sum());
        assert_eq!(h.min(), 10.0);
        assert_eq!(h.max(), 1000.0);
        // p100 clamps to the observed max exactly.
        assert_eq!(h.percentile(100.0), 1000.0);
        // p50 lands within one bucket width (≤ ~9.1%) of a middle sample.
        let p50 = h.percentile(50.0);
        assert!((18.0..=33.0).contains(&p50), "{p50}");
        // Quantiles never leave the observed range.
        assert!(h.percentile(0.0) >= 10.0);
        assert!(h.percentile(99.9) <= 1000.0);
    }

    #[test]
    fn histogram_quantile_error_is_bounded() {
        // Uniform samples: every estimated percentile stays within one
        // sub-bucket ratio (9.1%) of the exact order statistic.
        let h = Histogram::new();
        let xs: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        for &v in &xs {
            h.record(v);
        }
        for p in [10.0, 50.0, 90.0, 99.0, 99.9] {
            let exact = crate::util::percentile(&xs, p);
            let est = h.percentile(p);
            let err = (est - exact).abs() / exact;
            assert!(err <= 0.10, "p{p}: exact {exact} est {est} err {err}");
        }
    }

    #[test]
    fn histogram_clamps_pathological_inputs() {
        let h = Histogram::new();
        h.record(f64::NAN);
        h.record(-5.0);
        h.record(f64::INFINITY);
        assert_eq!(h.count(), 3);
        assert!(h.percentile(50.0).is_finite());
    }

    #[test]
    fn snapshot_roundtrips_buckets() {
        let h = Histogram::new();
        for v in [1.5, 1.6, 300.0] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 3);
        let total: u64 = s.buckets.iter().map(|&(_, n)| n).sum();
        assert_eq!(total, 3, "bucket counts must sum to the event count");
        // Buckets arrive sorted by index (BTree iteration order upstream,
        // enumerate order here).
        for w in s.buckets.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
    }
}
