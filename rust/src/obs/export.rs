//! Snapshot exporters — Prometheus text exposition and the JSON snapshot
//! document (§Observability tentpole; formats in `docs/OBSERVABILITY.md`).
//!
//! Both render a [`Snapshot`], never the live registry: exporting is
//! read-only and costs the hot path nothing. JSON is hand-rolled on the
//! same helpers as `BenchLog` (no serde in the dependency-free crate) and
//! is what `--metrics-out` writes and `tools/check_metrics.py` validates.

use crate::util::bench::{json_escape, json_num};

use super::registry::{bucket_hi, HistogramSnapshot, Snapshot};

/// Version tag of the JSON snapshot document, bumped on breaking layout
/// changes (`tools/metrics_schema.json` pins it).
pub const SNAPSHOT_VERSION: u32 = 1;

/// Prometheus text exposition format: counters and gauges as single
/// samples, histograms as cumulative `_bucket{le=...}` series plus
/// `_sum`/`_count`/`_min`/`_max` companions.
pub fn prometheus(s: &Snapshot) -> String {
    let mut out = String::new();
    for (name, v) in &s.counters {
        let name = sanitize(name);
        out.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
    }
    for (name, v) in &s.gauges {
        let name = sanitize(name);
        out.push_str(&format!("# TYPE {name} gauge\n{name} {v}\n"));
    }
    for (name, h) in &s.histograms {
        let name = sanitize(name);
        out.push_str(&format!("# TYPE {name} histogram\n"));
        let mut cum = 0u64;
        for &(i, n) in &h.buckets {
            cum += n;
            let hi = bucket_hi(i);
            if hi.is_finite() {
                out.push_str(&format!("{name}_bucket{{le=\"{hi}\"}} {cum}\n"));
            }
        }
        out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count));
        out.push_str(&format!("{name}_sum {}\n", h.sum));
        out.push_str(&format!("{name}_count {}\n", h.count));
        if h.count > 0 {
            out.push_str(&format!("{name}_min {}\n{name}_max {}\n", h.min, h.max));
        }
    }
    out
}

/// JSON snapshot document:
///
/// ```json
/// {
///   "schema": 1,
///   "counters": {"serve_served_total": 12, ...},
///   "gauges": {"fleet_dev0_busy_us": 812.5, ...},
///   "histograms": {
///     "serve_stage_execute_us": {
///       "count": 12, "sum": 4096.0, "min": 80.1, "max": 912.0,
///       "p50": 210.2, "p99": 899.0, "p999": 910.0,
///       "buckets": [[64.0, 3], [76.1, 9]]
///     }
///   }
/// }
/// ```
///
/// Bucket entries are `[inclusive lower bound, count]` pairs for
/// non-empty buckets, sorted ascending. Percentiles are precomputed from
/// the buckets (clamped to `[min, max]`) so stdlib-only consumers don't
/// reimplement the quantile walk.
pub fn json(s: &Snapshot) -> String {
    let mut out = String::from("{\n");
    out.push_str(&format!("  \"schema\": {SNAPSHOT_VERSION},\n"));
    out.push_str("  \"counters\": {");
    push_entries(&mut out, &s.counters, |v| v.to_string());
    out.push_str("  },\n  \"gauges\": {");
    push_entries(&mut out, &s.gauges, |v| json_num(*v));
    out.push_str("  },\n  \"histograms\": {");
    push_entries(&mut out, &s.histograms, histogram_json);
    out.push_str("  }\n}\n");
    out
}

/// Render a `name: value` map body with the shared layout (newline per
/// entry, two-space indent, no trailing comma).
fn push_entries<V>(out: &mut String, entries: &[(String, V)], render: impl Fn(&V) -> String) {
    for (i, (name, v)) in entries.iter().enumerate() {
        let sep = if i + 1 == entries.len() { "" } else { "," };
        out.push_str(&format!("\n    \"{}\": {}{sep}", json_escape(name), render(v)));
    }
    if !entries.is_empty() {
        out.push('\n');
    }
}

fn histogram_json(h: &HistogramSnapshot) -> String {
    let buckets: Vec<String> = h
        .buckets
        .iter()
        .map(|&(i, n)| format!("[{}, {n}]", json_num(super::registry::bucket_lo(i))))
        .collect();
    format!(
        "{{\"count\": {}, \"sum\": {}, \"min\": {}, \"max\": {}, \
         \"p50\": {}, \"p99\": {}, \"p999\": {}, \"buckets\": [{}]}}",
        h.count,
        json_num(h.sum),
        json_num(h.min),
        json_num(h.max),
        json_num(h.percentile(50.0)),
        json_num(h.percentile(99.0)),
        json_num(h.percentile(99.9)),
        buckets.join(", "),
    )
}

/// Prometheus metric names: `[a-zA-Z0-9_:]`, no leading digit.
fn sanitize(name: &str) -> String {
    let mut s: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' || c == ':' { c } else { '_' })
        .collect();
    if s.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        s.insert(0, '_');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::super::registry::MetricsRegistry;
    use super::*;

    fn sample_snapshot() -> Snapshot {
        let r = MetricsRegistry::new();
        r.counter("serve_served_total").add(7);
        r.gauge("fleet_dev0_busy_us").set(12.5);
        let h = r.histogram("serve_stage_execute_us");
        for v in [10.0, 20.0, 400.0] {
            h.record(v);
        }
        r.snapshot()
    }

    #[test]
    fn prometheus_renders_all_families() {
        let text = prometheus(&sample_snapshot());
        assert!(text.contains("# TYPE serve_served_total counter"), "{text}");
        assert!(text.contains("serve_served_total 7"), "{text}");
        assert!(text.contains("# TYPE fleet_dev0_busy_us gauge"), "{text}");
        assert!(text.contains("# TYPE serve_stage_execute_us histogram"), "{text}");
        assert!(text.contains("serve_stage_execute_us_bucket{le=\"+Inf\"} 3"), "{text}");
        assert!(text.contains("serve_stage_execute_us_count 3"), "{text}");
        // Cumulative bucket counts are non-decreasing.
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.contains("_bucket{")) {
            let n: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(n >= last, "{line}");
            last = n;
        }
    }

    #[test]
    fn json_document_is_parseable_shape() {
        let doc = json(&sample_snapshot());
        assert!(doc.contains("\"schema\": 1"), "{doc}");
        assert!(doc.contains("\"serve_served_total\": 7"), "{doc}");
        assert!(doc.contains("\"fleet_dev0_busy_us\": 12.5"), "{doc}");
        assert!(doc.contains("\"p99\":"), "{doc}");
        assert!(doc.contains("\"buckets\": [["), "{doc}");
        // Balanced braces/brackets — the structural sanity a hand-rolled
        // emitter can get wrong.
        assert_eq!(doc.matches('{').count(), doc.matches('}').count(), "{doc}");
        assert_eq!(doc.matches('[').count(), doc.matches(']').count(), "{doc}");
        // No trailing commas before a closing brace.
        assert!(!doc.contains(",\n  }"), "{doc}");
        assert!(!doc.contains(",]"), "{doc}");
    }

    #[test]
    fn empty_snapshot_exports_cleanly() {
        let s = Snapshot::default();
        assert_eq!(prometheus(&s), "");
        let doc = json(&s);
        assert!(doc.contains("\"counters\": {"), "{doc}");
        assert_eq!(doc.matches('{').count(), doc.matches('}').count(), "{doc}");
    }

    #[test]
    fn sanitize_prometheus_names() {
        assert_eq!(sanitize("a-b.c"), "a_b_c");
        assert_eq!(sanitize("0abc"), "_0abc");
        assert_eq!(sanitize("ok_name:sub"), "ok_name:sub");
    }
}
