//! Industry-baseline latency models: RTX 5090 (GPU), TPU v6e-8 and a rigid
//! systolic array (§VI-C, Fig. 11).
//!
//! The paper measures these with Nsight / the JAX profiler on real devices;
//! we cannot, so each baseline is an analytical *granularity-padding
//! roofline*: a device executes GEMMs in fixed-shape compute atoms, and a
//! workload whose dimensions do not divide the atom pads up, wasting MACs.
//! Fig. 11's effect (FEATHER+ wins on irregular shapes, loses ~30% to the
//! TPU on perfectly-aligned ones) is exactly this padding effect plus
//! peak-rate scaling to the common 575 W budget. DESIGN.md records the
//! substitution.

use crate::util::{ceil_div, round_up};
use crate::workloads::Gemm;

/// A fixed-granularity matrix engine.
#[derive(Debug, Clone)]
pub struct PaddedDevice {
    pub name: String,
    /// Compute-atom granularity (gm, gk, gn): a GEMM is executed as
    /// ⌈M/gm⌉·⌈K/gk⌉·⌈N/gn⌉ atoms.
    pub gm: usize,
    pub gk: usize,
    pub gn: usize,
    /// Peak INT8 MACs per second at the iso-power operating point.
    pub peak_macs_per_s: f64,
    /// Fixed per-kernel launch/reconfiguration overhead in seconds.
    pub launch_overhead_s: f64,
    /// Achievable fraction of peak on the padded problem (memory system,
    /// scheduling; <1.0).
    pub efficiency: f64,
}

impl PaddedDevice {
    /// Padded MAC count for a workload.
    pub fn padded_macs(&self, g: &Gemm) -> u64 {
        (round_up(g.m, self.gm) as u64)
            * (round_up(g.k, self.gk) as u64)
            * (round_up(g.n, self.gn) as u64)
    }

    /// Latency in microseconds.
    pub fn latency_us(&self, g: &Gemm) -> f64 {
        let macs = self.padded_macs(g) as f64;
        macs / (self.peak_macs_per_s * self.efficiency) * 1e6 + self.launch_overhead_s * 1e6
    }

    /// Compute utilization: useful MACs / padded MACs.
    pub fn utilization(&self, g: &Gemm) -> f64 {
        g.macs() as f64 / self.padded_macs(g) as f64
    }
}

/// RTX 5090 model: tensor-core MMA atom 16×32×8 (INT8), ~1.7 PMACs/s
/// effective INT8 throughput (838 INT8 dense TOPS ≈ 0.42 PMACs/s sustained
/// after scheduling losses — modeled via efficiency), 575 W board power.
pub fn rtx5090() -> PaddedDevice {
    PaddedDevice {
        name: "RTX5090".into(),
        gm: 16,
        gk: 32,
        gn: 8,
        // 838 TOPS INT8 → 419e12 MAC/s peak.
        peak_macs_per_s: 419e12,
        launch_overhead_s: 8e-6,
        efficiency: 0.35,
    }
}

/// TPU v6e-8 model: eight tensor cores, each executing 8×256×256 atoms
/// (the paper's stated minimal INT8 granularity); best (M, N) sharding over
/// the 8 cores is assumed (divide M by up to 8 before padding).
pub fn tpu_v6e8() -> PaddedDevice {
    PaddedDevice {
        name: "TPUv6e-8".into(),
        gm: 8,
        gk: 256,
        gn: 256,
        // 8 cores × ~459 INT8 TOPS ≈ 1837e12 MAC/s; sharding handled in
        // latency_us_sharded.
        peak_macs_per_s: 1837e12,
        launch_overhead_s: 25e-6,
        efficiency: 0.72,
    }
}

/// TPU latency with best (M, N) sharding across 8 cores (§VI-A Metrics).
pub fn tpu_latency_us(g: &Gemm) -> f64 {
    let dev = tpu_v6e8();
    let mut best = f64::INFINITY;
    for shards_m in [1usize, 2, 4, 8] {
        let shards_n = 8 / shards_m;
        let gs = Gemm::new(&g.name, &g.category, ceil_div(g.m, shards_m), g.k, ceil_div(g.n, shards_n));
        // Each core runs its shard at 1/8 of aggregate peak.
        let core = PaddedDevice { peak_macs_per_s: dev.peak_macs_per_s / 8.0, ..dev.clone() };
        best = best.min(core.latency_us(&gs));
    }
    best
}

/// GPU latency: best of tiled/strided/contiguous CUDA-kernel layouts is
/// modeled as the best of three granularity orientations, scaled by a
/// reduction-depth factor: tensor-core pipelines need K ≳ 256 to stream at
/// rate (measured GEMM kernels on K≈40 shapes run far below padding-only
/// rooflines — the effect the paper's Nsight traces capture).
pub fn gpu_latency_us(g: &Gemm) -> f64 {
    let base = rtx5090();
    let variants = [
        (base.gm, base.gk, base.gn),
        (base.gn, base.gk, base.gm), // transposed kernel
        (32, 32, 32),                // generic tiled kernel
    ];
    let depth_factor = (g.k as f64 / 256.0).clamp(0.12, 1.0);
    variants
        .iter()
        .map(|&(gm, gk, gn)| {
            let dev = PaddedDevice {
                gm,
                gk,
                gn,
                efficiency: base.efficiency * depth_factor,
                ..base.clone()
            };
            dev.latency_us(g)
        })
        .fold(f64::INFINITY, f64::min)
}

/// Rigid systolic array (Fig. 13's "3% utilization" comparator): a single
/// 256×256 weight-stationary array with no mapping flexibility.
pub fn rigid_systolic() -> PaddedDevice {
    PaddedDevice {
        name: "Systolic256".into(),
        gm: 1,
        gk: 256,
        gn: 256,
        peak_macs_per_s: 65536e9, // 256·256 MACs @ 1 GHz
        launch_overhead_s: 0.0,
        efficiency: 1.0,
    }
}

/// FEATHER+ iso-power scaling for Fig. 11: 64 instances of a 16×256 tile in
/// an 8×8 mesh (§VI-C1). A workload is sharded over instances along M.
pub fn featherplus_mesh_latency_us(single_tile_us: f64, m: usize, instances: usize) -> f64 {
    // M-sharding: each instance handles ⌈M/instances⌉ of the rows; latency
    // scales by the shard fraction (the per-instance model already includes
    // all other dimensions).
    let shard = ceil_div(m, instances) as f64 / m.max(1) as f64;
    single_tile_us * shard
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padding_hurts_irregular_shapes() {
        let irregular = Gemm::new("i", "FHE", 65536, 40, 88);
        let regular = Gemm::new("r", "NTT", 65536, 1024, 2048);
        let tpu = tpu_v6e8();
        // K=40 pads to 256 (6.4×), N=88 pads to 256 (2.9×) → utilization
        // collapses on the TPU for the irregular shape.
        assert!(tpu.utilization(&irregular) < 0.1);
        assert!(tpu.utilization(&regular) > 0.99);
    }

    #[test]
    fn rigid_systolic_is_terrible_on_fhe_shapes() {
        // Fig. 13: rigid arrays at ~3% utilization on mismatched dims.
        let g = Gemm::new("i", "FHE", 65536, 40, 88);
        let u = rigid_systolic().utilization(&g);
        assert!(u < 0.06, "util {u}");
    }

    #[test]
    fn gpu_padding_finer_than_tpu() {
        // GPU atoms are much smaller → better utilization on small K/N.
        let g = Gemm::new("i", "FHE", 65536, 40, 88);
        assert!(rtx5090().utilization(&g) > tpu_v6e8().utilization(&g));
    }

    #[test]
    fn latency_positive_and_monotone_in_size() {
        let small = Gemm::new("s", "t", 128, 128, 128);
        let big = Gemm::new("b", "t", 4096, 4096, 4096);
        assert!(gpu_latency_us(&small) > 0.0);
        assert!(gpu_latency_us(&big) > gpu_latency_us(&small));
        assert!(tpu_latency_us(&big) > tpu_latency_us(&small));
    }

    #[test]
    fn tpu_sharding_helps_tall_matrices() {
        let tall = Gemm::new("t", "t", 16384, 1024, 1024);
        let dev = tpu_v6e8();
        let unsharded = PaddedDevice { peak_macs_per_s: dev.peak_macs_per_s / 8.0, ..dev }
            .latency_us(&tall);
        assert!(tpu_latency_us(&tall) < unsharded * 0.9);
    }

    #[test]
    fn mesh_sharding_scales() {
        let us = featherplus_mesh_latency_us(640.0, 65536, 64);
        assert!(us < 640.0 / 32.0);
        // Tiny M cannot use all instances.
        let small = featherplus_mesh_latency_us(640.0, 32, 64);
        assert!(small >= 640.0 / 64.0);
    }
}
