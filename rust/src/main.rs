//! MINISA CLI — see `minisa help` or cli/mod.rs.
fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(minisa::cli::run(&argv));
}
