//! Compiled model **Programs** — compile a whole layer chain once, serve it
//! many times (§IV-G, §V-A).
//!
//! MINISA's headline win is amortized control: traces span *layers* (layer
//! i's `SetOVNLayout` doubles as layer i+1's `SetIVNLayout`, §IV-G2), yet a
//! per-request serving path that re-runs the mapper and re-derives wave
//! control per GEMM throws that away. A [`Program`] is the compile-once
//! artifact the serving stack executes instead:
//!
//! * **Per-layer decisions** from a chain-aware mapper pass: every layer is
//!   searched under *both* dataflows ([`search_constrained`]) and the two
//!   dataflow-alternating assignments are compared — alternation is what
//!   makes layer i's committed output land in exactly the buffer layer i+1
//!   consumes from (§III-B refinement 3), i.e. the §V-A "inter-layer layout
//!   compatibility" rule. The cheaper alternating assignment wins; a layer
//!   whose required dataflow is infeasible falls back to its free best,
//!   breaking compatibility only at that boundary.
//! * **Boundary-aligned layout orders**: where alternation alone leaves the
//!   producer's output order disagreeing with the consumer's expected
//!   order, the order is re-tuned (never at a latency cost) so the §IV-G2
//!   `SetIVNLayout` elision applies.
//! * **The fused trace** with elision accounting ([`Program::elided`],
//!   fused vs standalone byte totals).
//! * **Per-layer staging plans** from [`lower_gemm`] (HBM images, harvests,
//!   per-invocation schedules).
//! * **Pre-built wave plans**: every (θ_EM, θ_ES, layouts) tuple the fused
//!   trace will execute is compiled to a [`WavePlan`] at program-compile
//!   time; [`Program::seed_sim`] installs them so functional execution of
//!   the whole program performs **zero** plan compiles per request.
//!
//! Programs are immutable and shareable (`Arc<Program>`): the serving
//! coordinator registers one per model session
//! ([`crate::coordinator::serve::Server::register_chain`]) and every request
//! references it by [`crate::coordinator::serve::ProgramId`] instead of
//! carrying weights inline.

use std::collections::HashMap;
use std::sync::Arc;

use crate::arch::config::ArchConfig;
use crate::arith::{naive_gemm_e, Element};
use crate::artifact::{Artifact, ArtifactError, WeightsPayload};
use crate::functional::{BlockSim, FunctionalSim, PlanKey, SimError, WavePlan};
use crate::isa::encode::Codec;
use crate::isa::inst::Inst;
use crate::mapper::exec::{execute_program_on, execute_program_rows_on};
use crate::isa::Trace;
use crate::mapper::chain::{boundary_compatible, Chain, ChainDecision};
use crate::mapper::lower::LoweredProgram;
use crate::mapper::search::{estimate, search_constrained, MapperOptions};
use crate::mapper::{lower_gemm, Decision};
use crate::mapping::Dataflow;
use crate::perf::StallModel;
use crate::workloads::Gemm;

/// One compiled layer: the workload, its mapping decision and the lowered
/// MINISA program (trace + staging + harvests + schedule).
#[derive(Debug, Clone)]
pub struct ProgramLayer {
    pub gemm: Gemm,
    pub decision: Decision,
    pub lowered: LoweredProgram,
}

/// A compiled multi-layer model program. Immutable once built; share it as
/// `Arc<Program>`.
#[derive(Debug, Clone)]
pub struct Program {
    pub cfg: ArchConfig,
    pub chain: Chain,
    pub layers: Vec<ProgramLayer>,
    /// Fused multi-layer trace, §IV-G2 elision applied.
    pub fused: Trace,
    /// Redundant inter-layer `SetIVNLayout`s: boundaries where the
    /// §V-A compatibility rule holds (the successor's consumed layout is
    /// the predecessor's committed output layout), so the fused trace may
    /// skip the successor's layout programming.
    pub elided: usize,
    /// Fused trace size in bytes, after elision.
    pub fused_bytes: u64,
    /// Sum of standalone per-layer trace bytes (no elision).
    pub standalone_bytes: u64,
    /// Total modeled cycles (layers serialize on the data dependence).
    pub total_cycles: f64,
    /// Modeled compute vs instruction-fetch cycles for the whole chain
    /// under MINISA control and its micro-instruction twin — the unit the
    /// fleet's live stall accounting apportions per dispatched shard
    /// (derived deterministically from the decisions; deliberately **not**
    /// part of the artifact accounting or its fidelity checks).
    pub stall: StallModel,
    /// Wave plans for every (θ_EM, θ_ES, layouts) tuple in the fused trace,
    /// compiled once here and installed into simulators via [`seed_sim`].
    ///
    /// [`seed_sim`]: Program::seed_sim
    plans: HashMap<PlanKey, Arc<WavePlan>>,
}

impl Program {
    /// Compile a chain: chain-aware mapper search, lowering, trace fusion
    /// and wave-plan precompilation. `None` when the chain is invalid or no
    /// layer maps feasibly.
    pub fn compile(cfg: &ArchConfig, chain: &Chain, opts: &MapperOptions) -> Option<Program> {
        if chain.layers.is_empty() {
            return None;
        }
        chain.validate().ok()?;
        let mut decisions = plan_chain_decisions(cfg, chain, opts)?;
        align_boundary_orders(cfg, chain, &mut decisions, opts.minisa);
        let built = build_chain(cfg, chain, &decisions);
        let plans = compile_plans(cfg, &built.layers);
        Some(Program {
            cfg: cfg.clone(),
            chain: chain.clone(),
            layers: built.layers,
            fused: built.fused,
            elided: built.elided,
            fused_bytes: built.fused_bytes,
            standalone_bytes: built.standalone_bytes,
            total_cycles: built.total_cycles,
            stall: built.stall,
            plans,
        })
    }

    pub fn layer_count(&self) -> usize {
        self.layers.len()
    }

    /// Activation feature width the program consumes (layer 0's K).
    pub fn in_features(&self) -> usize {
        self.chain.layers[0].k
    }

    /// Output feature width the program produces (last layer's N).
    pub fn out_features(&self) -> usize {
        self.chain.layers.last().unwrap().n
    }

    /// Activation row count the chain was compiled for (shared M).
    pub fn rows(&self) -> usize {
        self.chain.layers[0].m
    }

    /// Number of distinct wave plans compiled for the fused trace.
    pub fn plan_count(&self) -> usize {
        self.plans.len()
    }

    /// The view `mapper::chain::map_chain` reports.
    pub fn chain_decision(&self) -> ChainDecision {
        ChainDecision {
            per_layer: self.layers.iter().map(|l| l.decision.clone()).collect(),
            total_cycles: self.total_cycles,
            elided: self.elided,
            fused_bytes: self.fused_bytes,
            standalone_bytes: self.standalone_bytes,
        }
    }

    /// Install this program's precompiled wave plans into a simulator, so
    /// executing the program compiles nothing (idempotent). Plans hold
    /// addressing only, so one program seeds simulators of *any* element
    /// backend.
    ///
    /// Panics if the simulator was built from a different `ArchConfig`:
    /// `PlanKey` deliberately excludes buffer geometry (fixed per
    /// simulator), so cross-config seeding would execute plans whose
    /// addressing was baked for the wrong array.
    pub fn seed_sim<E: Element>(&self, sim: &mut FunctionalSim<E>) {
        assert_eq!(sim.cfg, self.cfg, "simulator must share the program's ArchConfig");
        sim.seed_plans(self.plans.iter().map(|(k, v)| (*k, Arc::clone(v))));
    }

    /// Execute the whole program functionally under any element backend:
    /// the activation flows through every layer, narrowed to the element
    /// domain between layers ([`Element::reduce`]) exactly as the
    /// OB→operand-buffer commit narrows it. Returns the final layer's
    /// `M × N_last` output (row-major accumulators).
    ///
    /// All tile execution goes through the plans compiled at
    /// program-compile time ([`Self::seed_sim`] runs first), so
    /// `sim.plan_compiles` does not grow — for prime-field backends this is
    /// the compile-once path that serves FHE/ZKP NTT programs field-exactly.
    pub fn execute<E: Element>(
        &self,
        sim: &mut FunctionalSim<E>,
        input: &[E],
        weights: &[Vec<E>],
    ) -> Result<Vec<E::Acc>, SimError> {
        if weights.len() != self.layers.len() {
            return Err(SimError::Invalid(format!(
                "program expects {} weight matrices, got {}",
                self.layers.len(),
                weights.len()
            )));
        }
        if input.len() != self.rows() * self.in_features() {
            return Err(SimError::Invalid(format!(
                "activation is {} elements, expected {}×{}",
                input.len(),
                self.rows(),
                self.in_features()
            )));
        }
        self.seed_sim(sim);
        let mut act: Vec<E> = input.to_vec();
        let mut out: Vec<E::Acc> = Vec::new();
        for (li, l) in self.layers.iter().enumerate() {
            out = execute_program_on(sim, &l.gemm, &l.lowered, &act, &weights[li])?;
            if li + 1 < self.layers.len() {
                act = out.iter().map(|&v| E::reduce(v)).collect();
            }
        }
        Ok(out)
    }

    /// [`Self::execute`] across a block of activation batches (§Perf):
    /// `inputs[l]` flows through the whole chain on lane `l` of the block
    /// simulator, with every tile executed by the blocked multi-row kernel
    /// ([`crate::functional::WavePlan::execute_rows`]) — the compiled wave
    /// plans are walked once per block instead of once per batch, and the
    /// weight staging images are built once and broadcast. Lane-for-lane
    /// bit-identical to sequential [`Self::execute`] calls, with zero plan
    /// compiles (every lane is seeded); `tests/plan_equivalence.rs`
    /// enforces both.
    pub fn execute_rows<E: Element>(
        &self,
        block: &mut BlockSim<E>,
        inputs: &[Vec<E>],
        weights: &[Vec<E>],
    ) -> Result<Vec<Vec<E::Acc>>, SimError> {
        if weights.len() != self.layers.len() {
            return Err(SimError::Invalid(format!(
                "program expects {} weight matrices, got {}",
                self.layers.len(),
                weights.len()
            )));
        }
        for input in inputs {
            if input.len() != self.rows() * self.in_features() {
                return Err(SimError::Invalid(format!(
                    "activation is {} elements, expected {}×{}",
                    input.len(),
                    self.rows(),
                    self.in_features()
                )));
            }
        }
        for sim in block.lanes_mut(inputs.len()) {
            self.seed_sim(sim);
        }
        let mut acts: Vec<Vec<E>> = inputs.to_vec();
        let mut outs: Vec<Vec<E::Acc>> = Vec::new();
        for (li, l) in self.layers.iter().enumerate() {
            outs = execute_program_rows_on(block, &l.gemm, &l.lowered, &acts, &weights[li])?;
            if li + 1 < self.layers.len() {
                acts = outs
                    .iter()
                    .map(|out| out.iter().map(|&v| E::reduce(v)).collect())
                    .collect();
            }
        }
        Ok(outs)
    }

    /// [`Self::execute`] at the default saturating-i32 backend (the
    /// pre-`arith` API, kept verbatim for existing callers).
    pub fn execute_i32(
        &self,
        sim: &mut FunctionalSim,
        input: &[i32],
        weights: &[Vec<i32>],
    ) -> Result<Vec<i64>, SimError> {
        self.execute(sim, input, weights)
    }

    /// Reference semantics of [`Self::execute`]: chained naive GEMMs with
    /// the same inter-layer narrowing.
    pub fn reference<E: Element>(&self, input: &[E], weights: &[Vec<E>]) -> Vec<E::Acc> {
        assert_eq!(weights.len(), self.layers.len(), "one weight matrix per layer");
        let m = self.rows();
        let mut act: Vec<E> = input.to_vec();
        let mut out: Vec<E::Acc> = Vec::new();
        for (li, (g, w)) in self.chain.layers.iter().zip(weights).enumerate() {
            out = naive_gemm_e::<E>(&act, w, m, g.k, g.n);
            if li + 1 < self.layers.len() {
                act = out.iter().map(|&v| E::reduce(v)).collect();
            }
        }
        out
    }

    /// [`Self::reference`] at the default saturating-i32 backend.
    pub fn reference_i32(&self, input: &[i32], weights: &[Vec<i32>]) -> Vec<i64> {
        self.reference(input, weights)
    }

    /// Package this program as a deployable [`Artifact`] whose payload is
    /// the **encoded** fused MINISA trace — the paper's minimal off-chip
    /// form — plus the chain spec, the per-layer decisions and an optional
    /// resident-weights payload. `Program::from_artifact` is the inverse;
    /// `crate::artifact::Compiler` is the builder front-end over
    /// [`Self::compile`] + this.
    pub fn to_artifact(&self, payload: Option<WeightsPayload>) -> Result<Artifact, ArtifactError> {
        if let Some(p) = &payload {
            crate::artifact::validate_payload_dims(&self.chain, &p.weights)?;
        }
        let codec = Codec::new(&self.cfg);
        let trace_bytes = codec.encode_all(&self.fused.insts)?;
        Ok(Artifact {
            cfg: self.cfg.clone(),
            chain: self.chain.clone(),
            decision: self.chain_decision(),
            layer_starts: self.fused.layer_starts.clone(),
            inst_count: self.fused.len(),
            trace_bytes,
            payload,
        })
    }

    /// Rebuild an executable program from a deployable artifact with
    /// **zero mapper runs**: the encoded stream is decoded back into the
    /// executable fused trace ([`Codec::decode_stream`] — the decoded
    /// instructions *are* this program's `fused` field), the per-layer
    /// staging/schedule metadata is replayed by deterministic lowering from
    /// the stored decisions (`lower_gemm` — the mapper's *output*, never
    /// its search), and the wave plans are recompiled locally.
    ///
    /// Every load proves byte-level round-trip fidelity: the decoded stream
    /// must be structurally identical to the re-lowered trace, re-encode to
    /// exactly the stored bytes, and reproduce the stored elision/byte
    /// accounting — a corrupted or drifted artifact fails here rather than
    /// serving wrong addresses.
    pub fn from_artifact(art: &Artifact) -> Result<Program, ArtifactError> {
        let cfg = &art.cfg;
        let chain = &art.chain;
        if art.decision.per_layer.len() != chain.layers.len() {
            return Err(ArtifactError::Corrupt(format!(
                "{} decisions for {} layers",
                art.decision.per_layer.len(),
                chain.layers.len()
            )));
        }
        // `from_bytes` already bounds parsed containers; re-check here so a
        // hand-assembled in-memory Artifact (fields are public) can't make
        // the re-lowering below loop without bound either.
        crate::artifact::bound_lowering_work(cfg, chain, &art.decision.per_layer)?;
        let codec = Codec::new(cfg);
        // 1. The canonical program: decode the shipped instruction stream.
        let insts = codec.decode_stream(&art.trace_bytes, art.inst_count)?;
        let fused = Trace::from_insts(insts, art.layer_starts.clone());
        // 2. Deterministic re-lowering from the stored decisions — the same
        //    `build_chain` the compiler ran, so the two paths cannot drift.
        let built = build_chain(cfg, chain, &art.decision.per_layer);
        // 3. Fidelity proofs: decoded ≡ re-lowered ≡ stored bytes.
        if fused.insts != built.fused.insts || fused.layer_starts != built.fused.layer_starts {
            return Err(ArtifactError::Mismatch(
                "decoded stream disagrees with deterministic re-lowering".into(),
            ));
        }
        if codec.encode_all(&built.fused.insts)? != art.trace_bytes {
            return Err(ArtifactError::Mismatch(
                "re-encoded trace differs from the stored bytes".into(),
            ));
        }
        if built.elided != art.decision.elided
            || built.fused_bytes != art.decision.fused_bytes
            || built.standalone_bytes != art.decision.standalone_bytes
            || built.total_cycles != art.decision.total_cycles
        {
            return Err(ArtifactError::Mismatch(
                "stored accounting (elision/bytes/cycles) disagrees with the stream".into(),
            ));
        }
        // 4. Recompile the wave plans locally (addressing only; no search).
        let plans = compile_plans(cfg, &built.layers);
        Ok(Program {
            cfg: cfg.clone(),
            chain: chain.clone(),
            layers: built.layers,
            fused,
            elided: built.elided,
            fused_bytes: built.fused_bytes,
            standalone_bytes: built.standalone_bytes,
            total_cycles: built.total_cycles,
            stall: built.stall,
            plans,
        })
    }

    /// A contiguous row-range view of this program for tile-parallel (fleet)
    /// execution. Rows of a GEMM chain are independent, so a larger
    /// activation can be split into contiguous shards, each executed against
    /// the *same* compiled program — shards reuse the program's precompiled
    /// wave plans verbatim, so sharding performs **zero** additional plan or
    /// program compiles. The shard maps its row range onto input/output word
    /// ranges of the full activation; `start > end` ranges are normalized to
    /// empty rather than panicking (adversarial boundaries are the caller's
    /// domain — see `coordinator::fleet::plan_shards`).
    pub fn shard_rows(&self, rows: std::ops::Range<usize>) -> ProgramShard<'_> {
        let start = rows.start.min(rows.end);
        ProgramShard { program: self, rows: start..rows.end }
    }
}

/// A row-range view of a [`Program`] — the unit of tile-parallel fleet
/// execution ([`Program::shard_rows`]). Holds addressing only: the shard
/// borrows the program (and therefore its compiled wave plans) rather than
/// copying anything.
#[derive(Debug, Clone)]
pub struct ProgramShard<'a> {
    pub program: &'a Program,
    /// Row range within the (possibly batched) activation this shard covers.
    pub rows: std::ops::Range<usize>,
}

impl ProgramShard<'_> {
    /// Number of activation rows in this shard.
    pub fn row_count(&self) -> usize {
        self.rows.len()
    }

    /// Index range of this shard's words within the full row-major
    /// activation (`rows × in_features` words).
    pub fn input_words(&self) -> std::ops::Range<usize> {
        let kf = self.program.in_features();
        self.rows.start * kf..self.rows.end * kf
    }

    /// Index range of this shard's words within the full row-major output
    /// (`rows × out_features` words) — where the shard's result is stitched
    /// back, preserving `OutputBuffer` row order.
    pub fn output_words(&self) -> std::ops::Range<usize> {
        let nf = self.program.out_features();
        self.rows.start * nf..self.rows.end * nf
    }
}

/// Everything derived *deterministically* from finalized per-layer
/// decisions: the lowered layers, the fused trace with §IV-G2 elision, and
/// the byte/cycle accounting.
struct BuiltChain {
    layers: Vec<ProgramLayer>,
    fused: Trace,
    elided: usize,
    fused_bytes: u64,
    standalone_bytes: u64,
    total_cycles: f64,
    stall: StallModel,
}

/// Lower every layer from its finalized decision, fuse, elide and account —
/// shared by [`Program::compile`] (post-search decisions) and
/// [`Program::from_artifact`] (stored decisions), so the compile path and
/// the loader's fidelity proof can never drift apart.
fn build_chain(cfg: &ArchConfig, chain: &Chain, decisions: &[Decision]) -> BuiltChain {
    let mut layers = Vec::with_capacity(chain.layers.len());
    let mut fused = Trace::new();
    let mut standalone_bytes = 0u64;
    let mut stall = StallModel::default();
    for (g, d) in chain.layers.iter().zip(decisions) {
        let lowered = lower_gemm(cfg, g, &d.choice, d.i_order, d.w_order, d.o_order);
        standalone_bytes += lowered.minisa_bytes();
        fused.splice_layer(&lowered.trace);
        // Live stall accounting: the same mapping re-costed under
        // micro-instruction control (closed-form `estimate`, never a mapper
        // search — the zero-mapper-run loading guarantee holds). A layer
        // the closed form cannot re-cost contributes its MINISA report
        // twice, i.e. a neutral stall entry rather than a hole.
        let micro = estimate(cfg, g, &d.choice, d.i_order, d.o_order, false)
            .unwrap_or_else(|| d.report.clone());
        stall.absorb_scaled(&StallModel::from_reports(&d.report, &micro), 1.0);
        layers.push(ProgramLayer { gemm: g.clone(), decision: d.clone(), lowered });
    }
    let trace_elided = fused.elide_interlayer_layouts();
    let mut compat = 0usize;
    for i in 1..layers.len() {
        if boundary_compatible(
            &layers[i - 1].decision,
            &layers[i].decision,
            cfg,
            (&chain.layers[i - 1], &chain.layers[i]),
        ) {
            compat += 1;
        }
    }
    let fused_bytes = fused.size_bytes(&Codec::new(cfg));
    let total_cycles = layers.iter().map(|l| l.decision.report.total_cycles).sum();
    BuiltChain {
        layers,
        fused,
        elided: compat.max(trace_elided),
        fused_bytes,
        standalone_bytes,
        total_cycles,
        stall,
    }
}

/// Chain-aware per-layer decision planning: search each layer under both
/// dataflows, then pick the cheaper of the two alternating assignments
/// (§V-A). A layer infeasible under its required dataflow falls back to the
/// other one (compatibility breaks at that boundary only).
fn plan_chain_decisions(
    cfg: &ArchConfig,
    chain: &Chain,
    opts: &MapperOptions,
) -> Option<Vec<Decision>> {
    let per_df: Vec<[Option<Decision>; 2]> = chain
        .layers
        .iter()
        .map(|g| {
            [
                search_constrained(cfg, g, opts, Some(Dataflow::WoS)),
                search_constrained(cfg, g, opts, Some(Dataflow::IoS)),
            ]
        })
        .collect();
    let assignment = |start_wos: bool| -> Option<(Vec<Decision>, f64)> {
        let mut out: Vec<Decision> = Vec::with_capacity(per_df.len());
        let mut total = 0.0;
        let mut want_wos = start_wos;
        for dfs in per_df.iter() {
            let want = usize::from(!want_wos); // 0 = WoS, 1 = IoS
            let d = dfs[want].as_ref().or(dfs[1 - want].as_ref())?;
            total += d.report.total_cycles;
            // Alternate from the dataflow actually taken, so a layer that
            // fell back to the other dataflow breaks compatibility at its
            // own boundary only — successors re-alternate from it.
            want_wos = d.choice.df == Dataflow::IoS;
            out.push(d.clone());
        }
        Some((out, total))
    };
    let alt = match (assignment(true), assignment(false)) {
        (Some((a, ta)), Some((b, tb))) => Some(if ta <= tb { a } else { b }),
        (Some((a, _)), None) => Some(a),
        (None, Some((b, _))) => Some(b),
        (None, None) => None,
    }?;
    // Alternation is only worth enforcing when some boundary can actually
    // become compatible (dataflows alternate AND VN sizes agree; the order
    // is alignable afterwards). If no boundary qualifies — single-layer
    // chains, or VN sizes that differ everywhere — there is nothing to
    // elide, so take each layer's free best instead of paying the
    // constraint for nothing.
    let any_compat = alt
        .windows(2)
        .any(|w| w[0].choice.df != w[1].choice.df && w[0].choice.vn == w[1].choice.vn);
    if any_compat {
        return Some(alt);
    }
    let free: Option<Vec<Decision>> = per_df
        .iter()
        .map(|dfs| match (dfs[0].as_ref(), dfs[1].as_ref()) {
            (Some(a), Some(b)) => {
                let best = if a.report.total_cycles <= b.report.total_cycles { a } else { b };
                Some(best.clone())
            }
            (Some(a), None) => Some(a.clone()),
            (None, Some(b)) => Some(b.clone()),
            (None, None) => None,
        })
        .collect();
    Some(free.unwrap_or(alt))
}

/// Re-tune layout orders at alternating boundaries so the committed output
/// layout equals the consumed layout (making the §IV-G2 elision apply) —
/// accepted only when the re-estimated latency does not regress.
fn align_boundary_orders(
    cfg: &ArchConfig,
    chain: &Chain,
    decisions: &mut [Decision],
    minisa: bool,
) {
    for i in 1..decisions.len() {
        let (head, tail) = decisions.split_at_mut(i);
        let prev = &mut head[i - 1];
        let next = &mut tail[0];
        let (g_prev, g_next) = (&chain.layers[i - 1], &chain.layers[i]);
        if next.choice.df == prev.choice.df || next.choice.vn != prev.choice.vn {
            continue; // compatibility cannot hold; leave the decisions alone
        }
        if boundary_compatible(prev, next, cfg, (g_prev, g_next)) {
            continue;
        }
        match prev.choice.df {
            // WO-S feeds IO-S: the successor consumes through its
            // *stationary* layout (order `w_order`); re-tune the
            // predecessor's output order to match.
            Dataflow::WoS => {
                if let Some(rep) =
                    estimate(cfg, g_prev, &prev.choice, prev.i_order, next.w_order, minisa)
                {
                    if rep.total_cycles <= prev.report.total_cycles {
                        prev.o_order = next.w_order;
                        prev.report = rep;
                    }
                }
            }
            // IO-S feeds WO-S: the successor *streams* its input (order
            // `i_order`); re-tune the successor's streamed order.
            Dataflow::IoS => {
                if let Some(rep) =
                    estimate(cfg, g_next, &next.choice, prev.o_order, next.o_order, minisa)
                {
                    if rep.total_cycles <= next.report.total_cycles {
                        next.i_order = prev.o_order;
                        next.report = rep;
                    }
                }
            }
        }
    }
}

/// Compile the wave plan for every (θ_EM, θ_ES, layouts) tuple the layers'
/// traces will execute — the same key derivation as
/// `FunctionalSim::run_tile`, performed once at program-compile time.
fn compile_plans(cfg: &ArchConfig, layers: &[ProgramLayer]) -> HashMap<PlanKey, Arc<WavePlan>> {
    let mut plans = HashMap::new();
    for l in layers {
        let mut i_lay = None;
        let mut w_lay = None;
        let mut o_lay = None;
        let mut cur_em = None;
        for inst in &l.lowered.trace.insts {
            match inst {
                Inst::SetIVNLayout(x) => i_lay = Some(x.layout),
                Inst::SetWVNLayout(x) => w_lay = Some(x.layout),
                Inst::SetOVNLayout(x) => o_lay = Some(x.layout),
                Inst::ExecuteMapping(em) => cur_em = Some(*em),
                Inst::ExecuteStreaming(es) => {
                    let (Some(em), Some(i), Some(w), Some(o)) = (cur_em, i_lay, w_lay, o_lay)
                    else {
                        continue; // malformed prefix: the simulator will error
                    };
                    let (sta, strl) = match es.df {
                        Dataflow::WoS => (w, i),
                        Dataflow::IoS => (i, w),
                    };
                    if sta.vn_size < es.vn_size {
                        continue; // illegal-program class: reference path handles it
                    }
                    let key =
                        PlanKey { em, es: *es, sta_layout: sta, str_layout: strl, o_layout: o };
                    plans.entry(key).or_insert_with(|| {
                        Arc::new(WavePlan::compile(
                            cfg,
                            &em,
                            es,
                            &sta,
                            &strl,
                            &o,
                            cfg.d_sta(),
                            cfg.d_str(),
                            cfg.d_ob(),
                        ))
                    });
                }
                _ => {}
            }
        }
    }
    plans
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Lcg;

    fn fast() -> MapperOptions {
        MapperOptions { full_layout_search: false, threads: 1, ..Default::default() }
    }

    fn rand_weights(chain: &Chain, seed: u64) -> Vec<Vec<i32>> {
        let mut rng = Lcg::new(seed);
        chain
            .layers
            .iter()
            .map(|g| (0..g.k * g.n).map(|_| rng.range(0, 9) as i32 - 4).collect())
            .collect()
    }

    #[test]
    fn compiles_three_layer_mlp() {
        let cfg = ArchConfig::paper(4, 8);
        let chain = Chain::mlp("mlp", 16, &[16, 24, 16, 8]);
        let p = Program::compile(&cfg, &chain, &fast()).unwrap();
        assert_eq!(p.layer_count(), 3);
        assert_eq!(p.in_features(), 16);
        assert_eq!(p.out_features(), 8);
        assert_eq!(p.rows(), 16);
        assert_eq!(p.fused.layer_count(), 3);
        assert!(p.plan_count() > 0, "wave plans precompiled");
        assert!(p.fused_bytes <= p.standalone_bytes);
        assert!(p.total_cycles > 0.0);
    }

    #[test]
    fn invalid_chain_does_not_compile() {
        let cfg = ArchConfig::paper(4, 4);
        let chain = Chain {
            layers: vec![Gemm::new("a", "t", 8, 8, 8), Gemm::new("b", "t", 8, 16, 8)],
        };
        assert!(Program::compile(&cfg, &chain, &fast()).is_none());
    }

    /// Whole-program functional execution through the precompiled plans is
    /// bit-identical to chained naive GEMMs, and compiles zero plans at
    /// execution time — across repeated executions on one simulator.
    #[test]
    fn executes_exactly_with_zero_runtime_plan_compiles() {
        let cfg = ArchConfig::paper(4, 4);
        let chain = Chain::mlp("mlp", 8, &[12, 16, 8, 12]);
        let p = Program::compile(&cfg, &chain, &fast()).unwrap();
        let weights = rand_weights(&chain, 3);
        let mut rng = Lcg::new(11);
        let mut sim = FunctionalSim::new(&cfg);
        for round in 0..3 {
            let input: Vec<i32> =
                (0..p.rows() * p.in_features()).map(|_| rng.range(0, 9) as i32 - 4).collect();
            let got = p.execute_i32(&mut sim, &input, &weights).unwrap();
            assert_eq!(got, p.reference_i32(&input, &weights), "round {round}");
        }
        assert_eq!(sim.plan_compiles, 0, "all plans came precompiled");
        assert_eq!(sim.plan_cache_len(), p.plan_count());
    }

    /// One compiled program executes a whole chain over a prime field —
    /// bit-exact against the chained naive mod-p reference, with zero
    /// runtime plan compiles (plans are element-independent, so the same
    /// compile-once artifact serves every backend).
    #[test]
    fn executes_field_chain_exactly_with_zero_plan_compiles() {
        use crate::arith::{BabyBear, ModP};
        type B = ModP<BabyBear>;
        let cfg = ArchConfig::paper(4, 4);
        let chain = Chain::mlp("mlp", 8, &[12, 16, 8]);
        let p = Program::compile(&cfg, &chain, &fast()).unwrap();
        let mut rng = Lcg::new(17);
        let weights: Vec<Vec<B>> = chain
            .layers
            .iter()
            .map(|g| (0..g.k * g.n).map(|_| B::new(rng.next_u64())).collect())
            .collect();
        let mut sim: FunctionalSim<B> = FunctionalSim::new(&cfg);
        for round in 0..2 {
            let input: Vec<B> =
                (0..p.rows() * p.in_features()).map(|_| B::new(rng.next_u64())).collect();
            let got = p.execute(&mut sim, &input, &weights).unwrap();
            assert_eq!(got, p.reference(&input, &weights), "round {round}");
        }
        assert_eq!(sim.plan_compiles, 0, "field execution reuses the precompiled plans");
        assert_eq!(sim.plan_cache_len(), p.plan_count());
    }

    /// Malformed inputs surface as `SimError::Invalid`, not a panic — the
    /// serving leader calls `execute` with request-supplied shapes.
    #[test]
    fn execute_rejects_bad_shapes_without_panicking() {
        let cfg = ArchConfig::paper(4, 4);
        let chain = Chain::mlp("mlp", 8, &[12, 8]);
        let p = Program::compile(&cfg, &chain, &fast()).unwrap();
        let weights = rand_weights(&chain, 5);
        let mut sim = FunctionalSim::new(&cfg);
        let r = p.execute_i32(&mut sim, &[1i32; 3], &weights);
        assert!(matches!(r, Err(SimError::Invalid(_))), "{r:?}");
        let input = vec![1i32; p.rows() * p.in_features()];
        let r = p.execute_i32(&mut sim, &input, &weights[..1]);
        assert!(matches!(r, Err(SimError::Invalid(_))), "{r:?}");
    }

    /// The chain-aware search alternates dataflows (§V-A compatibility) and
    /// the boundary alignment yields at least one elidable layout on a
    /// symmetric 3-layer MLP.
    #[test]
    fn alternation_and_elision_on_symmetric_mlp() {
        let cfg = ArchConfig::paper(4, 4);
        let chain = Chain::mlp("mlp", 32, &[32, 32, 32, 32]);
        let p = Program::compile(&cfg, &chain, &fast()).unwrap();
        let dfs: Vec<Dataflow> = p.layers.iter().map(|l| l.decision.choice.df).collect();
        assert!(
            dfs.windows(2).all(|w| w[0] != w[1]),
            "dataflows alternate across layers: {dfs:?}"
        );
        assert!(p.elided >= 1, "at least one boundary elides its SetIVNLayout");
    }

    /// Shard views are pure addressing: row ranges map to word ranges, the
    /// degenerate inputs (empty, inverted, past-the-end) never panic, and
    /// the shard borrows the program (same plan set, nothing recompiled).
    #[test]
    fn shard_rows_addressing() {
        let cfg = ArchConfig::paper(4, 4);
        let chain = Chain::mlp("mlp", 8, &[12, 16, 8]);
        let p = Program::compile(&cfg, &chain, &fast()).unwrap();
        let (kf, nf) = (p.in_features(), p.out_features());
        let s = p.shard_rows(2..5);
        assert_eq!(s.row_count(), 3);
        assert_eq!(s.input_words(), 2 * kf..5 * kf);
        assert_eq!(s.output_words(), 2 * nf..5 * nf);
        // Degenerate ranges normalize to empty.
        assert_eq!(p.shard_rows(4..4).row_count(), 0);
        assert_eq!(p.shard_rows(5..2).row_count(), 0);
        // Ranges past the compiled height are legal: shards index a larger
        // batched activation, not the compiled M.
        let tall = p.shard_rows(20..23);
        assert_eq!(tall.input_words(), 20 * kf..23 * kf);
        assert_eq!(tall.program.plan_count(), p.plan_count());
    }

    /// `from_artifact(to_artifact(p))` reproduces the program: identical
    /// fused stream, plan set, accounting — and executes bit-identically
    /// with zero runtime plan compiles, without any mapper run.
    #[test]
    fn artifact_roundtrip_reproduces_program() {
        let cfg = ArchConfig::paper(4, 4);
        let chain = Chain::mlp("mlp", 8, &[12, 16, 8]);
        let p = Program::compile(&cfg, &chain, &fast()).unwrap();
        let art = p.to_artifact(None).unwrap();
        let searches_before = crate::mapper::search::searches_run();
        let q = Program::from_artifact(&art).unwrap();
        assert_eq!(
            crate::mapper::search::searches_run(),
            searches_before,
            "loading must not run the mapper"
        );
        assert_eq!(q.fused.insts, p.fused.insts);
        assert_eq!(q.fused.layer_starts, p.fused.layer_starts);
        assert_eq!(q.plan_count(), p.plan_count());
        assert_eq!((q.elided, q.fused_bytes, q.standalone_bytes), (p.elided, p.fused_bytes, p.standalone_bytes));
        // Stall accounting is re-derived deterministically on load (it is
        // not stored in the artifact), so the twin programs agree exactly.
        assert_eq!(q.stall, p.stall);
        let weights = rand_weights(&chain, 7);
        let mut rng = Lcg::new(13);
        let input: Vec<i32> =
            (0..p.rows() * p.in_features()).map(|_| rng.range(0, 9) as i32 - 4).collect();
        let mut sim = FunctionalSim::new(&cfg);
        let got = q.execute_i32(&mut sim, &input, &weights).unwrap();
        assert_eq!(got, p.reference_i32(&input, &weights));
        assert_eq!(sim.plan_compiles, 0, "loaded program's plans came precompiled");
    }

    /// A tampered stream (or accounting) is rejected at load, not served.
    #[test]
    fn from_artifact_rejects_drifted_accounting() {
        let cfg = ArchConfig::paper(4, 4);
        let chain = Chain::mlp("mlp", 8, &[12, 8]);
        let p = Program::compile(&cfg, &chain, &fast()).unwrap();
        let mut art = p.to_artifact(None).unwrap();
        art.decision.fused_bytes += 1;
        assert!(matches!(Program::from_artifact(&art), Err(ArtifactError::Mismatch(_))));
    }

    /// Compiled programs carry the chain's modeled stall accounting: the
    /// MINISA totals equal the program's `total_cycles` (same per-layer
    /// reports), and the micro-instruction twin never costs less — it only
    /// adds instruction traffic to an otherwise identical mapping.
    #[test]
    fn program_stall_model_tracks_chain() {
        let cfg = ArchConfig::paper(4, 8);
        let chain = Chain::mlp("mlp", 16, &[24, 16, 24]);
        let p = Program::compile(&cfg, &chain, &fast()).unwrap();
        assert!(p.stall.is_populated());
        assert!((p.stall.minisa_total_cycles - p.total_cycles).abs() < 1e-6);
        assert!(p.stall.micro_total_cycles >= p.stall.minisa_total_cycles);
        assert!(p.stall.micro_fetch_stall_cycles >= p.stall.minisa_fetch_stall_cycles);
        assert!(p.stall.control_speedup() >= 1.0);
    }

    /// `total_cycles` stays the sum of the (possibly re-estimated) per-layer
    /// reports after boundary order alignment.
    #[test]
    fn total_cycles_consistent_with_layers() {
        let cfg = ArchConfig::paper(4, 8);
        let chain = Chain::mlp("mlp", 16, &[24, 16, 24]);
        let p = Program::compile(&cfg, &chain, &fast()).unwrap();
        let sum: f64 = p.layers.iter().map(|l| l.decision.report.total_cycles).sum();
        assert_eq!(p.total_cycles, sum);
    }
}
