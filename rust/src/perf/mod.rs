//! Cycle-level analytical performance model — the "5-engine asynchronous
//! execution simulator" of the paper's artifact (§VI-A Methodology).
//!
//! Execution is modeled as a pipeline of engines over a sequence of compute
//! tiles (NEST invocations):
//!
//! 1. **InstrFetch** — off-chip instruction interface (9 B/cycle), feeding
//!    either MINISA traces (tiny) or micro-instruction streams (huge).
//! 2. **LoadData** — off-chip input/weight transfers (AW B/cycle), shared
//!    port; components tracked separately as Load-In / Load-W.
//! 3. **Compute** — NEST streaming (T·vn cycles per invocation, scaled by
//!    the streaming-buffer row-block factor), stationary fill when exposed,
//!    pipeline drain.
//! 4. **OutStream** — OB → streaming/stationary move for layer chaining.
//! 5. **StoreOut** — off-chip output transfer (4·AW B/cycle).
//!
//! Each engine processes tiles in order; tile `t` on engine `e` starts at
//! `max(finish(e, t−1), finish(dep(e), t))`. Double buffering falls out of
//! the recurrence (engine e may work on tile t+1 while e+1 works on t).
//! Stall attribution on the Compute engine separates instruction-fetch
//! stalls (the paper's headline) from data stalls.

use crate::arch::config::ArchConfig;

/// Per-tile resource demands, produced by the mapper's lowering.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TilePlan {
    /// Instruction bits that must arrive before this tile issues.
    pub instr_bits: u64,
    /// Off-chip input words (elem-size) loaded for this tile.
    pub load_in_words: u64,
    /// Off-chip weight words loaded for this tile.
    pub load_w_words: u64,
    /// NEST streaming cycles (T · vn · row-block factor).
    pub compute_cycles: u64,
    /// Stationary-fill cycles (exposed only when not hidden by compute).
    pub fill_cycles: u64,
    /// Pipeline drain cycles (array depth + BIRRD stages).
    pub drain_cycles: u64,
    /// OB → operand-buffer words moved at tile commit.
    pub out_stream_words: u64,
    /// Output words (acc-size) stored off-chip at tile commit.
    pub store_out_words: u64,
    /// MACs that do useful work in this tile (utilization numerator).
    pub macs_used: u64,
}

/// Cycle breakdown + derived metrics for one simulated program.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PerfReport {
    pub total_cycles: f64,
    /// Busy cycles per engine.
    pub fetch_cycles: f64,
    pub load_in_cycles: f64,
    pub load_w_cycles: f64,
    pub compute_cycles: f64,
    pub out_stream_cycles: f64,
    pub store_out_cycles: f64,
    /// Compute-engine wait attributed to instruction fetch.
    pub stall_instr_cycles: f64,
    /// Compute-engine wait attributed to data loads.
    pub stall_data_cycles: f64,
    pub macs_used: u64,
    pub tiles: usize,
    /// Peak-MACs denominator per cycle.
    pub peak_macs_per_cycle: u64,
}

impl PerfReport {
    /// Fraction of end-to-end time the compute engine waits on instruction
    /// fetch (Table I / Fig. 10 "stall").
    pub fn instr_stall_fraction(&self) -> f64 {
        if self.total_cycles == 0.0 {
            return 0.0;
        }
        self.stall_instr_cycles / self.total_cycles
    }

    /// Average compute utilization (§VI-A Metrics).
    pub fn utilization(&self) -> f64 {
        if self.total_cycles == 0.0 {
            return 0.0;
        }
        self.macs_used as f64 / (self.total_cycles * self.peak_macs_per_cycle as f64)
    }

    pub fn latency_us(&self, cfg: &ArchConfig) -> f64 {
        cfg.cycles_to_us(self.total_cycles)
    }
}

/// Simulate a tile schedule through the engine pipeline.
pub fn simulate(cfg: &ArchConfig, tiles: &[TilePlan]) -> PerfReport {
    let mut rep = PerfReport {
        peak_macs_per_cycle: cfg.peak_macs_per_cycle() as u64,
        tiles: tiles.len(),
        ..Default::default()
    };
    let instr_bps = cfg.instr_bw * 8.0; // bits/cycle
    let data_in_bps = cfg.data_bw_in; // bytes/cycle (elem_bytes applied below)
    let data_out_bps = cfg.data_bw_out;
    let internal_wpc = cfg.aw as f64; // on-chip OB drain words/cycle

    let mut fetch_fin = 0.0f64;
    let mut load_fin = 0.0f64;
    // Shadow load pipeline without instruction gating, used only to
    // attribute compute stalls to fetch vs data.
    let mut load_fin_nf = 0.0f64;
    let mut comp_fin = 0.0f64;
    let mut outs_fin = 0.0f64;
    let mut store_fin = 0.0f64;

    for t in tiles {
        // Engine busy durations.
        let fetch_dur = t.instr_bits as f64 / instr_bps;
        let load_in_dur = t.load_in_words as f64 * cfg.elem_bytes as f64 / data_in_bps;
        let load_w_dur = t.load_w_words as f64 * cfg.elem_bytes as f64 / data_in_bps;
        let load_dur = load_in_dur + load_w_dur; // shared off-chip port
        let comp_dur = (t.compute_cycles + t.fill_cycles + t.drain_cycles) as f64;
        let outs_dur = t.out_stream_words as f64 / internal_wpc;
        let store_dur = t.store_out_words as f64 * cfg.acc_bytes as f64 / data_out_bps;

        // Fetch is sequential (one instruction port).
        let fetch_start = fetch_fin;
        fetch_fin = fetch_start + fetch_dur;
        // Loads for tile t need its instructions.
        let load_start = load_fin.max(fetch_fin);
        load_fin = load_start + load_dur;
        load_fin_nf = load_fin_nf.max(0.0) + load_dur;
        // Compute needs instructions + operands (+ previous tile done).
        let ready_data = load_fin;
        let ready_instr = fetch_fin;
        let comp_start = comp_fin.max(ready_data).max(ready_instr);
        // Stall attribution: the wait beyond what a fetch-free machine
        // would see is charged to instruction fetch; genuine data waits
        // (shadow pipeline) are charged to loads.
        let base = comp_fin;
        let start_without_fetch = base.max(load_fin_nf);
        if comp_start > start_without_fetch {
            rep.stall_instr_cycles += comp_start - start_without_fetch;
        }
        if load_fin_nf > base {
            rep.stall_data_cycles += load_fin_nf - base;
        }
        comp_fin = comp_start + comp_dur;
        // Output path.
        let outs_start = outs_fin.max(comp_fin);
        outs_fin = outs_start + outs_dur;
        let store_start = store_fin.max(outs_fin);
        store_fin = store_start + store_dur;

        rep.fetch_cycles += fetch_dur;
        rep.load_in_cycles += load_in_dur;
        rep.load_w_cycles += load_w_dur;
        rep.compute_cycles += comp_dur;
        rep.out_stream_cycles += outs_dur;
        rep.store_out_cycles += store_dur;
        rep.macs_used += t.macs_used;
    }
    rep.total_cycles = store_fin.max(comp_fin).max(fetch_fin);
    rep
}

/// Convenience: re-cost a MINISA schedule as its micro-instruction twin —
/// identical mapping (same compute/data engines), but per-tile instruction
/// bits replaced by the fine-grained control stream.
pub fn with_micro_instructions(
    cfg: &ArchConfig,
    tiles: &[TilePlan],
    vn_size: usize,
) -> Vec<TilePlan> {
    let c = crate::microinst::cost(cfg, vn_size);
    tiles
        .iter()
        .map(|t| {
            let waves = t.compute_cycles / vn_size.max(1) as u64;
            TilePlan {
                instr_bits: waves * c.bits_per_wave + c.bits_per_invocation,
                ..*t
            }
        })
        .collect()
}

/// Modeled compute vs instruction-fetch cycle accounting for work executed
/// under both control regimes: the MINISA encoding actually served and its
/// micro-instruction twin ([`with_micro_instructions`]). This is the unit
/// the live stall accounting threads through the fleet — a `Program`
/// carries one for its whole chain, each `Device` accumulates the share of
/// it that its shards executed, and [`FleetReport`] rolls the fleet total
/// back up into the paper's Table I stall breakdown (§Observability
/// tentpole).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StallModel {
    /// End-to-end modeled cycles under MINISA control.
    pub minisa_total_cycles: f64,
    /// Compute-engine busy cycles under MINISA control.
    pub minisa_compute_cycles: f64,
    /// Compute stall cycles attributed to instruction fetch under MINISA.
    pub minisa_fetch_stall_cycles: f64,
    /// End-to-end modeled cycles for the micro-instruction twin.
    pub micro_total_cycles: f64,
    /// Compute-engine busy cycles for the micro twin.
    pub micro_compute_cycles: f64,
    /// Fetch-stall cycles for the micro twin (the paper's 96.9% on 16×256).
    pub micro_fetch_stall_cycles: f64,
    /// Fetch-engine busy cycles under MINISA control — the off-chip
    /// instruction traffic actually moved, i.e. this work's demand on a
    /// shared fetch channel ([`SharedFetch`]).
    pub minisa_fetch_cycles: f64,
    /// Fetch-engine busy cycles for the micro twin.
    pub micro_fetch_cycles: f64,
}

impl StallModel {
    /// Build from a MINISA report and its micro-twin report.
    pub fn from_reports(minisa: &PerfReport, micro: &PerfReport) -> Self {
        StallModel {
            minisa_total_cycles: minisa.total_cycles,
            minisa_compute_cycles: minisa.compute_cycles,
            minisa_fetch_stall_cycles: minisa.stall_instr_cycles,
            micro_total_cycles: micro.total_cycles,
            micro_compute_cycles: micro.compute_cycles,
            micro_fetch_stall_cycles: micro.stall_instr_cycles,
            minisa_fetch_cycles: minisa.fetch_cycles,
            micro_fetch_cycles: micro.fetch_cycles,
        }
    }

    /// Fetch-stall fraction under MINISA control (≈ 0 when the ISA works).
    pub fn minisa_stall_fraction(&self) -> f64 {
        if self.minisa_total_cycles == 0.0 {
            return 0.0;
        }
        self.minisa_fetch_stall_cycles / self.minisa_total_cycles
    }

    /// Fetch-stall fraction of the micro-instruction baseline (the paper's
    /// 96.9% headline on 16×256).
    pub fn micro_stall_fraction(&self) -> f64 {
        if self.micro_total_cycles == 0.0 {
            return 0.0;
        }
        self.micro_fetch_stall_cycles / self.micro_total_cycles
    }

    /// Modeled end-to-end speedup of MINISA over the micro baseline
    /// (control-overhead elimination). 0 when nothing was accumulated.
    pub fn control_speedup(&self) -> f64 {
        if self.minisa_total_cycles == 0.0 {
            return 0.0;
        }
        self.micro_total_cycles / self.minisa_total_cycles
    }

    /// True once any work has been accumulated.
    pub fn is_populated(&self) -> bool {
        self.minisa_total_cycles > 0.0 || self.micro_total_cycles > 0.0
    }

    /// Accumulate `frac` of `other` (a shard that executed `frac` of a
    /// program's rows charges that share of the program's modeled cycles).
    pub fn absorb_scaled(&mut self, other: &StallModel, frac: f64) {
        self.minisa_total_cycles += other.minisa_total_cycles * frac;
        self.minisa_compute_cycles += other.minisa_compute_cycles * frac;
        self.minisa_fetch_stall_cycles += other.minisa_fetch_stall_cycles * frac;
        self.micro_total_cycles += other.micro_total_cycles * frac;
        self.micro_compute_cycles += other.micro_compute_cycles * frac;
        self.micro_fetch_stall_cycles += other.micro_fetch_stall_cycles * frac;
        self.minisa_fetch_cycles += other.minisa_fetch_cycles * frac;
        self.micro_fetch_cycles += other.micro_fetch_cycles * frac;
    }
}

/// Shared off-chip instruction-fetch channel model (§ROADMAP item 3, the
/// cost-aware scheduling tentpole): devices in the same arch group fetch
/// their control streams over one common off-chip channel, so the channel's
/// service time is the **sum** of the group's fetch demand while compute
/// proceeds in parallel per device. A group's makespan under a control
/// regime is therefore `max(slowest device's standalone cycles, Σ group
/// fetch cycles)`; the fleet makespan is the max over groups (each group
/// owns its own channel). Under micro-instruction control the summed fetch
/// traffic saturates the channel and the fleet makespan collapses onto it —
/// the paper's per-device fetch-stall headline (96.9% on 16×256) re-emerges
/// as fleet-scale contention — while MINISA's tiny traces leave the channel
/// idle and the fleet scales with compute.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SharedFetch {
    /// Fleet makespan under MINISA control with the shared channel applied.
    pub minisa_makespan: f64,
    /// Fleet makespan for the micro twin with the shared channel applied.
    pub micro_makespan: f64,
    /// `makespan / standalone makespan` under MINISA (1.0 = the channel
    /// never binds; MINISA stays ≈ 1 even on wide fleets).
    pub minisa_contention: f64,
    /// `makespan / standalone makespan` for the micro twin — grows with the
    /// number of devices sharing the channel once fetch dominates.
    pub micro_contention: f64,
}

impl SharedFetch {
    /// Fleet-wide modeled speedup of the MINISA encoding over micro-coded
    /// control with the shared fetch channel applied. At paper scale this
    /// exceeds the per-device [`StallModel::control_speedup`] because micro
    /// contends for the channel and MINISA does not. 0 when nothing was
    /// accumulated.
    pub fn control_speedup(&self) -> f64 {
        if self.minisa_makespan == 0.0 {
            return 0.0;
        }
        self.micro_makespan / self.minisa_makespan
    }

    /// True once any modeled work flowed through the channel.
    pub fn is_populated(&self) -> bool {
        self.minisa_makespan > 0.0 || self.micro_makespan > 0.0
    }
}

/// One device's share of a fleet observation window — the per-device row of
/// [`FleetReport`]. Times are in the window's unit: wall-clock µs on the
/// serving path (where devices are simulated and the window is real time),
/// modeled cycles when a cycle-level window is rolled up.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DeviceLoad {
    pub device: usize,
    /// Time this device spent executing dispatches/shards.
    pub busy: f64,
    /// Window remainder: time the device sat idle (or, after a dropout,
    /// dark). `window − busy`, floored at zero.
    pub stall: f64,
    /// Batches this device's worker executed.
    pub dispatches: u64,
    /// Tile-parallel row shards executed (incl. the trivial 1-shard case).
    pub shards: u64,
    /// Activation rows executed across all shards.
    pub rows: u64,
    /// Jobs taken from another device's queue.
    pub steals: u64,
    /// Shards/jobs re-executed here after their assigned device dropped.
    pub requeues: u64,
    /// Queue time of stolen jobs (submit → steal): how long work waited
    /// before a neighbour rescued it.
    pub steal_wait_us: f64,
    /// Shard executions beyond the first attempt (watchdog requeues).
    pub retries: u64,
    /// Shards that ran past the watchdog budget on this device.
    pub watchdog_trips: u64,
    /// Health-probe re-admissions after a transient failure.
    pub recoveries: u64,
    /// Wave plans compiled at runtime by this device's simulators — stays 0
    /// when every executed program was compiled ahead of time.
    pub plan_compiles: u64,
    /// NEST waves actually issued by this device's functional simulators.
    pub waves: u64,
    /// Modeled compute vs fetch-stall cycles for the shards this device
    /// executed, under MINISA and the micro baseline (live stall
    /// accounting; zero when the executed work carried no perf decision,
    /// e.g. raw GEMM dispatch).
    pub modeled: StallModel,
    /// Arch-fingerprint group this device belongs to (placement eligibility
    /// and the shared fetch channel share the grouping); 0 for a bare
    /// report built outside the fleet.
    pub group: u64,
    /// Human-readable arch name ("4x4"); empty for a bare report.
    pub arch: String,
    /// Cycles the cost-aware scheduler (`coordinator::sched`) predicted for
    /// the work this device executed; 0 when cost-aware dispatch was not
    /// engaged (bare fleet, raw GEMM).
    pub predicted_cycles: f64,
    /// Device has dropped out (failure injection).
    pub failed: bool,
}

impl DeviceLoad {
    /// Relative error of the scheduler's cycle prediction against the
    /// modeled cycles this device actually executed (wave-scaled MINISA
    /// model — the "simulated" side of predicted-vs-simulated). The two
    /// sides differ honestly on partial chunks: prediction charges whole
    /// chain passes (`ceil(rows / m)`), accounting charges the executed row
    /// fraction. 0 until both sides have accumulated work.
    pub fn predict_err(&self) -> f64 {
        let modeled = self.modeled.minisa_total_cycles;
        if modeled <= 0.0 || self.predicted_cycles <= 0.0 {
            return 0.0;
        }
        (self.predicted_cycles - modeled).abs() / modeled
    }
}

/// Fleet-level roll-up over one observation window: per-device busy/stall
/// plus the shard-imbalance and utilization metrics the serving CLI reports.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FleetReport {
    /// Observation window length (same unit as the per-device times).
    pub window: f64,
    /// Requests shed by admission control over the window (filled by the
    /// serving layer; 0 for a bare fleet).
    pub shed: u64,
    /// Requests answered `deadline_exceeded` over the window (serving
    /// layer; 0 for a bare fleet).
    pub expired: u64,
    pub devices: Vec<DeviceLoad>,
}

impl FleetReport {
    /// Total busy time summed over devices.
    pub fn busy_total(&self) -> f64 {
        self.devices.iter().map(|d| d.busy).sum()
    }

    /// Runtime wave-plan compiles summed over devices (0 on the
    /// compile-once path).
    pub fn plan_compiles(&self) -> u64 {
        self.devices.iter().map(|d| d.plan_compiles).sum()
    }

    /// Shard retries summed over devices (watchdog requeues).
    pub fn retries(&self) -> u64 {
        self.devices.iter().map(|d| d.retries).sum()
    }

    /// Watchdog trips summed over devices.
    pub fn watchdog_trips(&self) -> u64 {
        self.devices.iter().map(|d| d.watchdog_trips).sum()
    }

    /// Health-probe recoveries summed over devices.
    pub fn recoveries(&self) -> u64 {
        self.devices.iter().map(|d| d.recoveries).sum()
    }

    /// Fleet-total modeled stall accounting: every device's accumulated
    /// [`StallModel`] summed. `micro_stall_fraction()` of this roll-up is
    /// the paper's Table I stall number measured at fleet scale.
    pub fn modeled(&self) -> StallModel {
        let mut m = StallModel::default();
        for d in &self.devices {
            m.absorb_scaled(&d.modeled, 1.0);
        }
        m
    }

    /// Shared instruction-fetch channel roll-up: per arch group, the
    /// makespan is `max(slowest device standalone, Σ group fetch demand)`;
    /// the fleet makespan is the max over groups. See [`SharedFetch`].
    pub fn shared_fetch(&self) -> SharedFetch {
        let mut groups: Vec<u64> = Vec::new();
        for d in &self.devices {
            if d.modeled.is_populated() && !groups.contains(&d.group) {
                groups.push(d.group);
            }
        }
        let mut sf = SharedFetch::default();
        let mut minisa_standalone = 0.0f64;
        let mut micro_standalone = 0.0f64;
        for g in groups {
            let mut minisa_max = 0.0f64;
            let mut minisa_fetch = 0.0f64;
            let mut micro_max = 0.0f64;
            let mut micro_fetch = 0.0f64;
            for d in self.devices.iter().filter(|d| d.group == g) {
                if !d.modeled.is_populated() {
                    continue;
                }
                minisa_max = minisa_max.max(d.modeled.minisa_total_cycles);
                micro_max = micro_max.max(d.modeled.micro_total_cycles);
                minisa_fetch += d.modeled.minisa_fetch_cycles;
                micro_fetch += d.modeled.micro_fetch_cycles;
            }
            sf.minisa_makespan = sf.minisa_makespan.max(minisa_max.max(minisa_fetch));
            sf.micro_makespan = sf.micro_makespan.max(micro_max.max(micro_fetch));
            minisa_standalone = minisa_standalone.max(minisa_max);
            micro_standalone = micro_standalone.max(micro_max);
        }
        sf.minisa_contention =
            if minisa_standalone > 0.0 { sf.minisa_makespan / minisa_standalone } else { 0.0 };
        sf.micro_contention =
            if micro_standalone > 0.0 { sf.micro_makespan / micro_standalone } else { 0.0 };
        sf
    }

    /// Mean queue time of stolen jobs (µs): the steal-latency headline.
    /// 0 when nothing was stolen.
    pub fn steal_wait_mean_us(&self) -> f64 {
        let steals: u64 = self.devices.iter().map(|d| d.steals).sum();
        if steals == 0 {
            return 0.0;
        }
        self.devices.iter().map(|d| d.steal_wait_us).sum::<f64>() / steals as f64
    }

    /// Fraction of the fleet's aggregate capacity (window × devices) spent
    /// busy. Dropped devices still count in the denominator: a dark device
    /// is lost capacity, not a smaller fleet.
    pub fn utilization(&self) -> f64 {
        let n = self.devices.len();
        if n == 0 || self.window <= 0.0 {
            return 0.0;
        }
        (self.busy_total() / (self.window * n as f64)).min(1.0)
    }

    /// Shard-imbalance metric over *surviving* devices: `(max − mean) / max`
    /// busy time, in `[0, 1)`. 0 means perfectly even load; values near 1
    /// mean one device did essentially all the work (sharding or stealing is
    /// not spreading load).
    pub fn imbalance(&self) -> f64 {
        let busy: Vec<f64> =
            self.devices.iter().filter(|d| !d.failed).map(|d| d.busy).collect();
        if busy.is_empty() {
            return 0.0;
        }
        let max = busy.iter().cloned().fold(0.0f64, f64::max);
        if max <= 0.0 {
            return 0.0;
        }
        let mean = busy.iter().sum::<f64>() / busy.len() as f64;
        (max - mean) / max
    }

    /// Human-readable per-device table + headline metrics (CLI output).
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(
            "fleet: device    busy      stall  dispatches  shards    rows  steals  requeues  retries  wdog  recov\n",
        );
        for d in &self.devices {
            s.push_str(&format!(
                "fleet: dev{:<3}{} {:>9.1} {:>9.1} {:>11} {:>7} {:>7} {:>7} {:>9} {:>8} {:>5} {:>6}\n",
                d.device,
                if d.failed { "✗" } else { " " },
                d.busy,
                d.stall,
                d.dispatches,
                d.shards,
                d.rows,
                d.steals,
                d.requeues,
                d.retries,
                d.watchdog_trips,
                d.recoveries,
            ));
        }
        s.push_str(&format!(
            "fleet: utilization {:.1}%, shard imbalance {:.2}, {} runtime plan compile(s)\n",
            self.utilization() * 100.0,
            self.imbalance(),
            self.plan_compiles(),
        ));
        s.push_str(&format!(
            "fleet: shed {}, expired {}, retries {}, mean steal wait {:.1} µs",
            self.shed,
            self.expired,
            self.retries(),
            self.steal_wait_mean_us(),
        ));
        if self.devices.iter().any(|d| d.modeled.is_populated()) {
            s.push_str(
                "\nstall: device   minisa-compute  minisa-fetch-stall   micro-compute   micro-fetch-stall  micro-stall%  ctrl-speedup\n",
            );
            for d in &self.devices {
                let m = &d.modeled;
                s.push_str(&format!(
                    "stall: dev{:<4} {:>15.0} {:>19.0} {:>15.0} {:>19.0} {:>12.1} {:>13.1}\n",
                    d.device,
                    m.minisa_compute_cycles,
                    m.minisa_fetch_stall_cycles,
                    m.micro_compute_cycles,
                    m.micro_fetch_stall_cycles,
                    m.micro_stall_fraction() * 100.0,
                    m.control_speedup(),
                ));
            }
            let m = self.modeled();
            s.push_str(&format!(
                "stall: fleet micro-baseline fetch-stall {:.1}% of cycles (MINISA {:.1}%), control speedup {:.1}x",
                m.micro_stall_fraction() * 100.0,
                m.minisa_stall_fraction() * 100.0,
                m.control_speedup(),
            ));
            let sf = self.shared_fetch();
            if sf.is_populated() {
                s.push_str(&format!(
                    "\nfetch: shared channel contention {:.2}x micro vs {:.2}x minisa, fleet control speedup {:.1}x",
                    sf.micro_contention,
                    sf.minisa_contention,
                    sf.control_speedup(),
                ));
            }
        }
        if self.devices.iter().any(|d| d.predicted_cycles > 0.0) {
            s.push_str(
                "\nsched: device  arch     predicted-cycles    modeled-cycles  predict-err%\n",
            );
            for d in self.devices.iter().filter(|d| d.predicted_cycles > 0.0) {
                s.push_str(&format!(
                    "sched: dev{:<4} {:<8} {:>15.0} {:>17.0} {:>13.1}\n",
                    d.device,
                    d.arch,
                    d.predicted_cycles,
                    d.modeled.minisa_total_cycles,
                    d.predict_err() * 100.0,
                ));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tile(instr_bits: u64, compute: u64) -> TilePlan {
        TilePlan {
            instr_bits,
            compute_cycles: compute,
            drain_cycles: 4,
            macs_used: compute * 16,
            ..Default::default()
        }
    }

    #[test]
    fn compute_bound_program_has_no_stall() {
        let cfg = ArchConfig::paper(4, 4);
        let tiles: Vec<TilePlan> = (0..10).map(|_| tile(100, 1000)).collect();
        let rep = simulate(&cfg, &tiles);
        // 100 bits @72 bits/cycle ≈ 1.4 cycles ≪ 1004-cycle tiles.
        assert!(rep.instr_stall_fraction() < 0.01, "{}", rep.instr_stall_fraction());
        assert!(rep.total_cycles >= 10.0 * 1004.0);
    }

    #[test]
    fn fetch_bound_program_stalls() {
        let cfg = ArchConfig::paper(4, 4);
        // 72 kbit per tile @ 72 bits/cycle = 1000 fetch cycles vs 104
        // compute cycles → heavily instruction-bound.
        let tiles: Vec<TilePlan> = (0..10).map(|_| tile(72_000, 100)).collect();
        let rep = simulate(&cfg, &tiles);
        assert!(rep.instr_stall_fraction() > 0.8, "{}", rep.instr_stall_fraction());
    }

    #[test]
    fn pipeline_overlaps_load_and_compute() {
        let cfg = ArchConfig::paper(4, 4);
        let t = TilePlan {
            instr_bits: 0,
            load_in_words: 4000, // 1000 cycles at 4 B/c
            compute_cycles: 1000,
            ..Default::default()
        };
        let tiles = vec![t; 4];
        let rep = simulate(&cfg, &tiles);
        // Perfect double buffering: total ≈ load(1st tile) + 4×1000, not
        // 4×2000.
        assert!(rep.total_cycles < 4.0 * 2000.0 * 0.8, "{}", rep.total_cycles);
        assert!(rep.total_cycles >= 4998.0);
    }

    #[test]
    fn store_tail_extends_makespan() {
        let cfg = ArchConfig::paper(4, 4);
        let t = TilePlan {
            compute_cycles: 10,
            store_out_words: 16_000, // 16000*4B / 16 B/c = 4000 cycles
            ..Default::default()
        };
        let rep = simulate(&cfg, &[t]);
        assert!(rep.total_cycles >= 4000.0);
    }

    #[test]
    fn micro_twin_inflates_instruction_bits() {
        let cfg = ArchConfig::paper(16, 256);
        let tiles = vec![TilePlan { compute_cycles: 1600, ..Default::default() }];
        let micro = with_micro_instructions(&cfg, &tiles, 16);
        assert!(micro[0].instr_bits > 100 * 1600); // ≫ any MINISA trace
        // Same compute work.
        assert_eq!(micro[0].compute_cycles, tiles[0].compute_cycles);
    }

    #[test]
    fn table1_shape_through_pipeline() {
        // End-to-end: micro-instruction twin of a long streaming program
        // reproduces the Table I stall ordering.
        let mut stalls = Vec::new();
        for (ah, aw) in [(4usize, 4usize), (8, 8), (16, 16), (16, 256)] {
            let cfg = ArchConfig::paper(ah, aw);
            // Enough tiles that the first tile's cold-start fetch (not a
            // steady-state stall) is amortized away.
            let tiles = vec![
                TilePlan { compute_cycles: (ah * 1024) as u64, ..Default::default() };
                64
            ];
            let micro = with_micro_instructions(&cfg, &tiles, ah);
            let rep = simulate(&cfg, &micro);
            stalls.push(rep.instr_stall_fraction());
        }
        assert!(stalls[0] < 0.05, "4x4 {}", stalls[0]);
        assert!(stalls[1] < 0.30, "8x8 {}", stalls[1]);
        assert!(stalls[3] > 0.90, "16x256 {}", stalls[3]);
        assert!(stalls[2] < stalls[3]);
    }

    #[test]
    fn utilization_bounded() {
        let cfg = ArchConfig::paper(4, 4);
        let t = TilePlan {
            compute_cycles: 100,
            macs_used: 100 * 16, // peak
            ..Default::default()
        };
        let rep = simulate(&cfg, &[t]);
        assert!(rep.utilization() <= 1.0 && rep.utilization() > 0.9);
    }

    #[test]
    fn empty_schedule() {
        let cfg = ArchConfig::paper(4, 4);
        let rep = simulate(&cfg, &[]);
        assert_eq!(rep.total_cycles, 0.0);
        assert_eq!(rep.utilization(), 0.0);
        assert_eq!(rep.instr_stall_fraction(), 0.0);
    }

    #[test]
    fn stall_model_reproduces_paper_breakdown_on_paper_config() {
        // Satellite: on the paper-sized 16×256 array, the micro-control
        // baseline's modeled fetch-stall fraction exceeds 0.9 while the
        // MINISA encoding's stays negligible — the Table I headline as a
        // StallModel, the unit the fleet accounting accumulates.
        let cfg = ArchConfig::paper(16, 256);
        let tiles =
            vec![TilePlan { compute_cycles: 16 * 1024, ..Default::default() }; 64];
        let minisa = simulate(&cfg, &tiles);
        let micro = simulate(&cfg, &with_micro_instructions(&cfg, &tiles, 16));
        let m = StallModel::from_reports(&minisa, &micro);
        assert!(m.micro_stall_fraction() > 0.9, "{}", m.micro_stall_fraction());
        assert!(m.minisa_stall_fraction() < 0.05, "{}", m.minisa_stall_fraction());
        assert!(m.control_speedup() > 2.0, "{}", m.control_speedup());
        assert!(m.is_populated());
    }

    #[test]
    fn stall_model_scaled_absorption_is_linear() {
        let unit = StallModel {
            minisa_total_cycles: 100.0,
            minisa_compute_cycles: 90.0,
            minisa_fetch_stall_cycles: 5.0,
            micro_total_cycles: 1000.0,
            micro_compute_cycles: 90.0,
            micro_fetch_stall_cycles: 900.0,
            minisa_fetch_cycles: 2.0,
            micro_fetch_cycles: 950.0,
        };
        // Shards covering halves of a program sum back to the whole.
        let mut acc = StallModel::default();
        assert!(!acc.is_populated());
        acc.absorb_scaled(&unit, 0.5);
        acc.absorb_scaled(&unit, 0.5);
        assert!((acc.minisa_total_cycles - 100.0).abs() < 1e-9);
        assert!((acc.micro_fetch_stall_cycles - 900.0).abs() < 1e-9);
        assert!((acc.minisa_fetch_cycles - 2.0).abs() < 1e-9);
        assert!((acc.micro_fetch_cycles - 950.0).abs() < 1e-9);
        assert!((acc.micro_stall_fraction() - 0.9).abs() < 1e-9);
        assert!((acc.control_speedup() - 10.0).abs() < 1e-9);
        // Empty model divides safely.
        let empty = StallModel::default();
        assert_eq!(empty.micro_stall_fraction(), 0.0);
        assert_eq!(empty.control_speedup(), 0.0);
    }

    #[test]
    fn fleet_report_rolls_up_and_renders_stall_columns() {
        let unit = StallModel {
            minisa_total_cycles: 100.0,
            minisa_compute_cycles: 90.0,
            minisa_fetch_stall_cycles: 2.0,
            micro_total_cycles: 2000.0,
            micro_compute_cycles: 90.0,
            micro_fetch_stall_cycles: 1900.0,
            minisa_fetch_cycles: 3.0,
            micro_fetch_cycles: 1950.0,
        };
        let mut d0 = load(0, 10.0, false);
        d0.modeled = unit;
        let mut d1 = load(1, 10.0, false);
        d1.modeled = unit;
        let rep =
            FleetReport { window: 100.0, devices: vec![d0, d1], ..Default::default() };
        let m = rep.modeled();
        assert!((m.minisa_total_cycles - 200.0).abs() < 1e-9);
        assert!((m.micro_stall_fraction() - 0.95).abs() < 1e-9);
        let r = rep.render();
        assert!(r.contains("micro-fetch-stall"), "{r}");
        assert!(r.contains("stall: dev0"), "{r}");
        assert!(r.contains("fetch-stall 95.0% of cycles"), "{r}");
        assert!(r.contains("control speedup 20.0x"), "{r}");
        // No modeled work → no stall table (bare-fleet render unchanged).
        let bare = FleetReport {
            window: 100.0,
            devices: vec![load(0, 1.0, false)],
            ..Default::default()
        };
        assert!(!bare.render().contains("micro-fetch-stall"));
    }

    fn load(device: usize, busy: f64, failed: bool) -> DeviceLoad {
        DeviceLoad { device, busy, failed, ..Default::default() }
    }

    #[test]
    fn shared_fetch_channel_collapses_micro_but_not_minisa() {
        // Fleet of 4 paper(16,256) devices, each having executed the same
        // modeled workload. Micro control is fetch-bound per device, so the
        // shared channel serializes ~4× the traffic; MINISA's traces leave
        // the channel idle. The fleet-scale control speedup must exceed the
        // per-device one — ROADMAP item 3 measured, not asserted by fiat.
        let cfg = ArchConfig::paper(16, 256);
        let tiles =
            vec![TilePlan { compute_cycles: 16 * 1024, ..Default::default() }; 64];
        let minisa = simulate(&cfg, &tiles);
        let micro = simulate(&cfg, &with_micro_instructions(&cfg, &tiles, 16));
        let unit = StallModel::from_reports(&minisa, &micro);
        let fp = 0xfeed_f00du64;
        let devices: Vec<DeviceLoad> = (0..4)
            .map(|i| DeviceLoad {
                device: i,
                busy: 10.0,
                modeled: unit,
                group: fp,
                arch: "16x256".into(),
                ..Default::default()
            })
            .collect();
        let rep = FleetReport { window: 100.0, devices, ..Default::default() };
        let sf = rep.shared_fetch();
        assert!(sf.is_populated());
        // Micro saturates the shared channel: contention grows toward the
        // device count. MINISA stays channel-unbound.
        assert!(sf.micro_contention > 2.0, "{}", sf.micro_contention);
        assert!(sf.minisa_contention < 1.1, "{}", sf.minisa_contention);
        assert!(
            sf.control_speedup() > unit.control_speedup(),
            "fleet {} vs device {}",
            sf.control_speedup(),
            unit.control_speedup()
        );
        let r = rep.render();
        assert!(r.contains("shared channel contention"), "{r}");
        // Empty report: everything divides safely.
        let empty = FleetReport::default().shared_fetch();
        assert!(!empty.is_populated());
        assert_eq!(empty.control_speedup(), 0.0);
    }

    #[test]
    fn predict_err_and_sched_render() {
        let mut d = load(0, 10.0, false);
        d.arch = "4x4".into();
        d.predicted_cycles = 110.0;
        d.modeled.minisa_total_cycles = 100.0;
        assert!((d.predict_err() - 0.1).abs() < 1e-9);
        // One-sided accumulation reads as zero error, not a divide blowup.
        assert_eq!(load(1, 0.0, false).predict_err(), 0.0);
        let rep = FleetReport {
            window: 100.0,
            devices: vec![d, load(1, 5.0, false)],
            ..Default::default()
        };
        let r = rep.render();
        assert!(r.contains("predict-err%"), "{r}");
        assert!(r.contains("sched: dev0"), "{r}");
        // Devices that never saw cost-aware dispatch don't get a row.
        assert!(!r.contains("sched: dev1"), "{r}");
        // A bare report renders no sched table at all.
        let bare = FleetReport {
            window: 100.0,
            devices: vec![load(0, 1.0, false)],
            ..Default::default()
        };
        assert!(!bare.render().contains("predict-err%"));
    }

    #[test]
    fn fleet_report_metrics() {
        let rep = FleetReport {
            window: 100.0,
            devices: vec![load(0, 80.0, false), load(1, 40.0, false)],
            ..Default::default()
        };
        // 120 busy over 200 capacity.
        assert!((rep.utilization() - 0.6).abs() < 1e-12);
        // max 80, mean 60 → (80-60)/80 = 0.25.
        assert!((rep.imbalance() - 0.25).abs() < 1e-12);
        assert_eq!(rep.plan_compiles(), 0);
        assert!(rep.render().contains("dev0"));
        assert!(rep.render().contains("shed 0, expired 0"));
    }

    #[test]
    fn fleet_report_robustness_columns() {
        let mut d0 = load(0, 10.0, false);
        d0.steals = 2;
        d0.steal_wait_us = 300.0;
        d0.retries = 1;
        d0.watchdog_trips = 1;
        d0.recoveries = 1;
        let rep = FleetReport { window: 100.0, shed: 3, expired: 2, devices: vec![d0] };
        assert_eq!(rep.retries(), 1);
        assert_eq!(rep.watchdog_trips(), 1);
        assert_eq!(rep.recoveries(), 1);
        assert!((rep.steal_wait_mean_us() - 150.0).abs() < 1e-9);
        let r = rep.render();
        assert!(r.contains("retries"), "{r}");
        assert!(r.contains("shed 3, expired 2"), "{r}");
        // No steals → mean wait well-defined at 0.
        assert_eq!(FleetReport::default().steal_wait_mean_us(), 0.0);
    }

    #[test]
    fn fleet_report_ignores_failed_devices_in_imbalance_only() {
        let rep = FleetReport {
            window: 100.0,
            devices: vec![load(0, 50.0, false), load(1, 0.0, true)],
            ..Default::default()
        };
        // Survivor alone → perfectly balanced among survivors…
        assert_eq!(rep.imbalance(), 0.0);
        // …but the dark device still counts as lost capacity.
        assert!((rep.utilization() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn fleet_report_empty_and_idle_edge_cases() {
        assert_eq!(FleetReport::default().utilization(), 0.0);
        assert_eq!(FleetReport::default().imbalance(), 0.0);
        let idle = FleetReport {
            window: 10.0,
            devices: vec![load(0, 0.0, false)],
            ..Default::default()
        };
        assert_eq!(idle.utilization(), 0.0);
        assert_eq!(idle.imbalance(), 0.0);
    }
}
