//! Pluggable element arithmetic — the representation axis of the execution
//! stack.
//!
//! The paper's evaluation spans GPT-oss inference, FHE basis conversion and
//! FHE/ZKP NTTs (§VI, Table IV), but those domains do not share a number
//! system: LLM layers quantize to saturating integers, serving oracles use
//! f32, and NTTs are only correct over prime fields. Related
//! representation-adaptive ISA work (PAPERS.md) treats the arithmetic
//! representation as a reconfiguration axis of its own; this module makes
//! it one here. [`Element`] abstracts exactly what the datapath does per
//! element — widening multiply-accumulate into a psum, in-network psum
//! addition (BIRRD), narrowing the accumulator back to the element domain
//! (the OB→operand-buffer commit), and a canonical encoding to the 64-bit
//! datapath word — and the whole execution stack
//! ([`crate::arch::buffer::OutputBuffer`], [`crate::functional::FunctionalSim`],
//! [`crate::functional::WavePlan`], [`crate::program::Program`], the serving
//! sessions) is generic over it.
//!
//! Backends:
//!
//! * [`SatI32`] (`= i32`): the pre-refactor semantics, bit-identical — i64
//!   psums, saturating narrowing ([`Element::reduce`] is the former
//!   `clamp_acc`). This is the default type parameter everywhere, so
//!   existing i32 call sites compile and behave unchanged.
//! * `f32`: f32 psums (no widening), identity narrowing — the PJRT-oracle
//!   number system, now executable on the functional path too.
//! * [`ModP`]`<F>`: Montgomery arithmetic over a [`modp::PrimeField`]
//!   (Baby Bear, Goldilocks, Pallas-style — see [`modp`]), the backend that
//!   makes the FHE/ZKP NTT rows of Table IV executable *for real* (see
//!   [`crate::workloads::ntt`]).
//!
//! Wave-plan compilation is element-independent (plans resolve addressing,
//! not values), so one compiled [`crate::program::Program`] serves any
//! element type and the compile-once/serve-many invariant carries over
//! unchanged.

pub mod modp;

use std::fmt;

use crate::isa::inst::ActFn;
use crate::util::Lcg;

pub use modp::{two_adic_root, BabyBear, Goldilocks, ModP, PallasStyle, PrimeField};

/// Today's element semantics under its subsystem name: saturating i32 with
/// i64 accumulation. (`Element` is implemented directly on `i32` so that
/// pre-refactor call sites stay source- and bit-identical.)
pub type SatI32 = i32;

/// One datapath element type: everything the execution stack needs to
/// compute with values of this representation.
///
/// Contract (enforced by `tests/arith_prop.rs` against naive references):
/// `mac`/`acc_add` must be the same addition (so BIRRD in-network merging
/// and OB temporal accumulation commute with per-PE accumulation), `reduce`
/// must match the narrowing the OB→operand commit applies between chained
/// layers, and `decode(encode(x)) == x` for every representable `x` (the
/// serving word format round-trips).
pub trait Element:
    Copy + Clone + Default + PartialEq + Send + Sync + fmt::Debug + 'static
{
    /// The psum/accumulator type (`i64` for `SatI32`; the element itself
    /// for fields, where sums never widen).
    type Acc: Copy + Clone + Default + PartialEq + Send + Sync + fmt::Debug + 'static;

    /// Backend name as spelled by the CLI `--elem` flag.
    const NAME: &'static str;

    /// Whether `0 · x == 0` for **every** representable `x` — true in the
    /// integers and in `Z_p`, false for IEEE floats (`0 · ∞` and `0 · NaN`
    /// are NaN). Reference implementations may skip zero operands only when
    /// this holds, so they stay bit-identical to the always-multiplying
    /// datapath on non-finite inputs.
    const ZERO_ANNIHILATES: bool = true;

    /// Additive identity (equals `Default::default()`).
    #[inline]
    fn zero() -> Self {
        Self::default()
    }

    /// Multiplicative identity.
    fn one() -> Self;

    /// Accumulator zero (equals `Acc::default()`).
    #[inline]
    fn acc_zero() -> Self::Acc {
        Self::Acc::default()
    }

    /// Widening multiply-accumulate: `acc + a·b` in the accumulator domain.
    fn mac(acc: Self::Acc, a: Self, b: Self) -> Self::Acc;

    /// Accumulator addition (BIRRD spatial reduction, OB temporal
    /// accumulation).
    fn acc_add(a: Self::Acc, b: Self::Acc) -> Self::Acc;

    /// Dot product `Σ a[i]·b[i]` from `acc_zero()` — the per-op kernel of
    /// the wave hot loop ([`crate::functional::WavePlan`]). Contract:
    /// **bit-identical** to the sequential fold
    /// `(0..n).fold(acc_zero(), |acc, i| mac(acc, a[i], b[i]))`. Backends
    /// may override with unrolled or delayed-reduction kernels only where
    /// reassociating the additions is provably exact (two's-complement or
    /// modular addition); rounding arithmetic (f32) must keep this
    /// sequential default. Callers pass equal-length slices; the shorter
    /// length governs otherwise.
    #[inline]
    fn dot(a: &[Self], b: &[Self]) -> Self::Acc {
        let mut acc = Self::acc_zero();
        for (&x, &y) in a.iter().zip(b.iter()) {
            acc = Self::mac(acc, x, y);
        }
        acc
    }

    /// Is this accumulator exactly zero? (Orphan-psum legality check.)
    fn acc_is_zero(a: Self::Acc) -> bool;

    /// Narrow an accumulator to the element domain — the conversion the
    /// OB→operand-buffer commit applies, and therefore the one chained
    /// execution applies between layers.
    fn reduce(acc: Self::Acc) -> Self;

    /// Canonical encoding into the 64-bit datapath word (the serving wire
    /// format for element-typed sessions).
    fn encode(self) -> u64;

    /// Inverse of [`Self::encode`] on canonical words; non-canonical words
    /// are normalized into the domain (documented per backend).
    fn decode(word: u64) -> Self;

    /// In-buffer activation semantics for this representation.
    fn act(f: ActFn, v: Self) -> Self;
}

impl Element for i32 {
    type Acc = i64;
    const NAME: &'static str = "i32";

    #[inline]
    fn one() -> Self {
        1
    }

    #[inline(always)]
    fn mac(acc: i64, a: i32, b: i32) -> i64 {
        acc + a as i64 * b as i64
    }

    #[inline(always)]
    fn acc_add(a: i64, b: i64) -> i64 {
        a + b
    }

    /// 4-wide unrolled dot. Reassociation is exact here: two's-complement
    /// i64 addition is associative and commutative, so the four partial
    /// accumulators recombine to the sequential fold bit-for-bit. The
    /// unrolled lanes use `wrapping_add`, which equals `+` everywhere the
    /// sequential fold does not overflow i64 (all real operand ranges — an
    /// overflowing psum would need ~2^32 maximal products) and agrees with
    /// release-mode wrap semantics when it does.
    #[inline]
    fn dot(a: &[i32], b: &[i32]) -> i64 {
        let n = a.len().min(b.len());
        let (mut s0, mut s1, mut s2, mut s3) = (0i64, 0i64, 0i64, 0i64);
        let mut i = 0usize;
        while i + 4 <= n {
            s0 = s0.wrapping_add(a[i] as i64 * b[i] as i64);
            s1 = s1.wrapping_add(a[i + 1] as i64 * b[i + 1] as i64);
            s2 = s2.wrapping_add(a[i + 2] as i64 * b[i + 2] as i64);
            s3 = s3.wrapping_add(a[i + 3] as i64 * b[i + 3] as i64);
            i += 4;
        }
        let mut acc = s0.wrapping_add(s1).wrapping_add(s2).wrapping_add(s3);
        while i < n {
            acc = acc.wrapping_add(a[i] as i64 * b[i] as i64);
            i += 1;
        }
        acc
    }

    #[inline]
    fn acc_is_zero(a: i64) -> bool {
        a == 0
    }

    /// Saturating narrowing — the former `functional::clamp_acc` contract
    /// (that function is now a deprecated shim over this).
    #[inline]
    fn reduce(acc: i64) -> i32 {
        acc.clamp(i32::MIN as i64, i32::MAX as i64) as i32
    }

    #[inline]
    fn encode(self) -> u64 {
        self as u32 as u64
    }

    /// Decodes the low 32 bits (high word ignored).
    #[inline]
    fn decode(word: u64) -> i32 {
        word as u32 as i32
    }

    #[inline]
    fn act(f: ActFn, v: i32) -> i32 {
        match f {
            ActFn::None => v,
            ActFn::Relu => v.max(0),
            // Integer surrogate: the real chip applies GELU in a
            // requantized fixed-point pipeline; only ReLU/None sit on the
            // exact path.
            ActFn::Gelu => {
                let x = v as f64;
                (x * 0.5 * (1.0 + (0.7978845608 * (x + 0.044715 * x * x * x)).tanh())) as i32
            }
            ActFn::Softmax => v, // needs a row context; modeled in L2
        }
    }
}

impl Element for f32 {
    /// f32 psums do not widen; accumulation order therefore matters for
    /// rounding — bit-exactness guarantees hold only on exactly
    /// representable inputs (integers below 2^24), which is what the
    /// property tests use.
    type Acc = f32;
    const NAME: &'static str = "f32";
    /// `0.0 · ∞` / `0.0 · NaN` are NaN: zero operands must still multiply.
    const ZERO_ANNIHILATES: bool = false;

    #[inline]
    fn one() -> Self {
        1.0
    }

    // `dot` deliberately NOT overridden: f32 addition is not associative,
    // so any unroll would change rounding order and break the blocked
    // path's bit-identity contract. The sequential trait default is the
    // only legal kernel here.

    #[inline(always)]
    fn mac(acc: f32, a: f32, b: f32) -> f32 {
        acc + a * b
    }

    #[inline(always)]
    fn acc_add(a: f32, b: f32) -> f32 {
        a + b
    }

    #[inline]
    fn acc_is_zero(a: f32) -> bool {
        a == 0.0
    }

    #[inline]
    fn reduce(acc: f32) -> f32 {
        acc
    }

    #[inline]
    fn encode(self) -> u64 {
        self.to_bits() as u64
    }

    #[inline]
    fn decode(word: u64) -> f32 {
        f32::from_bits(word as u32)
    }

    #[inline]
    fn act(f: ActFn, v: f32) -> f32 {
        match f {
            ActFn::None => v,
            ActFn::Relu => v.max(0.0),
            ActFn::Gelu => {
                let x = v as f64;
                (x * 0.5 * (1.0 + (0.7978845608 * (x + 0.044715 * x * x * x)).tanh())) as f32
            }
            ActFn::Softmax => v,
        }
    }
}

/// Runtime tag naming an [`Element`] backend — the serving/CLI currency.
/// Use [`crate::with_element!`] to dispatch a tag to its concrete type.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ElemType {
    I32,
    F32,
    BabyBear,
    Goldilocks,
    Pallas,
}

impl ElemType {
    pub const ALL: [ElemType; 5] = [
        ElemType::I32,
        ElemType::F32,
        ElemType::BabyBear,
        ElemType::Goldilocks,
        ElemType::Pallas,
    ];

    /// The `--elem` spelling.
    pub fn name(self) -> &'static str {
        match self {
            ElemType::I32 => <i32 as Element>::NAME,
            ElemType::F32 => <f32 as Element>::NAME,
            ElemType::BabyBear => <ModP<BabyBear> as Element>::NAME,
            ElemType::Goldilocks => <ModP<Goldilocks> as Element>::NAME,
            ElemType::Pallas => <ModP<PallasStyle> as Element>::NAME,
        }
    }

    /// Parse a `--elem` value.
    pub fn parse(s: &str) -> Result<Self, String> {
        ElemType::ALL
            .iter()
            .copied()
            .find(|e| e.name() == s)
            .ok_or_else(|| {
                let names: Vec<&str> = ElemType::ALL.iter().map(|e| e.name()).collect();
                format!("unknown element type '{s}' (expected one of {})", names.join(", "))
            })
    }

    /// The field modulus, for the prime-field backends.
    pub fn modulus(self) -> Option<u64> {
        match self {
            ElemType::I32 | ElemType::F32 => None,
            ElemType::BabyBear => Some(BabyBear::P),
            ElemType::Goldilocks => Some(Goldilocks::P),
            ElemType::Pallas => Some(PallasStyle::P),
        }
    }

    pub fn is_field(self) -> bool {
        self.modulus().is_some()
    }

    /// Deterministic operand words in this backend's natural test range:
    /// small signed values for `i32` (keeps chained layers clear of
    /// saturation), exactly representable small integers for `f32` (keeps
    /// accumulation order irrelevant), uniform canonical residues for the
    /// fields.
    pub fn sample_words(self, rng: &mut Lcg, n: usize) -> Vec<u64> {
        (0..n)
            .map(|_| match self {
                ElemType::I32 => (rng.range(0, 15) as i32 - 7).encode(),
                ElemType::F32 => ((rng.range(0, 15) as i32 - 7) as f32).encode(),
                _ => rng.next_u64() % self.modulus().unwrap_or(u64::MAX),
            })
            .collect()
    }
}

impl fmt::Display for ElemType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Dispatch an [`ElemType`] tag to a block generic over the concrete
/// [`Element`] type, bound to the given identifier:
///
/// ```ignore
/// with_element!(elem, E => {
///     let xs: Vec<E> = decode_words::<E>(&words);
///     ...
/// })
/// ```
#[macro_export]
macro_rules! with_element {
    ($elem:expr, $E:ident => $body:block) => {
        match $elem {
            $crate::arith::ElemType::I32 => {
                type $E = i32;
                $body
            }
            $crate::arith::ElemType::F32 => {
                type $E = f32;
                $body
            }
            $crate::arith::ElemType::BabyBear => {
                type $E = $crate::arith::ModP<$crate::arith::BabyBear>;
                $body
            }
            $crate::arith::ElemType::Goldilocks => {
                type $E = $crate::arith::ModP<$crate::arith::Goldilocks>;
                $body
            }
            $crate::arith::ElemType::Pallas => {
                type $E = $crate::arith::ModP<$crate::arith::PallasStyle>;
                $body
            }
        }
    };
}

/// Decode a canonical word slice into elements.
pub fn decode_words<E: Element>(words: &[u64]) -> Vec<E> {
    words.iter().map(|&w| E::decode(w)).collect()
}

/// Encode elements into canonical words.
pub fn encode_words<E: Element>(xs: &[E]) -> Vec<u64> {
    xs.iter().map(|&x| x.encode()).collect()
}

/// Reference GEMM over any element backend: `O[M,N] = I[M,K]·W[K,N]` with
/// accumulation in the `Acc` domain. For `i32` this is bit-identical to the
/// pre-refactor `functional::naive_gemm` (which now delegates here). The
/// zero-operand skip is taken only where [`Element::ZERO_ANNIHILATES`]
/// holds, so the f32 reference agrees with the always-multiplying datapath
/// even on non-finite operands (`0·∞`, `0·NaN`).
pub fn naive_gemm_e<E: Element>(i: &[E], w: &[E], m: usize, k: usize, n: usize) -> Vec<E::Acc> {
    assert_eq!(i.len(), m * k, "input shape");
    assert_eq!(w.len(), k * n, "weight shape");
    let mut o = vec![E::acc_zero(); m * n];
    for mi in 0..m {
        for ki in 0..k {
            let a = i[mi * k + ki];
            if E::ZERO_ANNIHILATES && a == E::zero() {
                continue;
            }
            for ni in 0..n {
                o[mi * n + ni] = E::mac(o[mi * n + ni], a, w[ki * n + ni]);
            }
        }
    }
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn i32_backend_is_pre_refactor_semantics() {
        // mac widens into i64 without wrapping.
        assert_eq!(<i32 as Element>::mac(0, i32::MAX, 2), 2 * i32::MAX as i64);
        // reduce saturates exactly like the former clamp_acc.
        assert_eq!(<i32 as Element>::reduce(i64::MAX), i32::MAX);
        assert_eq!(<i32 as Element>::reduce(i64::MIN), i32::MIN);
        assert_eq!(<i32 as Element>::reduce(-5), -5);
        assert_eq!(<i32 as Element>::reduce(i32::MAX as i64 + 1), i32::MAX);
        assert_eq!(<i32 as Element>::reduce(i32::MIN as i64 - 1), i32::MIN);
    }

    #[test]
    fn i32_encode_roundtrip() {
        for v in [0, 1, -1, 42, i32::MAX, i32::MIN] {
            assert_eq!(i32::decode(v.encode()), v);
        }
    }

    #[test]
    fn f32_encode_roundtrip() {
        for v in [0.0f32, 1.5, -3.25, f32::MAX] {
            assert_eq!(f32::decode(v.encode()), v);
        }
        assert!(f32::decode(f32::NAN.encode()).is_nan());
    }

    #[test]
    fn elem_type_parse_and_names() {
        for e in ElemType::ALL {
            assert_eq!(ElemType::parse(e.name()), Ok(e));
            assert_eq!(format!("{e}"), e.name());
        }
        assert!(ElemType::parse("i64").is_err());
        assert!(ElemType::I32.modulus().is_none());
        assert!(ElemType::Goldilocks.is_field());
        assert_eq!(ElemType::BabyBear.modulus(), Some(2_013_265_921));
    }

    #[test]
    fn with_element_dispatches_every_tag() {
        for e in ElemType::ALL {
            let name = with_element!(e, E => { <E as Element>::NAME });
            assert_eq!(name, e.name());
        }
    }

    #[test]
    fn sample_words_are_canonical() {
        let mut rng = Lcg::new(7);
        for e in ElemType::ALL {
            for w in e.sample_words(&mut rng, 64) {
                let rt = with_element!(e, E => { E::decode(w).encode() });
                assert_eq!(rt, w, "{e} word {w:#x} canonical");
            }
        }
    }

    #[test]
    fn naive_gemm_e_matches_by_hand() {
        // 2x2·2x2 over i32.
        let i = [1, 2, 3, 4];
        let w = [5, 6, 7, 8];
        assert_eq!(naive_gemm_e::<i32>(&i, &w, 2, 2, 2), vec![19, 22, 43, 50]);
        // Same values over Goldilocks.
        type G = ModP<Goldilocks>;
        let ig: Vec<G> = i.iter().map(|&x| G::new(x as u64)).collect();
        let wg: Vec<G> = w.iter().map(|&x| G::new(x as u64)).collect();
        let og: Vec<u64> = naive_gemm_e::<G>(&ig, &wg, 2, 2, 2)
            .into_iter()
            .map(|x| x.to_u64())
            .collect();
        assert_eq!(og, vec![19, 22, 43, 50]);
    }

    #[test]
    fn f32_reference_does_not_skip_zero_times_infinity() {
        // 0.0 · ∞ is NaN; the reference must multiply it like the datapath
        // does, not skip it as an annihilating zero.
        let o = naive_gemm_e::<f32>(&[0.0], &[f32::INFINITY], 1, 1, 1);
        assert!(o[0].is_nan());
        assert!(!<f32 as Element>::ZERO_ANNIHILATES);
        assert!(<i32 as Element>::ZERO_ANNIHILATES);
        assert!(<ModP<Goldilocks> as Element>::ZERO_ANNIHILATES);
    }

    #[test]
    fn encode_decode_words_roundtrip() {
        let xs: Vec<i32> = vec![-3, 0, 7, i32::MIN];
        assert_eq!(decode_words::<i32>(&encode_words::<i32>(&xs)), xs);
    }

    /// `Element::dot` ≡ the sequential `mac` fold, bit-for-bit, for every
    /// backend and for lengths straddling every unroll/chunk boundary
    /// (i32's 4-wide unroll; `ModP::mac_block`'s delayed-REDC chunks — for
    /// PallasStyle the chunk limit is 4, so 1..=19 crosses it repeatedly).
    #[test]
    fn dot_matches_sequential_fold_all_backends() {
        let mut rng = Lcg::new(0xD07);
        for elem in ElemType::ALL {
            for len in 0..=19usize {
                let wa = elem.sample_words(&mut rng, len);
                let wb = elem.sample_words(&mut rng, len);
                with_element!(elem, E => {
                    let a: Vec<E> = decode_words::<E>(&wa);
                    let b: Vec<E> = decode_words::<E>(&wb);
                    let mut seq = E::acc_zero();
                    for i in 0..len {
                        seq = E::mac(seq, a[i], b[i]);
                    }
                    assert_eq!(E::dot(&a, &b), seq, "{elem} dot len={len}");
                });
            }
        }
    }
}
