//! Montgomery prime-field arithmetic — the `ModP` element backend.
//!
//! FHE and ZKP kernels (Table IV's NTT entries) compute over `Z_p`, not
//! saturating integers: an NTT-as-GEMM is only *correct* if every
//! multiply-accumulate reduces modulo the field prime. `ModP<F>` stores
//! residues in Montgomery form (`x·R mod p`, `R = 2^64`) so the hot-path
//! multiply is one 64×64→128 multiply plus one REDC — no `%` on the wave
//! loop (the §Perf story applied to the arithmetic itself; see
//! `benches/hotpath.rs` "arith/" cases and `BENCH_arith.json`).
//!
//! Supported primes are declared as [`PrimeField`] marker types. The
//! Montgomery constants (`R`, `R²`, `-p⁻¹ mod 2^64`) are derived at compile
//! time from `P` alone by const evaluation, so adding a field is three
//! constants and a name. The REDC below is valid for any odd `p < 2^64`
//! (including Goldilocks, where `2p` overflows u64 — the carry branch
//! handles it); all three shipped fields were cross-validated against a
//! big-integer oracle during development.
//!
//! Shipped fields (two-adic roots are the standard published constants):
//!
//! | field        | p                              | 2-adicity | use         |
//! |--------------|--------------------------------|-----------|-------------|
//! | `BabyBear`   | 2^31 − 2^27 + 1                | 27        | FHE RNS limb|
//! | `Goldilocks` | 2^64 − 2^32 + 1                | 32        | ZKP STARKs  |
//! | `PallasStyle`| 0x3fffff5d·2^32 + 1 (62-bit)   | 32        | ZKP (Pallas-like 2-adicity) |
//!
//! `PallasStyle` is *not* the 255-bit Pallas base field (which does not fit
//! the 64-bit datapath word); it is the largest 62-bit prime `c·2^32 + 1`
//! with **odd** `c` (i.e. 2-adicity exactly 32), chosen to mirror Pallas's
//! high 2-adicity so the same NTT sizes lower (§VI Table IV ZKP rows).

use std::fmt;
use std::marker::PhantomData;

use super::Element;
use crate::isa::inst::ActFn;

/// `p⁻¹ mod 2^64` by Newton–Hensel iteration (3 correct bits at start for
/// odd `p`, doubling per step: 6 steps ≥ 64 bits), negated for REDC.
const fn mont_ninv(p: u64) -> u64 {
    let mut inv: u64 = p;
    let mut i = 0;
    while i < 6 {
        inv = inv.wrapping_mul(2u64.wrapping_sub(p.wrapping_mul(inv)));
        i += 1;
    }
    inv.wrapping_neg()
}

/// `2^64 mod p` — the Montgomery form of 1.
const fn mont_r(p: u64) -> u64 {
    ((1u128 << 64) % p as u128) as u64
}

/// `(2^64)² mod p` — the to-Montgomery conversion constant.
const fn mont_r2(p: u64) -> u64 {
    let r = mont_r(p) as u128;
    ((r * r) % p as u128) as u64
}

/// A prime modulus usable as a `ModP` backend: an odd prime `< 2^64` with a
/// published multiplicative generator and two-adic root of unity (the root
/// is what `workloads::ntt` derives twiddle matrices from). The Montgomery
/// constants default to compile-time derivations from `P`.
pub trait PrimeField:
    Copy + Clone + Default + PartialEq + Eq + std::hash::Hash + Send + Sync + fmt::Debug + 'static
{
    /// The modulus (odd prime, `< 2^64`).
    const P: u64;
    /// A generator of the multiplicative group (canonical residue).
    const GENERATOR: u64;
    /// Largest `s` with `2^s | p − 1`: NTT sizes up to `2^s` lower exactly.
    const TWO_ADICITY: u32;
    /// A primitive `2^TWO_ADICITY`-th root of unity (canonical residue).
    const TWO_ADIC_ROOT: u64;
    const NAME: &'static str;
    /// `−p⁻¹ mod 2^64` (REDC constant; derived, do not override).
    const NINV: u64 = mont_ninv(Self::P);
    /// `2^64 mod p` (Montgomery 1; derived, do not override).
    const R: u64 = mont_r(Self::P);
    /// `(2^64)² mod p` (to-Montgomery constant; derived, do not override).
    const R2: u64 = mont_r2(Self::P);
}

/// Baby Bear: `p = 2^31 − 2^27 + 1`, the RISC-Zero/Plonky3 31-bit field —
/// the natural RNS-limb stand-in for the FHE NTT rows of Table IV.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, Hash)]
pub struct BabyBear;

impl PrimeField for BabyBear {
    const P: u64 = 0x7800_0001; // 2_013_265_921
    const GENERATOR: u64 = 31;
    const TWO_ADICITY: u32 = 27;
    const TWO_ADIC_ROOT: u64 = 0x1a42_7a41; // 31^((p-1)/2^27) mod p
    const NAME: &'static str = "babybear";
}

/// Goldilocks: `p = 2^64 − 2^32 + 1` (Plonky2/winterfell), the ZKP STARK
/// workhorse — exercises the near-2^64 REDC carry path.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, Hash)]
pub struct Goldilocks;

impl PrimeField for Goldilocks {
    const P: u64 = 0xffff_ffff_0000_0001;
    const GENERATOR: u64 = 7;
    const TWO_ADICITY: u32 = 32;
    const TWO_ADIC_ROOT: u64 = 0x1856_29dc_da58_878c; // 7^((p-1)/2^32) mod p
    const NAME: &'static str = "goldilocks";
}

/// Pallas-style: the largest 62-bit prime `c·2^32 + 1` with odd `c`
/// (`c = 0x3fffff5d`, 2-adicity exactly 32), mirroring the Pallas curve
/// field's high 2-adicity within the 64-bit datapath word. See the module
/// docs for why the real 255-bit field is out.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, Hash)]
pub struct PallasStyle;

impl PrimeField for PallasStyle {
    const P: u64 = 0x3fff_ff5d_0000_0001; // 4_611_685_318_347_718_657
    const GENERATOR: u64 = 5;
    const TWO_ADICITY: u32 = 32;
    const TWO_ADIC_ROOT: u64 = 0x1b94_1e27_c355_b864; // 5^((p-1)/2^32) mod p
    const NAME: &'static str = "pallas";
}

/// A field element in Montgomery form. `Default` is 0; construct canonical
/// values with [`ModP::new`] and read them back with [`ModP::to_u64`].
#[derive(Clone, Copy, Default, PartialEq, Eq, Hash)]
pub struct ModP<F: PrimeField>(u64, PhantomData<F>);

impl<F: PrimeField> ModP<F> {
    /// How many Montgomery products may be summed in a u128 before one
    /// REDC, with the REDC precondition `t < p·2^64` still provably held:
    /// each product of representations is `< p²`, so `n` of them sum to
    /// `< n·p²`, and `n·p² ≤ p·2^64 ⇔ n·p ≤ 2^64` — i.e. `n = ⌊2^64/p⌋`
    /// (computed as `u64::MAX / P`, off by at most one product's worth of
    /// slack, always on the safe side). BabyBear: ~9.2e9 (one REDC per
    /// dot). PallasStyle: 4. Goldilocks: 1 — the bound degenerates to
    /// REDC-per-product, i.e. exactly the sequential `mac` chain.
    pub const DELAYED_MACS: usize = {
        let n = (u64::MAX / F::P) as usize;
        if n == 0 {
            1
        } else {
            n
        }
    };

    /// Fused dot-product kernel with delayed Montgomery reduction:
    /// `acc + Σ a[i]·b[i]` over Montgomery representations, accumulating up
    /// to [`Self::DELAYED_MACS`] widening products in a u128 before each
    /// REDC. **Bit-identical** to folding [`Element::mac`] sequentially:
    /// both sides compute canonical Montgomery representations (REDC output
    /// is the unique representative in `[0, p)` given its precondition, and
    /// field addition of canonical representatives is exact), so delaying
    /// the reduction changes the number of REDCs executed, never the
    /// residue they produce. See docs/PERF.md for the bound derivation and
    /// the autovectorization notes.
    #[inline]
    pub fn mac_block(acc: Self, a: &[Self], b: &[Self]) -> Self {
        let n = a.len().min(b.len());
        let mut acc = acc;
        let mut i = 0usize;
        while i < n {
            let end = (i + Self::DELAYED_MACS).min(n);
            let mut t: u128 = 0;
            while i < end {
                t += a[i].0 as u128 * b[i].0 as u128;
                i += 1;
            }
            acc = acc + Self(Self::redc(t), PhantomData);
        }
        acc
    }

    /// From a canonical residue (values `>= p` are reduced).
    #[inline]
    pub fn new(v: u64) -> Self {
        Self(Self::redc(v as u128 * F::R2 as u128), PhantomData)
    }

    /// The canonical residue in `[0, p)`.
    #[inline]
    pub fn to_u64(self) -> u64 {
        Self::redc(self.0 as u128)
    }

    pub const fn modulus() -> u64 {
        F::P
    }

    /// Montgomery reduction: `t·2^-64 mod p` for `t < p·2^64`. The carry
    /// branch keeps this exact for `p` within one bit of 2^64 (Goldilocks):
    /// `(t + m·p)/2^64 < 2p` may not fit u64, but `carry` recovers the
    /// 2^64 bit and the subtract folds it back below `p`.
    /// `inline(always)`: this is the innermost operation of the wave hot
    /// loop and must fuse into the [`Self::mac_block`]/`dot` kernels across
    /// the generic call boundary for LLVM to see the whole mul/REDC chain.
    #[inline(always)]
    fn redc(t: u128) -> u64 {
        let m = (t as u64).wrapping_mul(F::NINV);
        let (sum, carry) = t.overflowing_add(m as u128 * F::P as u128);
        let r = (sum >> 64) as u64;
        if carry || r >= F::P {
            r.wrapping_sub(F::P)
        } else {
            r
        }
    }

    /// `self^e` by square-and-multiply (exponent over canonical integers).
    pub fn pow(self, mut e: u64) -> Self {
        let mut base = self;
        let mut acc = Self::new(1);
        while e > 0 {
            if e & 1 == 1 {
                acc = acc * base;
            }
            base = base * base;
            e >>= 1;
        }
        acc
    }

    /// Multiplicative inverse via Fermat (`self^(p−2)`); `inv(0) == 0`.
    pub fn inv(self) -> Self {
        self.pow(F::P - 2)
    }
}

impl<F: PrimeField> std::ops::Add for ModP<F> {
    type Output = Self;
    #[inline(always)]
    fn add(self, rhs: Self) -> Self {
        // a, b < p so a + b < 2p < 2^65: the carry (possible only when p is
        // within one bit of 2^64) marks sums ≥ 2^64, which are always ≥ p.
        let (s, carry) = self.0.overflowing_add(rhs.0);
        let s = if carry || s >= F::P { s.wrapping_sub(F::P) } else { s };
        Self(s, PhantomData)
    }
}

impl<F: PrimeField> std::ops::Sub for ModP<F> {
    type Output = Self;
    #[inline]
    fn sub(self, rhs: Self) -> Self {
        let (d, borrow) = self.0.overflowing_sub(rhs.0);
        Self(if borrow { d.wrapping_add(F::P) } else { d }, PhantomData)
    }
}

impl<F: PrimeField> std::ops::Neg for ModP<F> {
    type Output = Self;
    #[inline]
    fn neg(self) -> Self {
        Self::default() - self
    }
}

impl<F: PrimeField> std::ops::Mul for ModP<F> {
    type Output = Self;
    #[inline(always)]
    fn mul(self, rhs: Self) -> Self {
        Self(Self::redc(self.0 as u128 * rhs.0 as u128), PhantomData)
    }
}

impl<F: PrimeField> fmt::Debug for ModP<F> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Print the canonical residue, not the Montgomery representation.
        write!(f, "{}#{}", self.to_u64(), F::NAME)
    }
}

impl<F: PrimeField> fmt::Display for ModP<F> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.to_u64())
    }
}

impl<F: PrimeField> Element for ModP<F> {
    /// Field psums never widen: BIRRD/OB accumulation is field addition.
    type Acc = ModP<F>;
    const NAME: &'static str = F::NAME;

    #[inline]
    fn one() -> Self {
        // R is the Montgomery form of 1 — no conversion multiply needed.
        Self(F::R, PhantomData)
    }

    #[inline(always)]
    fn mac(acc: Self::Acc, a: Self, b: Self) -> Self::Acc {
        acc + a * b
    }

    #[inline(always)]
    fn acc_add(a: Self::Acc, b: Self::Acc) -> Self::Acc {
        a + b
    }

    /// Delayed-REDC dot kernel — see [`ModP::mac_block`] for the bound and
    /// the bit-identity argument.
    #[inline]
    fn dot(a: &[Self], b: &[Self]) -> Self::Acc {
        Self::mac_block(Self::default(), a, b)
    }

    #[inline]
    fn acc_is_zero(a: Self::Acc) -> bool {
        // Montgomery form of 0 is 0.
        a.0 == 0
    }

    /// Identity: field accumulators are already elements (the OB→operand
    /// commit between chained NTT layers is exact, unlike `SatI32`).
    #[inline]
    fn reduce(acc: Self::Acc) -> Self {
        acc
    }

    #[inline]
    fn encode(self) -> u64 {
        self.to_u64()
    }

    #[inline]
    fn decode(word: u64) -> Self {
        Self::new(word)
    }

    /// ReLU/GELU/softmax have no order-theoretic meaning in `Z_p`; field
    /// programs (NTT chains) use `ActFn::None` only, and the others are
    /// identity so a stray activation cannot corrupt exactness silently.
    #[inline]
    fn act(_f: ActFn, v: Self) -> Self {
        v
    }
}

/// A primitive `n`-th root of unity for power-of-two `n`, derived from the
/// field's two-adic root by repeated squaring. `Err` when `n` exceeds the
/// field's two-adic subgroup (or is not a power of two).
pub fn two_adic_root<F: PrimeField>(n: usize) -> Result<ModP<F>, String> {
    if !n.is_power_of_two() {
        return Err(format!("NTT size {n} is not a power of two"));
    }
    let log_n = n.trailing_zeros();
    if log_n > F::TWO_ADICITY {
        return Err(format!(
            "NTT size {n} exceeds {}'s two-adic subgroup (2^{})",
            F::NAME,
            F::TWO_ADICITY
        ));
    }
    let mut root = ModP::<F>::new(F::TWO_ADIC_ROOT);
    for _ in 0..(F::TWO_ADICITY - log_n) {
        root = root * root;
    }
    Ok(root)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Lcg;

    /// Big-integer oracle: `a·b mod p` through u128.
    fn mulmod(a: u64, b: u64, p: u64) -> u64 {
        ((a as u128 * b as u128) % p as u128) as u64
    }

    fn roundtrip_and_ops<F: PrimeField>() {
        let p = F::P;
        let mut rng = Lcg::new(0xF1E1D);
        for _ in 0..2000 {
            let a = rng.next_u64() % p;
            let b = rng.next_u64() % p;
            let (fa, fb) = (ModP::<F>::new(a), ModP::<F>::new(b));
            assert_eq!(fa.to_u64(), a, "{} roundtrip", F::NAME);
            assert_eq!((fa * fb).to_u64(), mulmod(a, b, p), "{} mul", F::NAME);
            assert_eq!(
                (fa + fb).to_u64(),
                ((a as u128 + b as u128) % p as u128) as u64,
                "{} add",
                F::NAME
            );
            assert_eq!(
                (fa - fb).to_u64(),
                ((a as u128 + p as u128 - b as u128) % p as u128) as u64,
                "{} sub",
                F::NAME
            );
        }
        // Boundary values — the REDC carry / add overflow paths.
        for a in [0, 1, 2, p - 2, p - 1] {
            for b in [0, 1, 2, p - 2, p - 1] {
                let (fa, fb) = (ModP::<F>::new(a), ModP::<F>::new(b));
                assert_eq!((fa * fb).to_u64(), mulmod(a, b, p), "{} mul edge", F::NAME);
                assert_eq!(
                    (fa + fb).to_u64(),
                    ((a as u128 + b as u128) % p as u128) as u64,
                    "{} add edge",
                    F::NAME
                );
            }
        }
        // Non-canonical input reduces.
        assert_eq!(ModP::<F>::new(p).to_u64(), 0);
        assert_eq!(ModP::<F>::one().to_u64(), 1);
        assert_eq!((-ModP::<F>::one()).to_u64(), p - 1);
    }

    #[test]
    fn babybear_field_ops() {
        roundtrip_and_ops::<BabyBear>();
    }

    #[test]
    fn goldilocks_field_ops() {
        roundtrip_and_ops::<Goldilocks>();
    }

    #[test]
    fn pallas_style_field_ops() {
        roundtrip_and_ops::<PallasStyle>();
    }

    fn inverse_and_pow<F: PrimeField>() {
        let mut rng = Lcg::new(99);
        for _ in 0..200 {
            let a = 1 + rng.next_u64() % (F::P - 1);
            let fa = ModP::<F>::new(a);
            assert_eq!((fa * fa.inv()).to_u64(), 1, "{} inverse", F::NAME);
        }
        assert_eq!(ModP::<F>::new(0).inv().to_u64(), 0, "inv(0) convention");
        // Fermat: a^(p-1) = 1.
        assert_eq!(ModP::<F>::new(12345 % F::P).pow(F::P - 1).to_u64(), 1);
    }

    #[test]
    fn inverses() {
        inverse_and_pow::<BabyBear>();
        inverse_and_pow::<Goldilocks>();
        inverse_and_pow::<PallasStyle>();
    }

    fn root_structure<F: PrimeField>() {
        // The declared two-adic root has exact order 2^TWO_ADICITY …
        let r = ModP::<F>::new(F::TWO_ADIC_ROOT);
        assert_eq!(r.pow(1 << (F::TWO_ADICITY - 1)).to_u64(), F::P - 1, "{}", F::NAME);
        // … and derived n-th roots have exact order n.
        for log_n in [1u32, 3, 6] {
            let n = 1usize << log_n;
            let w = two_adic_root::<F>(n).unwrap();
            assert_eq!(w.pow(n as u64).to_u64(), 1);
            assert_eq!(w.pow((n / 2) as u64).to_u64(), F::P - 1, "primitive {n}-th root");
        }
        assert!(two_adic_root::<F>(3).is_err(), "non-power-of-two rejected");
        assert!(two_adic_root::<F>(1usize << 40).is_err(), "oversized rejected");
    }

    #[test]
    fn two_adic_roots() {
        root_structure::<BabyBear>();
        root_structure::<Goldilocks>();
        root_structure::<PallasStyle>();
    }

    #[test]
    fn derived_montgomery_constants() {
        // The const-fn derivations match the definitional identities.
        fn check<F: PrimeField>() {
            assert_eq!(F::P.wrapping_mul(F::NINV.wrapping_neg()), 1, "{} ninv", F::NAME);
            assert_eq!(F::R as u128, (1u128 << 64) % F::P as u128);
            assert_eq!(F::R2 as u128, (F::R as u128 * F::R as u128) % F::P as u128);
        }
        check::<BabyBear>();
        check::<Goldilocks>();
        check::<PallasStyle>();
    }

    /// `mac_block` vs two oracles — the sequential `mac` fold (bit-identity
    /// contract) and a schoolbook `u128 % p` sum (value contract) — across
    /// lengths that straddle the delayed-reduction chunk boundary. For
    /// PallasStyle `DELAYED_MACS == 4`, so lengths 1..=21 cross chunk
    /// boundaries at 4/8/…; for Goldilocks the bound is 1 (sequential
    /// degeneration); BabyBear never chunks at these lengths.
    fn mac_block_vs_oracles<F: PrimeField>() {
        let p = F::P;
        let mut rng = Lcg::new(0xB10C << 4);
        for len in 0..=21usize {
            for round in 0..8 {
                let acc0 = rng.next_u64() % p;
                let a: Vec<u64> = (0..len).map(|_| rng.next_u64() % p).collect();
                let b: Vec<u64> = (0..len).map(|_| rng.next_u64() % p).collect();
                let fa: Vec<ModP<F>> = a.iter().map(|&x| ModP::new(x)).collect();
                let fb: Vec<ModP<F>> = b.iter().map(|&x| ModP::new(x)).collect();
                let facc = ModP::<F>::new(acc0);
                let blocked = ModP::<F>::mac_block(facc, &fa, &fb);
                // Bit-identity with the sequential fold (Montgomery words,
                // not just canonical values).
                let mut seq = facc;
                for i in 0..len {
                    seq = <ModP<F> as Element>::mac(seq, fa[i], fb[i]);
                }
                assert_eq!(blocked, seq, "{} len={len} round={round} bit-identity", F::NAME);
                // Value contract against the schoolbook oracle.
                let mut want = acc0;
                for i in 0..len {
                    want = ((want as u128 + mulmod(a[i], b[i], p) as u128) % p as u128) as u64;
                }
                assert_eq!(blocked.to_u64(), want, "{} len={len} round={round} value", F::NAME);
            }
        }
        // Worst-case magnitudes: DELAYED_MACS products of (p−1)² must not
        // break the REDC precondition (the bound proof, exercised).
        let lim = ModP::<F>::DELAYED_MACS.min(64);
        let top: Vec<ModP<F>> = vec![ModP::new(p - 1); lim + 3];
        let blocked = ModP::<F>::mac_block(ModP::default(), &top, &top);
        let mut want = 0u64;
        for _ in 0..lim + 3 {
            want = ((want as u128 + mulmod(p - 1, p - 1, p) as u128) % p as u128) as u64;
        }
        assert_eq!(blocked.to_u64(), want, "{} worst-case magnitudes", F::NAME);
    }

    #[test]
    fn mac_block_babybear() {
        mac_block_vs_oracles::<BabyBear>();
    }

    #[test]
    fn mac_block_goldilocks() {
        assert_eq!(ModP::<Goldilocks>::DELAYED_MACS, 1, "p near 2^64: no delay possible");
        mac_block_vs_oracles::<Goldilocks>();
    }

    #[test]
    fn mac_block_pallas_style() {
        assert_eq!(ModP::<PallasStyle>::DELAYED_MACS, 4, "62-bit p: 4 products per REDC");
        mac_block_vs_oracles::<PallasStyle>();
    }

    #[test]
    fn delayed_macs_bound_is_safe() {
        // n·p ≤ 2^64 for the chosen n — the REDC precondition `t < p·2^64`
        // then holds for any chunk of n products of values < p.
        fn check<F: PrimeField>() {
            let n = ModP::<F>::DELAYED_MACS as u128;
            assert!(n >= 1);
            assert!(n * F::P as u128 <= 1u128 << 64, "{} delayed bound", F::NAME);
        }
        check::<BabyBear>();
        check::<Goldilocks>();
        check::<PallasStyle>();
    }

    #[test]
    fn element_contract() {
        type E = ModP<Goldilocks>;
        let a = E::new(5);
        let b = E::new(7);
        assert_eq!(E::mac(E::acc_zero(), a, b).to_u64(), 35);
        assert!(E::acc_is_zero(E::acc_zero()));
        assert!(!E::acc_is_zero(E::mac(E::acc_zero(), a, b)));
        assert_eq!(E::decode(E::encode(a)), a);
        assert_eq!(E::reduce(a * b), a * b, "reduce is identity in a field");
        assert_eq!(E::act(ActFn::Relu, a), a, "activations are identity in Z_p");
    }
}
