//! Result tables: CSV and aligned-text emitters used by the CLI, the bench
//! harness and EXPERIMENTS.md generation.

use std::fmt::Write as _;
use std::path::Path;

/// A simple column-oriented table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    /// Aligned plain-text rendering.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut s = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(s, "== {} ==", self.title);
        }
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(s, "{}", line(&self.headers, &widths));
        let _ = writeln!(s, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        for row in &self.rows {
            let _ = writeln!(s, "{}", line(row, &widths));
        }
        s
    }

    /// CSV rendering (RFC-4180-lite; quotes cells containing commas).
    pub fn to_csv(&self) -> String {
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut s = self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            s.push('\n');
        }
        s
    }

    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())
    }

    /// GitHub-flavored markdown rendering (EXPERIMENTS.md).
    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "| {} |", self.headers.join(" | "));
        let _ = writeln!(s, "|{}|", self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
        for row in &self.rows {
            let _ = writeln!(s, "| {} |", row.join(" | "));
        }
        s
    }
}

/// Format helpers.
pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}
/// Engineering notation for big ratios, e.g. "4.4e5".
pub fn eng(x: f64) -> String {
    if x == 0.0 {
        return "0".into();
    }
    if x.abs() >= 1e4 {
        format!("{x:.1e}")
    } else if x.abs() >= 100.0 {
        format!("{x:.0}")
    } else {
        format!("{x:.1}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into(), "hello".into()]);
        t.row(vec!["22".into(), "x,y".into()]);
        t
    }

    #[test]
    fn render_aligns() {
        let r = sample().render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("a   b"));
    }

    #[test]
    fn csv_quotes_commas() {
        let c = sample().to_csv();
        assert!(c.contains("\"x,y\""));
        assert!(c.starts_with("a,b\n"));
    }

    #[test]
    fn markdown_shape() {
        let md = sample().to_markdown();
        assert!(md.starts_with("| a | b |"));
        assert!(md.contains("|---|---|"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(f1(1.26), "1.3");
        assert_eq!(pct(0.969), "96.9%");
        assert_eq!(eng(440_000.0), "4.4e5");
        assert_eq!(eng(31.6), "31.6");
        assert_eq!(eng(0.0), "0");
    }

    #[test]
    fn csv_writes_to_disk() {
        let p = std::env::temp_dir().join("minisa_report_test.csv");
        sample().write_csv(&p).unwrap();
        let back = std::fs::read_to_string(&p).unwrap();
        assert!(back.contains("hello"));
        let _ = std::fs::remove_file(&p);
    }
}
