//! Functional (bit-accurate dataflow) simulator for FEATHER+ under MINISA
//! (§IV-G execution model).
//!
//! Executes instruction traces against real operand values: Load/Store move
//! words between an HBM image and the on-chip buffers, layout instructions
//! program address generation, and each ExecuteMapping/ExecuteStreaming
//! pair runs one NEST compute tile — Eq. (1) placement, top-to-bottom
//! streaming, BIRRD spatial reduction and OB temporal accumulation.
//!
//! The simulator is generic over the element backend
//! ([`crate::arith::Element`]): `FunctionalSim<i32>` (the default) is the
//! pre-`arith` saturating-integer simulator bit-for-bit, `FunctionalSim<f32>`
//! mirrors the PJRT oracle's number system, and
//! `FunctionalSim<ModP<F>>` executes FHE/ZKP NTT traces field-exactly
//! (`crate::workloads::ntt`). Trace structure, addressing, wave plans and
//! `SimStats`/`SimError` semantics are element-independent.
//!
//! This is the repo's substitute for the paper's RTL functional validation
//! (DESIGN.md §Hardware-Adaptation): traces produced by the mapper must
//! reproduce a naive GEMM exactly, and integration tests additionally
//! cross-check against the PJRT-executed JAX/Pallas oracle.

pub mod block;
pub mod plan;

use std::collections::HashMap;
use std::sync::Arc;

use crate::arch::buffer::{DataBuffer, OutputBuffer};
use crate::arch::config::ArchConfig;
use crate::arith::Element;
use crate::isa::inst::{BufTarget, Inst};
use crate::layout::VnLayout;
use crate::mapping::{Dataflow, MappingCfg, StreamCfg};

pub use block::{BlockSim, DEFAULT_ROW_BLOCK};
pub use plan::{PlanKey, PlanScratch, WavePlan};

/// Compiled-plan cache bound: distinct (θ_EM, θ_ES, layouts) tuples per
/// lowered program are small (one per chunk pattern per tile shape), so the
/// cap only guards against pathological generated traces.
const PLAN_CACHE_CAP: usize = 512;

/// Simulator errors — each corresponds to an illegal program, not a
/// simulator limitation.
#[derive(Debug, Clone, PartialEq)]
pub enum SimError {
    HbmOutOfRange { addr: u64, len: usize },
    BufferOverflow { buf: BufTarget, need: usize, have: usize },
    NoMapping,
    NoLayout(&'static str),
    OrphanPsum { m: usize, n: usize },
    ObOverflow { row: usize, depth: usize },
    Invalid(String),
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::HbmOutOfRange { addr, len } => {
                write!(f, "HBM access out of range: addr {addr} len {len}")
            }
            SimError::BufferOverflow { buf, need, have } => {
                write!(f, "{buf:?} buffer overflow: need {need} rows, have {have}")
            }
            SimError::NoMapping => {
                write!(f, "ExecuteStreaming without a preceding ExecuteMapping")
            }
            SimError::NoLayout(which) => write!(f, "execute before {which} layout was set"),
            SimError::OrphanPsum { m, n } => {
                write!(f, "nonzero psum for output ({m}, {n}) outside the OVN layout")
            }
            SimError::ObOverflow { row, depth } => {
                write!(f, "output buffer overflow: row {row} >= depth {depth}")
            }
            SimError::Invalid(msg) => write!(f, "instruction validation: {msg}"),
        }
    }
}

impl std::error::Error for SimError {}

/// Execution statistics accumulated over a trace.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SimStats {
    /// MAC operations that consumed in-bounds data.
    pub macs_used: u64,
    /// MAC slots available over all waves (AH·AW per wave).
    pub macs_possible: u64,
    /// Streaming waves executed (one per (column-step) group).
    pub waves: u64,
    /// In-network (BIRRD) pairwise additions.
    pub birrd_adds: u64,
    /// Output-buffer bank conflicts observed.
    pub ob_conflicts: u64,
    /// Words moved by Load / Store.
    pub load_words: u64,
    pub store_words: u64,
    /// Instructions executed by class.
    pub n_layout: u64,
    pub n_execute: u64,
    pub n_memory: u64,
    pub n_activation: u64,
}

impl SimStats {
    /// Average compute utilization over the executed waves.
    pub fn utilization(&self) -> f64 {
        if self.macs_possible == 0 {
            return 0.0;
        }
        self.macs_used as f64 / self.macs_possible as f64
    }

    /// Accumulate another stats record into this one — the roll-up
    /// [`BlockSim::stats`] and fleet reporting use. Every field is a
    /// count, so summation is the correct aggregation for all of them.
    pub fn absorb(&mut self, o: &SimStats) {
        self.macs_used += o.macs_used;
        self.macs_possible += o.macs_possible;
        self.waves += o.waves;
        self.birrd_adds += o.birrd_adds;
        self.ob_conflicts += o.ob_conflicts;
        self.load_words += o.load_words;
        self.store_words += o.store_words;
        self.n_layout += o.n_layout;
        self.n_execute += o.n_execute;
        self.n_memory += o.n_memory;
        self.n_activation += o.n_activation;
    }
}

/// Pack a tile's VNs into the row-major buffer image `Load` expects:
/// VN slot `L` of the layout lands at rows `(L/aw)·vn .. +vn`, column
/// `L mod aw`. `gather(r, c)` supplies each VN's (zero-padded) elements.
pub fn pack_image<T: Copy + Default>(
    layout: &VnLayout,
    aw: usize,
    gather: impl Fn(usize, usize) -> Vec<T>,
) -> Vec<T> {
    let rows = layout.rows_needed(aw);
    let mut img = vec![T::default(); rows * aw];
    for l in 0..layout.vn_slots() {
        let (r, c) = layout.unflatten(l).expect("slot in range");
        let elems = gather(r, c);
        debug_assert_eq!(elems.len(), layout.vn_size);
        let (row0, col) = ((l / aw) * layout.vn_size, l % aw);
        for (i, &e) in elems.iter().enumerate() {
            img[(row0 + i) * aw + col] = e;
        }
    }
    img
}

/// The functional simulator, generic over the element backend `E`
/// (defaulting to the saturating-i32 semantics the repo started with).
#[derive(Debug, Clone)]
pub struct FunctionalSim<E: Element = i32> {
    pub cfg: ArchConfig,
    hbm: Vec<E>,
    hbm_top: usize,
    streaming: DataBuffer<E>,
    stationary: DataBuffer<E>,
    ob: OutputBuffer<E>,
    i_layout: Option<VnLayout>,
    w_layout: Option<VnLayout>,
    o_layout: Option<VnLayout>,
    cur_em: Option<MappingCfg>,
    last_df: Dataflow,
    pub stats: SimStats,
    /// Execute tiles through compiled [`WavePlan`]s (default). Disable to
    /// run the reference per-wave interpreter — kept for the bit-exactness
    /// tests and as the semantic ground truth.
    pub use_plans: bool,
    /// Plans compiled *by this simulator* (cache misses). Stays at zero when
    /// every tile hits a plan installed up-front via [`Self::seed_plans`] —
    /// the compile-once/serve-many invariant `crate::program` tests assert.
    /// Lives outside [`SimStats`] so plan-vs-reference stat equality holds.
    pub plan_compiles: u64,
    /// Plans compiled on demand, keyed by (θ_EM, θ_ES, layouts); reused
    /// across the M/K/N tile loops of a lowered program. Bounded by
    /// `PLAN_CACHE_CAP` with arbitrary eviction. Plans hold addressing
    /// only, no element data — they are shared across backends unchanged.
    plans: HashMap<PlanKey, Arc<WavePlan>>,
    /// Plans installed via [`Self::seed_plans`] (a compiled program's plan
    /// set). Kept apart from the dynamic cache so cap eviction can never
    /// silently un-compile a program — the compile-once invariant. Bounded
    /// by the caller: a program's plan set is small by construction.
    seeded: HashMap<PlanKey, Arc<WavePlan>>,
    /// Per-sim scratch arena for plan execution (§Perf): flat vectors sized
    /// to the high-water plan shape, reused across every tile invocation so
    /// the tile loops allocate nothing.
    scratch: PlanScratch<E>,
}

impl<E: Element> FunctionalSim<E> {
    pub fn new(cfg: &ArchConfig) -> Self {
        Self {
            streaming: DataBuffer::new(cfg.d_str(), cfg.aw),
            stationary: DataBuffer::new(cfg.d_sta(), cfg.aw),
            ob: OutputBuffer::new(cfg.d_ob(), cfg.aw),
            cfg: cfg.clone(),
            hbm: Vec::new(),
            hbm_top: 0,
            i_layout: None,
            w_layout: None,
            o_layout: None,
            cur_em: None,
            last_df: Dataflow::WoS,
            stats: SimStats::default(),
            use_plans: true,
            plan_compiles: 0,
            plans: HashMap::new(),
            seeded: HashMap::new(),
            scratch: PlanScratch::new(),
        }
    }

    /// Number of compiled plans currently resident (dynamic + seeded).
    pub fn plan_cache_len(&self) -> usize {
        self.plans.len() + self.seeded.len()
    }

    /// Install pre-compiled wave plans (e.g. a [`crate::program::Program`]'s
    /// compile-time plan set). Seeded plans live outside the capped dynamic
    /// cache, so its eviction can never drop them. Existing entries win, so
    /// seeding is idempotent and never invalidates plans already in use.
    pub fn seed_plans<I>(&mut self, plans: I)
    where
        I: IntoIterator<Item = (PlanKey, Arc<WavePlan>)>,
    {
        for (k, p) in plans {
            // A key compiled on demand before seeding moves to the seeded
            // tier: no double-resident plan, no double-counted cache entry.
            self.plans.remove(&k);
            self.seeded.entry(k).or_insert(p);
        }
    }

    /// Bump-allocate `words` of HBM; returns the word address.
    pub fn hbm_alloc(&mut self, words: usize) -> u64 {
        let addr = self.hbm_top;
        self.hbm_top += words;
        if self.hbm.len() < self.hbm_top {
            self.hbm.resize(self.hbm_top, E::zero());
        }
        addr as u64
    }

    pub fn hbm_write(&mut self, addr: u64, data: &[E]) {
        let a = addr as usize;
        if self.hbm.len() < a + data.len() {
            self.hbm.resize(a + data.len(), E::zero());
            self.hbm_top = self.hbm_top.max(a + data.len());
        }
        self.hbm[a..a + data.len()].copy_from_slice(data);
    }

    pub fn hbm_read(&self, addr: u64, len: usize) -> Result<&[E], SimError> {
        let a = addr as usize;
        if a + len > self.hbm.len() {
            return Err(SimError::HbmOutOfRange { addr, len });
        }
        Ok(&self.hbm[a..a + len])
    }

    fn buf_mut(&mut self, t: BufTarget) -> &mut DataBuffer<E> {
        match t {
            BufTarget::Streaming => &mut self.streaming,
            BufTarget::Stationary => &mut self.stationary,
        }
    }

    fn buf(&self, t: BufTarget) -> &DataBuffer<E> {
        match t {
            BufTarget::Streaming => &self.streaming,
            BufTarget::Stationary => &self.stationary,
        }
    }

    /// Execute one instruction.
    pub fn exec(&mut self, inst: &Inst) -> Result<(), SimError> {
        match inst {
            Inst::Load { target, hbm_addr, rows } => {
                self.stats.n_memory += 1;
                let aw = self.cfg.aw;
                let need = *rows as usize;
                let have = self.buf(*target).depth;
                if need > have {
                    return Err(SimError::BufferOverflow { buf: *target, need, have });
                }
                let words = need * aw;
                let data: Vec<E> = self.hbm_read(*hbm_addr, words)?.to_vec();
                let buf = self.buf_mut(*target);
                for (i, &v) in data.iter().enumerate() {
                    buf.set(i / aw, i % aw, v);
                }
                self.stats.load_words += words as u64;
                Ok(())
            }
            Inst::Store { target, hbm_addr, rows } => {
                self.stats.n_memory += 1;
                let aw = self.cfg.aw;
                let need = *rows as usize;
                let have = self.buf(*target).depth;
                if need > have {
                    return Err(SimError::BufferOverflow { buf: *target, need, have });
                }
                let mut out = vec![E::zero(); need * aw];
                {
                    let buf = self.buf(*target);
                    for (i, o) in out.iter_mut().enumerate() {
                        *o = buf.get(i / aw, i % aw);
                    }
                }
                self.hbm_write(*hbm_addr, &out);
                self.stats.store_words += out.len() as u64;
                Ok(())
            }
            Inst::Activation { func, target, rows } => {
                self.stats.n_activation += 1;
                let aw = self.cfg.aw;
                let need = (*rows as usize).min(self.buf(*target).depth);
                let buf = self.buf_mut(*target);
                for row in 0..need {
                    for col in 0..aw {
                        let v = buf.get(row, col);
                        buf.set(row, col, E::act(*func, v));
                    }
                }
                Ok(())
            }
            Inst::SetIVNLayout(l) => {
                self.stats.n_layout += 1;
                self.i_layout = Some(l.layout);
                Ok(())
            }
            Inst::SetWVNLayout(l) => {
                self.stats.n_layout += 1;
                self.w_layout = Some(l.layout);
                Ok(())
            }
            Inst::SetOVNLayout(l) => {
                self.stats.n_memory += 1;
                // Commit the finished tile to the next operand buffer
                // (§IV-G1): WO-S → stationary (feeding a subsequent IO-S
                // layer through the OB→StaB link), IO-S → streaming.
                if let Some(old) = self.o_layout {
                    self.commit_output(&old);
                }
                self.o_layout = Some(l.layout);
                self.ob.clear();
                Ok(())
            }
            Inst::ExecuteMapping(em) => {
                self.stats.n_execute += 1;
                em.validate(&self.cfg).map_err(SimError::Invalid)?;
                self.cur_em = Some(*em);
                Ok(())
            }
            Inst::ExecuteStreaming(es) => {
                self.stats.n_execute += 1;
                es.validate(&self.cfg).map_err(SimError::Invalid)?;
                let em = self.cur_em.ok_or(SimError::NoMapping)?;
                self.last_df = es.df;
                self.run_tile(&em, es)
            }
        }
    }

    pub fn exec_trace(&mut self, insts: &[Inst]) -> Result<(), SimError> {
        for i in insts {
            self.exec(i)?;
        }
        Ok(())
    }

    /// Commit OB → operand buffer at the same layout coordinates, narrowing
    /// each accumulator to the element domain with [`Element::reduce`]
    /// (saturation for `SatI32`, identity for fields/f32).
    fn commit_output(&mut self, layout: &VnLayout) {
        let aw = self.cfg.aw;
        let target = match self.last_df {
            Dataflow::WoS => BufTarget::Stationary,
            Dataflow::IoS => BufTarget::Streaming,
        };
        let mut writes: Vec<(usize, usize, Vec<E>)> = Vec::new();
        for l in 0..layout.vn_slots() {
            let (r, c) = layout.unflatten(l).expect("slot");
            let (row0, col) = ((l / aw) * layout.vn_size, l % aw);
            if row0 + layout.vn_size > self.ob.depth {
                continue;
            }
            let vals: Vec<E> = (0..layout.vn_size)
                .map(|i| E::reduce(self.ob.get(row0 + i, col)))
                .collect();
            writes.push((r, c, vals));
        }
        for (r, c, vals) in writes {
            self.buf_mut(target).write_vn(layout, r, c, &vals);
        }
    }

    /// One compute tile: Eq. (1) placement + streaming + reduction.
    ///
    /// Hot path: look up (or compile) the [`WavePlan`] for this
    /// (θ_EM, θ_ES, layouts) tuple and interpret it — all address
    /// translation, BIRRD merge grouping and OB conflict accounting were
    /// resolved at compile time, once, instead of once per wave.
    fn run_tile(&mut self, em: &MappingCfg, es: &StreamCfg) -> Result<(), SimError> {
        if !self.use_plans {
            return self.run_tile_reference(em, es);
        }
        let Some(plan) = self.resolve_plan(em, es)? else {
            return self.run_tile_reference(em, es);
        };
        plan.execute(
            &mut self.scratch,
            &self.streaming,
            &self.stationary,
            &mut self.ob,
            &mut self.stats,
        )
    }

    /// Resolve (seed-lookup / cache / compile) the [`WavePlan`] for one ES
    /// invocation. `Ok(None)` marks the illegal-program layout class that
    /// must run through the reference interpreter instead (see below).
    /// Shared by [`Self::run_tile`] and the blocked path ([`BlockSim`],
    /// which resolves once on its first lane for the whole row block).
    fn resolve_plan(
        &mut self,
        em: &MappingCfg,
        es: &StreamCfg,
    ) -> Result<Option<Arc<WavePlan>>, SimError> {
        // Layout resolution order matches the reference (stationary, then
        // streamed, then output) so `NoLayout` errors are identical.
        let (sta_layout, str_layout) = match es.df {
            Dataflow::WoS => (
                self.w_layout.ok_or(SimError::NoLayout("WVN"))?,
                self.i_layout.ok_or(SimError::NoLayout("IVN"))?,
            ),
            Dataflow::IoS => (
                self.i_layout.ok_or(SimError::NoLayout("IVN"))?,
                self.w_layout.ok_or(SimError::NoLayout("WVN"))?,
            ),
        };
        let o_layout = self.o_layout.ok_or(SimError::NoLayout("OVN"))?;
        // Pathological mismatch (stationary layout VNs shorter than the
        // invocation's VN size) panics in the reference register fill; the
        // compiled fill would over-read instead. Delegate to the reference
        // so behavior stays bit-identical for this illegal-program class.
        if sta_layout.vn_size < es.vn_size {
            return Ok(None);
        }
        let key = PlanKey { em: *em, es: *es, sta_layout, str_layout, o_layout };
        let plan = match self.seeded.get(&key).or_else(|| self.plans.get(&key)) {
            Some(p) => Arc::clone(p),
            None => {
                if self.plans.len() >= PLAN_CACHE_CAP {
                    // Evict one arbitrary entry: keeps the memory bound
                    // without the recompile-everything thrash a full clear
                    // would cause for working sets just over the cap.
                    if let Some(k) = self.plans.keys().next().copied() {
                        self.plans.remove(&k);
                    }
                }
                let p = Arc::new(WavePlan::compile(
                    &self.cfg,
                    em,
                    es,
                    &sta_layout,
                    &str_layout,
                    &o_layout,
                    self.stationary.depth,
                    self.streaming.depth,
                    self.ob.depth,
                ));
                self.plan_compiles += 1;
                self.plans.insert(key, Arc::clone(&p));
                p
            }
        };
        Ok(Some(plan))
    }

    /// Reference per-wave interpreter (the seed semantics): re-derives
    /// placement and addressing every wave. Kept as the ground truth the
    /// compiled path is tested against (`tests/plan_equivalence.rs`).
    fn run_tile_reference(&mut self, em: &MappingCfg, es: &StreamCfg) -> Result<(), SimError> {
        let cfg = self.cfg.clone();
        let vn = es.vn_size;
        let active_rows = vn.min(cfg.ah);
        let (sta_layout, str_layout) = match es.df {
            // WO-S: weights stationary, inputs stream.
            Dataflow::WoS => (
                self.w_layout.ok_or(SimError::NoLayout("WVN"))?,
                self.i_layout.ok_or(SimError::NoLayout("IVN"))?,
            ),
            // IO-S: inputs stationary, weights stream.
            Dataflow::IoS => (
                self.i_layout.ok_or(SimError::NoLayout("IVN"))?,
                self.w_layout.ok_or(SimError::NoLayout("WVN"))?,
            ),
        };
        let o_layout = self.o_layout.ok_or(SimError::NoLayout("OVN"))?;
        let (sta_buf, str_buf) = match es.df {
            Dataflow::WoS => (BufTarget::Stationary, BufTarget::Streaming),
            Dataflow::IoS => (BufTarget::Stationary, BufTarget::Streaming),
        };
        // Note: physically the stationary operand always lives in the
        // stationary buffer and the streamed one in the streaming buffer;
        // the dataflow bit decides which *tensor* was loaded where.
        // Load the stationary tile into PE local registers once per
        // invocation (the NEST double-buffered register fill; also the
        // §Perf optimization that removes T redundant buffer reads per PE).
        // reg_valid[a_h·AW + a_w] marks PEs with in-bounds stationary VNs;
        // regs holds their vn elements contiguously.
        let mut regs: Vec<E> = vec![E::zero(); active_rows * cfg.aw * vn];
        let mut reg_meta: Vec<Option<usize>> = vec![None; active_rows * cfg.aw]; // c index
        {
            let mut tmp: Vec<E> = Vec::with_capacity(vn);
            for a_w in 0..cfg.aw {
                for a_h in 0..active_rows {
                    let (r, c) = em.stationary_vn(a_h, a_w);
                    if self.buf(sta_buf).read_vn_into(&sta_layout, r, c, &mut tmp) {
                        let base = (a_h * cfg.aw + a_w) * vn;
                        regs[base..base + vn].copy_from_slice(&tmp[..vn]);
                        reg_meta[a_h * cfg.aw + a_w] = Some(c);
                    }
                }
            }
        }
        // Scratch buffers reused across the wave loop (no per-read
        // allocation on the hot path — §Perf).
        let mut streamed: Vec<E> = Vec::with_capacity(vn);
        let mut wave: Vec<(usize, usize, E::Acc, (usize, usize))> =
            Vec::with_capacity(cfg.aw * active_rows);
        for t in 0..es.t {
            self.stats.waves += 1;
            self.stats.macs_possible += (cfg.ah * cfg.aw * vn) as u64;
            // Gather this wave's psums: (ob_row, bank, value, (m, n)).
            wave.clear();
            for a_w in 0..cfg.aw {
                let (m, j) = es.streamed_vn(em, a_w, t);
                if !self.buf(str_buf).read_vn_into(&str_layout, j, m, &mut streamed) {
                    continue; // zero-padded streamed VN: contributes 0
                }
                for a_h in 0..active_rows {
                    let Some(c) = reg_meta[a_h * cfg.aw + a_w] else {
                        continue; // zero-padded stationary VN
                    };
                    debug_assert_eq!(em.stationary_vn(a_h, a_w).0, j, "reduction consistency");
                    let base = (a_h * cfg.aw + a_w) * vn;
                    let stationary = &regs[base..base + vn];
                    let mut psum = E::acc_zero();
                    for (&a, &b) in streamed.iter().take(vn).zip(stationary.iter()) {
                        psum = E::mac(psum, a, b);
                    }
                    self.stats.macs_used += vn as u64;
                    // Output element (p, q): row index from the streamed
                    // operand, column index from the stationary one. Under
                    // WO-S that is (m, c); under IO-S roles transpose to
                    // (c, m) in GEMM space.
                    let (p, q) = match es.df {
                        Dataflow::WoS => (m, c),
                        Dataflow::IoS => (c, m),
                    };
                    // OVN coordinates: reduction rank of O is q (next
                    // layer's J); r_o = q / vn, c_o = p, offset q mod vn.
                    let (r_o, off, c_o) = (q / o_layout.vn_size, q % o_layout.vn_size, p);
                    match o_layout.addr(r_o, c_o, cfg.aw) {
                        Some((row0, bank)) => {
                            let row = row0 + off;
                            if row >= self.ob.depth {
                                return Err(SimError::ObOverflow {
                                    row,
                                    depth: self.ob.depth,
                                });
                            }
                            wave.push((row, bank, psum, (p, q)));
                        }
                        None => {
                            if !E::acc_is_zero(psum) {
                                return Err(SimError::OrphanPsum { m: p, n: q });
                            }
                        }
                    }
                }
            }
            // BIRRD spatial reduction: psums sharing an OB slot merge
            // in-network before the banked write.
            wave.sort_unstable_by_key(|w| (w.0, w.1));
            let mut writes: Vec<(usize, usize, E::Acc)> = Vec::new();
            for w in &wave {
                match writes.last_mut() {
                    Some(last) if last.0 == w.0 && last.1 == w.1 => {
                        last.2 = E::acc_add(last.2, w.2);
                        self.stats.birrd_adds += 1;
                    }
                    _ => writes.push((w.0, w.1, w.2)),
                }
            }
            let before = self.ob.conflicts;
            self.ob.accumulate_group(&writes);
            self.stats.ob_conflicts += self.ob.conflicts - before;
        }
        Ok(())
    }

    /// Read output element (p, q) of the current OVN layout from the OB.
    pub fn output_element(&self, p: usize, q: usize) -> Option<E::Acc> {
        let l = self.o_layout?;
        let (r_o, off, c_o) = (q / l.vn_size, q % l.vn_size, p);
        let (row0, bank) = l.addr(r_o, c_o, self.cfg.aw)?;
        let row = row0 + off;
        if row >= self.ob.depth {
            return None;
        }
        Some(self.ob.get(row, bank))
    }

    /// Extract the full `p_extent × q_extent` output tile.
    pub fn read_output_tile(&self, p_extent: usize, q_extent: usize) -> Option<Vec<E::Acc>> {
        let mut out = vec![E::acc_zero(); p_extent * q_extent];
        for p in 0..p_extent {
            for q in 0..q_extent {
                out[p * q_extent + q] = self.output_element(p, q)?;
            }
        }
        Some(out)
    }

    /// Peek a buffer word (tests / GUI trace dump).
    pub fn peek(&self, t: BufTarget, row: usize, col: usize) -> E {
        self.buf(t).get(row, col)
    }
}

/// Narrow an i64 accumulator to the i32 element width, saturating.
///
/// The contract lives in [`Element::reduce`] now (`<i32 as Element>::reduce`
/// is this exact function); this shim remains for pre-`arith` call sites and
/// is asserted equivalent by a unit test below.
#[deprecated(note = "use `<i32 as crate::arith::Element>::reduce` — the \
                     OB-commit narrowing contract moved into the Element trait")]
pub fn clamp_acc(v: i64) -> i32 {
    <i32 as Element>::reduce(v)
}

/// Reference GEMM: `O[M,N] = I[M,K]·W[K,N]` over i32 operands, i64 psums.
/// (The generic form for other element backends is
/// [`crate::arith::naive_gemm_e`]; this delegates to it.)
pub fn naive_gemm(i: &[i32], w: &[i32], m: usize, k: usize, n: usize) -> Vec<i64> {
    crate::arith::naive_gemm_e::<i32>(i, w, m, k, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::inst::{ActFn, LayoutInst};
    use crate::util::Lcg;

    fn cfg() -> ArchConfig {
        ArchConfig::paper(4, 4)
    }

    /// Hand-built single-tile program: 4×4 NEST computes an (M=4, K=4, N=4)
    /// GEMM in one invocation — W_VNs distinct per column (Fig. 4 case 3),
    /// all I_VNs streamed with s_m = 1. Generic over the element backend so
    /// the arith property tests reuse it.
    fn single_tile_program<E: Element>(
        sim: &mut FunctionalSim<E>,
        iv: &[E],
        wv: &[E],
        m: usize,
        k: usize,
        n: usize,
    ) -> Vec<Inst> {
        let c = cfg();
        let vn = 4;
        let gi = crate::arch::vn::VnGrid::new(k, m, vn);
        let gw = crate::arch::vn::VnGrid::new(k, n, vn);
        let i_lay = VnLayout::row_major(gi.rows(), m, vn);
        let w_lay = VnLayout::row_major(gw.rows(), n, vn);
        let o_lay = VnLayout::row_major(crate::util::ceil_div(n, vn), m, vn);
        let i_img = pack_image(&i_lay, c.aw, |r, cc| gi.gather_input(iv, r, cc));
        let w_img = pack_image(&w_lay, c.aw, |r, cc| gw.gather_weight(wv, r, cc));
        let ia = sim.hbm_alloc(i_img.len());
        sim.hbm_write(ia, &i_img);
        let wa = sim.hbm_alloc(w_img.len());
        sim.hbm_write(wa, &w_img);
        vec![
            Inst::Load {
                target: BufTarget::Streaming,
                hbm_addr: ia,
                rows: i_lay.rows_needed(c.aw) as u32,
            },
            Inst::Load {
                target: BufTarget::Stationary,
                hbm_addr: wa,
                rows: w_lay.rows_needed(c.aw) as u32,
            },
            Inst::SetIVNLayout(LayoutInst { layout: i_lay }),
            Inst::SetWVNLayout(LayoutInst { layout: w_lay }),
            Inst::SetOVNLayout(LayoutInst { layout: o_lay }),
            // One column per n (distinct W_VN columns): G_r=AW, G_c=AW,
            // s_r=1? No: each PE row a_h takes c = c0 + s_r·a_h. With
            // s_r=1 and s_c=4... For K=4 (one reduction tile), we want
            // column a_w to hold W_VNs c = a_w·? — here N=4 ≤ AH so place
            // W_VN(0, a_h) replicated across columns (Fig. 4 case 1) and
            // split the I stream across columns.
            Inst::ExecuteMapping(MappingCfg { r0: 0, c0: 0, g_r: 4, g_c: 1, s_r: 1, s_c: 0 }),
            Inst::ExecuteStreaming(StreamCfg {
                df: Dataflow::WoS,
                m0: 0,
                s_m: 4,
                t: crate::util::ceil_div(m, 4).max(1),
                vn_size: vn,
            }),
        ]
    }

    #[test]
    fn single_tile_gemm_matches_naive() {
        let (m, k, n) = (4usize, 4usize, 4usize);
        let mut rng = Lcg::new(1);
        let iv: Vec<i32> = (0..m * k).map(|_| rng.range(0, 16) as i32 - 8).collect();
        let wv: Vec<i32> = (0..k * n).map(|_| rng.range(0, 16) as i32 - 8).collect();
        let c = cfg();
        let mut sim = FunctionalSim::new(&c);
        let prog = single_tile_program(&mut sim, &iv, &wv, m, k, n);
        sim.exec_trace(&prog).unwrap();
        let got = sim.read_output_tile(m, n).unwrap();
        let expect = naive_gemm(&iv, &wv, m, k, n);
        assert_eq!(got, expect);
        // Full utilization for an exactly-fitting tile.
        assert!(sim.stats.utilization() > 0.99, "util {}", sim.stats.utilization());
    }

    /// The same hand-built tile over a prime field matches the generic
    /// naive reference — the smallest end-to-end witness that trace
    /// execution is field-exact.
    #[test]
    fn single_tile_gemm_exact_over_goldilocks() {
        use crate::arith::{naive_gemm_e, Goldilocks, ModP};
        type G = ModP<Goldilocks>;
        let (m, k, n) = (4usize, 4usize, 4usize);
        let mut rng = Lcg::new(21);
        let iv: Vec<G> = (0..m * k).map(|_| G::new(rng.next_u64())).collect();
        let wv: Vec<G> = (0..k * n).map(|_| G::new(rng.next_u64())).collect();
        let c = cfg();
        let mut sim: FunctionalSim<G> = FunctionalSim::new(&c);
        let prog = single_tile_program(&mut sim, &iv, &wv, m, k, n);
        sim.exec_trace(&prog).unwrap();
        assert_eq!(sim.read_output_tile(m, n).unwrap(), naive_gemm_e::<G>(&iv, &wv, m, k, n));
    }

    #[test]
    fn padded_tile_zero_padding_is_exact() {
        // K=3 (not a multiple of VN), N=3, M=2: padding paths must yield
        // exact results.
        let (m, k, n) = (2usize, 3usize, 3usize);
        let mut rng = Lcg::new(2);
        let iv: Vec<i32> = (0..m * k).map(|_| rng.range(0, 8) as i32 - 4).collect();
        let wv: Vec<i32> = (0..k * n).map(|_| rng.range(0, 8) as i32 - 4).collect();
        let c = cfg();
        let mut sim = FunctionalSim::new(&c);
        let prog = single_tile_program(&mut sim, &iv, &wv, m, k, n);
        sim.exec_trace(&prog).unwrap();
        let got = sim.read_output_tile(m, n).unwrap();
        assert_eq!(got, naive_gemm(&iv, &wv, m, k, n));
        assert!(sim.stats.utilization() < 0.99); // padding wastes slots
    }

    #[test]
    fn streaming_without_mapping_errors() {
        let c = cfg();
        let mut sim: FunctionalSim = FunctionalSim::new(&c);
        let es = Inst::ExecuteStreaming(StreamCfg {
            df: Dataflow::WoS,
            m0: 0,
            s_m: 1,
            t: 1,
            vn_size: 4,
        });
        assert_eq!(sim.exec(&es), Err(SimError::NoMapping));
    }

    #[test]
    fn execute_without_layouts_errors() {
        let c = cfg();
        let mut sim: FunctionalSim = FunctionalSim::new(&c);
        sim.exec(&Inst::ExecuteMapping(MappingCfg {
            r0: 0,
            c0: 0,
            g_r: 1,
            g_c: 1,
            s_r: 0,
            s_c: 0,
        }))
        .unwrap();
        let es = Inst::ExecuteStreaming(StreamCfg {
            df: Dataflow::WoS,
            m0: 0,
            s_m: 1,
            t: 1,
            vn_size: 4,
        });
        assert!(matches!(sim.exec(&es), Err(SimError::NoLayout(_))));
    }

    #[test]
    fn load_overflow_detected() {
        let c = cfg();
        let mut sim: FunctionalSim = FunctionalSim::new(&c);
        let a = sim.hbm_alloc(16);
        let too_many = (c.d_str() + 1) as u32;
        let r = sim.exec(&Inst::Load { target: BufTarget::Streaming, hbm_addr: a, rows: too_many });
        assert!(matches!(r, Err(SimError::BufferOverflow { .. })));
    }

    #[test]
    fn hbm_out_of_range_detected() {
        let c = cfg();
        let mut sim: FunctionalSim = FunctionalSim::new(&c);
        let r = sim.exec(&Inst::Load { target: BufTarget::Streaming, hbm_addr: 10_000, rows: 1 });
        assert!(matches!(r, Err(SimError::HbmOutOfRange { .. })));
    }

    #[test]
    fn store_roundtrips_buffer() {
        let c = cfg();
        let mut sim: FunctionalSim = FunctionalSim::new(&c);
        let data: Vec<i32> = (0..8).collect();
        let a = sim.hbm_alloc(8);
        sim.hbm_write(a, &data);
        sim.exec(&Inst::Load { target: BufTarget::Streaming, hbm_addr: a, rows: 2 }).unwrap();
        let b = sim.hbm_alloc(8);
        sim.exec(&Inst::Store { target: BufTarget::Streaming, hbm_addr: b, rows: 2 }).unwrap();
        assert_eq!(sim.hbm_read(b, 8).unwrap(), &data[..]);
    }

    #[test]
    fn relu_activation_applies() {
        let c = cfg();
        let mut sim: FunctionalSim = FunctionalSim::new(&c);
        let a = sim.hbm_alloc(4);
        sim.hbm_write(a, &[-5, 3, -1, 0]);
        sim.exec(&Inst::Load { target: BufTarget::Streaming, hbm_addr: a, rows: 1 }).unwrap();
        sim.exec(&Inst::Activation { func: ActFn::Relu, target: BufTarget::Streaming, rows: 1 })
            .unwrap();
        assert_eq!(
            (0..4).map(|i| sim.peek(BufTarget::Streaming, 0, i)).collect::<Vec<_>>(),
            vec![0, 3, 0, 0]
        );
    }

    #[test]
    fn plan_and_reference_paths_agree() {
        // The compiled-plan default path and the reference per-wave
        // interpreter must be bit-identical: outputs and SimStats.
        let (m, k, n) = (4usize, 4usize, 4usize);
        let mut rng = Lcg::new(7);
        let iv: Vec<i32> = (0..m * k).map(|_| rng.range(0, 16) as i32 - 8).collect();
        let wv: Vec<i32> = (0..k * n).map(|_| rng.range(0, 16) as i32 - 8).collect();
        let c = cfg();
        let mut fast = FunctionalSim::new(&c);
        let prog = single_tile_program(&mut fast, &iv, &wv, m, k, n);
        fast.exec_trace(&prog).unwrap();
        let mut slow = FunctionalSim::new(&c);
        slow.use_plans = false;
        let prog = single_tile_program(&mut slow, &iv, &wv, m, k, n);
        slow.exec_trace(&prog).unwrap();
        assert_eq!(fast.read_output_tile(m, n), slow.read_output_tile(m, n));
        assert_eq!(fast.stats, slow.stats);
        assert_eq!(fast.plan_cache_len(), 1);
        assert_eq!(slow.plan_cache_len(), 0);
    }

    #[test]
    fn naive_gemm_identity() {
        // I = identity → O == W.
        let m = 3;
        let k = 3;
        let n = 2;
        let mut i = vec![0i32; m * k];
        for d in 0..3 {
            i[d * k + d] = 1;
        }
        let w: Vec<i32> = (1..=6).collect();
        let o = naive_gemm(&i, &w, m, k, n);
        assert_eq!(o, w.iter().map(|&x| x as i64).collect::<Vec<_>>());
    }

    /// The deprecated `clamp_acc` shim and `<i32 as Element>::reduce` are
    /// the same function — the doc-drift satellite's equivalence guarantee.
    #[test]
    #[allow(deprecated)]
    fn clamp_acc_shim_equals_element_reduce() {
        let mut rng = Lcg::new(13);
        let mut probes: Vec<i64> = vec![
            0,
            1,
            -1,
            i32::MAX as i64,
            i32::MIN as i64,
            i32::MAX as i64 + 1,
            i32::MIN as i64 - 1,
            i64::MAX,
            i64::MIN,
        ];
        probes.extend((0..1000).map(|_| rng.next_u64() as i64));
        for v in probes {
            assert_eq!(clamp_acc(v), <i32 as Element>::reduce(v), "v = {v}");
        }
    }
}
