//! Cache-blocked multi-row execution — [`BlockSim`] drives a block of
//! [`FunctionalSim`] lanes through one shared instruction trace (§Perf
//! tentpole).
//!
//! The serving executors chunk a request's rows into compiled-height
//! batches and used to replay the program once per chunk: every chunk
//! re-walked the same wave plans, re-filled the same stationary registers
//! and re-interpreted the same op arrays, touching the plan's control
//! arrays O(chunks) times. `BlockSim` holds up to [`DEFAULT_ROW_BLOCK`]
//! independent simulator lanes — one per chunk — and executes each
//! `ExecuteStreaming` tile through [`WavePlan::execute_rows`], which walks
//! the op/slot arrays **once** and applies each op across all lanes. The
//! plan's control data then stays hot in L1 while only the lanes' operand
//! data streams, and the per-op inner products become a lane batch the
//! backend kernels ([`crate::arith::Element::dot`]) chew through
//! back-to-back.
//!
//! Bit-exactness contract: executing a trace across `n` lanes is
//! lane-for-lane bit-identical — outputs, OB state and `SimStats` — to
//! executing it on `n` independent `FunctionalSim`s sequentially
//! (`tests/plan_equivalence.rs` proves it across every element backend).
//! The one legal divergence is *abort schedules*: if an instruction
//! errors, all lanes have advanced in lockstep to the failing instruction,
//! whereas the sequential schedule would never have started later chunks —
//! the call errors identically in both cases and no outputs are produced.

// Hot-file lint escalation (§Perf CI satellite) — see plan.rs.
#![deny(clippy::needless_range_loop, clippy::manual_memcpy)]

use crate::arch::config::ArchConfig;
use crate::arith::Element;
use crate::isa::inst::Inst;

use super::plan::PlanScratch;
use super::{FunctionalSim, SimError, SimStats, WavePlan};

/// Default lane count per block. Sized for L1: a lane's hot working set is
/// one register file + one streamed VN + its slot accumulators (roughly
/// `regs_len + dot_len + max_slots` elements ≈ a few hundred bytes for
/// paper-scale 4×4..8×8 configs), so 8 lanes of operand data plus the
/// shared plan arrays sit comfortably in a 32 KiB L1D while giving the
/// per-op lane loop enough width to amortize control overhead and keep
/// SIMD units fed. Re-tune with [`BlockSim::with_block`] + the
/// `funcsim blocked` cases of `benches/hotpath.rs` (docs/PERF.md).
pub const DEFAULT_ROW_BLOCK: usize = 8;

/// A block of [`FunctionalSim`] lanes executing one instruction trace in
/// lockstep. Lanes are created lazily ([`Self::ensure_lanes`]) and reused
/// across calls — a persistent `BlockSim` (e.g. per fleet device) keeps
/// every lane's seeded plan cache and scratch arena warm across requests.
#[derive(Debug, Clone)]
pub struct BlockSim<E: Element> {
    cfg: ArchConfig,
    lanes: Vec<FunctionalSim<E>>,
    /// Shared multi-lane scratch arena for [`WavePlan::execute_rows`].
    scratch: PlanScratch<E>,
    block: usize,
}

impl<E: Element> BlockSim<E> {
    pub fn new(cfg: &ArchConfig) -> Self {
        Self::with_block(cfg, DEFAULT_ROW_BLOCK)
    }

    /// A block simulator with a non-default lane budget (perf tuning; 0 is
    /// clamped to 1).
    pub fn with_block(cfg: &ArchConfig, block: usize) -> Self {
        Self {
            cfg: cfg.clone(),
            lanes: Vec::new(),
            scratch: PlanScratch::new(),
            block: block.max(1),
        }
    }

    /// Maximum lanes callers should batch per [`Self::exec`] round.
    pub fn block(&self) -> usize {
        self.block
    }

    pub fn cfg(&self) -> &ArchConfig {
        &self.cfg
    }

    /// Lanes materialized so far (high-water mark of requested widths).
    pub fn lane_count(&self) -> usize {
        self.lanes.len()
    }

    /// Materialize at least `n` lanes. Existing lanes (and their seeded
    /// plan caches) are kept; a single-chunk request never pays for a full
    /// block.
    pub fn ensure_lanes(&mut self, n: usize) {
        while self.lanes.len() < n {
            self.lanes.push(FunctionalSim::new(&self.cfg));
        }
    }

    /// Mutable access to the first `n` lanes (staging HBM images, seeding
    /// plans), materializing them as needed.
    pub fn lanes_mut(&mut self, n: usize) -> &mut [FunctionalSim<E>] {
        self.ensure_lanes(n);
        &mut self.lanes[..n]
    }

    /// Lane `i` (harvesting outputs). Panics if the lane was never
    /// materialized.
    pub fn lane(&self, i: usize) -> &FunctionalSim<E> {
        &self.lanes[i]
    }

    /// Runtime plan compiles summed over lanes. A seeded program keeps
    /// this at zero — the compile-once invariant carries through the
    /// blocked path. (Unseeded traces compile once per *block* on the
    /// first lane, vs once per chunk sequentially: never more.)
    pub fn plan_compiles(&self) -> u64 {
        self.lanes.iter().map(|l| l.plan_compiles).sum()
    }

    /// NEST waves issued so far, summed over lanes. The fleet's telemetry
    /// reads this before/after each execution to charge the wave delta to
    /// the owning device (`DeviceStats::waves`) — cheaper than a full
    /// [`Self::stats`] roll-up on that per-dispatch path.
    pub fn waves(&self) -> u64 {
        self.lanes.iter().map(|l| l.stats.waves).sum()
    }

    /// Execution statistics summed over all lanes — equals the stats a
    /// single sequential simulator would accumulate over the same chunks.
    pub fn stats(&self) -> SimStats {
        let mut total = SimStats::default();
        for l in &self.lanes {
            total.absorb(&l.stats);
        }
        total
    }

    /// Execute one instruction across the first `n` lanes. Non-ES
    /// instructions run per lane (their work is identical per lane except
    /// for operand values); `ExecuteStreaming` tiles go through the
    /// blocked kernel: the wave plan is resolved once on lane 0 (all lanes
    /// executed the same trace, so their addressing state is identical)
    /// and [`WavePlan::execute_rows`] applies it across the block.
    pub fn exec(&mut self, inst: &Inst, n: usize) -> Result<(), SimError> {
        self.ensure_lanes(n);
        let lanes = &mut self.lanes[..n];
        let Inst::ExecuteStreaming(es) = inst else {
            for sim in lanes.iter_mut() {
                sim.exec(inst)?;
            }
            return Ok(());
        };
        // Mirror `FunctionalSim::exec`'s ES arm per lane: stats bump, then
        // validation, then the mapping lookup — so error kinds and the
        // stats already accumulated when an error fires match the scalar
        // path exactly.
        let mut em = None;
        for sim in lanes.iter_mut() {
            sim.stats.n_execute += 1;
            es.validate(&sim.cfg).map_err(SimError::Invalid)?;
            em = Some(sim.cur_em.ok_or(SimError::NoMapping)?);
            sim.last_df = es.df;
        }
        let Some(em) = em else {
            return Ok(()); // n == 0: nothing to execute
        };
        if !lanes[0].use_plans {
            for sim in lanes.iter_mut() {
                sim.run_tile_reference(&em, es)?;
            }
            return Ok(());
        }
        let plan: Option<std::sync::Arc<WavePlan>> = lanes[0].resolve_plan(&em, es)?;
        let Some(plan) = plan else {
            // Pathological layout class (see `FunctionalSim::resolve_plan`):
            // reference interpreter per lane, exactly like the scalar path.
            for sim in lanes.iter_mut() {
                sim.run_tile_reference(&em, es)?;
            }
            return Ok(());
        };
        plan.execute_rows(lanes, &mut self.scratch)
    }

    /// Execute a whole trace across the first `n` lanes.
    pub fn exec_trace(&mut self, insts: &[Inst], n: usize) -> Result<(), SimError> {
        for i in insts {
            self.exec(i, n)?;
        }
        Ok(())
    }
}
