//! Compiled wave plans — the functional simulator's hot-path compiler
//! (§Perf; the software mirror of the paper's control-elimination story).
//!
//! MINISA's headline is that per-wave control work disappears from the
//! hardware hot path: one `ExecuteMapping`/`ExecuteStreaming` pair triggers
//! `T` waves with zero instruction fetches. The seed simulator nevertheless
//! *re-derived* all of that control state in software on every wave: Eq.-(1)
//! placement, streamed-VN address translation through `VnLayout::flatten`,
//! output-VN addressing, a per-wave `sort_unstable_by_key` to group BIRRD
//! merges, and per-wave `Vec` allocations in `accumulate_group`.
//!
//! A [`WavePlan`] compiles all of that **once** per
//! (θ_EM, θ_ES, streamed/stationary/output layout) tuple into flat arrays:
//!
//! * `reg_fills` — stationary-register loads: (PE register base, buffer
//!   word offset) pairs, resolved through the stationary layout;
//! * per wave, a list of column groups carrying the streamed-VN source
//!   word offset;
//! * per (column, PE-row) op, the stationary register base and the
//!   *pre-merged* OB destination slot (or the orphan/overflow outcome);
//! * per wave, the merged OB slot list in BIRRD order plus precomputed
//!   `birrd_adds` / bank-conflict counts (both are data-independent).
//!
//! Executing a plan is then a tight interpreter: contiguous-slice dot
//! products into per-slot accumulators, one bucketed OB flush per wave — no
//! layout math, no sorting, no allocation on the wave loop. Plans are cached
//! in the simulator keyed by the config tuple, so the M/K/N tile loops of a
//! lowered program (`mapper::exec`) compile each distinct invocation shape
//! exactly once.
//!
//! Bit-exactness contract: `WavePlan::execute` reproduces the reference
//! per-wave interpreter (`FunctionalSim::run_tile` with `use_plans = false`)
//! exactly — identical outputs, identical `SimStats` (including partial
//! `macs_used` counts on error paths) and identical `SimError` values raised
//! at the same (wave, column, row) position, and [`WavePlan::execute_rows`]
//! reproduces per-lane `execute` exactly (docs/PERF.md). This holds because
//! both paths share one per-op kernel, [`Element::dot`], whose backend
//! overrides are individually proven bit-identical to the sequential `mac`
//! fold. `tests/plan_equivalence.rs` and the unit tests below enforce it.

// Hot-file lint escalation (§Perf CI satellite): the wave loop must never
// regress into index-by-range iteration or element-wise copies that LLVM
// won't vectorize.
#![deny(clippy::needless_range_loop, clippy::manual_memcpy)]

use crate::arch::buffer::{DataBuffer, OutputBuffer};
use crate::arch::config::ArchConfig;
use crate::arith::Element;
use crate::layout::VnLayout;
use crate::mapping::{Dataflow, MappingCfg, StreamCfg};

use super::{FunctionalSim, SimError, SimStats};

/// Cache key: everything a plan's addressing depends on. Buffer geometry
/// (depths, width) is fixed per simulator, so it stays out of the key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub em: MappingCfg,
    pub es: StreamCfg,
    pub sta_layout: VnLayout,
    pub str_layout: VnLayout,
    pub o_layout: VnLayout,
}

/// One stationary-register load: copy `vn` elements from the stationary
/// buffer (word offset `src`, row stride = buffer width) into `regs[dst..]`.
#[derive(Debug, Clone, Copy)]
struct RegFill {
    dst: u32,
    src: u32,
}

/// What happens to one (column, PE-row) psum.
#[derive(Debug, Clone, Copy)]
enum OpKind {
    /// Accumulate into the wave-local merged slot with this index.
    Slot(u32),
    /// Outside the OVN layout: legal only while the psum stays zero.
    Orphan { p: u32, q: u32 },
    /// Mapped beyond OB depth: always an error when reached.
    Overflow { row: u32 },
}

/// One PE-row's work within a column group.
#[derive(Debug, Clone, Copy)]
struct Op {
    /// Base index into the stationary register file.
    reg_base: u32,
    kind: OpKind,
}

/// One streamed-VN gather plus the contiguous run of ops consuming it.
#[derive(Debug, Clone, Copy)]
struct ColGroup {
    /// Word offset of the streamed VN's first element (row stride = width).
    str_src: u32,
    op_start: u32,
    op_end: u32,
}

/// One wave's slice of the flat arrays plus its precomputed statistics.
#[derive(Debug, Clone, Copy)]
struct Wave {
    cg_start: u32,
    cg_end: u32,
    slot_start: u32,
    slot_end: u32,
    /// In-network pairwise additions (merged psums − distinct slots).
    birrd_adds: u32,
    /// OB bank conflicts of the merged write group.
    ob_conflicts: u32,
}

/// A merged OB destination, ordered the way the reference sorts psums.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct Slot {
    row: u32,
    bank: u32,
}

/// A fully compiled invocation: `T` waves of pre-resolved work.
#[derive(Debug, Clone)]
pub struct WavePlan {
    /// VN size of the invocation (stationary register elements per PE).
    vn: usize,
    /// Dot-product length actually used: `vn.min(str_layout.vn_size)`
    /// (the reference zips the streamed VN against the first `vn` register
    /// elements, truncating to the shorter side).
    dot_len: usize,
    macs_possible_per_wave: u64,
    reg_fills: Vec<RegFill>,
    /// Register file size: `active_rows · AW · vn` elements.
    regs_len: usize,
    waves: Vec<Wave>,
    col_groups: Vec<ColGroup>,
    ops: Vec<Op>,
    slots: Vec<Slot>,
    /// Largest per-wave slot count (sizes the accumulator scratch).
    max_slots: usize,
    /// Every op lands in a merged OB slot — no Orphan/Overflow outcomes
    /// anywhere in the plan. Such a plan can never raise a `SimError`, so
    /// the blocked multi-lane path needs no early-exit or partial-stats
    /// bookkeeping; plans with hazards run per lane through the scalar
    /// interpreter instead (see [`Self::execute_rows`]).
    pub(super) hazard_free: bool,
}

impl WavePlan {
    /// Resolve every wave of (θ_EM, θ_ES) against the three layouts and the
    /// buffer geometry. Pure control-plane work: no operand data involved.
    #[allow(clippy::too_many_arguments)]
    pub fn compile(
        cfg: &ArchConfig,
        em: &MappingCfg,
        es: &StreamCfg,
        sta_layout: &VnLayout,
        str_layout: &VnLayout,
        o_layout: &VnLayout,
        sta_depth: usize,
        str_depth: usize,
        ob_depth: usize,
    ) -> Self {
        let (ah, aw) = (cfg.ah, cfg.aw);
        let vn = es.vn_size;
        let active_rows = vn.min(ah);

        // Stationary register placement (the once-per-invocation NEST fill).
        // reg_c[a_h·AW + a_w] records the VN column index for PEs holding an
        // in-bounds stationary VN; the reference gathers in (a_w, a_h) order.
        let mut reg_fills = Vec::new();
        let mut reg_c: Vec<Option<usize>> = vec![None; active_rows * aw];
        for a_w in 0..aw {
            for a_h in 0..active_rows {
                let (r, c) = em.stationary_vn(a_h, a_w);
                if let Some((row0, col)) = sta_layout.addr(r, c, aw) {
                    if row0 + sta_layout.vn_size <= sta_depth {
                        reg_fills.push(RegFill {
                            dst: ((a_h * aw + a_w) * vn) as u32,
                            src: (row0 * aw + col) as u32,
                        });
                        reg_c[a_h * aw + a_w] = Some(c);
                    }
                }
            }
        }

        let mut waves = Vec::with_capacity(es.t);
        let mut col_groups: Vec<ColGroup> = Vec::new();
        let mut ops: Vec<Op> = Vec::new();
        let mut slots: Vec<Slot> = Vec::new();
        let mut max_slots = 0usize;
        // Per-wave scratch, reused across waves.
        let mut dests: Vec<Slot> = Vec::new();
        let mut pending: Vec<(usize, Slot)> = Vec::new(); // (op index, dest)
        let mut seen_row: Vec<Option<u32>> = vec![None; aw];

        for t in 0..es.t {
            let cg_start = col_groups.len() as u32;
            let slot_start = slots.len() as u32;
            dests.clear();
            pending.clear();
            for a_w in 0..aw {
                let (m, j) = es.streamed_vn(em, a_w, t);
                let Some((row0, col)) = str_layout.addr(j, m, aw) else {
                    continue; // zero-padded streamed VN: contributes 0
                };
                if row0 + str_layout.vn_size > str_depth {
                    continue;
                }
                let op_start = ops.len() as u32;
                for a_h in 0..active_rows {
                    let Some(c) = reg_c[a_h * aw + a_w] else {
                        continue; // zero-padded stationary VN
                    };
                    // Output element (p, q): the streamed index supplies one
                    // rank, the stationary the other (transposed under IO-S).
                    let (p, q) = match es.df {
                        Dataflow::WoS => (m, c),
                        Dataflow::IoS => (c, m),
                    };
                    let (r_o, off, c_o) =
                        (q / o_layout.vn_size, q % o_layout.vn_size, p);
                    let kind = match o_layout.addr(r_o, c_o, aw) {
                        Some((orow0, bank)) => {
                            let row = orow0 + off;
                            if row >= ob_depth {
                                OpKind::Overflow { row: row as u32 }
                            } else {
                                let s = Slot { row: row as u32, bank: bank as u32 };
                                dests.push(s);
                                pending.push((ops.len(), s));
                                OpKind::Slot(u32::MAX) // patched below
                            }
                        }
                        None => OpKind::Orphan { p: p as u32, q: q as u32 },
                    };
                    ops.push(Op { reg_base: ((a_h * aw + a_w) * vn) as u32, kind });
                }
                let op_end = ops.len() as u32;
                if op_end > op_start {
                    col_groups.push(ColGroup {
                        str_src: (row0 * aw + col) as u32,
                        op_start,
                        op_end,
                    });
                }
            }

            // BIRRD merge grouping, resolved at compile time: the reference
            // sorts this wave's psums by (row, bank) and folds equal keys.
            let n_contrib = dests.len();
            dests.sort_unstable();
            dests.dedup();
            let birrd_adds = (n_contrib - dests.len()) as u32;
            for (op_idx, dest) in &pending {
                let idx = dests.binary_search(dest).expect("merged slot present");
                ops[*op_idx].kind = OpKind::Slot(idx as u32);
            }
            // Bank conflicts of the merged write group, mirroring
            // `OutputBuffer::accumulate_group` over the sorted merged writes.
            seen_row.iter_mut().for_each(|s| *s = None);
            let mut ob_conflicts = 0u32;
            for s in &dests {
                match seen_row[s.bank as usize] {
                    None => seen_row[s.bank as usize] = Some(s.row),
                    Some(prev) if prev != s.row => ob_conflicts += 1,
                    _ => {}
                }
            }

            max_slots = max_slots.max(dests.len());
            slots.extend_from_slice(&dests);
            waves.push(Wave {
                cg_start,
                cg_end: col_groups.len() as u32,
                slot_start,
                slot_end: slots.len() as u32,
                birrd_adds,
                ob_conflicts,
            });
        }

        let hazard_free = ops.iter().all(|op| matches!(op.kind, OpKind::Slot(_)));
        Self {
            vn,
            dot_len: vn.min(str_layout.vn_size),
            macs_possible_per_wave: (ah * aw * vn) as u64,
            reg_fills,
            regs_len: active_rows * aw * vn,
            waves,
            col_groups,
            ops,
            slots,
            max_slots,
            hazard_free,
        }
    }

    /// Number of compiled waves (`T`).
    pub fn wave_count(&self) -> usize {
        self.waves.len()
    }

    /// Total compiled (column, PE-row) ops across all waves.
    pub fn op_count(&self) -> usize {
        self.ops.len()
    }

    /// Execute the plan against live buffer contents through the caller's
    /// scratch arena. Allocation pattern: **zero** — the arena's flat
    /// vectors are grown once per plan shape ([`PlanScratch::ensure`]) and
    /// reused across every tile invocation (extending PR 1's
    /// allocation-free claim from the wave loop to the whole tile loop;
    /// previously the register fill and streamed/psum temporaries were
    /// rebuilt per invocation).
    ///
    /// Generic over the element backend: a plan holds addressing only, so
    /// one compiled plan executes i32, f32 and prime-field buffers alike.
    /// The per-op inner product goes through [`Element::dot`] — the same
    /// kernel the blocked path uses — so backend dot overrides (unrolled
    /// i32, delayed-REDC Montgomery) apply here identically and the two
    /// paths cannot diverge.
    pub fn execute<E: Element>(
        &self,
        scratch: &mut PlanScratch<E>,
        streaming: &DataBuffer<E>,
        stationary: &DataBuffer<E>,
        ob: &mut OutputBuffer<E>,
        stats: &mut SimStats,
    ) -> Result<(), SimError> {
        let width = streaming.width;
        let sta_width = stationary.width;
        let str_data = streaming.data();
        let sta_data = stationary.data();
        let vn = self.vn;
        let dot_len = self.dot_len;

        scratch.ensure(1, self);
        let PlanScratch { regs, streamed, slot_acc } = scratch;
        let regs = &mut regs[..self.regs_len];
        let streamed = &mut streamed[..dot_len];

        // Stationary register fill (double-buffered NEST load).
        regs.iter_mut().for_each(|r| *r = E::zero());
        for f in &self.reg_fills {
            let (dst, src) = (f.dst as usize, f.src as usize);
            for (i, r) in regs[dst..dst + vn].iter_mut().enumerate() {
                *r = sta_data[src + i * sta_width];
            }
        }

        let mut macs_local: u64 = 0;

        for w in &self.waves {
            stats.waves += 1;
            stats.macs_possible += self.macs_possible_per_wave;
            let wave_slots = &self.slots[w.slot_start as usize..w.slot_end as usize];
            slot_acc[..wave_slots.len()].iter_mut().for_each(|v| *v = E::acc_zero());

            for cg in &self.col_groups[w.cg_start as usize..w.cg_end as usize] {
                let base = cg.str_src as usize;
                for (i, s) in streamed.iter_mut().enumerate() {
                    *s = str_data[base + i * width];
                }
                for op in &self.ops[cg.op_start as usize..cg.op_end as usize] {
                    macs_local += vn as u64;
                    let rb = op.reg_base as usize;
                    let psum = E::dot(streamed, &regs[rb..rb + dot_len]);
                    match op.kind {
                        OpKind::Slot(s) => {
                            let cell = &mut slot_acc[s as usize];
                            *cell = E::acc_add(*cell, psum);
                        }
                        OpKind::Orphan { p, q } => {
                            if !E::acc_is_zero(psum) {
                                stats.macs_used += macs_local;
                                return Err(SimError::OrphanPsum {
                                    m: p as usize,
                                    n: q as usize,
                                });
                            }
                        }
                        OpKind::Overflow { row } => {
                            stats.macs_used += macs_local;
                            return Err(SimError::ObOverflow {
                                row: row as usize,
                                depth: ob.depth,
                            });
                        }
                    }
                }
            }

            // Banked OB flush of the pre-merged write group.
            for (acc, s) in slot_acc.iter().zip(wave_slots) {
                ob.accumulate(s.row as usize, s.bank as usize, *acc);
            }
            ob.conflicts += w.ob_conflicts as u64;
            stats.ob_conflicts += w.ob_conflicts as u64;
            stats.birrd_adds += w.birrd_adds as u64;
        }
        stats.macs_used += macs_local;
        Ok(())
    }

    /// Cache-blocked multi-row execution: walk the compiled op/slot arrays
    /// **once** per wave and apply every op across all `lanes` at each
    /// step (§Perf tentpole). Each lane is an independent
    /// [`FunctionalSim`] holding one row-batch's buffer state; the plan —
    /// and therefore every address, slot and statistic — is identical
    /// across lanes because all lanes executed the same instruction trace.
    ///
    /// Scratch layout (flat, zero allocation per invocation):
    /// * `regs`: lane-major, lane `l` at `[l·regs_len ..]` — each lane's
    ///   stationary register file, filled once per invocation;
    /// * `streamed`: lane-major, lane `l` at `[l·dot_len ..]` — refreshed
    ///   per column group;
    /// * `slot_acc`: **slot-major**, slot `s` lane `l` at `[s·n_lanes + l]`
    ///   — consecutive lanes of one slot are contiguous, so the per-op
    ///   accumulate loop over lanes is a unit-stride sweep LLVM can
    ///   autovectorize (docs/PERF.md).
    ///
    /// Bit-exactness: each lane's outputs, OB state and `SimStats` equal a
    /// scalar [`Self::execute`] run on that lane alone. Per-lane work is
    /// never reordered *within* a lane (the dot product, slot accumulation
    /// order and OB flush order are exactly the scalar path's), so this
    /// holds for every backend including f32. Plans with hazard ops — and
    /// single-lane calls, which blocking cannot help — run each lane
    /// through the scalar interpreter, preserving error positions and
    /// partial-stats semantics exactly.
    pub(super) fn execute_rows<E: Element>(
        &self,
        lanes: &mut [FunctionalSim<E>],
        scratch: &mut PlanScratch<E>,
    ) -> Result<(), SimError> {
        if !self.hazard_free || lanes.len() == 1 {
            for sim in lanes.iter_mut() {
                self.execute(
                    &mut sim.scratch,
                    &sim.streaming,
                    &sim.stationary,
                    &mut sim.ob,
                    &mut sim.stats,
                )?;
            }
            return Ok(());
        }

        let nl = lanes.len();
        let vn = self.vn;
        let dot_len = self.dot_len;
        scratch.ensure(nl, self);
        let PlanScratch { regs, streamed, slot_acc } = scratch;

        // Per-lane stationary register fill (each lane's NEST load).
        for (l, sim) in lanes.iter().enumerate() {
            let sta_data = sim.stationary.data();
            let sta_width = sim.stationary.width;
            let lane_regs = &mut regs[l * self.regs_len..(l + 1) * self.regs_len];
            lane_regs.iter_mut().for_each(|r| *r = E::zero());
            for f in &self.reg_fills {
                let (dst, src) = (f.dst as usize, f.src as usize);
                for (i, r) in lane_regs[dst..dst + vn].iter_mut().enumerate() {
                    *r = sta_data[src + i * sta_width];
                }
            }
        }

        // Identical op sequence per lane on the hazard-free path, so the
        // MAC count is computed once and credited to every lane at the end.
        let mut macs_local: u64 = 0;

        for w in &self.waves {
            let wave_slots = &self.slots[w.slot_start as usize..w.slot_end as usize];
            let ns = wave_slots.len();
            slot_acc[..ns * nl].iter_mut().for_each(|v| *v = E::acc_zero());

            for cg in &self.col_groups[w.cg_start as usize..w.cg_end as usize] {
                let base = cg.str_src as usize;
                for (l, sim) in lanes.iter().enumerate() {
                    let str_data = sim.streaming.data();
                    let width = sim.streaming.width;
                    let lane_str = &mut streamed[l * dot_len..(l + 1) * dot_len];
                    for (i, s) in lane_str.iter_mut().enumerate() {
                        *s = str_data[base + i * width];
                    }
                }
                for op in &self.ops[cg.op_start as usize..cg.op_end as usize] {
                    macs_local += vn as u64;
                    let rb = op.reg_base as usize;
                    let OpKind::Slot(s) = op.kind else {
                        unreachable!("hazard-free plan holds Slot ops only");
                    };
                    let cells = &mut slot_acc[s as usize * nl..(s as usize + 1) * nl];
                    for (l, cell) in cells.iter_mut().enumerate() {
                        let a = &streamed[l * dot_len..(l + 1) * dot_len];
                        let b = &regs[l * self.regs_len + rb..l * self.regs_len + rb + dot_len];
                        *cell = E::acc_add(*cell, E::dot(a, b));
                    }
                }
            }

            // Banked OB flush per lane, in the scalar path's slot order.
            for (l, sim) in lanes.iter_mut().enumerate() {
                for (si, slot) in wave_slots.iter().enumerate() {
                    sim.ob.accumulate(
                        slot.row as usize,
                        slot.bank as usize,
                        slot_acc[si * nl + l],
                    );
                }
                sim.ob.conflicts += w.ob_conflicts as u64;
                sim.stats.ob_conflicts += w.ob_conflicts as u64;
                sim.stats.birrd_adds += w.birrd_adds as u64;
                sim.stats.waves += 1;
                sim.stats.macs_possible += self.macs_possible_per_wave;
            }
        }
        for sim in lanes.iter_mut() {
            sim.stats.macs_used += macs_local;
        }
        Ok(())
    }
}

/// Flat reusable scratch for plan execution — the per-sim arena of the
/// §Perf tentpole. Sized once per (plan, lane-count) high-water mark by
/// [`PlanScratch::ensure`]; never shrinks, never allocates inside the tile
/// loops. [`FunctionalSim`] owns one for its scalar path and
/// [`super::BlockSim`] owns one shared across its lanes.
#[derive(Debug, Clone, Default)]
pub struct PlanScratch<E: Element> {
    /// Stationary register files, lane-major: `lanes · regs_len`.
    regs: Vec<E>,
    /// Streamed-VN gather, lane-major: `lanes · dot_len`.
    streamed: Vec<E>,
    /// Per-slot psum accumulators, slot-major: `max_slots · lanes`.
    slot_acc: Vec<E::Acc>,
}

impl<E: Element> PlanScratch<E> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow (never shrink) to fit `lanes` concurrent lanes of `plan`.
    fn ensure(&mut self, lanes: usize, plan: &WavePlan) {
        let regs = lanes * plan.regs_len;
        if self.regs.len() < regs {
            self.regs.resize(regs, E::zero());
        }
        let streamed = lanes * plan.dot_len;
        if self.streamed.len() < streamed {
            self.streamed.resize(streamed, E::zero());
        }
        let slots = lanes * plan.max_slots;
        if self.slot_acc.len() < slots {
            self.slot_acc.resize(slots, E::acc_zero());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functional::FunctionalSim;
    use crate::mapper::exec::execute_program_on;
    use crate::mapper::lower_gemm;
    use crate::mapper::MappingChoice;
    use crate::util::Lcg;
    use crate::workloads::Gemm;

    /// Compiled and reference interpreters agree bit-exactly on a lowered
    /// program: outputs AND the full `SimStats`.
    #[test]
    fn plan_matches_reference_on_lowered_program() {
        let cfg = ArchConfig::paper(4, 4);
        let g = Gemm::new("t", "test", 12, 20, 10);
        let ch = MappingChoice {
            df: Dataflow::WoS,
            vn: 4,
            m_t: 8,
            k_t: 8,
            n_t: 8,
            nbc: 2,
            dup: 2,
        };
        let prog = lower_gemm(&cfg, &g, &ch, 4, 0, 2);
        let mut rng = Lcg::new(9);
        let iv: Vec<i32> = (0..g.m * g.k).map(|_| rng.range(0, 15) as i32 - 7).collect();
        let wv: Vec<i32> = (0..g.k * g.n).map(|_| rng.range(0, 15) as i32 - 7).collect();

        let mut fast = FunctionalSim::new(&cfg);
        let mut slow = FunctionalSim::new(&cfg);
        slow.use_plans = false;
        let a = execute_program_on(&mut fast, &g, &prog, &iv, &wv).unwrap();
        let b = execute_program_on(&mut slow, &g, &prog, &iv, &wv).unwrap();
        assert_eq!(a, b);
        assert_eq!(fast.stats, slow.stats);
        assert!(fast.plan_cache_len() > 0, "plans were compiled");
        assert!(
            fast.plan_cache_len() < prog.invocations as usize || prog.invocations <= 1,
            "tile loops reuse cached plans: {} plans for {} invocations",
            fast.plan_cache_len(),
            prog.invocations
        );
    }

    /// The plan compiler's precomputed wave statistics are internally
    /// consistent: ops cover every column group, slot indices are in range.
    #[test]
    fn compiled_plan_structure_is_consistent() {
        let cfg = ArchConfig::paper(4, 8);
        let em = MappingCfg { r0: 0, c0: 0, g_r: 4, g_c: 2, s_r: 1, s_c: 4 };
        let es = StreamCfg { df: Dataflow::WoS, m0: 0, s_m: 2, t: 6, vn_size: 4 };
        let sta = VnLayout::row_major(4, 16, 4);
        let strl = VnLayout::row_major(4, 16, 4);
        let o = VnLayout::row_major(4, 16, 4);
        let plan = WavePlan::compile(
            &cfg,
            &em,
            &es,
            &sta,
            &strl,
            &o,
            cfg.d_sta(),
            cfg.d_str(),
            cfg.d_ob(),
        );
        assert_eq!(plan.wave_count(), es.t);
        for w in &plan.waves {
            assert!(w.cg_start <= w.cg_end);
            assert!(w.slot_start <= w.slot_end);
            let nslots = (w.slot_end - w.slot_start) as u32;
            for cg in &plan.col_groups[w.cg_start as usize..w.cg_end as usize] {
                assert!(cg.op_start < cg.op_end, "no empty column groups");
                for op in &plan.ops[cg.op_start as usize..cg.op_end as usize] {
                    if let OpKind::Slot(s) = op.kind {
                        assert!(s < nslots, "slot index {s} within wave");
                    }
                }
            }
            // Merged slots are strictly sorted (deduped) per wave.
            let ws = &plan.slots[w.slot_start as usize..w.slot_end as usize];
            assert!(ws.windows(2).all(|p| p[0] < p[1]));
        }
    }
}
