//! Convolution support via im2col (paper Fig. 1): a convolution
//! `O[P,Q,C_out] = Conv(I[H,W,C_in], K[R,S,C_in,C_out])` is rewritten as a
//! GEMM `O[(P·Q) × C_out] = I_col[(P·Q) × (R·S·C_in)] · K_col[(R·S·C_in) × C_out]`,
//! which the FEATHER+ mapper then schedules like any other workload
//! (the artifact's "(mapping, layout) co-search for GEMM/conv" entry).

use super::Gemm;

/// A 2-D convolution layer (NHWC, valid padding unless `pad` set).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Conv2d {
    pub h: usize,
    pub w: usize,
    pub c_in: usize,
    pub r: usize,
    pub s: usize,
    pub c_out: usize,
    pub stride: usize,
    pub pad: usize,
}

impl Conv2d {
    pub fn new(h: usize, w: usize, c_in: usize, r: usize, s: usize, c_out: usize) -> Self {
        Self { h, w, c_in, r, s, c_out, stride: 1, pad: 0 }
    }

    pub fn with_stride(mut self, stride: usize) -> Self {
        self.stride = stride;
        self
    }

    pub fn with_pad(mut self, pad: usize) -> Self {
        self.pad = pad;
        self
    }

    /// Output spatial extents.
    pub fn out_hw(&self) -> (usize, usize) {
        let oh = (self.h + 2 * self.pad - self.r) / self.stride + 1;
        let ow = (self.w + 2 * self.pad - self.s) / self.stride + 1;
        (oh, ow)
    }

    /// The equivalent GEMM shape (extended-einsum of Fig. 1):
    /// `M = P·Q`, `K = R·S·C_in`, `N = C_out`.
    pub fn as_gemm(&self, name: &str) -> Gemm {
        let (oh, ow) = self.out_hw();
        Gemm::new(name, "Conv-im2col", oh * ow, self.r * self.s * self.c_in, self.c_out)
    }

    /// im2col expansion of an input tensor (row-major H×W×C_in) into the
    /// `M × K` GEMM operand. Out-of-image taps are zero (padding).
    pub fn im2col(&self, input: &[i32]) -> Vec<i32> {
        assert_eq!(input.len(), self.h * self.w * self.c_in, "input shape");
        let (oh, ow) = self.out_hw();
        let k = self.r * self.s * self.c_in;
        let mut out = vec![0i32; oh * ow * k];
        for op in 0..oh {
            for oq in 0..ow {
                let row = op * ow + oq;
                for kr in 0..self.r {
                    for ks in 0..self.s {
                        let ih = (op * self.stride + kr) as isize - self.pad as isize;
                        let iw = (oq * self.stride + ks) as isize - self.pad as isize;
                        if ih < 0 || iw < 0 || ih >= self.h as isize || iw >= self.w as isize {
                            continue; // zero pad
                        }
                        let src = ((ih as usize) * self.w + iw as usize) * self.c_in;
                        let dst = row * k + (kr * self.s + ks) * self.c_in;
                        out[dst..dst + self.c_in]
                            .copy_from_slice(&input[src..src + self.c_in]);
                    }
                }
            }
        }
        out
    }

    /// Reshape a kernel tensor (row-major R×S×C_in×C_out) to the `K × N`
    /// GEMM operand — already contiguous in that order, so this is a copy
    /// with a shape check.
    pub fn kernel_matrix(&self, kernel: &[i32]) -> Vec<i32> {
        assert_eq!(kernel.len(), self.r * self.s * self.c_in * self.c_out, "kernel shape");
        kernel.to_vec()
    }

    /// Direct (reference) convolution for validation.
    pub fn direct(&self, input: &[i32], kernel: &[i32]) -> Vec<i64> {
        let (oh, ow) = self.out_hw();
        let mut out = vec![0i64; oh * ow * self.c_out];
        for op in 0..oh {
            for oq in 0..ow {
                for co in 0..self.c_out {
                    let mut acc = 0i64;
                    for kr in 0..self.r {
                        for ks in 0..self.s {
                            let ih = (op * self.stride + kr) as isize - self.pad as isize;
                            let iw = (oq * self.stride + ks) as isize - self.pad as isize;
                            if ih < 0 || iw < 0 || ih >= self.h as isize || iw >= self.w as isize
                            {
                                continue;
                            }
                            for ci in 0..self.c_in {
                                let iv =
                                    input[((ih as usize) * self.w + iw as usize) * self.c_in + ci];
                                let kv = kernel
                                    [((kr * self.s + ks) * self.c_in + ci) * self.c_out + co];
                                acc += iv as i64 * kv as i64;
                            }
                        }
                    }
                    out[(op * ow + oq) * self.c_out + co] = acc;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functional::naive_gemm;
    use crate::util::prop::forall;
    use crate::util::Lcg;

    #[test]
    fn shapes_match_fig1() {
        let c = Conv2d::new(8, 8, 3, 3, 3, 16);
        assert_eq!(c.out_hw(), (6, 6));
        let g = c.as_gemm("conv");
        assert_eq!((g.m, g.k, g.n), (36, 27, 16));
    }

    #[test]
    fn im2col_gemm_equals_direct_conv() {
        let c = Conv2d::new(6, 5, 2, 3, 2, 4);
        let mut rng = Lcg::new(1);
        let input: Vec<i32> = (0..c.h * c.w * c.c_in).map(|_| rng.range(0, 9) as i32 - 4).collect();
        let kernel: Vec<i32> =
            (0..c.r * c.s * c.c_in * c.c_out).map(|_| rng.range(0, 9) as i32 - 4).collect();
        let g = c.as_gemm("t");
        let icol = c.im2col(&input);
        let kmat = c.kernel_matrix(&kernel);
        let via_gemm = naive_gemm(&icol, &kmat, g.m, g.k, g.n);
        assert_eq!(via_gemm, c.direct(&input, &kernel));
    }

    #[test]
    fn padding_and_stride_variants() {
        forall("conv-im2col-equiv", 30, |gen| {
            let c = Conv2d::new(
                gen.usize(3, 8),
                gen.usize(3, 8),
                gen.usize(1, 3),
                gen.usize(1, 3),
                gen.usize(1, 3),
                gen.usize(1, 4),
            )
            .with_stride(gen.usize(1, 2))
            .with_pad(gen.usize(0, 1));
            if c.h + 2 * c.pad < c.r || c.w + 2 * c.pad < c.s {
                return;
            }
            let mut rng = Lcg::new(7);
            let input: Vec<i32> =
                (0..c.h * c.w * c.c_in).map(|_| rng.range(0, 5) as i32 - 2).collect();
            let kernel: Vec<i32> =
                (0..c.r * c.s * c.c_in * c.c_out).map(|_| rng.range(0, 5) as i32 - 2).collect();
            let g = c.as_gemm("p");
            let got = naive_gemm(&c.im2col(&input), &c.kernel_matrix(&kernel), g.m, g.k, g.n);
            assert_eq!(got, c.direct(&input, &kernel));
        });
    }

    #[test]
    fn conv_through_full_mapper_stack() {
        // conv → im2col GEMM → mapper → MINISA trace → functional sim.
        let c = Conv2d::new(6, 6, 2, 3, 3, 4);
        let g = c.as_gemm("conv_e2e");
        let cfg = crate::arch::ArchConfig::paper(4, 4);
        let opts = crate::mapper::search::MapperOptions {
            full_layout_search: false,
            ..Default::default()
        };
        let d = crate::mapper::search::search(&cfg, &g, &opts).unwrap();
        let prog =
            crate::mapper::lower_gemm(&cfg, &g, &d.choice, d.i_order, d.w_order, d.o_order);
        let mut rng = Lcg::new(3);
        let input: Vec<i32> = (0..c.h * c.w * c.c_in).map(|_| rng.range(0, 7) as i32 - 3).collect();
        let kernel: Vec<i32> =
            (0..c.r * c.s * c.c_in * c.c_out).map(|_| rng.range(0, 7) as i32 - 3).collect();
        let icol = c.im2col(&input);
        let kmat = c.kernel_matrix(&kernel);
        let sim = crate::mapper::exec::execute_program(&cfg, &g, &prog, &icol, &kmat).unwrap();
        assert_eq!(sim, c.direct(&input, &kernel));
    }
}
