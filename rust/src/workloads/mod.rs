//! The paper's GEMM workload suite (Table IV): 50 kernels spanning FHE
//! basis conversion (BConv), FHE/ZKP number-theoretic transforms (NTT) and
//! GPT-oss-20B LLM inference layers.
//!
//! The paper's artifact ships the exact shapes as a CSV; the published text
//! gives the generating ranges. We enumerate deterministic shapes from those
//! ranges (documented in DESIGN.md): metrics in the evaluation depend only on
//! shapes, and the ranges below match Table IV exactly.

pub mod conv;
pub mod ntt;

use std::fmt;
use std::path::Path;

/// One GEMM workload: `O[M,N] = I[M,K] · W[K,N]` (extended-einsum ranks
/// P=M, Q=N, J=K — §II-A).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Gemm {
    pub name: String,
    pub category: String,
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

impl Gemm {
    pub fn new(name: &str, category: &str, m: usize, k: usize, n: usize) -> Self {
        Self { name: name.to_string(), category: category.to_string(), m, k, n }
    }

    /// Total multiply-accumulates.
    pub fn macs(&self) -> u64 {
        self.m as u64 * self.k as u64 * self.n as u64
    }

    /// Operand bytes (int8 I/W + int32 O by default widths).
    pub fn data_bytes(&self, elem_bytes: usize, acc_bytes: usize) -> u64 {
        (self.m * self.k * elem_bytes + self.k * self.n * elem_bytes
            + self.m * self.n * acc_bytes) as u64
    }

    /// Shape is "irregular" when no dimension is a multiple of 256 — the
    /// regime where rigid architectures pad heavily (§VI-C2).
    pub fn is_irregular(&self) -> bool {
        !(self.k % 256 == 0 && self.n % 256 == 0)
    }
}

impl fmt::Display for Gemm {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} [{}] M={} K={} N={}", self.name, self.category, self.m, self.k, self.n)
    }
}

/// FHE BConv: (65536 × K) · (K × N), K ∈ [28, 60], N ∈ [72, 160] — 41 shapes.
/// K/N pairs are generated on a deterministic lattice over the stated ranges
/// (the artifact CSV is not public at build time).
pub fn fhe_bconv() -> Vec<Gemm> {
    let mut v = Vec::with_capacity(41);
    for i in 0..41usize {
        let k = 28 + (i * 32) / 40; // 28..=60
        let n = 72 + (i * 88) / 40; // 72..=160
        v.push(Gemm::new(&format!("bconv_{:02}", i), "FHE-BConv", 65536, k, n));
    }
    v
}

/// FHE NTT: J=K=N ∈ {1024, 2048, 4096}, M ∈ {64,128,256} with M ≤ K/16.
/// The suite keeps the largest legal M per K (3 shapes).
pub fn fhe_ntt() -> Vec<Gemm> {
    [(1024usize, 64usize), (2048, 128), (4096, 256)]
        .iter()
        .map(|&(k, m)| Gemm::new(&format!("fhe_ntt_{}", k), "FHE-NTT", m, k, k))
        .collect()
}

/// ZKP NTT: K=N ∈ {8192, 16384, 32768}, M = K/16 (3 shapes).
pub fn zkp_ntt() -> Vec<Gemm> {
    [8192usize, 16384, 32768]
        .iter()
        .map(|&k| Gemm::new(&format!("zkp_ntt_{}", k), "ZKP-NTT", k / 16, k, k))
        .collect()
}

/// GPT-oss-20B inference GEMMs: M=2048,
/// (K, N) ∈ {(64, 2048), (2880, 5120), (4096, 2880)} for the 50-suite; the
/// full list (incl. the 201088-wide MoE router-adjacent shape) is in
/// `gpt_oss_full`.
pub fn gpt_oss() -> Vec<Gemm> {
    [(64usize, 2048usize), (2880, 5120), (4096, 2880)]
        .iter()
        .map(|&(k, n)| Gemm::new(&format!("gpt_oss_{}x{}", k, n), "GPT-oss", 2048, k, n))
        .collect()
}

/// All GPT-oss shapes listed in Table IV (5 shapes).
pub fn gpt_oss_full() -> Vec<Gemm> {
    [(64usize, 2048usize), (2880, 4096), (2880, 5120), (2880, 201_088), (4096, 2880)]
        .iter()
        .map(|&(k, n)| Gemm::new(&format!("gpt_oss_{}x{}", k, n), "GPT-oss", 2048, k, n))
        .collect()
}

/// The 50-workload evaluation suite: 41 BConv + 3 FHE-NTT + 3 ZKP-NTT +
/// 3 GPT-oss.
pub fn suite50() -> Vec<Gemm> {
    let mut v = fhe_bconv();
    v.extend(fhe_ntt());
    v.extend(zkp_ntt());
    v.extend(gpt_oss());
    v
}

/// A reduced suite for fast CI / examples: every 8th BConv + one per domain.
pub fn suite_small() -> Vec<Gemm> {
    let mut v: Vec<Gemm> = fhe_bconv().into_iter().step_by(8).collect();
    v.push(fhe_ntt().swap_remove(0));
    v.push(zkp_ntt().swap_remove(0));
    v.push(gpt_oss().swap_remove(0));
    v
}

/// The Table I workload: `I[65536×40] · W[40×88]`.
pub fn table1_workload() -> Gemm {
    Gemm::new("table1", "FHE-BConv", 65536, 40, 88)
}

/// Feature ladder of the 3-layer GPT-oss MLP slice the §IV-G chain example
/// compiles (qkv projection → MLP down → lm-head slice, Tab. IV widths).
/// Feed it to `mapper::chain::Chain::mlp` with the sequence length as M.
pub fn gpt_oss_mlp_dims() -> Vec<usize> {
    vec![2880, 5120, 2880, 2048]
}

/// Parse a workload CSV with header `category,name,M,K,N` (artifact §E
/// customization format).
pub fn from_csv(path: &Path) -> Result<Vec<Gemm>, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
    parse_csv(&text)
}

/// Parse CSV text (header `category,name,M,K,N`; `#` comments allowed).
pub fn parse_csv(text: &str) -> Result<Vec<Gemm>, String> {
    let mut out = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if ln == 0 && line.to_lowercase().starts_with("category") {
            continue;
        }
        let parts: Vec<&str> = line.split(',').map(|s| s.trim()).collect();
        if parts.len() != 5 {
            return Err(format!("line {}: expected 5 fields, got {}", ln + 1, parts.len()));
        }
        let parse = |s: &str, field: &str| -> Result<usize, String> {
            s.parse::<usize>().map_err(|_| format!("line {}: bad {field} '{s}'", ln + 1))
        };
        out.push(Gemm::new(
            parts[1],
            parts[0],
            parse(parts[2], "M")?,
            parse(parts[3], "K")?,
            parse(parts[4], "N")?,
        ));
    }
    if out.is_empty() {
        return Err("no workloads parsed".into());
    }
    Ok(out)
}

/// Serialize workloads to the artifact CSV format.
pub fn to_csv(ws: &[Gemm]) -> String {
    let mut s = String::from("category,name,M,K,N\n");
    for w in ws {
        s.push_str(&format!("{},{},{},{},{}\n", w.category, w.name, w.m, w.k, w.n));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_exactly_50() {
        let s = suite50();
        assert_eq!(s.len(), 50);
        assert_eq!(s.iter().filter(|w| w.category == "FHE-BConv").count(), 41);
        assert_eq!(s.iter().filter(|w| w.category == "FHE-NTT").count(), 3);
        assert_eq!(s.iter().filter(|w| w.category == "ZKP-NTT").count(), 3);
        assert_eq!(s.iter().filter(|w| w.category == "GPT-oss").count(), 3);
    }

    #[test]
    fn bconv_ranges_match_table_iv() {
        for w in fhe_bconv() {
            assert_eq!(w.m, 65536);
            assert!((28..=60).contains(&w.k), "{w}");
            assert!((72..=160).contains(&w.n), "{w}");
        }
        let v = fhe_bconv();
        assert_eq!(v.first().unwrap().k, 28);
        assert_eq!(v.last().unwrap().k, 60);
        assert_eq!(v.first().unwrap().n, 72);
        assert_eq!(v.last().unwrap().n, 160);
    }

    #[test]
    fn ntt_constraints_hold() {
        for w in fhe_ntt() {
            assert_eq!(w.k, w.n);
            assert!(w.m <= w.k / 16, "{w}");
        }
        for w in zkp_ntt() {
            assert_eq!(w.k, w.n);
            assert_eq!(w.m, w.k / 16);
        }
    }

    #[test]
    fn names_unique() {
        let s = suite50();
        let mut names: Vec<&str> = s.iter().map(|w| w.name.as_str()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), s.len());
    }

    #[test]
    fn macs_and_bytes() {
        let g = Gemm::new("t", "c", 2, 3, 4);
        assert_eq!(g.macs(), 24);
        assert_eq!(g.data_bytes(1, 4), (6 + 12 + 32) as u64);
    }

    #[test]
    fn csv_roundtrip() {
        let s = suite_small();
        let csv = to_csv(&s);
        let parsed = parse_csv(&csv).unwrap();
        assert_eq!(parsed, s);
    }

    #[test]
    fn csv_rejects_malformed() {
        assert!(parse_csv("category,name,M,K,N\nx,y,1,2").is_err());
        assert!(parse_csv("category,name,M,K,N\nx,y,1,2,zzz").is_err());
        assert!(parse_csv("").is_err());
    }

    #[test]
    fn csv_allows_comments() {
        let parsed = parse_csv("category,name,M,K,N\n# hi\nc,n,1,2,3\n").unwrap();
        assert_eq!(parsed.len(), 1);
        assert_eq!(parsed[0].n, 3);
    }

    #[test]
    fn irregularity_flag() {
        assert!(Gemm::new("a", "c", 64, 40, 88).is_irregular());
        assert!(!Gemm::new("b", "c", 64, 1024, 2048).is_irregular());
    }

    #[test]
    fn table1_shape() {
        let w = table1_workload();
        assert_eq!((w.m, w.k, w.n), (65536, 40, 88));
    }
}
