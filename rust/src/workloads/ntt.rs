//! NTT-as-GEMM lowering for the suite's FHE/ZKP rows (Table IV).
//!
//! A size-`n` number-theoretic transform of a batch of `m` vectors is the
//! GEMM `O[m, j] = Σ_k I[m, k] · ω^{kj} (mod p)` — i.e. exactly the suite's
//! `FHE-NTT` / `ZKP-NTT` entries (`M × K · K × K` with `K = N = n`), with
//! the weight matrix fixed to the **twiddle matrix** `W[k][j] = ω^{kj}` for
//! a primitive `n`-th root of unity `ω` in the chosen prime field. Until
//! the `arith` subsystem these entries only existed as shapes for the
//! analytical model; with [`crate::arith::ModP`] they execute *for real*:
//! compile the 1-layer chain to a [`crate::program::Program`] once, then
//! run activations through it field-exactly.
//!
//! The inverse transform is the same GEMM with `ω⁻¹` twiddles and a final
//! `1/n` scale; [`intt_matrix`] folds the scale into the matrix, so the
//! 2-layer chain NTT → INTT is the identity — the strongest cheap witness
//! that chained field execution (including the inter-layer OB commit) is
//! exact end-to-end.
//!
//! Full-size suite entries (n up to 32768) need `n²` twiddle words — fine
//! for serving real workloads, far too hot for CI — so [`scaled`] shrinks
//! an entry to a CI-sized power of two while preserving its category,
//! `K = N`, and the ZKP `M = K/16` row rule. Default field assignment
//! follows the domains: FHE rows get the 31-bit RNS-limb field (Baby
//! Bear), ZKP rows the STARK field (Goldilocks); `--elem` overrides.

use super::Gemm;
use crate::arith::{two_adic_root, ElemType, Element, ModP, PrimeField};

/// Parse an NTT suite entry: square (`K == N`) power-of-two kernels in the
/// NTT categories. Returns the transform size.
pub fn ntt_size(g: &Gemm) -> Option<usize> {
    if !g.category.contains("NTT") {
        return None;
    }
    if g.k != g.n || !g.k.is_power_of_two() {
        return None;
    }
    Some(g.k)
}

/// The natural element backend for a suite category: Baby Bear for the FHE
/// rows (RNS limb arithmetic), Goldilocks for ZKP, saturating i32 for
/// everything else (LLM/BConv quantized layers).
pub fn default_elem(category: &str) -> ElemType {
    if category.contains("ZKP") {
        ElemType::Goldilocks
    } else if category.contains("NTT") {
        ElemType::BabyBear
    } else {
        ElemType::I32
    }
}

/// Shrink an NTT entry to a CI-sized transform: `K = N = min(max_n, K)`
/// rounded down to a power of two, preserving the ZKP `M = K/16` row rule
/// (min 1 row) and the entry's name/category lineage. Only ZKP rows carry
/// the `M = K/16` rule — FHE rows *happen* to satisfy `m·16 == k` too, but
/// their M is a batch size capped independently, so the branch keys on the
/// category, not the arithmetic coincidence.
pub fn scaled(g: &Gemm, max_n: usize) -> Gemm {
    // Both the cap and the entry round *down* to a power of two (NTT sizes
    // must be exact powers of two; rounding up would exceed the cap).
    let floor_pow2 = |x: usize| 1usize << (usize::BITS - 1 - x.leading_zeros());
    let n = floor_pow2(g.n.max(2)).min(floor_pow2(max_n.max(2)));
    let m = if g.category.contains("ZKP") && g.m * 16 == g.k {
        (n / 16).max(1)
    } else {
        g.m.min(n)
    };
    Gemm::new(&format!("{}_s{}", g.name, n), &g.category, m, n, n)
}

/// The `n × n` twiddle matrix `W[k][j] = ω^{kj}` (row-major), for a
/// primitive `n`-th root `ω` of the field's two-adic subgroup.
pub fn twiddle_matrix<F: PrimeField>(n: usize) -> Result<Vec<ModP<F>>, String> {
    let w = two_adic_root::<F>(n)?;
    build_twiddles(w, n)
}

/// The inverse-NTT matrix `W'[k][j] = n⁻¹ · ω^{-kj}`: `intt(ntt(x)) == x`
/// exactly, so the scale is folded in rather than left to a separate pass.
pub fn intt_matrix<F: PrimeField>(n: usize) -> Result<Vec<ModP<F>>, String> {
    let w = two_adic_root::<F>(n)?;
    let n_inv = ModP::<F>::new(n as u64).inv();
    let mut m = build_twiddles(w.inv(), n)?;
    for e in &mut m {
        *e = *e * n_inv;
    }
    Ok(m)
}

fn build_twiddles<F: PrimeField>(w: ModP<F>, n: usize) -> Result<Vec<ModP<F>>, String> {
    // Row k is the geometric progression of ω^k — O(n²) multiplies, no pow.
    let mut m = Vec::with_capacity(n * n);
    let mut wk = ModP::<F>::one(); // ω^k
    for _ in 0..n {
        let mut x = ModP::<F>::one();
        for _ in 0..n {
            m.push(x);
            x = x * wk;
        }
        wk = wk * w;
    }
    Ok(m)
}

/// Twiddle matrix as canonical datapath words for a runtime-tagged field —
/// what [`crate::coordinator::serve::Server::register_chain_elem`] wants.
/// Errors for non-field element types or unsupported sizes.
pub fn twiddle_words(elem: ElemType, n: usize) -> Result<Vec<u64>, String> {
    use crate::arith::{encode_words, BabyBear as Bb, Goldilocks as Gl, PallasStyle as Pa};
    match elem {
        ElemType::BabyBear => Ok(encode_words(&twiddle_matrix::<Bb>(n)?)),
        ElemType::Goldilocks => Ok(encode_words(&twiddle_matrix::<Gl>(n)?)),
        ElemType::Pallas => Ok(encode_words(&twiddle_matrix::<Pa>(n)?)),
        other => Err(format!("NTT twiddles need a prime-field element type, not {other}")),
    }
}

/// Schoolbook NTT of each row of `input` (`m × n`, row-major): the naive
/// mod-p reference the GEMM lowering is validated against.
pub fn ntt_reference<F: PrimeField>(
    input: &[ModP<F>],
    m: usize,
    n: usize,
) -> Result<Vec<ModP<F>>, String> {
    let w = two_adic_root::<F>(n)?;
    let mut out = vec![ModP::<F>::default(); m * n];
    for row in 0..m {
        // ω^{kj} walked incrementally: wj = ω^j, x = ω^{kj}.
        let mut wj = ModP::<F>::one();
        for j in 0..n {
            let mut acc = ModP::<F>::default();
            let mut x = ModP::<F>::one();
            for k in 0..n {
                acc = acc + input[row * n + k] * x;
                x = x * wj;
            }
            out[row * n + j] = acc;
            wj = wj * w;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::{naive_gemm_e, BabyBear, Goldilocks, PallasStyle};
    use crate::util::Lcg;

    #[test]
    fn suite_entries_parse_as_ntts() {
        for g in super::super::fhe_ntt().iter().chain(super::super::zkp_ntt().iter()) {
            assert_eq!(ntt_size(g), Some(g.k), "{g}");
        }
        assert_eq!(ntt_size(&Gemm::new("x", "GPT-oss", 8, 16, 16)), None);
        assert_eq!(ntt_size(&Gemm::new("x", "ZKP-NTT", 8, 16, 24)), None, "non-square");
        assert_eq!(ntt_size(&Gemm::new("x", "ZKP-NTT", 8, 24, 24)), None, "non-pow2");
    }

    #[test]
    fn default_fields_by_domain() {
        assert_eq!(default_elem("ZKP-NTT"), ElemType::Goldilocks);
        assert_eq!(default_elem("FHE-NTT"), ElemType::BabyBear);
        assert_eq!(default_elem("GPT-oss"), ElemType::I32);
        assert_eq!(default_elem("FHE-BConv"), ElemType::I32);
    }

    #[test]
    fn scaling_preserves_structure() {
        let zkp = &super::super::zkp_ntt()[0]; // M=512, K=N=8192
        let s = scaled(zkp, 64);
        assert_eq!((s.m, s.k, s.n), (4, 64, 64), "ZKP M=K/16 rule preserved");
        assert_eq!(s.category, zkp.category);
        assert_eq!(ntt_size(&s), Some(64));
        let fhe = &super::super::fhe_ntt()[0]; // M=64, K=N=1024
        let s = scaled(fhe, 32);
        assert_eq!((s.m, s.k, s.n), (32, 32, 32));
        // Already-small entries pass through unscaled dims.
        let tiny = Gemm::new("t", "ZKP-NTT", 1, 16, 16);
        let s = scaled(&tiny, 64);
        assert_eq!((s.m, s.k, s.n), (1, 16, 16));
    }

    #[test]
    fn twiddle_rows_are_geometric() {
        let n = 16;
        let m = twiddle_matrix::<BabyBear>(n).unwrap();
        let w = two_adic_root::<BabyBear>(n).unwrap();
        assert_eq!(m.len(), n * n);
        for k in 0..n {
            for j in 0..n {
                assert_eq!(m[k * n + j], w.pow((k * j) as u64), "({k},{j})");
            }
        }
        // Row 0 and column 0 are all ones.
        for i in 0..n {
            assert_eq!(m[i].to_u64(), 1);
            assert_eq!(m[i * n].to_u64(), 1);
        }
    }

    fn gemm_equals_schoolbook<F: PrimeField>() {
        let (m, n) = (3usize, 32usize);
        let mut rng = Lcg::new(0xA11CE);
        let input: Vec<ModP<F>> = (0..m * n).map(|_| ModP::<F>::new(rng.next_u64())).collect();
        let tw = twiddle_matrix::<F>(n).unwrap();
        let via_gemm: Vec<ModP<F>> = naive_gemm_e::<ModP<F>>(&input, &tw, m, n, n);
        let schoolbook = ntt_reference::<F>(&input, m, n).unwrap();
        assert_eq!(via_gemm, schoolbook, "{}", F::NAME);
    }

    #[test]
    fn ntt_as_gemm_equals_schoolbook_all_fields() {
        gemm_equals_schoolbook::<BabyBear>();
        gemm_equals_schoolbook::<Goldilocks>();
        gemm_equals_schoolbook::<PallasStyle>();
    }

    #[test]
    fn intt_inverts_ntt() {
        let n = 16usize;
        let m = 2usize;
        let mut rng = Lcg::new(5);
        type G = ModP<Goldilocks>;
        let input: Vec<G> = (0..m * n).map(|_| G::new(rng.next_u64())).collect();
        let fwd = naive_gemm_e::<G>(&input, &twiddle_matrix::<Goldilocks>(n).unwrap(), m, n, n);
        let back = naive_gemm_e::<G>(&fwd, &intt_matrix::<Goldilocks>(n).unwrap(), m, n, n);
        assert_eq!(back, input, "INTT(NTT(x)) == x");
    }

    #[test]
    fn twiddle_words_are_canonical_and_field_only() {
        let words = twiddle_words(ElemType::BabyBear, 8).unwrap();
        assert_eq!(words.len(), 64);
        assert!(words.iter().all(|&w| w < BabyBear::P));
        assert!(twiddle_words(ElemType::I32, 8).is_err());
        assert!(twiddle_words(ElemType::F32, 8).is_err());
        assert!(twiddle_words(ElemType::Goldilocks, 24).is_err(), "non-pow2 size");
    }
}
