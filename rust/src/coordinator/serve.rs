//! Model-serving request loop — the L3 hot path.
//!
//! A leader thread accepts requests, batches compatible ones, and
//! dispatches execution to a pluggable `TileExecutor` — the PJRT runtime in
//! production (`runtime::PjrtExecutor`), the functional simulator or the
//! naive executor in tests. Python never appears on this path: the executor
//! consumes AOT-compiled artifacts.
//!
//! Two request kinds coexist:
//!
//! * **Program requests** (the compile-once/serve-many path): a model
//!   session is registered once through `Server::register(ArtifactSource)` —
//!   canonically from a deployable `.minisa` [`Artifact`] (in memory or a
//!   file path), which is loaded by *decoding its instruction stream* with
//!   zero mapper runs; or compile-on-register for callers that never
//!   persist (`register_chain`/`register_chain_elem` wrappers, one
//!   chain-aware mapper run). Either way the session is an immutable
//!   `Arc<Program>` plus resident weights, and every subsequent request
//!   references it by [`ProgramId`], carrying only its activation.
//!   Batching stacks activations of the *same program* (true shared-weight
//!   continuous batching: the weights live in the session, not the
//!   request).
//! * **Ad-hoc GEMM requests** (the pre-Program path, kept for one-off
//!   shapes and as the equivalence baseline): per-shape mapping decisions
//!   are cached; batching keys on (shape, weight identity) where weight
//!   identity is `Arc` pointer equality — no weight cloning or per-element
//!   comparison on the dispatch path.
//!
//! Execution itself is owned by a [`Fleet`](super::fleet::Fleet) of one or
//! more simulated devices ([`ServerOptions::devices`]): with one device the
//! leader dispatches inline exactly as before; with several, batches are
//! routed onto per-device work-stealing queues (request-parallel) and large
//! batches additionally split their activation rows across idle devices
//! (tile-parallel) — see `coordinator::fleet`.
//!
//! Built on std::thread + mpsc channels (offline substitute for tokio,
//! DESIGN.md).
//!
//! Telemetry (docs/OBSERVABILITY.md): every front-door counter lives in a
//! per-server [`MetricsRegistry`] (one relaxed atomic add per event,
//! handles cached at construction); [`ServerOptions::tracing`] samples
//! requests into [`RequestTrace`] pipeline spans whose per-stage latencies
//! feed `serve_stage_*_us` histograms; [`Server::metrics_snapshot`] folds
//! the fleet's utilisation and live stall accounting in as gauges.

use std::collections::hash_map::DefaultHasher;
use std::collections::{HashMap, HashSet};
use std::hash::{Hash, Hasher};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, OnceLock, RwLock};
use std::time::Instant;

use super::admission::{Admission, AdmissionController, AdmissionOptions, ErrorCode, QosClass, Verdict};
use super::fleet::{lock_clean, Device, Fleet, FleetOptions};
use crate::arch::config::ArchConfig;
use crate::arith::{decode_words, encode_words, ElemType, Element};
use crate::artifact::Artifact;
use crate::functional::{BlockSim, FunctionalSim};
use crate::mapper::chain::Chain;
use crate::mapper::search::{search, MapperOptions};
use crate::mapper::Decision;
use crate::obs::{Counter, Gauge, MetricsRegistry, RequestTrace, Snapshot, Stage, TraceOptions};
use crate::program::Program;
use crate::registry::{LoadedWeights, Registry};
use crate::with_element;
use crate::workloads::Gemm;

/// Handle to a registered model session (a compiled [`Program`] plus its
/// resident weights).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ProgramId(pub u64);

/// What a request asks for.
#[derive(Debug, Clone)]
pub enum Payload {
    /// One ad-hoc GEMM carrying its own operands. The weight is shared by
    /// `Arc` so identical-weight requests batch by pointer identity.
    Gemm { m: usize, k: usize, n: usize, input: Vec<f32>, weight: Arc<Vec<f32>> },
    /// An activation (`rows × in_features`, row-major) for a registered
    /// f32 program; weights live in the session.
    Program { program: ProgramId, rows: usize, input: Vec<f32> },
    /// An activation for an element-typed program session
    /// ([`Server::register_chain_elem`]): canonical datapath words in the
    /// session's [`ElemType`] encoding. Kept apart from [`Payload::Program`]
    /// down to the batch key, so word and f32 requests can never co-batch
    /// even if they name the same program id.
    ProgramWords { program: ProgramId, rows: usize, input: Vec<u64> },
}

/// A serving request: f32 operands for the GEMM/Program payloads (the PJRT
/// oracle path computes in f32), canonical element words for
/// [`Payload::ProgramWords`].
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub payload: Payload,
    /// Admission tag: QoS class plus optional deadline. Constructors default
    /// to `Interactive` with no deadline — the pre-admission behaviour.
    pub admission: Admission,
    /// Pipeline trace, populated by the server at arrival when
    /// [`ServerOptions::tracing`] samples this request; `None` otherwise
    /// (constructors never set it). Stage marks accumulate as the request
    /// moves through the pipeline and the finished trace is returned on
    /// the [`Response`]. Requests rejected before admission (shed / dead on
    /// arrival) drop their trace with the request.
    pub trace: Option<RequestTrace>,
}

impl Request {
    /// An ad-hoc single-GEMM request.
    pub fn gemm(id: u64, m: usize, k: usize, n: usize, input: Vec<f32>, weight: Arc<Vec<f32>>) -> Self {
        Self {
            id,
            payload: Payload::Gemm { m, k, n, input, weight },
            admission: Admission::default(),
            trace: None,
        }
    }

    /// An activation for a registered f32 program.
    pub fn for_program(id: u64, program: ProgramId, rows: usize, input: Vec<f32>) -> Self {
        Self {
            id,
            payload: Payload::Program { program, rows, input },
            admission: Admission::default(),
            trace: None,
        }
    }

    /// An activation (canonical words) for an element-typed program session.
    pub fn for_program_words(id: u64, program: ProgramId, rows: usize, input: Vec<u64>) -> Self {
        Self {
            id,
            payload: Payload::ProgramWords { program, rows, input },
            admission: Admission::default(),
            trace: None,
        }
    }

    /// Tag this request with a QoS class (default: `Interactive`).
    pub fn with_qos(mut self, qos: QosClass) -> Self {
        self.admission.qos = qos;
        self
    }

    /// Give this request a deadline `ms` milliseconds from now; past the
    /// deadline it is answered with a typed `deadline_exceeded` error at the
    /// next hand-off point instead of occupying a device.
    pub fn with_deadline_ms(mut self, ms: u64) -> Self {
        self.admission = self.admission.with_deadline_ms(ms);
        self
    }

    /// Replace the whole admission tag.
    pub fn with_admission(mut self, admission: Admission) -> Self {
        self.admission = admission;
        self
    }
}

/// A served response.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Row-major output for f32 requests (`Gemm`/`Program` payloads);
    /// empty for word requests.
    pub output: Vec<f32>,
    /// Row-major output for `ProgramWords` requests, as canonical words in
    /// the session's element encoding (already narrowed by
    /// `Element::reduce`, i.e. exactly what a next layer would consume);
    /// empty for f32 requests.
    pub output_words: Vec<u64>,
    /// Wall-clock service time (queue + execute) in µs.
    pub service_us: f64,
    /// Modeled FEATHER+ cycles for this request. Single-GEMM: the mapper
    /// decision for the *stacked* batch shape. Program: the chain's
    /// compile-time total for its registered shape — deliberately not
    /// re-modeled per batched row count, since avoiding per-request mapper
    /// work is what sessions exist for.
    pub modeled_cycles: f64,
    /// Requests co-batched with this one.
    pub batch_size: usize,
    /// Set when the request could not be served (unknown program, shape
    /// mismatch, executor failure); `output` is empty then.
    pub error: Option<String>,
    /// Machine-readable error class when `error` is set; `None` on success.
    /// The string forms ([`ErrorCode::as_str`]) are stable — clients switch
    /// on these, not on the human-readable `error` message.
    pub code: Option<ErrorCode>,
    /// The request's completed pipeline trace (arrival → respond) when it
    /// was sampled ([`ServerOptions::tracing`]) and answered successfully;
    /// `None` for untraced requests and error responses.
    pub trace: Option<RequestTrace>,
}

/// Execution backend abstraction.
pub trait TileExecutor: Send + Sync {
    /// Execute `O[M,N] = I · W` and return O row-major.
    fn gemm(&self, m: usize, k: usize, n: usize, i: &[f32], w: &[f32])
        -> anyhow::Result<Vec<f32>>;
    fn name(&self) -> &str;

    /// Execute a whole compiled program on `rows` activation rows
    /// (`input.len() == rows · program.in_features()`), returning
    /// `rows × program.out_features()` row-major. The weights arrive as the
    /// session's shared `Arc` so backends can retain them without copying
    /// the (potentially hundreds of MB of) matrices per dispatch.
    ///
    /// The default walks the chain layer by layer through [`Self::gemm`],
    /// so every executor (naive, PJRT, …) serves programs out of the box;
    /// backends with a fused whole-chain path override it.
    fn run_program(
        &self,
        program: &Program,
        rows: usize,
        input: &[f32],
        weights: &Arc<Vec<Vec<f32>>>,
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(
            weights.len() == program.layer_count(),
            "program expects {} weight matrices, got {}",
            program.layer_count(),
            weights.len()
        );
        let mut act = input.to_vec();
        for (layer, w) in program.layers.iter().zip(weights.iter()) {
            act = self.gemm(rows, layer.gemm.k, layer.gemm.n, &act, w)?;
        }
        Ok(act)
    }

    /// Execute a compiled program on an element-typed activation (canonical
    /// words in the session's encoding), returning the final layer's output
    /// as canonical words narrowed by `Element::reduce`.
    ///
    /// The default runs the **functional simulator over the program's
    /// precompiled wave plans** — exact in the element domain (field-exact
    /// for `ModP` sessions, which no f32 backend can be), with zero runtime
    /// plan compiles. f32-oracle backends like PJRT cannot represent field
    /// arithmetic, so they keep this default rather than lowering to
    /// [`Self::gemm`].
    fn run_program_words(
        &self,
        program: &Program,
        rows: usize,
        input: &[u64],
        weights: &WordWeights,
    ) -> anyhow::Result<Vec<u64>> {
        execute_program_words(program, rows, input, weights)
    }
}

/// The resident weights of an element-typed session, decoded to their
/// per-backend form **once at registration** — word sessions must not pay
/// an O(weights) decode (for `ModP`, a Montgomery conversion per element)
/// on every dispatch, mirroring how f32 sessions retain their matrices
/// without per-dispatch copies. The canonical words are *not* retained
/// (they would double the session's resident weight memory); re-encode
/// from the decoded form if ever needed.
pub struct WordWeights {
    /// `Vec<Vec<E>>` for the session's element type, type-erased.
    decoded: Arc<dyn std::any::Any + Send + Sync>,
    elem: ElemType,
    layers: usize,
}

impl WordWeights {
    /// Decode canonical word matrices (one per layer) for `elem`, consuming
    /// the words.
    pub fn new(words: Vec<Vec<u64>>, elem: ElemType) -> Self {
        let layers = words.len();
        let decoded = with_element!(elem, E => {
            let d: Vec<Vec<E>> = words.iter().map(|m| decode_words::<E>(m)).collect();
            // Explicit per-arm coercion so every dispatch arm yields the
            // same erased type.
            let erased: Arc<dyn std::any::Any + Send + Sync> = Arc::new(d);
            erased
        });
        Self { decoded, elem, layers }
    }

    /// Decode straight from container weight matrices (owned or zero-copy
    /// shared views) without materialising intermediate `Vec<u64>`s.
    pub fn from_matrices(mats: &[crate::artifact::WordMatrix], elem: ElemType) -> Self {
        let layers = mats.len();
        let decoded = with_element!(elem, E => {
            let d: Vec<Vec<E>> = mats.iter().map(|m| m.decode::<E>()).collect();
            let erased: Arc<dyn std::any::Any + Send + Sync> = Arc::new(d);
            erased
        });
        Self { decoded, elem, layers }
    }

    pub fn elem(&self) -> ElemType {
        self.elem
    }

    /// Number of weight matrices (chain layers).
    pub fn layer_count(&self) -> usize {
        self.layers
    }

    /// The registration-time decoded matrices; `None` only if `E` does not
    /// match the session's element type.
    pub fn decoded<E: Element>(&self) -> Option<&Vec<Vec<E>>> {
        self.decoded.downcast_ref::<Vec<Vec<E>>>()
    }
}

/// The simulator-backed word-program executor behind
/// [`TileExecutor::run_program_words`]. The program is compiled for a fixed
/// activation height `program.rows()`; larger (batched) activations run in
/// row chunks of that height, the final chunk zero-padded — rows of a GEMM
/// chain are independent, so chunking is exact.
pub fn execute_program_words(
    program: &Program,
    rows: usize,
    input: &[u64],
    weights: &WordWeights,
) -> anyhow::Result<Vec<u64>> {
    with_element!(weights.elem(), E => {
        // Registration-time decode; a mismatch is impossible through the
        // Server API (WordWeights::new decodes for the tag it stores).
        let w: &[Vec<E>] = weights
            .decoded::<E>()
            .ok_or_else(|| anyhow::anyhow!("WordWeights decoded form does not match its tag"))?;
        let mut block: BlockSim<E> = BlockSim::new(&program.cfg);
        execute_program_words_blocked(&mut block, program, rows, input, w)
    })
}

/// [`execute_program_words`] against a caller-provided **scalar** simulator
/// — the sequential chunk loop the blocked path
/// ([`execute_program_words_blocked`]) is proven bit-identical to
/// (`tests/plan_equivalence.rs`). Kept as the reference oracle for the
/// equivalence battery and benchmarks; production callers (the serving
/// front door, fleet devices) route through the blocked executor. The
/// simulator must share the program's `ArchConfig` (`Program::seed_sim`
/// asserts it).
pub fn execute_program_words_on<E: Element>(
    sim: &mut FunctionalSim<E>,
    program: &Program,
    rows: usize,
    input: &[u64],
    w: &[Vec<E>],
) -> anyhow::Result<Vec<u64>> {
    let kf = program.in_features();
    let nf = program.out_features();
    anyhow::ensure!(
        input.len() == rows * kf,
        "activation is {} words, expected {rows}×{kf}",
        input.len()
    );
    anyhow::ensure!(
        w.len() == program.layer_count(),
        "program expects {} weight matrices, got {}",
        program.layer_count(),
        w.len()
    );
    let m = program.rows();
    // Seed once up front; `execute` re-seeds idempotently per chunk, which
    // is then O(plan-count) hash lookups — noise next to the chunk's chain
    // execution.
    program.seed_sim(sim);
    let mut out_words: Vec<u64> = Vec::with_capacity(rows * nf);
    let mut row0 = 0usize;
    while row0 < rows {
        let rows_here = m.min(rows - row0);
        let mut act: Vec<E> = decode_words::<E>(&input[row0 * kf..(row0 + rows_here) * kf]);
        act.resize(m * kf, E::zero());
        let out = program
            .execute(sim, &act, w)
            .map_err(|e| anyhow::anyhow!("functional execution: {e}"))?;
        let reduced: Vec<E> = out[..rows_here * nf].iter().map(|&v| E::reduce(v)).collect();
        out_words.extend(encode_words::<E>(&reduced));
        row0 += rows_here;
    }
    Ok(out_words)
}

/// The blocked word-program executor (§Perf tentpole): same chunking and
/// reduce semantics as [`execute_program_words_on`], but up to
/// `block.block()` row chunks are gathered per round and executed together
/// through [`Program::execute_rows`], so every tile's compiled wave plan is
/// walked once per *block* instead of once per chunk and the inner products
/// run as lane batches. Bit-identical to the scalar loop — each lane
/// reproduces exactly one sequential chunk, and lane outputs are reduced and
/// encoded in chunk order (`tests/plan_equivalence.rs` enforces word-level
/// equality and `SimStats` equality across all backends).
pub fn execute_program_words_blocked<E: Element>(
    block: &mut BlockSim<E>,
    program: &Program,
    rows: usize,
    input: &[u64],
    w: &[Vec<E>],
) -> anyhow::Result<Vec<u64>> {
    let kf = program.in_features();
    let nf = program.out_features();
    anyhow::ensure!(
        input.len() == rows * kf,
        "activation is {} words, expected {rows}×{kf}",
        input.len()
    );
    anyhow::ensure!(
        w.len() == program.layer_count(),
        "program expects {} weight matrices, got {}",
        program.layer_count(),
        w.len()
    );
    let m = program.rows();
    let lanes_max = block.block();
    let mut out_words: Vec<u64> = Vec::with_capacity(rows * nf);
    let mut row0 = 0usize;
    let mut chunk_acts: Vec<Vec<E>> = Vec::with_capacity(lanes_max);
    let mut chunk_rows: Vec<usize> = Vec::with_capacity(lanes_max);
    while row0 < rows {
        chunk_acts.clear();
        chunk_rows.clear();
        while row0 < rows && chunk_acts.len() < lanes_max {
            let rows_here = m.min(rows - row0);
            let mut act: Vec<E> = decode_words::<E>(&input[row0 * kf..(row0 + rows_here) * kf]);
            act.resize(m * kf, E::zero());
            chunk_acts.push(act);
            chunk_rows.push(rows_here);
            row0 += rows_here;
        }
        let outs = program
            .execute_rows(block, &chunk_acts, w)
            .map_err(|e| anyhow::anyhow!("functional execution: {e}"))?;
        for (out, &rows_here) in outs.iter().zip(chunk_rows.iter()) {
            let reduced: Vec<E> = out[..rows_here * nf].iter().map(|&v| E::reduce(v)).collect();
            out_words.extend(encode_words::<E>(&reduced));
        }
    }
    Ok(out_words)
}

/// Reference executor: naive f32 GEMM (tests / fallback).
pub struct NaiveExecutor;

impl TileExecutor for NaiveExecutor {
    fn gemm(
        &self,
        m: usize,
        k: usize,
        n: usize,
        iv: &[f32],
        wv: &[f32],
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(iv.len() == m * k && wv.len() == k * n, "shape mismatch");
        let mut o = vec![0f32; m * n];
        for mi in 0..m {
            for ki in 0..k {
                let a = iv[mi * k + ki];
                if a == 0.0 {
                    continue;
                }
                for ni in 0..n {
                    o[mi * n + ni] += a * wv[ki * n + ni];
                }
            }
        }
        Ok(o)
    }
    fn name(&self) -> &str {
        "naive"
    }
}

/// Routing + batching statistics — a point-in-time read model synthesized
/// by [`Server::stats`] from the server's metrics registry (the registry's
/// atomic counters are the single telemetry path; this struct is a
/// convenience view, not separate state).
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub served: u64,
    pub batches: u64,
    pub mapper_cache_hits: u64,
    pub mapper_cache_misses: u64,
    /// Chains compiled into programs (`register` calls that ran the
    /// chain-aware mapper). Program *requests* never bump this: compile
    /// once, serve many.
    pub program_compiles: u64,
    /// Sessions registered from a deployable `.minisa` artifact
    /// ([`Server::register`] with an [`ArtifactSource::Artifact`]/`Path`
    /// source) — zero mapper work, the loaded counterpart of
    /// `program_compiles`.
    pub artifact_loads: u64,
    /// Requests served through a registered program.
    pub program_served: u64,
    /// Requests answered with an error.
    pub errors: u64,
    pub total_service_us: f64,
    pub max_batch: usize,
    /// Requests rejected by admission control (`ErrorCode::Shed`). Not
    /// counted in `errors`: shedding is policy, not failure.
    pub shed: u64,
    /// Requests answered `deadline_exceeded` at any hand-off point
    /// (admission, batch formation, queue, post-execution stitch).
    pub expired: u64,
    /// Requests whose session was unregistered while they were in flight
    /// (`ErrorCode::SessionGone`); also counted in `errors`.
    pub session_gone: u64,
    /// Requests injected into an already-submitted open batch (continuous
    /// batching) instead of waiting for the next leader cycle.
    pub injected: u64,
    /// Completed zero-downtime session swaps ([`Server::swap`]).
    pub swaps: u64,
    /// Swap attempts that failed validation or build; the old session kept
    /// serving throughout.
    pub swap_failed: u64,
    /// Registry program-cache hits observed by this server's
    /// registrations/swaps (a hit shares the cached allocation; no blob
    /// read, no decode).
    pub registry_hits: u64,
    /// Registry program-cache misses (full verified load + decode).
    pub registry_misses: u64,
    /// Registry program-cache LRU evictions triggered by this server's
    /// loads.
    pub registry_evictions: u64,
}

impl ServeStats {
    pub fn mean_latency_us(&self) -> f64 {
        if self.served == 0 {
            0.0
        } else {
            self.total_service_us / self.served as f64
        }
    }
    pub fn throughput_per_s(&self, wall_us: f64) -> f64 {
        if wall_us <= 0.0 {
            0.0
        } else {
            self.served as f64 / (wall_us / 1e6)
        }
    }
}

/// Registry handles for every front-door counter, fetched once at server
/// construction so the hot path is a single relaxed atomic add per event —
/// the registry's name-map mutex is never touched while serving.
struct ServeCounters {
    served: Counter,
    program_served: Counter,
    batches: Counter,
    mapper_cache_hits: Counter,
    mapper_cache_misses: Counter,
    program_compiles: Counter,
    artifact_loads: Counter,
    errors: Counter,
    shed: Counter,
    expired: Counter,
    session_gone: Counter,
    injected: Counter,
    /// Total service time in integer nanoseconds — a counter rather than a
    /// float so concurrent accumulation stays exact.
    service_ns: Counter,
    swaps: Counter,
    swap_failed: Counter,
    registry_hits: Counter,
    registry_misses: Counter,
    registry_evictions: Counter,
    max_batch: Gauge,
}

impl ServeCounters {
    fn new(reg: &MetricsRegistry) -> Self {
        Self {
            served: reg.counter("serve_served_total"),
            program_served: reg.counter("serve_program_served_total"),
            batches: reg.counter("serve_batches_total"),
            mapper_cache_hits: reg.counter("serve_mapper_cache_hits_total"),
            mapper_cache_misses: reg.counter("serve_mapper_cache_misses_total"),
            program_compiles: reg.counter("serve_program_compiles_total"),
            artifact_loads: reg.counter("serve_artifact_loads_total"),
            errors: reg.counter("serve_errors_total"),
            shed: reg.counter("serve_shed_total"),
            expired: reg.counter("serve_expired_total"),
            session_gone: reg.counter("serve_session_gone_total"),
            injected: reg.counter("serve_injected_total"),
            service_ns: reg.counter("serve_service_time_ns_total"),
            swaps: reg.counter("serve_swaps_total"),
            swap_failed: reg.counter("serve_swap_failed_total"),
            registry_hits: reg.counter("registry_hits_total"),
            registry_misses: reg.counter("registry_misses_total"),
            registry_evictions: reg.counter("registry_evictions_total"),
            max_batch: reg.gauge("serve_max_batch"),
        }
    }
}

/// Traces pulled off a batch's requests at dispatch time, keyed by request
/// id. The dispatchers work on shared `&[Request]` slices, so execute /
/// stitch / respond stage marks go through this owned side table instead of
/// needing mutable access to the requests. Traces of requests that error
/// are simply dropped with the table.
#[derive(Default)]
struct BatchTraces(Vec<(u64, RequestTrace)>);

impl BatchTraces {
    fn pull(batch: &mut [Request]) -> Self {
        Self(batch.iter_mut().filter_map(|r| r.trace.take().map(|t| (r.id, t))).collect())
    }

    fn mark_all(&mut self, stage: Stage) {
        for (_, t) in &mut self.0 {
            t.mark(stage);
        }
    }

    fn take(&mut self, id: u64) -> Option<RequestTrace> {
        self.0.iter().position(|(i, _)| *i == id).map(|p| self.0.remove(p).1)
    }
}

/// Per-shape cache slot. `done` is the published decision (lock-free reads
/// once set); `build` is the in-flight guard that makes concurrent misses
/// on one shape run the mapper exactly once.
#[derive(Default)]
struct ShapeSlot {
    done: OnceLock<Option<Decision>>,
    build: Mutex<()>,
}

/// Weights resident in a session, in the session's number system.
#[derive(Clone)]
enum SessionWeights {
    F32(Arc<Vec<Vec<f32>>>),
    Words(Arc<WordWeights>),
}

/// A registered model session: compiled program + element type + resident
/// weights. One session has exactly one element type, fixed at
/// registration.
#[derive(Clone)]
struct Session {
    program: Arc<Program>,
    elem: ElemType,
    weights: SessionWeights,
}

/// How a session came to be — decides which provenance counter moves
/// (`artifact_loads` vs `program_compiles`), for registrations and swaps
/// alike.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SessionOrigin {
    /// Loaded from a deployable artifact (file, memory, or registry) —
    /// zero mapper runs.
    Loaded,
    /// Compiled here by the chain-aware mapper.
    Compiled,
}

/// Why a [`Server::swap`] did not happen. The old session keeps serving in
/// every case — a failed swap is never a partial swap.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SwapError {
    /// No session is registered under this id.
    UnknownProgram(ProgramId),
    /// Another swap of the same program is already building its
    /// replacement.
    InProgress(ProgramId),
    /// The replacement failed to build or failed validation (shape/element
    /// compatibility with the running session).
    Failed(String),
}

impl std::fmt::Display for SwapError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SwapError::UnknownProgram(id) => write!(f, "swap: unknown program {id:?}"),
            SwapError::InProgress(id) => write!(f, "swap_in_progress: program {id:?}"),
            SwapError::Failed(m) => write!(f, "swap_failed: {m}"),
        }
    }
}

impl std::error::Error for SwapError {}

/// Where a model session comes from — the single argument of
/// [`Server::register`]. The canonical deployment path is an [`Artifact`]
/// (in memory or a `.minisa` file): compiled once anywhere, loaded here with
/// **zero mapper runs**. The `Compile*` variants keep the old
/// compile-on-register behaviour for callers that never persist.
pub enum ArtifactSource {
    /// A parsed artifact (must carry a weights payload — sessions need
    /// resident weights).
    Artifact(Box<Artifact>),
    /// Load a `.minisa` container from disk.
    Path(PathBuf),
    /// Resolve and load from the server's attached artifact registry
    /// ([`ServerOptions::registry`]). `key` is any [`Registry::find`] spec:
    /// an exact `<content>-<arch>` key, a content-hash prefix, or a model
    /// name (resolved against the fleet's eligible arch fingerprints).
    /// Loads go through the shared program cache, so N sessions of one
    /// content hash share a single decoded weight allocation.
    Registry { key: String },
    /// Back-compat: compile the chain here, f32 weights
    /// (the former `register_chain`).
    CompileF32 { chain: Chain, weights: Vec<Vec<f32>> },
    /// Back-compat: compile the chain here, canonical-word weights under an
    /// explicit element backend (the former `register_chain_elem`).
    CompileWords { chain: Chain, weights: Vec<Vec<u64>>, elem: ElemType },
}

/// Shared weight-shape validation for the compile-on-register sources.
fn validate_weight_dims<T>(chain: &Chain, weights: &[Vec<T>], unit: &str) -> anyhow::Result<()> {
    anyhow::ensure!(
        weights.len() == chain.layers.len(),
        "chain has {} layers, got {} weight matrices",
        chain.layers.len(),
        weights.len()
    );
    for (g, w) in chain.layers.iter().zip(weights) {
        anyhow::ensure!(
            w.len() == g.k * g.n,
            "layer {} weight is {} {unit}, expected {}×{}",
            g.name,
            w.len(),
            g.k,
            g.n
        );
    }
    Ok(())
}

/// How requests group into one executor dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum BatchKey {
    /// Shape plus weight identity (the `Arc` pointer, not its contents).
    Gemm { m: usize, k: usize, n: usize, weight: usize },
    Program(ProgramId),
    /// Word-encoded program requests: a distinct variant so f32 and
    /// element-typed payloads never co-batch, even under one program id —
    /// element types must never mix inside a dispatch.
    ProgramWords(ProgramId),
}

fn batch_key(r: &Request) -> BatchKey {
    match &r.payload {
        Payload::Gemm { m, k, n, weight, .. } => {
            BatchKey::Gemm { m: *m, k: *k, n: *n, weight: Arc::as_ptr(weight) as usize }
        }
        Payload::Program { program, .. } => BatchKey::Program(*program),
        Payload::ProgramWords { program, .. } => BatchKey::ProgramWords(*program),
    }
}

/// Device-routing affinity of a batch key: same key → same surviving
/// device, so a session's per-device simulators and plan caches stay warm.
fn affinity(key: &BatchKey) -> u64 {
    let mut h = DefaultHasher::new();
    key.hash(&mut h);
    h.finish()
}

/// A batch submitted to the fleet but not yet claimed by a device worker.
/// The leader keeps it addressable by [`BatchKey`] so compatible arrivals
/// inject into it (continuous batching) instead of waiting for the next
/// leader cycle; the claiming worker `take`s the request list exactly once.
struct OpenBatch {
    reqs: Mutex<Option<Vec<Request>>>,
}

/// Serving-stack sizing knobs.
#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// Simulated FEATHER+ devices in the fleet (1 = the classic inline
    /// single-device leader). Ignored when `device_archs` is non-empty.
    pub devices: usize,
    /// Per-device architectures for a heterogeneous fleet (the CLI's
    /// `--device-archs 4x4,8x16,...`). Empty means a homogeneous fleet of
    /// `devices` copies of the server config. When set, the fleet has one
    /// device per entry and session work is placed only on devices whose
    /// arch fingerprint matches the session's program (see
    /// [`super::fleet::Device::eligible`]).
    pub device_archs: Vec<ArchConfig>,
    /// Minimum activation rows per tile-parallel shard (see
    /// [`super::fleet::FleetOptions::shard_min_rows`]).
    pub shard_min_rows: usize,
    /// Max requests batched per dispatch.
    pub max_batch: usize,
    /// Per-shard watchdog budget in milliseconds, forwarded to
    /// [`super::fleet::FleetOptions::shard_timeout_ms`]; 0 disables.
    pub shard_timeout_ms: u64,
    /// Front-door admission policy. Defaults disable every limit, so a
    /// default-constructed server behaves exactly like the pre-admission
    /// front door.
    pub admission: AdmissionOptions,
    /// Request tracing policy: disabled by default (zero per-request
    /// overhead beyond one relaxed sequence increment when enabled with
    /// sampling). Sampled requests carry a [`RequestTrace`] through the
    /// pipeline and record per-stage latency histograms on completion.
    pub tracing: TraceOptions,
    /// Artifact registry for [`ArtifactSource::Registry`] sessions and
    /// registry-sourced swaps. Shared (`Arc`) so several servers — or a
    /// server and its operational tooling — see one program cache.
    pub registry: Option<Arc<Registry>>,
}

impl Default for ServerOptions {
    fn default() -> Self {
        Self {
            devices: 1,
            device_archs: Vec::new(),
            shard_min_rows: 8,
            max_batch: 8,
            shard_timeout_ms: 0,
            admission: AdmissionOptions::default(),
            tracing: TraceOptions::default(),
            registry: None,
        }
    }
}

/// The serving coordinator (leader). Owns the model sessions, the per-shape
/// mapper cache, the batcher, and the device fleet that executes dispatches.
pub struct Server {
    cfg: ArchConfig,
    fleet: Arc<Fleet>,
    opts: MapperOptions,
    /// Shape → mapping decision routing table for ad-hoc GEMMs. `RwLock` so
    /// concurrent hits on *different* shapes share a read lock; per-shape
    /// `ShapeSlot`s de-duplicate concurrent mapper runs. Infeasible shapes
    /// cache `None` so repeat requests don't re-run a search that cannot
    /// succeed.
    cache: RwLock<HashMap<(usize, usize, usize), Arc<ShapeSlot>>>,
    /// Registered model sessions (compile-once/serve-many).
    sessions: RwLock<HashMap<ProgramId, Session>>,
    next_program: AtomicU64,
    /// Unified telemetry: every front-door counter lives in this registry
    /// (read it back as a [`ServeStats`] view via [`Self::stats`], or
    /// export it via [`Self::metrics_snapshot`]); sampled request traces
    /// record their per-stage histograms here too.
    metrics: Arc<MetricsRegistry>,
    /// Cached registry handles — the serving hot path never touches the
    /// registry's name map.
    ctr: ServeCounters,
    /// Request-tracing policy for this server.
    tracing: TraceOptions,
    /// Arrival sequence number driving trace sampling.
    arrivals: AtomicU64,
    /// Max requests batched per dispatch.
    pub max_batch: usize,
    /// The front-door gate: deadlines, per-session rate limits, and the
    /// global in-flight budget with graduated QoS shedding.
    admission: AdmissionController,
    /// Submitted-but-unclaimed fleet batches by key — the continuous-
    /// batching injection surface (`run_fleet` adds compatible arrivals
    /// here until a device worker claims the batch).
    open: Mutex<HashMap<BatchKey, Arc<OpenBatch>>>,
    /// Attached artifact registry ([`ArtifactSource::Registry`] sessions,
    /// registry-sourced swaps).
    registry: Option<Arc<Registry>>,
    /// Programs with a swap in flight: at most one [`Self::swap`] builds a
    /// replacement per program at a time; a second attempt is the typed
    /// [`SwapError::InProgress`], never a queue.
    swapping: Mutex<HashSet<ProgramId>>,
}

impl Server {
    pub fn new(cfg: &ArchConfig, executor: Arc<dyn TileExecutor>) -> Self {
        Self::with_options(cfg, executor, ServerOptions::default())
    }

    /// Build a server over an N-device fleet. The executor handle is shared
    /// by every device (simulated devices are stateless per call; stateful
    /// per-device plan caches live in the fleet's devices themselves).
    pub fn with_options(
        cfg: &ArchConfig,
        executor: Arc<dyn TileExecutor>,
        sopts: ServerOptions,
    ) -> Self {
        let fopts = FleetOptions {
            devices: if sopts.device_archs.is_empty() {
                sopts.devices
            } else {
                sopts.device_archs.len()
            },
            shard_min_rows: sopts.shard_min_rows,
            shard_timeout_ms: sopts.shard_timeout_ms,
            ..Default::default()
        };
        let fleet = Arc::new(if sopts.device_archs.is_empty() {
            Fleet::new(cfg, executor, fopts)
        } else {
            Fleet::with_archs(&sopts.device_archs, executor, fopts)
        });
        let metrics = Arc::new(MetricsRegistry::new());
        let ctr = ServeCounters::new(&metrics);
        Self {
            cfg: cfg.clone(),
            fleet,
            opts: MapperOptions { full_layout_search: false, threads: 1, ..Default::default() },
            cache: RwLock::new(HashMap::new()),
            sessions: RwLock::new(HashMap::new()),
            next_program: AtomicU64::new(1),
            metrics,
            ctr,
            tracing: sopts.tracing,
            arrivals: AtomicU64::new(0),
            max_batch: sopts.max_batch,
            admission: AdmissionController::new(sopts.admission),
            open: Mutex::new(HashMap::new()),
            registry: sopts.registry,
            swapping: Mutex::new(HashSet::new()),
        }
    }

    /// The front-door admission gate (in-flight introspection for tests and
    /// operational tooling).
    pub fn admission(&self) -> &AdmissionController {
        &self.admission
    }

    /// Fleet utilisation roll-up with the front door's shed/expired counters
    /// folded in (the fleet itself never sees rejected requests).
    pub fn fleet_report(&self, window_us: f64) -> crate::perf::FleetReport {
        let mut rep = self.fleet.report(window_us);
        rep.shed = self.ctr.shed.get();
        rep.expired = self.ctr.expired.get();
        rep
    }

    /// Point-in-time serving statistics, read from the metrics registry's
    /// atomic counters (there is no separate stats state to get out of
    /// sync with the exporters).
    pub fn stats(&self) -> ServeStats {
        ServeStats {
            served: self.ctr.served.get(),
            batches: self.ctr.batches.get(),
            mapper_cache_hits: self.ctr.mapper_cache_hits.get(),
            mapper_cache_misses: self.ctr.mapper_cache_misses.get(),
            program_compiles: self.ctr.program_compiles.get(),
            artifact_loads: self.ctr.artifact_loads.get(),
            program_served: self.ctr.program_served.get(),
            errors: self.ctr.errors.get(),
            total_service_us: self.ctr.service_ns.get() as f64 / 1e3,
            max_batch: self.ctr.max_batch.get() as usize,
            shed: self.ctr.shed.get(),
            expired: self.ctr.expired.get(),
            session_gone: self.ctr.session_gone.get(),
            injected: self.ctr.injected.get(),
            swaps: self.ctr.swaps.get(),
            swap_failed: self.ctr.swap_failed.get(),
            registry_hits: self.ctr.registry_hits.get(),
            registry_misses: self.ctr.registry_misses.get(),
            registry_evictions: self.ctr.registry_evictions.get(),
        }
    }

    /// This server's metrics registry — counters, gauges, and (when
    /// tracing is on) per-stage latency histograms. Exporters and tests
    /// read from here; [`crate::obs::export`] renders it.
    pub fn metrics(&self) -> &Arc<MetricsRegistry> {
        &self.metrics
    }

    /// Full observability snapshot: the registry's counters / gauges /
    /// stage histograms with the fleet's per-device utilisation and live
    /// stall accounting folded in as `fleet_dev{i}_*` gauges (including
    /// the modeled MINISA-vs-micro compute and fetch-stall cycle split —
    /// the paper's Table I breakdown measured at fleet scale).
    pub fn metrics_snapshot(&self, window_us: f64) -> Snapshot {
        let rep = self.fleet_report(window_us);
        let g = |name: String, v: f64| self.metrics.gauge(&name).set(v);
        g("fleet_devices".to_string(), rep.devices.len() as f64);
        for d in &rep.devices {
            let i = d.device;
            let dg = |k: &str, v: f64| g(format!("fleet_dev{i}_{k}"), v);
            dg("busy_us", d.busy);
            dg("idle_us", d.stall);
            dg("dispatches", d.dispatches as f64);
            dg("shards", d.shards as f64);
            dg("rows", d.rows as f64);
            dg("steals", d.steals as f64);
            dg("requeues", d.requeues as f64);
            dg("retries", d.retries as f64);
            dg("watchdog_trips", d.watchdog_trips as f64);
            dg("recoveries", d.recoveries as f64);
            dg("plan_compiles", d.plan_compiles as f64);
            dg("waves", d.waves as f64);
            dg("minisa_compute_cycles", d.modeled.minisa_compute_cycles);
            dg("minisa_fetch_stall_cycles", d.modeled.minisa_fetch_stall_cycles);
            dg("micro_compute_cycles", d.modeled.micro_compute_cycles);
            dg("micro_fetch_stall_cycles", d.modeled.micro_fetch_stall_cycles);
            dg("predict_err", d.predict_err());
        }
        let m = rep.modeled();
        g("fleet_minisa_stall_fraction".to_string(), m.minisa_stall_fraction());
        g("fleet_micro_stall_fraction".to_string(), m.micro_stall_fraction());
        g("fleet_control_speedup".to_string(), m.control_speedup());
        g("fleet_fetch_contention".to_string(), rep.shared_fetch().micro_contention);
        self.metrics.snapshot()
    }

    /// The device fleet executing this server's dispatches (per-device
    /// stats, failure injection, `report()` roll-ups).
    pub fn fleet(&self) -> &Arc<Fleet> {
        &self.fleet
    }

    /// Register a model session from any [`ArtifactSource`] — the one
    /// registration surface.
    ///
    /// * `Artifact`/`Path`: the canonical deployment path. The container's
    ///   config must match this server's; the program is rebuilt by
    ///   decoding the shipped instruction stream
    ///   ([`Program::from_artifact`]) with **zero mapper runs** — the
    ///   `artifact_loads` stat moves, `program_compiles` does not.
    /// * `CompileF32`/`CompileWords`: compile-on-register back-compat (one
    ///   chain-aware mapper run; `program_compiles` moves).
    pub fn register(&self, src: ArtifactSource) -> anyhow::Result<ProgramId> {
        let (session, origin) = self.build_session(src)?;
        let id = self.insert_session(session);
        match origin {
            SessionOrigin::Loaded => self.ctr.artifact_loads.inc(),
            SessionOrigin::Compiled => self.ctr.program_compiles.inc(),
        }
        Ok(id)
    }

    /// Build a [`Session`] from a source without touching the session map —
    /// the shared back half of [`Self::register`] and [`Self::swap`] (a
    /// swap must do all of this *off* the serving path, before the atomic
    /// switch).
    fn build_session(&self, src: ArtifactSource) -> anyhow::Result<(Session, SessionOrigin)> {
        match src {
            ArtifactSource::Path(path) => {
                // One read, shared buffer: parse borrows windows of the
                // mmap-shaped `Arc<[u8]>` instead of re-reading or copying
                // the payload (`Artifact::load_shared`).
                let art = Artifact::load_shared(&path)
                    .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
                self.build_session(ArtifactSource::Artifact(Box::new(art)))
            }
            ArtifactSource::Registry { key } => {
                let reg = self.registry.as_ref().ok_or_else(|| {
                    anyhow::anyhow!(
                        "ArtifactSource::Registry {key:?} but no registry attached \
                         (ServerOptions::registry)"
                    )
                })?;
                let eligible = self.fleet.fingerprints();
                let rkey = reg
                    .find(&key, Some(&eligible))
                    .map_err(|e| anyhow::anyhow!("registry find {key:?}: {e}"))?;
                anyhow::ensure!(
                    eligible.contains(&rkey.arch),
                    "registry key {rkey} was compiled for fingerprint {:016x} but no fleet \
                     device matches",
                    rkey.arch,
                );
                let (loaded, outcome) =
                    reg.load(rkey).map_err(|e| anyhow::anyhow!("registry load {rkey}: {e}"))?;
                if outcome.hit {
                    self.ctr.registry_hits.inc();
                } else {
                    self.ctr.registry_misses.inc();
                }
                self.ctr.registry_evictions.add(outcome.evicted);
                let weights = match &loaded.weights {
                    LoadedWeights::F32(w) => SessionWeights::F32(Arc::clone(w)),
                    LoadedWeights::Words(w) => SessionWeights::Words(Arc::clone(w)),
                };
                let session = Session {
                    program: Arc::clone(&loaded.program),
                    elem: loaded.elem,
                    weights,
                };
                Ok((session, SessionOrigin::Loaded))
            }
            ArtifactSource::Artifact(art) => {
                // Heterogeneous fleets accept any artifact that at least one
                // device can execute; placement eligibility then keeps the
                // session's work on fingerprint-matching devices only.
                let fp = crate::artifact::arch_fingerprint(&art.cfg);
                anyhow::ensure!(
                    self.fleet.devices().iter().any(|d| d.fingerprint() == fp),
                    "artifact was compiled for {} (fingerprint {:016x}) but this server runs {} \
                     ({:016x}) and no fleet device matches",
                    art.cfg.name(),
                    art.fingerprint(),
                    self.cfg.name(),
                    crate::artifact::arch_fingerprint(&self.cfg),
                );
                anyhow::ensure!(
                    art.payload.is_some(),
                    "artifact carries no weights payload; serving sessions need resident weights \
                     (compile with `Compiler::weights`)"
                );
                let program = Program::from_artifact(&art)
                    .map_err(|e| anyhow::anyhow!("artifact load: {e}"))?;
                let payload = art.payload.expect("checked above");
                let elem = payload.elem;
                let weights = if elem == ElemType::F32 {
                    // An f32 payload serves the classic f32 session path
                    // (`Payload::Program`); words are IEEE bit patterns.
                    SessionWeights::F32(Arc::new(
                        payload.weights.iter().map(|m| m.decode::<f32>()).collect(),
                    ))
                } else {
                    SessionWeights::Words(Arc::new(WordWeights::from_matrices(
                        &payload.weights,
                        elem,
                    )))
                };
                let session = Session { program: Arc::new(program), elem, weights };
                Ok((session, SessionOrigin::Loaded))
            }
            ArtifactSource::CompileF32 { chain, weights } => {
                chain.validate().map_err(anyhow::Error::msg)?;
                validate_weight_dims(&chain, &weights, "elements")?;
                let program = Program::compile(&self.cfg, &chain, &self.opts).ok_or_else(|| {
                    anyhow::anyhow!("no feasible mapping for chain on {}", self.cfg.name())
                })?;
                let session = Session {
                    program: Arc::new(program),
                    elem: ElemType::F32,
                    weights: SessionWeights::F32(Arc::new(weights)),
                };
                Ok((session, SessionOrigin::Compiled))
            }
            ArtifactSource::CompileWords { chain, weights, elem } => {
                chain.validate().map_err(anyhow::Error::msg)?;
                validate_weight_dims(&chain, &weights, "words")?;
                let program = Program::compile(&self.cfg, &chain, &self.opts).ok_or_else(|| {
                    anyhow::anyhow!("no feasible mapping for chain on {}", self.cfg.name())
                })?;
                // Decode-once: the per-backend form is built here, not per
                // dispatch (for ModP that is one Montgomery conversion per
                // weight element — session-sized work).
                let session = Session {
                    program: Arc::new(program),
                    elem,
                    weights: SessionWeights::Words(Arc::new(WordWeights::new(weights, elem))),
                };
                Ok((session, SessionOrigin::Compiled))
            }
        }
    }

    fn insert_session(&self, session: Session) -> ProgramId {
        let id = ProgramId(self.next_program.fetch_add(1, Ordering::Relaxed));
        self.sessions.write().unwrap().insert(id, session);
        id
    }

    /// Zero-downtime hot swap: replace the session behind `id` with a
    /// freshly built one from `src`, without ever leaving `id`
    /// unregistered.
    ///
    /// The replacement is compiled/loaded entirely **off** the serving path
    /// (requests keep dispatching against the old session), then validated
    /// for compatibility — same element type and same in/out feature widths,
    /// since admitted requests were sized against the old program — and
    /// only then installed by one atomic map-entry replacement. Dispatchers
    /// clone the `Session` out of the map before executing, so in-flight
    /// batches drain against whichever version admitted them and answer
    /// bit-exact for that version; requests arriving after the switch see
    /// the new one. No request is ever dropped, duplicated, or answered
    /// with a swap-attributable error.
    ///
    /// Failures are typed ([`SwapError`]) and leave the old session
    /// serving; `serve_swaps_total` / `serve_swap_failed_total` account the
    /// outcomes. Provenance counters move exactly as in
    /// [`Self::register`]: a loaded replacement bumps `artifact_loads`, a
    /// compiled one bumps `program_compiles` — a swap on the serving path
    /// never hides mapper work.
    pub fn swap(&self, id: ProgramId, src: ArtifactSource) -> Result<(), SwapError> {
        let old = self
            .sessions
            .read()
            .unwrap()
            .get(&id)
            .cloned()
            .ok_or(SwapError::UnknownProgram(id))?;
        if !self.swapping.lock().unwrap().insert(id) {
            // Deliberately not counted as swap_failed: nothing was
            // attempted, the first swap still owns the outcome.
            return Err(SwapError::InProgress(id));
        }
        let outcome = match self.build_session(src) {
            Err(e) => Err(SwapError::Failed(e.to_string())),
            Ok((new, origin)) => {
                let (op, np) = (&old.program, &new.program);
                if new.elem != old.elem
                    || np.in_features() != op.in_features()
                    || np.out_features() != op.out_features()
                {
                    Err(SwapError::Failed(format!(
                        "replacement is {:?} {}→{}, running session is {:?} {}→{}",
                        new.elem,
                        np.in_features(),
                        np.out_features(),
                        old.elem,
                        op.in_features(),
                        op.out_features(),
                    )))
                } else {
                    match origin {
                        SessionOrigin::Loaded => self.ctr.artifact_loads.inc(),
                        SessionOrigin::Compiled => self.ctr.program_compiles.inc(),
                    }
                    // The atomic switch: one map-entry replacement under the
                    // write lock. In-flight dispatches hold clones of the
                    // old session and drain untouched.
                    self.sessions.write().unwrap().insert(id, new);
                    Ok(())
                }
            }
        };
        self.swapping.lock().unwrap().remove(&id);
        match &outcome {
            Ok(()) => self.ctr.swaps.inc(),
            Err(_) => self.ctr.swap_failed.inc(),
        }
        outcome
    }

    /// Pointer identity of the session's resident weight allocation — lets
    /// tests and operational tooling *prove* that sessions loaded from one
    /// registry content hash share a single buffer, and that a swap
    /// actually changed the serving weights.
    pub fn weights_ptr(&self, id: ProgramId) -> Option<usize> {
        self.sessions.read().unwrap().get(&id).map(|s| match &s.weights {
            SessionWeights::F32(w) => Arc::as_ptr(w) as usize,
            SessionWeights::Words(w) => Arc::as_ptr(w) as usize,
        })
    }

    /// Register a model chain: runs the chain-aware mapper, fuses the
    /// trace, precompiles wave plans — exactly once — and pins the weights
    /// in the session. Requests then reference the returned [`ProgramId`].
    /// (Compile-on-register wrapper over [`Self::register`].)
    pub fn register_chain(&self, chain: &Chain, weights: Vec<Vec<f32>>) -> anyhow::Result<ProgramId> {
        self.register(ArtifactSource::CompileF32 { chain: chain.clone(), weights })
    }

    /// Register a model chain under an explicit element backend: weights
    /// arrive as canonical datapath words in `elem`'s encoding (e.g. field
    /// residues for a `ModP` session — `workloads::ntt::twiddle_words`
    /// produces NTT weights directly in this format). Compiles the chain
    /// exactly once, like [`Self::register_chain`]; requests use
    /// [`Payload::ProgramWords`] and are answered (and batched) strictly
    /// within this session's element type.
    ///
    /// Note on `ElemType::I32` sessions: the i32 backend keeps the
    /// pre-`arith` unchecked i64 accumulation, so overflow-heavy untrusted
    /// operands can panic the executor under debug assertions (wrap in
    /// release). The dispatcher contains such panics and answers the batch
    /// with an error response; quantized (small-magnitude) operands are the
    /// intended use.
    pub fn register_chain_elem(
        &self,
        chain: &Chain,
        weights: Vec<Vec<u64>>,
        elem: ElemType,
    ) -> anyhow::Result<ProgramId> {
        self.register(ArtifactSource::CompileWords { chain: chain.clone(), weights, elem })
    }

    /// The compiled program behind a session, if registered.
    pub fn program(&self, id: ProgramId) -> Option<Arc<Program>> {
        self.sessions.read().unwrap().get(&id).map(|s| Arc::clone(&s.program))
    }

    /// The element type a session was registered with.
    pub fn session_elem(&self, id: ProgramId) -> Option<ElemType> {
        self.sessions.read().unwrap().get(&id).map(|s| s.elem)
    }

    /// Drop a model session, releasing its program and resident weights
    /// (sessions pin potentially large weight matrices, so long-lived
    /// servers must unregister models they stop serving). In-flight
    /// dispatches already holding the session's `Arc` finish normally;
    /// requests that reach dispatch after this returns get a typed
    /// `session_gone` error response (ids that were never registered answer
    /// `unknown program` instead).
    pub fn unregister(&self, id: ProgramId) -> bool {
        self.sessions.write().unwrap().remove(&id).is_some()
    }

    /// Route a shape through the mapper (cached). Hot path: one shared
    /// cache read lock plus a lock-free `OnceLock` read and a single
    /// `Decision` clone; the hit/miss counters are relaxed atomic adds.
    pub fn route(&self, m: usize, k: usize, n: usize) -> Option<Decision> {
        let key = (m, k, n);
        let slot = {
            let cache = self.cache.read().unwrap();
            cache.get(&key).cloned()
        };
        let slot = match slot {
            Some(s) => s,
            None => {
                let mut cache = self.cache.write().unwrap();
                Arc::clone(cache.entry(key).or_default())
            }
        };
        if let Some(d) = slot.done.get() {
            self.ctr.mapper_cache_hits.inc();
            return d.clone();
        }
        // In-flight guard: first arrival builds, racers block here and then
        // read the published result. A panic inside a previous build only
        // poisons the guard, not any data (`done` is a OnceLock), so clear
        // the poison and retry rather than wedging this shape forever.
        let _build = slot.build.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(d) = slot.done.get() {
            self.ctr.mapper_cache_hits.inc();
            return d.clone();
        }
        self.ctr.mapper_cache_misses.inc();
        let g = Gemm::new("serve", "online", m, k, n);
        let d = search(&self.cfg, &g, &self.opts);
        let _ = slot.done.set(d.clone());
        d
    }

    /// Pull the head request plus everything batchable with it (same
    /// [`BatchKey`], up to `max_batch`) out of `pending`.
    fn take_batch(pending: &mut Vec<Request>, max_batch: usize) -> Vec<Request> {
        let head = pending.remove(0);
        let key = batch_key(&head);
        let mut batch = vec![head];
        let mut rest = Vec::with_capacity(pending.len());
        for r in pending.drain(..) {
            if batch.len() < max_batch && batch_key(&r) == key {
                batch.push(r);
            } else {
                rest.push(r);
            }
        }
        *pending = rest;
        for r in batch.iter_mut() {
            if let Some(t) = r.trace.as_mut() {
                t.mark(Stage::Batch);
            }
        }
        batch
    }

    /// Gate one arriving request through admission control: admitted
    /// requests land in `pending`; shed/expired ones are answered with a
    /// typed error immediately (they never enter the in-flight count).
    fn admit_or_reject(
        &self,
        mut r: Request,
        pending: &mut Vec<Request>,
        tx: &Sender<Response>,
    ) -> Result<(), ()> {
        // Arrival: stamp a trace on sampled requests. Untraced requests pay
        // exactly one relaxed atomic increment here (and nothing at all
        // when tracing is off).
        if self.tracing.enabled {
            let seq = self.arrivals.fetch_add(1, Ordering::Relaxed);
            if self.tracing.sample(seq) {
                r.trace = Some(RequestTrace::start());
            }
        }
        match self.admission.admit(affinity(&batch_key(&r)), &r.admission, Instant::now()) {
            Verdict::Admit => {
                if let Some(t) = r.trace.as_mut() {
                    t.mark(Stage::Admission);
                }
                pending.push(r);
                Ok(())
            }
            Verdict::Shed => {
                let msg = format!("shed: {} request rejected by admission control", r.admission.qos);
                self.reject(r.id, ErrorCode::Shed, &msg, tx)
            }
            Verdict::Expired => {
                self.reject(r.id, ErrorCode::DeadlineExceeded, "deadline exceeded on arrival", tx)
            }
        }
    }

    /// Serve requests pulled from `rx`, sending responses on `tx`, with
    /// dispatch inline on this (leader) thread. Returns when `rx` closes.
    /// Requests batch by [`BatchKey`]: same-program activations stack into
    /// one taller pass through the chain; ad-hoc GEMMs stack when shape and
    /// weight identity agree.
    pub fn run(&self, rx: Receiver<Request>, tx: Sender<Response>) {
        let mut pending: Vec<Request> = Vec::new();
        loop {
            // Pull at least one request (blocking), then drain greedily.
            match rx.recv() {
                Ok(r) => {
                    if self.admit_or_reject(r, &mut pending, &tx).is_err() {
                        return;
                    }
                }
                Err(_) => break,
            }
            while pending.len() < self.max_batch {
                match rx.try_recv() {
                    Ok(r) => {
                        if self.admit_or_reject(r, &mut pending, &tx).is_err() {
                            return;
                        }
                    }
                    Err(_) => break,
                }
            }
            while !pending.is_empty() {
                let batch = Self::take_batch(&mut pending, self.max_batch);
                if self.dispatch(None, batch, &tx).is_err() {
                    return; // receiver dropped
                }
            }
        }
    }

    /// [`Self::run`] in fleet mode: the leader only forms batches; each is
    /// submitted to the fleet's work-stealing queues (routed by batch-key
    /// affinity) and dispatched on a device worker thread, so different
    /// batches execute concurrently on different devices. The caller starts
    /// the workers first and shuts the fleet down after this returns
    /// ([`spawn_with_options`] does both).
    pub fn run_fleet(self: &Arc<Self>, rx: Receiver<Request>, tx: Sender<Response>) {
        let mut pending: Vec<Request> = Vec::new();
        loop {
            match rx.recv() {
                Ok(r) => {
                    if self.admit_or_inject(r, &mut pending, &tx).is_err() {
                        return;
                    }
                }
                Err(_) => break,
            }
            while pending.len() < self.max_batch {
                match rx.try_recv() {
                    Ok(r) => {
                        if self.admit_or_inject(r, &mut pending, &tx).is_err() {
                            return;
                        }
                    }
                    Err(_) => break,
                }
            }
            while !pending.is_empty() {
                let batch = Self::take_batch(&mut pending, self.max_batch);
                self.submit_fleet(batch, &tx);
            }
        }
    }

    /// Fleet-mode admission: admitted requests first try to join a
    /// compatible open (submitted but unclaimed) batch — continuous
    /// batching — and only fall back to `pending` for the next leader cycle.
    fn admit_or_inject(
        self: &Arc<Self>,
        r: Request,
        pending: &mut Vec<Request>,
        tx: &Sender<Response>,
    ) -> Result<(), ()> {
        let mut staged = Vec::new();
        self.admit_or_reject(r, &mut staged, tx)?;
        if let Some(r) = staged.pop() {
            if let Some(r) = self.try_inject(r) {
                pending.push(r);
            }
        }
        Ok(())
    }

    /// Try to add an admitted request to a compatible open batch. Returns
    /// the request back if no open batch can take it (wrong key, already
    /// claimed, or full).
    fn try_inject(&self, mut r: Request) -> Option<Request> {
        let key = batch_key(&r);
        let open = lock_clean(&self.open);
        if let Some(ob) = open.get(&key) {
            // Map lock is held, so the claim path (same order: map → batch)
            // cannot take the list out from under this push.
            let mut reqs = lock_clean(&ob.reqs);
            if let Some(v) = reqs.as_mut() {
                if v.len() < self.max_batch {
                    if let Some(t) = r.trace.as_mut() {
                        t.mark(Stage::Batch);
                    }
                    v.push(r);
                    drop(reqs);
                    drop(open);
                    self.ctr.injected.inc();
                    return None;
                }
            }
        }
        Some(r)
    }

    /// Placement inputs for a batch: the session's arch fingerprint (None
    /// for ad-hoc GEMMs, which any device serves under the server config)
    /// and the predicted cycle cost charged against the chosen device's
    /// queue (see [`super::sched::predict_cycles`]).
    fn placement_cost(&self, bk: &BatchKey, batch: &[Request]) -> (Option<u64>, u64) {
        let pid = match bk {
            BatchKey::Program(pid) | BatchKey::ProgramWords(pid) => *pid,
            BatchKey::Gemm { .. } => return (None, 0),
        };
        // A missing session answers `session_gone` downstream; placement
        // just falls back to cost-blind routing.
        let Some(program) = self.program(pid) else { return (None, 0) };
        let rows: usize = batch
            .iter()
            .map(|r| match &r.payload {
                Payload::Program { rows, .. } | Payload::ProgramWords { rows, .. } => *rows,
                Payload::Gemm { .. } => 0,
            })
            .sum();
        let fp = crate::artifact::arch_fingerprint(&program.cfg);
        (Some(fp), super::sched::predict_cycles(&program, rows) as u64)
    }

    /// Submit one formed batch to the fleet, leaving it open for injection
    /// until a device worker claims it.
    fn submit_fleet(self: &Arc<Self>, batch: Vec<Request>, tx: &Sender<Response>) {
        let bk = batch_key(&batch[0]);
        let key = affinity(&bk);
        let (fingerprint, cost) = self.placement_cost(&bk, &batch);
        let ob = Arc::new(OpenBatch { reqs: Mutex::new(Some(batch)) });
        lock_clean(&self.open).insert(bk, Arc::clone(&ob));
        let srv = Arc::clone(self);
        let txc = tx.clone();
        self.fleet.submit_eligible(
            key,
            fingerprint,
            cost,
            Box::new(move |dev| {
                // A send failure means the response receiver is gone;
                // remaining jobs drain harmlessly.
                if let Some(batch) = srv.claim_open(&bk, &ob) {
                    let _ = srv.dispatch(Some(dev), batch, &txc);
                }
            }),
        );
    }

    /// Claim a submitted batch for execution: removes its open-map entry
    /// (if still current — a newer batch may have replaced it under the
    /// same key) so later arrivals form a fresh batch, then takes the
    /// request list exactly once.
    fn claim_open(&self, bk: &BatchKey, ob: &Arc<OpenBatch>) -> Option<Vec<Request>> {
        let mut open = lock_clean(&self.open);
        if let Some(cur) = open.get(bk) {
            if Arc::ptr_eq(cur, ob) {
                open.remove(bk);
            }
        }
        // Take while the map lock is held: injectors lock map → batch, so
        // after this releases no injector can still reach this batch.
        lock_clean(&ob.reqs).take()
    }

    fn dispatch(
        &self,
        dev: Option<&Arc<Device>>,
        batch: Vec<Request>,
        tx: &Sender<Response>,
    ) -> Result<(), ()> {
        // Hand-off point: drop requests whose deadline passed while queued.
        let now = Instant::now();
        let (mut live, dead): (Vec<Request>, Vec<Request>) =
            batch.into_iter().partition(|r| !r.admission.expired(now));
        if !dead.is_empty() {
            let ids: Vec<u64> = dead.iter().map(|r| r.id).collect();
            self.answer_error(
                &ids,
                dead.len(),
                ErrorCode::DeadlineExceeded,
                "deadline exceeded in queue",
                tx,
            )?;
        }
        // Traces leave the requests here: the dispatchers work on shared
        // request slices, so later stage marks go through this side table.
        let mut traces = BatchTraces::pull(&mut live);
        traces.mark_all(Stage::Dispatch);
        let Some(first) = live.first() else { return Ok(()) };
        match &first.payload {
            Payload::Gemm { .. } => self.dispatch_gemm(dev, &live, &mut traces, tx),
            Payload::Program { .. } => self.dispatch_program(dev, &live, &mut traces, tx),
            Payload::ProgramWords { .. } => {
                self.dispatch_program_words(dev, &live, &mut traces, tx)
            }
        }
    }

    /// Bump the counter matching an error class.
    fn account_error(&self, code: ErrorCode, n: u64) {
        match code {
            ErrorCode::Shed => self.ctr.shed.add(n),
            ErrorCode::DeadlineExceeded => self.ctr.expired.add(n),
            ErrorCode::SessionGone => {
                self.ctr.session_gone.add(n);
                self.ctr.errors.add(n);
            }
            ErrorCode::Watchdog | ErrorCode::Exec | ErrorCode::NoEligibleDevice => {
                self.ctr.errors.add(n)
            }
        }
    }

    fn error_response(id: u64, batch_size: usize, code: ErrorCode, msg: &str) -> Response {
        Response {
            id,
            output: Vec::new(),
            output_words: Vec::new(),
            service_us: 0.0,
            modeled_cycles: 0.0,
            batch_size,
            error: Some(msg.to_string()),
            code: Some(code),
            trace: None,
        }
    }

    /// Answer *admitted* requests with a typed error, balancing their
    /// in-flight count.
    fn answer_error(
        &self,
        ids: &[u64],
        batch_size: usize,
        code: ErrorCode,
        msg: &str,
        tx: &Sender<Response>,
    ) -> Result<(), ()> {
        self.account_error(code, ids.len() as u64);
        for &id in ids {
            tx.send(Self::error_response(id, batch_size, code, msg)).map_err(|_| ())?;
        }
        self.admission.complete(ids.len());
        Ok(())
    }

    /// Answer a request rejected *before* admission (shed / dead on
    /// arrival) — it never entered the in-flight count.
    fn reject(&self, id: u64, code: ErrorCode, msg: &str, tx: &Sender<Response>) -> Result<(), ()> {
        self.account_error(code, 1);
        tx.send(Self::error_response(id, 1, code, msg)).map_err(|_| ())
    }

    /// Fleet errors carry a `watchdog:` prefix when a slow shard exhausted
    /// the retry budget; surface those under the typed watchdog code. A
    /// `no eligible device` prefix means every arch-compatible device has
    /// dropped out of a heterogeneous fleet — its own typed code so
    /// clients can distinguish placement starvation from compute faults.
    fn exec_code(msg: &str) -> ErrorCode {
        if msg.starts_with("watchdog") {
            ErrorCode::Watchdog
        } else if msg.starts_with("no eligible device") {
            ErrorCode::NoEligibleDevice
        } else {
            ErrorCode::Exec
        }
    }

    /// Answer the given request ids with the same (execution) error.
    fn fail(&self, ids: &[u64], batch_size: usize, msg: &str, tx: &Sender<Response>) -> Result<(), ()> {
        self.answer_error(ids, batch_size, ErrorCode::Exec, msg, tx)
    }

    /// Classify a request for a session that isn't registered: ids the
    /// server has handed out before (`next_program` is a monotone counter
    /// starting at 1) were unregistered mid-flight → typed `session_gone`;
    /// ids it never issued are plain `unknown program` errors.
    fn missing_session(&self, pid: ProgramId) -> (ErrorCode, String) {
        let issued = pid.0 >= 1 && pid.0 < self.next_program.load(Ordering::Relaxed);
        if issued {
            (ErrorCode::SessionGone, format!("session {pid:?} was unregistered (session_gone)"))
        } else {
            (ErrorCode::Exec, format!("unknown program {pid:?}"))
        }
    }

    fn dispatch_gemm(
        &self,
        dev: Option<&Arc<Device>>,
        batch: &[Request],
        traces: &mut BatchTraces,
        tx: &Sender<Response>,
    ) -> Result<(), ()> {
        let t0 = std::time::Instant::now();
        let Payload::Gemm { m, k, n, weight, .. } = &batch[0].payload else { unreachable!() };
        let (m, k, n) = (*m, *k, *n);
        // The weight is shared across the batch (it is part of the batch
        // key), so one check covers every request.
        if weight.len() != k * n {
            let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
            let msg = format!("weight is {} elements, expected {k}×{n}", weight.len());
            return self.fail(&ids, batch.len(), &msg, tx);
        }
        // Reject malformed inputs individually — a bad co-batched request
        // must not poison (or, via an out-of-bounds slice in a backend,
        // kill) its neighbours' valid ones.
        let mut valid: Vec<&Request> = Vec::with_capacity(batch.len());
        for r in batch {
            let Payload::Gemm { input, .. } = &r.payload else { unreachable!() };
            if input.len() != m * k {
                let msg = format!("input is {} elements, expected {m}×{k}", input.len());
                self.fail(&[r.id], 1, &msg, tx)?;
            } else {
                valid.push(r);
            }
        }
        if valid.is_empty() {
            return Ok(());
        }
        let bm = m * valid.len();
        let decision = self.route(bm, k, n);
        // Stack inputs into one (batch·M) × K GEMM.
        let mut stacked = Vec::with_capacity(bm * k);
        for r in &valid {
            let Payload::Gemm { input, .. } = &r.payload else { unreachable!() };
            stacked.extend_from_slice(input);
        }
        // The fleet may split the stacked M range across idle devices
        // (tile-parallel); executor panics are contained per shard and
        // surface here as errors, so a poisoned operand answers with an
        // error response instead of killing the dispatching thread.
        let out = match self.fleet.gemm(dev, bm, k, n, &stacked, weight) {
            Ok(o) => o,
            Err(e) => {
                let ids: Vec<u64> = valid.iter().map(|r| r.id).collect();
                let msg = e.to_string();
                return self.answer_error(&ids, valid.len(), Self::exec_code(&msg), &msg, tx);
            }
        };
        // A backend returning the wrong amount of output must surface as an
        // error response, not an out-of-bounds panic of the leader thread.
        if out.len() != bm * n {
            let ids: Vec<u64> = valid.iter().map(|r| r.id).collect();
            let msg = format!("executor returned {} elements, expected {}", out.len(), bm * n);
            return self.fail(&ids, valid.len(), &msg, tx);
        }
        traces.mark_all(Stage::Execute);
        let elapsed = t0.elapsed();
        let service_us = elapsed.as_secs_f64() * 1e6;
        let modeled = decision.map(|d| d.report.total_cycles).unwrap_or(0.0);
        // Stitch hand-off point: a deadline that died during execution
        // answers `deadline_exceeded`, not a result nobody is waiting for.
        let now = Instant::now();
        traces.mark_all(Stage::Stitch);
        let live_n = valid.iter().filter(|r| !r.admission.expired(now)).count();
        self.ctr.served.add(live_n as u64);
        self.ctr.batches.inc();
        self.ctr.service_ns.add(elapsed.as_nanos() as u64 * live_n as u64);
        self.ctr.max_batch.set_max(valid.len() as f64);
        for (bi, r) in valid.iter().enumerate() {
            if r.admission.expired(now) {
                self.answer_error(
                    &[r.id],
                    valid.len(),
                    ErrorCode::DeadlineExceeded,
                    "deadline exceeded during execution",
                    tx,
                )?;
                continue;
            }
            let trace = traces.take(r.id).map(|mut t| {
                t.mark(Stage::Respond);
                t.record_into(&self.metrics);
                t
            });
            let resp = Response {
                id: r.id,
                output: out[bi * m * n..(bi + 1) * m * n].to_vec(),
                output_words: Vec::new(),
                service_us,
                modeled_cycles: modeled,
                batch_size: valid.len(),
                error: None,
                code: None,
                trace,
            };
            tx.send(resp).map_err(|_| ())?;
        }
        self.admission.complete(live_n);
        Ok(())
    }

    fn dispatch_program(
        &self,
        dev: Option<&Arc<Device>>,
        batch: &[Request],
        traces: &mut BatchTraces,
        tx: &Sender<Response>,
    ) -> Result<(), ()> {
        let Payload::Program { program: pid, .. } = &batch[0].payload else { unreachable!() };
        let session = self.sessions.read().unwrap().get(pid).cloned();
        let Some(session) = session else {
            let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
            let (code, msg) = self.missing_session(*pid);
            return self.answer_error(&ids, batch.len(), code, &msg, tx);
        };
        // f32 payloads only serve f32 sessions; element-typed sessions take
        // `ProgramWords` (representations must never mix in a dispatch).
        let SessionWeights::F32(weights) = &session.weights else {
            let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
            let msg = format!(
                "program {pid:?} is an {}-typed session; send ProgramWords payloads",
                session.elem
            );
            return self.fail(&ids, batch.len(), &msg, tx);
        };
        let weights = Arc::clone(weights);
        let program = Arc::clone(&session.program);
        self.dispatch_session_batch(
            batch,
            traces,
            tx,
            &session,
            "elements",
            |r| {
                let Payload::Program { rows, input, .. } = &r.payload else { unreachable!() };
                (*rows, input.as_slice())
            },
            |total_rows, stacked| {
                self.fleet.run_program(dev, &program, total_rows, stacked, &weights)
            },
            |o| (o, Vec::new()),
        )
    }

    /// Serve a batch of element-typed program requests: the shared batch
    /// protocol over canonical words and the session's element backend.
    fn dispatch_program_words(
        &self,
        dev: Option<&Arc<Device>>,
        batch: &[Request],
        traces: &mut BatchTraces,
        tx: &Sender<Response>,
    ) -> Result<(), ()> {
        let Payload::ProgramWords { program: pid, .. } = &batch[0].payload else { unreachable!() };
        let session = self.sessions.read().unwrap().get(pid).cloned();
        let Some(session) = session else {
            let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
            let (code, msg) = self.missing_session(*pid);
            return self.answer_error(&ids, batch.len(), code, &msg, tx);
        };
        let SessionWeights::Words(weights) = &session.weights else {
            let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
            let msg = format!("program {pid:?} is an f32 session; send Program payloads");
            return self.fail(&ids, batch.len(), &msg, tx);
        };
        let weights = Arc::clone(weights);
        let program = Arc::clone(&session.program);
        self.dispatch_session_batch(
            batch,
            traces,
            tx,
            &session,
            "words",
            |r| {
                let Payload::ProgramWords { rows, input, .. } = &r.payload else { unreachable!() };
                (*rows, input.as_slice())
            },
            |total_rows, stacked| {
                self.fleet.run_program_words(dev, &program, total_rows, stacked, &weights)
            },
            |o| (Vec::new(), o),
        )
    }

    /// The program-session batch protocol shared by the f32 and word
    /// dispatchers: reject malformed activations individually (a bad
    /// co-batched request must not poison its neighbours' valid ones),
    /// stack same-program activations into one taller chain pass, execute,
    /// surface wrong-sized executor output as error responses (never an
    /// out-of-bounds panic of the leader thread), account stats, and slice
    /// the stacked output back per request.
    #[allow(clippy::too_many_arguments)]
    fn dispatch_session_batch<T: Copy>(
        &self,
        batch: &[Request],
        traces: &mut BatchTraces,
        tx: &Sender<Response>,
        session: &Session,
        unit: &str,
        extract: impl Fn(&Request) -> (usize, &[T]),
        exec: impl FnOnce(usize, &[T]) -> anyhow::Result<Vec<T>>,
        wrap: impl Fn(Vec<T>) -> (Vec<f32>, Vec<u64>),
    ) -> Result<(), ()> {
        let t0 = std::time::Instant::now();
        let kf = session.program.in_features();
        let nf = session.program.out_features();
        let mut valid: Vec<&Request> = Vec::with_capacity(batch.len());
        for r in batch {
            let (rows, input) = extract(r);
            if input.len() != rows * kf {
                let msg = format!("activation is {} {unit}, expected {rows}×{kf}", input.len());
                self.fail(&[r.id], 1, &msg, tx)?;
            } else {
                valid.push(r);
            }
        }
        if valid.is_empty() {
            return Ok(());
        }
        let mut total_rows = 0usize;
        let mut stacked: Vec<T> = Vec::new();
        for r in &valid {
            let (rows, input) = extract(r);
            total_rows += rows;
            stacked.extend_from_slice(input);
        }
        // Contain executor panics: e.g. an i32 word session fed operands
        // whose i64 psum overflows panics in debug builds (`Element::mac`
        // keeps the pre-`arith` unchecked-add semantics). The leader thread
        // must answer with an error, not die with every queued request.
        let out = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            exec(total_rows, &stacked)
        })) {
            Ok(Ok(o)) => o,
            Ok(Err(e)) => {
                let ids: Vec<u64> = valid.iter().map(|r| r.id).collect();
                let msg = e.to_string();
                return self.answer_error(&ids, valid.len(), Self::exec_code(&msg), &msg, tx);
            }
            Err(_) => {
                let ids: Vec<u64> = valid.iter().map(|r| r.id).collect();
                let msg = "executor panicked (operands outside the element domain?)";
                return self.fail(&ids, valid.len(), msg, tx);
            }
        };
        if out.len() != total_rows * nf {
            let ids: Vec<u64> = valid.iter().map(|r| r.id).collect();
            let msg =
                format!("executor returned {} {unit}, expected {}", out.len(), total_rows * nf);
            return self.fail(&ids, valid.len(), &msg, tx);
        }
        traces.mark_all(Stage::Execute);
        let elapsed = t0.elapsed();
        let service_us = elapsed.as_secs_f64() * 1e6;
        // Stitch hand-off point: deadlines that died during execution
        // answer `deadline_exceeded` instead of a result nobody awaits.
        let now = Instant::now();
        traces.mark_all(Stage::Stitch);
        let live_n = valid.iter().filter(|r| !r.admission.expired(now)).count();
        self.ctr.served.add(live_n as u64);
        self.ctr.program_served.add(live_n as u64);
        self.ctr.batches.inc();
        self.ctr.service_ns.add(elapsed.as_nanos() as u64 * live_n as u64);
        self.ctr.max_batch.set_max(valid.len() as f64);
        let mut row0 = 0usize;
        for r in &valid {
            let (rows, _) = extract(r);
            let slice = out[row0 * nf..(row0 + rows) * nf].to_vec();
            row0 += rows;
            if r.admission.expired(now) {
                self.answer_error(
                    &[r.id],
                    valid.len(),
                    ErrorCode::DeadlineExceeded,
                    "deadline exceeded during execution",
                    tx,
                )?;
                continue;
            }
            let (output, output_words) = wrap(slice);
            let trace = traces.take(r.id).map(|mut t| {
                t.mark(Stage::Respond);
                t.record_into(&self.metrics);
                t
            });
            let resp = Response {
                id: r.id,
                output,
                output_words,
                service_us,
                modeled_cycles: session.program.total_cycles,
                batch_size: valid.len(),
                error: None,
                code: None,
                trace,
            };
            tx.send(resp).map_err(|_| ())?;
        }
        self.admission.complete(live_n);
        Ok(())
    }
}

/// Spawn a single-device server on its own thread; returns (request sender,
/// response receiver, join handle, server). The `Arc<Server>` registers
/// model sessions (`register_chain`) and reads stats while the loop runs.
pub fn spawn(
    cfg: &ArchConfig,
    executor: Arc<dyn TileExecutor>,
) -> (Sender<Request>, Receiver<Response>, std::thread::JoinHandle<ServeStats>, Arc<Server>) {
    spawn_with_options(cfg, executor, ServerOptions::default())
}

/// [`spawn`] with explicit sizing: a multi-device fleet serves with
/// per-device worker threads (started here, joined before the returned
/// handle resolves); one device keeps the classic inline leader. Either
/// way, every request sent before the request sender drops is answered
/// before the join handle yields the final stats.
pub fn spawn_with_options(
    cfg: &ArchConfig,
    executor: Arc<dyn TileExecutor>,
    opts: ServerOptions,
) -> (Sender<Request>, Receiver<Response>, std::thread::JoinHandle<ServeStats>, Arc<Server>) {
    let (req_tx, req_rx) = channel::<Request>();
    let (resp_tx, resp_rx) = channel::<Response>();
    let server = Arc::new(Server::with_options(cfg, executor, opts));
    let srv = Arc::clone(&server);
    let handle = std::thread::spawn(move || {
        if srv.fleet.device_count() > 1 {
            srv.fleet.start_workers();
            srv.run_fleet(req_rx, resp_tx);
            // Joins workers; stranded jobs (all-devices-dropped) drain
            // inline so their requests still answer.
            srv.fleet.shutdown();
        } else {
            srv.run(req_rx, resp_tx);
        }
        srv.stats()
    });
    (req_tx, resp_rx, handle, server)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Lcg;

    fn shared_weight(k: usize, n: usize) -> Arc<Vec<f32>> {
        let mut wr = Lcg::new(999);
        Arc::new(wr.f32_matrix(k, n))
    }

    fn req(id: u64, m: usize, k: usize, n: usize, seed: u64, w: &Arc<Vec<f32>>) -> Request {
        let mut rng = Lcg::new(seed);
        Request::gemm(id, m, k, n, rng.f32_matrix(m, k), Arc::clone(w))
    }

    #[test]
    fn serves_and_answers_correctly() {
        let cfg = ArchConfig::paper(4, 4);
        let (tx, rx, h, _srv) = spawn(&cfg, Arc::new(NaiveExecutor));
        let w = shared_weight(8, 4);
        let r = req(7, 4, 8, 4, 1, &w);
        let Payload::Gemm { input, .. } = &r.payload else { unreachable!() };
        let expect = NaiveExecutor.gemm(4, 8, 4, input, &w).unwrap();
        tx.send(r.clone()).unwrap();
        let resp = rx.recv().unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.output, expect);
        assert!(resp.error.is_none());
        drop(tx);
        let stats = h.join().unwrap();
        assert_eq!(stats.served, 1);
    }

    #[test]
    fn batches_same_shape_shared_weights() {
        let cfg = ArchConfig::paper(4, 4);
        let (tx, rx, h, _srv) = spawn(&cfg, Arc::new(NaiveExecutor));
        let w = shared_weight(8, 4);
        for i in 0..6 {
            tx.send(req(i, 2, 8, 4, i, &w)).unwrap();
        }
        // Give the queue a moment to fill before the server drains it.
        std::thread::sleep(std::time::Duration::from_millis(30));
        let mut got = 0;
        let mut max_batch = 0;
        while got < 6 {
            let r = rx.recv().unwrap();
            max_batch = max_batch.max(r.batch_size);
            got += 1;
        }
        drop(tx);
        let stats = h.join().unwrap();
        assert_eq!(stats.served, 6);
        assert!(stats.batches <= 6);
        assert!(max_batch >= 1);
    }

    /// A malformed GEMM input in a batch is rejected alone; co-batched
    /// valid requests still get served.
    #[test]
    fn bad_gemm_input_rejected_individually() {
        let cfg = ArchConfig::paper(4, 4);
        let (tx, rx, h, _srv) = spawn(&cfg, Arc::new(NaiveExecutor));
        let w = shared_weight(8, 4);
        tx.send(req(0, 2, 8, 4, 0, &w)).unwrap();
        tx.send(Request::gemm(1, 2, 8, 4, vec![0.0; 3], Arc::clone(&w))).unwrap();
        tx.send(req(2, 2, 8, 4, 2, &w)).unwrap();
        let mut ok = 0;
        let mut bad = 0;
        for _ in 0..3 {
            let r = rx.recv().unwrap();
            if r.id == 1 {
                assert!(r.error.is_some());
                bad += 1;
            } else {
                assert!(r.error.is_none(), "{:?}", r.error);
                assert_eq!(r.output.len(), 2 * 4);
                ok += 1;
            }
        }
        assert_eq!((ok, bad), (2, 1));
        drop(tx);
        let stats = h.join().unwrap();
        assert_eq!(stats.served, 2);
        assert_eq!(stats.errors, 1);
    }

    /// Distinct weight objects never batch, even with equal contents: the
    /// key is identity, not value.
    #[test]
    fn distinct_weight_objects_do_not_batch() {
        let cfg = ArchConfig::paper(4, 4);
        let server = Server::new(&cfg, Arc::new(NaiveExecutor));
        let w1 = shared_weight(8, 4);
        let w2 = Arc::new(w1.as_ref().clone());
        assert_ne!(batch_key(&req(0, 2, 8, 4, 0, &w1)), batch_key(&req(1, 2, 8, 4, 1, &w2)));
        assert_eq!(batch_key(&req(2, 2, 8, 4, 2, &w1)), batch_key(&req(3, 2, 8, 4, 3, &w1)));
        let _ = server;
    }

    #[test]
    fn mapper_cache_hits_on_repeat_shapes() {
        let cfg = ArchConfig::paper(4, 4);
        let server = Server::new(&cfg, Arc::new(NaiveExecutor));
        assert!(server.route(64, 40, 24).is_some());
        assert!(server.route(64, 40, 24).is_some());
        let st = server.stats();
        assert_eq!(st.mapper_cache_misses, 1);
        assert_eq!(st.mapper_cache_hits, 1);
    }

    #[test]
    fn naive_executor_rejects_bad_shapes() {
        assert!(NaiveExecutor.gemm(2, 2, 2, &[1.0; 3], &[1.0; 4]).is_err());
    }

    /// Concurrent misses on one shape run the mapper exactly once: the
    /// in-flight guard turns N racing routes into 1 miss + N−1 hits, and
    /// every caller gets the same decision.
    #[test]
    fn concurrent_misses_run_mapper_once() {
        let cfg = ArchConfig::paper(4, 4);
        let server = Arc::new(Server::new(&cfg, Arc::new(NaiveExecutor)));
        let n_threads: u64 = 8;
        let decisions: Vec<Option<f64>> = std::thread::scope(|s| {
            let mut handles = Vec::new();
            for _ in 0..n_threads {
                let srv = Arc::clone(&server);
                handles.push(s.spawn(move || {
                    srv.route(64, 40, 24).map(|d| d.report.total_cycles)
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert!(decisions.iter().all(|d| d.is_some()));
        assert!(decisions.windows(2).all(|w| w[0] == w[1]), "identical decisions");
        let st = server.stats();
        assert_eq!(st.mapper_cache_misses, 1, "mapper ran once");
        assert_eq!(st.mapper_cache_hits, n_threads - 1);
    }

    /// Infeasible shapes cache their `None` so repeats don't re-search.
    #[test]
    fn infeasible_shape_cached_as_none() {
        let mut cfg = ArchConfig::paper(4, 4);
        // Shrink buffers so no candidate fits.
        cfg.str_bytes = 4;
        cfg.sta_bytes = 4;
        cfg.ob_bytes = 16;
        let server = Server::new(&cfg, Arc::new(NaiveExecutor));
        assert!(server.route(1 << 20, 1 << 12, 1 << 12).is_none());
        assert!(server.route(1 << 20, 1 << 12, 1 << 12).is_none());
        let st = server.stats();
        assert_eq!(st.mapper_cache_misses, 1);
        assert_eq!(st.mapper_cache_hits, 1);
    }

    /// Program sessions: register once, serve many — outputs equal a
    /// hand-chained naive pass, the chain compiles exactly once, and the
    /// per-shape mapper cache is never touched.
    #[test]
    fn program_requests_serve_registered_chain() {
        let cfg = ArchConfig::paper(4, 4);
        let (tx, rx, h, server) = spawn(&cfg, Arc::new(NaiveExecutor));
        let chain = Chain::mlp("mlp", 4, &[8, 12, 8]);
        let mut rng = Lcg::new(3);
        let weights: Vec<Vec<f32>> =
            chain.layers.iter().map(|g| rng.f32_matrix(g.k, g.n)).collect();
        let pid = server.register_chain(&chain, weights.clone()).unwrap();
        let n_req = 5u64;
        let mut expects = HashMap::new();
        for id in 0..n_req {
            let input = rng.f32_matrix(4, 8);
            let mut act = input.clone();
            for (g, w) in chain.layers.iter().zip(&weights) {
                act = NaiveExecutor.gemm(4, g.k, g.n, &act, w).unwrap();
            }
            expects.insert(id, act);
            tx.send(Request::for_program(id, pid, 4, input)).unwrap();
        }
        for _ in 0..n_req {
            let resp = rx.recv().unwrap();
            assert!(resp.error.is_none(), "{:?}", resp.error);
            assert_eq!(&resp.output, &expects[&resp.id]);
            assert!(resp.modeled_cycles > 0.0);
        }
        drop(tx);
        let stats = h.join().unwrap();
        assert_eq!(stats.program_compiles, 1, "chain compiled exactly once");
        assert_eq!(stats.program_served, n_req);
        assert_eq!(stats.mapper_cache_misses, 0, "program path skips the shape cache");
    }

    /// Same-program activations batch together (continuous batching keyed
    /// by ProgramId), and row bookkeeping splits the stacked output back.
    #[test]
    fn program_requests_batch_by_id() {
        let cfg = ArchConfig::paper(4, 4);
        let (tx, rx, h, server) = spawn(&cfg, Arc::new(NaiveExecutor));
        let chain = Chain::mlp("mlp", 2, &[8, 8]);
        let mut rng = Lcg::new(4);
        let weights: Vec<Vec<f32>> =
            chain.layers.iter().map(|g| rng.f32_matrix(g.k, g.n)).collect();
        let pid = server.register_chain(&chain, weights).unwrap();
        for id in 0..6u64 {
            tx.send(Request::for_program(id, pid, 2, rng.f32_matrix(2, 8))).unwrap();
        }
        std::thread::sleep(std::time::Duration::from_millis(30));
        let mut got = 0;
        let mut max_batch = 0;
        while got < 6 {
            let r = rx.recv().unwrap();
            assert!(r.error.is_none());
            assert_eq!(r.output.len(), 2 * 8);
            max_batch = max_batch.max(r.batch_size);
            got += 1;
        }
        drop(tx);
        let stats = h.join().unwrap();
        assert_eq!(stats.program_served, 6);
        assert!(stats.batches <= 6);
        assert!(max_batch >= 1);
    }

    #[test]
    fn unknown_program_answers_with_error() {
        let cfg = ArchConfig::paper(4, 4);
        let (tx, rx, h, _srv) = spawn(&cfg, Arc::new(NaiveExecutor));
        tx.send(Request::for_program(9, ProgramId(777), 2, vec![0.0; 16])).unwrap();
        let resp = rx.recv().unwrap();
        assert_eq!(resp.id, 9);
        assert!(resp.error.as_deref().unwrap_or("").contains("unknown program"));
        assert!(resp.output.is_empty());
        drop(tx);
        let stats = h.join().unwrap();
        assert_eq!(stats.errors, 1);
        assert_eq!(stats.served, 0);
    }

    /// A malformed activation in a batch is rejected alone; co-batched
    /// valid requests still get served.
    #[test]
    fn bad_activation_does_not_poison_batch() {
        let cfg = ArchConfig::paper(4, 4);
        let (tx, rx, h, server) = spawn(&cfg, Arc::new(NaiveExecutor));
        let chain = Chain::mlp("mlp", 2, &[8, 8]);
        let mut rng = Lcg::new(6);
        let weights: Vec<Vec<f32>> =
            chain.layers.iter().map(|g| rng.f32_matrix(g.k, g.n)).collect();
        let pid = server.register_chain(&chain, weights).unwrap();
        tx.send(Request::for_program(0, pid, 2, rng.f32_matrix(2, 8))).unwrap();
        tx.send(Request::for_program(1, pid, 2, vec![0.0; 3])).unwrap(); // wrong size
        tx.send(Request::for_program(2, pid, 2, rng.f32_matrix(2, 8))).unwrap();
        let mut ok = 0;
        let mut bad = 0;
        for _ in 0..3 {
            let r = rx.recv().unwrap();
            if r.id == 1 {
                assert!(r.error.is_some());
                assert!(r.output.is_empty());
                bad += 1;
            } else {
                assert!(r.error.is_none(), "{:?}", r.error);
                assert_eq!(r.output.len(), 2 * 8);
                ok += 1;
            }
        }
        assert_eq!((ok, bad), (2, 1));
        drop(tx);
        let stats = h.join().unwrap();
        assert_eq!(stats.program_served, 2);
        assert_eq!(stats.errors, 1);
    }

    #[test]
    fn register_chain_validates_weights() {
        let cfg = ArchConfig::paper(4, 4);
        let server = Server::new(&cfg, Arc::new(NaiveExecutor));
        let chain = Chain::mlp("mlp", 4, &[8, 8]);
        // Wrong count.
        assert!(server.register_chain(&chain, vec![]).is_err());
        // Wrong size.
        assert!(server.register_chain(&chain, vec![vec![0.0; 7]]).is_err());
        assert_eq!(server.stats().program_compiles, 0);
    }

    #[test]
    fn unregister_releases_session() {
        let cfg = ArchConfig::paper(4, 4);
        let server = Server::new(&cfg, Arc::new(NaiveExecutor));
        let chain = Chain::mlp("mlp", 4, &[8, 8]);
        let pid = server.register_chain(&chain, vec![vec![0.5; 64]]).unwrap();
        assert!(server.program(pid).is_some());
        assert!(server.unregister(pid));
        assert!(server.program(pid).is_none());
        assert!(!server.unregister(pid));
        assert_eq!(server.session_elem(pid), None);
    }

    /// Element-typed sessions serve word activations exactly: responses
    /// match the chained naive mod-p reference bit-for-bit, the chain
    /// compiles once, and the per-shape mapper cache stays untouched.
    #[test]
    fn word_session_serves_field_exact_responses() {
        use crate::arith::{decode_words, BabyBear, ModP};
        type B = ModP<BabyBear>;
        let cfg = ArchConfig::paper(4, 4);
        let (tx, rx, h, server) = spawn(&cfg, Arc::new(NaiveExecutor));
        let chain = Chain::mlp("mlp", 4, &[8, 12, 8]);
        let mut rng = Lcg::new(31);
        let weights: Vec<Vec<u64>> = chain
            .layers
            .iter()
            .map(|g| ElemType::BabyBear.sample_words(&mut rng, g.k * g.n))
            .collect();
        let pid = server.register_chain_elem(&chain, weights.clone(), ElemType::BabyBear).unwrap();
        assert_eq!(server.session_elem(pid), Some(ElemType::BabyBear));
        let program = server.program(pid).unwrap();
        let wb: Vec<Vec<B>> = weights.iter().map(|w| decode_words::<B>(w)).collect();
        let n_req = 4u64;
        let mut expects = HashMap::new();
        for id in 0..n_req {
            let input = ElemType::BabyBear.sample_words(&mut rng, 4 * 8);
            let expect: Vec<u64> = program
                .reference(&decode_words::<B>(&input), &wb)
                .into_iter()
                .map(|v| v.to_u64())
                .collect();
            expects.insert(id, expect);
            tx.send(Request::for_program_words(id, pid, 4, input)).unwrap();
        }
        for _ in 0..n_req {
            let resp = rx.recv().unwrap();
            assert!(resp.error.is_none(), "{:?}", resp.error);
            assert!(resp.output.is_empty(), "word sessions answer in words");
            assert_eq!(&resp.output_words, &expects[&resp.id], "field-exact response");
        }
        drop(tx);
        let stats = h.join().unwrap();
        assert_eq!(stats.program_compiles, 1, "chain compiled exactly once");
        assert_eq!(stats.program_served, n_req);
        assert_eq!(stats.mapper_cache_misses, 0, "word path skips the shape cache");
    }

    /// f32 and word payloads never share a batch key — even under the same
    /// program id — and payload kind must match the session's type.
    #[test]
    fn element_types_never_cobatch_or_cross_dispatch() {
        let cfg = ArchConfig::paper(4, 4);
        let (tx, rx, h, server) = spawn(&cfg, Arc::new(NaiveExecutor));
        let chain = Chain::mlp("mlp", 2, &[8, 8]);
        let mut rng = Lcg::new(41);
        let f32_pid = server
            .register_chain(&chain, chain.layers.iter().map(|g| rng.f32_matrix(g.k, g.n)).collect())
            .unwrap();
        let word_weights: Vec<Vec<u64>> = chain
            .layers
            .iter()
            .map(|g| ElemType::Goldilocks.sample_words(&mut rng, g.k * g.n))
            .collect();
        let word_pid =
            server.register_chain_elem(&chain, word_weights, ElemType::Goldilocks).unwrap();
        // Distinct key variants even for one id: no f32/word co-batching.
        assert_ne!(
            batch_key(&Request::for_program(0, f32_pid, 2, vec![0.0; 16])),
            batch_key(&Request::for_program_words(1, f32_pid, 2, vec![0; 16])),
        );
        // Word payload to an f32 session and vice versa answer with errors.
        tx.send(Request::for_program_words(7, f32_pid, 2, vec![0; 16])).unwrap();
        let r = rx.recv().unwrap();
        assert_eq!(r.id, 7);
        assert!(r.error.as_deref().unwrap_or("").contains("f32 session"), "{:?}", r.error);
        tx.send(Request::for_program(8, word_pid, 2, vec![0.0; 16])).unwrap();
        let r = rx.recv().unwrap();
        assert_eq!(r.id, 8);
        assert!(r.error.as_deref().unwrap_or("").contains("goldilocks"), "{:?}", r.error);
        drop(tx);
        let stats = h.join().unwrap();
        assert_eq!(stats.errors, 2);
    }

    /// Batched word requests stack rows across the program's compiled
    /// height (the chunked execution path) and still answer exactly, with
    /// a malformed activation rejected alone.
    #[test]
    fn word_requests_batch_and_chunk_exactly() {
        use crate::arith::{decode_words, Goldilocks, ModP};
        type G = ModP<Goldilocks>;
        let cfg = ArchConfig::paper(4, 4);
        let (tx, rx, h, server) = spawn(&cfg, Arc::new(NaiveExecutor));
        let chain = Chain::mlp("mlp", 2, &[8, 8]);
        let mut rng = Lcg::new(43);
        let weights: Vec<Vec<u64>> = chain
            .layers
            .iter()
            .map(|g| ElemType::Goldilocks.sample_words(&mut rng, g.k * g.n))
            .collect();
        let pid = server.register_chain_elem(&chain, weights.clone(), ElemType::Goldilocks).unwrap();
        let program = server.program(pid).unwrap();
        let wg: Vec<Vec<G>> = weights.iter().map(|w| decode_words::<G>(w)).collect();
        let mut expects = HashMap::new();
        for id in 0..6u64 {
            if id == 3 {
                tx.send(Request::for_program_words(id, pid, 2, vec![0; 3])).unwrap();
                continue;
            }
            let input = ElemType::Goldilocks.sample_words(&mut rng, 2 * 8);
            let expect: Vec<u64> = program
                .reference(&decode_words::<G>(&input), &wg)
                .into_iter()
                .map(|v| v.to_u64())
                .collect();
            expects.insert(id, expect);
            tx.send(Request::for_program_words(id, pid, 2, input)).unwrap();
        }
        std::thread::sleep(std::time::Duration::from_millis(30));
        let (mut ok, mut bad) = (0, 0);
        for _ in 0..6 {
            let r = rx.recv().unwrap();
            if r.id == 3 {
                assert!(r.error.is_some());
                bad += 1;
            } else {
                assert!(r.error.is_none(), "{:?}", r.error);
                assert_eq!(&r.output_words, &expects[&r.id]);
                ok += 1;
            }
        }
        assert_eq!((ok, bad), (5, 1));
        drop(tx);
        let stats = h.join().unwrap();
        assert_eq!(stats.program_served, 5);
        assert_eq!(stats.errors, 1);
    }

    /// An i32 word session fed overflow-heavy operands (i64 psum overflow
    /// panics under debug assertions) answers with an error response and
    /// the leader keeps serving — panic containment in the dispatcher.
    /// Debug-only: release arithmetic wraps instead of panicking.
    #[test]
    #[cfg(debug_assertions)]
    fn i32_word_overflow_answers_error_not_thread_death() {
        let cfg = ArchConfig::paper(4, 4);
        let (tx, rx, h, server) = spawn(&cfg, Arc::new(NaiveExecutor));
        let chain = Chain::mlp("mlp", 2, &[8, 8]);
        let mut rng = Lcg::new(51);
        let weights: Vec<Vec<u64>> =
            chain.layers.iter().map(|g| vec![i32::MAX.encode(); g.k * g.n]).collect();
        let pid = server.register_chain_elem(&chain, weights, ElemType::I32).unwrap();
        // K=8 psums of (2^31-1)^2 overflow the i64 accumulator.
        tx.send(Request::for_program_words(0, pid, 2, vec![i32::MAX.encode(); 2 * 8])).unwrap();
        let r = rx.recv().unwrap();
        assert!(r.error.as_deref().unwrap_or("").contains("panicked"), "{:?}", r.error);
        // The leader survived: a sane request still gets served.
        tx.send(Request::for_program_words(1, pid, 2, ElemType::I32.sample_words(&mut rng, 16)))
            .unwrap();
        let r = rx.recv().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        drop(tx);
        let stats = h.join().unwrap();
        assert_eq!(stats.errors, 1);
        assert_eq!(stats.program_served, 1);
    }

    #[test]
    fn register_chain_elem_validates_weights() {
        let cfg = ArchConfig::paper(4, 4);
        let server = Server::new(&cfg, Arc::new(NaiveExecutor));
        let chain = Chain::mlp("mlp", 4, &[8, 8]);
        assert!(server.register_chain_elem(&chain, vec![], ElemType::BabyBear).is_err());
        assert!(server
            .register_chain_elem(&chain, vec![vec![0; 7]], ElemType::BabyBear)
            .is_err());
        assert_eq!(server.stats().program_compiles, 0);
    }

    /// An executor that panics when the first input element carries a
    /// marker value — targets the ad-hoc GEMM path, which used to call the
    /// executor outside any panic containment.
    struct PanicOnMarker;

    impl TileExecutor for PanicOnMarker {
        fn gemm(
            &self,
            m: usize,
            k: usize,
            n: usize,
            iv: &[f32],
            wv: &[f32],
        ) -> anyhow::Result<Vec<f32>> {
            assert!(iv.first() != Some(&666.0), "injected executor panic");
            NaiveExecutor.gemm(m, k, n, iv, wv)
        }
        fn name(&self) -> &str {
            "panic-on-marker"
        }
    }

    /// A panicking GEMM executor answers with an error response (contained
    /// in the fleet shard runner) and the leader keeps serving.
    #[test]
    fn gemm_executor_panic_answers_error_not_thread_death() {
        let cfg = ArchConfig::paper(4, 4);
        let (tx, rx, h, _srv) = spawn(&cfg, Arc::new(PanicOnMarker));
        let w = shared_weight(8, 4);
        let mut poisoned = Lcg::new(1).f32_matrix(2, 8);
        poisoned[0] = 666.0;
        tx.send(Request::gemm(0, 2, 8, 4, poisoned, Arc::clone(&w))).unwrap();
        let r = rx.recv().unwrap();
        assert!(r.error.as_deref().unwrap_or("").contains("panicked"), "{:?}", r.error);
        // The leader survived and still serves.
        tx.send(req(1, 2, 8, 4, 1, &w)).unwrap();
        let r = rx.recv().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        drop(tx);
        let stats = h.join().unwrap();
        assert_eq!(stats.errors, 1);
        assert_eq!(stats.served, 1);
    }

    /// A session registered from an in-memory artifact serves f32 requests
    /// bit-identically to a compiled session — with zero mapper runs and
    /// zero program compiles (`artifact_loads` moves instead).
    #[test]
    fn artifact_session_serves_with_zero_mapper_runs() {
        use crate::artifact::Compiler;
        let cfg = ArchConfig::paper(4, 4);
        let chain = Chain::mlp("mlp", 4, &[8, 12, 8]);
        let mut rng = Lcg::new(61);
        let weights: Vec<Vec<f32>> =
            chain.layers.iter().map(|g| rng.f32_matrix(g.k, g.n)).collect();
        let words: Vec<Vec<u64>> = weights.iter().map(|w| encode_words::<f32>(w)).collect();
        let art = Compiler::new(&cfg)
            .elem(ElemType::F32)
            .weights(words)
            .compile(&chain)
            .unwrap();
        // Sanity: the builder already produced the payload we asked for.
        assert_eq!(art.payload.as_ref().unwrap().elem, ElemType::F32);
        let (tx, rx, h, server) = spawn(&cfg, Arc::new(NaiveExecutor));
        let searches_before = crate::mapper::search::searches_run();
        let pid = server.register(ArtifactSource::Artifact(Box::new(art))).unwrap();
        assert_eq!(
            crate::mapper::search::searches_run(),
            searches_before,
            "artifact registration must not run the mapper"
        );
        assert_eq!(server.session_elem(pid), Some(ElemType::F32));
        let n_req = 4u64;
        let mut expects = HashMap::new();
        for id in 0..n_req {
            let input = rng.f32_matrix(4, 8);
            let mut act = input.clone();
            for (g, w) in chain.layers.iter().zip(&weights) {
                act = NaiveExecutor.gemm(4, g.k, g.n, &act, w).unwrap();
            }
            expects.insert(id, act);
            tx.send(Request::for_program(id, pid, 4, input)).unwrap();
        }
        for _ in 0..n_req {
            let resp = rx.recv().unwrap();
            assert!(resp.error.is_none(), "{:?}", resp.error);
            assert_eq!(&resp.output, &expects[&resp.id]);
        }
        drop(tx);
        let stats = h.join().unwrap();
        assert_eq!(stats.artifact_loads, 1, "one artifact load");
        assert_eq!(stats.program_compiles, 0, "no mapper work on the serving host");
        assert_eq!(stats.program_served, n_req);
    }

    /// A `.minisa` file registered by path serves an element-typed session
    /// field-exactly, again without compiling anything.
    #[test]
    fn artifact_file_registers_word_session() {
        use crate::arith::{Goldilocks, ModP};
        use crate::artifact::Compiler;
        type G = ModP<Goldilocks>;
        let cfg = ArchConfig::paper(4, 4);
        let chain = Chain::mlp("mlp", 4, &[8, 8]);
        let mut rng = Lcg::new(67);
        let weights: Vec<Vec<u64>> = chain
            .layers
            .iter()
            .map(|g| ElemType::Goldilocks.sample_words(&mut rng, g.k * g.n))
            .collect();
        let art = Compiler::new(&cfg)
            .elem(ElemType::Goldilocks)
            .weights(weights.clone())
            .compile(&chain)
            .unwrap();
        let path =
            std::env::temp_dir().join(format!("minisa_serve_{}.minisa", std::process::id()));
        art.save(&path).unwrap();
        let (tx, rx, h, server) = spawn(&cfg, Arc::new(NaiveExecutor));
        let pid = server.register(ArtifactSource::Path(path.clone())).unwrap();
        std::fs::remove_file(&path).ok();
        let program = server.program(pid).unwrap();
        let wg: Vec<Vec<G>> = weights.iter().map(|w| decode_words::<G>(w)).collect();
        let input = ElemType::Goldilocks.sample_words(&mut rng, 4 * 8);
        let expect: Vec<u64> = program
            .reference(&decode_words::<G>(&input), &wg)
            .into_iter()
            .map(|v| v.to_u64())
            .collect();
        tx.send(Request::for_program_words(0, pid, 4, input)).unwrap();
        let resp = rx.recv().unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.output_words, expect, "field-exact from a loaded artifact");
        drop(tx);
        let stats = h.join().unwrap();
        assert_eq!(stats.artifact_loads, 1);
        assert_eq!(stats.program_compiles, 0);
    }

    /// Weightless artifacts and config-mismatched artifacts are rejected
    /// with descriptive errors (and nothing is registered).
    #[test]
    fn register_rejects_unusable_artifacts() {
        use crate::artifact::Compiler;
        let cfg = ArchConfig::paper(4, 4);
        let chain = Chain::mlp("mlp", 4, &[8, 8]);
        // No weights payload.
        let bare = Compiler::new(&cfg).compile(&chain).unwrap();
        let server = Server::new(&cfg, Arc::new(NaiveExecutor));
        let err = server
            .register(ArtifactSource::Artifact(Box::new(bare)))
            .unwrap_err()
            .to_string();
        assert!(err.contains("weights payload"), "{err}");
        // Wrong architecture.
        let mut rng = Lcg::new(3);
        let other = ArchConfig::paper(4, 8);
        let art = Compiler::new(&other)
            .weights(
                chain.layers.iter().map(|g| ElemType::I32.sample_words(&mut rng, g.k * g.n)).collect(),
            )
            .compile(&chain)
            .unwrap();
        let err = server
            .register(ArtifactSource::Artifact(Box::new(art)))
            .unwrap_err()
            .to_string();
        assert!(err.contains("compiled for 4x8"), "{err}");
        assert_eq!(server.stats().artifact_loads, 0);
        assert!(server.sessions.read().unwrap().is_empty());
    }

    /// Multi-device serving answers every request with the same bytes as a
    /// single-device server: same GEMM responses, same program responses,
    /// one program compile for the whole fleet.
    #[test]
    fn fleet_server_matches_single_device_responses() {
        let cfg = ArchConfig::paper(4, 4);
        let opts = ServerOptions { devices: 3, shard_min_rows: 1, max_batch: 8, ..Default::default() };
        let (tx, rx, h, server) = spawn_with_options(&cfg, Arc::new(NaiveExecutor), opts);
        let chain = Chain::mlp("mlp", 4, &[8, 12, 8]);
        let mut rng = Lcg::new(19);
        let weights: Vec<Vec<f32>> =
            chain.layers.iter().map(|g| rng.f32_matrix(g.k, g.n)).collect();
        let pid = server.register_chain(&chain, weights.clone()).unwrap();
        let n_req = 8u64;
        let mut expects = HashMap::new();
        for id in 0..n_req {
            let input = rng.f32_matrix(4, 8);
            let mut act = input.clone();
            for (g, w) in chain.layers.iter().zip(&weights) {
                act = NaiveExecutor.gemm(4, g.k, g.n, &act, w).unwrap();
            }
            expects.insert(id, act);
            tx.send(Request::for_program(id, pid, 4, input)).unwrap();
        }
        for _ in 0..n_req {
            let resp = rx.recv().unwrap();
            assert!(resp.error.is_none(), "{:?}", resp.error);
            assert_eq!(&resp.output, &expects[&resp.id]);
        }
        drop(tx);
        let stats = h.join().unwrap();
        assert_eq!(stats.program_compiles, 1, "one compile per fleet");
        assert_eq!(stats.program_served, n_req);
        assert_eq!(server.fleet().plan_compiles(), 0);
        assert_eq!(server.fleet().device_count(), 3);
    }

    /// Fleet-mode error paths behave like single-device: unknown programs
    /// and malformed activations answer errors from worker threads too.
    #[test]
    fn fleet_server_answers_errors_from_workers() {
        let cfg = ArchConfig::paper(4, 4);
        let opts = ServerOptions { devices: 2, shard_min_rows: 4, max_batch: 4, ..Default::default() };
        let (tx, rx, h, server) = spawn_with_options(&cfg, Arc::new(NaiveExecutor), opts);
        let chain = Chain::mlp("mlp", 2, &[8, 8]);
        let mut rng = Lcg::new(21);
        let weights: Vec<Vec<f32>> =
            chain.layers.iter().map(|g| rng.f32_matrix(g.k, g.n)).collect();
        let pid = server.register_chain(&chain, weights).unwrap();
        tx.send(Request::for_program(0, pid, 2, rng.f32_matrix(2, 8))).unwrap();
        tx.send(Request::for_program(1, pid, 2, vec![0.0; 3])).unwrap(); // malformed
        tx.send(Request::for_program(2, ProgramId(777), 2, vec![0.0; 16])).unwrap();
        let mut ok = 0;
        let mut bad = 0;
        for _ in 0..3 {
            let r = rx.recv().unwrap();
            match r.id {
                1 | 2 => {
                    assert!(r.error.is_some());
                    bad += 1;
                }
                _ => {
                    assert!(r.error.is_none(), "{:?}", r.error);
                    ok += 1;
                }
            }
        }
        assert_eq!((ok, bad), (1, 2));
        drop(tx);
        let stats = h.join().unwrap();
        assert_eq!(stats.errors, 2);
    }

    /// A dead-on-arrival deadline answers a typed `deadline_exceeded`
    /// response (not an exec error), and live deadlines serve normally.
    #[test]
    fn expired_deadline_answers_typed_error() {
        let cfg = ArchConfig::paper(4, 4);
        let (tx, rx, h, _srv) = spawn(&cfg, Arc::new(NaiveExecutor));
        let w = shared_weight(8, 4);
        tx.send(req(0, 2, 8, 4, 0, &w).with_deadline_ms(0)).unwrap();
        let r = rx.recv().unwrap();
        assert_eq!(r.code, Some(ErrorCode::DeadlineExceeded));
        assert!(r.error.is_some());
        assert!(r.output.is_empty());
        // A deadline far in the future serves normally.
        tx.send(req(1, 2, 8, 4, 1, &w).with_deadline_ms(60_000).with_qos(QosClass::Batch))
            .unwrap();
        let r = rx.recv().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.code, None);
        drop(tx);
        let stats = h.join().unwrap();
        assert_eq!(stats.expired, 1);
        assert_eq!(stats.served, 1);
        assert_eq!(stats.errors, 0, "expiry is policy, not an exec error");
    }

    /// A dry token bucket sheds rate-limited classes with a typed `shed`
    /// response while `Interactive` stays exempt; the in-flight gauge
    /// drains back to zero once everything is answered.
    #[test]
    fn rate_limiter_sheds_typed_and_spares_interactive() {
        let cfg = ArchConfig::paper(4, 4);
        let opts = ServerOptions {
            admission: AdmissionOptions { rate_per_s: 0.0, burst: 1.0, ..Default::default() },
            ..Default::default()
        };
        let (tx, rx, h, server) = spawn_with_options(&cfg, Arc::new(NaiveExecutor), opts);
        let w = shared_weight(8, 4);
        // One token in the bucket: the first best-effort request spends it,
        // the second sheds (rate 0 never refills), interactive is exempt.
        tx.send(req(0, 2, 8, 4, 0, &w).with_qos(QosClass::BestEffort)).unwrap();
        tx.send(req(1, 2, 8, 4, 1, &w).with_qos(QosClass::BestEffort)).unwrap();
        tx.send(req(2, 2, 8, 4, 2, &w).with_qos(QosClass::Interactive)).unwrap();
        let mut by_id = HashMap::new();
        for _ in 0..3 {
            let r = rx.recv().unwrap();
            by_id.insert(r.id, r);
        }
        assert!(by_id[&0].error.is_none(), "{:?}", by_id[&0].error);
        assert_eq!(by_id[&1].code, Some(ErrorCode::Shed));
        assert!(by_id[&1].error.as_deref().unwrap_or("").contains("shed"));
        assert!(by_id[&2].error.is_none(), "interactive is exempt from the rate limiter");
        assert_eq!(server.admission().in_flight(), 0, "every admitted request completed");
        drop(tx);
        let stats = h.join().unwrap();
        assert_eq!(stats.shed, 1);
        assert_eq!(stats.served, 2);
        assert_eq!(stats.errors, 0, "shedding is policy, not an exec error");
    }

    /// Requests racing `Server::unregister` answer a typed `session_gone`
    /// error — never a panic or a silent hang — while ids the server never
    /// issued still answer plain `unknown program`.
    #[test]
    fn unregistered_session_answers_session_gone() {
        let cfg = ArchConfig::paper(4, 4);
        let (tx, rx, h, server) = spawn(&cfg, Arc::new(NaiveExecutor));
        let chain = Chain::mlp("mlp", 2, &[8, 8]);
        let mut rng = Lcg::new(77);
        let weights: Vec<Vec<f32>> =
            chain.layers.iter().map(|g| rng.f32_matrix(g.k, g.n)).collect();
        let pid = server.register_chain(&chain, weights).unwrap();
        assert!(server.unregister(pid));
        tx.send(Request::for_program(0, pid, 2, rng.f32_matrix(2, 8))).unwrap();
        let r = rx.recv().unwrap();
        assert_eq!(r.code, Some(ErrorCode::SessionGone), "{:?}", r.error);
        assert!(r.error.as_deref().unwrap_or("").contains("unregistered"));
        // Never-issued ids are unknown programs, not gone sessions.
        tx.send(Request::for_program(1, ProgramId(999), 2, vec![0.0; 16])).unwrap();
        let r = rx.recv().unwrap();
        assert_eq!(r.code, Some(ErrorCode::Exec));
        assert!(r.error.as_deref().unwrap_or("").contains("unknown program"));
        drop(tx);
        let stats = h.join().unwrap();
        assert_eq!(stats.session_gone, 1);
        assert_eq!(stats.errors, 2);
    }

    /// Continuous batching mechanics: a compatible arrival joins an open
    /// (submitted but unclaimed) batch, claiming is exactly-once, and a
    /// claimed batch accepts no further arrivals.
    #[test]
    fn continuous_batching_injects_into_open_batches() {
        let cfg = ArchConfig::paper(4, 4);
        let server = Arc::new(Server::with_options(
            &cfg,
            Arc::new(NaiveExecutor),
            ServerOptions { devices: 2, ..Default::default() },
        ));
        let w = shared_weight(8, 4);
        let r0 = req(0, 2, 8, 4, 0, &w);
        let bk = batch_key(&r0);
        let ob = Arc::new(OpenBatch { reqs: Mutex::new(Some(vec![r0])) });
        lock_clean(&server.open).insert(bk, Arc::clone(&ob));
        // Same key: injected.
        assert!(server.try_inject(req(1, 2, 8, 4, 1, &w)).is_none());
        // Different weight identity → different key: handed back.
        let other = shared_weight(8, 4);
        assert!(server.try_inject(req(2, 2, 8, 4, 2, &other)).is_some());
        // The claiming worker takes both requests, exactly once.
        let claimed = server.claim_open(&bk, &ob).unwrap();
        assert_eq!(claimed.len(), 2);
        assert!(server.claim_open(&bk, &ob).is_none(), "claim is exactly-once");
        // After the claim the batch is closed to new arrivals.
        assert!(server.try_inject(req(3, 2, 8, 4, 3, &w)).is_some());
        assert_eq!(server.stats().injected, 1);
    }

    /// Tracing on: sampled responses carry a complete, monotonically
    /// ordered stage timeline and the registry grows per-stage histograms;
    /// `sample_every` thins which requests are traced.
    #[test]
    fn tracing_records_complete_stage_timelines() {
        let cfg = ArchConfig::paper(4, 4);
        let opts = ServerOptions { tracing: TraceOptions::all(), ..Default::default() };
        let (tx, rx, h, server) = spawn_with_options(&cfg, Arc::new(NaiveExecutor), opts);
        let w = shared_weight(8, 4);
        for i in 0..3 {
            tx.send(req(i, 2, 8, 4, i, &w)).unwrap();
        }
        for _ in 0..3 {
            let r = rx.recv().unwrap();
            assert!(r.error.is_none(), "{:?}", r.error);
            let t = r.trace.expect("sample_every=1 traces every request");
            assert!(t.is_complete(), "stages {:?}", t.stages());
            assert!(t.is_monotonic());
            assert!(t.total_us() >= 0.0);
        }
        let snap = server.metrics_snapshot(1000.0);
        // Arrival opens the timeline (no duration); every later stage has
        // a delta histogram.
        for stage in &Stage::ALL[1..] {
            let name = format!("serve_stage_{}_us", stage.name());
            let hist = snap.histogram(&name).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(hist.count, 3, "{name}");
        }
        assert_eq!(snap.histogram("serve_request_us").unwrap().count, 3);
        assert_eq!(snap.counter("serve_served_total"), Some(3));
        assert!(snap.gauge("fleet_dev0_busy_us").is_some(), "fleet gauges folded in");
        drop(tx);
        h.join().unwrap();
    }

    /// Tracing off (the default): responses carry no trace and the
    /// registry records no span histograms — the serving path is counter
    /// increments only.
    #[test]
    fn tracing_disabled_leaves_no_span_histograms() {
        let cfg = ArchConfig::paper(4, 4);
        let (tx, rx, h, server) = spawn(&cfg, Arc::new(NaiveExecutor));
        let w = shared_weight(8, 4);
        tx.send(req(0, 2, 8, 4, 0, &w)).unwrap();
        let r = rx.recv().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert!(r.trace.is_none(), "untraced by default");
        let snap = server.metrics().snapshot();
        assert!(snap.histograms.is_empty(), "no span histograms when tracing is off");
        assert_eq!(snap.counter("serve_served_total"), Some(1));
        drop(tx);
        h.join().unwrap();
    }

    /// `sample_every` traces only every Nth arrival.
    #[test]
    fn trace_sampling_thins_traced_requests() {
        let cfg = ArchConfig::paper(4, 4);
        let opts = ServerOptions {
            tracing: TraceOptions { enabled: true, sample_every: 2 },
            ..Default::default()
        };
        let (tx, rx, h, _server) = spawn_with_options(&cfg, Arc::new(NaiveExecutor), opts);
        let w = shared_weight(8, 4);
        for i in 0..4 {
            tx.send(req(i, 2, 8, 4, i, &w)).unwrap();
            // Serialize arrivals so the sampling sequence is deterministic.
            let r = rx.recv().unwrap();
            assert!(r.error.is_none(), "{:?}", r.error);
            assert_eq!(r.trace.is_some(), i % 2 == 0, "arrival {i}");
        }
        drop(tx);
        h.join().unwrap();
    }

    fn word_artifact(cfg: &ArchConfig, chain: &Chain, elem: ElemType, seed: u64) -> Artifact {
        use crate::artifact::Compiler;
        let mut rng = Lcg::new(seed);
        let weights: Vec<Vec<u64>> =
            chain.layers.iter().map(|g| elem.sample_words(&mut rng, g.k * g.n)).collect();
        Compiler::new(cfg).elem(elem).weights(weights).compile(chain).unwrap()
    }

    /// A successful swap atomically replaces the session (new weight
    /// allocation, same id) and accounts provenance honestly; validation
    /// failures are typed and leave the old session untouched.
    #[test]
    fn swap_replaces_session_and_validates_compatibility() {
        let cfg = ArchConfig::paper(4, 4);
        let chain = Chain::mlp("mlp", 4, &[8, 8]);
        let server = Server::new(&cfg, Arc::new(NaiveExecutor));
        let a = word_artifact(&cfg, &chain, ElemType::I32, 70);
        let pid = server.register(ArtifactSource::Artifact(Box::new(a))).unwrap();
        let ptr_before = server.weights_ptr(pid).unwrap();
        // Incompatible replacement: different element type.
        let wrong_elem = word_artifact(&cfg, &chain, ElemType::Goldilocks, 71);
        let err = server.swap(pid, ArtifactSource::Artifact(Box::new(wrong_elem))).unwrap_err();
        assert!(matches!(err, SwapError::Failed(_)), "{err}");
        assert_eq!(server.weights_ptr(pid).unwrap(), ptr_before, "old session kept serving");
        // Incompatible replacement: different feature widths.
        let wrong_shape =
            word_artifact(&cfg, &Chain::mlp("mlp", 4, &[8, 12, 12]), ElemType::I32, 72);
        assert!(server.swap(pid, ArtifactSource::Artifact(Box::new(wrong_shape))).is_err());
        // Compatible replacement: new weights, same chain shape.
        let b = word_artifact(&cfg, &chain, ElemType::I32, 73);
        server.swap(pid, ArtifactSource::Artifact(Box::new(b))).unwrap();
        assert_ne!(server.weights_ptr(pid).unwrap(), ptr_before, "weights actually swapped");
        // Unknown id is its own typed error.
        let c = word_artifact(&cfg, &chain, ElemType::I32, 74);
        assert_eq!(
            server.swap(ProgramId(999), ArtifactSource::Artifact(Box::new(c))),
            Err(SwapError::UnknownProgram(ProgramId(999)))
        );
        let st = server.stats();
        assert_eq!(st.swaps, 1);
        assert_eq!(st.swap_failed, 2, "unknown-id attempts are not counted as failed swaps");
        assert_eq!(st.artifact_loads, 2, "register + successful swap");
        assert_eq!(st.program_compiles, 0, "nothing on this path ran the mapper");
    }

    /// At most one swap per program builds at a time: a second attempt is
    /// the typed `swap_in_progress` surface, and it does not consume a
    /// `swap_failed` count.
    #[test]
    fn concurrent_swap_is_typed_in_progress() {
        let cfg = ArchConfig::paper(4, 4);
        let chain = Chain::mlp("mlp", 4, &[8, 8]);
        let server = Server::new(&cfg, Arc::new(NaiveExecutor));
        let a = word_artifact(&cfg, &chain, ElemType::I32, 80);
        let pid = server.register(ArtifactSource::Artifact(Box::new(a))).unwrap();
        // Hold the guard as a racing swap would.
        assert!(server.swapping.lock().unwrap().insert(pid));
        let b = word_artifact(&cfg, &chain, ElemType::I32, 81);
        assert_eq!(
            server.swap(pid, ArtifactSource::Artifact(Box::new(b))),
            Err(SwapError::InProgress(pid))
        );
        server.swapping.lock().unwrap().remove(&pid);
        assert_eq!(server.stats().swap_failed, 0);
        // Guard released: the swap goes through.
        let c = word_artifact(&cfg, &chain, ElemType::I32, 82);
        server.swap(pid, ArtifactSource::Artifact(Box::new(c))).unwrap();
        assert_eq!(server.stats().swaps, 1);
    }

    /// Registry-sourced sessions: three registrations of one content hash
    /// share a single decoded weight allocation (pointer identity), the
    /// shared cache counts 1 miss + 2 hits, and serving needs zero mapper
    /// runs.
    #[test]
    fn registry_sessions_share_one_weight_allocation() {
        let cfg = ArchConfig::paper(4, 4);
        let chain = Chain::mlp("regmlp", 4, &[8, 8]);
        let art = word_artifact(&cfg, &chain, ElemType::BabyBear, 90);
        let registry = Arc::new(crate::registry::Registry::new(
            Box::new(crate::registry::MemBackend::new()),
            4,
        ));
        let key = registry.put(&art).unwrap();
        let opts = ServerOptions { registry: Some(Arc::clone(&registry)), ..Default::default() };
        let server = Server::with_options(&cfg, Arc::new(NaiveExecutor), opts);
        let p1 = server.register(ArtifactSource::Registry { key: key.to_string() }).unwrap();
        // Resolve by model name and by content prefix too — all one entry.
        let p2 = server.register(ArtifactSource::Registry { key: "regmlp".into() }).unwrap();
        let p3 = server
            .register(ArtifactSource::Registry {
                key: format!("{:016x}", key.content)[..8].to_string(),
            })
            .unwrap();
        let ptrs: Vec<usize> =
            [p1, p2, p3].iter().map(|p| server.weights_ptr(*p).unwrap()).collect();
        assert_eq!(ptrs[0], ptrs[1], "one decoded buffer behind every session");
        assert_eq!(ptrs[1], ptrs[2]);
        let st = server.stats();
        assert_eq!((st.registry_misses, st.registry_hits), (1, 2));
        assert_eq!(st.artifact_loads, 3);
        assert_eq!(st.program_compiles, 0);
        let snap = server.metrics().snapshot();
        assert_eq!(snap.counter("registry_hits_total"), Some(2));
        assert_eq!(snap.counter("registry_misses_total"), Some(1));
        // No registry attached → typed, descriptive failure.
        let bare = Server::new(&cfg, Arc::new(NaiveExecutor));
        let err = bare
            .register(ArtifactSource::Registry { key: key.to_string() })
            .unwrap_err()
            .to_string();
        assert!(err.contains("no registry attached"), "{err}");
    }
}
