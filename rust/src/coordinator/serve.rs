//! GEMM-serving request loop — the L3 hot path.
//!
//! A leader thread accepts GEMM requests, routes them to the per-shape
//! mapping decision (mapper results are cached), batches compatible
//! requests, and dispatches execution to a pluggable `TileExecutor` — the
//! PJRT runtime in production (`runtime::PjrtExecutor`), the functional
//! simulator in tests. Python never appears on this path: the executor
//! consumes AOT-compiled artifacts.
//!
//! Built on std::thread + mpsc channels (offline substitute for tokio,
//! DESIGN.md).

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, OnceLock, RwLock};

use crate::arch::config::ArchConfig;
use crate::mapper::search::{search, MapperOptions};
use crate::mapper::Decision;
use crate::workloads::Gemm;

/// A GEMM request: f32 operands (the PJRT oracle path computes in f32).
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub input: Vec<f32>,
    pub weight: Vec<f32>,
}

/// A served response.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub output: Vec<f32>,
    /// Wall-clock service time (queue + execute) in µs.
    pub service_us: f64,
    /// Modeled FEATHER+ cycles for this request (from the mapper decision).
    pub modeled_cycles: f64,
    /// Requests co-batched with this one.
    pub batch_size: usize,
}

/// Execution backend abstraction.
pub trait TileExecutor: Send + Sync {
    /// Execute `O[M,N] = I · W` and return O row-major.
    fn gemm(&self, m: usize, k: usize, n: usize, i: &[f32], w: &[f32])
        -> anyhow::Result<Vec<f32>>;
    fn name(&self) -> &str;
}

/// Reference executor: naive f32 GEMM (tests / fallback).
pub struct NaiveExecutor;

impl TileExecutor for NaiveExecutor {
    fn gemm(
        &self,
        m: usize,
        k: usize,
        n: usize,
        iv: &[f32],
        wv: &[f32],
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(iv.len() == m * k && wv.len() == k * n, "shape mismatch");
        let mut o = vec![0f32; m * n];
        for mi in 0..m {
            for ki in 0..k {
                let a = iv[mi * k + ki];
                if a == 0.0 {
                    continue;
                }
                for ni in 0..n {
                    o[mi * n + ni] += a * wv[ki * n + ni];
                }
            }
        }
        Ok(o)
    }
    fn name(&self) -> &str {
        "naive"
    }
}

/// Routing + batching statistics.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub served: u64,
    pub batches: u64,
    pub mapper_cache_hits: u64,
    pub mapper_cache_misses: u64,
    pub total_service_us: f64,
    pub max_batch: usize,
}

impl ServeStats {
    pub fn mean_latency_us(&self) -> f64 {
        if self.served == 0 {
            0.0
        } else {
            self.total_service_us / self.served as f64
        }
    }
    pub fn throughput_per_s(&self, wall_us: f64) -> f64 {
        if wall_us <= 0.0 {
            0.0
        } else {
            self.served as f64 / (wall_us / 1e6)
        }
    }
}

/// Per-shape cache slot. `done` is the published decision (lock-free reads
/// once set); `build` is the in-flight guard that makes concurrent misses
/// on one shape run the mapper exactly once.
#[derive(Default)]
struct ShapeSlot {
    done: OnceLock<Option<Decision>>,
    build: Mutex<()>,
}

/// The serving coordinator (leader). Owns the mapper cache and the batcher.
pub struct Server {
    cfg: ArchConfig,
    executor: Arc<dyn TileExecutor>,
    opts: MapperOptions,
    /// Shape → mapping decision routing table. `RwLock` so concurrent hits
    /// on *different* shapes share a read lock (the seed's `Mutex<HashMap>`
    /// serialized every lookup); per-shape `ShapeSlot`s de-duplicate
    /// concurrent mapper runs. Infeasible shapes cache `None` so repeat
    /// requests don't re-run a search that cannot succeed.
    cache: RwLock<HashMap<(usize, usize, usize), Arc<ShapeSlot>>>,
    pub stats: Mutex<ServeStats>,
    /// Max requests batched per dispatch.
    pub max_batch: usize,
}

impl Server {
    pub fn new(cfg: &ArchConfig, executor: Arc<dyn TileExecutor>) -> Self {
        Self {
            cfg: cfg.clone(),
            executor,
            opts: MapperOptions { full_layout_search: false, threads: 1, ..Default::default() },
            cache: RwLock::new(HashMap::new()),
            stats: Mutex::new(ServeStats::default()),
            max_batch: 8,
        }
    }

    /// Route a shape through the mapper (cached). Hot path: one shared
    /// cache read lock plus a lock-free `OnceLock` read and a single
    /// `Decision` clone (the seed took the exclusive cache mutex twice and
    /// cloned twice on a miss). The stats counter still takes the global
    /// stats mutex — held for one increment; fold it into atomics if it
    /// ever shows up in a profile.
    pub fn route(&self, m: usize, k: usize, n: usize) -> Option<Decision> {
        let key = (m, k, n);
        let slot = {
            let cache = self.cache.read().unwrap();
            cache.get(&key).cloned()
        };
        let slot = match slot {
            Some(s) => s,
            None => {
                let mut cache = self.cache.write().unwrap();
                Arc::clone(cache.entry(key).or_default())
            }
        };
        if let Some(d) = slot.done.get() {
            self.stats.lock().unwrap().mapper_cache_hits += 1;
            return d.clone();
        }
        // In-flight guard: first arrival builds, racers block here and then
        // read the published result. A panic inside a previous build only
        // poisons the guard, not any data (`done` is a OnceLock), so clear
        // the poison and retry rather than wedging this shape forever.
        let _build = slot.build.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(d) = slot.done.get() {
            self.stats.lock().unwrap().mapper_cache_hits += 1;
            return d.clone();
        }
        self.stats.lock().unwrap().mapper_cache_misses += 1;
        let g = Gemm::new("serve", "online", m, k, n);
        let d = search(&self.cfg, &g, &self.opts);
        let _ = slot.done.set(d.clone());
        d
    }

    /// Serve a batch of requests pulled from `rx`, sending responses on
    /// `tx`. Returns when `rx` closes. Requests with identical (M, K, N)
    /// and weight pointer-equality are batched by stacking their inputs
    /// into one taller GEMM (continuous batching for shared-weight layers).
    pub fn run(&self, rx: Receiver<Request>, tx: Sender<Response>) {
        let mut pending: Vec<Request> = Vec::new();
        loop {
            // Pull at least one request (blocking), then drain greedily.
            match rx.recv() {
                Ok(r) => pending.push(r),
                Err(_) => break,
            }
            while pending.len() < self.max_batch {
                match rx.try_recv() {
                    Ok(r) => pending.push(r),
                    Err(_) => break,
                }
            }
            // Group by shape + identical weights.
            while !pending.is_empty() {
                let head = pending.remove(0);
                let mut batch = vec![head];
                let (hm, hk, hn) = (batch[0].m, batch[0].k, batch[0].n);
                let hw = batch[0].weight.clone();
                pending.retain(|r| {
                    if batch.len() < self.max_batch
                        && (r.m, r.k, r.n) == (hm, hk, hn)
                        && r.weight == hw
                    {
                        batch.push(r.clone());
                        false
                    } else {
                        true
                    }
                });
                if self.dispatch(&batch, &tx).is_err() {
                    return; // receiver dropped
                }
            }
        }
    }

    fn dispatch(&self, batch: &[Request], tx: &Sender<Response>) -> Result<(), ()> {
        let t0 = std::time::Instant::now();
        let (m, k, n) = (batch[0].m, batch[0].k, batch[0].n);
        let bm = m * batch.len();
        let decision = self.route(bm, k, n);
        // Stack inputs into one (batch·M) × K GEMM.
        let mut stacked = Vec::with_capacity(bm * k);
        for r in batch {
            stacked.extend_from_slice(&r.input);
        }
        let out = match self.executor.gemm(bm, k, n, &stacked, &batch[0].weight) {
            Ok(o) => o,
            Err(_) => return Err(()),
        };
        let service_us = t0.elapsed().as_secs_f64() * 1e6;
        let modeled = decision.map(|d| d.report.total_cycles).unwrap_or(0.0);
        {
            let mut st = self.stats.lock().unwrap();
            st.served += batch.len() as u64;
            st.batches += 1;
            st.total_service_us += service_us * batch.len() as f64;
            st.max_batch = st.max_batch.max(batch.len());
        }
        for (bi, r) in batch.iter().enumerate() {
            let resp = Response {
                id: r.id,
                output: out[bi * m * n..(bi + 1) * m * n].to_vec(),
                service_us,
                modeled_cycles: modeled,
                batch_size: batch.len(),
            };
            tx.send(resp).map_err(|_| ())?;
        }
        Ok(())
    }
}

/// Spawn a server on its own thread; returns (request sender, response
/// receiver, join handle).
pub fn spawn(
    cfg: &ArchConfig,
    executor: Arc<dyn TileExecutor>,
) -> (Sender<Request>, Receiver<Response>, std::thread::JoinHandle<ServeStats>) {
    let (req_tx, req_rx) = channel::<Request>();
    let (resp_tx, resp_rx) = channel::<Response>();
    let server = Server::new(cfg, executor);
    let handle = std::thread::spawn(move || {
        server.run(req_rx, resp_tx);
        server.stats.lock().unwrap().clone()
    });
    (req_tx, resp_rx, handle)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Lcg;

    fn req(id: u64, m: usize, k: usize, n: usize, seed: u64) -> Request {
        let mut rng = Lcg::new(seed);
        Request {
            id,
            m,
            k,
            n,
            input: rng.f32_matrix(m, k),
            weight: {
                let mut wr = Lcg::new(999); // shared weights across requests
                wr.f32_matrix(k, n)
            },
        }
    }

    #[test]
    fn serves_and_answers_correctly() {
        let cfg = ArchConfig::paper(4, 4);
        let (tx, rx, h) = spawn(&cfg, Arc::new(NaiveExecutor));
        let r = req(7, 4, 8, 4, 1);
        let expect = NaiveExecutor.gemm(4, 8, 4, &r.input, &r.weight).unwrap();
        tx.send(r).unwrap();
        let resp = rx.recv().unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.output, expect);
        drop(tx);
        let stats = h.join().unwrap();
        assert_eq!(stats.served, 1);
    }

    #[test]
    fn batches_same_shape_shared_weights() {
        let cfg = ArchConfig::paper(4, 4);
        let (tx, rx, h) = spawn(&cfg, Arc::new(NaiveExecutor));
        for i in 0..6 {
            tx.send(req(i, 2, 8, 4, i)).unwrap();
        }
        // Give the queue a moment to fill before the server drains it.
        std::thread::sleep(std::time::Duration::from_millis(30));
        let mut got = 0;
        let mut max_batch = 0;
        while got < 6 {
            let r = rx.recv().unwrap();
            max_batch = max_batch.max(r.batch_size);
            got += 1;
        }
        drop(tx);
        let stats = h.join().unwrap();
        assert_eq!(stats.served, 6);
        assert!(stats.batches <= 6);
        assert!(max_batch >= 1);
    }

    #[test]
    fn mapper_cache_hits_on_repeat_shapes() {
        let cfg = ArchConfig::paper(4, 4);
        let server = Server::new(&cfg, Arc::new(NaiveExecutor));
        assert!(server.route(64, 40, 24).is_some());
        assert!(server.route(64, 40, 24).is_some());
        let st = server.stats.lock().unwrap();
        assert_eq!(st.mapper_cache_misses, 1);
        assert_eq!(st.mapper_cache_hits, 1);
    }

    #[test]
    fn naive_executor_rejects_bad_shapes() {
        assert!(NaiveExecutor.gemm(2, 2, 2, &[1.0; 3], &[1.0; 4]).is_err());
    }

    /// Concurrent misses on one shape run the mapper exactly once: the
    /// in-flight guard turns N racing routes into 1 miss + N−1 hits, and
    /// every caller gets the same decision.
    #[test]
    fn concurrent_misses_run_mapper_once() {
        let cfg = ArchConfig::paper(4, 4);
        let server = Arc::new(Server::new(&cfg, Arc::new(NaiveExecutor)));
        let n_threads: u64 = 8;
        let decisions: Vec<Option<f64>> = std::thread::scope(|s| {
            let mut handles = Vec::new();
            for _ in 0..n_threads {
                let srv = Arc::clone(&server);
                handles.push(s.spawn(move || {
                    srv.route(64, 40, 24).map(|d| d.report.total_cycles)
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert!(decisions.iter().all(|d| d.is_some()));
        assert!(decisions.windows(2).all(|w| w[0] == w[1]), "identical decisions");
        let st = server.stats.lock().unwrap();
        assert_eq!(st.mapper_cache_misses, 1, "mapper ran once");
        assert_eq!(st.mapper_cache_hits, n_threads - 1);
    }

    /// Infeasible shapes cache their `None` so repeats don't re-search.
    #[test]
    fn infeasible_shape_cached_as_none() {
        let mut cfg = ArchConfig::paper(4, 4);
        // Shrink buffers so no candidate fits.
        cfg.str_bytes = 4;
        cfg.sta_bytes = 4;
        cfg.ob_bytes = 16;
        let server = Server::new(&cfg, Arc::new(NaiveExecutor));
        assert!(server.route(1 << 20, 1 << 12, 1 << 12).is_none());
        assert!(server.route(1 << 20, 1 << 12, 1 << 12).is_none());
        let st = server.stats.lock().unwrap();
        assert_eq!(st.mapper_cache_misses, 1);
        assert_eq!(st.mapper_cache_hits, 1);
    }
}
